"""Roofline analysis from the dry-run artifacts (deliverable g).

Per (arch x shape x mesh):
  compute term    = HLO_FLOPs_per_chip / peak_FLOPs      [s]
  memory term     = HLO_bytes_per_chip / HBM_bw          [s]
  collective term = collective_bytes_per_chip / link_bw  [s]
(the dry-run stores per-partition numbers: cost_analysis runs on the
post-SPMD module, and collective bytes are parsed from per-partition HLO
shapes with a ring cost model — all-gather counts result bytes,
all-reduce counts 2x operand bytes.)

Also: MODEL_FLOPS (6*N_active*tokens for train, 2*N_active*tokens for
inference; embedding-table lookups excluded, lm_head included, MoE
experts scaled by top_k/n_experts) and the useful-compute ratio
MODEL_FLOPS / HLO_FLOPs, which surfaces remat recompute, padding waste,
and replicated-attention redundancy.
"""
from __future__ import annotations

import json
import math
import pathlib
import sys
from typing import Dict, List, Optional

PEAK_FLOPS = 197e12          # TPU v5e bf16
HBM_BW = 819e9               # B/s
LINK_BW = 50e9               # B/s per ICI link

ROOT = pathlib.Path(__file__).resolve().parents[1]
DRYRUN = ROOT / "results" / "dryrun"


def n_active_params(arch: str) -> float:
    """Non-embedding active params (MoE experts scaled by top_k/E)."""
    from repro import configs
    from repro.models import registry

    cfg = configs.get(arch)
    specs = registry.param_specs(cfg)
    import jax

    from repro.compat import tree_leaves_with_path

    total = 0.0
    for path, leaf in tree_leaves_with_path(specs):
        name = jax.tree_util.keystr(path)
        size = math.prod(leaf.shape)
        if "embed" in name and "lm_head" not in name:
            continue                      # lookup, not matmul
        if cfg.n_experts and any(w in name for w in
                                 ("w_gate", "w_up", "w_down")) \
                and "moe" in name:
            size *= cfg.top_k / cfg.n_experts
        total += size
    return total


def model_flops_per_chip(rec: Dict) -> float:
    from repro.launch import shapes as shp

    arch, shape_name = rec["arch"], rec["shape"]
    shape = shp.SHAPES[shape_name]
    n_act = n_active_params(arch)
    chips = rec["n_chips"]
    if shape.kind == "train":
        tokens = shape.batch * shape.seq
        return 6.0 * n_act * tokens / chips
    if shape.kind == "prefill":
        tokens = shape.batch * shape.seq
        return 2.0 * n_act * tokens / chips
    # decode: one token per sequence
    return 2.0 * n_act * shape.batch / chips


def corrected_for(rec: Dict, variant: str = "") -> Optional[Dict]:
    """Trip-count-corrected costs from launch/costcount.py, if present."""
    suffix = f"__{variant}" if variant else ""
    f = (DRYRUN.parent / "costs"
         / f"{rec['arch']}__{rec['shape']}__{rec['mesh']}{suffix}.json")
    if f.exists():
        c = json.loads(f.read_text())
        if c.get("status") == "ok":
            return c["corrected"]
    return None


def analyze(rec: Dict, variant: str = "") -> Optional[Dict]:
    if rec["status"] != "ok":
        return None
    corr = corrected_for(rec, variant)
    if corr is not None:
        flops = corr["flops"]
        bts = corr["bytes"]
        coll_bytes = corr["coll_bytes"]
        coll = {"count": corr["coll_count"]}
        source = f"corrected{'+' + variant if variant else ''}"
    else:
        flops = rec["flops_per_chip"]
        bts = rec["bytes_per_chip"]
        coll = rec["collectives"]
        coll_bytes = sum(v for k, v in coll.items() if k != "count")
        source = "raw"
    t_c = flops / PEAK_FLOPS
    t_m = bts / HBM_BW
    t_n = coll_bytes / LINK_BW
    terms = {"compute": t_c, "memory": t_m, "collective": t_n}
    dom = max(terms, key=terms.get)
    bound = max(t_c, t_m, t_n)
    mf = model_flops_per_chip(rec)
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "source": source,
        "compute_s": t_c, "memory_s": t_m, "collective_s": t_n,
        "dominant": dom,
        "step_s_lower_bound": bound,
        "roofline_frac": t_c / bound if bound > 0 else 0.0,
        "model_flops_per_chip": mf,
        "useful_ratio": mf / flops if flops > 0 else 0.0,
        "coll_count": coll["count"],
        "coll_bytes_per_chip": coll_bytes,
        "hbm_gb_per_chip": (rec["memory"]["argument_bytes"]
                            + rec["memory"]["temp_bytes"]
                            + rec["memory"]["output_bytes"]
                            - rec["memory"]["alias_bytes"]) / 2**30,
    }


def load_all(mesh: str = "16x16", variant: str = "") -> List[Dict]:
    rows = []
    for f in sorted(DRYRUN.glob(f"*__{mesh}.json")):
        rec = json.loads(f.read_text())
        row = analyze(rec, variant)
        if row:
            rows.append(row)
    return rows


def print_table(rows: List[Dict], out=sys.stdout) -> None:
    cols = ["arch", "shape", "mesh", "source", "compute_s", "memory_s",
            "collective_s", "dominant", "roofline_frac", "useful_ratio",
            "hbm_gb_per_chip"]
    print(",".join(cols), file=out)
    for r in rows:
        vals = [f"{r[c]:.4g}" if isinstance(r[c], float) else str(r[c])
                for c in cols]
        print(",".join(vals), file=out)


def main() -> None:
    out_dir = ROOT / "results"
    out_dir.mkdir(exist_ok=True)
    all_rows = []
    for mesh in ("16x16", "2x16x16"):
        rows = load_all(mesh)
        all_rows.extend(rows)
    with open(out_dir / "roofline.csv", "w") as f:
        print_table(all_rows, f)
    print_table(all_rows)
    print(f"\n{len(all_rows)} cells analyzed -> results/roofline.csv",
          file=sys.stderr)


if __name__ == "__main__":
    main()
