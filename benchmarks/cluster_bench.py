"""Cluster-scale simulation benchmark: 512-chip training of the assigned
architectures under LiveStack, validated against the closed-form roofline
and exercised with stragglers/failures (what closed forms cannot do).
"""
from __future__ import annotations

import json
import pathlib
import time

ROOT = pathlib.Path(__file__).resolve().parents[1]


def simulate(arch: str = "qwen3_4b", shape: str = "train_4k",
             n_steps: int = 5, straggler: bool = False,
             multi_pod: bool = True) -> dict:
    from repro.core.cluster import (ClusterSpec, StepCost, StragglerSpec,
                                    analytic_step_ns,
                                    build_training_cluster)
    from repro.core.vtime import SEC

    spec = ClusterSpec(n_pods=2 if multi_pod else 1, chips_per_pod=256)
    try:
        cost = StepCost.from_dryrun(arch, shape,
                                    "2x16x16" if multi_pod else "16x16")
    except Exception:
        cost = StepCost(compute_ns=5_000_000, ici_bytes=50_000_000)
    cost.dcn_bytes = cost.ici_bytes // 8
    stragglers = (StragglerSpec(chip=7, slowdown=2.0),) if straggler \
        else ()
    sched, tasks, ctx = build_training_cluster(
        spec, cost, n_steps, stragglers=stragglers)
    t0 = time.perf_counter()
    sched.run()
    wall = time.perf_counter() - t0
    sim_ns = max(t.vtime for t in tasks)
    analytic_ns = analytic_step_ns(spec, cost) * n_steps
    return {
        "arch": arch, "n_chips": spec.n_chips, "n_steps": n_steps,
        "straggler": straggler,
        "sim_step_ms": sim_ns / n_steps / 1e6,
        "analytic_step_ms": analytic_ns / n_steps / 1e6,
        "ratio": sim_ns / max(analytic_ns, 1),
        "wall_s": wall,
        "sim_speed": (sim_ns / SEC) / wall,     # simulated s per wall s
        "messages": sum(h.stats["messages"] for h in ctx["hubs"]),
        "done_steps_min": int(ctx["done_steps"].min()),
    }


def main():
    rows = []
    for arch in ("qwen3_4b", "olmoe_1b_7b"):
        rows.append(simulate(arch, straggler=False))
        rows.append(simulate(arch, straggler=True))
    out = ROOT / "results" / "cluster_bench.json"
    out.parent.mkdir(exist_ok=True)
    out.write_text(json.dumps(rows, indent=2))
    print(f"{'arch':16s} {'strag':>6s} {'sim ms/step':>12s} "
          f"{'analytic':>9s} {'ratio':>6s} {'msgs':>8s} {'wall_s':>7s}")
    for r in rows:
        print(f"{r['arch']:16s} {str(r['straggler']):>6s} "
              f"{r['sim_step_ms']:12.2f} {r['analytic_step_ms']:9.2f} "
              f"{r['ratio']:6.2f} {r['messages']:8d} {r['wall_s']:7.2f}")
    return rows


if __name__ == "__main__":
    main()
