"""Cluster-scale simulation benchmark: 512-chip training of the assigned
architectures under LiveStack, validated against the closed-form roofline
and exercised with stragglers/failures (what closed forms cannot do) —
driven through the declarative `repro.sim` facade.

Also the orchestration-engine head-to-head (``simulate_multihost`` /
``main_multihost``): a >=4-host heterogeneous-latency topology (fast
intra-rack + slow cross-rack links) run under ``mode="barrier"``
(global-min-latency epochs), ``mode="async"`` (per-link-lookahead
conservative PDES), and the multi-process ``dist`` engine with 1 and K
OS worker processes.  All engines must produce identical simulation
results; the bench records each engine's synchronization cost (rounds,
proxy syncs, per-round overhead) and dispatch throughput.  Two regimes
track the hot path PR-over-PR:

* **rack** (4 hosts, fine-grained) — coordination-overhead-dominated;
  this is where the coalesced binary dist transport shows up.
* **large** (64 hosts / 2048 sharded chips) — scale regime for the
  indexed scheduler + incremental LBTS; barrier is skipped here (its
  per-min-latency epochs are exactly the cost the async engine
  removes).
* **cells** (interference-heavy, co-located live workers bound to §3.3
  memory-hierarchy cells) — every live call prices spatial interference
  and warm-slot reconditioning, so this regime tracks the cell hot path
  (the per-host live-cell multiset that replaced the O(tasks) coactive
  scan); ``--smoke`` asserts its dispatch throughput stays above the
  PR-4 scheduler floor.
* **vectorized** (same rack scenario through the compiled array
  engine) — one more row in the multihost head-to-head, held to the
  same bit-identical-results assertion as the rest of the matrix
  (exact-tier conformance on real bench inputs, not just unit tests).
* **sweep** (vmap batched configuration exploration) — V straggler
  variants of the rack scenario in one ``Simulation.sweep`` dispatch;
  records configs/s and the speedup over running the same variants
  through sequential vectorized runs.
* **live_recovery** (recorded-cost replay of the marquee live
  scenario) — the real sharded trainer's failure-recovery trace
  (tests/golden/live_recovery_trace.json) replayed under async and
  dist; records the recovery window (detect -> resumed vtime span) and
  holds replay dispatch throughput above the scheduler floor, so the
  live replay path stays on the hot-path budget.
* **live_serve** (recorded-cost replay of the live serving scenario) —
  the real BatchServer's prefill/decode trace
  (tests/golden/live_serve_trace.json) replayed under async and dist;
  records the simulated p50/p99 time-in-system and wave count, and
  holds the same dispatch-throughput floor as live_recovery.

Outputs (single writer: everything is derived from the root schema):
  BENCH_cluster.json              — compact aggregates-only summary
                                    (schema BENCH_cluster/v7, documented
                                    in README.md), committed at the repo
                                    root so the perf trajectory stays
                                    reviewable PR-over-PR
  results/cluster_bench.json      — derived: the root schema's
                                    ``training`` rows
  results/orchestrator_bench.json — derived: the root schema's
                                    ``multihost`` table
"""
from __future__ import annotations

import argparse
import json
import os
import pathlib

try:        # as a package (benchmarks.run) or as a script
    from benchmarks.sched_scale import SEED_REFERENCE_4096_DISPATCH_PER_S
except ImportError:   # pragma: no cover - script invocation
    from sched_scale import SEED_REFERENCE_4096_DISPATCH_PER_S

ROOT = pathlib.Path(__file__).resolve().parents[1]

#: OS worker count for the dist engine rows ("K" in BENCH_cluster)
DIST_WORKERS = 2

#: the dist engine forks OS workers; skip its rows where fork is absent
HAS_FORK = hasattr(os, "fork")


def _aggregate(report) -> dict:
    """The compact BENCH_cluster/v3 per-run record: aggregates only,
    plus the two hot-path-overhead derived columns (per-sync-round
    wall overhead and dispatch throughput)."""
    dispatches = sum(h.dispatches for h in report.hosts)
    return {
        "status": report.status,
        "n_hosts": report.n_hosts,
        "n_workers": report.n_workers,
        "sync_rounds": report.sync_rounds,
        "proxy_syncs": report.proxy_syncs,
        "cross_host_msgs": report.cross_host_msgs,
        "messages": report.messages,
        "bytes": report.bytes,
        "vtime_ns": report.vtime_ns,
        "wall_s": round(report.wall_s, 4),
        "dispatches": dispatches,
        "round_overhead_us": round(
            report.wall_s / max(report.sync_rounds, 1) * 1e6, 2),
        "dispatch_per_s": round(
            dispatches / max(report.wall_s, 1e-9)),
        "max_window_ns": report.max_window_ns,
        "max_proxy_staleness_ns": report.max_proxy_staleness_ns,
    }


def simulate_multihost(engine: str, *, n_workers: int = DIST_WORKERS,
                       n_racks: int = 2, hosts_per_rack: int = 2,
                       n_iters: int = 300, rack_slowdown=(1.0, 3.0),
                       skew_bound_ns: int = 2_000_000) -> dict:
    """One engine run on the heterogeneous rack topology.  ``engine``
    is ``"barrier"``/``"async"`` or ``"dist"`` (with ``n_workers`` OS
    worker processes)."""
    from repro.sim import RackRing, Scenario, Simulation, Topology

    wl = RackRing(n_racks=n_racks, hosts_per_rack=hosts_per_rack,
                  n_iters=n_iters, skew_bound_ns=skew_bound_ns)
    sim = Simulation(
        Topology.racks(n_racks, hosts_per_rack), wl,
        Scenario("imbalanced racks", wl.stragglers(rack_slowdown)),
        placement=wl.default_placement(),
    )
    if engine == "dist":
        report = sim.run(engine="dist", n_workers=n_workers,
                         on_deadlock="raise")
    elif engine == "vectorized":
        report = sim.run(engine="vectorized", on_deadlock="raise")
        assert report.tier == "exact", report.tier
    else:
        report = sim.run(engine=engine, on_deadlock="raise")
    assert all(t["state"] == "done" for t in report.tasks.values())
    row = _aggregate(report)
    row["engine"] = engine
    row["final_vtimes"] = [report.tasks[f"w{h}"]["vtime"]
                           for h in range(wl.n_workers)]
    return row


def _engine_rows(engines, **kwargs) -> dict:
    rows = {}
    for name, engine, n_workers in engines:
        rows[name] = simulate_multihost(engine, n_workers=n_workers,
                                        **kwargs)
    vt = {k: r["final_vtimes"] for k, r in rows.items()}
    base = next(iter(rows))
    assert all(v == vt[base] for v in vt.values()), \
        "engines disagree on simulation results"
    assert all(r["messages"] == rows[base]["messages"]
               for r in rows.values())
    return rows


def main_multihost() -> dict:
    engines = [("barrier", "barrier", 1), ("async", "async", 1),
               ("vectorized", "vectorized", 1)]
    if HAS_FORK:
        engines += [("dist_1w", "dist", 1),
                    (f"dist_{DIST_WORKERS}w", "dist", DIST_WORKERS)]
    rows = _engine_rows(engines)
    b, a = rows["barrier"], rows["async"]
    assert a["sync_rounds"] < b["sync_rounds"], \
        (a["sync_rounds"], b["sync_rounds"])
    print(f"orchestration engines, {b['n_hosts']} hosts, "
          f"2us intra-rack / 50us cross-rack, imbalanced racks:")
    print(f"{'engine':>10s} {'workers':>7s} {'rounds':>7s} "
          f"{'proxy_syncs':>12s} {'msgs':>6s} {'sim_ms':>7s} "
          f"{'wall_s':>7s} {'us/round':>8s}")
    for name, r in rows.items():
        print(f"{r['engine']:>10s} {r['n_workers']:7d} "
              f"{r['sync_rounds']:7d} {r['proxy_syncs']:12d} "
              f"{r['messages']:6d} {r['vtime_ns']/1e6:7.2f} "
              f"{r['wall_s']:7.3f} {r['round_overhead_us']:8.1f}")
    print(f"async speedup: {b['sync_rounds']/a['sync_rounds']:.2f}x fewer "
          f"rounds, {b['proxy_syncs']/max(a['proxy_syncs'],1):.0f}x fewer "
          f"proxy syncs, identical results")
    if HAS_FORK:
        d1 = rows["dist_1w"]
        print(f"dist transport: dist_1w wall "
              f"{d1['wall_s']/max(a['wall_s'], 1e-9):.2f}x in-process "
              f"async (acceptance bar: <= 3x), identical results")
    return rows


def main_multihost_large(n_racks: int = 16, hosts_per_rack: int = 4,
                         n_iters: int = 60) -> dict:
    """The >=64-host regime: scale stress for the indexed scheduler,
    incremental LBTS bounds, and quiescent-host skipping.  Barrier is
    deliberately absent — one epoch per global min-latency window at 64
    hosts is the overhead the async engine exists to remove."""
    engines = [("async", "async", 1)]
    if HAS_FORK:
        engines += [("dist_1w", "dist", 1),
                    ("dist_4w", "dist", 4)]
    rows = _engine_rows(engines, n_racks=n_racks,
                        hosts_per_rack=hosts_per_rack, n_iters=n_iters,
                        rack_slowdown=(1.0, 3.0) * (n_racks // 2))
    a = rows["async"]
    print(f"large regime: {a['n_hosts']} hosts, "
          f"{a['dispatches']} dispatches:")
    for name, r in rows.items():
        print(f"{name:>10s} x{r['n_workers']}: {r['sync_rounds']} "
              f"rounds, wall {r['wall_s']:.3f}s, "
              f"{r['dispatch_per_s']} disp/s")
    return rows


def simulate_cells(engine: str = "async", *, n_hosts: int = 4,
                   workers_per_host: int = 2, n_iters: int = 400,
                   n_workers: int = DIST_WORKERS) -> dict:
    """The cells regime: co-located live ring workers bound to §3.3
    memory-hierarchy cells (one contended + one cool cell per host,
    warm slots scarcer than cells so every switch reconditions).  Hosts
    dispatch serially (n_cpus=1), the regime where cell state is
    engine-exact."""
    from repro.sim import RackRing, Scenario, Simulation, Topology

    n = n_hosts * workers_per_host
    cells = {f"w{i}": f"cell{i % workers_per_host}" for i in range(n)}
    wl = RackRing(n_racks=n_hosts, hosts_per_rack=workers_per_host,
                  n_iters=n_iters, compute_ns=20_000, cross_every=10,
                  live=True, cells=cells, skew_bound_ns=2_000_000)
    topo = Topology(n_hosts=n_hosts, n_cpus=1)
    topo.cell("cell0", ways=3, working_set_frac=0.65, bw_share=0.4,
              bw_demand=0.7, mem_frac=0.6)
    if workers_per_host > 1:
        topo.cell("cell1", ways=6, working_set_frac=0.4, bw_share=0.5,
                  bw_demand=0.45, mem_frac=0.3)
    topo.cell_config(n_warm_slots=1, recondition_ns=20_000)
    sim = Simulation(
        topo, wl, Scenario("cells"),
        placement={f"w{i}": i // workers_per_host for i in range(n)})
    if engine == "dist":
        report = sim.run(engine="dist", n_workers=n_workers,
                         on_deadlock="raise")
    else:
        report = sim.run(engine=engine, on_deadlock="raise")
    assert all(t["state"] == "done" for t in report.tasks.values())
    row = _aggregate(report)
    row["engine"] = engine
    row["cell_switches"] = sum(c["switches"]
                               for c in report.cells.values())
    row["cell_recondition_ns"] = sum(c["recondition_ns"]
                                     for c in report.cells.values())
    row["interference_events"] = sum(c["interference_events"]
                                     for c in report.cells.values())
    row["final_vtimes"] = [report.tasks[f"w{i}"]["vtime"]
                           for i in range(n)]
    row["cell_report"] = report.cells
    return row


def main_cells() -> dict:
    engines = [("async", "async", 1)]
    if HAS_FORK:
        engines += [(f"dist_{DIST_WORKERS}w", "dist", DIST_WORKERS)]
    rows = {}
    for name, engine, k in engines:
        rows[name] = simulate_cells(engine, n_workers=k)
    base = next(iter(rows))
    assert all(r["final_vtimes"] == rows[base]["final_vtimes"]
               and r["cell_report"] == rows[base]["cell_report"]
               for r in rows.values()), \
        "engines disagree on cell-enabled simulation results"
    a = rows["async"]
    print(f"cells regime: {a['n_hosts']} hosts x 2 live workers in "
          f"cells, {a['dispatches']} dispatches:")
    for name, r in rows.items():
        print(f"{name:>10s} x{r['n_workers']}: wall {r['wall_s']:.3f}s, "
              f"{r['dispatch_per_s']} disp/s, "
              f"{r['interference_events']} interference events, "
              f"{r['cell_switches']} switches "
              f"({r['cell_recondition_ns']/1e6:.2f} ms reconditioned)")
    return rows


def smoke_cells() -> None:
    """CI smoke: the cells regime must emit its stats and keep dispatch
    throughput above the PR-4 scheduler floor (half the seed
    scheduler's 4096-task baseline — generous headroom for loaded
    runners, trips only on a real cell-hot-path regression)."""
    row = simulate_cells("async", n_hosts=2, n_iters=150)
    assert row["status"] == "ok", row
    assert row["interference_events"] > 0, row
    assert row["cell_switches"] > 0, row
    assert row["cell_recondition_ns"] > 0, row
    floor = SEED_REFERENCE_4096_DISPATCH_PER_S / 2
    assert row["dispatch_per_s"] > floor, (row["dispatch_per_s"], floor)
    print(f"cells smoke ok: {row['dispatch_per_s']} disp/s with cells "
          f"active (floor {floor:.0f}), "
          f"{row['interference_events']} interference events, "
          f"{row['cell_switches']} switches")


def simulate_live_recovery(engine: str = "async", *,
                           n_workers: int = DIST_WORKERS) -> dict:
    """One replay of the recorded marquee trace under ``engine``.  Pure
    replay: pinned integer costs, no JAX work — the row measures the
    live subsystem's scheduling overhead and the recovery window."""
    from repro.live import CostLedger
    from repro.sim import live_recovery_sim, recovery_timeline

    trace = ROOT / "tests" / "golden" / "live_recovery_trace.json"
    sim = live_recovery_sim(CostLedger.replay(trace))
    if engine == "dist":
        report = sim.run(engine="dist", n_workers=n_workers,
                         on_deadlock="raise")
    else:
        report = sim.run(engine=engine, on_deadlock="raise")
    assert report.status == "ok", report.detail
    tl = recovery_timeline(report)
    v = {e["event"]: e["vtime"] for e in tl}
    assert v["detect"] < v["restore"] < v["remesh"] <= v["resumed"], tl
    row = _aggregate(report)
    row["engine"] = engine
    row["recovery_ns"] = v["resumed"] - v["detect"]
    row["restore_ns"] = v["restore"] - v["detect"]
    row["remesh_ns"] = v["remesh"] - v["restore"]
    row["final_vtimes"] = sorted(t["vtime"]
                                 for t in report.tasks.values())
    row["live_section"] = report.to_dict()["live"]
    return row


def main_live_recovery() -> dict:
    engines = [("async", "async", 1)]
    if HAS_FORK:
        engines += [(f"dist_{DIST_WORKERS}w", "dist", DIST_WORKERS)]
    rows = {}
    for name, engine, k in engines:
        rows[name] = simulate_live_recovery(engine, n_workers=k)
    base = next(iter(rows))
    assert all(r["final_vtimes"] == rows[base]["final_vtimes"]
               and r["live_section"] == rows[base]["live_section"]
               for r in rows.values()), \
        "engines disagree on the live recovery replay"
    a = rows["async"]
    print(f"live recovery regime (recorded-cost replay, "
          f"{a['n_hosts']} hosts):")
    for name, r in rows.items():
        print(f"{name:>10s} x{r['n_workers']}: recovery window "
              f"{r['recovery_ns']/1e6:.1f} ms (restore "
              f"{r['restore_ns']/1e6:.1f} + remesh "
              f"{r['remesh_ns']/1e6:.1f}), wall {r['wall_s']:.3f}s, "
              f"{r['dispatch_per_s']} disp/s")
    return rows


def smoke_live_recovery() -> None:
    """CI smoke: the recorded marquee trace must replay cleanly with an
    ordered recovery timeline, and the replay path's dispatch
    throughput must clear the same generous floor as the cells regime
    (half the seed scheduler's 4096-task baseline) — live replay is
    modeled-cost scheduling and must stay on that budget."""
    row = simulate_live_recovery("async")
    assert row["recovery_ns"] > 0, row
    floor = SEED_REFERENCE_4096_DISPATCH_PER_S / 2
    assert row["dispatch_per_s"] > floor, (row["dispatch_per_s"], floor)
    print(f"live recovery smoke ok: recovery window "
          f"{row['recovery_ns']/1e6:.1f} ms, {row['dispatch_per_s']} "
          f"disp/s (floor {floor:.0f})")


def simulate_live_serve(engine: str = "async", *,
                        n_workers: int = DIST_WORKERS) -> dict:
    """One replay of the recorded serve trace under ``engine``: the
    real BatchServer's per-wave costs as pinned integers, open-loop
    arrivals from the trace meta — no JAX work.  The row records the
    simulated latency percentiles alongside the replay path's
    scheduling overhead."""
    from repro.live import CostLedger
    from repro.sim import live_serve_sim, serve_latency

    trace = ROOT / "tests" / "golden" / "live_serve_trace.json"
    sim = live_serve_sim(CostLedger.replay(trace))
    if engine == "dist":
        report = sim.run(engine="dist", n_workers=n_workers,
                         on_deadlock="raise")
    else:
        report = sim.run(engine=engine, on_deadlock="raise")
    assert report.status == "ok", report.detail
    lat = serve_latency(report)
    task = report.to_dict()["live"]["live_serve"]["tasks"]["serve.live"]
    row = _aggregate(report)
    row["engine"] = engine
    row["requests"] = task["requests"]
    row["waves"] = task["waves"]
    row["latency_p50_ns"] = lat["p50"]
    row["latency_p99_ns"] = lat["p99"]
    row["queue_depth_max"] = task["queue_depth"]["max"]
    row["final_vtimes"] = sorted(t["vtime"]
                                 for t in report.tasks.values())
    row["live_section"] = report.to_dict()["live"]
    return row


def main_live_serve() -> dict:
    engines = [("async", "async", 1)]
    if HAS_FORK:
        engines += [(f"dist_{DIST_WORKERS}w", "dist", DIST_WORKERS)]
    rows = {}
    for name, engine, k in engines:
        rows[name] = simulate_live_serve(engine, n_workers=k)
    base = next(iter(rows))
    assert all(r["final_vtimes"] == rows[base]["final_vtimes"]
               and r["live_section"] == rows[base]["live_section"]
               for r in rows.values()), \
        "engines disagree on the live serve replay"
    a = rows["async"]
    print(f"live serve regime (recorded-cost replay, "
          f"{a['requests']} requests in {a['waves']} waves):")
    for name, r in rows.items():
        print(f"{name:>10s} x{r['n_workers']}: p50 "
              f"{r['latency_p50_ns']/1e6:.1f} ms, p99 "
              f"{r['latency_p99_ns']/1e6:.1f} ms, max queue depth "
              f"{r['queue_depth_max']}, wall {r['wall_s']:.3f}s, "
              f"{r['dispatch_per_s']} disp/s")
    return rows


def smoke_live_serve() -> None:
    """CI smoke: the recorded serve trace must replay cleanly with
    ordered latency percentiles, and the replay path must hold the
    same dispatch-throughput floor as the other live regimes."""
    row = simulate_live_serve("async")
    assert row["requests"] > 0 and row["waves"] > 0, row
    assert 0 < row["latency_p50_ns"] <= row["latency_p99_ns"], row
    floor = SEED_REFERENCE_4096_DISPATCH_PER_S / 2
    assert row["dispatch_per_s"] > floor, (row["dispatch_per_s"], floor)
    print(f"live serve smoke ok: p50 {row['latency_p50_ns']/1e6:.1f} ms"
          f", p99 {row['latency_p99_ns']/1e6:.1f} ms over "
          f"{row['requests']} requests, {row['dispatch_per_s']} disp/s "
          f"(floor {floor:.0f})")


def main_sweep(n_variants: int = 32, *, n_iters: int = 300,
               warm: bool = True) -> dict:
    """The vmap batched-sweep regime: ``n_variants`` straggler variants
    of the rack scenario in one ``Simulation.sweep`` dispatch, compared
    against running the same variants through sequential vectorized
    ``run()`` calls (both jit-warmed, so the ratio isolates the batching
    win, not compile time)."""
    import time

    from repro.sim import RackRing, Scenario, Simulation, Straggler, \
        Topology

    def make(sc=None):
        wl = RackRing(n_racks=2, hosts_per_rack=2, n_iters=n_iters,
                      skew_bound_ns=2_000_000)
        return Simulation(Topology.racks(2, 2), wl, sc,
                          placement=wl.default_placement())

    axis = [Scenario(f"v{i}", (Straggler(f"w{i % 4}",
                                         1.0 + (i % 5) * 0.5),))
            for i in range(n_variants)]
    if warm:
        make().sweep(axis)              # compile the batched loop
    res = make().sweep(axis)
    # sequential baseline: the same variants, one vectorized run each
    # (second variant timed so its tape shape is already compiled)
    make(axis[0]).run(engine="vectorized")
    t0 = time.perf_counter()
    solo_reports = [make(sc).run(engine="vectorized") for sc in axis]
    solo_wall = time.perf_counter() - t0
    for sc, batched, solo in zip(axis, res.reports, solo_reports):
        assert batched.tasks == solo.tasks, \
            f"sweep lane diverged from solo run on {sc.name}"
    row = {
        "n_variants": n_variants,
        "n_hosts": res.reports[0].n_hosts,
        "tick_ns": res.tick_ns,
        "tier": res.tier,
        "wall_s": round(res.wall_s, 4),
        "configs_per_s": round(res.configs_per_s, 1),
        "solo_vectorized_wall_s": round(solo_wall, 4),
        "speedup_vs_sequential": round(
            solo_wall / max(res.wall_s, 1e-9), 2),
        "bit_identical_to_solo": True,
    }
    print(f"sweep regime: {n_variants} variants in {row['wall_s']:.3f}s "
          f"({row['configs_per_s']:.1f} configs/s, "
          f"{row['speedup_vs_sequential']:.1f}x vs sequential "
          f"vectorized runs, bit-identical lanes)")
    return row


def smoke_vectorized() -> None:
    """CI smoke for the compiled engine on bench inputs: the vectorized
    row must be bit-identical to async on the rack scenario, and a small
    sweep must be bit-identical lane-for-lane to solo runs."""
    ref = simulate_multihost("async", n_iters=40)
    vec = simulate_multihost("vectorized", n_iters=40)
    assert vec["final_vtimes"] == ref["final_vtimes"], (vec, ref)
    assert vec["messages"] == ref["messages"]
    assert vec["vtime_ns"] == ref["vtime_ns"]
    row = main_sweep(8, n_iters=40, warm=False)
    assert row["bit_identical_to_solo"]
    print(f"vectorized smoke ok: bit-identical to async on the rack "
          f"scenario ({vec['dispatch_per_s']} disp/s), sweep lanes "
          f"bit-identical to solo runs")


def main_campaign() -> dict:
    """The fault-campaign regime: sweep the registered serve_smoke@v1
    grid (bitflip/fail_task/fail_host/straggler x client x vtime) and
    the rack_ring@v1 grid (which exercises the vectorized sweep fast
    path for its admissible points), reporting points/s, the outcome
    histogram, and minimized-reproducer counts."""
    from repro.sim import Campaign, registry

    rows = {}
    for ref in ("serve_smoke@v1", "rack_ring@v1"):
        ent = registry.entry(ref)
        report = Campaign(ent.make, ent.grid(), seed=0,
                          base_name=ent.ref).run()
        rows[ent.name] = {
            "n_points": report.grid["n_points"],
            "shape": report.grid["shape"],
            "fast_path": report.fast_path,
            "histogram": report.histogram,
            "n_reproducers": len(report.reproducers),
            "wall_s": round(report.wall_s, 4),
            "points_per_s": round(report.points_per_s, 1),
        }
        print(f"campaign regime {ent.ref}: {report.grid['n_points']} "
              f"points in {report.wall_s:.3f}s "
              f"({report.points_per_s:.1f} pts/s, "
              f"fast_path={report.fast_path}), histogram "
              f"{report.histogram}, "
              f"{len(report.reproducers)} minimized reproducers")
    return rows


def smoke_campaign() -> None:
    """CI smoke for the campaign harness on bench inputs: the serve
    grid must land its pinned histogram with byte-stable minimized
    reproducers (delegates to the campaign CLI's own smoke gate), and
    the registry's pinned goldens must still replay."""
    from repro.sim import registry
    from repro.sim.campaign import _cmd_smoke

    assert _cmd_smoke() == 0
    failures = registry.check(["rack_ring@v1", "serve_smoke@v1",
                               "bitflip_serve@v1", "clock_skew_rack@v1",
                               "serve_flip_min@v1"])
    assert not failures, failures
    print("campaign smoke ok: pinned histogram + byte-stable "
          "reproducers, modeled registry goldens replay")


def _control_sim(*, n_pool: int, founding: int, n_arrivals: int,
                 seed: int = 5):
    """A CI-sized autoscaled fleet: ``founding`` hosts at vtime 0, the
    rest of the pool joining the cluster mid-run on a staggered
    capacity schedule, one diurnal traffic period."""
    from repro.sim import (AutoscaledServe, Scenario, Simulation,
                           ThresholdAutoscaler, Topology,
                           diurnal_arrivals)

    join0, stagger = 20_000_000, 500_000
    topo = Topology(n_hosts=n_pool + 1, n_cpus=2)
    topo.capacity_pool(range(founding + 1, n_pool + 1), join0,
                       stagger_ns=stagger)
    ready = [0] * founding + [join0 + i * stagger
                              for i in range(n_pool - founding)]
    wl = AutoscaledServe(
        arrivals=diurnal_arrivals(n_arrivals, base_gap_ns=1_000_000,
                                  peak_gap_ns=60_000,
                                  period_ns=100_000_000, seed=seed),
        n_pool=n_pool, ready_ns=ready, service_ns=400_000,
        min_active=founding, decide_every=8, probe_every=4,
        autoscaler=ThresholdAutoscaler(patience=2),
        placement="worst_fit")
    return Simulation(topo, wl, Scenario("diurnal autoscale bench"),
                      placement=wl.default_placement())


def simulate_control_plane(engine: str = "async", *,
                           n_workers: int = DIST_WORKERS,
                           marquee: bool = True) -> dict:
    """One run of the membership + control-plane regime.  ``marquee``
    uses the registered 65-host diurnal_autoscale@v1 scenario (60
    hosts joining mid-run, 4->64->4); the smoke variant is a downsized
    9-host fleet with the same machinery."""
    from repro.sim import registry

    if marquee:
        sim = registry.load("diurnal_autoscale@v1")
    else:
        sim = _control_sim(n_pool=8, founding=4, n_arrivals=700)
    if engine == "dist":
        report = sim.run(engine="dist", n_workers=n_workers,
                         on_deadlock="raise")
    else:
        report = sim.run(engine=engine, on_deadlock="raise")
    assert report.status == "ok", report.detail
    sec = report.control["autoserve"]
    moves = [(d["from"], d["to"]) for d in sec["decisions"]
             if d["from"] != d["to"]]
    row = _aggregate(report)
    row["engine"] = engine
    row["final_vtimes"] = sorted(t["vtime"]
                                 for t in report.tasks.values())
    row["control_section"] = report.to_dict()["control"]
    row["n_joins"] = sum(1 for e in report.control["membership"]
                         if e["event"] == "join")
    row["scale_ups"] = sum(1 for a, b in moves if b > a)
    row["scale_downs"] = sum(1 for a, b in moves if b < a)
    row["peak_active"] = sec["peak_active"]
    row["served"] = sec["served"]
    row["latency_p50_ns"] = sec["latency_ns"]["p50"]
    row["latency_p99_ns"] = sec["latency_ns"]["p99"]
    return row


def main_control_plane() -> dict:
    engines = [("async", "async", 1)]
    if HAS_FORK:
        engines += [(f"dist_{DIST_WORKERS}w", "dist", DIST_WORKERS)]
    rows = {}
    for name, engine, k in engines:
        rows[name] = simulate_control_plane(engine, n_workers=k)
    base = next(iter(rows))
    assert all(r["final_vtimes"] == rows[base]["final_vtimes"]
               and r["control_section"] == rows[base]["control_section"]
               for r in rows.values()), \
        "engines disagree on the control-plane simulation"
    a = rows["async"]
    print(f"control-plane regime ({a['n_hosts']} hosts, {a['n_joins']} "
          f"joining mid-run):")
    for name, r in rows.items():
        print(f"{name:>10s} x{r['n_workers']}: peak {r['peak_active']} "
              f"active ({r['scale_ups']} ups / {r['scale_downs']} "
              f"downs), {r['served']} served, "
              f"p99 {r['latency_p99_ns']/1e6:.2f} ms, "
              f"wall {r['wall_s']:.3f}s, {r['dispatch_per_s']} disp/s")
    return rows


def smoke_control_plane() -> None:
    """CI smoke: the autoscaled fleet must scale up AND back down from
    observed traffic alone, keep the simulated request p99 finite and
    bounded (50x the service time — generous, trips only if the
    control plane stops tracking load), and hold dispatch throughput
    above the shared scheduler floor."""
    row = simulate_control_plane("async", marquee=False)
    assert row["scale_ups"] > 0, row
    assert row["scale_downs"] > 0, row
    assert 0 < row["latency_p99_ns"] < 50 * 400_000, row
    floor = SEED_REFERENCE_4096_DISPATCH_PER_S / 2
    assert row["dispatch_per_s"] > floor, (row["dispatch_per_s"], floor)
    print(f"control-plane smoke ok: {row['n_joins']} joins, "
          f"{row['scale_ups']} ups / {row['scale_downs']} downs, "
          f"p99 {row['latency_p99_ns']/1e6:.2f} ms, "
          f"{row['dispatch_per_s']} disp/s (floor {floor:.0f})")


def simulate_sharded_dist(*, n_chips: int = 512, n_hosts: int = 4,
                          n_steps: int = 3) -> dict:
    """The dist engine's parallelism case: a training ring sharded
    across hosts (heavy per-window dispatch work, few sync rounds), run
    with 1 vs K OS worker processes and checked bit-identical to the
    in-process async engine."""
    from repro.core.cluster import ClusterSpec, StepCost
    from repro.sim import ChipRingTraining, Simulation, Topology

    def make():
        spec = ClusterSpec(n_pods=n_hosts,
                           chips_per_pod=n_chips // n_hosts)
        cost = StepCost(compute_ns=5_000_000, ici_bytes=50_000_000,
                        dcn_bytes=6_000_000)
        wl = ChipRingTraining(spec, cost, n_steps,
                              skew_bound_ns=1_000_000)
        return Simulation(Topology(n_hosts=n_hosts, n_cpus=128), wl,
                          capacity=n_chips // n_hosts)

    ref = make().run(engine="async", on_deadlock="raise")
    runs = {k: make().run(engine="dist", n_workers=k,
                          on_deadlock="raise")
            for k in (1, DIST_WORKERS)}
    for r in runs.values():
        assert r.tasks == ref.tasks, "dist diverged from async"
    d1, dk = runs[1], runs[DIST_WORKERS]
    return {
        "n_chips": n_chips, "n_hosts": n_hosts, "n_steps": n_steps,
        "workers": DIST_WORKERS,
        "cross_partition_sync_rounds": dk.sync_rounds,
        "cross_host_msgs": dk.cross_host_msgs,
        "vtime_ns": dk.vtime_ns,
        "dispatch_per_s": round(
            sum(h.dispatches for h in dk.hosts)
            / max(dk.wall_s, 1e-9)),
        "wall_s_1_worker": round(d1.wall_s, 4),
        "wall_s_k_workers": round(dk.wall_s, 4),
        "wall_speedup_vs_1_worker": round(
            d1.wall_s / max(dk.wall_s, 1e-9), 3),
        "wall_s_async": round(ref.wall_s, 4),
        "bit_identical_to_async": True,
    }


def simulate(arch: str = "qwen3_4b", shape: str = "train_4k",
             n_steps: int = 5, straggler: bool = False,
             multi_pod: bool = True) -> dict:
    from repro.core.cluster import (ClusterSpec, StepCost,
                                    analytic_step_ns)
    from repro.core.vtime import SEC
    from repro.sim import (ChipRingTraining, Scenario, Simulation,
                           Straggler, Topology)

    spec = ClusterSpec(n_pods=2 if multi_pod else 1, chips_per_pod=256)
    try:
        cost = StepCost.from_dryrun(arch, shape,
                                    "2x16x16" if multi_pod else "16x16")
    except Exception:
        cost = StepCost(compute_ns=5_000_000, ici_bytes=50_000_000)
    cost.dcn_bytes = cost.ici_bytes // 8
    scenario = Scenario("straggler" if straggler else "baseline",
                        (Straggler("chip7", 2.0),) if straggler else ())
    wl = ChipRingTraining(spec, cost, n_steps, skew_bound_ns=1_000_000)
    report = Simulation(Topology.single_host(n_cpus=64), wl,
                        scenario).run(on_deadlock="raise")
    analytic_ns = analytic_step_ns(spec, cost) * n_steps
    done = report.progress["train"]["done_steps"]
    return {
        "arch": arch, "n_chips": spec.n_chips, "n_steps": n_steps,
        "straggler": straggler,
        "sim_step_ms": round(report.vtime_ns / n_steps / 1e6, 4),
        "analytic_step_ms": round(analytic_ns / n_steps / 1e6, 4),
        "ratio": round(report.vtime_ns / max(analytic_ns, 1), 4),
        "wall_s": round(report.wall_s, 3),
        "sim_speed": round((report.vtime_ns / SEC)
                           / max(report.wall_s, 1e-9), 3),
        "messages": report.messages,
        "done_steps_min": int(min(done)),
    }


def write_bench(bench: dict) -> None:
    """Single writer for every bench artifact: the root
    ``BENCH_cluster.json`` is the source schema; everything under
    ``results/`` (gitignored) is derived from it, so the two can never
    drift."""
    (ROOT / "BENCH_cluster.json").write_text(
        json.dumps(bench, indent=2) + "\n")
    results = ROOT / "results"
    results.mkdir(exist_ok=True)
    (results / "cluster_bench.json").write_text(
        json.dumps(bench["training"], indent=2))
    (results / "orchestrator_bench.json").write_text(
        json.dumps(bench["multihost"], indent=2))


def main():
    multihost = main_multihost()
    large = main_multihost_large()
    cells = main_cells()
    sweep = main_sweep()
    live = main_live_recovery()
    serve = main_live_serve()
    campaign = main_campaign()
    control = main_control_plane()
    sharded = simulate_sharded_dist() if HAS_FORK else None
    sharded_large = (simulate_sharded_dist(n_chips=2048, n_hosts=16)
                     if HAS_FORK else None)
    for tag, s in (("sharded", sharded), ("large", sharded_large)):
        if s:
            print(f"dist {tag} {s['n_chips']}-chip ring, "
                  f"{s['n_hosts']} hosts: "
                  f"{s['cross_partition_sync_rounds']} sync rounds, "
                  f"{s['workers']} workers "
                  f"{s['wall_speedup_vs_1_worker']:.2f}x vs 1 worker "
                  f"(async {s['wall_s_async']:.2f}s, "
                  f"dist {s['wall_s_k_workers']:.2f}s)")
    print()
    rows = []
    for arch in ("qwen3_4b", "olmoe_1b_7b"):
        rows.append(simulate(arch, straggler=False))
        rows.append(simulate(arch, straggler=True))
    # compact machine-readable perf trajectory (schema in README.md):
    # aggregates only, so PR-over-PR diffs stay reviewable
    def strip(rs):
        return {name: {k: v for k, v in r.items()
                       if k not in ("final_vtimes", "cell_report",
                                    "live_section", "control_section")}
                for name, r in rs.items()}
    bench = {
        # v9: + the control_plane regime (mutable membership: the
        # 65-host diurnal_autoscale marquee — joins as simulation
        # events, autoscaler decisions, simulated latency
        # percentiles); v8 added the fault-campaign regime (swept
        # grids, outcome histograms, minimized-reproducer throughput);
        # v7 the live_serve replay regime (simulated latency
        # percentiles + replay dispatch throughput); v6 the
        # live_recovery replay regime; v5 the vectorized engine row in
        # multihost and the vmap batched-sweep regime
        "schema": "BENCH_cluster/v9",
        "multihost": strip(multihost),
        "multihost_large": strip(large),
        "cells": strip(cells),
        "sweep": sweep,
        "live_recovery": strip(live),
        "live_serve": strip(serve),
        "campaign": campaign,
        "control_plane": strip(control),
        "training": rows,
    }
    if HAS_FORK:
        a, d1 = multihost["async"], multihost["dist_1w"]
        bench["dist"] = {
            # fine-grained rack workload: sync-round overhead dominates
            # (few dispatches per window), so dist-vs-async wall clock
            # tracks the per-round transport cost...
            "rack": {
                "n_hosts": d1["n_hosts"],
                "workers": DIST_WORKERS,
                "cross_partition_sync_rounds":
                    multihost[f"dist_{DIST_WORKERS}w"]["sync_rounds"],
                "wall_dist_1w_vs_async": round(
                    d1["wall_s"] / max(a["wall_s"], 1e-9), 3),
                "bit_identical_to_async": d1["final_vtimes"]
                == a["final_vtimes"],
            },
            # ...while the sharded training rings (heavy per-window
            # dispatch work, few rounds) are where extra OS workers pay.
            "sharded": sharded,
            "sharded_large": sharded_large,
        }
    write_bench(bench)
    print(f"{'arch':16s} {'strag':>6s} {'sim ms/step':>12s} "
          f"{'analytic':>9s} {'ratio':>6s} {'msgs':>8s} {'wall_s':>7s}")
    for r in rows:
        print(f"{r['arch']:16s} {str(r['straggler']):>6s} "
              f"{r['sim_step_ms']:12.2f} {r['analytic_step_ms']:9.2f} "
              f"{r['ratio']:6.2f} {r['messages']:8d} {r['wall_s']:7.2f}")
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized cells + vectorized checks; does not "
                         "rewrite the root BENCH_cluster.json")
    if ap.parse_args().smoke:
        smoke_cells()
        smoke_vectorized()
        smoke_live_recovery()
        smoke_live_serve()
        smoke_campaign()
        smoke_control_plane()
    else:
        main()
