"""Cluster-scale simulation benchmark: 512-chip training of the assigned
architectures under LiveStack, validated against the closed-form roofline
and exercised with stragglers/failures (what closed forms cannot do) —
driven through the declarative `repro.sim` facade.

Also the orchestration-engine head-to-head (``simulate_multihost`` /
``main_multihost``): a >=4-host heterogeneous-latency topology (fast
intra-rack + slow cross-rack links) run under both ``mode="barrier"``
(global-min-latency epochs) and ``mode="async"`` (per-link-lookahead
conservative PDES).  Both must produce identical simulation results; the
async engine must need fewer synchronization rounds and far fewer proxy
syncs, at no wall-clock cost.

Outputs:
  results/orchestrator_bench.json — engine head-to-head summary (legacy)
  BENCH_cluster.json              — machine-readable SimReports for the
                                    whole run, committed at the repo
                                    root so the perf trajectory is
                                    tracked PR-over-PR (results/ is
                                    gitignored)
"""
from __future__ import annotations

import json
import pathlib

ROOT = pathlib.Path(__file__).resolve().parents[1]


def simulate_multihost(mode: str, *, n_racks: int = 2,
                       hosts_per_rack: int = 2, n_iters: int = 300,
                       rack_slowdown=(1.0, 3.0),
                       skew_bound_ns: int = 2_000_000) -> dict:
    """One engine run on the heterogeneous rack topology."""
    from repro.sim import RackRing, Scenario, Simulation, Topology

    wl = RackRing(n_racks=n_racks, hosts_per_rack=hosts_per_rack,
                  n_iters=n_iters, skew_bound_ns=skew_bound_ns)
    report = Simulation(
        Topology.racks(n_racks, hosts_per_rack), wl,
        Scenario("imbalanced racks", wl.stragglers(rack_slowdown)),
        placement=wl.default_placement(), mode=mode,
    ).run(on_deadlock="raise")
    assert all(t["state"] == "done" for t in report.tasks.values())
    return {
        "mode": mode, "n_hosts": n_racks * hosts_per_rack,
        "sync_rounds": report.sync_rounds,
        "proxy_syncs": report.proxy_syncs,
        "cross_host_msgs": report.cross_host_msgs,
        "messages": report.messages,
        "vtime_ns": report.vtime_ns,
        "final_vtimes": [report.tasks[f"w{h}"]["vtime"]
                         for h in range(wl.n_workers)],
        "wall_s": report.wall_s,
        "dispatches": sum(h.dispatches for h in report.hosts),
        "report": report.to_dict(),
    }


def main_multihost() -> dict:
    rows = {m: simulate_multihost(m) for m in ("barrier", "async")}
    b, a = rows["barrier"], rows["async"]
    assert a["final_vtimes"] == b["final_vtimes"], \
        "engines disagree on simulation results"
    assert a["messages"] == b["messages"]
    assert a["sync_rounds"] < b["sync_rounds"], \
        (a["sync_rounds"], b["sync_rounds"])
    print(f"orchestration engines, {b['n_hosts']} hosts, "
          f"2us intra-rack / 50us cross-rack, imbalanced racks:")
    print(f"{'mode':>8s} {'rounds':>7s} {'proxy_syncs':>12s} "
          f"{'msgs':>6s} {'sim_ms':>7s} {'wall_s':>7s}")
    for m in ("barrier", "async"):
        r = rows[m]
        print(f"{m:>8s} {r['sync_rounds']:7d} {r['proxy_syncs']:12d} "
              f"{r['messages']:6d} {r['vtime_ns']/1e6:7.2f} "
              f"{r['wall_s']:7.3f}")
    print(f"async speedup: {b['sync_rounds']/a['sync_rounds']:.2f}x fewer "
          f"rounds, {b['proxy_syncs']/max(a['proxy_syncs'],1):.0f}x fewer "
          f"proxy syncs, identical results")
    out = ROOT / "results" / "orchestrator_bench.json"
    out.parent.mkdir(exist_ok=True)
    slim = {m: {k: v for k, v in r.items()
                if k not in ("final_vtimes", "report")}
            for m, r in rows.items()}
    out.write_text(json.dumps(slim, indent=2))
    return rows


def simulate(arch: str = "qwen3_4b", shape: str = "train_4k",
             n_steps: int = 5, straggler: bool = False,
             multi_pod: bool = True) -> dict:
    from repro.core.cluster import (ClusterSpec, StepCost,
                                    analytic_step_ns)
    from repro.core.vtime import SEC
    from repro.sim import (ChipRingTraining, Scenario, Simulation,
                           Straggler, Topology)

    spec = ClusterSpec(n_pods=2 if multi_pod else 1, chips_per_pod=256)
    try:
        cost = StepCost.from_dryrun(arch, shape,
                                    "2x16x16" if multi_pod else "16x16")
    except Exception:
        cost = StepCost(compute_ns=5_000_000, ici_bytes=50_000_000)
    cost.dcn_bytes = cost.ici_bytes // 8
    scenario = Scenario("straggler" if straggler else "baseline",
                        (Straggler("chip7", 2.0),) if straggler else ())
    wl = ChipRingTraining(spec, cost, n_steps, skew_bound_ns=1_000_000)
    report = Simulation(Topology.single_host(n_cpus=64), wl,
                        scenario).run(on_deadlock="raise")
    analytic_ns = analytic_step_ns(spec, cost) * n_steps
    done = report.progress["train"]["done_steps"]
    return {
        "arch": arch, "n_chips": spec.n_chips, "n_steps": n_steps,
        "straggler": straggler,
        "sim_step_ms": report.vtime_ns / n_steps / 1e6,
        "analytic_step_ms": analytic_ns / n_steps / 1e6,
        "ratio": report.vtime_ns / max(analytic_ns, 1),
        "wall_s": report.wall_s,
        "sim_speed": (report.vtime_ns / SEC) / max(report.wall_s, 1e-9),
        "messages": report.messages,
        "done_steps_min": int(min(done)),
        "report": report.to_dict(),
    }


def main():
    multihost = main_multihost()
    print()
    rows = []
    for arch in ("qwen3_4b", "olmoe_1b_7b"):
        rows.append(simulate(arch, straggler=False))
        rows.append(simulate(arch, straggler=True))
    out = ROOT / "results" / "cluster_bench.json"
    out.parent.mkdir(exist_ok=True)
    out.write_text(json.dumps(
        [{k: v for k, v in r.items() if k != "report"} for r in rows],
        indent=2))
    # machine-readable perf trajectory: full SimReports for every run
    bench = {
        "schema": "BENCH_cluster/v1",
        "multihost": {m: multihost[m]["report"]
                      for m in ("barrier", "async")},
        "training": [{"arch": r["arch"], "straggler": r["straggler"],
                      "sim_step_ms": r["sim_step_ms"],
                      "analytic_step_ms": r["analytic_step_ms"],
                      "wall_s": r["wall_s"],
                      # the 512-entry per-task map is redundant with the
                      # progress arrays for trajectory tracking
                      "report": {k: v for k, v in r["report"].items()
                                 if k != "tasks"}} for r in rows],
    }
    (ROOT / "BENCH_cluster.json").write_text(
        json.dumps(bench, indent=2))
    print(f"{'arch':16s} {'strag':>6s} {'sim ms/step':>12s} "
          f"{'analytic':>9s} {'ratio':>6s} {'msgs':>8s} {'wall_s':>7s}")
    for r in rows:
        print(f"{r['arch']:16s} {str(r['straggler']):>6s} "
              f"{r['sim_step_ms']:12.2f} {r['analytic_step_ms']:9.2f} "
              f"{r['ratio']:6.2f} {r['messages']:8d} {r['wall_s']:7.2f}")
    return rows


if __name__ == "__main__":
    main()
