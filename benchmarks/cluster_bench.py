"""Cluster-scale simulation benchmark: 512-chip training of the assigned
architectures under LiveStack, validated against the closed-form roofline
and exercised with stragglers/failures (what closed forms cannot do) —
driven through the declarative `repro.sim` facade.

Also the orchestration-engine head-to-head (``simulate_multihost`` /
``main_multihost``): a >=4-host heterogeneous-latency topology (fast
intra-rack + slow cross-rack links) run under ``mode="barrier"``
(global-min-latency epochs), ``mode="async"`` (per-link-lookahead
conservative PDES), and the multi-process ``dist`` engine with 1 and K
OS worker processes.  All engines must produce identical simulation
results; the bench records each engine's synchronization cost (rounds,
proxy syncs) and, for dist, the worker count, cross-partition sync
rounds, and the 1-vs-K wall-clock speedup.

Outputs:
  results/orchestrator_bench.json — engine head-to-head summary (legacy)
  BENCH_cluster.json              — compact aggregates-only summary
                                    (schema BENCH_cluster/v2, documented
                                    in README.md), committed at the repo
                                    root so the perf trajectory stays
                                    reviewable PR-over-PR (results/ is
                                    gitignored; v1 checked in ~2500
                                    lines of full SimReports)
"""
from __future__ import annotations

import json
import os
import pathlib

ROOT = pathlib.Path(__file__).resolve().parents[1]

#: OS worker count for the dist engine rows ("K" in BENCH_cluster)
DIST_WORKERS = 2

#: the dist engine forks OS workers; skip its rows where fork is absent
HAS_FORK = hasattr(os, "fork")


def _aggregate(report) -> dict:
    """The compact BENCH_cluster/v2 per-run record: aggregates only."""
    return {
        "status": report.status,
        "n_hosts": report.n_hosts,
        "n_workers": report.n_workers,
        "sync_rounds": report.sync_rounds,
        "proxy_syncs": report.proxy_syncs,
        "cross_host_msgs": report.cross_host_msgs,
        "messages": report.messages,
        "bytes": report.bytes,
        "vtime_ns": report.vtime_ns,
        "wall_s": round(report.wall_s, 4),
        "dispatches": sum(h.dispatches for h in report.hosts),
        "max_window_ns": report.max_window_ns,
        "max_proxy_staleness_ns": report.max_proxy_staleness_ns,
    }


def simulate_multihost(engine: str, *, n_workers: int = DIST_WORKERS,
                       n_racks: int = 2, hosts_per_rack: int = 2,
                       n_iters: int = 300, rack_slowdown=(1.0, 3.0),
                       skew_bound_ns: int = 2_000_000) -> dict:
    """One engine run on the heterogeneous rack topology.  ``engine``
    is ``"barrier"``/``"async"`` or ``"dist"`` (with ``n_workers`` OS
    worker processes)."""
    from repro.sim import RackRing, Scenario, Simulation, Topology

    wl = RackRing(n_racks=n_racks, hosts_per_rack=hosts_per_rack,
                  n_iters=n_iters, skew_bound_ns=skew_bound_ns)
    sim = Simulation(
        Topology.racks(n_racks, hosts_per_rack), wl,
        Scenario("imbalanced racks", wl.stragglers(rack_slowdown)),
        placement=wl.default_placement(),
    )
    if engine == "dist":
        report = sim.run(engine="dist", n_workers=n_workers,
                         on_deadlock="raise")
    else:
        report = sim.run(engine=engine, on_deadlock="raise")
    assert all(t["state"] == "done" for t in report.tasks.values())
    row = _aggregate(report)
    row["engine"] = engine
    row["final_vtimes"] = [report.tasks[f"w{h}"]["vtime"]
                           for h in range(wl.n_workers)]
    return row


def main_multihost() -> dict:
    rows = {
        "barrier": simulate_multihost("barrier"),
        "async": simulate_multihost("async"),
    }
    if HAS_FORK:
        rows["dist_1w"] = simulate_multihost("dist", n_workers=1)
        rows[f"dist_{DIST_WORKERS}w"] = simulate_multihost(
            "dist", n_workers=DIST_WORKERS)
    vt = {k: r["final_vtimes"] for k, r in rows.items()}
    assert all(v == vt["barrier"] for v in vt.values()), \
        "engines disagree on simulation results"
    assert all(r["messages"] == rows["barrier"]["messages"]
               for r in rows.values())
    b, a = rows["barrier"], rows["async"]
    assert a["sync_rounds"] < b["sync_rounds"], \
        (a["sync_rounds"], b["sync_rounds"])
    print(f"orchestration engines, {b['n_hosts']} hosts, "
          f"2us intra-rack / 50us cross-rack, imbalanced racks:")
    print(f"{'engine':>10s} {'workers':>7s} {'rounds':>7s} "
          f"{'proxy_syncs':>12s} {'msgs':>6s} {'sim_ms':>7s} "
          f"{'wall_s':>7s}")
    for name, r in rows.items():
        print(f"{r['engine']:>10s} {r['n_workers']:7d} "
              f"{r['sync_rounds']:7d} {r['proxy_syncs']:12d} "
              f"{r['messages']:6d} {r['vtime_ns']/1e6:7.2f} "
              f"{r['wall_s']:7.3f}")
    print(f"async speedup: {b['sync_rounds']/a['sync_rounds']:.2f}x fewer "
          f"rounds, {b['proxy_syncs']/max(a['proxy_syncs'],1):.0f}x fewer "
          f"proxy syncs, identical results")
    if HAS_FORK:
        d1, dk = rows["dist_1w"], rows[f"dist_{DIST_WORKERS}w"]
        print(f"dist {DIST_WORKERS} workers: {dk['sync_rounds']} "
              f"cross-partition sync rounds, wall-clock "
              f"{d1['wall_s']/max(dk['wall_s'], 1e-9):.2f}x vs 1 worker, "
              f"identical results")
    out = ROOT / "results" / "orchestrator_bench.json"
    out.parent.mkdir(exist_ok=True)
    out.write_text(json.dumps(
        {k: {kk: vv for kk, vv in r.items() if kk != "final_vtimes"}
         for k, r in rows.items()}, indent=2))
    return rows


def simulate_sharded_dist(*, n_chips: int = 512, n_hosts: int = 4,
                          n_steps: int = 3) -> dict:
    """The dist engine's parallelism case: a 512-chip training ring
    sharded across hosts (heavy per-window dispatch work, few sync
    rounds), run with 1 vs K OS worker processes and checked
    bit-identical to the in-process async engine."""
    from repro.core.cluster import ClusterSpec, StepCost
    from repro.sim import ChipRingTraining, Simulation, Topology

    def make():
        spec = ClusterSpec(n_pods=n_hosts,
                           chips_per_pod=n_chips // n_hosts)
        cost = StepCost(compute_ns=5_000_000, ici_bytes=50_000_000,
                        dcn_bytes=6_000_000)
        wl = ChipRingTraining(spec, cost, n_steps,
                              skew_bound_ns=1_000_000)
        return Simulation(Topology(n_hosts=n_hosts, n_cpus=128), wl,
                          capacity=n_chips // n_hosts)

    ref = make().run(engine="async", on_deadlock="raise")
    runs = {k: make().run(engine="dist", n_workers=k,
                          on_deadlock="raise")
            for k in (1, DIST_WORKERS)}
    for r in runs.values():
        assert r.tasks == ref.tasks, "dist diverged from async"
    d1, dk = runs[1], runs[DIST_WORKERS]
    return {
        "n_chips": n_chips, "n_hosts": n_hosts, "n_steps": n_steps,
        "workers": DIST_WORKERS,
        "cross_partition_sync_rounds": dk.sync_rounds,
        "cross_host_msgs": dk.cross_host_msgs,
        "vtime_ns": dk.vtime_ns,
        "wall_s_1_worker": round(d1.wall_s, 4),
        "wall_s_k_workers": round(dk.wall_s, 4),
        "wall_speedup_vs_1_worker": round(
            d1.wall_s / max(dk.wall_s, 1e-9), 3),
        "wall_s_async": round(ref.wall_s, 4),
        "bit_identical_to_async": True,
    }


def simulate(arch: str = "qwen3_4b", shape: str = "train_4k",
             n_steps: int = 5, straggler: bool = False,
             multi_pod: bool = True) -> dict:
    from repro.core.cluster import (ClusterSpec, StepCost,
                                    analytic_step_ns)
    from repro.core.vtime import SEC
    from repro.sim import (ChipRingTraining, Scenario, Simulation,
                           Straggler, Topology)

    spec = ClusterSpec(n_pods=2 if multi_pod else 1, chips_per_pod=256)
    try:
        cost = StepCost.from_dryrun(arch, shape,
                                    "2x16x16" if multi_pod else "16x16")
    except Exception:
        cost = StepCost(compute_ns=5_000_000, ici_bytes=50_000_000)
    cost.dcn_bytes = cost.ici_bytes // 8
    scenario = Scenario("straggler" if straggler else "baseline",
                        (Straggler("chip7", 2.0),) if straggler else ())
    wl = ChipRingTraining(spec, cost, n_steps, skew_bound_ns=1_000_000)
    report = Simulation(Topology.single_host(n_cpus=64), wl,
                        scenario).run(on_deadlock="raise")
    analytic_ns = analytic_step_ns(spec, cost) * n_steps
    done = report.progress["train"]["done_steps"]
    return {
        "arch": arch, "n_chips": spec.n_chips, "n_steps": n_steps,
        "straggler": straggler,
        "sim_step_ms": round(report.vtime_ns / n_steps / 1e6, 4),
        "analytic_step_ms": round(analytic_ns / n_steps / 1e6, 4),
        "ratio": round(report.vtime_ns / max(analytic_ns, 1), 4),
        "wall_s": round(report.wall_s, 3),
        "sim_speed": round((report.vtime_ns / SEC)
                           / max(report.wall_s, 1e-9), 3),
        "messages": report.messages,
        "done_steps_min": int(min(done)),
    }


def main():
    multihost = main_multihost()
    sharded = simulate_sharded_dist() if HAS_FORK else None
    if sharded:
        print(f"dist sharded {sharded['n_chips']}-chip ring, "
              f"{sharded['n_hosts']} hosts: "
              f"{sharded['cross_partition_sync_rounds']} sync rounds, "
              f"{sharded['workers']} workers "
              f"{sharded['wall_speedup_vs_1_worker']:.2f}x vs 1 worker "
              f"(async {sharded['wall_s_async']:.2f}s, "
              f"dist {sharded['wall_s_k_workers']:.2f}s)")
    print()
    rows = []
    for arch in ("qwen3_4b", "olmoe_1b_7b"):
        rows.append(simulate(arch, straggler=False))
        rows.append(simulate(arch, straggler=True))
    out = ROOT / "results" / "cluster_bench.json"
    out.parent.mkdir(exist_ok=True)
    out.write_text(json.dumps(rows, indent=2))
    # compact machine-readable perf trajectory (schema in README.md):
    # aggregates only, so PR-over-PR diffs stay reviewable
    bench = {
        "schema": "BENCH_cluster/v2",
        "multihost": {
            name: {k: v for k, v in r.items() if k != "final_vtimes"}
            for name, r in multihost.items()},
        "training": rows,
    }
    if HAS_FORK:
        d1 = multihost["dist_1w"]
        dk = multihost[f"dist_{DIST_WORKERS}w"]
        bench["dist"] = {
            # fine-grained rack workload: sync-round overhead dominates
            # (few dispatches per window), so 1-vs-K wall clock shows
            # the protocol cost...
            "rack": {
                "n_hosts": dk["n_hosts"],
                "workers": DIST_WORKERS,
                "cross_partition_sync_rounds": dk["sync_rounds"],
                "wall_speedup_vs_1_worker": round(
                    d1["wall_s"] / max(dk["wall_s"], 1e-9), 3),
                "bit_identical_to_async": dk["final_vtimes"]
                == multihost["async"]["final_vtimes"],
            },
            # ...while the sharded 512-chip ring (heavy per-window
            # dispatch work, few rounds) is where extra OS workers pay.
            "sharded": sharded,
        }
    (ROOT / "BENCH_cluster.json").write_text(
        json.dumps(bench, indent=2) + "\n")
    print(f"{'arch':16s} {'strag':>6s} {'sim ms/step':>12s} "
          f"{'analytic':>9s} {'ratio':>6s} {'msgs':>8s} {'wall_s':>7s}")
    for r in rows:
        print(f"{r['arch']:16s} {str(r['straggler']):>6s} "
              f"{r['sim_step_ms']:12.2f} {r['analytic_step_ms']:9.2f} "
              f"{r['ratio']:6.2f} {r['messages']:8d} {r['wall_s']:7.2f}")
    return rows


if __name__ == "__main__":
    main()
