"""Scheduler scalability: reference engine vs vectorized JAX engine.

Dispatch throughput (vtask-dispatches/second) as cluster size grows —
the motivation for the kernel-resident fast path (paper: "kernel
mechanisms keep virtual-time updates ... on the hot path") and for the
``minskew`` Pallas kernel.  The reference engine rows track the indexed
scheduler core (lazy runnable heap + incremental scope minima, see
``repro.core.scheduler``) PR-over-PR.

Outputs:
  BENCH_sched.json         — machine-readable dispatches/sec by n_tasks
                             (schema BENCH_sched/v1), committed at the
                             repo root next to BENCH_cluster.json; the
                             full run is the canonical artifact
  results/sched_scale.json — raw rows of the last local run

``--smoke`` runs a CI-sized subset and leaves the committed root
artifact untouched: the reference engine with and without §3.3 cells
assigned, the vectorized engine (its own floor, so a fast-path
regression trips CI too), and a Pallas-vs-jnp path check — one facade
scenario run with ``pallas="interpret"`` (the kernels, interpreted on
CPU) and ``pallas="off"`` (the jnp oracle), asserted bit-identical.
"""
from __future__ import annotations

import argparse
import json
import pathlib
import time

import numpy as np

ROOT = pathlib.Path(__file__).resolve().parents[1]

#: the seed repo's scan-based scheduler at n_tasks=4096 (measured on
#: the same container the indexed rewrite was measured on) — the
#: acceptance bar is >= 2x this, tracked in BENCH_sched.json
SEED_REFERENCE_4096_DISPATCH_PER_S = 16578


def bench_reference(n_tasks: int, n_scopes: int, steps: int = 20) -> dict:
    from repro.core import Compute, Scheduler, Scope, US, VTask

    sched = Scheduler(n_cpus=max(8, n_tasks // 4))
    scopes = [Scope(f"s{i}", 50 * US) for i in range(n_scopes)]
    rng = np.random.default_rng(0)

    def body(dur):
        def gen():
            for _ in range(steps):
                yield Compute(int(dur))
        return gen()

    for i in range(n_tasks):
        t = VTask(f"t{i}", body(rng.integers(5, 50) * US), kind="modeled")
        t.join(scopes[i % n_scopes])
        if i % 7 == 0:
            t.join(scopes[(i + 1) % n_scopes])
        sched.spawn(t)
    t0 = time.perf_counter()
    sched.run()
    wall = time.perf_counter() - t0
    return {"engine": "reference", "n_tasks": n_tasks,
            "dispatches": sched.stats.dispatches, "wall_s": wall,
            "dispatch_per_s": sched.stats.dispatches / wall}


def bench_reference_cells(n_tasks: int, n_scopes: int,
                          steps: int = 20) -> dict:
    """The reference engine with every vtask live and bound to a §3.3
    cell: each dispatch prices spatial interference off the per-host
    live-cell multiset and warm-slot reconditioning, so this row tracks
    the cell hot path (the indexed replacement for the old O(tasks)
    coactive scan) against the same smoke floor as the plain rows."""
    from repro.core import (CellManager, LiveCall, Scheduler, Scope, US,
                            VTask)

    n_cells = max(4, n_tasks // 64)
    cm = CellManager(n_warm_slots=max(2, n_cells // 2))
    for i in range(n_cells):
        cm.create(f"c{i}", ways=3, working_set_frac=0.5,
                  bw_share=1.0 / n_cells, bw_demand=1.5 / n_cells,
                  mem_frac=0.4)
    sched = Scheduler(n_cpus=max(8, n_tasks // 4), cells=cm)
    scopes = [Scope(f"s{i}", 50 * US) for i in range(n_scopes)]
    rng = np.random.default_rng(0)

    def noop():
        return None

    def body(dur):
        def gen():
            for _ in range(steps):
                yield LiveCall(noop, cost_ns=int(dur))
        return gen()

    for i in range(n_tasks):
        t = VTask(f"t{i}", body(rng.integers(5, 50) * US), kind="live")
        t.join(scopes[i % n_scopes])
        if i % 7 == 0:
            t.join(scopes[(i + 1) % n_scopes])
        sched.spawn(t)
        cm.assign(t, f"c{i % n_cells}")
    t0 = time.perf_counter()
    sched.run()
    wall = time.perf_counter() - t0
    assert cm.stats["switches"] > 0     # the regime really exercised it
    return {"engine": "reference_cells", "n_tasks": n_tasks,
            "dispatches": sched.stats.dispatches, "wall_s": wall,
            "dispatch_per_s": sched.stats.dispatches / wall}


def bench_vectorized(n_tasks: int, n_scopes: int, steps: int = 20) -> dict:
    import jax

    from repro.core.engine_jax import VecState, run_vectorized

    rng = np.random.default_rng(0)
    membership = np.zeros((n_tasks, n_scopes), bool)
    idx = np.arange(n_tasks)
    membership[idx, idx % n_scopes] = True
    membership[idx[idx % 7 == 0], (idx[idx % 7 == 0] + 1) % n_scopes] = True
    st = VecState.create(
        n_tasks, n_scopes,
        durations=rng.integers(5, 50, n_tasks) * 1000,
        steps=np.full(n_tasks, steps),
        membership=membership,
        skews=np.full(n_scopes, 50_000))
    # warm-up compile
    st2, _ = run_vectorized(st, max_rounds=1)
    st = VecState.create(
        n_tasks, n_scopes,
        durations=rng.integers(5, 50, n_tasks) * 1000,
        steps=np.full(n_tasks, steps),
        membership=membership,
        skews=np.full(n_scopes, 50_000))
    t0 = time.perf_counter()
    st, rounds = run_vectorized(st)
    jax.block_until_ready(st.vtime)
    wall = time.perf_counter() - t0
    dispatches = int(n_tasks * steps)
    return {"engine": "vectorized", "n_tasks": n_tasks,
            "dispatches": dispatches, "rounds": rounds, "wall_s": wall,
            "dispatch_per_s": dispatches / wall}


def bench_sweep(n_variants: int = 64) -> dict:
    """The vmap batched-sweep regime (``Simulation.sweep``): one
    compiled dispatch over ``n_variants`` straggler variants of a
    16-worker rack ring — the paper's iterative configuration
    exploration measured as completed SimReports per wall-second.  A
    first sweep warms the jit cache so the recorded wall clock is the
    steady-state exploration rate, not XLA compile time."""
    from repro.sim import RackRing, Scenario, Simulation, Straggler, \
        Topology

    def make():
        wl = RackRing(n_racks=4, hosts_per_rack=4, n_iters=128,
                      cross_every=8, skew_bound_ns=2_000_000)
        return Simulation(Topology.racks(4, 4), wl,
                          placement=wl.default_placement())

    axis = [Scenario(f"v{i}",
                     (Straggler(f"w{i % 16}", 1.0 + (i % 7) * 0.5),))
            for i in range(n_variants)]
    make().sweep(axis)                  # warm-up: compile the batch
    res = make().sweep(axis)
    dispatches = sum(sum(h.dispatches for h in r.hosts)
                     for r in res.reports)
    assert res.tier == "exact" and len(res.reports) == n_variants
    return {"engine": "sweep", "n_tasks": 16,
            "n_variants": n_variants, "wall_s": res.wall_s,
            "configs_per_s": res.configs_per_s,
            "dispatch_per_s": dispatches / max(res.wall_s, 1e-9)}


def check_pallas_path(pallas: str = "interpret") -> None:
    """The Pallas hot paths (minskew eligibility + hub_route fan-out)
    must be bit-identical to the jnp oracle path on a real facade
    scenario — the CPU-CI stand-in for the TPU ``pallas="on"`` choice."""
    from repro.sim import (DegradeLink, RackRing, Scenario, Simulation,
                           Straggler, Topology)

    def make():
        wl = RackRing(n_racks=2, hosts_per_rack=2, n_iters=20,
                      cross_every=4, skew_bound_ns=100_000)
        return Simulation(
            Topology.racks(2, 2), wl,
            Scenario("pallas-check",
                     (Straggler("w1", 2.0),
                      DegradeLink(hosts=(0, 2), extra_ns=5_000))),
            placement=wl.default_placement())

    ref = make().run(engine="vectorized", pallas="off", verify=True)
    ker = make().run(engine="vectorized", pallas=pallas, verify=True)
    a, b = ref.to_dict(), ker.to_dict()
    a["wall_s"] = b["wall_s"] = 0.0
    assert a == b, "pallas path diverged from the jnp oracle"


def write_bench(rows, sweep: dict) -> None:
    """Single writer: the root BENCH_sched.json is the schema; the
    results/ copy is raw derived data."""
    ref4k = [r for r in rows
             if r["engine"] == "reference" and r["n_tasks"] == 4096]
    bench = {
        # v3: + the vmap batched-sweep regime (configs/s)
        "schema": "BENCH_sched/v3",
        "rows": [{"engine": r["engine"], "n_tasks": r["n_tasks"],
                  "dispatch_per_s": round(r["dispatch_per_s"])}
                 for r in rows],
        "sweep": {"n_tasks": sweep["n_tasks"],
                  "n_variants": sweep["n_variants"],
                  "configs_per_s": round(sweep["configs_per_s"], 1),
                  "dispatch_per_s": round(sweep["dispatch_per_s"])},
        "seed_reference_4096_dispatch_per_s":
            SEED_REFERENCE_4096_DISPATCH_PER_S,
        "speedup_vs_seed_at_4096": round(
            min(r["dispatch_per_s"] for r in ref4k)
            / SEED_REFERENCE_4096_DISPATCH_PER_S, 2) if ref4k else None,
    }
    (ROOT / "BENCH_sched.json").write_text(
        json.dumps(bench, indent=2) + "\n")
    (ROOT / "results").mkdir(exist_ok=True)
    (ROOT / "results" / "sched_scale.json").write_text(
        json.dumps(rows + [sweep], indent=2))


def main(smoke: bool = False):
    rows = []
    sizes = (256, 1024) if smoke else (256, 1024, 4096, 16384)
    for n in sizes:
        rows.append(bench_reference(n, max(4, n // 64)))
        rows.append(bench_reference_cells(n, max(4, n // 64)))
        if not smoke:
            rows.append(bench_vectorized(n, max(4, n // 64)))
    if not smoke:
        write_bench(rows, bench_sweep())
    print(f"{'engine':12s} {'n_tasks':>8s} {'disp/s':>12s} {'wall_s':>8s}")
    for r in rows:
        print(f"{r['engine']:12s} {r['n_tasks']:8d} "
              f"{r['dispatch_per_s']:12.0f} {r['wall_s']:8.3f}")
    if smoke:
        # CI smoke bar: the indexed scheduler runs >= 4x the seed
        # scheduler on equal hardware, so half the seed's absolute
        # throughput is a regression floor with ~8x headroom for a
        # slower/loaded CI runner — it only trips on a real hot-path
        # regression, not on machine variance
        floor = SEED_REFERENCE_4096_DISPATCH_PER_S / 2
        assert all(r["dispatch_per_s"] > floor for r in rows), rows
        # the vectorized engine clears 100k+ disp/s at this size on an
        # unloaded container (BENCH_sched.json); the same conservative
        # floor gives it ~15x headroom while still catching a compiled
        # fast path that silently fell back to something scheduler-like
        vec = bench_vectorized(1024, 16)
        assert vec["dispatch_per_s"] > floor, (vec, floor)
        check_pallas_path()
        print(f"smoke ok: all sizes above the regression floor "
              f"({floor:.0f} dispatches/s); vectorized "
              f"{vec['dispatch_per_s']:.0f} disp/s; pallas interpret "
              f"path == jnp oracle")
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized subset; does not rewrite the root "
                         "BENCH_sched.json")
    main(smoke=ap.parse_args().smoke)
