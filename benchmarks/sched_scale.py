"""Scheduler scalability: reference engine vs vectorized JAX engine.

Dispatch throughput (vtask-dispatches/second) as cluster size grows —
the motivation for the kernel-resident fast path (paper: "kernel
mechanisms keep virtual-time updates ... on the hot path") and for the
``minskew`` Pallas kernel.
"""
from __future__ import annotations

import json
import pathlib
import time

import numpy as np

ROOT = pathlib.Path(__file__).resolve().parents[1]


def bench_reference(n_tasks: int, n_scopes: int, steps: int = 20) -> dict:
    from repro.core import Compute, Scheduler, Scope, US, VTask

    sched = Scheduler(n_cpus=max(8, n_tasks // 4))
    scopes = [Scope(f"s{i}", 50 * US) for i in range(n_scopes)]
    rng = np.random.default_rng(0)

    def body(dur):
        def gen():
            for _ in range(steps):
                yield Compute(int(dur))
        return gen()

    for i in range(n_tasks):
        t = VTask(f"t{i}", body(rng.integers(5, 50) * US), kind="modeled")
        t.join(scopes[i % n_scopes])
        if i % 7 == 0:
            t.join(scopes[(i + 1) % n_scopes])
        sched.spawn(t)
    t0 = time.perf_counter()
    sched.run()
    wall = time.perf_counter() - t0
    return {"engine": "reference", "n_tasks": n_tasks,
            "dispatches": sched.stats.dispatches, "wall_s": wall,
            "dispatch_per_s": sched.stats.dispatches / wall}


def bench_vectorized(n_tasks: int, n_scopes: int, steps: int = 20) -> dict:
    import jax

    from repro.core.engine_jax import VecState, run_vectorized

    rng = np.random.default_rng(0)
    membership = np.zeros((n_tasks, n_scopes), bool)
    idx = np.arange(n_tasks)
    membership[idx, idx % n_scopes] = True
    membership[idx[idx % 7 == 0], (idx[idx % 7 == 0] + 1) % n_scopes] = True
    st = VecState.create(
        n_tasks, n_scopes,
        durations=rng.integers(5, 50, n_tasks) * 1000,
        steps=np.full(n_tasks, steps),
        membership=membership,
        skews=np.full(n_scopes, 50_000))
    # warm-up compile
    st2, _ = run_vectorized(st, max_rounds=1)
    st = VecState.create(
        n_tasks, n_scopes,
        durations=rng.integers(5, 50, n_tasks) * 1000,
        steps=np.full(n_tasks, steps),
        membership=membership,
        skews=np.full(n_scopes, 50_000))
    t0 = time.perf_counter()
    st, rounds = run_vectorized(st)
    jax.block_until_ready(st.vtime)
    wall = time.perf_counter() - t0
    dispatches = int(n_tasks * steps)
    return {"engine": "vectorized", "n_tasks": n_tasks,
            "dispatches": dispatches, "rounds": rounds, "wall_s": wall,
            "dispatch_per_s": dispatches / wall}


def main():
    rows = []
    for n in (256, 1024, 4096, 16384):
        rows.append(bench_reference(n, max(4, n // 64)))
        rows.append(bench_vectorized(n, max(4, n // 64)))
    out = ROOT / "results" / "sched_scale.json"
    out.parent.mkdir(exist_ok=True)
    out.write_text(json.dumps(rows, indent=2))
    print(f"{'engine':12s} {'n_tasks':>8s} {'disp/s':>12s} {'wall_s':>8s}")
    for r in rows:
        print(f"{r['engine']:12s} {r['n_tasks']:8d} "
              f"{r['dispatch_per_s']:12.0f} {r['wall_s']:8.3f}")
    return rows


if __name__ == "__main__":
    main()
