"""Table 2 reproduction: accuracy + wall time per workload.

For each workload (paper row analogues):
  physical  — real threads + real wire delays: ground-truth wall time
  livestack — same unmodified functions under virtual time: accuracy =
              1 - |predicted - physical|/physical; slowdown = sim wall /
              physical wall
  DES       — fine-grained event baseline (gem5 stand-in): measured or
              extrapolated wall time
"""
from __future__ import annotations

import json
import pathlib
import sys

ROOT = pathlib.Path(__file__).resolve().parents[1]


def run(sizes: str = "full") -> list:
    from repro.core import workloads as wl

    scale = {"full": 1.0, "quick": 0.25}[sizes]
    rows = []
    params = {
        "arith": dict(iters=max(50, int(300 * scale))),
        "oltp": dict(n_req=max(100, int(800 * scale))),
        "kvstore": dict(n_ops=max(100, int(600 * scale))),
        "shuffle": dict(rounds=max(2, int(6 * scale))),
    }
    for name, spec in wl.WORKLOADS.items():
        kw = params[name]
        phys = spec["physical"](**kw)
        live = spec["livestack"](**kw)
        metric = spec["metric"]
        acc_runtime = wl.accuracy(live.sim_s, phys.sim_s)
        acc_metric = wl.accuracy(live.metrics[metric],
                                 phys.metrics[metric])
        row = {
            "workload": name,
            "paper_row": spec["paper_row"],
            "instances": spec["instances"],
            "metric": metric,
            "physical_s": phys.sim_s,
            "livestack_pred_s": live.sim_s,
            "livestack_wall_s": live.wall_s,
            "accuracy_runtime": acc_runtime,
            "accuracy_metric": acc_metric,
            "slowdown_x": live.wall_s / phys.wall_s,
        }
        if "des" in spec:
            des = spec["des"](**kw)
            row["des_wall_s"] = des.wall_s
            row["des_slowdown_x"] = des.wall_s / phys.wall_s
        rows.append(row)
    return rows


def main():
    rows = run()
    out = ROOT / "results" / "table2.json"
    out.parent.mkdir(exist_ok=True)
    out.write_text(json.dumps(rows, indent=2))
    hdr = (f"{'workload':10s} {'#inst':>5s} {'acc(metric)':>11s} "
           f"{'acc(runtime)':>12s} {'phys_s':>8s} {'LS_wall':>8s} "
           f"{'slowdn':>7s} {'DES_wall':>10s}")
    print(hdr)
    for r in rows:
        des = r.get("des_wall_s")
        print(f"{r['workload']:10s} {r['instances']:5d} "
              f"{r['accuracy_metric']*100:10.1f}% "
              f"{r['accuracy_runtime']*100:11.1f}% "
              f"{r['physical_s']:8.2f} {r['livestack_wall_s']:8.2f} "
              f"{r['slowdown_x']:6.2f}x "
              f"{des:10.1f}" if des else
              f"{r['workload']:10s} {r['instances']:5d} "
              f"{r['accuracy_metric']*100:10.1f}% "
              f"{r['accuracy_runtime']*100:11.1f}% "
              f"{r['physical_s']:8.2f} {r['livestack_wall_s']:8.2f} "
              f"{r['slowdown_x']:6.2f}x {'-':>10s}")
    return rows


if __name__ == "__main__":
    main()
