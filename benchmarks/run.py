"""Benchmark harness — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows per the harness contract,
and writes detailed JSON under results/.

  table1    — method-comparison matrix (qualitative, from the paper)
  table2    — accuracy + wall time vs physical + DES baseline (the
              paper's headline table)
  fig2      — scheduling timeline stats (skew stalls, wake forwarding)
  sched     — scheduler dispatch throughput (reference vs vectorized)
  hub       — IPC hub routing microbenchmark
  cells     — cell-isolation accounting microbenchmark
  cluster   — 512-chip cluster simulation vs analytic roofline
  roofline  — dry-run roofline terms summary (see benchmarks/roofline.py)
"""
from __future__ import annotations

import json
import pathlib
import sys
import time

ROOT = pathlib.Path(__file__).resolve().parents[1]
sys.path.insert(0, str(ROOT))


def _csv(name: str, us: float, derived: str) -> None:
    print(f"{name},{us:.3f},{derived}")


def table1() -> None:
    rows = [
        ("gem5/Simics (DES)", "slow", "full end-host", "single-node"),
        ("ns-3/OMNeT++ (DES)", "fast", "no end-host stack", "cluster"),
        ("SimBricks/SplitSim", "slowest-component", "full", "cluster"),
        ("Phantora (live)", "fast", "ML apps w/o OS", "cluster"),
        ("NEX (live)", "fast", "no full stack", "single-server"),
        ("LiveStack (this work)", "fast", "full", "cluster"),
    ]
    t0 = time.perf_counter()
    (ROOT / "results").mkdir(exist_ok=True)
    (ROOT / "results" / "table1.json").write_text(json.dumps(rows))
    _csv("table1_matrix", (time.perf_counter() - t0) * 1e6,
         "methods=6;livestack=fast+full+cluster")


def table2() -> None:
    from benchmarks import table2 as t2

    t0 = time.perf_counter()
    rows = t2.run(sizes="quick")
    us = (time.perf_counter() - t0) * 1e6 / max(len(rows), 1)
    (ROOT / "results" / "table2.json").write_text(json.dumps(rows,
                                                             indent=2))
    for r in rows:
        _csv(f"table2_{r['workload']}", us,
             f"acc={r['accuracy_metric']*100:.1f}%;"
             f"slowdown={r['slowdown_x']:.2f}x;"
             f"des_slowdown={r.get('des_slowdown_x', 0):.0f}x")


def fig2() -> None:
    from repro.core import (Compute, Endpoint, Hub, LinkSpec, Recv,
                            Scheduler, Scope, Send, US, VTask)

    t0 = time.perf_counter()
    sc = Scope("fig2", 20 * US)
    hub = Hub("h", LinkSpec(bandwidth_bps=80e9, latency_ns=1000))
    sched = Scheduler(n_cpus=2)
    dev_ep = hub.attach(Endpoint("dev"))
    cpu_ep = hub.attach(Endpoint("cpu0"))

    def vcpu0():
        for _ in range(5):
            yield Compute(10 * US)
        yield Send(cpu_ep, "dev", 4096)
        for _ in range(20):
            yield Compute(10 * US)

    def vcpu1():
        for _ in range(25):
            yield Compute(10 * US)

    def device():
        yield Recv(dev_ep)
        for _ in range(10):
            yield Compute(30 * US)

    ts = [sched.spawn(VTask(n, b(), kind="modeled"))
          for n, b in (("vcpu0", vcpu0), ("vcpu1", vcpu1),
                       ("dev", device))]
    for t in ts:
        t.join(sc)
    sched.run()
    us = (time.perf_counter() - t0) * 1e6
    _csv("fig2_timeline", us,
         f"skew_stalls={sched.stats.skew_stalls};"
         f"max_skew_us={sched.stats.max_skew_seen/1000:.0f};"
         f"dev_wake_vtime_us={ts[2].vtime/1000:.0f}")


def sched() -> None:
    from benchmarks import sched_scale

    for n in (1024, 8192):
        r_ref = sched_scale.bench_reference(n, max(4, n // 64))
        r_vec = sched_scale.bench_vectorized(n, max(4, n // 64))
        _csv(f"sched_ref_{n}",
             r_ref["wall_s"] / r_ref["dispatches"] * 1e6,
             f"disp_per_s={r_ref['dispatch_per_s']:.0f}")
        _csv(f"sched_vec_{n}",
             r_vec["wall_s"] / r_vec["dispatches"] * 1e6,
             f"disp_per_s={r_vec['dispatch_per_s']:.0f};"
             f"speedup={r_vec['dispatch_per_s']/r_ref['dispatch_per_s']:.1f}x")


def hub() -> None:
    import numpy as np

    from repro.core.ipc import Endpoint, Hub, LinkSpec

    h = Hub("bench", LinkSpec(bandwidth_bps=100e9, latency_ns=1000))
    h.attach(Endpoint("rx"))
    h.attach(Endpoint("tx"))
    n = 50_000
    t0 = time.perf_counter()
    for i in range(n):
        h.send("tx", "rx", 1024, send_vtime=i * 100)
    wall = time.perf_counter() - t0
    _csv("hub_route_python", wall / n * 1e6,
         f"msgs_per_s={n/wall:.0f}")

    import jax.numpy as jnp

    from repro.core.engine_jax import hub_visibility

    rng = np.random.default_rng(0)
    m = 200_000
    link = np.sort(rng.integers(0, 64, m)).astype(np.int32)
    send = np.sort(rng.integers(0, 1 << 28, m)).astype(np.int32)
    size = rng.integers(64, 65536, m).astype(np.int32)
    bw = jnp.asarray(rng.uniform(1e9, 100e9, 64), jnp.float32)
    lat = jnp.asarray(rng.integers(100, 10000, 64), jnp.int32)
    args = (jnp.asarray(send), jnp.asarray(size), jnp.asarray(link), bw,
            lat)
    hub_visibility(*args).block_until_ready()
    t0 = time.perf_counter()
    hub_visibility(*args).block_until_ready()
    wall = time.perf_counter() - t0
    _csv("hub_route_vectorized", wall / m * 1e6,
         f"msgs_per_s={m/wall:.0f}")


def cells() -> None:
    from repro.core import CellManager, VTask

    cm = CellManager()
    for i in range(16):
        cm.create(f"c{i}", ways=max(1, 12 // 4), bw_share=1 / 4,
                  bw_demand=0.3, working_set_frac=0.5)
    tasks = [VTask(f"t{i}", None, kind="live") for i in range(16)]
    for i, t in enumerate(tasks):
        cm.assign(t, f"c{i}")
    n = 100_000
    t0 = time.perf_counter()
    acc = 0.0
    co = [f"c{j}" for j in range(4)]
    for i in range(n):
        acc += cm.slowdown(tasks[i % 16], co)
        cm.switch_cost(tasks[i % 16])
    wall = time.perf_counter() - t0
    _csv("cell_accounting", wall / n * 1e6,
         f"mean_slowdown={acc/n:.3f};switches={cm.stats['switches']}")


def cluster() -> None:
    from benchmarks import cluster_bench

    for straggler in (False, True):
        r = cluster_bench.simulate("qwen3_4b", straggler=straggler,
                                   n_steps=3)
        _csv(f"cluster_512chip_straggler={straggler}",
             r["wall_s"] * 1e6 / r["n_steps"],
             f"sim_ms_per_step={r['sim_step_ms']:.2f};"
             f"analytic_ms={r['analytic_step_ms']:.2f};"
             f"ratio={r['ratio']:.2f};msgs={r['messages']}")


def roofline() -> None:
    from benchmarks import roofline as rl

    t0 = time.perf_counter()
    rows = rl.load_all("16x16") + rl.load_all("2x16x16")
    if rows:
        import statistics

        worst = min(rows, key=lambda r: r["roofline_frac"])
        _csv("roofline_summary", (time.perf_counter() - t0) * 1e6,
             f"cells={len(rows)};"
             f"median_frac="
             f"{statistics.median(r['roofline_frac'] for r in rows):.3f};"
             f"worst={worst['arch']}/{worst['shape']}="
             f"{worst['roofline_frac']:.4f}")


def main() -> None:
    print("name,us_per_call,derived")
    table1()
    fig2()
    cells()
    hub()
    sched()
    cluster()
    table2()
    roofline()


if __name__ == "__main__":
    main()
