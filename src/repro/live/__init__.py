"""`repro.live` — recorded-cost ledger for live-execution workloads.

See :mod:`repro.live.recorder` for the record/replay model and
:mod:`repro.sim.live` for the workloads that consume it.
"""
from repro.live.recorder import (TRACE_SCHEMA, CostLedger,
                                 LiveTraceError, LiveTraceMismatch)

__all__ = ["TRACE_SCHEMA", "CostLedger", "LiveTraceError",
           "LiveTraceMismatch"]
