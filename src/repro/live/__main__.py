"""CLI for the live recovery scenario's record/replay ledger.

Record the marquee trace (real sharded trainer; needs >= 2 devices,
e.g. ``XLA_FLAGS=--xla_force_host_platform_device_count=4``)::

    python -m repro.live record --out tests/golden/live_recovery_trace.json

Replay it deterministically on any engine (no JAX work)::

    python -m repro.live replay --trace tests/golden/live_recovery_trace.json
"""
from __future__ import annotations

import argparse
import json
import sys


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.live")
    sub = ap.add_subparsers(dest="cmd", required=True)
    rec = sub.add_parser("record", help="record the live recovery trace")
    rec.add_argument("--out", required=True)
    rec.add_argument("--arch", default="qwen3_4b")
    rec.add_argument("--engine", default="async")
    rec.add_argument("--calibration", type=float, default=1.0)
    rec.add_argument("--n-steps", type=int, default=8)
    rec.add_argument("--checkpoint-every", type=int, default=3)
    rep = sub.add_parser("replay", help="replay a recorded trace")
    rep.add_argument("--trace", required=True)
    rep.add_argument("--engine", default="async")
    rep.add_argument("--n-workers", type=int, default=2)
    args = ap.parse_args(argv)

    if args.cmd == "record":
        from repro.sim.live import record_live_recovery
        report, ledger = record_live_recovery(
            args.out, arch=args.arch, engine=args.engine,
            calibration=args.calibration, n_steps=args.n_steps,
            checkpoint_every=args.checkpoint_every)
        print(f"recorded {args.out} "
              f"({sum(len(v) for v in ledger.tasks.values())} costs)")
    else:
        from repro.live import CostLedger
        from repro.sim.live import live_recovery_sim, recovery_timeline
        sim = live_recovery_sim(CostLedger.replay(args.trace))
        report = sim.run(engine=args.engine, n_workers=args.n_workers)
        print(json.dumps({"status": report.status,
                          "engine": report.mode,
                          "vtime_ns": report.vtime_ns,
                          "recovery": recovery_timeline(report)},
                         indent=1))
        if report.status != "ok" or not recovery_timeline(report):
            return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
