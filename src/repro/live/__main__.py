"""CLI for the live scenarios' record/replay ledgers.

Record a trace (``--scenario`` picks the canned scenario):

* ``recovery`` — the marquee trainer recovery (real sharded trainer;
  needs >= 2 devices, e.g.
  ``XLA_FLAGS=--xla_force_host_platform_device_count=4``)::

      python -m repro.live record --scenario recovery \\
          --out tests/golden/live_recovery_trace.json

* ``serve`` — the real BatchServer under open-loop arrivals (one
  device suffices)::

      python -m repro.live record --scenario serve \\
          --out tests/golden/live_serve_trace.json

* ``colocated`` — live trainer + live server sharing one §3.3 cell,
  both recorded into one multi-driver trace (one device suffices)::

      python -m repro.live record --scenario colocated \\
          --out tests/golden/live_colocated_trace.json

Replay any trace deterministically on any engine (no JAX work); the
scenario is inferred from the trace meta::

    python -m repro.live replay --trace tests/golden/live_serve_trace.json
"""
from __future__ import annotations

import argparse
import json
import sys


def _replay_sim(ledger):
    """Pick the canned scenario a trace belongs to from its pinned
    meta blocks (each recorder writes exactly one of these keys)."""
    from repro.sim.live import (live_colocated_sim, live_recovery_sim,
                                live_serve_sim)
    if "colocated" in ledger.meta:
        return "colocated", live_colocated_sim(ledger)
    if "serve" in ledger.meta:
        return "serve", live_serve_sim(ledger)
    if "recovery" in ledger.meta:
        return "recovery", live_recovery_sim(ledger)
    raise SystemExit(
        "trace meta names no canned scenario (expected one of "
        "'recovery', 'serve', 'colocated')")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.live")
    sub = ap.add_subparsers(dest="cmd", required=True)
    rec = sub.add_parser("record", help="record a live trace")
    rec.add_argument("--out", required=True)
    rec.add_argument("--scenario", default="recovery",
                     choices=("recovery", "serve", "colocated"))
    rec.add_argument("--arch", default="qwen3_4b")
    rec.add_argument("--engine", default="async")
    rec.add_argument("--calibration", type=float, default=1.0)
    rec.add_argument("--n-steps", type=int, default=8,
                     help="recovery: train steps")
    rec.add_argument("--checkpoint-every", type=int, default=3,
                     help="recovery: checkpoint cadence")
    rec.add_argument("--n-requests", type=int, default=12,
                     help="serve: open-loop request count")
    rep = sub.add_parser("replay", help="replay a recorded trace")
    rep.add_argument("--trace", required=True)
    rep.add_argument("--engine", default="async")
    rep.add_argument("--n-workers", type=int, default=2)
    args = ap.parse_args(argv)

    if args.cmd == "record":
        if args.scenario == "recovery":
            from repro.sim.live import record_live_recovery
            report, ledger = record_live_recovery(
                args.out, arch=args.arch, engine=args.engine,
                calibration=args.calibration, n_steps=args.n_steps,
                checkpoint_every=args.checkpoint_every)
        elif args.scenario == "serve":
            from repro.sim.live import record_live_serve
            report, ledger = record_live_serve(
                args.out, arch=args.arch, engine=args.engine,
                calibration=args.calibration,
                n_requests=args.n_requests)
        else:
            from repro.sim.live import record_live_colocated
            report, ledger = record_live_colocated(
                args.out, arch=args.arch, engine=args.engine,
                calibration=args.calibration)
        print(f"recorded {args.scenario} -> {args.out} "
              f"({sum(len(v) for v in ledger.tasks.values())} costs)")
    else:
        from repro.live import CostLedger
        from repro.sim.live import recovery_timeline, serve_latency
        ledger = CostLedger.replay(args.trace)
        scenario, sim = _replay_sim(ledger)
        report = sim.run(engine=args.engine, n_workers=args.n_workers)
        out = {"scenario": scenario, "status": report.status,
               "engine": report.mode, "vtime_ns": report.vtime_ns}
        ok = report.status == "ok"
        if scenario in ("recovery", "colocated"):
            out["recovery"] = recovery_timeline(report)
        if scenario in ("serve", "colocated"):
            out["latency_ns"] = serve_latency(report)
            ok = ok and bool(out["latency_ns"])
        if scenario == "recovery":
            ok = ok and bool(out["recovery"])
        print(json.dumps(out, indent=1))
        if not ok:
            return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
