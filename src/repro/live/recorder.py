"""Record/replay cost ledger for live workloads.

The live-execution subsystem (`repro.sim.live`) runs *real* stack
callables — train steps, checkpoint saves/restores, re-mesh rebuilds —
under simulated time.  Virtual time must advance by how long the call
actually took, but measured wall spans are nondeterministic, and the
cross-engine bar (tests/engine_harness.py) demands bit-identical
results.  SimBricks' lesson (PAPERS.md): composed live+modeled
components stay useful only if runs are repeatable.  The ledger
resolves the tension with two modes:

* ``record`` — :meth:`CostLedger.charge` executes the real callable,
  measures its wall span with ``perf_counter_ns``, scales it by the
  clock ``calibration`` (the pvclock analogue: simulated-ns per
  host-ns), clamps to >= 1 ns, and appends ``{label, cost_ns}`` to the
  per-task trace.  One record run per scenario; the trace is saved as
  versioned JSON (``live_trace/v1``).

  **Multi-driver recording** (SplitSim's isolation concern, PAPERS.md):
  one record run may capture several live drivers — e.g. a trainer and
  a serve stack sharing a ledger — because the in-process engines
  dispatch one live call at a time, so per-task wall spans are
  sequential by construction and never bleed into each other.  The
  ledger *enforces* that sequential-recording phase: a ``charge`` that
  starts while another task's span is still being measured (a nested
  charge, or a driver running off-thread) raises
  :class:`LiveTraceError` immediately instead of silently
  double-counting overlapped wall time in two tasks' costs.

  Optional trace-meta keys a recorder may pin for auditability:
  ``meta["fail_probe"]`` (how a derived fail-at vtime was computed:
  probe span, calibration, margin — see
  ``repro.sim.live.FAIL_PROBE_MARGIN_STEPS``) and per-scenario
  parameter blocks (``meta["recovery"]``, ``meta["serve"]``,
  ``meta["colocated"]`` — including the full open-loop arrival
  schedule, so a replay never re-derives it from an RNG stream).
* ``replay`` — ``charge`` does *not* execute the callable.  It pops the
  next recorded entry for the task, verifies the label matches (a
  scenario that diverges from its trace fails fast, naming the task and
  the expected/actual step key), and returns the pinned integer cost.
  Replayed costs flow through cost-derived
  :class:`~repro.core.vtask.LiveCall` actions, which every engine
  executes bit-identically — so a recorded live scenario passes the
  same equivalence bar as a fully modeled one.

Determinism argument: a live body's control-flow decisions (when to
checkpoint, when a failure is detected) depend only on step indices and
task vtimes.  Replay reproduces every vtime from the recorded integer
costs, so it re-derives exactly the decision sequence the record run
took; the label check turns any divergence into an immediate
:class:`LiveTraceMismatch` instead of silent drift.
"""
from __future__ import annotations

import json
import pathlib
import time
from typing import Any, Callable, Dict, List, Optional, Tuple, Union

TRACE_SCHEMA = "live_trace/v1"


class LiveTraceError(ValueError):
    """A trace file is malformed or has an unknown schema version."""


class LiveTraceMismatch(RuntimeError):
    """Replay diverged from the recorded trace: a task asked for a cost
    the trace does not have (missing task, exhausted entries, or a label
    that does not match the recorded sequence)."""


class CostLedger:
    """Per-(task, step) wall-time ledger; see the module docstring.

    ``meta`` is an opaque dict stored alongside the trace — scenario
    parameters the record run derived (e.g. the fail-at vtime it picked
    from a probe step) that replays must reuse verbatim.
    """

    def __init__(self, mode: str, *, calibration: float = 1.0,
                 tasks: Optional[Dict[str, List[dict]]] = None,
                 meta: Optional[Dict[str, Any]] = None):
        if mode not in ("record", "replay"):
            raise ValueError(f"mode must be 'record' or 'replay', "
                             f"got {mode!r}")
        if calibration <= 0:
            raise ValueError(f"calibration must be > 0, got {calibration}")
        self.mode = mode
        self.calibration = float(calibration)
        self.tasks: Dict[str, List[dict]] = tasks if tasks is not None \
            else {}
        self.meta: Dict[str, Any] = meta if meta is not None else {}
        self._cursor: Dict[str, int] = {}
        # (task, label) currently measuring a wall span, or None —
        # the sequential-recording guard (see module docstring)
        self._measuring: Optional[Tuple[str, str]] = None

    # -- constructors --------------------------------------------------------
    @classmethod
    def record(cls, *, calibration: float = 1.0,
               meta: Optional[Dict[str, Any]] = None) -> "CostLedger":
        return cls("record", calibration=calibration, meta=meta)

    @classmethod
    def replay(cls, trace: Union[str, pathlib.Path, Dict[str, Any]]
               ) -> "CostLedger":
        """Replay ledger from a trace dict or a JSON file path."""
        if isinstance(trace, (str, pathlib.Path)):
            path = pathlib.Path(trace)
            try:
                data = json.loads(path.read_text())
            except FileNotFoundError:
                raise LiveTraceError(f"live trace not found: {path}")
            except json.JSONDecodeError as e:
                raise LiveTraceError(f"live trace {path} is not valid "
                                     f"JSON: {e}")
        else:
            data = trace
        schema = data.get("schema")
        if schema != TRACE_SCHEMA:
            raise LiveTraceError(
                f"unsupported live trace schema {schema!r} "
                f"(this build reads {TRACE_SCHEMA!r})")
        tasks = data.get("tasks")
        if not isinstance(tasks, dict):
            raise LiveTraceError("live trace has no 'tasks' mapping")
        return cls("replay", calibration=float(data.get("calibration",
                                                        1.0)),
                   tasks=tasks, meta=dict(data.get("meta", {})))

    # -- the one verb --------------------------------------------------------
    def charge(self, task: str, label: str,
               fn: Optional[Callable] = None, args: tuple = (),
               kwargs: Optional[dict] = None) -> Tuple[Any, int]:
        """Record mode: run ``fn`` and return ``(result, measured
        cost_ns)``; replay mode: skip ``fn`` and return ``(None, pinned
        cost_ns)`` from the trace, failing fast on any divergence."""
        if self.mode == "record":
            if self._measuring is not None:
                raise LiveTraceError(
                    f"concurrent record: task {task!r} asked to measure "
                    f"{label!r} while task {self._measuring[0]!r} is "
                    f"still measuring {self._measuring[1]!r} — recorded "
                    f"wall spans must not overlap (each would absorb "
                    f"the other's wall time).  Live drivers record in "
                    f"sequential phases: the in-process engines "
                    f"guarantee this by dispatching one live call at a "
                    f"time; do not nest charge() calls or record from "
                    f"threads")
            self._measuring = (task, label)
            try:
                t0 = time.perf_counter_ns()
                result = fn(*args, **(kwargs or {})) if fn is not None \
                    else None
                span = time.perf_counter_ns() - t0
            finally:
                self._measuring = None
            # zero/negative spans (sub-ns callables, clock warp under a
            # virtualized timer) must still advance vtime: a 0-cost live
            # call would let a task spin without progressing, breaking
            # conservative lookahead
            cost = max(1, int(round(span * self.calibration)))
            self.tasks.setdefault(task, []).append(
                {"label": label, "cost_ns": cost})
            return result, cost
        entries = self.tasks.get(task)
        if entries is None:
            raise LiveTraceMismatch(
                f"live trace has no recorded costs for task {task!r} "
                f"(asked for step {label!r}); recorded tasks: "
                f"{sorted(self.tasks)}")
        i = self._cursor.get(task, 0)
        if i >= len(entries):
            raise LiveTraceMismatch(
                f"task {task!r}: trace exhausted after {len(entries)} "
                f"recorded calls but the scenario asked for {label!r} — "
                f"scenario/trace mismatch (re-record the trace)")
        rec = entries[i]
        if rec.get("label") != label:
            raise LiveTraceMismatch(
                f"task {task!r}: replay diverged at call #{i}: "
                f"scenario asked for {label!r} but the trace recorded "
                f"{rec.get('label')!r} — scenario/trace mismatch")
        self._cursor[task] = i + 1
        cost = int(rec["cost_ns"])
        if cost <= 0:
            raise LiveTraceError(
                f"task {task!r}: recorded cost_ns={cost} at {label!r} "
                f"is not positive — corrupt trace")
        return None, cost

    def rewind(self) -> None:
        """Reset the replay cursors to the start of the trace, so a
        replay ledger can drive the same scenario again (a Workload
        instance rebuilt for a second ``Simulation.run()`` calls this
        from its build-time ``reset()``).  Record-mode ledgers have no
        cursor; re-running a record workload is caught by the
        workload's own reset (one record run per ledger)."""
        self._cursor.clear()

    # -- persistence ---------------------------------------------------------
    def to_dict(self) -> Dict[str, Any]:
        return {"schema": TRACE_SCHEMA, "calibration": self.calibration,
                "meta": self.meta, "tasks": self.tasks}

    def save(self, path: Union[str, pathlib.Path]) -> pathlib.Path:
        if self.mode != "record":
            raise LiveTraceError("only a record-mode ledger can be saved")
        path = pathlib.Path(path)
        path.parent.mkdir(parents=True, exist_ok=True)
        path.write_text(json.dumps(self.to_dict(), indent=1,
                                   sort_keys=True) + "\n")
        return path
