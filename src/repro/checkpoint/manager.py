"""Sharded, manifest-committed checkpointing with elastic restore.

Layout (one directory per step):

  <root>/step_000042.tmp/      # written first
    leaf_00000.npy ...         # one file per pytree leaf
    manifest.json              # treedef, shapes, dtypes, step, written last
  <root>/step_000042/          # atomic rename after manifest fsync

Crash safety: a checkpoint exists iff the final rename happened; partial
writes are invisible (".tmp" dirs are garbage-collected on open).  On a
real multi-host deployment each host writes only the shards it owns
(``process_index`` prefix); this container is single-process, so files
hold full arrays but restore still goes through ``jax.device_put`` with
target shardings — restoring onto a *different* mesh (elastic re-shard)
is exercised in tests.

Async: ``save(..., blocking=False)`` snapshots to host RAM immediately
(donation-safe) and writes on a background thread; ``wait()`` joins.
"""
from __future__ import annotations

import json
import os
import pathlib
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np

from repro.compat import tree_leaves_with_path


def _flatten_with_names(tree):
    leaves, treedef = jax.tree.flatten(tree)
    paths = [jax.tree_util.keystr(p)
             for p, _ in tree_leaves_with_path(tree)]
    return leaves, paths, treedef


def save(path: os.PathLike, tree: Any, step: int,
         extra: Optional[dict] = None) -> pathlib.Path:
    """Blocking sharded save with atomic commit."""
    root = pathlib.Path(path)
    root.mkdir(parents=True, exist_ok=True)
    final = root / f"step_{step:08d}"
    tmp = root / f"step_{step:08d}.tmp"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)
    leaves, names, _ = _flatten_with_names(tree)
    manifest = {"step": step, "leaves": [], "extra": extra or {}}
    for i, (leaf, name) in enumerate(zip(leaves, names)):
        arr = np.asarray(leaf)
        logical_dtype = str(arr.dtype)
        if arr.dtype.kind == "V" or logical_dtype == "bfloat16":
            # ml_dtypes (bf16/fp8) are not npy-serializable: store the
            # raw bits and record the logical dtype in the manifest.
            arr = arr.view(np.uint16 if logical_dtype == "bfloat16"
                           else np.uint8)
        fn = f"leaf_{i:05d}.npy"
        np.save(tmp / fn, arr)
        manifest["leaves"].append(
            {"name": name, "file": fn, "shape": list(arr.shape),
             "dtype": logical_dtype})
    mpath = tmp / "manifest.json"
    with open(mpath, "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    if final.exists():
        shutil.rmtree(final)
    tmp.rename(final)
    return final


def latest_step(path: os.PathLike) -> Optional[int]:
    root = pathlib.Path(path)
    if not root.exists():
        return None
    # GC partial writes
    for tmp in root.glob("step_*.tmp"):
        shutil.rmtree(tmp, ignore_errors=True)
    steps = sorted(int(p.name.split("_")[1])
                   for p in root.glob("step_*") if p.is_dir()
                   and (p / "manifest.json").exists())
    return steps[-1] if steps else None


def restore(path: os.PathLike, like: Any, step: Optional[int] = None,
            shardings: Any = None) -> tuple:
    """Restore into the structure of ``like``.  ``shardings`` (optional
    pytree matching ``like``) re-shards onto the *current* mesh — the
    elastic-restart path (the saved mesh may have had a different size).
    Returns (tree, step, extra)."""
    root = pathlib.Path(path)
    if step is None:
        step = latest_step(root)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {root}")
    d = root / f"step_{step:08d}"
    manifest = json.loads((d / "manifest.json").read_text())
    leaves, names, treedef = _flatten_with_names(like)
    assert len(leaves) == len(manifest["leaves"]), \
        f"checkpoint has {len(manifest['leaves'])} leaves, " \
        f"expected {len(leaves)}"
    sh_leaves = (treedef.flatten_up_to(shardings)
                 if shardings is not None else [None] * len(leaves))
    import ml_dtypes

    out = []
    for rec, leaf, sh in zip(manifest["leaves"], leaves, sh_leaves):
        arr = np.load(d / rec["file"])
        if rec["dtype"] == "bfloat16":
            arr = arr.view(ml_dtypes.bfloat16)
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(
                f"{rec['name']}: shape {arr.shape} != {leaf.shape}")
        if sh is not None:
            out.append(jax.device_put(arr.astype(leaf.dtype), sh))
        else:
            out.append(jax.numpy.asarray(arr, dtype=leaf.dtype))
    return treedef.unflatten(out), manifest["step"], manifest["extra"]


class CheckpointManager:
    """Async writer + retention policy."""

    def __init__(self, path: os.PathLike, keep: int = 3):
        self.path = pathlib.Path(path)
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self.saved_steps: list = []

    def save(self, tree: Any, step: int, extra: Optional[dict] = None,
             blocking: bool = True) -> None:
        # snapshot to host before the training step can donate buffers
        host_tree = jax.tree.map(lambda x: np.asarray(x), tree)

        def work():
            save(self.path, host_tree, step, extra)
            self.saved_steps.append(step)
            self._retain()

        self.wait()
        if blocking:
            work()
        else:
            self._thread = threading.Thread(target=work, daemon=True)
            self._thread.start()

    def _retain(self) -> None:
        steps = sorted(int(p.name.split("_")[1])
                       for p in self.path.glob("step_*") if p.is_dir())
        for s in steps[: -self.keep]:
            shutil.rmtree(self.path / f"step_{s:08d}",
                          ignore_errors=True)

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def restore_latest(self, like: Any, shardings: Any = None):
        self.wait()
        return restore(self.path, like, shardings=shardings)
