from repro.serve.step import (build_prefill_step, build_decode_step,
                              cache_shardings)
