"""Batched serving loop: prefill + decode with per-request bookkeeping.

Single static batch per wave (continuous batching is a scheduling-layer
concern that LiveStack simulates; the execution layer here provides the
real prefill/decode steps with KV-cache reuse, EOS early-exit, and
latency accounting per request).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import registry
from repro.models.common import ModelConfig


@dataclasses.dataclass
class ServeStats:
    prefill_s: float
    decode_s: float
    tokens_out: int
    per_token_ms: float
    throughput_tok_s: float
    decode_steps: int = 0


class BatchServer:
    def __init__(self, cfg: ModelConfig, params, max_new_tokens: int = 32,
                 eos_id: Optional[int] = None, pad_id: int = 0):
        self.cfg = cfg
        self.params = params
        self.max_new = max_new_tokens
        self.eos_id = eos_id
        self.pad_id = pad_id
        self._prefill = jax.jit(
            lambda p, t, fe: registry.prefill(
                cfg, p, t, frontend_embeds=fe,
                max_len=t.shape[1] + max_new_tokens))
        self._decode = jax.jit(
            lambda p, tok, cache: registry.decode_step(cfg, p, tok, cache))

    def generate(self, prompts: jnp.ndarray,
                 frontend_embeds=None) -> Dict:
        """prompts (B, S) int32 -> dict with tokens (B, <=max_new) + stats.

        With an ``eos_id``, a lane that has emitted it is finished: its
        later positions hold ``pad_id`` (a finished lane's argmax is KV
        garbage, not output), ``tokens_out`` counts only tokens emitted
        by lanes still alive at step start, and decode exits as soon as
        every lane is done — ``per_token_ms`` divides by the decode
        steps actually executed, not the output width.
        """
        b = prompts.shape[0]
        t0 = time.perf_counter()
        logits, cache = self._prefill(self.params, prompts,
                                      frontend_embeds)
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        jax.block_until_ready(tok)
        t1 = time.perf_counter()
        t_np = np.asarray(tok)
        out = [t_np]
        alive = np.ones(b, bool)
        if self.eos_id is not None:
            alive &= t_np != self.eos_id
        n_out = b
        decode_steps = 0
        for _ in range(self.max_new - 1):
            if self.eos_id is not None and not alive.any():
                break
            logits, cache = self._decode(self.params, tok, cache)
            tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            decode_steps += 1
            t_np = np.asarray(tok)
            if self.eos_id is not None:
                t_np = np.where(alive, t_np,
                                self.pad_id).astype(np.int32)
                n_out += int(alive.sum())
                alive &= t_np != self.eos_id
            else:
                n_out += b
            out.append(t_np)
        jax.block_until_ready(tok)
        t2 = time.perf_counter()
        tokens = np.stack(out, axis=1)
        stats = ServeStats(
            prefill_s=t1 - t0, decode_s=t2 - t1, tokens_out=n_out,
            per_token_ms=(t2 - t1) / max(decode_steps, 1) * 1e3,
            throughput_tok_s=n_out / max(t2 - t0, 1e-9),
            decode_steps=decode_steps)
        return {"tokens": tokens, "stats": stats}
