"""Serve-step builders: prefill and single-token decode.

Decode shards the KV-cache sequence dimension over ``model`` (SP /
flash-decoding style) because GQA kv-head counts (1-10) rarely divide the
TP axis; batch shards over DP axes when divisible, else replicates
(long_500k has global_batch=1).
"""
from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models import registry
from repro.models.common import ModelConfig
from repro.parallel import ctx as pctx
from repro.parallel import sharding as shd


def build_prefill_step(cfg: ModelConfig) -> Callable:
    def step(params, tokens, frontend_embeds=None):
        return registry.prefill(cfg, params, tokens,
                                frontend_embeds=frontend_embeds)

    return step


def build_decode_step(cfg: ModelConfig) -> Callable:
    def step(params, token, cache):
        return registry.decode_step(cfg, params, token, cache)

    return step


def serve_rules(cfg: ModelConfig, mesh, batch: int) -> dict:
    """Rule overrides for serving shapes (batch may not divide DP)."""
    rules = dict(shd.DEFAULT_RULES)
    dp = pctx.dp_size(mesh)
    if batch % dp != 0:
        ba = [a for a in pctx.batch_axes(mesh)
              if batch % mesh.shape[a] == 0]
        rules["batch"] = tuple(ba) if ba else None
    else:
        rules["batch"] = tuple(pctx.batch_axes(mesh))
    return rules


def cache_shardings(cfg: ModelConfig, mesh, batch: int, max_len: int,
                    rules: Optional[dict] = None):
    rules = rules or serve_rules(cfg, mesh, batch)
    axes = registry.cache_axes(cfg)
    specs = registry.cache_specs(cfg, batch, max_len)
    return shd.shardings_from_axes(axes, mesh, rules, specs)
