"""Train-step builder: microbatched gradient accumulation + AdamW, fully
sharded (FSDP over ``data``, TP over ``model``, DP over ``pod``+``data``).

The returned step is a plain function of (params, opt_state, step_idx,
batch) so it can be ``jax.jit``-ed with explicit in/out shardings by both
the real trainer (``repro.launch.train``) and the dry-run launcher.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models import registry
from repro.models.common import ModelConfig, softmax_cross_entropy
from repro.optim import (AdamWConfig, adamw_init, adamw_update, lr_schedule,
                         opt_state_axes, opt_state_specs)
from repro.parallel import ctx as pctx
from repro.parallel import sharding as shd


def _loss_fn(cfg: ModelConfig, params, tokens, labels, frontend_embeds):
    if cfg.n_experts > 0:
        logits, aux = registry.forward(cfg, params, tokens,
                                       frontend_embeds=frontend_embeds,
                                       return_aux=True)
        ce = softmax_cross_entropy(logits[:, :-1], labels[:, 1:])
        return ce + cfg.router_aux_coef * aux, ce
    logits = registry.forward(cfg, params, tokens,
                              frontend_embeds=frontend_embeds)
    ce = softmax_cross_entropy(logits[:, :-1], labels[:, 1:])
    return ce, ce


def build_train_step(cfg: ModelConfig, *, n_microbatch: int = 1,
                     opt: AdamWConfig = AdamWConfig(),
                     lr_kwargs: Optional[dict] = None) -> Callable:
    """Returns step(params, opt_state, step_idx, batch) ->
    (params, opt_state, metrics).

    batch = {tokens (B,S), labels (B,S)[, frontend_embeds]}
    """
    lr_kwargs = lr_kwargs or {}

    def step(params, opt_state, step_idx, batch):
        tokens = batch["tokens"]
        b = tokens.shape[0]
        assert b % n_microbatch == 0, (b, n_microbatch)
        mb = b // n_microbatch

        # §Perf gather-weights-once: hoist the FSDP all-gather out of the
        # microbatch/remat passes (baseline re-gathers every pass).
        # Compute runs on a TP-only layout; gradients reduce-scatter back
        # to the FSDP layout before the optimizer.
        compute_params = params
        if cfg.gather_weights_once and pctx.get_mesh() is not None:
            mesh = pctx.get_mesh()
            rules = dict(shd.DEFAULT_RULES)
            rules["embed"] = None          # drop the FSDP dim
            rules["expert_mlp"] = None
            axes = registry.logical_axes(cfg)
            g_sh = shd.shardings_from_axes(axes, mesh, rules, params)
            compute_params = jax.tree.map(
                jax.lax.with_sharding_constraint, params, g_sh)

        def resh(x):
            y = x.reshape(n_microbatch, mb, *x.shape[1:])
            mesh = pctx.get_mesh()
            if mesh is not None:
                # Keep each microbatch sharded over ALL DP axes.  Without
                # this, GSPMD aligns the new n_mb dim with the pod axis
                # (pod p holds microbatch p) and every scan iteration then
                # computes a full microbatch replicated across pods —
                # verified 2x per-chip flops on the 2x16x16 mesh.
                ba = pctx.batch_axes(mesh)
                spec = P(None, ba if len(ba) > 1 else ba[0],
                         *([None] * (x.ndim - 1)))
                y = jax.lax.with_sharding_constraint(
                    y, NamedSharding(mesh, spec))
            return y

        mbatch = jax.tree.map(resh, batch)
        zeros_like32 = lambda p: jnp.zeros(p.shape, jnp.float32)
        grad0 = jax.tree.map(zeros_like32, params)

        def mb_body(carry, mbx):
            gacc, lacc = carry
            fe = mbx.get("frontend_embeds")
            (_, ce), grads = jax.value_and_grad(
                lambda p: _loss_fn(cfg, p, mbx["tokens"], mbx["labels"],
                                   fe), has_aux=True)(compute_params)
            gacc = jax.tree.map(
                lambda a, g: a + g.astype(jnp.float32), gacc, grads)
            return (gacc, lacc + ce), None

        if n_microbatch == 1:
            mbx = jax.tree.map(lambda x: x[0], mbatch)
            (grads, loss), _ = mb_body((grad0, jnp.float32(0.0)), mbx)
        elif pctx.get_unroll():
            carry = (grad0, jnp.float32(0.0))
            for i in range(n_microbatch):
                mbx = jax.tree.map(lambda x: x[i], mbatch)
                carry, _ = mb_body(carry, mbx)
            grads, loss = carry
        else:
            (grads, loss), _ = jax.lax.scan(
                mb_body, (grad0, jnp.float32(0.0)), mbatch)
        grads = jax.tree.map(lambda g: g / n_microbatch, grads)
        loss = loss / n_microbatch

        lr = lr_schedule(step_idx, **lr_kwargs)
        params2, opt_state2, om = adamw_update(opt, grads, params,
                                               opt_state, lr)
        metrics = {"loss": loss, **om}
        return params2, opt_state2, metrics

    return step


# ---------------------------------------------------------------------------
# Sharding helpers
# ---------------------------------------------------------------------------


def train_state_shardings(cfg: ModelConfig, mesh,
                          rules: Optional[dict] = None):
    """(param_shardings, opt_shardings) for jit."""
    axes = registry.logical_axes(cfg)
    p_specs = registry.param_specs(cfg)
    p_sh = shd.shardings_from_axes(axes, mesh, rules, p_specs)
    o_sh = {
        "m": p_sh,
        "v": p_sh,
        "count": NamedSharding(mesh, P()),
    }
    return p_sh, o_sh


def batch_shardings(cfg: ModelConfig, mesh, specs: Dict) -> Dict:
    out = {}
    for k, s in specs.items():
        out[k] = shd.batch_sharding(mesh, ndim=len(s.shape))
    return out


def train_state_specs(cfg: ModelConfig):
    p_specs = registry.param_specs(cfg)
    return p_specs, opt_state_specs(p_specs)
