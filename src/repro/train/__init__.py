from repro.train.step import build_train_step, train_state_shardings
