"""JAX version-compatibility shims.

The repo targets the modern JAX API surface, but must run on whatever
JAX the container bakes in (currently 0.4.x).  Two drift points matter:

* ``pltpu.CompilerParams`` was named ``pltpu.TPUCompilerParams`` before
  JAX 0.6; ``tpu_compiler_params(...)`` resolves whichever exists.
* ``jax.tree.leaves_with_path`` / ``jax.tree.flatten_with_path``
  appeared in 0.4.34+ in the ``jax.tree`` namespace; older releases only
  expose them under ``jax.tree_util`` with the ``tree_`` prefix.

All kernels, the checkpoint manager, and the smoke tests route through
this module instead of touching the drifting names directly.
"""
from __future__ import annotations

from typing import Any

import jax

try:  # pltpu is importable on CPU-only installs; guard anyway.
    from jax.experimental.pallas import tpu as _pltpu
except ImportError:  # pragma: no cover - pallas always ships with jax
    _pltpu = None

_TPU_PARAMS_CLS = None
if _pltpu is not None:
    _TPU_PARAMS_CLS = (getattr(_pltpu, "CompilerParams", None)
                       or getattr(_pltpu, "TPUCompilerParams", None))


def tpu_compiler_params(**kwargs: Any):
    """``pltpu.CompilerParams(**kwargs)`` under any JAX version.

    Returns None when pallas-TPU is unavailable (pallas_call accepts
    ``compiler_params=None``).
    """
    if _TPU_PARAMS_CLS is None:
        return None
    return _TPU_PARAMS_CLS(**kwargs)


def tree_leaves_with_path(tree: Any, is_leaf=None):
    """``jax.tree.leaves_with_path`` with a ``jax.tree_util`` fallback."""
    fn = getattr(getattr(jax, "tree", None), "leaves_with_path", None)
    if fn is None:
        fn = jax.tree_util.tree_leaves_with_path
    return fn(tree, is_leaf=is_leaf)


def tree_flatten_with_path(tree: Any, is_leaf=None):
    """``jax.tree.flatten_with_path`` with a ``jax.tree_util`` fallback."""
    fn = getattr(getattr(jax, "tree", None), "flatten_with_path", None)
    if fn is None:
        fn = jax.tree_util.tree_flatten_with_path
    return fn(tree, is_leaf=is_leaf)
