"""Deterministic synthetic LM data pipeline.

Step-indexed (stateless) generation: batch(step) is a pure function of
(seed, step), so restarts resume mid-stream exactly (the checkpoint only
needs the step counter — the fault-tolerance property tested in
tests/test_runtime.py), and every data-parallel host can slice its own
shard without coordination.

The token stream is a repeatable mixture: a Markov-ish structured
component (so the loss actually goes down in examples) plus uniform
noise.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class SyntheticLMData:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    frontend_dim: int = 0
    frontend_tokens: int = 0

    def batch(self, step: int) -> Dict[str, jnp.ndarray]:
        rng = np.random.default_rng((self.seed, step))
        b, s = self.global_batch, self.seq_len
        # structured component: a GLOBAL affine token map t_{i+1} =
        # (a*t_i + c) % vocab (fixed per seed) — learnable as a lookup
        # table, so training losses drop fast even for tiny models.
        g = np.random.default_rng(self.seed)
        a = int(g.integers(1, 8)) | 1          # odd -> bijective mod 2^k
        c = int(g.integers(0, self.vocab))
        t0 = rng.integers(0, self.vocab, size=(b, 1))
        idx = np.arange(s)[None, :]
        # closed form of the affine recurrence
        structured = t0.astype(np.int64)
        cols = [structured % self.vocab]
        for _ in range(s - 1):
            structured = (a * structured + c) % self.vocab
            cols.append(structured)
        structured = np.concatenate(cols, axis=1)
        noise = rng.integers(0, self.vocab, size=(b, s))
        take_noise = rng.random((b, s)) < 0.1
        tokens = np.where(take_noise, noise, structured).astype(np.int32)
        out = {"tokens": jnp.asarray(tokens),
               "labels": jnp.asarray(tokens)}
        if self.frontend_tokens:
            fe = rng.standard_normal(
                (b, self.frontend_tokens, self.frontend_dim))
            out["frontend_embeds"] = jnp.asarray(fe, jnp.float32)
        return out
