"""Reference attention used by every attention-bearing architecture.

This is the pure-jnp path that the dry-run lowers (XLA fuses it well and it
keeps multi-device compiles robust).  The Pallas kernels in
``repro.kernels.flash_attention`` / ``decode_attention`` are numerical
drop-ins validated against this module.

Key property: queries are processed in chunks via ``lax.scan`` (native
flash-style blocking at the HLO level), so a 32k×32k attention never
materializes an (S, S) score tensor — per-chunk memory is (chunk, S).
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _repeat_kv(k: jnp.ndarray, q_per_kv: int) -> jnp.ndarray:
    """(B, S, Hkv, hd) -> (B, S, Hkv*q_per_kv, hd) by head-group broadcast."""
    if q_per_kv == 1:
        return k
    b, s, hkv, hd = k.shape
    k = jnp.broadcast_to(k[:, :, :, None, :], (b, s, hkv, q_per_kv, hd))
    return k.reshape(b, s, hkv * q_per_kv, hd)


def attend_chunk(q, k, v, mask, scale):
    """q (B,Cq,H,hd)  k/v (B,Sk,H,hd)  mask (Cq,Sk) bool -> (B,Cq,H,hd)."""
    s = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32) * scale
    s = jnp.where(mask[None, None, :, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", p.astype(v.dtype), v)


def multi_head_attention(
    q: jnp.ndarray,               # (B, Sq, H, hd)
    k: jnp.ndarray,               # (B, Sk, Hkv, hd)
    v: jnp.ndarray,               # (B, Sk, Hkv, hd)
    *,
    causal: bool = True,
    window: int = 0,              # 0 = full; >0 = sliding local window
    q_offset: int = 0,            # absolute position of q[0] (for decode)
    chunk_q: int = 1024,
    causal_slice: bool = False,   # §Perf: triangle slicing (unrolled path)
) -> jnp.ndarray:
    """Chunked masked attention.  Handles GQA by repeating KV heads."""
    b, sq, h, hd = q.shape
    sk = k.shape[1]
    q_per_kv = h // k.shape[2]
    k = _repeat_kv(k, q_per_kv)
    v = _repeat_kv(v, q_per_kv)
    scale = 1.0 / jnp.sqrt(jnp.float32(hd))

    kpos = jnp.arange(sk)

    def mask_for(qpos):
        m = jnp.ones((qpos.shape[0], sk), dtype=bool)
        if causal:
            m &= kpos[None, :] <= qpos[:, None]
        if window > 0:
            m &= kpos[None, :] > qpos[:, None] - window
        return m

    if sq <= chunk_q:
        qpos = q_offset + jnp.arange(sq)
        return attend_chunk(q, k, v, mask_for(qpos), scale)

    n_chunks = sq // chunk_q
    assert sq % chunk_q == 0, f"sq={sq} not divisible by chunk_q={chunk_q}"
    qc = q.reshape(b, n_chunks, chunk_q, h, hd).transpose(1, 0, 2, 3, 4)

    from repro.parallel import ctx as pctx

    if pctx.get_unroll():
        outs = []
        for i in range(n_chunks):
            qpos = q_offset + i * chunk_q + jnp.arange(chunk_q)
            if causal_slice and causal and window == 0:
                # causal triangle: chunk i only attends keys < chunk end
                # (the jnp analogue of the flash kernel's block skipping;
                # saves ~half the attention flops + masked-softmax work)
                hi = min(q_offset + (i + 1) * chunk_q, sk)
                ki, vi = k[:, :hi], v[:, :hi]
                m = mask_for(qpos)[:, :hi]
                outs.append(attend_chunk(qc[i], ki, vi, m, scale))
            else:
                outs.append(attend_chunk(qc[i], k, v, mask_for(qpos),
                                         scale))
        out = jnp.stack(outs)
    else:
        def body(_, args):
            i, qi = args
            qpos = q_offset + i * chunk_q + jnp.arange(chunk_q)
            return None, attend_chunk(qi, k, v, mask_for(qpos), scale)

        _, out = jax.lax.scan(body, None, (jnp.arange(n_chunks), qc))
    return out.transpose(1, 0, 2, 3, 4).reshape(b, sq, h, hd)


def decode_attention_sp(q, k_cache, v_cache, cache_len) -> jnp.ndarray:
    """Flash-decoding over the sequence-sharded KV cache (§Perf
    sp_decode): an explicit shard_map keeps each chip's cache shard in
    place — local partial softmax (max-trick) + tiny psum of (m, l, o)
    over the ``model`` axis — instead of GSPMD's whole-cache re-gather
    to kv-head sharding each layer.

    q (B,1,H,hd); caches (B,S,Hkv,hd) with S sharded over 'model' and B
    over DP axes; cache_len scalar."""
    from jax.sharding import PartitionSpec as P

    from repro.parallel import ctx as pctx

    mesh = pctx.get_mesh()
    if mesh is None or "model" not in mesh.axis_names:
        return decode_attention(q, k_cache, v_cache, cache_len)
    m = mesh.shape["model"]
    b, s = q.shape[0], k_cache.shape[1]
    ba = pctx.batch_axes(mesh)
    dp = pctx.dp_size(mesh)
    bspec = ((ba if len(ba) > 1 else ba[0])
             if (dp > 1 and b % dp == 0) else None)
    s_loc = s // m

    def local_fn(ql, kl, vl, ln):
        # shard offset along the sequence axis
        rank = jax.lax.axis_index("model")
        base = rank * s_loc
        hkv = kl.shape[2]
        h = ql.shape[2]
        kl = _repeat_kv(kl, h // hkv)
        vl = _repeat_kv(vl, h // hkv)
        scale = 1.0 / jnp.sqrt(jnp.float32(ql.shape[-1]))
        sc = jnp.einsum("bqhd,bkhd->bhk", ql.astype(jnp.float32) * scale,
                        kl.astype(jnp.float32))          # (B,H,s_loc)
        valid = (base + jnp.arange(s_loc))[None, None, :] < ln
        sc = jnp.where(valid, sc, NEG_INF)
        m_loc = jnp.max(sc, axis=-1)                      # (B,H)
        m_g = jax.lax.pmax(m_loc, "model")
        p = jnp.exp(sc - m_g[..., None])
        p = jnp.where(valid, p, 0.0)
        l_loc = jnp.sum(p, axis=-1)                       # (B,H)
        o_loc = jnp.einsum("bhk,bkhd->bhd", p,
                           vl.astype(jnp.float32))        # (B,H,hd)
        l_g = jax.lax.psum(l_loc, "model")
        o_g = jax.lax.psum(o_loc, "model")
        o = o_g / jnp.maximum(l_g, 1e-30)[..., None]
        return o[:, None].astype(ql.dtype)                # (B,1,H,hd)

    ln = jnp.asarray(cache_len).reshape(())
    return jax.shard_map(
        local_fn, mesh=mesh,
        in_specs=(P(bspec, None, None, None),
                  P(bspec, "model", None, None),
                  P(bspec, "model", None, None), P()),
        out_specs=P(bspec, None, None, None),
        check_vma=False,
    )(q, k_cache, v_cache, ln)


def decode_attention(
    q: jnp.ndarray,               # (B, 1, H, hd)
    k_cache: jnp.ndarray,         # (B, S, Hkv, hd)
    v_cache: jnp.ndarray,         # (B, S, Hkv, hd)
    cache_len: jnp.ndarray | int, # valid prefix length (scalar or (B,))
) -> jnp.ndarray:
    """Single-token attention against a (possibly padded) KV cache."""
    b, _, h, hd = q.shape
    sk = k_cache.shape[1]
    q_per_kv = h // k_cache.shape[2]
    k = _repeat_kv(k_cache, q_per_kv)
    v = _repeat_kv(v_cache, q_per_kv)
    scale = 1.0 / jnp.sqrt(jnp.float32(hd))
    s = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32) * scale
    kpos = jnp.arange(sk)
    cache_len = jnp.asarray(cache_len)
    if cache_len.ndim == 0:
        valid = jnp.broadcast_to(kpos[None, :] < cache_len, (b, sk))
    else:
        valid = kpos[None, :] < cache_len[:, None]
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", p.astype(v.dtype), v)
