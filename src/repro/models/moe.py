"""Token-choice top-k Mixture-of-Experts FFN (olmoe-1b-7b, moonshot-v1-16b-a3b).

Two execution paths with identical dispatch semantics:

* ``moe_ffn_reference`` — single-shard capacity dispatch (the oracle).
* ``moe_ffn_sharded``  — expert-parallel ``shard_map``:
     tokens resharded over the ``model`` axis (sequence-split) ->
     local capacity dispatch (scatter, no (T,E,C) one-hot) ->
     ``all_to_all`` over ``model`` (EP) -> per-expert SwiGLU
     (weights FSDP-gathered over ``data``) -> ``all_to_all`` back ->
     weighted combine.

Capacity: ``C = clamp(ceil(top_k * T / E * capacity_factor), 8, T*top_k)``
per shard; overflow tokens are dropped (GShard semantics) and their
residual stream passes through unchanged.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models import common as cm
from repro.models.common import ModelConfig
from repro.parallel import ctx as pctx

# ---------------------------------------------------------------------------
# Params
# ---------------------------------------------------------------------------


def moe_params(key, cfg: ModelConfig) -> dict:
    d, e, f, dt = cfg.d_model, cfg.n_experts, cfg.d_ff, cfg.dtype
    ks = jax.random.split(key, 4)
    return {
        "router": cm.dense_init(ks[0], (d, e), jnp.float32),
        "w_gate": cm.dense_init(ks[1], (e, d, f), dt, in_axis=1),
        "w_up": cm.dense_init(ks[2], (e, d, f), dt, in_axis=1),
        "w_down": cm.dense_init(ks[3], (e, f, d), dt, in_axis=1),
    }


def moe_specs(cfg: ModelConfig) -> dict:
    d, e, f, dt = cfg.d_model, cfg.n_experts, cfg.d_ff, cfg.dtype
    return {
        "router": jax.ShapeDtypeStruct((d, e), jnp.float32),
        "w_gate": jax.ShapeDtypeStruct((e, d, f), dt),
        "w_up": jax.ShapeDtypeStruct((e, d, f), dt),
        "w_down": jax.ShapeDtypeStruct((e, f, d), dt),
    }


MOE_AXES = {
    "router": (None, None),
    "w_gate": ("expert", None, "expert_mlp"),
    "w_up": ("expert", None, "expert_mlp"),
    "w_down": ("expert", "expert_mlp", None),
}


# ---------------------------------------------------------------------------
# Dispatch core (shared by both paths)
# ---------------------------------------------------------------------------


def _capacity(t: int, cfg: ModelConfig) -> int:
    c = math.ceil(cfg.top_k * t / cfg.n_experts * cfg.capacity_factor)
    return max(8, min(c, t * cfg.top_k))


def _route(xt: jnp.ndarray, router: jnp.ndarray, cfg: ModelConfig):
    """xt (T, D) -> top-k ids (T,k), weights fp32 (T,k), aux loss scalar."""
    logits = jnp.dot(xt.astype(jnp.float32), router)          # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    w, ids = jax.lax.top_k(probs, cfg.top_k)                  # (T, k)
    w = w / jnp.sum(w, axis=-1, keepdims=True)
    # Switch-style load-balancing loss: E * sum_e f_e * p_e
    f_e = jnp.mean(
        jnp.sum(jax.nn.one_hot(ids, cfg.n_experts, dtype=jnp.float32),
                axis=1), axis=0)
    p_e = jnp.mean(probs, axis=0)
    aux = cfg.n_experts * jnp.sum(f_e * p_e)
    return ids, w, aux


def _dispatch_indices(ids: jnp.ndarray, t: int, cap: int, cfg: ModelConfig):
    """Position of each (token, slot) within its expert's capacity buffer.

    Returns flat scatter indices (T*k,) into (E*cap) with dropped slots
    mapped to E*cap (out of bounds -> scatter 'drop' mode)."""
    flat = ids.reshape(-1)                                    # (T*k,) token-major
    onehot = jax.nn.one_hot(flat, cfg.n_experts, dtype=jnp.int32)
    pos = jnp.cumsum(onehot, axis=0) * onehot - 1             # (T*k, E)
    pos = jnp.sum(pos * onehot, axis=-1)                      # (T*k,)
    keep = pos < cap
    idx = flat * cap + pos
    return jnp.where(keep, idx, cfg.n_experts * cap), keep


def _expert_ffn(buf: jnp.ndarray, w_gate, w_up, w_down) -> jnp.ndarray:
    """buf (E, C, D) x weights (E, D, F)/(E, F, D) -> (E, C, D)."""
    g = jnp.einsum("ecd,edf->ecf", buf, w_gate)
    u = jnp.einsum("ecd,edf->ecf", buf, w_up)
    h = jax.nn.silu(g.astype(jnp.float32)).astype(buf.dtype) * u
    return jnp.einsum("ecf,efd->ecd", h, w_down)


def _local_moe(xt, p, cfg: ModelConfig, cap: int,
               ffn=_expert_ffn) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Full dispatch->ffn->combine on local tokens xt (T, D)."""
    t, d = xt.shape
    e, k = cfg.n_experts, cfg.top_k
    ids, w, aux = _route(xt, p["router"], cfg)
    idx, keep = _dispatch_indices(ids, t, cap, cfg)
    xt_rep = jnp.repeat(xt, k, axis=0)                        # (T*k, D)
    buf = jnp.zeros((e * cap, d), xt.dtype)
    buf = buf.at[idx].set(xt_rep, mode="drop")
    buf = buf.reshape(e, cap, d)
    out = ffn(buf, p["w_gate"], p["w_up"], p["w_down"])       # (E, C, D)
    out = out.reshape(e * cap, d)
    gathered = jnp.take(out, jnp.minimum(idx, e * cap - 1), axis=0)
    gathered = jnp.where(keep[:, None], gathered, 0.0)
    y = (gathered.reshape(t, k, d).astype(jnp.float32)
         * w[:, :, None]).sum(axis=1)
    return y.astype(xt.dtype), aux


# ---------------------------------------------------------------------------
# Reference (single-shard) path
# ---------------------------------------------------------------------------


def moe_ffn_reference(cfg: ModelConfig, p: dict, x: jnp.ndarray):
    b, s, d = x.shape
    xt = x.reshape(b * s, d)
    y, aux = _local_moe(xt, p, cfg, _capacity(b * s, cfg))
    return y.reshape(b, s, d), aux


# ---------------------------------------------------------------------------
# Sharded (expert-parallel) path
# ---------------------------------------------------------------------------


def moe_ffn_sharded(cfg: ModelConfig, p: dict, x: jnp.ndarray):
    """x (B, S, D) global.  Requires an active mesh (see parallel.ctx)."""
    mesh = pctx.get_mesh()
    axes = mesh.axis_names
    batch_ax = pctx.batch_axes(mesh)          # ('pod','data') or ('data',)
    mdl = "model"
    m = mesh.shape[mdl]
    b, s, d = x.shape
    shard_seq = (s % m == 0) and s >= m and s > 1
    # per-shard token count
    dp = math.prod(mesh.shape[a] for a in batch_ax)
    t_loc = (b // dp) * (s // m if shard_seq else s)
    cap = _capacity(max(t_loc, 1), cfg)

    x_spec = P(batch_ax, mdl, None) if shard_seq else P(batch_ax, None, None)
    w_specs = {
        "router": P(None, None),
        "w_gate": P(mdl, None, "data"),
        "w_up": P(mdl, None, "data"),
        "w_down": P(mdl, "data", None),
    }

    def local_fn(xl, pl):
        bl, sl, _ = xl.shape
        xt = xl.reshape(bl * sl, d)
        # FSDP-gather expert weights over 'data'
        pg = dict(pl)
        pg["w_gate"] = jax.lax.all_gather(pl["w_gate"], "data", axis=2,
                                          tiled=True)
        pg["w_up"] = jax.lax.all_gather(pl["w_up"], "data", axis=2,
                                        tiled=True)
        pg["w_down"] = jax.lax.all_gather(pl["w_down"], "data", axis=1,
                                          tiled=True)

        def ep_ffn(buf, wg, wu, wd):
            # buf (E, C, D) -> a2a -> (E/m, C*m, D) -> ffn -> a2a back
            buf = jax.lax.all_to_all(buf, mdl, split_axis=0, concat_axis=1,
                                     tiled=True)
            out = _expert_ffn(buf, wg, wu, wd)
            return jax.lax.all_to_all(out, mdl, split_axis=1, concat_axis=0,
                                      tiled=True)

        y, aux = _local_moe(xt, pg, cfg, cap, ffn=ep_ffn)
        # aux varies over the axes that shard tokens; pmean only those
        # (when S is not sharded, aux is model-invariant already).
        aux_axes = batch_ax + ((mdl,) if shard_seq else ())
        aux = jax.lax.pmean(aux, aux_axes)
        return y.reshape(bl, sl, d), aux

    # check_vma=False: when S is not sharded (decode), every model rank
    # computes identical dispatch and the a2a round trip reassembles the
    # full (E, C, D) buffer identically on each rank — replicated by
    # construction, but not statically inferable through all_to_all.
    y, aux = jax.shard_map(
        local_fn, mesh=mesh,
        in_specs=(x_spec, {"router": w_specs["router"],
                           "w_gate": w_specs["w_gate"],
                           "w_up": w_specs["w_up"],
                           "w_down": w_specs["w_down"]}),
        out_specs=(x_spec, P()),
        check_vma=False,
    )(x, p)
    return y, aux


def moe_ffn(cfg: ModelConfig, p: dict, x: jnp.ndarray):
    """Dispatch to sharded path when a mesh is active, else reference."""
    if pctx.get_mesh() is not None:
        return moe_ffn_sharded(cfg, p, x)
    return moe_ffn_reference(cfg, p, x)
