"""RecurrentGemma / Griffin hybrid (arXiv:2402.19427) — recurrentgemma-9b.

38 residual layers in the pattern (recurrent, recurrent, attention) x 12
plus 2 trailing recurrent layers.  Each layer = temporal-mixing block +
GeGLU MLP block.

* Recurrent block: LN -> two branches: main (D->W linear, causal conv(4),
  RG-LRU) and gate (D->W linear, GeLU); merged elementwise, W->D out proj.
  RG-LRU: r_t = sigma(W_a x + b_a); i_t = sigma(W_x x + b_x);
  log a_t = -c * softplus(Lambda) * r_t (c=8);
  h_t = a_t h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)
  -> parallelized over time with ``jax.lax.associative_scan``.
* Attention block: sliding-window (2048) MQA (kv=1), RoPE, head_dim 256.

Decode state: per recurrent layer h (B, W) fp32 + conv tail (B, 3, W);
per attention layer a ring-buffer KV cache of size ``window``.
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import common as cm
from repro.models.common import ModelConfig
from repro.models.xlstm import causal_conv

LRU_C = 8.0


def layer_kinds(cfg: ModelConfig):
    """List of 'rec' / 'attn' per layer index."""
    kinds = []
    for i in range(cfg.n_layers):
        kinds.append("attn" if (i % 3) == 2 else "rec")
    return kinds


# ---------------------------------------------------------------------------
# Params
# ---------------------------------------------------------------------------


def _rec_init(cfg: ModelConfig):
    d, dt = cfg.d_model, cfg.dtype
    w = cfg.lru_width
    f = cfg.d_ff

    def init_one(key):
        ks = jax.random.split(key, 8)
        return {
            "ln": jnp.zeros((d,), dt),
            "w_main": cm.dense_init(ks[0], (d, w), dt),
            "w_gate": cm.dense_init(ks[1], (d, w), dt),
            "conv": cm.dense_init(ks[2], (4, w), dt),
            "w_a": cm.dense_init(ks[3], (w, w), jnp.float32),
            "b_a": jnp.zeros((w,), jnp.float32),
            "w_i": cm.dense_init(ks[4], (w, w), jnp.float32),
            "b_i": jnp.zeros((w,), jnp.float32),
            "lam": jnp.full((w,), 0.7, jnp.float32),
            "w_out": cm.dense_init(ks[5], (w, d), dt),
            "ln2": jnp.zeros((d,), dt),
            "ff1": cm.dense_init(ks[6], (d, 2 * f), dt),
            "ff2": cm.dense_init(ks[7], (f, d), dt),
        }

    return init_one


def _rec_specs(cfg: ModelConfig) -> dict:
    d, dt, w, f = cfg.d_model, cfg.dtype, cfg.lru_width, cfg.d_ff
    f32 = jnp.float32
    return {
        "ln": jax.ShapeDtypeStruct((d,), dt),
        "w_main": jax.ShapeDtypeStruct((d, w), dt),
        "w_gate": jax.ShapeDtypeStruct((d, w), dt),
        "conv": jax.ShapeDtypeStruct((4, w), dt),
        "w_a": jax.ShapeDtypeStruct((w, w), f32),
        "b_a": jax.ShapeDtypeStruct((w,), f32),
        "w_i": jax.ShapeDtypeStruct((w, w), f32),
        "b_i": jax.ShapeDtypeStruct((w,), f32),
        "lam": jax.ShapeDtypeStruct((w,), f32),
        "w_out": jax.ShapeDtypeStruct((w, d), dt),
        "ln2": jax.ShapeDtypeStruct((d,), dt),
        "ff1": jax.ShapeDtypeStruct((d, 2 * f), dt),
        "ff2": jax.ShapeDtypeStruct((f, d), dt),
    }


_REC_AXES = {
    "ln": (None,),
    "w_main": ("embed", "lru"),
    "w_gate": ("embed", "lru"),
    "conv": (None, "lru"),
    "w_a": ("lru", None),
    "b_a": (None,),
    "w_i": ("lru", None),
    "b_i": (None,),
    "lam": (None,),
    "w_out": ("lru", "embed"),
    "ln2": (None,),
    "ff1": ("embed", "mlp"),
    "ff2": ("mlp", "embed"),
}


def _attn_init(cfg: ModelConfig):
    d, dt = cfg.d_model, cfg.dtype
    h, hkv, hd, f = cfg.n_heads, cfg.n_kv_heads, cfg.hd, cfg.d_ff

    def init_one(key):
        ks = jax.random.split(key, 6)
        return {
            "ln": jnp.zeros((d,), dt),
            "wq": cm.dense_init(ks[0], (d, h, hd), dt),
            "wk": cm.dense_init(ks[1], (d, hkv, hd), dt),
            "wv": cm.dense_init(ks[2], (d, hkv, hd), dt),
            "wo": cm.dense_init(ks[3], (h, hd, d), dt, in_axis=(0, 1)),
            "ln2": jnp.zeros((d,), dt),
            "ff1": cm.dense_init(ks[4], (d, 2 * f), dt),
            "ff2": cm.dense_init(ks[5], (f, d), dt),
        }

    return init_one


def _attn_specs(cfg: ModelConfig) -> dict:
    d, dt = cfg.d_model, cfg.dtype
    h, hkv, hd, f = cfg.n_heads, cfg.n_kv_heads, cfg.hd, cfg.d_ff
    return {
        "ln": jax.ShapeDtypeStruct((d,), dt),
        "wq": jax.ShapeDtypeStruct((d, h, hd), dt),
        "wk": jax.ShapeDtypeStruct((d, hkv, hd), dt),
        "wv": jax.ShapeDtypeStruct((d, hkv, hd), dt),
        "wo": jax.ShapeDtypeStruct((h, hd, d), dt),
        "ln2": jax.ShapeDtypeStruct((d,), dt),
        "ff1": jax.ShapeDtypeStruct((d, 2 * f), dt),
        "ff2": jax.ShapeDtypeStruct((f, d), dt),
    }


_ATTN_AXES = {
    "ln": (None,),
    "wq": ("embed", "heads", None),
    "wk": ("embed", "kv", None),
    "wv": ("embed", "kv", None),
    "wo": ("heads", None, "embed"),
    "ln2": (None,),
    "ff1": ("embed", "mlp"),
    "ff2": ("mlp", "embed"),
}


def _counts(cfg: ModelConfig) -> Tuple[int, int]:
    kinds = layer_kinds(cfg)
    return kinds.count("rec"), kinds.count("attn")


def init(cfg: ModelConfig, key) -> dict:
    n_rec, n_attn = _counts(cfg)
    k_e, k_r, k_a, k_h = jax.random.split(key, 4)
    return {
        "embed": cm.embed_init(k_e, (cfg.vocab, cfg.d_model), cfg.dtype),
        "rec": cm.stack_layer_params(_rec_init(cfg), k_r, n_rec),
        "attn": cm.stack_layer_params(_attn_init(cfg), k_a, n_attn),
        "final_norm": jnp.zeros((cfg.d_model,), cfg.dtype),
        "lm_head": cm.dense_init(k_h, (cfg.d_model, cfg.vocab), cfg.dtype),
    }


def param_specs(cfg: ModelConfig) -> dict:
    n_rec, n_attn = _counts(cfg)
    return {
        "embed": jax.ShapeDtypeStruct((cfg.vocab, cfg.d_model), cfg.dtype),
        "rec": cm.stacked_specs(_rec_specs(cfg), n_rec),
        "attn": cm.stacked_specs(_attn_specs(cfg), n_attn),
        "final_norm": jax.ShapeDtypeStruct((cfg.d_model,), cfg.dtype),
        "lm_head": jax.ShapeDtypeStruct((cfg.d_model, cfg.vocab), cfg.dtype),
    }


def logical_axes(cfg: ModelConfig) -> dict:
    return {
        "embed": ("vocab", "embed"),
        "rec": cm.stacked_axes(dict(_REC_AXES)),
        "attn": cm.stacked_axes(dict(_ATTN_AXES)),
        "final_norm": (None,),
        "lm_head": ("embed", "vocab"),
    }


# ---------------------------------------------------------------------------
# RG-LRU
# ---------------------------------------------------------------------------


def rglru_gates(p: dict, u: jnp.ndarray):
    """u (B,S,W) conv output -> (log_a (B,S,W) fp32, gated input (B,S,W))."""
    u32 = u.astype(jnp.float32)
    r = jax.nn.sigmoid(jnp.dot(u32, p["w_a"]) + p["b_a"])
    i = jax.nn.sigmoid(jnp.dot(u32, p["w_i"]) + p["b_i"])
    log_a = -LRU_C * jax.nn.softplus(p["lam"]) * r
    a = jnp.exp(log_a)
    gated = jnp.sqrt(jnp.maximum(1.0 - jnp.square(a), 1e-12)) * (i * u32)
    return log_a, gated


def rglru_scan(log_a: jnp.ndarray, b: jnp.ndarray,
               h0: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Linear recurrence h_t = exp(log_a_t) h_{t-1} + b_t over axis 1."""
    if h0 is not None:
        # fold initial state into the first step
        b = b.at[:, 0, :].add(jnp.exp(log_a[:, 0, :]) * h0)

    def combine(x, y):
        la1, b1 = x
        la2, b2 = y
        return la1 + la2, jnp.exp(la2) * b1 + b2

    _, h = jax.lax.associative_scan(combine, (log_a, b), axis=1)
    return h


def rec_block(cfg: ModelConfig, p: dict, x: jnp.ndarray,
              state: Optional[Tuple] = None):
    """Recurrent temporal block + MLP.  Returns (x_out, (h_last, conv_tail))."""
    h_in = cm.rms_norm(x, p["ln"], cfg.norm_eps)
    main = jnp.dot(h_in, p["w_main"])
    gate = jax.nn.gelu(jnp.dot(h_in, p["w_gate"]).astype(jnp.float32))
    conv_state = state[1] if state is not None else None
    u, conv_tail = causal_conv(main, p["conv"], conv_state)
    log_a, gated = rglru_gates(p, u)
    h0 = state[0] if state is not None else None
    hs = rglru_scan(log_a, gated, h0)                     # (B,S,W) fp32
    y = (hs * gate).astype(x.dtype)
    x = x + jnp.dot(y, p["w_out"])
    xf = cm.rms_norm(x, p["ln2"], cfg.norm_eps)
    g, uff = jnp.split(jnp.dot(xf, p["ff1"]), 2, axis=-1)
    ff = jax.nn.gelu(g.astype(jnp.float32)).astype(x.dtype) * uff
    return x + jnp.dot(ff, p["ff2"]), (hs[:, -1, :], conv_tail)


def attn_block(cfg: ModelConfig, p: dict, x: jnp.ndarray,
               positions: jnp.ndarray):
    """Sliding-window MQA block + MLP.  Returns (x_out, (k, v))."""
    h_in = cm.rms_norm(x, p["ln"], cfg.norm_eps)
    q = jnp.einsum("bsd,dhk->bshk", h_in, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", h_in, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", h_in, p["wv"])
    q = cm.apply_rope(q, positions, cfg.rope_theta)
    k = cm.apply_rope(k, positions, cfg.rope_theta)
    o = attn.multi_head_attention(q, k, v, causal=True, window=cfg.window)
    x = x + jnp.einsum("bshk,hkd->bsd", o, p["wo"])
    xf = cm.rms_norm(x, p["ln2"], cfg.norm_eps)
    g, uff = jnp.split(jnp.dot(xf, p["ff1"]), 2, axis=-1)
    ff = jax.nn.gelu(g.astype(jnp.float32)).astype(x.dtype) * uff
    return x + jnp.dot(ff, p["ff2"]), (k, v)


# ---------------------------------------------------------------------------
# Forward (training): scan over (rec, rec, attn) groups
# ---------------------------------------------------------------------------


def forward(cfg: ModelConfig, params: dict, tokens: jnp.ndarray,
            frontend_embeds=None, return_aux: bool = False):
    x = jnp.take(params["embed"], tokens, axis=0)
    positions = jnp.arange(tokens.shape[1])
    n_rec, n_attn = _counts(cfg)
    n_groups = n_attn                                      # groups of (r,r,a)

    def group_body(xc, gp):
        rp, ap = gp
        r0 = jax.tree.map(lambda a: a[0], rp)
        r1 = jax.tree.map(lambda a: a[1], rp)
        xc, _ = rec_block(cfg, r0, xc)
        xc, _ = rec_block(cfg, r1, xc)
        xc, _ = attn_block(cfg, ap, xc, positions)
        return xc

    grouped_rec = jax.tree.map(
        lambda a: a[: n_groups * 2].reshape(n_groups, 2, *a.shape[1:]),
        params["rec"])
    gfn = cm.maybe_remat(group_body, cfg)
    x, _ = cm.scan_or_unroll(lambda c, g: (gfn(c, g), None), x,
                             (grouped_rec, params["attn"]),
                             cfg.scan_layers)
    rest = n_rec - n_groups * 2
    if rest:
        rest_p = jax.tree.map(lambda a: a[-rest:], params["rec"])
        body = cm.maybe_remat(lambda c, lp: rec_block(cfg, lp, c)[0], cfg)
        x, _ = cm.scan_or_unroll(lambda c, lp: (body(c, lp), None), x,
                                 rest_p, cfg.scan_layers)
    x = cm.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = jnp.dot(x, params["lm_head"]).astype(jnp.float32)
    if return_aux:
        return logits, jnp.float32(0.0)
    return logits


# ---------------------------------------------------------------------------
# Serving
# ---------------------------------------------------------------------------


def cache_specs(cfg: ModelConfig, batch: int, max_len: int) -> dict:
    n_rec, n_attn = _counts(cfg)
    w = cfg.lru_width
    win = cfg.window
    return {
        "h": jax.ShapeDtypeStruct((n_rec, batch, w), jnp.float32),
        "conv": jax.ShapeDtypeStruct((n_rec, batch, 3, w), cfg.dtype),
        "k": jax.ShapeDtypeStruct((n_attn, batch, win, cfg.n_kv_heads,
                                   cfg.hd), cfg.dtype),
        "v": jax.ShapeDtypeStruct((n_attn, batch, win, cfg.n_kv_heads,
                                   cfg.hd), cfg.dtype),
        "len": jax.ShapeDtypeStruct((), jnp.int32),
    }


def cache_axes(cfg: ModelConfig) -> dict:
    return {
        "h": ("layer", "batch", "lru"),
        "conv": ("layer", "batch", None, "lru"),
        "k": ("layer", "batch", "kv_seq", "kv", None),
        "v": ("layer", "batch", "kv_seq", "kv", None),
        "len": (),
    }


def prefill(cfg: ModelConfig, params: dict, tokens: jnp.ndarray,
            frontend_embeds=None, max_len=None):
    # max_len ignored: window ring-buffer + recurrent state are O(window).
    b, s = tokens.shape
    x = jnp.take(params["embed"], tokens, axis=0)
    positions = jnp.arange(s)
    win = cfg.window
    kinds = layer_kinds(cfg)
    h_st, conv_st, k_st, v_st = [], [], [], []
    ri = ai = 0
    for kind in kinds:
        if kind == "rec":
            lp = jax.tree.map(lambda a: a[ri], params["rec"])
            x, (hl, ct) = rec_block(cfg, lp, x)
            h_st.append(hl)
            conv_st.append(ct)
            ri += 1
        else:
            lp = jax.tree.map(lambda a: a[ai], params["attn"])
            x, (k, v) = attn_block(cfg, lp, x, positions)
            # ring buffer: slot(p) = p % win, keep last `win` positions
            if s >= win:
                k_tail, v_tail = k[:, -win:], v[:, -win:]
                shift = s % win
                k_tail = jnp.roll(k_tail, shift, axis=1)
                v_tail = jnp.roll(v_tail, shift, axis=1)
            else:
                pad = win - s
                k_tail = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
                v_tail = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
            k_st.append(k_tail)
            v_st.append(v_tail)
            ai += 1
    x = cm.rms_norm(x[:, -1:, :], params["final_norm"], cfg.norm_eps)
    logits = jnp.dot(x[:, 0, :], params["lm_head"]).astype(jnp.float32)
    empty_kv = jnp.zeros((0, b, win, cfg.n_kv_heads, cfg.hd), cfg.dtype)
    cache = {
        "h": jnp.stack(h_st),
        "conv": jnp.stack(conv_st),
        "k": jnp.stack(k_st) if k_st else empty_kv,
        "v": jnp.stack(v_st) if v_st else empty_kv,
        "len": jnp.int32(s),
    }
    return logits, cache


def decode_step(cfg: ModelConfig, params: dict, token: jnp.ndarray,
                cache: dict):
    b = token.shape[0]
    x = jnp.take(params["embed"], token[:, None], axis=0)
    pos = cache["len"]
    positions = jnp.reshape(pos, (1,))
    win = cfg.window
    kinds = layer_kinds(cfg)
    h_out, conv_out, k_out, v_out = [], [], [], []
    ri = ai = 0
    for kind in kinds:
        if kind == "rec":
            lp = jax.tree.map(lambda a: a[ri], params["rec"])
            h_in = cm.rms_norm(x, lp["ln"], cfg.norm_eps)
            main = jnp.dot(h_in, lp["w_main"])
            gate = jax.nn.gelu(
                jnp.dot(h_in, lp["w_gate"]).astype(jnp.float32))
            u, ct = causal_conv(main, lp["conv"], cache["conv"][ri])
            log_a, gated = rglru_gates(lp, u)
            h_new = (jnp.exp(log_a[:, 0]) * cache["h"][ri]
                     + gated[:, 0])                        # (B,W)
            y = (h_new[:, None, :] * gate).astype(x.dtype)
            x = x + jnp.dot(y, lp["w_out"])
            xf = cm.rms_norm(x, lp["ln2"], cfg.norm_eps)
            g, uff = jnp.split(jnp.dot(xf, lp["ff1"]), 2, axis=-1)
            ff = jax.nn.gelu(g.astype(jnp.float32)).astype(x.dtype) * uff
            x = x + jnp.dot(ff, lp["ff2"])
            h_out.append(h_new)
            conv_out.append(ct)
            ri += 1
        else:
            lp = jax.tree.map(lambda a: a[ai], params["attn"])
            h_in = cm.rms_norm(x, lp["ln"], cfg.norm_eps)
            q = jnp.einsum("bsd,dhk->bshk", h_in, lp["wq"])
            k = jnp.einsum("bsd,dhk->bshk", h_in, lp["wk"])
            v = jnp.einsum("bsd,dhk->bshk", h_in, lp["wv"])
            q = cm.apply_rope(q, positions, cfg.rope_theta)
            k = cm.apply_rope(k, positions, cfg.rope_theta)
            slot = jnp.mod(pos, win)
            kc = jax.lax.dynamic_update_slice_in_dim(
                cache["k"][ai], k, slot, axis=1)
            vc = jax.lax.dynamic_update_slice_in_dim(
                cache["v"][ai], v, slot, axis=1)
            o = attn.decode_attention(q, kc, vc,
                                      jnp.minimum(pos + 1, win))
            x = x + jnp.einsum("bshk,hkd->bsd", o, lp["wo"])
            xf = cm.rms_norm(x, lp["ln2"], cfg.norm_eps)
            g, uff = jnp.split(jnp.dot(xf, lp["ff1"]), 2, axis=-1)
            ff = jax.nn.gelu(g.astype(jnp.float32)).astype(x.dtype) * uff
            x = x + jnp.dot(ff, lp["ff2"])
            k_out.append(kc)
            v_out.append(vc)
            ai += 1
    x = cm.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = jnp.dot(x[:, 0, :], params["lm_head"]).astype(jnp.float32)
    cache = {
        "h": jnp.stack(h_out),
        "conv": jnp.stack(conv_out),
        "k": jnp.stack(k_out) if k_out else cache["k"],
        "v": jnp.stack(v_out) if v_out else cache["v"],
        "len": cache["len"] + 1,
    }
    return logits, cache
