"""Family dispatch: every architecture exposes one uniform interface."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.common import ModelConfig

_FAMILY_MODULE = {}


def _module(cfg: ModelConfig):
    fam = cfg.family
    if fam not in _FAMILY_MODULE:
        if fam in ("dense", "moe", "vlm"):
            from repro.models import transformer as mod
        elif fam == "xlstm":
            from repro.models import xlstm as mod
        elif fam == "rglru":
            from repro.models import rglru as mod
        elif fam == "encdec":
            from repro.models import encdec as mod
        else:
            raise ValueError(f"unknown family: {fam}")
        _FAMILY_MODULE[fam] = mod
    return _FAMILY_MODULE[fam]


def init(cfg: ModelConfig, key):
    return _module(cfg).init(cfg, key)


def param_specs(cfg: ModelConfig):
    return _module(cfg).param_specs(cfg)


def logical_axes(cfg: ModelConfig):
    return _module(cfg).logical_axes(cfg)


def forward(cfg: ModelConfig, params, tokens, frontend_embeds=None,
            return_aux: bool = False):
    return _module(cfg).forward(cfg, params, tokens,
                                frontend_embeds=frontend_embeds,
                                return_aux=return_aux)


def prefill(cfg: ModelConfig, params, tokens, frontend_embeds=None,
            max_len=None):
    return _module(cfg).prefill(cfg, params, tokens,
                                frontend_embeds=frontend_embeds,
                                max_len=max_len)


def decode_step(cfg: ModelConfig, params, token, cache):
    return _module(cfg).decode_step(cfg, params, token, cache)


def cache_specs(cfg: ModelConfig, batch: int, max_len: int):
    return _module(cfg).cache_specs(cfg, batch, max_len)


def cache_axes(cfg: ModelConfig):
    return _module(cfg).cache_axes(cfg)


def has_frontend(cfg: ModelConfig) -> bool:
    return bool(cfg.frontend)


def sub_quadratic(cfg: ModelConfig) -> bool:
    """True when decode state is O(1)/windowed in context length."""
    return cfg.family in ("xlstm", "rglru")
