"""Dense decoder-only transformer family.

Covers: phi3-medium-14b, glm4-9b, deepseek-coder-33b, qwen3-4b (qk_norm),
pixtral-12b backbone (patch-embedding frontend stub), and the
recurrentgemma / MoE families reuse its attention + embedding pieces.

Layer: pre-RMSNorm -> GQA attention (RoPE, optional QK-norm, optional
sliding window) -> residual -> pre-RMSNorm -> SwiGLU MLP -> residual.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import common as cm
from repro.models.common import ModelConfig

# ---------------------------------------------------------------------------
# Per-layer params
# ---------------------------------------------------------------------------


def _layer_init(cfg: ModelConfig):
    d, h, hkv, hd, ff, dt = (cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                             cfg.hd, cfg.d_ff, cfg.dtype)

    def init_one(key):
        ks = jax.random.split(key, 8)
        p = {
            "ln1": jnp.zeros((d,), dt),
            "wq": cm.dense_init(ks[0], (d, h, hd), dt),
            "wk": cm.dense_init(ks[1], (d, hkv, hd), dt),
            "wv": cm.dense_init(ks[2], (d, hkv, hd), dt),
            "wo": cm.dense_init(ks[3], (h, hd, d), dt, in_axis=(0, 1)),
            "ln2": jnp.zeros((d,), dt),
        }
        if cfg.n_experts > 0:
            from repro.models import moe

            p["moe"] = moe.moe_params(ks[4], cfg)
        else:
            p["mlp"] = cm.mlp_params(ks[4], d, ff, dt)
        if cfg.qk_norm:
            p["q_norm"] = jnp.zeros((hd,), dt)
            p["k_norm"] = jnp.zeros((hd,), dt)
        return p

    return init_one


def _layer_specs(cfg: ModelConfig) -> dict:
    d, h, hkv, hd, ff, dt = (cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                             cfg.hd, cfg.d_ff, cfg.dtype)
    p = {
        "ln1": jax.ShapeDtypeStruct((d,), dt),
        "wq": jax.ShapeDtypeStruct((d, h, hd), dt),
        "wk": jax.ShapeDtypeStruct((d, hkv, hd), dt),
        "wv": jax.ShapeDtypeStruct((d, hkv, hd), dt),
        "wo": jax.ShapeDtypeStruct((h, hd, d), dt),
        "ln2": jax.ShapeDtypeStruct((d,), dt),
    }
    if cfg.n_experts > 0:
        from repro.models import moe

        p["moe"] = moe.moe_specs(cfg)
    else:
        p["mlp"] = cm.mlp_specs(d, ff, dt)
    if cfg.qk_norm:
        p["q_norm"] = jax.ShapeDtypeStruct((hd,), dt)
        p["k_norm"] = jax.ShapeDtypeStruct((hd,), dt)
    return p


def _layer_axes(cfg: ModelConfig) -> dict:
    p = {
        "ln1": (None,),
        "wq": ("embed", "heads", None),
        "wk": ("embed", "kv", None),
        "wv": ("embed", "kv", None),
        "wo": ("heads", None, "embed"),
        "ln2": (None,),
    }
    if cfg.n_experts > 0:
        from repro.models import moe

        p["moe"] = dict(moe.MOE_AXES)
    else:
        p["mlp"] = dict(cm.MLP_AXES)
    if cfg.qk_norm:
        p["q_norm"] = (None,)
        p["k_norm"] = (None,)
    return p


# ---------------------------------------------------------------------------
# Top-level params
# ---------------------------------------------------------------------------


def init(cfg: ModelConfig, key) -> dict:
    k_emb, k_layers, k_head, k_fe = jax.random.split(key, 4)
    params = {
        "embed": cm.embed_init(k_emb, (cfg.vocab, cfg.d_model), cfg.dtype),
        "layers": cm.stack_layer_params(_layer_init(cfg), k_layers,
                                        cfg.n_layers),
        "final_norm": jnp.zeros((cfg.d_model,), cfg.dtype),
        "lm_head": cm.dense_init(k_head, (cfg.d_model, cfg.vocab), cfg.dtype),
    }
    if cfg.frontend:
        params["frontend_proj"] = cm.dense_init(
            k_fe, (cfg.frontend_dim, cfg.d_model), cfg.dtype)
    return params


def param_specs(cfg: ModelConfig) -> dict:
    p = {
        "embed": jax.ShapeDtypeStruct((cfg.vocab, cfg.d_model), cfg.dtype),
        "layers": cm.stacked_specs(_layer_specs(cfg), cfg.n_layers),
        "final_norm": jax.ShapeDtypeStruct((cfg.d_model,), cfg.dtype),
        "lm_head": jax.ShapeDtypeStruct((cfg.d_model, cfg.vocab), cfg.dtype),
    }
    if cfg.frontend:
        p["frontend_proj"] = jax.ShapeDtypeStruct(
            (cfg.frontend_dim, cfg.d_model), cfg.dtype)
    return p


def logical_axes(cfg: ModelConfig) -> dict:
    p = {
        "embed": ("vocab", "embed"),
        "layers": cm.stacked_axes(_layer_axes(cfg)),
        "final_norm": (None,),
        "lm_head": ("embed", "vocab"),
    }
    if cfg.frontend:
        p["frontend_proj"] = (None, "embed")
    return p


# ---------------------------------------------------------------------------
# Forward (training / scoring)
# ---------------------------------------------------------------------------


def tp_attn_weights(cfg: ModelConfig, p: dict):
    """TP-aligned attention weights (cfg.tp_attention, §Perf hillclimb).

    GSPMD cannot propagate head-sharding through the GQA repeat-reshape
    (Hkv x q_per_kv -> H), so the baseline attention einsums replicate
    over the model axis.  This transform (a) repeats the KV projection
    weights to one kv head per q head (identical k/v values per group —
    bitwise the same math) and (b) zero-pads the q/kv/o head dims to a
    multiple of the TP width (padded o-rows are zero, so outputs are
    exactly unchanged).  Returns (wq, wk, wv, wo, h_eff)."""
    from repro.parallel import ctx as pctx

    mesh = pctx.get_mesh()
    wq, wk, wv, wo = p["wq"], p["wk"], p["wv"], p["wo"]
    h = cfg.n_heads
    if not cfg.tp_attention or mesh is None or "model" not in \
            mesh.axis_names:
        return wq, wk, wv, wo, h
    tp = mesh.shape["model"]
    qpk = cfg.q_per_kv
    wk = jnp.repeat(wk, qpk, axis=1)         # one kv head per q head
    wv = jnp.repeat(wv, qpk, axis=1)
    h_eff = -(-h // tp) * tp                 # ceil to TP multiple
    if h_eff != h:
        pad = ((0, 0), (0, h_eff - h), (0, 0))
        wq, wk, wv = (jnp.pad(w, pad) for w in (wq, wk, wv))
        wo = jnp.pad(wo, ((0, h_eff - h), (0, 0), (0, 0)))
    from jax.sharding import PartitionSpec as P

    cst = lambda w, spec: jax.lax.with_sharding_constraint(
        w, jax.sharding.NamedSharding(mesh, spec))
    wq = cst(wq, P(None, "model", None))
    wk = cst(wk, P(None, "model", None))
    wv = cst(wv, P(None, "model", None))
    wo = cst(wo, P("model", None, None))
    return wq, wk, wv, wo, h_eff


def _attn_block(cfg: ModelConfig, p: dict, x: jnp.ndarray,
                positions: jnp.ndarray, *, window: int = 0) -> jnp.ndarray:
    wq, wk, wv, wo, _ = tp_attn_weights(cfg, p)
    h = cm.rms_norm(x, p["ln1"], cfg.norm_eps)
    q = jnp.einsum("bsd,dhk->bshk", h, wq)
    k = jnp.einsum("bsd,dhk->bshk", h, wk)
    v = jnp.einsum("bsd,dhk->bshk", h, wv)
    if cfg.qk_norm:
        q = cm.head_rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = cm.head_rms_norm(k, p["k_norm"], cfg.norm_eps)
    q = cm.apply_rope(q, positions, cfg.rope_theta)
    k = cm.apply_rope(k, positions, cfg.rope_theta)
    o = attn.multi_head_attention(q, k, v, causal=True, window=window,
                                  causal_slice=cfg.causal_slice)
    return x + jnp.einsum("bshk,hkd->bsd", o, wo)


def _ffn_block(cfg: ModelConfig, p: dict, x: jnp.ndarray):
    """Returns (x_out, aux_loss)."""
    h = cm.rms_norm(x, p["ln2"], cfg.norm_eps)
    if cfg.n_experts > 0:
        from repro.models import moe

        y, aux = moe.moe_ffn(cfg, p["moe"], h)
        return x + y, aux
    return x + cm.mlp_forward(p["mlp"], h), jnp.float32(0.0)


def embed_tokens(cfg: ModelConfig, params: dict, tokens: jnp.ndarray,
                 frontend_embeds: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    x = jnp.take(params["embed"], tokens, axis=0)
    if cfg.frontend and frontend_embeds is not None:
        fe = jnp.dot(frontend_embeds.astype(cfg.dtype),
                     params["frontend_proj"])
        nf = fe.shape[1]
        x = jnp.concatenate([fe, x[:, nf:, :]], axis=1)
    return x


def forward(cfg: ModelConfig, params: dict, tokens: jnp.ndarray,
            frontend_embeds: Optional[jnp.ndarray] = None,
            return_aux: bool = False):
    """tokens (B, S) -> logits (B, S, V) [+ moe aux loss]."""
    x = embed_tokens(cfg, params, tokens, frontend_embeds)
    positions = jnp.arange(tokens.shape[1])

    def body(carry, lp):
        xc, aux = carry
        xc = _attn_block(cfg, lp, xc, positions, window=cfg.window)
        xc, a = _ffn_block(cfg, lp, xc)
        return xc, aux + a

    (x, aux) = cm.scan_layers(body, (x, jnp.float32(0.0)),
                              params["layers"], cfg)
    x = cm.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = jnp.dot(x, params["lm_head"]).astype(jnp.float32)
    if return_aux:
        return logits, aux / cfg.n_layers
    return logits


# ---------------------------------------------------------------------------
# Serving: prefill + decode
# ---------------------------------------------------------------------------


def prefill(cfg: ModelConfig, params: dict, tokens: jnp.ndarray,
            frontend_embeds: Optional[jnp.ndarray] = None,
            max_len: Optional[int] = None) -> Tuple[jnp.ndarray, dict]:
    """Returns (last-position logits (B,V), kv cache).

    cache = {"k": (L,B,max_len,Hkv,hd), "v": ..., "len": int32[]} —
    ``max_len`` (default S + 64) reserves decode headroom.
    """
    x = embed_tokens(cfg, params, tokens, frontend_embeds)
    s = tokens.shape[1]
    positions = jnp.arange(s)

    def body(xc, lp):
        h = cm.rms_norm(xc, lp["ln1"], cfg.norm_eps)
        q = jnp.einsum("bsd,dhk->bshk", h, lp["wq"])
        k = jnp.einsum("bsd,dhk->bshk", h, lp["wk"])
        v = jnp.einsum("bsd,dhk->bshk", h, lp["wv"])
        if cfg.qk_norm:
            q = cm.head_rms_norm(q, lp["q_norm"], cfg.norm_eps)
            k = cm.head_rms_norm(k, lp["k_norm"], cfg.norm_eps)
        q = cm.apply_rope(q, positions, cfg.rope_theta)
        k = cm.apply_rope(k, positions, cfg.rope_theta)
        o = attn.multi_head_attention(q, k, v, causal=True, window=cfg.window)
        xc = xc + jnp.einsum("bshk,hkd->bsd", o, lp["wo"])
        xc, _ = _ffn_block(cfg, lp, xc)
        return xc, (k, v)

    fn = cm.maybe_remat(body, cfg)
    x, (ks, vs) = cm.scan_or_unroll(fn, x, params["layers"],
                                    cfg.scan_layers)
    x = cm.rms_norm(x[:, -1:, :], params["final_norm"], cfg.norm_eps)
    logits = jnp.dot(x[:, 0, :], params["lm_head"]).astype(jnp.float32)
    cap = max_len if max_len is not None else s + 64
    if cap > s:
        pad = ((0, 0), (0, 0), (0, cap - s), (0, 0), (0, 0))
        ks, vs = jnp.pad(ks, pad), jnp.pad(vs, pad)
    cache = {"k": ks, "v": vs, "len": jnp.int32(s)}
    return logits, cache


def _pin_seq_sharding(kc: jnp.ndarray, vc: jnp.ndarray):
    """sp_decode (§Perf): constrain the per-layer KV slice to the cache's
    storage layout (sequence over `model`, batch over DP) so the decode
    attention computes flash-decoding style (partial softmax + all-reduce)
    instead of GSPMD resharding the whole cache to kv-head sharding —
    the 'involuntary full rematerialization' the baseline HLO warns about."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.parallel import ctx as pctx

    mesh = pctx.get_mesh()
    if mesh is None or "model" not in mesh.axis_names:
        return kc, vc
    ba = pctx.batch_axes(mesh)
    b = kc.shape[0]
    dp = pctx.dp_size(mesh)
    bspec = (ba if len(ba) > 1 else ba[0]) if (b % max(dp, 1) == 0
                                               and dp > 1) else None
    spec = P(bspec, "model", None, None)
    cst = lambda x: jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, spec))
    return cst(kc), cst(vc)


def decode_step(cfg: ModelConfig, params: dict, token: jnp.ndarray,
                cache: dict) -> Tuple[jnp.ndarray, dict]:
    """token (B,) int32; cache from ``prefill``.  One-token step.

    Returns (logits (B,V), updated cache)."""
    x = jnp.take(params["embed"], token[:, None], axis=0)  # (B,1,D)
    positions = jnp.reshape(cache["len"], (1,))

    def body(xc, layer_in):
        lp, kc, vc = layer_in
        h = cm.rms_norm(xc, lp["ln1"], cfg.norm_eps)
        q = jnp.einsum("bsd,dhk->bshk", h, lp["wq"])
        k = jnp.einsum("bsd,dhk->bshk", h, lp["wk"])
        v = jnp.einsum("bsd,dhk->bshk", h, lp["wv"])
        if cfg.qk_norm:
            q = cm.head_rms_norm(q, lp["q_norm"], cfg.norm_eps)
            k = cm.head_rms_norm(k, lp["k_norm"], cfg.norm_eps)
        q = cm.apply_rope(q, positions, cfg.rope_theta)
        k = cm.apply_rope(k, positions, cfg.rope_theta)
        kc = jax.lax.dynamic_update_slice_in_dim(kc, k, cache["len"], axis=1)
        vc = jax.lax.dynamic_update_slice_in_dim(vc, v, cache["len"], axis=1)
        if cfg.sp_decode:
            kc, vc = _pin_seq_sharding(kc, vc)
            o = attn.decode_attention_sp(q, kc, vc, cache["len"] + 1)
        else:
            o = attn.decode_attention(q, kc, vc, cache["len"] + 1)
        xc = xc + jnp.einsum("bshk,hkd->bsd", o, lp["wo"])
        xc, _ = _ffn_block(cfg, lp, xc)
        return xc, (kc, vc)

    x, (ks, vs) = cm.scan_or_unroll(
        body, x, (params["layers"], cache["k"], cache["v"]),
        cfg.scan_layers)
    x = cm.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = jnp.dot(x[:, 0, :], params["lm_head"]).astype(jnp.float32)
    return logits, {"k": ks, "v": vs, "len": cache["len"] + 1}


def cache_specs(cfg: ModelConfig, batch: int, max_len: int) -> dict:
    shp = (cfg.n_layers, batch, max_len, cfg.n_kv_heads, cfg.hd)
    return {
        "k": jax.ShapeDtypeStruct(shp, cfg.dtype),
        "v": jax.ShapeDtypeStruct(shp, cfg.dtype),
        "len": jax.ShapeDtypeStruct((), jnp.int32),
    }


def cache_axes(cfg: ModelConfig) -> dict:
    ax = ("layer", "batch", "kv_seq", "kv", None)
    return {"k": ax, "v": ax, "len": ()}
