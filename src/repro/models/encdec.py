"""Encoder-decoder transformer (seamless-m4t-medium text/audio backbone).

The audio frontend is a STUB per the assignment: ``input_specs()`` provides
precomputed frame embeddings (B, S_enc, frontend_dim); a learned projection
maps them to d_model.  Encoder: bidirectional MHA + SwiGLU.  Decoder:
causal self-attention + cross-attention to encoder output + SwiGLU.

Shapes: the assignment's seq_len applies to the *decoder*; the encoder
consumes ``S_enc = max(seq_len // 4, 64)`` frames (typical 4x length ratio
for speech frames vs text tokens; recorded in DESIGN.md).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import common as cm
from repro.models.common import ModelConfig


def enc_len(cfg: ModelConfig, dec_len: int) -> int:
    return max(dec_len // 4, 64)


# ---------------------------------------------------------------------------
# Layer params
# ---------------------------------------------------------------------------


def _enc_layer_init(cfg: ModelConfig):
    d, h, hkv, hd, ff, dt = (cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                             cfg.hd, cfg.d_ff, cfg.dtype)

    def init_one(key):
        ks = jax.random.split(key, 5)
        return {
            "ln1": jnp.zeros((d,), dt),
            "wq": cm.dense_init(ks[0], (d, h, hd), dt),
            "wk": cm.dense_init(ks[1], (d, hkv, hd), dt),
            "wv": cm.dense_init(ks[2], (d, hkv, hd), dt),
            "wo": cm.dense_init(ks[3], (h, hd, d), dt, in_axis=(0, 1)),
            "ln2": jnp.zeros((d,), dt),
            "mlp": cm.mlp_params(ks[4], d, ff, dt),
        }

    return init_one


def _dec_layer_init(cfg: ModelConfig):
    d, h, hkv, hd, ff, dt = (cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                             cfg.hd, cfg.d_ff, cfg.dtype)

    def init_one(key):
        ks = jax.random.split(key, 10)
        return {
            "ln1": jnp.zeros((d,), dt),
            "wq": cm.dense_init(ks[0], (d, h, hd), dt),
            "wk": cm.dense_init(ks[1], (d, hkv, hd), dt),
            "wv": cm.dense_init(ks[2], (d, hkv, hd), dt),
            "wo": cm.dense_init(ks[3], (h, hd, d), dt, in_axis=(0, 1)),
            "ln_x": jnp.zeros((d,), dt),
            "xq": cm.dense_init(ks[4], (d, h, hd), dt),
            "xk": cm.dense_init(ks[5], (d, hkv, hd), dt),
            "xv": cm.dense_init(ks[6], (d, hkv, hd), dt),
            "xo": cm.dense_init(ks[7], (h, hd, d), dt, in_axis=(0, 1)),
            "ln2": jnp.zeros((d,), dt),
            "mlp": cm.mlp_params(ks[8], d, ff, dt),
        }

    return init_one


def _enc_layer_specs(cfg):
    d, h, hkv, hd, ff, dt = (cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                             cfg.hd, cfg.d_ff, cfg.dtype)
    return {
        "ln1": jax.ShapeDtypeStruct((d,), dt),
        "wq": jax.ShapeDtypeStruct((d, h, hd), dt),
        "wk": jax.ShapeDtypeStruct((d, hkv, hd), dt),
        "wv": jax.ShapeDtypeStruct((d, hkv, hd), dt),
        "wo": jax.ShapeDtypeStruct((h, hd, d), dt),
        "ln2": jax.ShapeDtypeStruct((d,), dt),
        "mlp": cm.mlp_specs(d, ff, dt),
    }


def _dec_layer_specs(cfg):
    d, h, hkv, hd, ff, dt = (cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                             cfg.hd, cfg.d_ff, cfg.dtype)
    base = _enc_layer_specs(cfg)
    base.update({
        "ln_x": jax.ShapeDtypeStruct((d,), dt),
        "xq": jax.ShapeDtypeStruct((d, h, hd), dt),
        "xk": jax.ShapeDtypeStruct((d, hkv, hd), dt),
        "xv": jax.ShapeDtypeStruct((d, hkv, hd), dt),
        "xo": jax.ShapeDtypeStruct((h, hd, d), dt),
    })
    return base


_ENC_AXES = {
    "ln1": (None,),
    "wq": ("embed", "heads", None),
    "wk": ("embed", "kv", None),
    "wv": ("embed", "kv", None),
    "wo": ("heads", None, "embed"),
    "ln2": (None,),
    "mlp": dict(cm.MLP_AXES),
}

_DEC_AXES = dict(_ENC_AXES, **{
    "ln_x": (None,),
    "xq": ("embed", "heads", None),
    "xk": ("embed", "kv", None),
    "xv": ("embed", "kv", None),
    "xo": ("heads", None, "embed"),
})


def init(cfg: ModelConfig, key) -> dict:
    n_enc = cfg.n_enc_layers or cfg.n_layers
    ks = jax.random.split(key, 5)
    return {
        "frontend_proj": cm.dense_init(
            ks[0], (cfg.frontend_dim, cfg.d_model), cfg.dtype),
        "embed": cm.embed_init(ks[1], (cfg.vocab, cfg.d_model), cfg.dtype),
        "enc": cm.stack_layer_params(_enc_layer_init(cfg), ks[2], n_enc),
        "enc_norm": jnp.zeros((cfg.d_model,), cfg.dtype),
        "dec": cm.stack_layer_params(_dec_layer_init(cfg), ks[3],
                                     cfg.n_layers),
        "final_norm": jnp.zeros((cfg.d_model,), cfg.dtype),
        "lm_head": cm.dense_init(ks[4], (cfg.d_model, cfg.vocab), cfg.dtype),
    }


def param_specs(cfg: ModelConfig) -> dict:
    n_enc = cfg.n_enc_layers or cfg.n_layers
    return {
        "frontend_proj": jax.ShapeDtypeStruct(
            (cfg.frontend_dim, cfg.d_model), cfg.dtype),
        "embed": jax.ShapeDtypeStruct((cfg.vocab, cfg.d_model), cfg.dtype),
        "enc": cm.stacked_specs(_enc_layer_specs(cfg), n_enc),
        "enc_norm": jax.ShapeDtypeStruct((cfg.d_model,), cfg.dtype),
        "dec": cm.stacked_specs(_dec_layer_specs(cfg), cfg.n_layers),
        "final_norm": jax.ShapeDtypeStruct((cfg.d_model,), cfg.dtype),
        "lm_head": jax.ShapeDtypeStruct((cfg.d_model, cfg.vocab), cfg.dtype),
    }


def logical_axes(cfg: ModelConfig) -> dict:
    return {
        "frontend_proj": (None, "embed"),
        "embed": ("vocab", "embed"),
        "enc": cm.stacked_axes(dict(_ENC_AXES)),
        "enc_norm": (None,),
        "dec": cm.stacked_axes(dict(_DEC_AXES)),
        "final_norm": (None,),
        "lm_head": ("embed", "vocab"),
    }


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------


def encode(cfg: ModelConfig, params: dict,
           frontend_embeds: jnp.ndarray) -> jnp.ndarray:
    x = jnp.dot(frontend_embeds.astype(cfg.dtype), params["frontend_proj"])
    positions = jnp.arange(x.shape[1])

    def body(xc, lp):
        h = cm.rms_norm(xc, lp["ln1"], cfg.norm_eps)
        q = jnp.einsum("bsd,dhk->bshk", h, lp["wq"])
        k = jnp.einsum("bsd,dhk->bshk", h, lp["wk"])
        v = jnp.einsum("bsd,dhk->bshk", h, lp["wv"])
        q = cm.apply_rope(q, positions, cfg.rope_theta)
        k = cm.apply_rope(k, positions, cfg.rope_theta)
        o = attn.multi_head_attention(q, k, v, causal=False)
        xc = xc + jnp.einsum("bshk,hkd->bsd", o, lp["wo"])
        h2 = cm.rms_norm(xc, lp["ln2"], cfg.norm_eps)
        return xc + cm.mlp_forward(lp["mlp"], h2)

    x = cm.scan_layers(body, x, params["enc"], cfg)
    return cm.rms_norm(x, params["enc_norm"], cfg.norm_eps)


def _dec_layer(cfg, lp, xc, enc_out, positions, enc_positions):
    h = cm.rms_norm(xc, lp["ln1"], cfg.norm_eps)
    q = jnp.einsum("bsd,dhk->bshk", h, lp["wq"])
    k = jnp.einsum("bsd,dhk->bshk", h, lp["wk"])
    v = jnp.einsum("bsd,dhk->bshk", h, lp["wv"])
    q = cm.apply_rope(q, positions, cfg.rope_theta)
    k = cm.apply_rope(k, positions, cfg.rope_theta)
    o = attn.multi_head_attention(q, k, v, causal=True)
    xc = xc + jnp.einsum("bshk,hkd->bsd", o, lp["wo"])
    # cross attention
    hx = cm.rms_norm(xc, lp["ln_x"], cfg.norm_eps)
    qx = jnp.einsum("bsd,dhk->bshk", hx, lp["xq"])
    kx = jnp.einsum("bsd,dhk->bshk", enc_out, lp["xk"])
    vx = jnp.einsum("bsd,dhk->bshk", enc_out, lp["xv"])
    ox = attn.multi_head_attention(qx, kx, vx, causal=False)
    xc = xc + jnp.einsum("bshk,hkd->bsd", ox, lp["xo"])
    h2 = cm.rms_norm(xc, lp["ln2"], cfg.norm_eps)
    return xc + cm.mlp_forward(lp["mlp"], h2)


def forward(cfg: ModelConfig, params: dict, tokens: jnp.ndarray,
            frontend_embeds: Optional[jnp.ndarray] = None,
            return_aux: bool = False):
    """tokens (B, S_dec); frontend_embeds (B, S_enc, F)."""
    assert frontend_embeds is not None, "encdec requires frontend embeds"
    enc_out = encode(cfg, params, frontend_embeds)
    x = jnp.take(params["embed"], tokens, axis=0)
    positions = jnp.arange(tokens.shape[1])
    enc_positions = jnp.arange(enc_out.shape[1])

    def body(xc, lp):
        return _dec_layer(cfg, lp, xc, enc_out, positions, enc_positions)

    x = cm.scan_layers(body, x, params["dec"], cfg)
    x = cm.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = jnp.dot(x, params["lm_head"]).astype(jnp.float32)
    if return_aux:
        return logits, jnp.float32(0.0)
    return logits


# ---------------------------------------------------------------------------
# Serving
# ---------------------------------------------------------------------------


def cache_specs(cfg: ModelConfig, batch: int, max_len: int) -> dict:
    l, hkv, hd = cfg.n_layers, cfg.n_kv_heads, cfg.hd
    se = enc_len(cfg, max_len)
    return {
        "k": jax.ShapeDtypeStruct((l, batch, max_len, hkv, hd), cfg.dtype),
        "v": jax.ShapeDtypeStruct((l, batch, max_len, hkv, hd), cfg.dtype),
        "xk": jax.ShapeDtypeStruct((l, batch, se, hkv, hd), cfg.dtype),
        "xv": jax.ShapeDtypeStruct((l, batch, se, hkv, hd), cfg.dtype),
        "len": jax.ShapeDtypeStruct((), jnp.int32),
    }


def cache_axes(cfg: ModelConfig) -> dict:
    ax = ("layer", "batch", "kv_seq", "kv", None)
    return {"k": ax, "v": ax, "xk": ax, "xv": ax, "len": ()}


def prefill(cfg: ModelConfig, params: dict, tokens: jnp.ndarray,
            frontend_embeds: Optional[jnp.ndarray] = None,
            max_len: Optional[int] = None):
    """Encoder pass + decoder prefill.  Cross-KV computed once."""
    assert frontend_embeds is not None
    enc_out = encode(cfg, params, frontend_embeds)
    x = jnp.take(params["embed"], tokens, axis=0)
    s = tokens.shape[1]
    positions = jnp.arange(s)

    def body(xc, lp):
        h = cm.rms_norm(xc, lp["ln1"], cfg.norm_eps)
        q = jnp.einsum("bsd,dhk->bshk", h, lp["wq"])
        k = jnp.einsum("bsd,dhk->bshk", h, lp["wk"])
        v = jnp.einsum("bsd,dhk->bshk", h, lp["wv"])
        q = cm.apply_rope(q, positions, cfg.rope_theta)
        k = cm.apply_rope(k, positions, cfg.rope_theta)
        o = attn.multi_head_attention(q, k, v, causal=True)
        xc = xc + jnp.einsum("bshk,hkd->bsd", o, lp["wo"])
        hx = cm.rms_norm(xc, lp["ln_x"], cfg.norm_eps)
        qx = jnp.einsum("bsd,dhk->bshk", hx, lp["xq"])
        kx = jnp.einsum("bsd,dhk->bshk", enc_out, lp["xk"])
        vx = jnp.einsum("bsd,dhk->bshk", enc_out, lp["xv"])
        ox = attn.multi_head_attention(qx, kx, vx, causal=False)
        xc = xc + jnp.einsum("bshk,hkd->bsd", ox, lp["xo"])
        h2 = cm.rms_norm(xc, lp["ln2"], cfg.norm_eps)
        xc = xc + cm.mlp_forward(lp["mlp"], h2)
        return xc, (k, v, kx, vx)

    fn = cm.maybe_remat(body, cfg)
    x, (ks, vs, xks, xvs) = cm.scan_or_unroll(fn, x, params["dec"],
                                              cfg.scan_layers)
    x = cm.rms_norm(x[:, -1:, :], params["final_norm"], cfg.norm_eps)
    logits = jnp.dot(x[:, 0, :], params["lm_head"]).astype(jnp.float32)
    cap = max_len if max_len is not None else s + 64
    if cap > s:
        pad = ((0, 0), (0, 0), (0, cap - s), (0, 0), (0, 0))
        ks, vs = jnp.pad(ks, pad), jnp.pad(vs, pad)
    cache = {"k": ks, "v": vs, "xk": xks, "xv": xvs, "len": jnp.int32(s)}
    return logits, cache


def decode_step(cfg: ModelConfig, params: dict, token: jnp.ndarray,
                cache: dict):
    x = jnp.take(params["embed"], token[:, None], axis=0)
    positions = jnp.reshape(cache["len"], (1,))

    def body(xc, layer_in):
        lp, kc, vc, xk, xv = layer_in
        h = cm.rms_norm(xc, lp["ln1"], cfg.norm_eps)
        q = jnp.einsum("bsd,dhk->bshk", h, lp["wq"])
        k = jnp.einsum("bsd,dhk->bshk", h, lp["wk"])
        v = jnp.einsum("bsd,dhk->bshk", h, lp["wv"])
        q = cm.apply_rope(q, positions, cfg.rope_theta)
        k = cm.apply_rope(k, positions, cfg.rope_theta)
        kc = jax.lax.dynamic_update_slice_in_dim(kc, k, cache["len"], axis=1)
        vc = jax.lax.dynamic_update_slice_in_dim(vc, v, cache["len"], axis=1)
        o = attn.decode_attention(q, kc, vc, cache["len"] + 1)
        xc = xc + jnp.einsum("bshk,hkd->bsd", o, lp["wo"])
        hx = cm.rms_norm(xc, lp["ln_x"], cfg.norm_eps)
        qx = jnp.einsum("bsd,dhk->bshk", hx, lp["xq"])
        ox = attn.decode_attention(qx, xk, xv, xk.shape[1])
        xc = xc + jnp.einsum("bshk,hkd->bsd", ox, lp["xo"])
        h2 = cm.rms_norm(xc, lp["ln2"], cfg.norm_eps)
        xc = xc + cm.mlp_forward(lp["mlp"], h2)
        return xc, (kc, vc)

    x, (ks, vs) = cm.scan_or_unroll(
        body, x, (params["dec"], cache["k"], cache["v"], cache["xk"],
                  cache["xv"]), cfg.scan_layers)
    x = cm.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = jnp.dot(x[:, 0, :], params["lm_head"]).astype(jnp.float32)
    return logits, {"k": ks, "v": vs, "xk": cache["xk"], "xv": cache["xv"],
                    "len": cache["len"] + 1}
