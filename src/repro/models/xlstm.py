"""xLSTM (arXiv:2405.04517) — sLSTM + mLSTM blocks (xlstm-1.3b).

Block layout (assignment: 48 blocks, d_model 2048, 4 heads, d_ff=0):
  * mLSTM blocks (matrix memory, parallelizable): pre-LN -> up-proj to
    2*d_inner (proj_factor 2.0) -> [u, z]; u -> causal depthwise conv(4)
    -> silu -> q,k,v heads + scalar i/f gates; chunkwise-parallel gated
    linear recurrence C_t = f_t C_{t-1} + i_t k_t v_t^T; h = (q·C)/
    max(|q·n|,1); output gated by silu(z); down-proj.
  * sLSTM blocks (scalar memory, strictly sequential): exponential gating
    with the max-stabilizer, per-head recurrent matrices, then a GeGLU FF
    (factor 4/3).  One sLSTM block every ``slstm_every`` (default 8).

Numerics: gates/accumulators in fp32; the input gate uses
``i = exp(min(i_raw, 8))`` so the chunkwise and the step-recurrent forms
are algebraically identical without a cross-chunk max-stabilizer (see
DESIGN.md deviations).
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import common as cm
from repro.models.common import ModelConfig

I_CAP = 8.0
CHUNK = 512


def d_inner(cfg: ModelConfig) -> int:
    return int(cfg.d_model * cfg.mlstm_proj_factor)


def slstm_ff(cfg: ModelConfig) -> int:
    d = int(cfg.d_model * cfg.slstm_ff_factor)
    return ((d + 127) // 128) * 128


def is_slstm(cfg: ModelConfig, layer_idx: int) -> bool:
    se = cfg.slstm_every
    return se > 0 and (layer_idx % se) == (se - 1)


# ---------------------------------------------------------------------------
# mLSTM block params
# ---------------------------------------------------------------------------


def _mlstm_init(cfg: ModelConfig):
    d, dt = cfg.d_model, cfg.dtype
    din = d_inner(cfg)
    h = cfg.n_heads

    def init_one(key):
        ks = jax.random.split(key, 7)
        return {
            "ln": jnp.zeros((d,), dt),
            "w_up": cm.dense_init(ks[0], (d, 2 * din), dt),
            "conv": cm.dense_init(ks[1], (4, din), dt),
            "wq": cm.dense_init(ks[2], (din, din), dt),
            "wk": cm.dense_init(ks[3], (din, din), dt),
            "wv": cm.dense_init(ks[4], (din, din), dt),
            "w_gates": cm.dense_init(ks[5], (din, 2 * h), jnp.float32),
            "b_gates": jnp.concatenate([
                jnp.full((h,), -2.0, jnp.float32),     # input gate bias
                jnp.full((h,), 3.0, jnp.float32),      # forget gate bias
            ]),
            "w_down": cm.dense_init(ks[6], (din, d), dt),
        }

    return init_one


def _mlstm_specs(cfg: ModelConfig) -> dict:
    d, dt = cfg.d_model, cfg.dtype
    din = d_inner(cfg)
    h = cfg.n_heads
    return {
        "ln": jax.ShapeDtypeStruct((d,), dt),
        "w_up": jax.ShapeDtypeStruct((d, 2 * din), dt),
        "conv": jax.ShapeDtypeStruct((4, din), dt),
        "wq": jax.ShapeDtypeStruct((din, din), dt),
        "wk": jax.ShapeDtypeStruct((din, din), dt),
        "wv": jax.ShapeDtypeStruct((din, din), dt),
        "w_gates": jax.ShapeDtypeStruct((din, 2 * h), jnp.float32),
        "b_gates": jax.ShapeDtypeStruct((2 * h,), jnp.float32),
        "w_down": jax.ShapeDtypeStruct((din, d), dt),
    }


_MLSTM_AXES = {
    "ln": (None,),
    "w_up": ("embed", "mlp"),
    "conv": (None, "mlp"),
    "wq": ("mlp", None),
    "wk": ("mlp", None),
    "wv": ("mlp", None),
    "w_gates": ("mlp", None),
    "b_gates": (None,),
    "w_down": ("mlp", "embed"),
}


# ---------------------------------------------------------------------------
# sLSTM block params
# ---------------------------------------------------------------------------


def _slstm_init(cfg: ModelConfig):
    d, dt, h = cfg.d_model, cfg.dtype, cfg.n_heads
    hd = d // h
    ff = slstm_ff(cfg)

    def init_one(key):
        ks = jax.random.split(key, 8)
        return {
            "ln": jnp.zeros((d,), dt),
            "w_in": cm.dense_init(ks[0], (d, 4 * d), dt),       # z,i,f,o
            "r": cm.dense_init(ks[1], (4, h, hd, hd), jnp.float32,
                               in_axis=2),
            "b": jnp.zeros((4 * d,), jnp.float32),
            "w_out": cm.dense_init(ks[2], (d, d), dt),
            "ln2": jnp.zeros((d,), dt),
            "ff1": cm.dense_init(ks[3], (d, 2 * ff), dt),
            "ff2": cm.dense_init(ks[4], (ff, d), dt),
        }

    return init_one


def _slstm_specs(cfg: ModelConfig) -> dict:
    d, dt, h = cfg.d_model, cfg.dtype, cfg.n_heads
    hd = d // h
    ff = slstm_ff(cfg)
    return {
        "ln": jax.ShapeDtypeStruct((d,), dt),
        "w_in": jax.ShapeDtypeStruct((d, 4 * d), dt),
        "r": jax.ShapeDtypeStruct((4, h, hd, hd), jnp.float32),
        "b": jax.ShapeDtypeStruct((4 * d,), jnp.float32),
        "w_out": jax.ShapeDtypeStruct((d, d), dt),
        "ln2": jax.ShapeDtypeStruct((d,), dt),
        "ff1": jax.ShapeDtypeStruct((d, 2 * ff), dt),
        "ff2": jax.ShapeDtypeStruct((ff, d), dt),
    }


_SLSTM_AXES = {
    "ln": (None,),
    "w_in": ("embed", "mlp"),
    "r": (None, "heads", None, None),
    "b": (None,),
    "w_out": (None, "embed"),
    "ln2": (None,),
    "ff1": ("embed", "mlp"),
    "ff2": ("mlp", "embed"),
}


# ---------------------------------------------------------------------------
# mLSTM cell — chunkwise parallel (training/prefill) and step (decode)
# ---------------------------------------------------------------------------


def causal_conv(u: jnp.ndarray, w: jnp.ndarray,
                state: Optional[jnp.ndarray] = None):
    """Depthwise causal conv, kernel 4.  u (B,S,C), w (4,C).

    Returns (out (B,S,C), new_state (B,3,C))."""
    b, s, c = u.shape
    if state is None:
        state = jnp.zeros((b, 3, c), u.dtype)
    xpad = jnp.concatenate([state, u], axis=1)           # (B, S+3, C)
    out = sum(xpad[:, i:i + s, :] * w[i][None, None, :] for i in range(4))
    return out, xpad[:, -3:, :]


def mlstm_chunkwise(q, k, v, i_raw, f_raw, c0, n0, chunk: int = CHUNK):
    """Chunkwise-parallel mLSTM.

    q,k,v: (B,S,H,hd) ; i_raw,f_raw: (B,S,H) fp32
    c0: (B,H,hd,hd) fp32 ; n0: (B,H,hd) fp32
    Returns h (B,S,H,hd), (c_final, n_final).
    """
    b, s, h, hd = q.shape
    if s % chunk != 0:
        chunk = s  # single chunk fallback for small sequences
    nc = s // chunk
    scale = 1.0 / math.sqrt(hd)

    li = jnp.minimum(i_raw, I_CAP)                        # log input gate
    lf = jax.nn.log_sigmoid(f_raw)                        # log forget gate

    def resh(x):
        return x.reshape(b, nc, chunk, *x.shape[2:]).swapaxes(0, 1)

    qc, kc, vc = resh(q), resh(k), resh(v)
    lic, lfc = resh(li), resh(lf)

    def body(carry, xs):
        c, n = carry
        qi, ki, vi, lii, lfi = xs                         # (B,L,H,...)
        qi32 = qi.astype(jnp.float32) * scale
        ki32 = ki.astype(jnp.float32)
        vi32 = vi.astype(jnp.float32)
        a = jnp.cumsum(lfi, axis=1)                       # (B,L,H)
        a_l = a[:, -1:, :]                                # (B,1,H)
        # inter-chunk: decay from chunk start
        dec_q = jnp.exp(a)                                # <= 1
        out = jnp.einsum("blhd,bhde->blhe", qi32 * dec_q[..., None], c)
        den = jnp.einsum("blhd,bhd->blh", qi32 * dec_q[..., None], n)
        # intra-chunk
        w_kj = jnp.exp(lii - a)                           # i_j * exp(-A_j)
        sc = jnp.einsum("blhd,bmhd->bhlm", qi32 * dec_q[..., None],
                        ki32 * w_kj[..., None])
        mask = jnp.tril(jnp.ones((chunk, chunk), bool))
        sc = jnp.where(mask[None, None], sc, 0.0)
        out = out + jnp.einsum("bhlm,bmhd->blhd", sc, vi32)
        den = den + jnp.sum(sc, axis=-1).swapaxes(1, 2)   # (B,L,H)
        hm = out / jnp.maximum(jnp.abs(den), 1.0)[..., None]
        # state update
        w_c = jnp.exp(a_l - a + lii)                      # (B,L,H)
        c = c * jnp.exp(a_l).swapaxes(1, 2)[..., None] + jnp.einsum(
            "blhd,blhe->bhde", ki32 * w_c[..., None], vi32)
        n = n * jnp.exp(a_l).swapaxes(1, 2) + jnp.sum(
            ki32 * w_c[..., None], axis=1)
        return (c, n), hm

    from repro.parallel import ctx as pctx

    # NOTE: this is the CHUNK loop (S/chunk trips) — unrolled in counting
    # mode so cost_analysis sees every chunk.  The sLSTM TIME scan (S
    # trips) is never unrolled; costcount corrects it analytically.
    (c_f, n_f), hs = cm.scan_or_unroll(body, (c0, n0),
                                       (qc, kc, vc, lic, lfc),
                                       not pctx.get_unroll())
    hs = hs.swapaxes(0, 1).reshape(b, s, h, hd)
    return hs.astype(q.dtype), (c_f, n_f)


def mlstm_step(q, k, v, i_raw, f_raw, c, n):
    """Single-token recurrent step.  q,k,v (B,H,hd); gates (B,H)."""
    hd = q.shape[-1]
    scale = 1.0 / math.sqrt(hd)
    q32 = q.astype(jnp.float32) * scale
    k32 = k.astype(jnp.float32)
    v32 = v.astype(jnp.float32)
    i_g = jnp.exp(jnp.minimum(i_raw, I_CAP))[..., None]   # (B,H,1)
    f_g = jax.nn.sigmoid(f_raw)[..., None]
    c = c * f_g[..., None] + i_g[..., None] * (k32[..., :, None]
                                               * v32[..., None, :])
    n = n * f_g + i_g * k32
    out = jnp.einsum("bhd,bhde->bhe", q32, c)
    den = jnp.einsum("bhd,bhd->bh", q32, n)
    h = out / jnp.maximum(jnp.abs(den), 1.0)[..., None]
    return h.astype(q.dtype), (c, n)


def _mlstm_qkvg(cfg: ModelConfig, p: dict, x: jnp.ndarray,
                conv_state: Optional[jnp.ndarray] = None):
    """Shared projection pipeline.  x (B,S,D) -> q,k,v,(i,f),z, conv_state."""
    b, s, _ = x.shape
    h = cfg.n_heads
    din = d_inner(cfg)
    hd = din // h
    xin = cm.rms_norm(x, p["ln"], cfg.norm_eps)
    uz = jnp.dot(xin, p["w_up"])
    u, z = jnp.split(uz, 2, axis=-1)
    uc, conv_state = causal_conv(u, p["conv"], conv_state)
    uc = jax.nn.silu(uc.astype(jnp.float32)).astype(x.dtype)
    q = jnp.dot(uc, p["wq"]).reshape(b, s, h, hd)
    k = jnp.dot(uc, p["wk"]).reshape(b, s, h, hd)
    v = jnp.dot(u, p["wv"]).reshape(b, s, h, hd)
    gates = jnp.dot(uc.astype(jnp.float32), p["w_gates"]) + p["b_gates"]
    i_raw, f_raw = jnp.split(gates, 2, axis=-1)           # (B,S,H)
    return q, k, v, i_raw, f_raw, z, conv_state


def mlstm_block(cfg: ModelConfig, p: dict, x: jnp.ndarray) -> jnp.ndarray:
    b, s, _ = x.shape
    h = cfg.n_heads
    din = d_inner(cfg)
    hd = din // h
    q, k, v, i_raw, f_raw, z, _ = _mlstm_qkvg(cfg, p, x)
    c0 = jnp.zeros((b, h, hd, hd), jnp.float32)
    n0 = jnp.zeros((b, h, hd), jnp.float32)
    hs, _ = mlstm_chunkwise(q, k, v, i_raw, f_raw, c0, n0)
    hs = hs.reshape(b, s, din) * jax.nn.silu(z.astype(jnp.float32)).astype(
        x.dtype)
    return x + jnp.dot(hs, p["w_down"])


# ---------------------------------------------------------------------------
# sLSTM cell
# ---------------------------------------------------------------------------


def slstm_seq(p: dict, x_proj: jnp.ndarray, h0, c0, n0, m0):
    """x_proj (B,S,4,H,hd) pre-computed input projections (z,i,f,o order).

    Sequential scan with max-stabilized exponential gating."""
    r = p["r"]                                            # (4,H,hd,hd)

    def step(carry, xt):
        hp, cp, np_, mp = carry                           # (B,H,hd) fp32
        pre = xt.astype(jnp.float32) + jnp.einsum(
            "bhd,ghde->gbhe", hp, r)                      # (4,B,H,hd)
        z_t = jnp.tanh(pre[0])
        i_t, f_t, o_t = pre[1], pre[2], pre[3]
        m_t = jnp.maximum(f_t + mp, i_t)
        i_p = jnp.exp(i_t - m_t)
        f_p = jnp.exp(f_t + mp - m_t)
        c_t = f_p * cp + i_p * z_t
        n_t = f_p * np_ + i_p
        h_t = jax.nn.sigmoid(o_t) * c_t / jnp.maximum(n_t, 1.0)
        return (h_t, c_t, n_t, m_t), h_t

    xs = x_proj.swapaxes(0, 1).swapaxes(1, 2)             # (S,4,B,H,hd)? no
    xs = x_proj.transpose(1, 2, 0, 3, 4)                  # (S,4,B,H,hd)
    (hf, cf, nf, mf), hs = jax.lax.scan(step, (h0, c0, n0, m0), xs)
    return hs.transpose(1, 0, 2, 3), (hf, cf, nf, mf)     # (B,S,H,hd)


def slstm_block(cfg: ModelConfig, p: dict, x: jnp.ndarray) -> jnp.ndarray:
    b, s, d = x.shape
    h = cfg.n_heads
    hd = d // h
    xin = cm.rms_norm(x, p["ln"], cfg.norm_eps)
    xp = (jnp.dot(xin, p["w_in"]).astype(jnp.float32)
          + p["b"]).reshape(b, s, 4, h, hd)
    zero = jnp.zeros((b, h, hd), jnp.float32)
    hs, _ = slstm_seq(p, xp, zero, zero, zero, zero - 1e30)
    hs = hs.reshape(b, s, d).astype(x.dtype)
    x = x + jnp.dot(hs, p["w_out"])
    # GeGLU feed-forward
    xf = cm.rms_norm(x, p["ln2"], cfg.norm_eps)
    g, u = jnp.split(jnp.dot(xf, p["ff1"]), 2, axis=-1)
    ff = jax.nn.gelu(g.astype(jnp.float32)).astype(x.dtype) * u
    return x + jnp.dot(ff, p["ff2"])


# ---------------------------------------------------------------------------
# Model assembly
# ---------------------------------------------------------------------------


def _block_ids(cfg: ModelConfig):
    m_ids = [i for i in range(cfg.n_layers) if not is_slstm(cfg, i)]
    s_ids = [i for i in range(cfg.n_layers) if is_slstm(cfg, i)]
    return m_ids, s_ids


def init(cfg: ModelConfig, key) -> dict:
    m_ids, s_ids = _block_ids(cfg)
    k_emb, k_m, k_s, k_h = jax.random.split(key, 4)
    return {
        "embed": cm.embed_init(k_emb, (cfg.vocab, cfg.d_model), cfg.dtype),
        "mlstm": cm.stack_layer_params(_mlstm_init(cfg), k_m, len(m_ids)),
        "slstm": cm.stack_layer_params(_slstm_init(cfg), k_s, len(s_ids)),
        "final_norm": jnp.zeros((cfg.d_model,), cfg.dtype),
        "lm_head": cm.dense_init(k_h, (cfg.d_model, cfg.vocab), cfg.dtype),
    }


def param_specs(cfg: ModelConfig) -> dict:
    m_ids, s_ids = _block_ids(cfg)
    return {
        "embed": jax.ShapeDtypeStruct((cfg.vocab, cfg.d_model), cfg.dtype),
        "mlstm": cm.stacked_specs(_mlstm_specs(cfg), len(m_ids)),
        "slstm": cm.stacked_specs(_slstm_specs(cfg), len(s_ids)),
        "final_norm": jax.ShapeDtypeStruct((cfg.d_model,), cfg.dtype),
        "lm_head": jax.ShapeDtypeStruct((cfg.d_model, cfg.vocab), cfg.dtype),
    }


def logical_axes(cfg: ModelConfig) -> dict:
    return {
        "embed": ("vocab", "embed"),
        "mlstm": cm.stacked_axes(dict(_MLSTM_AXES)),
        "slstm": cm.stacked_axes(dict(_SLSTM_AXES)),
        "final_norm": (None,),
        "lm_head": ("embed", "vocab"),
    }


def forward(cfg: ModelConfig, params: dict, tokens: jnp.ndarray,
            frontend_embeds=None, return_aux: bool = False):
    """Alternating mLSTM/sLSTM stack.  sLSTM every ``slstm_every`` blocks.

    Layout: scan over groups of (slstm_every-1) mLSTM blocks + 1 sLSTM.
    Leftover mLSTM blocks (when n_layers % slstm_every != 0) run after."""
    x = jnp.take(params["embed"], tokens, axis=0)
    m_ids, s_ids = _block_ids(cfg)
    n_groups = len(s_ids)
    m_per_group = cfg.slstm_every - 1 if cfg.slstm_every else len(m_ids)

    def group_body(xc, gp):
        mp, sp = gp

        def m_body(xc2, lp):
            return mlstm_block(cfg, lp, xc2)

        xc = cm.scan_layers(m_body, xc, mp, cfg)
        return slstm_block(cfg, sp, xc)

    if n_groups:
        grouped_m = jax.tree.map(
            lambda a: a[: n_groups * m_per_group].reshape(
                n_groups, m_per_group, *a.shape[1:]), params["mlstm"])
        gfn = cm.maybe_remat(group_body, cfg)
        x, _ = cm.scan_or_unroll(lambda c, g: (gfn(c, g), None), x,
                                 (grouped_m, params["slstm"]),
                                 cfg.scan_layers)
    rest = len(m_ids) - n_groups * m_per_group
    if rest:
        rest_m = jax.tree.map(lambda a: a[-rest:], params["mlstm"])
        x = cm.scan_layers(lambda c, lp: mlstm_block(cfg, lp, c), x,
                           rest_m, cfg)
    x = cm.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = jnp.dot(x, params["lm_head"]).astype(jnp.float32)
    if return_aux:
        return logits, jnp.float32(0.0)
    return logits


# ---------------------------------------------------------------------------
# Serving: recurrent state cache
# ---------------------------------------------------------------------------


def cache_specs(cfg: ModelConfig, batch: int, max_len: int) -> dict:
    m_ids, s_ids = _block_ids(cfg)
    din = d_inner(cfg)
    h = cfg.n_heads
    hd_m = din // h
    hd_s = cfg.d_model // h
    f32 = jnp.float32
    return {
        "m_c": jax.ShapeDtypeStruct((len(m_ids), batch, h, hd_m, hd_m), f32),
        "m_n": jax.ShapeDtypeStruct((len(m_ids), batch, h, hd_m), f32),
        "m_conv": jax.ShapeDtypeStruct((len(m_ids), batch, 3, din),
                                       cfg.dtype),
        "s_h": jax.ShapeDtypeStruct((len(s_ids), 4, batch, h, hd_s), f32),
        "len": jax.ShapeDtypeStruct((), jnp.int32),
    }


def cache_axes(cfg: ModelConfig) -> dict:
    return {
        "m_c": ("layer", "batch", None, None, "state_v"),
        "m_n": ("layer", "batch", None, None),
        "m_conv": ("layer", "batch", None, "mlp"),
        "s_h": ("layer", None, "batch", None, None),
        "len": (),
    }


def init_cache(cfg: ModelConfig, batch: int) -> dict:
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                        cache_specs(cfg, batch, 0))


def prefill(cfg: ModelConfig, params: dict, tokens: jnp.ndarray,
            frontend_embeds=None, max_len=None):
    """Run the full sequence, returning last logits + recurrent state.

    (``max_len`` is ignored: recurrent state is O(1) in context length.)"""
    b, s = tokens.shape
    x = jnp.take(params["embed"], tokens, axis=0)
    m_ids, s_ids = _block_ids(cfg)
    h = cfg.n_heads
    din = d_inner(cfg)
    hd = din // h

    def m_body(xc, lp):
        bq, kk, vv, ir, fr, z, conv_st = _mlstm_qkvg(cfg, lp, xc)
        c0 = jnp.zeros((b, h, hd, hd), jnp.float32)
        n0 = jnp.zeros((b, h, hd), jnp.float32)
        hs, (cf, nf) = mlstm_chunkwise(bq, kk, vv, ir, fr, c0, n0)
        hs = hs.reshape(b, s, din) * jax.nn.silu(
            z.astype(jnp.float32)).astype(xc.dtype)
        return xc + jnp.dot(hs, lp["w_down"]), (cf, nf, conv_st)

    def s_body(xc, lp):
        hd_s = cfg.d_model // h
        xin = cm.rms_norm(xc, lp["ln"], cfg.norm_eps)
        xp = (jnp.dot(xin, lp["w_in"]).astype(jnp.float32)
              + lp["b"]).reshape(b, s, 4, h, hd_s)
        zero = jnp.zeros((b, h, hd_s), jnp.float32)
        hs, (hf, cf, nf, mf) = slstm_seq(lp, xp, zero, zero, zero,
                                         zero - 1e30)
        hs = hs.reshape(b, s, cfg.d_model).astype(xc.dtype)
        xc = xc + jnp.dot(hs, lp["w_out"])
        xf = cm.rms_norm(xc, lp["ln2"], cfg.norm_eps)
        g, u = jnp.split(jnp.dot(xf, lp["ff1"]), 2, axis=-1)
        ff = jax.nn.gelu(g.astype(jnp.float32)).astype(xc.dtype) * u
        return xc + jnp.dot(ff, lp["ff2"]), jnp.stack([hf, cf, nf, mf])

    # interleaved execution with state collection (python loop over groups,
    # states collected per stacked type)
    m_states, s_states = [], []
    mi = si = 0
    for li in range(cfg.n_layers):
        if is_slstm(cfg, li):
            lp = jax.tree.map(lambda a: a[si], params["slstm"])
            x, st = s_body(x, lp)
            s_states.append(st)
            si += 1
        else:
            lp = jax.tree.map(lambda a: a[mi], params["mlstm"])
            x, st = m_body(x, lp)
            m_states.append(st)
            mi += 1
    x = cm.rms_norm(x[:, -1:, :], params["final_norm"], cfg.norm_eps)
    logits = jnp.dot(x[:, 0, :], params["lm_head"]).astype(jnp.float32)
    cache = {
        "m_c": jnp.stack([st[0] for st in m_states]),
        "m_n": jnp.stack([st[1] for st in m_states]),
        "m_conv": jnp.stack([st[2] for st in m_states]),
        "s_h": (jnp.stack(s_states) if s_states
                else jnp.zeros((0, 4, b, h, cfg.d_model // h), jnp.float32)),
        "len": jnp.int32(s),
    }
    return logits, cache


def decode_step(cfg: ModelConfig, params: dict, token: jnp.ndarray,
                cache: dict):
    """One-token recurrent step."""
    b = token.shape[0]
    x = jnp.take(params["embed"], token[:, None], axis=0)  # (B,1,D)
    h = cfg.n_heads
    din = d_inner(cfg)
    hd = din // h

    def m_body(carry, layer_in):
        xc = carry
        lp, c_st, n_st, conv_st = layer_in
        q, k, v, ir, fr, z, conv_st = _mlstm_qkvg(cfg, lp, xc, conv_st)
        hs, (cf, nf) = mlstm_step(q[:, 0], k[:, 0], v[:, 0], ir[:, 0],
                                  fr[:, 0], c_st, n_st)
        hs = hs.reshape(b, 1, din) * jax.nn.silu(
            z.astype(jnp.float32)).astype(xc.dtype)
        return xc + jnp.dot(hs, lp["w_down"]), (cf, nf, conv_st)

    def s_body(carry, layer_in):
        xc = carry
        lp, st = layer_in                                  # st (4,B,H,hd)
        hd_s = cfg.d_model // h
        xin = cm.rms_norm(xc, lp["ln"], cfg.norm_eps)
        xp = (jnp.dot(xin, lp["w_in"]).astype(jnp.float32)
              + lp["b"]).reshape(b, 1, 4, h, hd_s)
        hs, (hf, cf, nf, mf) = slstm_seq(lp, xp, st[0], st[1], st[2], st[3])
        hs = hs.reshape(b, 1, cfg.d_model).astype(xc.dtype)
        xc = xc + jnp.dot(hs, lp["w_out"])
        xf = cm.rms_norm(xc, lp["ln2"], cfg.norm_eps)
        g, u = jnp.split(jnp.dot(xf, lp["ff1"]), 2, axis=-1)
        ff = jax.nn.gelu(g.astype(jnp.float32)).astype(xc.dtype) * u
        return xc + jnp.dot(ff, lp["ff2"]), jnp.stack([hf, cf, nf, mf])

    m_out, s_out = [], []
    mi = si = 0
    for li in range(cfg.n_layers):
        if is_slstm(cfg, li):
            lp = jax.tree.map(lambda a: a[si], params["slstm"])
            x, st = s_body(x, (lp, cache["s_h"][si]))
            s_out.append(st)
            si += 1
        else:
            lp = jax.tree.map(lambda a: a[mi], params["mlstm"])
            x, st = m_body(x, (lp, cache["m_c"][mi], cache["m_n"][mi],
                               cache["m_conv"][mi]))
            m_out.append(st)
            mi += 1
    x = cm.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = jnp.dot(x[:, 0, :], params["lm_head"]).astype(jnp.float32)
    cache = {
        "m_c": jnp.stack([st[0] for st in m_out]),
        "m_n": jnp.stack([st[1] for st in m_out]),
        "m_conv": jnp.stack([st[2] for st in m_out]),
        "s_h": (jnp.stack(s_out) if s_out else cache["s_h"]),
        "len": cache["len"] + 1,
    }
    return logits, cache
