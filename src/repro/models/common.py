"""Common model-definition machinery shared by every architecture family.

Design notes
------------
* Parameters are plain nested dicts of ``jnp.ndarray`` — no flax/haiku. Every
  model exposes:
    - ``init(cfg, key)``            -> param pytree (materialized)
    - ``param_specs(cfg)``          -> pytree of ``jax.ShapeDtypeStruct`` (no alloc)
    - ``logical_axes(cfg)``         -> pytree of logical-axis tuples (for sharding)
    - ``forward(cfg, params, ...)`` -> logits
* Per-layer parameters are stacked with a leading ``L`` dimension so the layer
  stack lowers to a single ``lax.scan`` — small HLO, fast multi-device compile.
* Logical axis names (mapped to mesh axes in ``repro.parallel.sharding``):
    "embed"   – d_model dim            (FSDP candidate)
    "heads"   – attention head dim     (TP)
    "kv"      – kv-head dim            (TP when divisible)
    "mlp"     – feed-forward hidden    (TP)
    "vocab"   – vocabulary             (TP)
    "expert"  – MoE expert dim         (EP)
    "layer"   – stacked layer dim      (never sharded)
    None      – replicated
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp

# ---------------------------------------------------------------------------
# Config
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | xlstm | rglru | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None   # default: d_model // n_heads
    rope_theta: float = 10_000.0
    qk_norm: bool = False
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01
    # --- recurrentgemma / hybrid ---
    window: int = 0                  # sliding local-attention window (0 = full)
    lru_width: int = 0
    attn_every: int = 0              # 1 attention block per `attn_every` blocks
    # --- xlstm ---
    slstm_every: int = 0             # 1 sLSTM block per `slstm_every` blocks
    mlstm_proj_factor: float = 2.0
    slstm_ff_factor: float = 4.0 / 3.0
    # --- encoder-decoder ---
    n_enc_layers: int = 0            # if >0, family == encdec
    # --- multimodal frontend stubs ---
    frontend: str = ""               # "" | "patch" | "audio"
    frontend_dim: int = 0            # raw embedding dim provided by the stub
    n_frontend_tokens: int = 0       # tokens contributed by the frontend
    # --- numerics ---
    dtype: Any = jnp.bfloat16
    # --- training-time knobs (overridable per shape) ---
    remat: bool = True
    scan_layers: bool = True
    # --- beyond-paper optimization knobs (§Perf; default = baseline) ---
    tp_attention: bool = False   # TP-aligned GQA: repeat KV weights to one
    #                              kv head per q head + zero-pad heads to
    #                              the model-axis width, so the attention
    #                              einsums shard instead of replicating
    #                              (numerically identical; see EXPERIMENTS)
    sp_decode: bool = False      # pin decode attention to the sequence-
    #                              sharded KV layout (flash-decoding style)
    #                              instead of letting GSPMD reshard the
    #                              cache to kv-head sharding per layer
    #                              ("involuntary full rematerialization")
    gather_weights_once: bool = False  # hoist the FSDP all-gather out of
    #                              the microbatch/remat passes: gather bf16
    #                              weights to TP-only layout once per step
    #                              (ZeRO-1-for-compute; needs params*2/TP
    #                              bytes of HBM), reduce-scatter grads back
    remat_policy: str = "nothing"  # "nothing" | "dots" — remat checkpoint
    #                              policy (dots saves matmul outputs:
    #                              less recompute, more activation HBM)
    causal_slice: bool = False   # triangle-sliced chunked attention in the
    #                              unrolled path (flash-kernel block-skip
    #                              analogue; ~2x attention flops saving)

    @property
    def hd(self) -> int:
        return self.head_dim if self.head_dim else self.d_model // self.n_heads

    @property
    def q_per_kv(self) -> int:
        return self.n_heads // self.n_kv_heads

    def n_params(self) -> int:
        """Parameter count, derived from the real param specs (no alloc)."""
        from repro.models import registry

        specs = registry.param_specs(self)
        return int(sum(math.prod(s.shape) for s in jax.tree.leaves(specs)))


# ---------------------------------------------------------------------------
# Initializers (shape-only friendly)
# ---------------------------------------------------------------------------


def dense_init(key, shape, dtype, in_axis: int = 0) -> jnp.ndarray:
    fan_in = shape[in_axis] if isinstance(in_axis, int) else math.prod(
        shape[a] for a in in_axis)
    scale = 1.0 / math.sqrt(max(fan_in, 1))
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def embed_init(key, shape, dtype) -> jnp.ndarray:
    return (jax.random.normal(key, shape, jnp.float32) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def rms_norm(x: jnp.ndarray, scale: jnp.ndarray, eps: float) -> jnp.ndarray:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + scale.astype(jnp.float32))).astype(dt)


def head_rms_norm(x: jnp.ndarray, scale: jnp.ndarray, eps: float) -> jnp.ndarray:
    """QK-norm: RMS over the head_dim of a (..., H, hd) tensor."""
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + scale.astype(jnp.float32))).astype(dt)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope_freqs(hd: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, hd, 2, dtype=jnp.float32) / hd))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (B, S, H, hd); positions: (B, S) or (S,)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                      # (hd/2,)
    if positions.ndim == 1:
        positions = positions[None, :]
    ang = positions[..., None].astype(jnp.float32) * freqs  # (B,S,hd/2)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# SwiGLU MLP
# ---------------------------------------------------------------------------


def mlp_params(key, d_model: int, d_ff: int, dtype) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": dense_init(k1, (d_model, d_ff), dtype),
        "w_up": dense_init(k2, (d_model, d_ff), dtype),
        "w_down": dense_init(k3, (d_ff, d_model), dtype, in_axis=0),
    }


def mlp_specs(d_model: int, d_ff: int, dtype) -> dict:
    return {
        "w_gate": jax.ShapeDtypeStruct((d_model, d_ff), dtype),
        "w_up": jax.ShapeDtypeStruct((d_model, d_ff), dtype),
        "w_down": jax.ShapeDtypeStruct((d_ff, d_model), dtype),
    }


MLP_AXES = {
    "w_gate": ("embed", "mlp"),
    "w_up": ("embed", "mlp"),
    "w_down": ("mlp", "embed"),
}


def mlp_forward(p: dict, x: jnp.ndarray) -> jnp.ndarray:
    g = jnp.dot(x, p["w_gate"])
    u = jnp.dot(x, p["w_up"])
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    return jnp.dot(h, p["w_down"])


# ---------------------------------------------------------------------------
# Stacking helpers (scan over layers)
# ---------------------------------------------------------------------------


def stack_layer_params(init_one: Callable[[jax.Array], dict], key,
                       n_layers: int) -> dict:
    keys = jax.random.split(key, n_layers)
    return jax.vmap(init_one)(keys)


def stacked_specs(spec_one: dict, n_layers: int) -> dict:
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct((n_layers,) + s.shape, s.dtype), spec_one)


def stacked_axes(axes_one: dict) -> dict:
    return jax.tree.map(lambda a: ("layer",) + a, axes_one,
                        is_leaf=lambda x: isinstance(x, tuple))


def maybe_remat(fn: Callable, cfg: ModelConfig) -> Callable:
    if not cfg.remat:
        return fn
    policy = {
        "nothing": jax.checkpoint_policies.nothing_saveable,
        "dots": jax.checkpoint_policies.dots_with_no_batch_dims_saveable,
    }[cfg.remat_policy]
    return jax.checkpoint(fn, policy=policy)


def scan_or_unroll(body: Callable, carry, xs, use_scan: bool):
    """``lax.scan`` when use_scan, else a python loop (counting mode:
    XLA cost_analysis counts while bodies once, so the dry-run counting
    pass unrolls).  body(carry, x) -> (carry, y)."""
    if use_scan:
        return jax.lax.scan(body, carry, xs)
    n = jax.tree.leaves(xs)[0].shape[0]
    ys = []
    for i in range(n):
        x_i = jax.tree.map(lambda a: a[i], xs)
        carry, y = body(carry, x_i)
        ys.append(y)
    if ys and ys[0] is not None:
        ys = jax.tree.map(lambda *zs: jnp.stack(zs), *ys)
    else:
        ys = None
    return carry, ys


def scan_layers(body: Callable, x, layer_params, cfg: ModelConfig,
                extra_carry=None):
    """Run ``body(carry, one_layer_params) -> carry`` over stacked params."""
    fn = maybe_remat(body, cfg)
    if cfg.scan_layers:
        carry, _ = jax.lax.scan(lambda c, p: (fn(c, p), None), x, layer_params)
        return carry
    n = jax.tree.leaves(layer_params)[0].shape[0]
    for i in range(n):
        p_i = jax.tree.map(lambda a: a[i], layer_params)
        x = fn(x, p_i)
    return x


# ---------------------------------------------------------------------------
# Loss
# ---------------------------------------------------------------------------


def softmax_cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray,
                          z_loss: float = 1e-4) -> jnp.ndarray:
    """logits (..., V) fp-any; labels (...) int32. Returns mean loss (fp32)."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    loss = lse - gold
    if z_loss:
        loss = loss + z_loss * jnp.square(lse)
    return jnp.mean(loss)
