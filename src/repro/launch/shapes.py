"""The assigned input-shape grid and per-(arch x shape) applicability.

LM transformer shapes are seq_len x global_batch.  ``decode_*``/``long_*``
lower ``serve_step`` (one new token against a KV/recurrent cache of
seq_len), NOT ``train_step``.  ``long_500k`` requires sub-quadratic
attention: it runs for the SSM/hybrid archs (xlstm, recurrentgemma) and is
skipped (recorded N/A) for pure full-attention archs — see DESIGN.md
§Arch-applicability.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.models import registry
from repro.models.common import ModelConfig


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str        # train | prefill | decode
    seq: int
    batch: int


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524288, 1),
}

# Gradient-accumulation microbatch count per arch for train_4k
# (chosen so per-layer saved activations fit HBM; see DESIGN.md §6).
MICROBATCH: Dict[str, int] = {
    "phi3_medium_14b": 4,
    "glm4_9b": 4,
    "deepseek_coder_33b": 8,
    "qwen3_4b": 2,
    "seamless_m4t_medium": 1,
    "xlstm_1_3b": 2,
    "moonshot_v1_16b_a3b": 2,
    "olmoe_1b_7b": 1,
    "pixtral_12b": 4,
    "recurrentgemma_9b": 4,
}


def applicable(cfg: ModelConfig, shape: ShapeSpec) -> Optional[str]:
    """None if the (arch, shape) cell runs; else a skip reason (N/A cell)."""
    if shape.name == "long_500k" and not registry.sub_quadratic(cfg):
        return ("full-attention arch: 512k dense-KV decode is not "
                "sub-quadratic; skipped per assignment")
    return None


def frontend_tokens(cfg: ModelConfig, seq: int) -> int:
    if cfg.frontend == "patch":
        return min(cfg.n_frontend_tokens, seq // 2)
    if cfg.frontend == "audio":
        from repro.models import encdec

        return encdec.enc_len(cfg, seq)
    return 0


def input_specs(cfg: ModelConfig, shape: ShapeSpec) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of this cell.

    train  -> {tokens, labels[, frontend_embeds]}
    prefill-> {tokens[, frontend_embeds]}
    decode -> {token, cache}
    """
    b, s = shape.batch, shape.seq
    i32 = jnp.int32
    if shape.kind == "train":
        specs = {
            "tokens": jax.ShapeDtypeStruct((b, s), i32),
            "labels": jax.ShapeDtypeStruct((b, s), i32),
        }
        nf = frontend_tokens(cfg, s)
        if nf:
            specs["frontend_embeds"] = jax.ShapeDtypeStruct(
                (b, nf, cfg.frontend_dim), jnp.float32)
        return specs
    if shape.kind == "prefill":
        specs = {"tokens": jax.ShapeDtypeStruct((b, s), i32)}
        nf = frontend_tokens(cfg, s)
        if nf:
            specs["frontend_embeds"] = jax.ShapeDtypeStruct(
                (b, nf, cfg.frontend_dim), jnp.float32)
        return specs
    if shape.kind == "decode":
        return {
            "token": jax.ShapeDtypeStruct((b,), i32),
            "cache": registry.cache_specs(cfg, b, s),
        }
    raise ValueError(shape.kind)
