import os
if "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# Corrected cost counting for the roofline (§Dry-run methodology).
#
# XLA's cost_analysis counts while-loop bodies ONCE, not x trip-count
# (verified: a scanned 8-layer stack reports exactly 1/8 of the unrolled
# flops).  The full-config dry-run therefore proves compilability and
# memory, while THIS module produces the corrected per-chip flops/bytes/
# collective-bytes used in the roofline:
#
#   1. compile small "counting" variants with every inner loop unrolled
#      (chunked attention, mLSTM chunks, microbatch accumulation, layer
#      stacks — via cfg.scan_layers=False + the unroll context),
#   2. at several layer counts per kind (dense: L in {1,2}; rglru:
#      {1,3,6} solving (base, rec, attn); xlstm: {(1,0),(2,2),(4,4)}
#      layers x slstm_every solving (base, mlstm, slstm); encdec scales
#      enc/dec separately) — always at the production n_mb (totals are
#      n_mb-independent: same tokens; verified <2% on design points),
#   3. solve the linear attribution  cost = base + sum_k n_k * kind_k
#      and evaluate at the production counts,
#   4. add the analytic correction for the sLSTM time scan (its per-step
#      body is counted once but runs S times; the body cost is closed
#      form: 4 recurrent (H, hd, hd) matmuls + elementwise gates).
#
# Results land in results/costs/<arch>__<shape>__<mesh>.json.

import argparse
import dataclasses
import json
import pathlib
import sys
import traceback

import numpy as np

RESULTS = pathlib.Path(__file__).resolve().parents[3] / "results" / "costs"


def _counting_cfg(cfg, n_layers, n_enc=None, **extra):
    kw = dict(n_layers=n_layers, scan_layers=False, **extra)
    if n_enc is not None:
        kw["n_enc_layers"] = n_enc
    return dataclasses.replace(cfg, **kw)


def _kind_counts(cfg):
    """Per-kind layer counts for the attribution model."""
    if cfg.family == "rglru":
        from repro.models.rglru import _counts

        r, a = _counts(cfg)
        return {"rec": r, "attn": a}
    if cfg.family == "xlstm":
        from repro.models.xlstm import _block_ids

        m, s = _block_ids(cfg)
        return {"mlstm": len(m), "slstm": len(s)}
    if cfg.family == "encdec":
        return {"enc": cfg.n_enc_layers or cfg.n_layers,
                "dec": cfg.n_layers}
    return {"layer": cfg.n_layers}


def _design_points(cfg):
    """Counting configs: list of (cfg_variant, kind_counts dict)."""
    if cfg.family == "rglru":
        ls = [1, 3, 6]
    elif cfg.family == "xlstm":
        # small but identifiable (mlstm, slstm) counts: (1,0),(1,1),(3,1)
        pts = []
        for nl, se in [(1, 0), (2, 2), (4, 4)]:
            c = _counting_cfg(cfg, nl, slstm_every=se)
            pts.append((c, _kind_counts(c)))
        return pts
    elif cfg.family == "encdec":
        pts = []
        for ne, nd in [(1, 1), (2, 1), (1, 2)]:
            c = _counting_cfg(cfg, nd, n_enc=ne)
            pts.append((c, _kind_counts(c)))
        return pts
    else:
        ls = [1, 2]
    pts = []
    for l in ls:
        c = _counting_cfg(cfg, l)
        pts.append((c, _kind_counts(c)))
    return pts


def _measure(cfg, shape, mesh, n_mb):
    """Compile one counting variant; return dict of metrics."""
    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.launch import dryrun as dr
    from repro.launch import shapes as shp
    from repro.models import registry
    from repro.optim import opt_state_specs
    from repro.parallel import ctx as pctx
    from repro.parallel import sharding as shd
    from repro.serve.step import (build_decode_step, build_prefill_step,
                                  cache_shardings, serve_rules)
    from repro.train.step import build_train_step, train_state_shardings

    ispecs = shp.input_specs(cfg, shape)
    with pctx.use_mesh(mesh), pctx.use_unroll(True):
        if shape.kind == "train":
            step = build_train_step(cfg, n_microbatch=n_mb)
            p_sh, o_sh = train_state_shardings(cfg, mesh)
            p_specs = registry.param_specs(cfg)
            o_specs = opt_state_specs(p_specs)
            b_sh = {k: shd.batch_sharding(mesh, len(v.shape))
                    for k, v in ispecs.items()}
            fn = jax.jit(step,
                         in_shardings=(p_sh, o_sh, NamedSharding(mesh, P()),
                                       b_sh),
                         out_shardings=(p_sh, o_sh, None),
                         donate_argnums=(0, 1))
            lowered = fn.lower(p_specs, o_specs,
                               jax.ShapeDtypeStruct((), jnp.int32), ispecs)
        elif shape.kind == "prefill":
            step = build_prefill_step(cfg)
            rules = serve_rules(cfg, mesh, shape.batch)
            p_specs = registry.param_specs(cfg)
            p_sh = shd.shardings_from_axes(registry.logical_axes(cfg),
                                           mesh, rules, p_specs)
            c_sh = cache_shardings(cfg, mesh, shape.batch, shape.seq + 64,
                                   rules)
            b_sh = {k: shd.batch_sharding(mesh, len(v.shape))
                    for k, v in ispecs.items()}
            logits_sh = NamedSharding(mesh, shd.spec_from_axes(
                ("batch", "vocab"), mesh, rules, (shape.batch, cfg.vocab)))
            if "frontend_embeds" in ispecs:
                fn = jax.jit(step, in_shardings=(
                    p_sh, b_sh["tokens"], b_sh["frontend_embeds"]),
                    out_shardings=(logits_sh, c_sh))
                lowered = fn.lower(p_specs, ispecs["tokens"],
                                   ispecs["frontend_embeds"])
            else:
                fn = jax.jit(step, in_shardings=(p_sh, b_sh["tokens"]),
                             out_shardings=(logits_sh, c_sh))
                lowered = fn.lower(p_specs, ispecs["tokens"])
        else:
            step = build_decode_step(cfg)
            rules = serve_rules(cfg, mesh, shape.batch)
            p_specs = registry.param_specs(cfg)
            p_sh = shd.shardings_from_axes(registry.logical_axes(cfg),
                                           mesh, rules, p_specs)
            c_sh = cache_shardings(cfg, mesh, shape.batch, shape.seq,
                                   rules)
            tok_sh = NamedSharding(mesh, shd.spec_from_axes(
                ("batch",), mesh, rules, (shape.batch,)))
            logits_sh = NamedSharding(mesh, shd.spec_from_axes(
                ("batch", "vocab"), mesh, rules, (shape.batch, cfg.vocab)))
            fn = jax.jit(step, in_shardings=(p_sh, tok_sh, c_sh),
                         out_shardings=(logits_sh, c_sh),
                         donate_argnums=(2,))
            lowered = fn.lower(p_specs, ispecs["token"], ispecs["cache"])
        compiled = lowered.compile()
    cost = compiled.cost_analysis()
    coll = dr.collective_bytes(compiled.as_text())
    return {
        "flops": float(cost.get("flops", 0.0)),
        "bytes": float(cost.get("bytes accessed", 0.0)),
        "coll_bytes": float(sum(v for k, v in coll.items()
                                if k != "count")),
        "coll_count": float(coll["count"]),
    }


def _slstm_analytic(cfg, shape, mesh):
    """Per-chip correction for the sLSTM time scan (counted once,
    runs S times): (S-1) x per-step body, per sLSTM layer."""
    if cfg.family != "xlstm":
        return {}
    from repro.models.xlstm import _block_ids
    from repro.parallel import ctx as pctx

    _, s_ids = _block_ids(cfg)
    n_slstm = len(s_ids)
    if n_slstm == 0:
        return {}
    if shape.kind == "decode":
        return {}                       # S == 1 at decode
    seq = shape.seq
    dp = pctx.dp_size(mesh)
    b_loc = max(shape.batch // dp, 1)
    h, hd = cfg.n_heads, cfg.d_model // cfg.n_heads
    # per-step: 4 recurrent einsums (B,H,hd)x(H,hd,hd) + ~12 elementwise
    flops_step = b_loc * (4 * h * hd * hd * 2 + 12 * h * hd)
    bytes_step = 4 * h * hd * hd * 4 + b_loc * h * hd * 4 * 10
    mult = n_slstm * (seq - 1)
    if shape.kind == "train":
        mult *= 3                       # fwd + remat-fwd + bwd
    return {"flops": flops_step * mult, "bytes": bytes_step * mult,
            "coll_bytes": 0.0, "coll_count": 0.0}


def corrected_costs(arch: str, shape_name: str, multi_pod: bool,
                    overrides: dict | None = None) -> dict:
    from repro import configs
    from repro.launch import shapes as shp
    from repro.launch.mesh import make_production_mesh

    cfg = configs.get(arch)
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    shape = shp.SHAPES[shape_name]
    mesh_tag = "2x16x16" if multi_pod else "16x16"
    skip = shp.applicable(cfg, shape)
    if skip:
        return {"arch": arch, "shape": shape_name, "mesh": mesh_tag,
                "status": "n/a", "reason": skip}
    mesh = make_production_mesh(multi_pod=multi_pod)
    # Total flops/bytes/collective-bytes are independent of the
    # gradient-accumulation split (same tokens, weight collectives hoisted
    # once per step), verified to <2% on the design points — so counting
    # runs at the production n_mb (unrolled) and the fit is over layer
    # counts only.
    n_mb_real = (shp.MICROBATCH.get(arch, 1) if shape.kind == "train"
                 else 1)

    pts = _design_points(cfg)
    kinds = sorted(_kind_counts(cfg))
    metrics = ["flops", "bytes", "coll_bytes", "coll_count"]

    rows, feats = [], []
    for c_var, counts in pts:
        m = _measure(c_var, shape, mesh, n_mb_real)
        rows.append([m[k] for k in metrics])
        feats.append([1.0] + [float(counts[k]) for k in kinds])
    A = np.asarray(feats)
    Y = np.asarray(rows)
    coef, *_ = np.linalg.lstsq(A, Y, rcond=None)

    # evaluate at production counts
    counts_real = _kind_counts(cfg)
    f = [1.0] + [float(counts_real[k]) for k in kinds]
    pred = np.asarray(f) @ coef
    result = dict(zip(metrics, [float(max(v, 0.0)) for v in pred]))

    extra = _slstm_analytic(cfg, shape, mesh)
    for k, v in extra.items():
        result[k] = result.get(k, 0.0) + v

    out = {"arch": arch, "shape": shape_name, "mesh": mesh_tag,
           "status": "ok", "n_chips": int(mesh.devices.size),
           "overrides": overrides or {},
           "corrected": result,
           "design_points": [dict(zip(metrics, r)) for r in rows]}
    return out


def run_cell(arch, shape_name, multi_pod, verbose=True, overrides=None,
             variant=""):
    mesh_tag = "2x16x16" if multi_pod else "16x16"
    try:
        res = corrected_costs(arch, shape_name, multi_pod, overrides)
    except Exception as e:  # noqa: BLE001
        res = {"arch": arch, "shape": shape_name, "mesh": mesh_tag,
               "status": "error", "error": f"{type(e).__name__}: {e}",
               "traceback": traceback.format_exc()[-4000:]}
    RESULTS.mkdir(parents=True, exist_ok=True)
    suffix = f"__{variant}" if variant else ""
    (RESULTS / f"{arch}__{shape_name}__{mesh_tag}{suffix}.json").write_text(
        json.dumps(res, indent=2))
    if verbose:
        if res["status"] == "ok":
            c = res["corrected"]
            print(f"[ok] {arch} x {shape_name} x {mesh_tag}: "
                  f"flops/chip={c['flops']:.3e} bytes/chip={c['bytes']:.3e}"
                  f" coll/chip={c['coll_bytes']:.3e}")
        else:
            print(f"[{res['status']}] {arch} x {shape_name} x {mesh_tag}: "
                  f"{res.get('reason', res.get('error',''))[:300]}")
    return res


def main(argv=None):
    from repro import configs
    from repro.launch import shapes as shp

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--variant", default="",
                    help="named optimization variant, e.g. tp_attention")
    args = ap.parse_args(argv)
    overrides = {"tp_attention": {"tp_attention": True},
                 "sp_decode": {"sp_decode": True},
                 "gather_once": {"gather_weights_once": True},
                 "dots": {"remat_policy": "dots"},
                 "causal_slice": {"causal_slice": True},
                 "tp_causal": {"tp_attention": True, "causal_slice": True},
                 "tp_causal_dots": {"tp_attention": True,
                                    "causal_slice": True,
                                    "remat_policy": "dots"},
                 "gather_causal": {"gather_weights_once": True,
                                   "causal_slice": True},
                 "tp_causal_gather": {"tp_attention": True,
                                      "causal_slice": True,
                                      "gather_weights_once": True},
                 "": None}[args.variant]
    archs = [args.arch] if args.arch else configs.ARCHS
    shapes = [args.shape] if args.shape else list(shp.SHAPES)
    fails = 0
    for a in archs:
        for s in shapes:
            tag = "2x16x16" if args.multi_pod else "16x16"
            suffix = f"__{args.variant}" if args.variant else ""
            f = RESULTS / f"{a}__{s}__{tag}{suffix}.json"
            if args.skip_existing and f.exists():
                prev = json.loads(f.read_text())
                if prev.get("status") in ("ok", "n/a"):
                    continue
            r = run_cell(a, s, args.multi_pod, overrides=overrides,
                         variant=args.variant)
            fails += r["status"] == "error"
    sys.exit(1 if fails else 0)


if __name__ == "__main__":
    main()
