import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# Multi-pod dry-run: lower + compile every (architecture x input-shape x
# mesh) cell and extract memory / cost / collective statistics.
#
# The two lines above MUST stay first: jax locks the device count on first
# init, and only the dry-run wants 512 placeholder host devices.
#
# Usage:
#   PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-4b --shape train_4k
#   PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--single-pod]
# Results land in results/dryrun/<arch>__<shape>__<mesh>.json.

import argparse
import json
import pathlib
import re
import sys
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import configs
from repro.launch import shapes as shp
from repro.launch.mesh import make_production_mesh
from repro.models import registry
from repro.optim import opt_state_specs
from repro.parallel import ctx as pctx
from repro.parallel import sharding as shd
from repro.serve.step import (build_decode_step, build_prefill_step,
                              cache_shardings, serve_rules)
from repro.train.step import build_train_step, train_state_shardings

RESULTS = pathlib.Path(__file__).resolve().parents[3] / "results" / "dryrun"

_COLL_RE = re.compile(
    r"(\w[\w.\-]*)\s*=\s*([a-z0-9]+)\[([0-9,]*)\][^=]*?"
    r"\b(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
)

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
}


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims.strip():
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def collective_bytes(hlo_text: str) -> dict:
    """Per-chip wire-byte estimate per collective type from optimized HLO.

    Post-SPMD shapes are per-partition.  Ring cost model: all-gather ->
    result bytes; reduce-scatter/all-to-all/permute -> operand(=result)
    bytes; all-reduce -> 2x bytes (RS + AG phases)."""
    out = {"all-gather": 0, "all-reduce": 0, "reduce-scatter": 0,
           "all-to-all": 0, "collective-permute": 0, "count": 0}
    for m in _COLL_RE.finditer(hlo_text):
        _, dtype, dims, kind = m.groups()
        b = _shape_bytes(dtype, dims)
        if kind == "all-reduce":
            b *= 2
        out[kind] += b
        out["count"] += 1
    return out


def _sds_specs_only(tree):
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype), tree)


def lower_cell(arch: str, shape_name: str, multi_pod: bool):
    """Lower+compile one (arch, shape, mesh) cell.  Returns stats dict."""
    cfg = configs.get(arch)
    shape = shp.SHAPES[shape_name]
    skip = shp.applicable(cfg, shape)
    if skip:
        return {"arch": arch, "shape": shape_name,
                "mesh": "2x16x16" if multi_pod else "16x16",
                "status": "n/a", "reason": skip}
    mesh = make_production_mesh(multi_pod=multi_pod)
    ispecs = shp.input_specs(cfg, shape)
    t0 = time.time()
    with pctx.use_mesh(mesh):
        if shape.kind == "train":
            n_mb = shp.MICROBATCH.get(arch, 1)
            step = build_train_step(cfg, n_microbatch=n_mb)
            p_sh, o_sh = train_state_shardings(cfg, mesh)
            p_specs = registry.param_specs(cfg)
            o_specs = opt_state_specs(p_specs)
            b_sh = {k: shd.batch_sharding(mesh, len(v.shape))
                    for k, v in ispecs.items()}
            fn = jax.jit(
                step,
                in_shardings=(p_sh, o_sh, NamedSharding(mesh, P()), b_sh),
                out_shardings=(p_sh, o_sh, None),
                donate_argnums=(0, 1),
            )
            lowered = fn.lower(p_specs, o_specs,
                               jax.ShapeDtypeStruct((), jnp.int32), ispecs)
        elif shape.kind == "prefill":
            step = build_prefill_step(cfg)
            rules = serve_rules(cfg, mesh, shape.batch)
            axes = registry.logical_axes(cfg)
            p_specs = registry.param_specs(cfg)
            p_sh = shd.shardings_from_axes(axes, mesh, rules, p_specs)
            c_sh = cache_shardings(cfg, mesh, shape.batch, shape.seq + 64,
                                   rules)
            b_sh = {k: shd.batch_sharding(mesh, len(v.shape))
                    for k, v in ispecs.items()}
            logits_sh = NamedSharding(mesh, shd.spec_from_axes(
                ("batch", "vocab"), mesh, rules,
                (shape.batch, cfg.vocab)))
            if "frontend_embeds" in ispecs:
                in_sh = (p_sh, b_sh["tokens"], b_sh["frontend_embeds"])
                fn = jax.jit(step, in_shardings=in_sh,
                             out_shardings=(logits_sh, c_sh))
                lowered = fn.lower(p_specs, ispecs["tokens"],
                                   ispecs["frontend_embeds"])
            else:
                fn = jax.jit(step, in_shardings=(p_sh, b_sh["tokens"]),
                             out_shardings=(logits_sh, c_sh))
                lowered = fn.lower(p_specs, ispecs["tokens"])
        else:  # decode
            step = build_decode_step(cfg)
            rules = serve_rules(cfg, mesh, shape.batch)
            axes = registry.logical_axes(cfg)
            p_specs = registry.param_specs(cfg)
            p_sh = shd.shardings_from_axes(axes, mesh, rules, p_specs)
            c_sh = cache_shardings(cfg, mesh, shape.batch, shape.seq, rules)
            tok_sh = NamedSharding(mesh, shd.spec_from_axes(
                ("batch",), mesh, rules, (shape.batch,)))
            logits_sh = NamedSharding(mesh, shd.spec_from_axes(
                ("batch", "vocab"), mesh, rules,
                (shape.batch, cfg.vocab)))
            fn = jax.jit(step, in_shardings=(p_sh, tok_sh, c_sh),
                         out_shardings=(logits_sh, c_sh),
                         donate_argnums=(2,))
            lowered = fn.lower(p_specs, ispecs["token"], ispecs["cache"])
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    hlo = compiled.as_text()
    coll = collective_bytes(hlo)
    n_chips = mesh.devices.size
    result = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "status": "ok",
        "n_chips": int(n_chips),
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "flops_per_chip": float(cost.get("flops", -1.0)),
        "bytes_per_chip": float(cost.get("bytes accessed", -1.0)),
        "collectives": coll,
        "memory": {
            "argument_bytes": int(getattr(mem, "argument_size_in_bytes", 0)),
            "output_bytes": int(getattr(mem, "output_size_in_bytes", 0)),
            "temp_bytes": int(getattr(mem, "temp_size_in_bytes", 0)),
            "alias_bytes": int(getattr(mem, "alias_size_in_bytes", 0)),
            "generated_code_bytes": int(
                getattr(mem, "generated_code_size_in_bytes", 0)),
        },
        "n_params": cfg.n_params(),
    }
    return result


def run_cell(arch: str, shape_name: str, multi_pod: bool, verbose=True):
    mesh_tag = "2x16x16" if multi_pod else "16x16"
    try:
        res = lower_cell(arch, shape_name, multi_pod)
    except Exception as e:  # noqa: BLE001 — record failures as data
        res = {"arch": arch, "shape": shape_name, "mesh": mesh_tag,
               "status": "error", "error": f"{type(e).__name__}: {e}",
               "traceback": traceback.format_exc()[-4000:]}
    RESULTS.mkdir(parents=True, exist_ok=True)
    out = RESULTS / f"{arch}__{shape_name}__{mesh_tag}.json"
    out.write_text(json.dumps(res, indent=2))
    if verbose:
        if res["status"] == "ok":
            m = res["memory"]
            per_dev = (m["argument_bytes"] + m["temp_bytes"]
                       + m["output_bytes"] - m["alias_bytes"])
            print(f"[ok] {arch} x {shape_name} x {mesh_tag}: "
                  f"flops/chip={res['flops_per_chip']:.3e} "
                  f"bytes/chip={res['bytes_per_chip']:.3e} "
                  f"coll={res['collectives']['count']} "
                  f"compile={res['compile_s']:.1f}s")
        else:
            print(f"[{res['status']}] {arch} x {shape_name} x {mesh_tag}: "
                  f"{res.get('reason', res.get('error', ''))}")
    return res


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--single-pod", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args(argv)

    meshes = []
    if args.multi_pod or not args.single_pod:
        meshes.append(True)
    if args.single_pod or not args.multi_pod:
        meshes.append(False)
    meshes = sorted(set(meshes))  # [False, True] or subset

    archs = configs.ARCHS if (args.all or not args.arch) else [args.arch]
    shape_names = (list(shp.SHAPES) if (args.all or not args.shape)
                   else [args.shape])

    failures = 0
    for arch in archs:
        for shape_name in shape_names:
            for mp in meshes:
                tag = "2x16x16" if mp else "16x16"
                out = RESULTS / f"{arch}__{shape_name}__{tag}.json"
                if args.skip_existing and out.exists():
                    prev = json.loads(out.read_text())
                    if prev.get("status") in ("ok", "n/a"):
                        continue
                res = run_cell(arch, shape_name, mp)
                if res["status"] == "error":
                    failures += 1
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
