"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (not a module constant) so importing
this module never touches jax device state.  The dry-run launcher sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import; everything else (tests, benches) sees the single real CPU device.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh(data: int = 1, model: int = 1, pod: int = 0):
    """Small mesh for CPU tests (axis sizes 1 keep collectives trivial)."""
    if pod:
        return jax.make_mesh((pod, data, model), ("pod", "data", "model"))
    return jax.make_mesh((data, model), ("data", "model"))
