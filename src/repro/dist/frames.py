"""Packed binary wire format for the dist engine's per-round hot path.

Every pipe message is one frame: a 1-byte tag followed by the body.
The two per-round messages are fully ``struct``-packed — envelope and
clock records are fixed-size binary fields instead of pickled Python
objects, which is where most of the old per-round coordination cost
went (one pickle per Message/tuple, per round, per worker):

* ``STEP`` (coordinator -> worker): per-host window bounds + replica
  (vtime, state) updates + cross-partition envelope records, coalesced
  into a single message so one round costs one round-trip (the old
  protocol paid two: phase A sync + phase B run).
* ``REPLY`` (worker -> coordinator): progress flags/counters, per-host
  conservative next-event times, exported task-state deltas, and the
  outbox of envelope records.

Cold-path messages (handshake, finalize, reports, errors) ride
``PICKLE`` frames — a tag byte plus a pickled ``(tag, payload)`` pair.

Names never travel on the hot path: workers build bit-identical
replicas of the simulation, so hub/endpoint/task names are interned
into deterministic index tables at build time and records carry u16/u32
indexes.  The coordinator routes envelope records *without decoding
them* — it reads the destination-hub index and the forwarded send
vtime at fixed offsets and relays the record bytes verbatim to the
owning worker.

Message payloads are ``None`` for every built-in workload; a non-None
payload is pickled per record and carried opaquely (flagged by a
sentinel length), so arbitrary payloads still work without putting
pickle on the common path.

All integers are little-endian; vtimes are i64; ``-1`` encodes ``None``
for optional bounds / next-event times.
"""
from __future__ import annotations

import pickle
import struct
from typing import Any, Dict, Iterable, List, Optional, Tuple

from repro.core.vtask import State

TAG_STEP = b"S"
TAG_REPLY = b"R"
TAG_PICKLE = b"P"

#: fixed State <-> wire index mapping (enum declaration order)
STATES: List[State] = list(State)
STATE_IDX: Dict[State, int] = {s: i for i, s in enumerate(STATES)}

_U32 = struct.Struct("<I")
_HOST_VT = struct.Struct("<iq")            # host id, vtime-or--1
_TASK_STATE = struct.Struct("<Iqb")        # task idx, vtime, state idx
#: envelope fixed part: src_hub u16, dst_hub u16, src_ep u32, dst_ep
#: u32, size i64, send_vtime i64, seq i64, sent_at i64, hops i32
_ENV = struct.Struct("<HHIIqqqqi")
_NO_PAYLOAD = 0xFFFFFFFF
#: reply header: flags u8, dispatches u32, wakes u32
_REPLY_HDR = struct.Struct("<BII")
FLAG_UNFINISHED = 1
FLAG_APPLIED = 2
FLAG_LAZY = 4

#: byte offsets of the two fields the coordinator reads while routing
#: (layout: HH hubs, II endpoints, then q size, q send_vtime, ...)
_ENV_DST_HUB_OFF = 2
_ENV_SEND_VT_OFF = 2 + 2 + 4 + 4 + 8


def pack_envelope(src_hub: int, dst_hub: int, src_ep: int, dst_ep: int,
                  size_bytes: int, send_vtime: int, seq: int,
                  sent_at: int, hops: int, payload: Any) -> bytes:
    head = _ENV.pack(src_hub, dst_hub, src_ep, dst_ep, size_bytes,
                     send_vtime, seq, sent_at, hops)
    if payload is None:
        return head + _U32.pack(_NO_PAYLOAD)
    blob = pickle.dumps(payload, protocol=pickle.HIGHEST_PROTOCOL)
    return head + _U32.pack(len(blob)) + blob


def scan_envelope(buf: bytes, off: int) -> Tuple[int, int, int]:
    """Routing-only scan: returns (dst_hub_idx, send_vtime, next_off)
    without decoding the record."""
    (dst_hub,) = struct.unpack_from("<H", buf, off + _ENV_DST_HUB_OFF)
    (send_vt,) = struct.unpack_from("<q", buf, off + _ENV_SEND_VT_OFF)
    end = off + _ENV.size
    (plen,) = _U32.unpack_from(buf, end)
    end += _U32.size
    if plen != _NO_PAYLOAD:
        end += plen
    return dst_hub, send_vt, end


def unpack_envelope(buf: bytes, off: int) -> Tuple[tuple, Any, int]:
    """Full decode (worker side): returns (fixed fields, payload,
    next_off)."""
    fields = _ENV.unpack_from(buf, off)
    end = off + _ENV.size
    (plen,) = _U32.unpack_from(buf, end)
    end += _U32.size
    payload = None
    if plen != _NO_PAYLOAD:
        payload = pickle.loads(buf[end:end + plen])
        end += plen
    return fields, payload, end


def _pack_host_vts(items: Iterable[Tuple[int, Optional[int]]]) -> bytes:
    items = list(items)
    return _U32.pack(len(items)) + b"".join(
        _HOST_VT.pack(h, -1 if v is None else v) for h, v in items)


def _unpack_host_vts(buf: bytes, off: int
                     ) -> Tuple[Dict[int, Optional[int]], int]:
    (n,) = _U32.unpack_from(buf, off)
    off += _U32.size
    out: Dict[int, Optional[int]] = {}
    for _ in range(n):
        h, v = _HOST_VT.unpack_from(buf, off)
        off += _HOST_VT.size
        out[h] = None if v < 0 else v
    return out, off


def _pack_task_states(states: Dict[int, Tuple[int, int]]) -> bytes:
    return _U32.pack(len(states)) + b"".join(
        _TASK_STATE.pack(i, vt, st) for i, (vt, st) in states.items())


def _unpack_task_states(buf: bytes, off: int
                        ) -> Tuple[Dict[int, Tuple[int, int]], int]:
    (n,) = _U32.unpack_from(buf, off)
    off += _U32.size
    out: Dict[int, Tuple[int, int]] = {}
    for _ in range(n):
        i, vt, st = _TASK_STATE.unpack_from(buf, off)
        off += _TASK_STATE.size
        out[i] = (vt, st)
    return out, off


def _pack_envs(records: List[bytes]) -> bytes:
    return _U32.pack(len(records)) + b"".join(records)


def pack_step(bounds: Dict[int, Optional[int]],
              updates: Dict[int, Tuple[int, int]],
              envelopes: List[bytes]) -> bytes:
    return b"".join((TAG_STEP, _pack_host_vts(bounds.items()),
                     _pack_task_states(updates), _pack_envs(envelopes)))


def unpack_step(frame: bytes) -> Tuple[Dict[int, Optional[int]],
                                       Dict[int, Tuple[int, int]],
                                       bytes, int, int]:
    """Returns (bounds, updates, buffer, env_offset, n_envelopes); the
    caller iterates envelope records with :func:`unpack_envelope`."""
    off = 1
    bounds, off = _unpack_host_vts(frame, off)
    updates, off = _unpack_task_states(frame, off)
    (n_env,) = _U32.unpack_from(frame, off)
    return bounds, updates, frame, off + _U32.size, n_env


def pack_reply(*, unfinished: bool, applied: bool, lazy_changed: bool,
               dispatches: int, wakes: int,
               next_times: Dict[int, Optional[int]],
               task_states: Dict[int, Tuple[int, int]],
               envelopes: List[bytes]) -> bytes:
    flags = ((FLAG_UNFINISHED if unfinished else 0)
             | (FLAG_APPLIED if applied else 0)
             | (FLAG_LAZY if lazy_changed else 0))
    return b"".join((TAG_REPLY, _REPLY_HDR.pack(flags, dispatches, wakes),
                     _pack_host_vts(next_times.items()),
                     _pack_task_states(task_states),
                     _pack_envs(envelopes)))


class Reply:
    """Decoded REPLY frame; envelope records stay as opaque byte
    slices (the coordinator only routes them)."""

    __slots__ = ("unfinished", "applied", "lazy_changed", "dispatches",
                 "wakes", "next_times", "task_states", "envelopes")

    def __init__(self, frame: bytes):
        flags, self.dispatches, self.wakes = _REPLY_HDR.unpack_from(
            frame, 1)
        self.unfinished = bool(flags & FLAG_UNFINISHED)
        self.applied = bool(flags & FLAG_APPLIED)
        self.lazy_changed = bool(flags & FLAG_LAZY)
        off = 1 + _REPLY_HDR.size
        self.next_times, off = _unpack_host_vts(frame, off)
        self.task_states, off = _unpack_task_states(frame, off)
        (n_env,) = _U32.unpack_from(frame, off)
        off += _U32.size
        #: (dst_hub_idx, send_vtime, record bytes) per envelope
        self.envelopes: List[Tuple[int, int, bytes]] = []
        for _ in range(n_env):
            dst_hub, send_vt, end = scan_envelope(frame, off)
            self.envelopes.append((dst_hub, send_vt, frame[off:end]))
            off = end


def pack_pickle(tag: str, payload: Any) -> bytes:
    return TAG_PICKLE + pickle.dumps((tag, payload),
                                     protocol=pickle.HIGHEST_PROTOCOL)


def unpack_pickle(frame: bytes) -> Tuple[str, Any]:
    return pickle.loads(frame[1:])
