"""DistCoordinator: multi-process distributed simulation orchestration.

The parent-process control plane of the dist engine.  It forks
``n_workers`` OS processes *before* the facade builds anything (so every
worker derives a bit-identical replica, see ``repro.dist.worker``),
partitions the topology's hosts contiguously across them, and then runs
the same conservative per-link-lookahead clock protocol as
``Orchestrator(mode="async")`` — except that host windows execute in
real parallel processes and the LBTS null-message bounds travel over
pipes instead of shared memory.

Round structure (one "cross-partition sync round" = one A+B pair):

* **Phase A (sync)** — deliver cross-partition message envelopes
  produced last round and broadcast (vtime, state) updates for every
  proxied task; workers reply with per-host conservative next-event
  times and an unfinished flag.
* **Phase B (run)** — the coordinator computes LBTS clock bounds and
  per-host earliest-input times (:func:`repro.core.orchestrator.
  lbts_bounds` / :func:`~repro.core.orchestrator.earliest_input_time`,
  the exact functions the in-process async engine uses) and tells each
  worker to drain its hosts strictly below those bounds.  Workers run
  concurrently and reply with outboxes + progress counters.

Deadlock mirrors the in-process engines: a full round with no
dispatches, wakes, proxy/replica changes, or in-flight messages while
work remains is a wedged simulation — reported as
``SimReport.status == "deadlock"``, not a crash.

Fault containment: workers are daemon processes, every coordinator
receive has a timeout, and shutdown always terminates stragglers — a
hung or crashed worker fails the run fast instead of wedging the
caller (or CI).

Caveat: workers are *forked* (workload closures are not picklable), so
a parent that already started non-fork-safe threads — notably JAX's
internal pools, once any ``repro.models``/kernel module has run — forks
under CPython's multithreading warning.  The workers themselves never
touch JAX (the sim substrate is pure Python + numpy), which is why the
test suite runs dist reliably with JAX loaded; but a worker that does
wedge in an inherited lock is contained by ``timeout`` rather than
prevented.  Keep dist simulations on the modeled/pure-Python side, or
fork before importing the JAX stack.
"""
from __future__ import annotations

import multiprocessing
import time
from typing import Any, Dict, List, Optional

import numpy as np

from repro.core.orchestrator import earliest_input_time, lbts_bounds
from repro.sim.report import SimReport, _jsonable


class DistWorkerError(RuntimeError):
    """A worker crashed, hung past the timeout, or closed its pipe."""


def partition_hosts(n_hosts: int, n_workers: int) -> List[List[int]]:
    """Contiguous near-equal blocks: keeps rack-style topologies (hosts
    grouped contiguously) mostly intra-partition, minimizing
    cross-partition channels."""
    base, extra = divmod(n_hosts, n_workers)
    out, start = [], 0
    for w in range(n_workers):
        size = base + (1 if w < extra else 0)
        out.append(list(range(start, start + size)))
        start += size
    return out


class DistCoordinator:
    def __init__(self, sim, n_workers: int = 2, *,
                 max_rounds: int = 1_000_000, timeout: float = 120.0):
        if n_workers < 1:
            raise ValueError(f"n_workers must be >= 1, got {n_workers}")
        if sim._built:
            raise ValueError(
                "the dist engine forks workers that build their own "
                "replicas; run() it on an unbuilt Simulation")
        self.sim = sim
        self.n_workers = min(n_workers, sim.topology.n_hosts)
        self.partitions = partition_hosts(sim.topology.n_hosts,
                                          self.n_workers)
        self.owner = {h: w for w, hosts in enumerate(self.partitions)
                      for h in hosts}
        self.max_rounds = max_rounds
        self.timeout = timeout
        self.rounds = 0
        self.envelopes_routed = 0
        self._conns: List[Any] = []
        self._procs: List[Any] = []

    # -- worker lifecycle ----------------------------------------------------
    def _spawn(self) -> None:
        from repro.dist.worker import worker_main
        try:
            ctx = multiprocessing.get_context("fork")
        except ValueError as e:          # pragma: no cover - non-POSIX
            raise DistWorkerError(
                "dist engine needs the fork start method (workload "
                "closures are not picklable)") from e
        for w in range(self.n_workers):
            parent, child = ctx.Pipe()
            proc = ctx.Process(
                target=worker_main, name=f"dist-worker-{w}",
                args=(self.sim, w, self.partitions, child), daemon=True)
            proc.start()
            child.close()
            self._conns.append(parent)
            self._procs.append(proc)

    def _shutdown(self) -> None:
        """Every reply the run needs has been received by the time this
        runs (success or failure), so workers are terminated outright —
        a hung worker must never stall the caller's exit path."""
        for conn in self._conns:
            try:
                conn.close()
            except OSError:
                pass
        for proc in self._procs:
            if proc.is_alive():
                proc.terminate()
        for proc in self._procs:
            proc.join(timeout=5.0)
            if proc.is_alive():                 # pragma: no cover
                proc.kill()
                proc.join(timeout=5.0)

    def _send(self, w: int, tag: str, payload: Any) -> None:
        try:
            self._conns[w].send((tag, payload))
        except (BrokenPipeError, OSError) as e:
            raise DistWorkerError(f"dist worker {w} died: {e}") from e

    def _recv(self, w: int, expect: str) -> Any:
        conn = self._conns[w]
        if not conn.poll(self.timeout):
            raise DistWorkerError(
                f"dist worker {w} hung (> {self.timeout}s without a "
                f"{expect!r} reply)")
        try:
            tag, payload = conn.recv()
        except EOFError as e:
            raise DistWorkerError(f"dist worker {w} died mid-run") from e
        if tag == "error":
            raise DistWorkerError(
                f"dist worker {w} failed:\n{payload}")
        if tag != expect:
            raise DistWorkerError(
                f"dist worker {w}: expected {expect!r}, got {tag!r}")
        return payload

    def _broadcast(self, tag: str, payloads: List[Any],
                   expect: str) -> List[Any]:
        """Send to every worker first, then collect — phase execution
        overlaps across worker processes (the actual parallelism)."""
        for w in range(self.n_workers):
            self._send(w, tag, payloads[w])
        return [self._recv(w, expect) for w in range(self.n_workers)]

    # -- the run -------------------------------------------------------------
    def run(self) -> SimReport:
        t0 = time.perf_counter()
        self._spawn()
        try:
            readies = [self._recv(w, "ready")
                       for w in range(self.n_workers)]
            lookahead = readies[0]["lookahead"]
            hub_host = readies[0]["hub_host"]
            status, detail = "ok", ""
            pending: List[List] = [[] for _ in range(self.n_workers)]
            updates: Dict[str, tuple] = {}
            for _ in range(self.max_rounds):
                synced = self._broadcast(
                    "sync",
                    [{"envelopes": pending[w], "updates": updates}
                     for w in range(self.n_workers)],
                    "synced")
                pending = [[] for _ in range(self.n_workers)]
                if not any(s["unfinished"] for s in synced):
                    break
                next_times: Dict[int, Optional[int]] = {}
                for s in synced:
                    next_times.update(s["next_times"])
                lb = lbts_bounds(next_times, lookahead)
                bounds = {h: earliest_input_time(h, lb, lookahead)
                          for h in next_times}
                rans = self._broadcast(
                    "run",
                    [{h: bounds[h] for h in self.partitions[w]}
                     for w in range(self.n_workers)],
                    "ran")
                self.rounds += 1
                progressed = any(s["applied"] for s in synced)
                updates = {}
                for r in rans:
                    progressed = (progressed or r["dispatches"] > 0
                                  or r["wakes"] > 0 or r["lazy_changed"]
                                  or bool(r["outbox"]))
                    updates.update(r["task_states"])
                    for env in r["outbox"]:
                        dst = self.owner[hub_host[env[1]]]
                        pending[dst].append(env)
                        self.envelopes_routed += 1
                if not progressed:
                    status = "deadlock"
                    detail = "distributed simulation wedged"
                    break
            else:
                status = "deadlock"
                detail = (f"dist engine exceeded {self.max_rounds} "
                          f"rounds without finishing")
            reports = self._broadcast(
                "finalize", [None] * self.n_workers, "report")
            wall = time.perf_counter() - t0
            return self._merge(status, detail, wall, reports)
        finally:
            self._shutdown()

    # -- report merging ------------------------------------------------------
    def _merge_progress(self, worker_progress: List[Dict[str, dict]]
                        ) -> Dict[str, Any]:
        """Each worker ran a disjoint subset of programs, so its copies
        of the monotone progress counters are authoritative where it
        executed and zero elsewhere: merge by elementwise maximum, and
        write the merged arrays back into the parent's workload objects
        so ``wl.progress()`` reads post-run, like in-process."""
        for wl in self.sim.workloads:
            mine = wl.progress()
            for wp in worker_progress:
                for key, value in wp.get(wl.name, {}).items():
                    cur = mine.get(key)
                    if isinstance(cur, np.ndarray) and \
                            isinstance(value, np.ndarray):
                        np.maximum(cur, value, out=cur)
                    elif cur is None or (np.isscalar(cur)
                                         and np.isscalar(value)
                                         and value > cur):
                        mine[key] = value
        return {wl.name: _jsonable(wl.progress())
                for wl in self.sim.workloads}

    def _merge(self, status: str, detail: str, wall: float,
               reports: List[Dict[str, Any]]) -> SimReport:
        sim = self.sim
        hosts = sorted((hr for r in reports for hr in r["hosts"]),
                       key=lambda hr: hr.host)
        links: Dict[str, Dict[str, int]] = {}
        for r in reports:
            links.update(r["links"])
        tasks: Dict[str, Dict[str, Any]] = {}
        merged_tasks = {}
        for r in reports:
            merged_tasks.update(r["tasks"])
        for _, prog in sim._programs():    # declaration order, like
            tasks[prog.name] = merged_tasks[prog.name]   # in-process
        return SimReport(
            status=status, mode="dist", n_hosts=sim.topology.n_hosts,
            vtime_ns=max(r["horizon"] for r in reports),
            wall_s=wall,
            messages=sum(r["messages"] for r in reports),
            bytes=sum(r["bytes"] for r in reports),
            sync_rounds=self.rounds,
            proxy_syncs=sum(r["proxy_syncs"] for r in reports),
            cross_host_msgs=sum(st["messages"] for st in links.values()),
            max_proxy_staleness_ns=max(
                r["max_proxy_staleness_ns"] for r in reports),
            max_window_ns=max(r["max_window_ns"] for r in reports),
            hosts=hosts, links=links, tasks=tasks,
            progress=self._merge_progress(
                [r["progress"] for r in reports]),
            scenario=sim.scenario.name, detail=detail,
            n_workers=self.n_workers)


def run_dist(sim, n_workers: int = 2, *, max_rounds: int = 1_000_000,
             timeout: float = 120.0) -> SimReport:
    """Run an unbuilt facade Simulation across ``n_workers`` OS worker
    processes; see :class:`DistCoordinator`."""
    return DistCoordinator(sim, n_workers, max_rounds=max_rounds,
                           timeout=timeout).run()
