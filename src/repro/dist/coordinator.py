"""DistCoordinator: multi-process distributed simulation orchestration.

The parent-process control plane of the dist engine.  It forks
``n_workers`` OS processes *before* the facade builds anything (so every
worker derives a bit-identical replica, see ``repro.dist.worker``),
partitions the topology's hosts contiguously across them, and then runs
the same conservative per-link-lookahead clock protocol as
``Orchestrator(mode="async")`` — except that host windows execute in
real parallel processes and the LBTS clock bounds travel over pipes.

Round structure — one coalesced round-trip per round:

* The coordinator computes LBTS clock bounds and per-host
  earliest-input times with the same :class:`~repro.core.orchestrator.
  LBTSSolver` the in-process async engine uses, from each host's
  last-reported conservative next-event time *capped by the forwarded
  send vtime of any envelope being delivered this round* (a delivered
  message can wake its receiver no earlier than that, so the capped
  bounds are always conservative — see ``repro.dist.worker``).
* One packed binary ``STEP`` frame per worker carries that worker's
  bounds + replica-state deltas + inbound envelope records; the worker
  injects, runs its windows, and answers with one ``REPLY`` frame
  (``repro.dist.frames``).  The old protocol paid two pickled
  round-trips per round (phase A sync + phase B run) — coalescing and
  struct-packing is most of the dist engine's wall-clock win.
* **Adaptive skip**: a worker whose last reply showed no activity is
  not stepped at all while it has no inbound envelopes, no relevant
  replica updates, and unchanged bounds — re-running it would provably
  be a no-op, so its cached clock state is reused.
* **Sole-worker fast path**: with one worker there are no
  cross-partition channels, so the worker free-runs the in-process
  async engine (``run_all``) instead of paying a round-trip per window.

Deadlock mirrors the in-process engines: a full round with no
dispatches, wakes, replica changes, or delivered envelopes while work
remains is a wedged simulation — reported as
``SimReport.status == "deadlock"``, not a crash.

Fault containment: workers are daemon processes, every coordinator
receive has a timeout (``Simulation.run(worker_timeout=...)`` plumbs
straight through to the per-reply ``poll``), and shutdown always
terminates stragglers — a hung or crashed worker fails the run fast
instead of wedging the caller (or CI).

Caveat: workers are *forked* (workload closures are not picklable), so
a parent that already started non-fork-safe threads — notably JAX's
internal pools, once any ``repro.models``/kernel module has run — forks
under CPython's multithreading warning.  The workers themselves never
touch JAX (the sim substrate is pure Python + numpy), which is why the
test suite runs dist reliably with JAX loaded; but a worker that does
wedge in an inherited lock is contained by ``timeout`` rather than
prevented.  Keep dist simulations on the modeled/pure-Python side, or
fork before importing the JAX stack.
"""
from __future__ import annotations

import multiprocessing
import time
from typing import Any, Dict, List, Optional, Set, Tuple

import numpy as np

from repro.core.orchestrator import LBTSSolver
from repro.dist import frames
from repro.sim.live import merge_live_sections
from repro.sim.report import SimReport, _jsonable


class DistWorkerError(RuntimeError):
    """A worker crashed, hung past the timeout, or closed its pipe.

    ``worker`` is the failing worker's index when known;
    ``worker_traceback`` carries the worker-side traceback text for
    error-frame failures (a crash inside the replica), so callers —
    the fault-campaign harness in particular — can capture *why* a
    point crashed without parsing the message."""

    def __init__(self, message: str, *, worker: int = -1,
                 worker_traceback: str = ""):
        super().__init__(message)
        self.worker = worker
        self.worker_traceback = worker_traceback


def partition_hosts(n_hosts: int, n_workers: int) -> List[List[int]]:
    """Contiguous near-equal blocks: keeps rack-style topologies (hosts
    grouped contiguously) mostly intra-partition, minimizing
    cross-partition channels."""
    base, extra = divmod(n_hosts, n_workers)
    out, start = [], 0
    for w in range(n_workers):
        size = base + (1 if w < extra else 0)
        out.append(list(range(start, start + size)))
        start += size
    return out


class DistCoordinator:
    def __init__(self, sim, n_workers: int = 2, *,
                 max_rounds: int = 1_000_000, timeout: float = 120.0):
        if n_workers < 1:
            raise ValueError(f"n_workers must be >= 1, got {n_workers}")
        if sim._built:
            raise ValueError(
                "the dist engine forks workers that build their own "
                "replicas; run() it on an unbuilt Simulation")
        self.sim = sim
        self.n_workers = min(n_workers, sim.topology.n_hosts)
        self.partitions = partition_hosts(sim.topology.n_hosts,
                                          self.n_workers)
        self.owner = {h: w for w, hosts in enumerate(self.partitions)
                      for h in hosts}
        self.max_rounds = max_rounds
        self.timeout = timeout
        self.rounds = 0
        self.envelopes_routed = 0
        self.worker_skips = 0        # adaptive skips of idle workers
        self.membership_epochs = 0   # epoch flips (joins activated)
        self._conns: List[Any] = []
        self._procs: List[Any] = []

    # -- worker lifecycle ----------------------------------------------------
    def _spawn(self) -> None:
        from repro.dist.worker import worker_main
        try:
            ctx = multiprocessing.get_context("fork")
        except ValueError as e:          # pragma: no cover - non-POSIX
            raise DistWorkerError(
                "dist engine needs the fork start method (workload "
                "closures are not picklable)") from e
        for w in range(self.n_workers):
            parent, child = ctx.Pipe()
            proc = ctx.Process(
                target=worker_main, name=f"dist-worker-{w}",
                args=(self.sim, w, self.partitions, child), daemon=True)
            proc.start()
            child.close()
            self._conns.append(parent)
            self._procs.append(proc)

    def _shutdown(self) -> None:
        """Every reply the run needs has been received by the time this
        runs (success or failure), so workers are terminated outright —
        a hung worker must never stall the caller's exit path."""
        for conn in self._conns:
            try:
                conn.close()
            except OSError:
                pass
        for proc in self._procs:
            if proc.is_alive():
                proc.terminate()
        for proc in self._procs:
            proc.join(timeout=5.0)
            if proc.is_alive():                 # pragma: no cover
                proc.kill()
                proc.join(timeout=5.0)

    def _send(self, w: int, frame: bytes) -> None:
        try:
            self._conns[w].send_bytes(frame)
        except (BrokenPipeError, OSError) as e:
            raise DistWorkerError(f"dist worker {w} died: {e}") from e

    def _recv(self, w: int, expect) -> Any:
        """Receive one frame (timeout-guarded).  ``expect`` is a pickle
        sub-tag or ``"reply"`` for the binary REPLY frame; a tuple of
        sub-tags returns ``(sub_tag, payload)`` instead."""
        conn = self._conns[w]
        if not conn.poll(self.timeout):
            raise DistWorkerError(
                f"dist worker {w} hung (> {self.timeout}s without a "
                f"{expect!r} reply)")
        try:
            frame = conn.recv_bytes()
        except EOFError as e:
            raise DistWorkerError(f"dist worker {w} died mid-run",
                                  worker=w) from e
        tag = frame[:1]
        if tag == frames.TAG_PICKLE:
            sub, payload = frames.unpack_pickle(frame)
            if sub == "error":
                raise DistWorkerError(
                    f"dist worker {w} failed:\n{payload}",
                    worker=w, worker_traceback=str(payload))
            if isinstance(expect, tuple):
                if sub in expect:
                    return sub, payload
            elif sub == expect:
                return payload
            raise DistWorkerError(
                f"dist worker {w}: expected {expect!r}, got {sub!r}")
        if tag == frames.TAG_REPLY and expect == "reply":
            return frames.Reply(frame)
        raise DistWorkerError(
            f"dist worker {w}: expected {expect!r}, got frame {tag!r}")

    # -- the run -------------------------------------------------------------
    def run(self) -> SimReport:
        t0 = time.perf_counter()
        # the parent sim never builds (workers build their own
        # replicas), so clear run-scoped workload state here: the
        # parent's progress arrays are merge *targets* (_merge_progress
        # max-merges into them), and stale values from a previous run
        # of the same Workload instance would double-count.  Resetting
        # before the fork also hands every worker a clean replica.
        for wl in self.sim.workloads:
            wl.reset()
        self._spawn()
        try:
            readies = [self._recv(w, "ready")
                       for w in range(self.n_workers)]
            if self.n_workers == 1:
                status, detail, info = self._run_sole_worker()
            else:
                status, detail, info = self._run_rounds(readies)
            for w in range(self.n_workers):
                self._send(w, frames.pack_pickle("finalize", None))
            reports = [self._recv(w, "report")
                       for w in range(self.n_workers)]
            wall = time.perf_counter() - t0
            return self._merge(status, detail, wall, reports, info)
        finally:
            self._shutdown()

    def _run_sole_worker(self) -> Tuple[str, str, dict]:
        """One worker owns every host: no cross-partition channels, so
        it free-runs the async engine.  The worker heartbeats a "tick"
        every bounded chunk of rounds, so ``timeout`` stays a per-reply
        liveness bound — a long healthy run keeps ticking, a hung
        worker still fails fast."""
        self._send(0, frames.pack_pickle("run_all", self.max_rounds))
        while True:
            msg = self._recv(0, ("tick", "ran_all"))
            if msg[0] == "ran_all":
                ran = msg[1]
                self.rounds = ran["rounds"]
                return ran["status"], ran["detail"], ran.get("info", {})

    def _run_rounds(self, readies: List[Dict[str, Any]]
                    ) -> Tuple[str, str, dict]:
        # wire tables are identical across workers (bit-identical
        # replicas): take worker 0's
        lookahead = readies[0]["lookahead"]
        hub_names = readies[0]["hub_names"]
        hub_host = readies[0]["hub_host"]
        task_names = readies[0]["task_names"]
        task_idx = {n: i for i, n in enumerate(task_names)}
        hub_idx_host = [hub_host[n] for n in hub_names]
        interests: List[Set[int]] = [
            {task_idx[n] for n in r["imports"]} for r in readies]
        next_times: Dict[int, Optional[int]] = {}
        unfinished: List[bool] = []
        for r in readies:
            next_times.update(r["next_times"])
            unfinished.append(r["unfinished"])
        # membership epochs, mirroring Orchestrator._run_async: joiners
        # (join vtime > 0, identical in every replica) stay out of the
        # LBTS closure — and every active bound is clamped at the
        # earliest pending join vtime — until the active set provably
        # cannot act below it; then the closure re-solves over the grown
        # graph.  Joiners keep their build-time partition owner, so no
        # repartitioning message traffic is needed at a flip.
        join_vtime: Dict[int, int] = readies[0].get("join_vtime") or {
            h: 0 for h in next_times}
        active = sorted(h for h, t in join_vtime.items() if t <= 0)
        pending_joins = sorted(
            (t, h) for h, t in join_vtime.items() if t > 0)
        self.membership_epochs = 0

        def _epoch_solver() -> LBTSSolver:
            member = set(active)
            return LBTSSolver(
                {e: la for e, la in lookahead.items()
                 if e[0] in member and e[1] in member}, active)

        def _flip_or_wedge() -> bool:
            """A round made no progress: if a join is still pending,
            the epoch flip *is* the progress (mirrors the in-process
            engine's no-progress flip); otherwise the simulation is
            truly wedged."""
            if not pending_joins:
                return False
            t0 = pending_joins[0][0]
            while pending_joins and pending_joins[0][0] == t0:
                active.append(pending_joins.pop(0)[1])
            active.sort()
            self.membership_epochs += 1
            return True

        solver = _epoch_solver()
        W = range(self.n_workers)
        pending: List[List[bytes]] = [[] for _ in W]
        caps: Dict[int, int] = {}   # host -> min in-flight send vtime
        updates: Dict[int, Tuple[int, int]] = {}
        last_bounds: List[Optional[Dict[int, Optional[int]]]] = \
            [None for _ in W]
        idle = [False for _ in W]
        for _ in range(self.max_rounds):
            if not any(unfinished) and not any(pending):
                # note the pending check: a message can still be in
                # flight after every task finished (e.g. a send to a
                # task that died without receiving) — it must be
                # delivered and replayed anyway or message/byte totals
                # and link stats diverge from the in-process engines
                return "ok", "", {}
            eff_next = dict(next_times)
            for h, cap in caps.items():
                cur = eff_next[h]
                eff_next[h] = cap if cur is None else min(cur, cap)
            while pending_joins:
                # flip condition uses the envelope-capped next times: an
                # in-flight message below the join vtime may still
                # enable active-set progress there
                gmin = min((t for t in (eff_next[h] for h in active)
                            if t is not None), default=None)
                if gmin is not None and gmin < pending_joins[0][0]:
                    break
                t0 = pending_joins[0][0]
                while pending_joins and pending_joins[0][0] == t0:
                    active.append(pending_joins.pop(0)[1])
                active.sort()
                solver = _epoch_solver()
                self.membership_epochs += 1
            clamp = pending_joins[0][0] if pending_joins else None
            lb = solver.bounds(eff_next)
            bounds = {}
            for h in next_times:
                if h in join_vtime and join_vtime[h] > 0 \
                        and h not in solver._idx:
                    # pending joiner: nothing of it exists below its
                    # join vtime, so this bound is a provable no-op
                    bounds[h] = join_vtime[h]
                    continue
                b = solver.eit(h, lb)
                if clamp is not None:
                    b = clamp if b is None else min(b, clamp)
                bounds[h] = b
            stepped: List[int] = []
            delivered = False
            for w in W:
                wb = {h: bounds[h] for h in self.partitions[w]}
                w_up = {i: v for i, v in updates.items()
                        if i in interests[w]}
                if (idle[w] and not pending[w] and not w_up
                        and wb == last_bounds[w]):
                    # provably a no-op round for this worker: no new
                    # inputs and an unchanged window
                    self.worker_skips += 1
                    continue
                delivered = delivered or bool(pending[w])
                self._send(w, frames.pack_step(wb, w_up, pending[w]))
                pending[w] = []
                last_bounds[w] = wb
                stepped.append(w)
            if not stepped:
                if _flip_or_wedge():
                    solver = _epoch_solver()
                    continue
                return ("deadlock", "distributed simulation wedged",
                        self._wedge_info(unfinished, pending_joins))
            self.rounds += 1
            updates = {}
            caps = {}
            progressed = delivered
            for w in stepped:
                r = self._recv(w, "reply")
                unfinished[w] = r.unfinished
                worked = bool(r.applied or r.dispatches or r.wakes
                              or r.lazy_changed or r.envelopes)
                idle[w] = not worked
                progressed = progressed or worked
                next_times.update(r.next_times)
                updates.update(r.task_states)
                for dst_hub, send_vt, record in r.envelopes:
                    host = hub_idx_host[dst_hub]
                    pending[self.owner[host]].append(record)
                    prev = caps.get(host)
                    caps[host] = (send_vt if prev is None
                                  else min(prev, send_vt))
                    self.envelopes_routed += 1
            if not progressed:
                if _flip_or_wedge():
                    solver = _epoch_solver()
                    continue
                return ("deadlock", "distributed simulation wedged",
                        self._wedge_info(unfinished, pending_joins))
        return ("deadlock", (f"dist engine exceeded {self.max_rounds} "
                             f"rounds without finishing"),
                self._wedge_info(unfinished, pending_joins))

    def _wedge_info(self, unfinished: List[bool],
                    pending_joins: List[Tuple[int, int]]) -> dict:
        """Structured deadlock detail (``SimReport.detail_info``):
        hosts of the workers still holding unfinished work, plus any
        joins that never activated."""
        return {
            "kind": "wedged",
            "wedged_hosts": sorted(
                h for w, unf in enumerate(unfinished) if unf
                for h in self.partitions[w]),
            "pending_joins": [{"host": h, "vtime": t}
                              for t, h in pending_joins],
        }

    # -- report merging ------------------------------------------------------
    def _merge_progress(self, worker_progress: List[Dict[str, dict]]
                        ) -> Dict[str, Any]:
        """Each worker ran a disjoint subset of programs, so its copies
        of the monotone progress counters are authoritative where it
        executed and zero elsewhere: merge by elementwise maximum, and
        write the merged arrays back into the parent's workload objects
        so ``wl.progress()`` reads post-run, like in-process."""
        for wl in self.sim.workloads:
            mine = wl.progress()
            for wp in worker_progress:
                for key, value in wp.get(wl.name, {}).items():
                    cur = mine.get(key)
                    if isinstance(cur, np.ndarray) and \
                            isinstance(value, np.ndarray):
                        np.maximum(cur, value, out=cur)
                    elif cur is None or (np.isscalar(cur)
                                         and np.isscalar(value)
                                         and value > cur):
                        mine[key] = value
        return {wl.name: _jsonable(wl.progress())
                for wl in self.sim.workloads}

    def _merge(self, status: str, detail: str, wall: float,
               reports: List[Dict[str, Any]],
               detail_info: Optional[dict] = None) -> SimReport:
        sim = self.sim
        hosts = sorted((hr for r in reports for hr in r["hosts"]),
                       key=lambda hr: hr.host)
        links: Dict[str, Dict[str, int]] = {}
        for r in reports:
            links.update(r["links"])
        tasks: Dict[str, Dict[str, Any]] = {}
        merged_tasks = {}
        for r in reports:
            merged_tasks.update(r["tasks"])
        for _, prog in sim._programs():    # declaration order, like
            tasks[prog.name] = merged_tasks[prog.name]   # in-process
        cells: Dict[str, Any] = {}
        for r in reports:                  # per-host, owner-disjoint
            cells.update(r["cells"])
        cells = {h: cells[h] for h in sorted(cells, key=int)}
        # control-plane timeline: workload sections come from the one
        # worker owning the controller task (first non-empty wins, like
        # live); the membership timeline is build-time data identical
        # across replicas, so worker 0's copy is authoritative
        control: Dict[str, Any] = {}
        for r in reports:
            for wl_name, sec in r.get("control", {}).items():
                control.setdefault(wl_name, sec)
        membership = next((r["membership"] for r in reports
                           if r.get("membership")), [])
        if membership:
            control["membership"] = membership
        elif control:
            control["membership"] = []
        return SimReport(
            status=status, mode="dist", n_hosts=sim.topology.n_hosts,
            vtime_ns=max(r["horizon"] for r in reports),
            wall_s=wall,
            messages=sum(r["messages"] for r in reports),
            bytes=sum(r["bytes"] for r in reports),
            sync_rounds=self.rounds,
            proxy_syncs=sum(r["proxy_syncs"] for r in reports),
            cross_host_msgs=sum(st["messages"] for st in links.values()),
            max_proxy_staleness_ns=max(
                r["max_proxy_staleness_ns"] for r in reports),
            max_window_ns=max(r["max_window_ns"] for r in reports),
            hosts=hosts, links=links, tasks=tasks,
            progress=self._merge_progress(
                [r["progress"] for r in reports]),
            scenario=sim.scenario.name, detail=detail,
            n_workers=self.n_workers, cells=cells,
            live=merge_live_sections([r.get("live", {})
                                      for r in reports]),
            control=control, detail_info=dict(detail_info or {}))


def run_dist(sim, n_workers: int = 2, *, max_rounds: int = 1_000_000,
             timeout: float = 120.0) -> SimReport:
    """Run an unbuilt facade Simulation across ``n_workers`` OS worker
    processes; see :class:`DistCoordinator`."""
    return DistCoordinator(sim, n_workers, max_rounds=max_rounds,
                           timeout=timeout).run()
