"""`repro.dist` — multi-process distributed simulation orchestration.

The fourth LiveStack subsystem at real OS-process scale: a
:class:`~repro.dist.coordinator.DistCoordinator` shards a facade
:class:`~repro.sim.simulation.Simulation` across ``n_workers`` forked
worker processes (each running its own
:class:`~repro.core.scheduler.Scheduler` per owned host) and extends
the async engine's per-link-lookahead LBTS protocol across process
boundaries over pipes.  Results are bit-identical to the in-process
``barrier``/``async`` engines (enforced by ``tests/engine_harness.py``).

Entry point::

    report = Simulation(topology, workloads, scenario).run(
        engine="dist", n_workers=4)

``python -m repro.dist`` runs a 2-worker smoke (used by CI).
"""
from repro.dist.coordinator import (DistCoordinator, DistWorkerError,
                                    partition_hosts, run_dist)

__all__ = ["DistCoordinator", "DistWorkerError", "partition_hosts",
           "run_dist"]
