"""Distributed-engine smoke: a small heterogeneous rack topology run
in-process (async) and across 2 OS worker processes (dist), asserting
bit-identical task outcomes.  CI runs this as the dist smoke step:

    PYTHONPATH=src python -m repro.dist
"""
from __future__ import annotations

import argparse
import sys


def smoke(n_workers: int = 2, n_iters: int = 60) -> int:
    from repro.sim import RackRing, Scenario, Simulation, Topology

    def make():
        wl = RackRing(n_racks=2, hosts_per_rack=2, n_iters=n_iters,
                      skew_bound_ns=2_000_000)
        return Simulation(
            Topology.racks(2, 2), wl,
            Scenario("imbalanced racks", wl.stragglers((1.0, 3.0))),
            placement=wl.default_placement())

    inproc = make().run(engine="async", on_deadlock="raise")
    dist = make().run(engine="dist", n_workers=n_workers,
                      worker_timeout=60.0, on_deadlock="raise")
    assert dist.tasks == inproc.tasks, \
        (dist.tasks, inproc.tasks)
    assert dist.messages == inproc.messages
    assert dist.vtime_ns == inproc.vtime_ns
    print(f"dist smoke ok: {dist.n_hosts} hosts / {dist.n_workers} "
          f"workers, {dist.sync_rounds} cross-partition sync rounds, "
          f"{dist.cross_host_msgs} cross-host msgs, "
          f"sim={dist.vtime_ns / 1e6:.2f} ms, "
          f"wall={dist.wall_s * 1e3:.0f} ms — bit-identical to async "
          f"({inproc.sync_rounds} rounds, "
          f"wall={inproc.wall_s * 1e3:.0f} ms)")
    return 0


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--workers", type=int, default=2)
    ap.add_argument("--iters", type=int, default=60)
    args = ap.parse_args()
    sys.exit(smoke(args.workers, args.iters))
