"""Worker side of the multi-process dist engine.

Each OS worker process builds the *full* declarative
:class:`~repro.sim.simulation.Simulation` (fork happens before build, so
every worker derives a bit-identical replica of all hosts, hubs, tasks,
scopes, and injection wiring) but *executes* only the schedulers of its
own host partition.  Everything outside the partition is a passive
replica used for three things:

* **Message replay** — a cross-partition message is serialized on the
  sender's hub (channel queuing + lookahead, exactly as in-process),
  shipped over the pipe as a packed binary envelope record
  (``repro.dist.frames``), and replayed through ``dest_hub.route()`` on
  the owner, which computes the same visibility time the in-process
  engines would (per-channel ``busy_until`` only ever sees traffic from
  one sender, and pipes are FIFO, so replay order matches).
* **Proxy refresh** — :class:`~repro.core.orchestrator.ProxyVTask`
  mirrors keep pointing at the local replica of the remote task; the
  coordinator broadcasts (vtime, state) *deltas* for proxied tasks, the
  worker applies them to the replicas, and the existing lazy
  pin-bound sync then works unchanged.
* **Accounting replay** — per-link visibility-slack stats for a
  cross-partition channel are computed on the destination owner
  (against its replica of the sender hub) and merged by the
  coordinator.

Because replicas are bit-identical, all name tables (hubs, endpoints,
tasks) are derived deterministically at build time and the wire carries
only integer indexes — see ``repro.dist.frames``.

One coordinator round = one ``STEP`` -> ``REPLY`` exchange: the worker
injects envelopes, applies replica updates, runs one conservative
window per owned host (skipping hosts that are provably quiescent below
their bound), and replies with its outbox + clock state.  A worker that
owns *every* host (``n_workers == 1``) instead receives one
``run_all`` and free-runs the in-process async engine to completion —
no cross-partition channels exist, so there is nothing to mediate.

Safety: the coordinator computes a window's bounds from each host's
last-reported conservative next-event time, *capped* by the forwarded
send vtime of any envelope being delivered in the same STEP (a
delivered message can wake a receiver no earlier than that).  Bounds
are therefore always conservative, a message produced inside round
``r`` has visibility ``>= lb[sender] + lookahead >= EIT(receiver)``,
and the schedulers' strict window gate never consumes anything at or
past the receiver's bound — so delivering cross-partition messages one
round later is invisible to the simulation, which is what makes the
dist engine bit-identical to ``async``/``barrier``.
"""
from __future__ import annotations

import traceback
from typing import Any, Dict, List, Optional, Tuple

from repro.core.ipc import Message
from repro.core.scheduler import DeadlockError
from repro.dist import frames
from repro.sim.report import HostReport

#: legacy in-process envelope: (src_hub, dst_hub, Message, send vtime)
Envelope = Tuple[str, str, Any, int]


class RemotePeer:
    """Stand-in for a peered hub owned by another worker.  Quacks just
    enough like a Hub for ``Hub.route``'s forwarding branch: name,
    endpoint membership (the local replica's), and ``forward`` instead
    of ``route``."""

    is_remote = True

    def __init__(self, replica_hub, outbox: List[Envelope]):
        self.name = replica_hub.name
        self.endpoints = replica_hub.endpoints
        self._outbox = outbox

    def forward(self, src_hub: str, msg, sent_at: int):
        self._outbox.append((src_hub, self.name, msg, sent_at))
        return msg


class DistWorker:
    def __init__(self, sim, worker_id: int,
                 partitions: List[List[int]]):
        self.sim = sim
        self.id = worker_id
        self.owned = sorted(partitions[worker_id])
        self.owner = {h: w for w, hosts in enumerate(partitions)
                      for h in hosts}
        self.outbox: List[Envelope] = []
        # dist replicas are wired exactly like the async engine; the
        # coordinator (not Orchestrator.run) drives the clock protocol.
        sim.mode = "async"
        sim.build()
        self.orch = sim.orchestrator
        self.hub_host = {hub.name: h for h, hub in self.orch.hubs.items()}
        self.hubs_by_name = {hub.name: hub
                             for hub in self.orch.hubs.values()}
        self.lookahead = self.orch.lookahead_map()
        # deterministic wire index tables — identical in every worker
        # (and in the coordinator, which receives them at handshake)
        # because all replicas build bit-identically.
        self.hub_names = sorted(self.hubs_by_name)
        self.hub_idx = {n: i for i, n in enumerate(self.hub_names)}
        self.ep_names = sorted({ep for hub in self.hubs_by_name.values()
                                for ep in hub.endpoints})
        self.ep_idx = {n: i for i, n in enumerate(self.ep_names)}
        self.task_names = [t.name for t in sim.tasks]
        self.task_idx = {n: i for i, n in enumerate(self.task_names)}
        self.task_by_idx = list(sim.tasks)
        # swap cross-partition peers of *owned* hubs for RemotePeer
        # stubs; replica hubs of other partitions never send.
        for h in self.owned:
            hub = self.orch.hubs.get(h)
            if hub is None:
                continue
            for pname in list(hub.peers):
                if self.owner[self.hub_host[pname]] != self.id:
                    hub.peers[pname] = RemotePeer(
                        self.hubs_by_name[pname], self.outbox)
        self.tasks_by_name = {
            t.name: t for sched in self.orch.hosts.values()
            for t in sched.tasks if t.kind != "proxy"}
        # owned tasks some other partition mirrors through a proxy: their
        # (vtime, state) deltas are exported to the coordinator every
        # round; replicas start bit-identical, so only changes travel.
        self.exports = sorted({
            p.remote.name for p in self.orch.proxies
            if self.owner[p.remote.host] == self.id
            and self.owner[p.host] != self.id})
        self._last_export: Dict[str, Tuple[int, int]] = {
            n: self._task_wire_state(n) for n in self.exports}
        # remote tasks mirrored by a proxy on one of *our* hosts: the
        # coordinator uses this interest set to skip broadcasting
        # irrelevant updates (and to skip this worker entirely when a
        # round carries nothing for it).
        self.imports = sorted({
            p.remote.name for p in self.orch.proxies
            if self.owner[p.host] == self.id
            and self.owner[p.remote.host] != self.id})

    def _task_wire_state(self, name: str) -> Tuple[int, int]:
        t = self.tasks_by_name[name]
        return (t.vtime, frames.STATE_IDX[t.state])

    # -- protocol phases -----------------------------------------------------
    def handshake(self) -> Dict[str, Any]:
        return {"hosts": self.owned,
                "lookahead": self.lookahead,
                "hub_host": self.hub_host,
                "hub_names": self.hub_names,
                "task_names": self.task_names,
                "exports": self.exports,
                "imports": self.imports,
                "next_times": self.next_times(),
                "unfinished": self.unfinished(),
                # membership timeline (identical in every replica): the
                # coordinator mirrors the async engine's epoch-scoped
                # LBTS clamps from this
                "join_vtime": dict(self.orch.join_vtime)}

    def inject(self, frame: bytes, off: int, n_env: int) -> None:
        """Replay cross-partition envelope records on the owned
        destination hub (visibility computation identical to the
        in-process route) and mirror the sender-side per-link accounting
        on our replica of the sender hub."""
        for _ in range(n_env):
            fields, payload, off = frames.unpack_envelope(frame, off)
            (src_hub_i, dst_hub_i, src_ep_i, dst_ep_i, size_bytes,
             send_vtime, seq, sent_at, hops) = fields
            msg = Message(src=self.ep_names[src_ep_i],
                          dst=self.ep_names[dst_ep_i],
                          size_bytes=size_bytes, send_vtime=send_vtime,
                          payload=payload, seq=seq, hops=hops)
            src_name = self.hub_names[src_hub_i]
            dst_name = self.hub_names[dst_hub_i]
            routed = self.hubs_by_name[dst_name].route(msg)
            src_hub = self.hubs_by_name[src_name]
            link = src_hub.peer_links.get(dst_name, src_hub.peer_link)
            src_hub._account_peer(dst_name, routed, sent_at, link)

    def apply_updates(self, updates: Dict[int, Tuple[int, int]]) -> bool:
        """Refresh replicas of remote tasks from the coordinator's
        broadcast deltas; proxies pick the new values up at the next
        lazy sync.  Returns True iff anything changed (progress
        signal)."""
        changed = False
        for idx, (vtime, state_i) in updates.items():
            task = self.task_by_idx[idx]
            if self.owner[task.host] == self.id:
                continue
            state = frames.STATES[state_i]
            if task.vtime != vtime or task.state is not state:
                task.vtime = vtime
                task.state = state
                changed = True
        return changed

    def next_times(self) -> Dict[int, Optional[int]]:
        return {h: self.orch.hosts[h].next_time() for h in self.owned}

    def unfinished(self) -> bool:
        return any(self.orch.hosts[h].has_unfinished()
                   for h in self.owned)

    def _pack_outbox(self) -> List[bytes]:
        records = [frames.pack_envelope(
            self.hub_idx[src], self.hub_idx[dst],
            self.ep_idx[msg.src], self.ep_idx[msg.dst],
            msg.size_bytes, msg.send_vtime, msg.seq, sent_at, msg.hops,
            msg.payload) for src, dst, msg, sent_at in self.outbox]
        # drain in place: the RemotePeer stubs hold a reference to this
        # exact list, so rebinding would silently disconnect them.
        self.outbox.clear()
        return records

    def _export_deltas(self) -> Dict[int, Tuple[int, int]]:
        out: Dict[int, Tuple[int, int]] = {}
        for n in self.exports:
            cur = self._task_wire_state(n)
            if cur != self._last_export[n]:
                self._last_export[n] = cur
                out[self.task_idx[n]] = cur
        return out

    def step(self, frame: bytes) -> bytes:
        """One coalesced coordinator round: inject + apply + run one
        conservative window per owned host, mirroring one host iteration
        of ``Orchestrator._run_async`` (including the quiescent-host
        skip), and reply with outbox + clock state."""
        bounds, updates, buf, off, n_env = frames.unpack_step(frame)
        self.inject(buf, off, n_env)
        applied = self.apply_updates(updates)
        stats = self.orch.stats
        d0 = sum(self.orch.hosts[h].stats.dispatches for h in self.owned)
        w0 = sum(self.orch.hosts[h].stats.wakes for h in self.owned)
        lazy_changed = False
        for h in self.owned:
            sched = self.orch.hosts[h]
            bound = bounds.get(h)
            if self.orch._lazy_sync(h, bound):
                lazy_changed = True
            elif sched.quiescent_below(bound):
                stats["quiescent_skips"] += 1
                continue
            if bound is not None:
                start = sched.next_time()
                if start is not None and bound > start:
                    stats["max_window_ns"] = max(
                        stats["max_window_ns"], bound - start)
            sched.run_until(bound)
        return frames.pack_reply(
            unfinished=self.unfinished(), applied=applied,
            lazy_changed=lazy_changed,
            dispatches=sum(self.orch.hosts[h].stats.dispatches
                           for h in self.owned) - d0,
            wakes=sum(self.orch.hosts[h].stats.wakes
                      for h in self.owned) - w0,
            next_times=self.next_times(),
            task_states=self._export_deltas(),
            envelopes=self._pack_outbox())

    #: sole-worker heartbeat cadence: free-run this many engine rounds
    #: between ticks so the coordinator's per-reply timeout stays a
    #: liveness bound, not a cap on total run length
    RUN_ALL_CHUNK = 20_000

    def run_all(self, max_rounds: int, tick) -> Dict[str, Any]:
        """Sole-worker fast path: this worker owns every host, so there
        are no cross-partition channels, no proxies to refresh remotely,
        and nothing for the coordinator to mediate — free-run the
        in-process async engine instead of paying one pipe round-trip
        per conservative window.  Runs in bounded chunks, calling
        ``tick()`` between chunks to heartbeat the coordinator."""
        status, detail, info = "ok", "", {}
        remaining = max_rounds
        try:
            while True:
                chunk = min(self.RUN_ALL_CHUNK, remaining)
                if self.orch._run_async(chunk, raise_on_exhaust=False):
                    break
                remaining -= chunk
                if remaining <= 0:
                    status = "deadlock"
                    detail = (f"dist engine exceeded {max_rounds} "
                              f"rounds without finishing")
                    break
                tick()
        except DeadlockError as e:
            status, detail, info = "deadlock", str(e), e.info
        return {"status": status, "detail": detail, "info": info,
                "rounds": self.orch.stats["epochs"]}

    def final_report(self) -> Dict[str, Any]:
        orch = self.orch
        self.orch._note_staleness()
        owned_hubs = [orch.hubs[h] for h in self.owned if h in orch.hubs]
        links = {}
        for hub in self.hubs_by_name.values():
            for peer, st in hub.peer_stats.items():
                if self.owner[self.hub_host[peer]] == self.id:
                    links[f"{hub.name}->{peer}"] = dict(st)
        staleness = max((p.max_staleness_ns
                         for h in self.owned
                         for p in orch._host_proxies.get(h, ())),
                        default=0)
        # §3.3 cell state is per host and only the owner executed these
        # hosts, so each worker's snapshots are authoritative and
        # disjoint — the coordinator merges them by host key.
        cells = {}
        for h in self.owned:
            snap = orch.hosts[h].cells.snapshot()
            if snap is not None:
                cells[str(h)] = snap
        # live sections: per-task entries restricted to owned tasks (the
        # owner executed them; the rest is deterministic build-time data
        # the coordinator dedups)
        owned_tasks = {t.name for t in self.sim.tasks
                       if self.owner[t.host] == self.id}
        live = {}
        for wl in self.sim.workloads:
            sec = wl.live_report(owned_tasks)
            if sec is not None:
                live[wl.name] = sec
        # control-plane sections: controller state lives on exactly one
        # host (the facade co-locates source/LB/controller), so only the
        # owner of the controller task reports a non-None section and
        # the coordinator's first-non-empty merge is authoritative
        control = {}
        for wl in self.sim.workloads:
            fn = getattr(wl, "control_report", None)
            sec = fn(owned_tasks) if fn is not None else None
            if sec is not None:
                control[wl.name] = sec
        return {
            "cells": cells,
            "live": live,
            "control": control,
            "membership": self.orch.membership_timeline(),
            "hosts": [HostReport.from_sched(h, orch.hosts[h].stats)
                      for h in self.owned],
            "messages": sum(h.stats["messages"] for h in owned_hubs),
            "bytes": sum(h.stats["bytes"] for h in owned_hubs),
            "links": links,
            "tasks": {t.name: {"vtime": t.vtime, "state": t.state.value,
                               "host": t.host}
                      for t in self.sim.tasks
                      if self.owner[t.host] == self.id},
            "progress": {wl.name: dict(wl.progress())
                         for wl in self.sim.workloads},
            "horizon": max((t.vtime for h in self.owned
                            for t in orch.hosts[h].tasks
                            if t.kind != "proxy"), default=0),
            "proxy_syncs": orch.stats["proxy_syncs"],
            "max_proxy_staleness_ns": staleness,
            "max_window_ns": orch.stats["max_window_ns"],
        }


def worker_main(sim, worker_id: int, partitions: List[List[int]],
                conn) -> None:
    """Process entry point: build, handshake, then serve coordinator
    frames until ``finalize``.  Any exception is shipped back as an
    ``("error", traceback)`` pickle frame so the coordinator fails fast
    instead of hanging on a dead pipe."""
    try:
        worker = DistWorker(sim, worker_id, partitions)
        conn.send_bytes(frames.pack_pickle("ready", worker.handshake()))
        while True:
            frame = conn.recv_bytes()
            tag = frame[:1]
            if tag == frames.TAG_STEP:
                conn.send_bytes(worker.step(frame))
            elif tag == frames.TAG_PICKLE:
                sub, payload = frames.unpack_pickle(frame)
                if sub == "run_all":
                    def tick():
                        conn.send_bytes(frames.pack_pickle("tick", None))
                    conn.send_bytes(frames.pack_pickle(
                        "ran_all", worker.run_all(payload, tick)))
                elif sub == "finalize":
                    conn.send_bytes(frames.pack_pickle(
                        "report", worker.final_report()))
                    return
                else:
                    raise ValueError(
                        f"unknown coordinator message {sub!r}")
            else:
                raise ValueError(f"unknown frame tag {tag!r}")
    except (EOFError, KeyboardInterrupt):
        return
    except BaseException:
        try:
            conn.send_bytes(frames.pack_pickle(
                "error", traceback.format_exc()))
        except (BrokenPipeError, OSError):
            pass
