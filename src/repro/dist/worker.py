"""Worker side of the multi-process dist engine.

Each OS worker process builds the *full* declarative
:class:`~repro.sim.simulation.Simulation` (fork happens before build, so
every worker derives a bit-identical replica of all hosts, hubs, tasks,
scopes, and injection wiring) but *executes* only the schedulers of its
own host partition.  Everything outside the partition is a passive
replica used for three things:

* **Message replay** — a cross-partition message is serialized on the
  sender's hub (channel queuing + lookahead, exactly as in-process),
  shipped over the pipe, and replayed through ``dest_hub.route()`` on
  the owner, which computes the same visibility time the in-process
  engines would (per-channel ``busy_until`` only ever sees traffic from
  one sender, and pipes are FIFO, so replay order matches).
* **Proxy refresh** — :class:`~repro.core.orchestrator.ProxyVTask`
  mirrors keep pointing at the local replica of the remote task; the
  coordinator broadcasts (vtime, state) updates for proxied tasks, the
  worker applies them to the replicas, and the existing lazy
  pin-bound sync then works unchanged.
* **Accounting replay** — per-link visibility-slack stats for a
  cross-partition channel are computed on the destination owner
  (against its replica of the sender hub) and merged by the
  coordinator.

Safety: a message produced inside round ``r`` has visibility
``>= lb[sender] + lookahead >= EIT(receiver)``, and the schedulers'
strict window gate never consumes anything at or past the receiver's
EIT bound — so delivering cross-partition messages one round later is
invisible to the simulation, which is what makes the dist engine
bit-identical to ``async``/``barrier``.
"""
from __future__ import annotations

import traceback
from typing import Any, Dict, List, Optional, Tuple

from repro.core.vtask import State
from repro.sim.report import HostReport

#: (src_hub_name, dst_hub_name, Message, original send vtime)
Envelope = Tuple[str, str, Any, int]


class RemotePeer:
    """Stand-in for a peered hub owned by another worker.  Quacks just
    enough like a Hub for ``Hub.route``'s forwarding branch: name,
    endpoint membership (the local replica's), and ``forward`` instead
    of ``route``."""

    is_remote = True

    def __init__(self, replica_hub, outbox: List[Envelope]):
        self.name = replica_hub.name
        self.endpoints = replica_hub.endpoints
        self._outbox = outbox

    def forward(self, src_hub: str, msg, sent_at: int):
        self._outbox.append((src_hub, self.name, msg, sent_at))
        return msg


class DistWorker:
    def __init__(self, sim, worker_id: int,
                 partitions: List[List[int]]):
        self.sim = sim
        self.id = worker_id
        self.owned = sorted(partitions[worker_id])
        self.owner = {h: w for w, hosts in enumerate(partitions)
                      for h in hosts}
        self.outbox: List[Envelope] = []
        # dist replicas are wired exactly like the async engine; the
        # coordinator (not Orchestrator.run) drives the clock protocol.
        sim.mode = "async"
        sim.build()
        self.orch = sim.orchestrator
        self.hub_host = {hub.name: h for h, hub in self.orch.hubs.items()}
        self.hubs_by_name = {hub.name: hub
                             for hub in self.orch.hubs.values()}
        self.lookahead = self.orch.lookahead_map()
        # swap cross-partition peers of *owned* hubs for RemotePeer
        # stubs; replica hubs of other partitions never send.
        for h in self.owned:
            hub = self.orch.hubs.get(h)
            if hub is None:
                continue
            for pname in list(hub.peers):
                if self.owner[self.hub_host[pname]] != self.id:
                    hub.peers[pname] = RemotePeer(
                        self.hubs_by_name[pname], self.outbox)
        self.tasks_by_name = {
            t.name: t for sched in self.orch.hosts.values()
            for t in sched.tasks if t.kind != "proxy"}
        # owned tasks some other partition mirrors through a proxy: their
        # (vtime, state) is exported to the coordinator every run phase.
        self.exports = sorted({
            p.remote.name for p in self.orch.proxies
            if self.owner[p.remote.host] == self.id
            and self.owner[p.host] != self.id})

    # -- protocol phases -----------------------------------------------------
    def handshake(self) -> Dict[str, Any]:
        return {"hosts": self.owned,
                "lookahead": self.lookahead,
                "hub_host": self.hub_host,
                "exports": self.exports}

    def inject(self, envelopes: List[Envelope]) -> None:
        """Replay cross-partition messages on the owned destination hub
        (visibility computation identical to the in-process route) and
        mirror the sender-side per-link accounting on our replica of
        the sender hub."""
        for src_name, dst_name, msg, sent_at in envelopes:
            routed = self.hubs_by_name[dst_name].route(msg)
            src_hub = self.hubs_by_name[src_name]
            link = src_hub.peer_links.get(dst_name, src_hub.peer_link)
            src_hub._account_peer(dst_name, routed, sent_at, link)

    def apply_updates(self, updates: Dict[str, Tuple[int, str]]) -> bool:
        """Refresh replicas of remote tasks from the coordinator's
        broadcast; proxies pick the new values up at the next lazy
        sync.  Returns True iff anything changed (progress signal)."""
        changed = False
        for name, (vtime, state) in updates.items():
            task = self.tasks_by_name.get(name)
            if task is None or self.owner[task.host] == self.id:
                continue
            if task.vtime != vtime or task.state.value != state:
                task.vtime = vtime
                task.state = State(state)
                changed = True
        return changed

    def next_times(self) -> Dict[int, Optional[int]]:
        return {h: self.orch.hosts[h].next_time() for h in self.owned}

    def unfinished(self) -> bool:
        return any(t.state in (State.RUNNABLE, State.BLOCKED)
                   for h in self.owned
                   for t in self.orch.hosts[h].tasks
                   if t.kind != "proxy")

    def run_window(self, bounds: Dict[int, Optional[int]]
                   ) -> Dict[str, Any]:
        """One conservative window per owned host (lazy proxy sync +
        ``run_until`` below the coordinator-computed EIT), mirroring one
        host iteration of ``Orchestrator._run_async``."""
        stats = self.orch.stats
        d0 = sum(self.orch.hosts[h].stats.dispatches for h in self.owned)
        w0 = sum(self.orch.hosts[h].stats.wakes for h in self.owned)
        lazy_changed = False
        for h in self.owned:
            sched = self.orch.hosts[h]
            bound = bounds.get(h)
            if self.orch._lazy_sync(h, bound):
                lazy_changed = True
            if bound is not None:
                start = sched.next_time()
                if start is not None and bound > start:
                    stats["max_window_ns"] = max(
                        stats["max_window_ns"], bound - start)
            sched.run_until(bound)
        # drain in place: the RemotePeer stubs hold a reference to this
        # exact list, so rebinding would silently disconnect them.
        out = list(self.outbox)
        self.outbox.clear()
        return {
            "outbox": out,
            "task_states": {n: (self.tasks_by_name[n].vtime,
                                self.tasks_by_name[n].state.value)
                            for n in self.exports},
            "dispatches": sum(self.orch.hosts[h].stats.dispatches
                              for h in self.owned) - d0,
            "wakes": sum(self.orch.hosts[h].stats.wakes
                         for h in self.owned) - w0,
            "lazy_changed": lazy_changed,
        }

    def final_report(self) -> Dict[str, Any]:
        orch = self.orch
        self.orch._note_staleness()
        owned_hubs = [orch.hubs[h] for h in self.owned if h in orch.hubs]
        links = {}
        for hub in self.hubs_by_name.values():
            for peer, st in hub.peer_stats.items():
                if self.owner[self.hub_host[peer]] == self.id:
                    links[f"{hub.name}->{peer}"] = dict(st)
        staleness = max((p.max_staleness_ns
                         for h in self.owned
                         for p in orch._host_proxies.get(h, ())),
                        default=0)
        return {
            "hosts": [HostReport.from_sched(h, orch.hosts[h].stats)
                      for h in self.owned],
            "messages": sum(h.stats["messages"] for h in owned_hubs),
            "bytes": sum(h.stats["bytes"] for h in owned_hubs),
            "links": links,
            "tasks": {t.name: {"vtime": t.vtime, "state": t.state.value,
                               "host": t.host}
                      for t in self.sim.tasks
                      if self.owner[t.host] == self.id},
            "progress": {wl.name: dict(wl.progress())
                         for wl in self.sim.workloads},
            "horizon": max((t.vtime for h in self.owned
                            for t in orch.hosts[h].tasks
                            if t.kind != "proxy"), default=0),
            "proxy_syncs": orch.stats["proxy_syncs"],
            "max_proxy_staleness_ns": staleness,
            "max_window_ns": orch.stats["max_window_ns"],
        }


def worker_main(sim, worker_id: int, partitions: List[List[int]],
                conn) -> None:
    """Process entry point: build, handshake, then serve coordinator
    phases until ``finalize``.  Any exception is shipped back as an
    ``("error", traceback)`` message so the coordinator fails fast
    instead of hanging on a dead pipe."""
    try:
        worker = DistWorker(sim, worker_id, partitions)
        conn.send(("ready", worker.handshake()))
        while True:
            tag, payload = conn.recv()
            if tag == "sync":
                worker.inject(payload["envelopes"])
                applied = worker.apply_updates(payload["updates"])
                conn.send(("synced", {
                    "next_times": worker.next_times(),
                    "unfinished": worker.unfinished(),
                    "applied": applied,
                }))
            elif tag == "run":
                conn.send(("ran", worker.run_window(payload)))
            elif tag == "finalize":
                conn.send(("report", worker.final_report()))
                return
            else:
                raise ValueError(f"unknown coordinator message {tag!r}")
    except (EOFError, KeyboardInterrupt):
        return
    except BaseException:
        try:
            conn.send(("error", traceback.format_exc()))
        except (BrokenPipeError, OSError):
            pass
