from repro.runtime.trainer import Trainer, TrainerConfig
from repro.runtime.failures import FailureInjector, SimulatedHostFailure
