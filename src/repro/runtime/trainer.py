"""Fault-tolerant training runtime.

Wires together: model zoo + sharded train step + synthetic data +
AdamW (+ optional int8 gradient compression w/ error feedback) +
checkpoint manager (async, atomic) + failure injection (restart from
last commit, elastic re-mesh) + straggler monitor.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.checkpoint import CheckpointManager
from repro.data import SyntheticLMData
from repro.launch import shapes as shp
from repro.models import registry
from repro.models.common import ModelConfig
from repro.optim import AdamWConfig, adamw_init
from repro.optim.compress import compress_grads, ef_init
from repro.parallel import ctx as pctx
from repro.parallel import sharding as shd
from repro.runtime.failures import (FailureInjector, SimulatedHostFailure,
                                    StragglerMonitor)
from repro.train.step import build_train_step, train_state_shardings


@dataclasses.dataclass
class TrainerConfig:
    n_steps: int = 100
    seq_len: int = 128
    global_batch: int = 8
    n_microbatch: int = 1
    checkpoint_every: int = 20
    checkpoint_dir: str = "/tmp/repro_ckpt"
    checkpoint_async: bool = True
    keep_checkpoints: int = 3
    compress_grads: bool = False
    log_every: int = 10
    peak_lr: float = 3e-4
    warmup: int = 20
    seed: int = 0


class Trainer:
    def __init__(self, cfg: ModelConfig, tcfg: TrainerConfig, mesh=None,
                 injector: Optional[FailureInjector] = None,
                 log_fn: Callable[[str], None] = print):
        self.cfg = cfg
        self.tcfg = tcfg
        self.mesh = mesh
        self.injector = injector or FailureInjector()
        self.monitor = StragglerMonitor()
        self.log = log_fn
        self.ckpt = CheckpointManager(tcfg.checkpoint_dir,
                                      keep=tcfg.keep_checkpoints)
        self.data = SyntheticLMData(
            vocab=cfg.vocab, seq_len=tcfg.seq_len,
            global_batch=tcfg.global_batch, seed=tcfg.seed,
            frontend_dim=cfg.frontend_dim,
            frontend_tokens=shp.frontend_tokens(cfg, tcfg.seq_len))
        self.history: list = []
        self.restarts = 0
        self._build()

    # -- build/jit ------------------------------------------------------------
    def _build(self) -> None:
        tcfg = self.tcfg
        step_fn = build_train_step(
            self.cfg, n_microbatch=tcfg.n_microbatch,
            lr_kwargs=dict(peak_lr=tcfg.peak_lr, warmup=tcfg.warmup,
                           total=tcfg.n_steps))
        if tcfg.compress_grads:
            step_fn = self._with_compression(step_fn)
        if self.mesh is not None:
            p_sh, o_sh = train_state_shardings(self.cfg, self.mesh)
            if tcfg.compress_grads:
                o_sh = dict(o_sh, ef=p_sh)
            rep = NamedSharding(self.mesh, P())
            b_sh = shd.batch_sharding(self.mesh, 2)
            in_sh = (p_sh, o_sh, rep, None)
            self.step = jax.jit(step_fn, in_shardings=in_sh,
                                out_shardings=(p_sh, o_sh, None),
                                donate_argnums=(0, 1))
            self.p_sh, self.o_sh = p_sh, o_sh
        else:
            self.step = jax.jit(step_fn, donate_argnums=(0, 1))
            self.p_sh = self.o_sh = None

    def _with_compression(self, step_fn):
        cfg = self.cfg
        tcfg = self.tcfg
        from repro.models.common import softmax_cross_entropy
        from repro.optim import adamw_update, lr_schedule
        from repro.train.step import _loss_fn

        def step(params, opt_state, step_idx, batch):
            ef = opt_state["ef"]
            inner = {k: v for k, v in opt_state.items() if k != "ef"}

            def loss(p):
                fe = batch.get("frontend_embeds")
                l, ce = _loss_fn(cfg, p, batch["tokens"], batch["labels"],
                                 fe)
                return l, ce

            (_, ce), grads = jax.value_and_grad(loss, has_aux=True)(params)
            grads, ef = compress_grads(grads, ef)
            lr = lr_schedule(step_idx, peak_lr=tcfg.peak_lr,
                             warmup=tcfg.warmup, total=tcfg.n_steps)
            params, inner, om = adamw_update(AdamWConfig(), grads, params,
                                             inner, lr)
            return params, dict(inner, ef=ef), {"loss": ce, **om}

        return step

    # -- state ------------------------------------------------------------------
    def init_state(self):
        key = jax.random.PRNGKey(self.tcfg.seed)
        params = registry.init(self.cfg, key)
        opt = adamw_init(params)
        if self.tcfg.compress_grads:
            opt = dict(opt, ef=ef_init(params))
        if self.mesh is not None:
            params = jax.device_put(params, self.p_sh)
            opt = jax.device_put(opt, self.o_sh)
        return params, opt

    # -- loop --------------------------------------------------------------------
    def run(self) -> Dict:
        params, opt = self.init_state()
        start = 0
        ctx = (pctx.use_mesh(self.mesh) if self.mesh is not None
               else _null_ctx())
        with ctx:
            step = start
            while step < self.tcfg.n_steps:
                try:
                    params, opt, step = self._run_span(params, opt, step)
                except SimulatedHostFailure as e:
                    self.log(f"[trainer] {e}; elastic restart")
                    self.restarts += 1
                    params, opt, step = self._recover()
        self.ckpt.wait()
        return {"history": self.history, "restarts": self.restarts,
                "stragglers": self.monitor.stragglers,
                "final_step": step}

    def _run_span(self, params, opt, start):
        for step in range(start, self.tcfg.n_steps):
            self.injector.check(step)
            batch = self.data.batch(step)
            t0 = time.perf_counter()
            params, opt, metrics = self.step(
                params, opt, jnp.int32(step), batch)
            loss = float(metrics["loss"])
            wall = time.perf_counter() - t0
            if self.monitor.record(step, wall):
                self.log(f"[trainer] straggler step {step}: {wall:.3f}s")
            self.history.append({"step": step, "loss": loss,
                                 "wall_s": wall})
            if step % self.tcfg.log_every == 0:
                self.log(f"[trainer] step {step} loss {loss:.4f} "
                         f"({wall*1e3:.0f} ms)")
            if (step + 1) % self.tcfg.checkpoint_every == 0:
                self.ckpt.save({"params": params, "opt": opt}, step + 1,
                               blocking=not self.tcfg.checkpoint_async)
        return params, opt, self.tcfg.n_steps

    def _recover(self):
        """Elastic restart: rebuild state on the (possibly new) mesh and
        resume from the last committed checkpoint."""
        like = {"params": registry.param_specs(self.cfg), "opt": None}
        params0, opt0 = self.init_state()          # fresh buffers/shardings
        like = {"params": params0, "opt": opt0}
        shardings = ({"params": self.p_sh, "opt": self.o_sh}
                     if self.mesh is not None else None)
        try:
            state, step, _ = self.ckpt.restore_latest(like, shardings)
        except FileNotFoundError:
            self.log("[trainer] no checkpoint yet; restart from scratch")
            return params0, opt0, 0
        return state["params"], state["opt"], step


class _null_ctx:
    def __enter__(self):
        return None

    def __exit__(self, *a):
        return False
