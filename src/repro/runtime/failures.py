"""Failure injection + straggler detection for the training runtime.

``FailureInjector`` raises ``SimulatedHostFailure`` at configured steps —
the trainer treats it exactly as a real host loss: abandon in-flight
state, rebuild the mesh (possibly smaller — elastic), restore the last
committed checkpoint, and resume from its step (the data pipeline is
step-indexed, so the stream continues exactly).

``StragglerMonitor`` tracks per-step wall times; steps above
``threshold x rolling median`` are flagged (on real fleets this feeds
backup-task dispatch; here it feeds the LiveStack cluster simulation,
which models the backup-dispatch policy under virtual time).
"""
from __future__ import annotations

import dataclasses
import statistics
import time
from typing import Dict, List, Optional, Set


class SimulatedHostFailure(RuntimeError):
    def __init__(self, step: int, host: int = 0):
        super().__init__(f"simulated failure of host {host} at step {step}")
        self.step = step
        self.host = host


@dataclasses.dataclass
class FailureInjector:
    fail_at_steps: Set[int] = dataclasses.field(default_factory=set)
    fired: Set[int] = dataclasses.field(default_factory=set)

    def check(self, step: int) -> None:
        if step in self.fail_at_steps and step not in self.fired:
            self.fired.add(step)
            raise SimulatedHostFailure(step)


class StragglerMonitor:
    def __init__(self, threshold: float = 2.0, window: int = 20):
        self.threshold = threshold
        self.window = window
        self.times: List[float] = []
        self.stragglers: List[int] = []

    def record(self, step: int, wall_s: float) -> bool:
        self.times.append(wall_s)
        hist = self.times[-self.window:]
        if len(hist) >= 5:
            med = statistics.median(hist)
            if wall_s > self.threshold * med:
                self.stragglers.append(step)
                return True
        return False
