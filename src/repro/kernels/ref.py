"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
import numpy as np

NEG_INF = -1e30


# -- flash attention -----------------------------------------------------------


def attention_flat_ref(q, k, v, *, causal=True, window=0):
    """q (BH, Sq, hd); k/v (BHkv, Sk, hd) — exact softmax attention."""
    bh, sq, hd = q.shape
    bhkv, sk, _ = k.shape
    qpk = bh // bhkv
    k = jnp.repeat(k, qpk, axis=0)
    v = jnp.repeat(v, qpk, axis=0)
    scale = 1.0 / math.sqrt(hd)
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    qpos = jnp.arange(sq)[:, None]
    kpos = jnp.arange(sk)[None, :]
    mask = jnp.ones((sq, sk), bool)
    if causal:
        mask &= kpos <= qpos
    if window > 0:
        mask &= kpos > qpos - window
    s = jnp.where(mask[None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p, v.astype(jnp.float32)
                      ).astype(q.dtype)


# -- decode attention ----------------------------------------------------------


def decode_attention_ref(q, k_cache, v_cache, lengths):
    """q (B, H, hd); caches (B, S, Hkv, hd); lengths (B,) valid prefixes."""
    b, h, hd = q.shape
    _, s, hkv, _ = k_cache.shape
    qpk = h // hkv
    k = jnp.repeat(k_cache, qpk, axis=2)             # (B, S, H, hd)
    v = jnp.repeat(v_cache, qpk, axis=2)
    scale = 1.0 / math.sqrt(hd)
    sc = jnp.einsum("bhd,bshd->bhs", q.astype(jnp.float32),
                    k.astype(jnp.float32)) * scale
    valid = jnp.arange(s)[None, None, :] < lengths[:, None, None]
    sc = jnp.where(valid, sc, NEG_INF)
    p = jax.nn.softmax(sc, axis=-1)
    return jnp.einsum("bhs,bshd->bhd", p, v.astype(jnp.float32)
                      ).astype(q.dtype)


# -- RG-LRU linear recurrence ---------------------------------------------------


def rglru_ref(log_a, b, h0=None):
    """h_t = exp(log_a_t) * h_{t-1} + b_t over axis 1.  (B, S, W) fp32."""
    def step(h, xs):
        la, bt = xs
        h = jnp.exp(la) * h + bt
        return h, h

    B, S, W = log_a.shape
    h0 = jnp.zeros((B, W), jnp.float32) if h0 is None else h0
    _, hs = jax.lax.scan(step, h0, (log_a.swapaxes(0, 1),
                                    b.swapaxes(0, 1)))
    return hs.swapaxes(0, 1)


# -- mLSTM chunkwise ------------------------------------------------------------
# (oracle = the step-recurrent form in repro.models.xlstm.mlstm_step)


def mlstm_seq_ref(q, k, v, i_raw, f_raw, c0, n0, i_cap=8.0):
    """Sequential stabilized-gate mLSTM; q,k,v (B,S,H,hd)."""
    from repro.models.xlstm import mlstm_step

    def step(carry, xs):
        c, n = carry
        qt, kt, vt, it, ft = xs
        h, (c, n) = mlstm_step(qt, kt, vt, it, ft, c, n)
        return (c, n), h

    xs = tuple(x.swapaxes(0, 1) for x in (q, k, v, i_raw, f_raw))
    (cf, nf), hs = jax.lax.scan(step, (c0, n0), xs)
    return hs.swapaxes(0, 1), (cf, nf)


# -- minskew (scheduler hot spot) -----------------------------------------------


def minskew_ref(vtime, runnable, membership, skew):
    """Scope minima + eligibility mask — numpy oracle."""
    vtime = np.asarray(vtime)
    runnable = np.asarray(runnable)
    membership = np.asarray(membership)
    skew = np.asarray(skew)
    n, s = membership.shape
    INF = np.int32(2**30)
    minima = np.full(s, INF, np.int32)
    for j in range(s):
        members = runnable & membership[:, j]
        if members.any():
            minima[j] = vtime[members].min()
    elig = runnable.copy()
    for i in range(n):
        for j in range(s):
            if membership[i, j] and minima[j] != INF:
                if vtime[i] > minima[j] + skew[j]:
                    elig[i] = False
    return minima, elig


# -- hub_route -------------------------------------------------------------------
# oracle lives in repro.core.engine_jax.hub_visibility_ref
