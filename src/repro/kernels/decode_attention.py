"""Flash-decoding Pallas TPU kernel: one new token vs. a long KV cache.

Decode attention is memory-bound: the whole KV cache streams HBM->VMEM
once per step.  The kernel tiles the cache sequence dimension (grid dim
``arbitrary``) with online-softmax scratch, processing all q heads of one
batch element per grid row so each KV tile is read ONCE for the whole
GQA head group (kv reuse = q_per_kv — the roofline win vs. naive).

Layouts: q (B, H, hd); k/v caches (B, S, Hkv, hd); per-batch valid
``lengths`` mask ragged caches.  Block: (block_s x hd) KV tiles, fp32
accumulation (H x hd) in VMEM.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat import tpu_compiler_params

NEG_INF = -1e30


def _kernel(len_ref, q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
            scale, block_s, ns, q_per_kv):
    i = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    length = len_ref[i]
    s_first = j * block_s

    @pl.when(s_first < length)
    def _compute():
        q = q_ref[0].astype(jnp.float32) * scale       # (H, hd)
        k = k_ref[0].astype(jnp.float32)               # (bs, Hkv, hd)
        v = v_ref[0].astype(jnp.float32)
        h, hd = q.shape
        bs, hkv, _ = k.shape
        # scores: q head hq attends kv head hq // q_per_kv
        qg = q.reshape(hkv, q_per_kv, hd)
        s = jnp.einsum("ghd,sgd->ghs", qg, k,
                       preferred_element_type=jnp.float32)  # (Hkv,qpk,bs)
        s = s.reshape(h, bs)
        kpos = s_first + jax.lax.broadcasted_iota(jnp.int32, (h, bs), 1)
        mask = kpos < length
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[...]                            # (H,)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        corr = jnp.exp(m_prev - m_new)
        p = jnp.where(mask, jnp.exp(s - m_new[:, None]), 0.0)  # (H, bs)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1)
        m_ref[...] = m_new
        pg = p.reshape(hkv, q_per_kv, bs)
        pv = jnp.einsum("gqs,sgd->gqd", pg, v,
                        preferred_element_type=jnp.float32)
        acc_ref[...] = acc_ref[...] * corr[:, None] + pv.reshape(h, hd)

    @pl.when(j == ns - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def decode_attention(q, k_cache, v_cache, lengths, *, block_s=512,
                     interpret=False):
    """q (B, H, hd); k/v (B, S, Hkv, hd); lengths (B,) int32."""
    b, h, hd = q.shape
    _, s, hkv, _ = k_cache.shape
    q_per_kv = h // hkv
    scale = 1.0 / math.sqrt(hd)
    block_s = min(block_s, s)
    s_pad = pl.cdiv(s, block_s) * block_s
    if s_pad != s:
        pad = ((0, 0), (0, s_pad - s), (0, 0), (0, 0))
        k_cache = jnp.pad(k_cache, pad)
        v_cache = jnp.pad(v_cache, pad)
    ns = s_pad // block_s

    kernel = functools.partial(_kernel, scale=scale, block_s=block_s,
                               ns=ns, q_per_kv=q_per_kv)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(b, ns),
        in_specs=[
            pl.BlockSpec((1, h, hd), lambda i, j, lens: (i, 0, 0)),
            pl.BlockSpec((1, block_s, hkv, hd),
                         lambda i, j, lens: (i, j, 0, 0)),
            pl.BlockSpec((1, block_s, hkv, hd),
                         lambda i, j, lens: (i, j, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, h, hd), lambda i, j, lens: (i, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((h, hd), jnp.float32),
            pltpu.VMEM((h,), jnp.float32),
            pltpu.VMEM((h,), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, h, hd), q.dtype),
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(lengths, q, k_cache, v_cache)
