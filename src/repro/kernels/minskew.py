"""LiveStack scheduler hot spot as a Pallas TPU kernel.

Per dispatch round the scheduler computes (paper §3.2):
  1. scope minima: min vtime over runnable members of each scope,
  2. eligibility:  vtask runnable AND vtime <= min + skew in EVERY scope.

At cluster scale (10^4..10^5 vtasks x 10^2..10^3 scopes) this is the
per-round bottleneck — a masked segmented-min plus a masked all-reduce
over the scope axis.  The kernel tiles the (N x S) membership matrix into
VMEM blocks: grid (n_blocks, s_blocks) with the scope-min pass
accumulating into a VMEM scratch row per scope block, then a second
fused pass producing the per-vtask eligibility conjunction.

Layout notes: vtimes are int32 ticks (see engine_jax); membership is a
dense int8 mask (bitpacking is a further 8x but int8 keeps the VPU mask
ops trivial); tiles are (8..512, 128)-aligned for the (8,128) VREG shape.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat import tpu_compiler_params

INF = 2**30  # python int: jnp scalars would be captured as consts


def _minima_kernel(vtime_ref, runnable_ref, member_ref, min_ref):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        min_ref[...] = jnp.full_like(min_ref, INF)

    v = vtime_ref[...]                       # (bn,)
    r = runnable_ref[...] != 0               # (bn,)
    m = member_ref[...] != 0                 # (bn, bs)
    vm = jnp.where(r[:, None] & m, v[:, None], INF)
    min_ref[...] = jnp.minimum(min_ref[...], jnp.min(vm, axis=0))


def _elig_kernel(vtime_ref, runnable_ref, member_ref, skew_ref, minima_ref,
                 elig_ref, ok_ref, *, ns):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        ok_ref[...] = jnp.ones_like(ok_ref)

    v = vtime_ref[...]
    m = member_ref[...] != 0
    mins = minima_ref[...]
    skew = skew_ref[...]
    ok_scope = (v[:, None] <= mins[None, :] + skew[None, :])
    ok_scope |= ~m | (mins == INF)[None, :]
    ok_ref[...] &= jnp.all(ok_scope, axis=1).astype(jnp.int8)

    @pl.when(j == ns - 1)
    def _finalize():
        elig_ref[...] = ok_ref[...] & runnable_ref[...]


def minskew(vtime, runnable, membership, skew, *, block_n=512,
            block_s=128, interpret=False):
    """Returns (scope minima (S,), eligibility (N,) int8).

    vtime (N,) int32; runnable (N,) int8; membership (N, S) int8;
    skew (S,) int32."""
    n, s = membership.shape
    block_n = min(block_n, max(8, n))
    block_s = min(block_s, max(8, s))
    n_pad = pl.cdiv(n, block_n) * block_n
    s_pad = pl.cdiv(s, block_s) * block_s
    vtime = jnp.pad(vtime, (0, n_pad - n), constant_values=INF)
    runnable = jnp.pad(runnable, (0, n_pad - n))
    membership = jnp.pad(membership, ((0, n_pad - n), (0, s_pad - s)))
    skew = jnp.pad(skew, (0, s_pad - s))
    nb, sb = n_pad // block_n, s_pad // block_s

    minima = pl.pallas_call(
        _minima_kernel,
        grid=(nb, sb),
        in_specs=[
            pl.BlockSpec((block_n,), lambda i, j: (i,)),
            pl.BlockSpec((block_n,), lambda i, j: (i,)),
            pl.BlockSpec((block_n, block_s), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((block_s,), lambda i, j: (j,)),
        out_shape=jax.ShapeDtypeStruct((s_pad,), jnp.int32),
        compiler_params=tpu_compiler_params(
            dimension_semantics=("arbitrary", "parallel")),
        interpret=interpret,
    )(vtime, runnable, membership)

    elig = pl.pallas_call(
        functools.partial(_elig_kernel, ns=sb),
        grid=(nb, sb),
        in_specs=[
            pl.BlockSpec((block_n,), lambda i, j: (i,)),
            pl.BlockSpec((block_n,), lambda i, j: (i,)),
            pl.BlockSpec((block_n, block_s), lambda i, j: (i, j)),
            pl.BlockSpec((block_s,), lambda i, j: (j,)),
            pl.BlockSpec((block_s,), lambda i, j: (j,)),
        ],
        out_specs=pl.BlockSpec((block_n,), lambda i, j: (i,)),
        out_shape=jax.ShapeDtypeStruct((n_pad,), jnp.int8),
        scratch_shapes=[pltpu.VMEM((block_n,), jnp.int8)],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(vtime, runnable, membership, skew, minima)

    return minima[:s], elig[:n]
