"""jit'd public wrappers for the Pallas kernels.

On TPU the kernels lower natively; on CPU they run in interpret mode
(used by the test-suite oracles) or fall back to the pure-jnp reference
(used by the models at trace time — XLA:CPU fuses those fine).  Set
``KERNEL_MODE`` to force a path:
  auto      — TPU: kernels; CPU: references
  kernel    — always kernels (interpret=True off-TPU)
  reference — always references
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels import ref as _ref
from repro.kernels.decode_attention import decode_attention as _decode_k
from repro.kernels.flash_attention import flash_attention_flat as _flash_k
from repro.kernels.hub_route import hub_route as _hub_k
from repro.kernels.minskew import minskew as _minskew_k
from repro.kernels.mlstm_kernel import mlstm_chunkwise as _mlstm_k
from repro.kernels.rglru_scan import rglru_scan as _rglru_k

KERNEL_MODE = "auto"


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _use_kernel() -> bool:
    if KERNEL_MODE == "kernel":
        return True
    if KERNEL_MODE == "reference":
        return False
    return _on_tpu()


def _interp() -> bool:
    return not _on_tpu()


@partial(jax.jit, static_argnames=("causal", "window"))
def flash_attention(q, k, v, *, causal=True, window=0):
    """q (B,S,H,hd); k/v (B,S,Hkv,hd) -> (B,S,H,hd)."""
    b, s, h, hd = q.shape
    hkv = k.shape[2]
    qf = q.transpose(0, 2, 1, 3).reshape(b * h, s, hd)
    kf = k.transpose(0, 2, 1, 3).reshape(b * hkv, s, hd)
    vf = v.transpose(0, 2, 1, 3).reshape(b * hkv, s, hd)
    if _use_kernel():
        of = _flash_k(qf, kf, vf, causal=causal, window=window,
                      interpret=_interp())
    else:
        of = _ref.attention_flat_ref(qf, kf, vf, causal=causal,
                                     window=window)
    return of.reshape(b, h, s, hd).transpose(0, 2, 1, 3)


@jax.jit
def decode_attention(q, k_cache, v_cache, lengths):
    """q (B,H,hd); caches (B,S,Hkv,hd); lengths (B,) -> (B,H,hd)."""
    if _use_kernel():
        return _decode_k(q, k_cache, v_cache, lengths,
                         interpret=_interp())
    return _ref.decode_attention_ref(q, k_cache, v_cache, lengths)


@jax.jit
def rglru(log_a, b, h0=None):
    if _use_kernel():
        return _rglru_k(log_a, b, h0, interpret=_interp())
    return _ref.rglru_ref(log_a, b, h0)


@partial(jax.jit, static_argnames=("chunk",))
def mlstm(q, k, v, i_raw, f_raw, *, chunk=128):
    """q,k,v (B,S,H,hd); gates (B,S,H) -> h (B,S,H,hd)."""
    b, s, h, hd = q.shape
    qf = q.transpose(0, 2, 1, 3).reshape(b * h, s, hd)
    kf = k.transpose(0, 2, 1, 3).reshape(b * h, s, hd)
    vf = v.transpose(0, 2, 1, 3).reshape(b * h, s, hd)
    gi = i_raw.transpose(0, 2, 1).reshape(b * h, s).astype(jnp.float32)
    gf = f_raw.transpose(0, 2, 1).reshape(b * h, s).astype(jnp.float32)
    chunk = min(chunk, s)
    if s % chunk:
        raise ValueError(f"S={s} not divisible by chunk={chunk}")
    hf = _mlstm_k(qf, kf, vf, gi, gf, chunk=chunk, interpret=_interp())
    return hf.reshape(b, h, s, hd).transpose(0, 2, 1, 3)


def minskew(vtime, runnable, membership, skew):
    if _use_kernel():
        return _minskew_k(vtime, runnable, membership, skew,
                          interpret=_interp())
    from repro.core.engine_jax import eligibility, scope_minima

    minima = scope_minima(vtime, runnable != 0, membership != 0)
    elig = eligibility(vtime, runnable != 0, membership != 0, skew,
                       minima)
    return minima, elig.astype(jnp.int8)


def hub_route(send_vtime, size_bytes, link_id, link_bw_Bps, link_lat_ns):
    if _use_kernel():
        return _hub_k(send_vtime, size_bytes, link_id, link_bw_Bps,
                      link_lat_ns, interpret=_interp())
    from repro.core.engine_jax import hub_visibility

    return hub_visibility(send_vtime, size_bytes, link_id, link_bw_Bps,
                          link_lat_ns)
