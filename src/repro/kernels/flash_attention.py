"""Blockwise (flash) attention Pallas TPU kernel.

TPU-native adaptation: VMEM-resident (block_q x head_dim) query tiles and
(block_k x head_dim) key/value tiles feed the MXU via
``jax.lax.dot_general`` with fp32 accumulation; the online-softmax
running max/denominator live in VMEM scratch across the (innermost,
``arbitrary``) key-block grid dimension.  Tile sides default to 128/512 —
multiples of the 128-lane MXU dimension.

Supports causal masking, sliding-window (local) attention, and GQA: the
kernel is written over flattened (B*H, S, hd) queries with the k/v
BlockSpec index map folding q-head -> kv-head (h // q_per_kv), so no KV
replication ever materializes in HBM.

Block-level early-exit: key blocks wholly outside the causal/window
band are skipped via ``pl.when`` (the classic flash-attention triangle
saving ~2x on causal, much more for small windows).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat import tpu_compiler_params

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
            scale, causal, window, block_q, block_k, nk, seq_k):
    iq = pl.program_id(1)
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_first = iq * block_q                 # first q position in tile
    q_last = q_first + block_q - 1
    k_first = ik * block_k
    k_last = k_first + block_k - 1

    run = k_first < seq_k                  # padded tail key blocks
    if causal:
        run &= k_first <= q_last
    if window > 0:
        run &= k_last > q_first - window

    @pl.when(run)
    def _compute():
        q = q_ref[0].astype(jnp.float32) * scale        # (bq, hd)
        k = k_ref[0].astype(jnp.float32)                # (bk, hd)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        qpos = q_first + jax.lax.broadcasted_iota(jnp.int32,
                                                  (block_q, block_k), 0)
        kpos = k_first + jax.lax.broadcasted_iota(jnp.int32,
                                                  (block_q, block_k), 1)
        mask = kpos < seq_k
        if causal:
            mask &= kpos <= qpos
        if window > 0:
            mask &= kpos > qpos - window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]                              # (bq,)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        corr = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        p = jnp.where(mask, p, 0.0)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1)
        m_ref[...] = m_new
        v = v_ref[0].astype(jnp.float32)                 # (bk, hd)
        pv = jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        acc_ref[...] = acc_ref[...] * corr[:, None] + pv

    @pl.when(ik == nk - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention_flat(q, k, v, *, causal=True, window=0,
                         block_q=128, block_k=512, interpret=False):
    """q (BH, Sq, hd); k/v (BHkv, Sk, hd).  BH % BHkv == 0."""
    bh, sq, hd = q.shape
    bhkv, sk, _ = k.shape
    assert bh % bhkv == 0
    q_per_kv = bh // bhkv
    scale = 1.0 / math.sqrt(hd)

    block_q = min(block_q, sq)
    block_k = min(block_k, sk)
    sq_pad = pl.cdiv(sq, block_q) * block_q
    sk_pad = pl.cdiv(sk, block_k) * block_k
    if sq_pad != sq:
        q = jnp.pad(q, ((0, 0), (0, sq_pad - sq), (0, 0)))
    if sk_pad != sk:
        k = jnp.pad(k, ((0, 0), (0, sk_pad - sk), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, sk_pad - sk), (0, 0)))
    nq = sq_pad // block_q
    nk = sk_pad // block_k

    kernel = functools.partial(
        _kernel, scale=scale, causal=causal, window=window,
        block_q=block_q, block_k=block_k, nk=nk, seq_k=sk)

    out = pl.pallas_call(
        kernel,
        grid=(bh, nq, nk),
        in_specs=[
            pl.BlockSpec((1, block_q, hd), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_k, hd),
                         lambda b, i, j, qpk=q_per_kv: (b // qpk, j, 0)),
            pl.BlockSpec((1, block_k, hd),
                         lambda b, i, j, qpk=q_per_kv: (b // qpk, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, hd), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq_pad, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, hd), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v)
    return out[:, :sq, :]
