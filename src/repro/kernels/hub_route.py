"""Batched hub message-routing Pallas TPU kernel (simulation-aware IPC
fast path, paper §3.4).

Computes visibility times for a batch of messages with per-link FIFO
queuing — the hub's common-path latency control as one vectorized pass:

  end_i = max(send_i, end_{i-1 on same link}) + size_i/bw
  visibility_i = end_i + latency

The FIFO recurrence is a segmented max-plus scan (elements (S, A) with
composition (max(S1, S2-A1), A1+A2)); within a VMEM tile it runs as a
log-depth doubling on VREGs, and the running prefix + link id carry
across tiles in VMEM/SMEM scratch (grid ``arbitrary``).

Messages must be pre-sorted by (link_id, send_vtime) — the hub batches
per flush epoch, so the sort amortizes.  Oracle:
``repro.core.engine_jax.hub_visibility_ref``.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat import tpu_compiler_params

NEG = -(2**30)  # python int: jnp scalars would be captured as consts


def _kernel(send_ref, ser_ref, link_ref, lat_ref, out_ref, carry_ref, *,
            block):
    j = pl.program_id(0)

    @pl.when(j == 0)
    def _init():
        carry_ref[0] = NEG          # S_run
        carry_ref[1] = 0            # A_run
        carry_ref[2] = -1           # last link id

    send = send_ref[...]
    ser = ser_ref[...]
    link = link_ref[...]
    lat = lat_ref[...]

    prev_link = jnp.concatenate(
        [jnp.full((1,), carry_ref[2], jnp.int32), link[:-1]])
    seg_first = link != prev_link

    # in-tile segmented max-plus scan via doubling
    S, A, G = send, ser, seg_first
    steps = int(math.log2(block))
    for st in range(steps):
        d = 1 << st
        # fills are the monoid identity (NEG, 0, False) so tile-start
        # prefixes compose with a no-op rather than a fake boundary
        S_sh = jnp.concatenate([jnp.full((d,), NEG, jnp.int32), S[:-d]])
        A_sh = jnp.concatenate([jnp.zeros((d,), jnp.int32), A[:-d]])
        G_sh = jnp.concatenate([jnp.zeros((d,), bool), G[:-d]])
        S_new = jnp.where(G, S, jnp.maximum(S_sh, S - A_sh))
        A_new = jnp.where(G, A, A_sh + A)
        S, A, G = S_new, A_new, G | G_sh

    # fold the cross-tile carry into prefixes with no boundary yet
    S_c, A_c = carry_ref[0], carry_ref[1]
    S_fin = jnp.where(G, S, jnp.maximum(S_c, S - A_c))
    A_fin = jnp.where(G, A, A_c + A)
    out_ref[...] = S_fin + A_fin + lat

    carry_ref[0] = S_fin[-1]
    carry_ref[1] = A_fin[-1]
    carry_ref[2] = link[-1]


def hub_route(send_vtime, size_bytes, link_id, link_bw_Bps, link_lat_ns,
              *, ser_ns=None, block=2048, interpret=False):
    """Visibility times (ns int32) for sorted messages.

    send_vtime (M,) int32; size_bytes (M,) int32; link_id (M,) int32;
    link_bw_Bps/link_lat_ns (L,) per-link tables.  ``ser_ns`` (M,)
    bypasses the float32 size/bandwidth serialization math with exact
    precomputed per-message durations — the vectorized engine's
    tick-quantized tapes need bit-exact integer queuing (float32 only
    carries 24 mantissa bits, so ``size * 1e9`` already rounds)."""
    m = send_vtime.shape[0]
    if ser_ns is not None:
        ser = ser_ns.astype(jnp.int32)
    else:
        ser = (size_bytes.astype(jnp.float32) * 1e9
               / link_bw_Bps[link_id]).astype(jnp.int32)
    lat = link_lat_ns[link_id].astype(jnp.int32)
    block = min(block, 1 << int(math.ceil(math.log2(max(m, 1)))))
    assert block & (block - 1) == 0
    m_pad = pl.cdiv(m, block) * block
    if m_pad != m:
        pad = (0, m_pad - m)
        send_vtime = jnp.pad(send_vtime, pad)
        ser = jnp.pad(ser, pad)
        # padded tail gets a fresh fake link so it can't affect carries
        link_id = jnp.pad(link_id, pad, constant_values=2**30)
        lat = jnp.pad(lat, pad)

    out = pl.pallas_call(
        functools.partial(_kernel, block=block),
        grid=(m_pad // block,),
        in_specs=[
            pl.BlockSpec((block,), lambda j: (j,)),
            pl.BlockSpec((block,), lambda j: (j,)),
            pl.BlockSpec((block,), lambda j: (j,)),
            pl.BlockSpec((block,), lambda j: (j,)),
        ],
        out_specs=pl.BlockSpec((block,), lambda j: (j,)),
        out_shape=jax.ShapeDtypeStruct((m_pad,), jnp.int32),
        scratch_shapes=[pltpu.SMEM((3,), jnp.int32)],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("arbitrary",)),
        interpret=interpret,
    )(send_vtime, ser, link_id, lat)
    return out[:m]
