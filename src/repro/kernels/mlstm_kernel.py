"""Chunkwise-parallel mLSTM matrix-memory Pallas TPU kernel (xlstm).

The mLSTM cell C_t = f_t C_{t-1} + i_t k_t v_t^T has a (hd x hd) matrix
state per head — on GPU this is a warp-per-head serial loop; the TPU
adaptation keeps the *chunkwise* formulation (intra-chunk attention-like
MXU matmuls + an inter-chunk C/n carry) with the carry resident in VMEM
scratch across the chunk grid dimension:

  intra:  S_ij = (q_i . k_j) exp(A_i - A_j) i_j   (j <= i, within chunk)
  inter:  out_i += exp(A_i) (q_i C),  den_i += exp(A_i) (q_i . n)
  carry:  C' = exp(A_L) C + sum_j exp(A_L - A_j) i_j k_j v_j^T

All matmuls are MXU-shaped ((L x hd) @ (hd x hd), (L x L) @ (L x hd));
gates/decays are fp32 VPU ops.  Matches ``repro.models.xlstm
.mlstm_chunkwise`` (same gate convention: i = exp(min(i_raw, 8)),
f = sigmoid) and is oracle-tested against the sequential step form.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat import tpu_compiler_params

I_CAP = 8.0


def _kernel(q_ref, k_ref, v_ref, ig_ref, fg_ref, out_ref,
            c_ref, n_ref, *, chunk, scale):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        c_ref[...] = jnp.zeros_like(c_ref)
        n_ref[...] = jnp.zeros_like(n_ref)

    q = q_ref[0].astype(jnp.float32) * scale      # (L, hd)
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)
    li = jnp.minimum(ig_ref[0], I_CAP)            # (L,)
    lf = jax.nn.log_sigmoid(fg_ref[0])
    a = jnp.cumsum(lf)                            # (L,)
    a_l = a[-1]

    dec_q = jnp.exp(a)[:, None]                   # (L, 1)
    w_kj = jnp.exp(li - a)[:, None]               # i_j * exp(-A_j)

    c = c_ref[...]
    n = n_ref[...]
    out = jax.lax.dot_general(q * dec_q, c, (((1,), (0,)), ((), ())),
                              preferred_element_type=jnp.float32)
    den = jax.lax.dot_general(q * dec_q, n[:, None],
                              (((1,), (0,)), ((), ())),
                              preferred_element_type=jnp.float32)[:, 0]

    s = jax.lax.dot_general(q * dec_q, k * w_kj, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (L, L)
    ii = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    s = jnp.where(jj <= ii, s, 0.0)
    out = out + jax.lax.dot_general(s, v, (((1,), (0,)), ((), ())),
                                    preferred_element_type=jnp.float32)
    den = den + jnp.sum(s, axis=1)
    h = out / jnp.maximum(jnp.abs(den), 1.0)[:, None]
    out_ref[0] = h.astype(out_ref.dtype)

    w_c = jnp.exp(a_l - a + li)[:, None]          # (L, 1)
    c_ref[...] = c * jnp.exp(a_l) + jax.lax.dot_general(
        k * w_c, v, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    n_ref[...] = n * jnp.exp(a_l) + jnp.sum(k * w_c, axis=0)


def mlstm_chunkwise(q, k, v, i_raw, f_raw, *, chunk=128, interpret=False):
    """q,k,v (BH, S, hd); gates (BH, S) fp32 -> h (BH, S, hd).

    S must be divisible by ``chunk`` (ops.py pads)."""
    bh, s, hd = q.shape
    assert s % chunk == 0, (s, chunk)
    nc = s // chunk
    scale = 1.0 / math.sqrt(hd)

    return pl.pallas_call(
        functools.partial(_kernel, chunk=chunk, scale=scale),
        grid=(bh, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, hd), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, chunk, hd), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, chunk, hd), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, chunk), lambda i, j: (i, j)),
            pl.BlockSpec((1, chunk), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((1, chunk, hd), lambda i, j: (i, j, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, s, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((hd, hd), jnp.float32),
            pltpu.VMEM((hd,), jnp.float32),
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v, i_raw, f_raw)
