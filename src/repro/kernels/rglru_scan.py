"""RG-LRU chunked linear-recurrence Pallas TPU kernel (recurrentgemma).

h_t = a_t * h_{t-1} + b_t with diagonal, input-dependent a_t.  The TPU
adaptation replaces the GPU "one-thread-per-channel sequential loop"
with a *chunked two-level scan* shaped for the VPU: the sequence axis is
tiled into (block_t x block_w) VMEM blocks; within a block the recurrence
is evaluated by the classic log-depth Blelloch-style doubling on VREGs
(log2(block_t) vector ops instead of block_t serial steps), and the
carry h propagates across sequence tiles through VMEM scratch (grid dim
``arbitrary``).  Width is embarrassingly parallel (lane dimension).

Inputs are fp32: log_a (B, S, W), b (B, S, W); optional initial state
h0 (B, W).  Output: h (B, S, W).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat import tpu_compiler_params


def _kernel(log_a_ref, b_ref, h0_ref, out_ref, carry_ref, *,
            block_t, n_t):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        carry_ref[...] = h0_ref[0]

    la = log_a_ref[0]                       # (bt, bw) fp32
    bv = b_ref[0]

    # log-depth inclusive scan of the affine recurrence within the block:
    # pairs (A, B) compose as (A2*A1, A2*B1 + B2); shift-and-combine
    # doubling over the time axis.
    A = jnp.exp(la)
    B = bv
    steps = int(math.log2(block_t))
    for s in range(steps):
        d = 1 << s
        A_shift = jnp.concatenate(
            [jnp.ones((d, A.shape[1]), A.dtype), A[:-d]], axis=0)
        B_shift = jnp.concatenate(
            [jnp.zeros((d, B.shape[1]), B.dtype), B[:-d]], axis=0)
        B = A * B_shift + B
        A = A * A_shift

    h_in = carry_ref[...]                   # (bw,)
    h = A * h_in[None, :] + B
    out_ref[0] = h
    carry_ref[...] = h[-1]


def rglru_scan(log_a, b, h0=None, *, block_t=256, interpret=False):
    """(B, S, W) fp32 -> (B, S, W).  S padded to a power-of-two block."""
    bsz, s, w = log_a.shape
    if h0 is None:
        h0 = jnp.zeros((bsz, w), jnp.float32)
    block_t = min(block_t, 1 << int(math.ceil(math.log2(max(s, 1)))))
    assert block_t & (block_t - 1) == 0, "block_t must be a power of two"
    s_pad = pl.cdiv(s, block_t) * block_t
    if s_pad != s:
        # pad with a=1, b=0 (identity elements continue the carry)
        log_a = jnp.pad(log_a, ((0, 0), (0, s_pad - s), (0, 0)))
        b = jnp.pad(b, ((0, 0), (0, s_pad - s), (0, 0)))
    n_t = s_pad // block_t

    out = pl.pallas_call(
        functools.partial(_kernel, block_t=block_t, n_t=n_t),
        grid=(bsz, n_t),
        in_specs=[
            pl.BlockSpec((1, block_t, w), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, block_t, w), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, w), lambda i, j: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_t, w), lambda i, j: (i, j, 0)),
        out_shape=jax.ShapeDtypeStruct((bsz, s_pad, w), jnp.float32),
        scratch_shapes=[pltpu.VMEM((w,), jnp.float32)],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(log_a, b, h0)
    return out[:, :s, :]
