"""Assigned-architecture configs.  ``get(name)`` returns the full
(paper-exact) ModelConfig; ``get_smoke(name)`` returns a reduced config of
the same family for CPU smoke tests."""
from __future__ import annotations

import dataclasses
import importlib
from typing import Dict, List

from repro.models.common import ModelConfig

ARCHS: List[str] = [
    "phi3_medium_14b",
    "glm4_9b",
    "deepseek_coder_33b",
    "qwen3_4b",
    "seamless_m4t_medium",
    "xlstm_1_3b",
    "moonshot_v1_16b_a3b",
    "olmoe_1b_7b",
    "pixtral_12b",
    "recurrentgemma_9b",
]

# canonical dashed ids (as given in the assignment) -> module names
ALIASES: Dict[str, str] = {
    "phi3-medium-14b": "phi3_medium_14b",
    "glm4-9b": "glm4_9b",
    "deepseek-coder-33b": "deepseek_coder_33b",
    "qwen3-4b": "qwen3_4b",
    "seamless-m4t-medium": "seamless_m4t_medium",
    "xlstm-1.3b": "xlstm_1_3b",
    "moonshot-v1-16b-a3b": "moonshot_v1_16b_a3b",
    "olmoe-1b-7b": "olmoe_1b_7b",
    "pixtral-12b": "pixtral_12b",
    "recurrentgemma-9b": "recurrentgemma_9b",
}


def _norm(name: str) -> str:
    return ALIASES.get(name, name.replace("-", "_").replace(".", "_"))


def get(name: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{_norm(name)}")
    return mod.CONFIG


def get_smoke(name: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{_norm(name)}")
    return mod.SMOKE


def all_configs() -> Dict[str, ModelConfig]:
    return {a: get(a) for a in ARCHS}
