"""xlstm-1.3b [ssm] — sLSTM + mLSTM blocks (1 sLSTM per 8) [arXiv:2405.04517].

d_ff=0 per assignment: block-internal projections use mlstm_proj_factor=2.0
(mLSTM) and slstm_ff_factor=4/3 (sLSTM GeGLU)."""
import dataclasses

from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-1.3b", family="xlstm",
    n_layers=48, d_model=2048, n_heads=4, n_kv_heads=4,
    d_ff=0, vocab=50304, slstm_every=8,
    mlstm_proj_factor=2.0, slstm_ff_factor=4.0 / 3.0,
)

SMOKE = dataclasses.replace(
    CONFIG, name="xlstm-1.3b-smoke",
    n_layers=4, d_model=64, n_heads=2, n_kv_heads=2, vocab=256,
    slstm_every=2,
)
