"""glm4-9b [dense] — RoPE, GQA kv=2 [hf:THUDM/glm-4-9b]."""
import dataclasses

from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="glm4-9b", family="dense",
    n_layers=40, d_model=4096, n_heads=32, n_kv_heads=2,
    d_ff=13696, vocab=151552, head_dim=128, rope_theta=10_000.0,
)

SMOKE = dataclasses.replace(
    CONFIG, name="glm4-9b-smoke",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
    vocab=256, head_dim=16,
)
