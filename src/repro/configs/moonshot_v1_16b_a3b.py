"""moonshot-v1-16b-a3b [moe] — kimi/moonlight, 64 experts top-6
[hf:moonshotai/Moonlight-16B-A3B]."""
import dataclasses

from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="moonshot-v1-16b-a3b", family="moe",
    n_layers=48, d_model=2048, n_heads=16, n_kv_heads=16,
    d_ff=1408, vocab=163840, head_dim=128, rope_theta=50_000.0,
    n_experts=64, top_k=6,
)

SMOKE = dataclasses.replace(
    CONFIG, name="moonshot-v1-16b-a3b-smoke",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=64,
    vocab=256, head_dim=16, n_experts=8, top_k=2,
)
