"""qwen3-4b [dense] — qk_norm, GQA kv=8, head_dim 128 [hf:Qwen/Qwen3-4B]."""
import dataclasses

from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-4b", family="dense",
    n_layers=36, d_model=2560, n_heads=32, n_kv_heads=8,
    d_ff=9728, vocab=151936, head_dim=128, rope_theta=1_000_000.0,
    qk_norm=True,
)

SMOKE = dataclasses.replace(
    CONFIG, name="qwen3-4b-smoke",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
    vocab=256, head_dim=16,
)
