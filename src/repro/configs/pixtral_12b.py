"""pixtral-12b [vlm] — pixtral-ViT frontend STUB + mistral-nemo decoder
backbone [hf:mistralai/Pixtral-12B-2409].

input_specs provides precomputed patch embeddings (1024-d) which occupy the
first n_frontend_tokens positions of the sequence."""
import dataclasses

from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="pixtral-12b", family="vlm",
    n_layers=40, d_model=5120, n_heads=32, n_kv_heads=8,
    d_ff=14336, vocab=131072, head_dim=128, rope_theta=1_000_000.0,
    frontend="patch", frontend_dim=1024, n_frontend_tokens=1024,
)

SMOKE = dataclasses.replace(
    CONFIG, name="pixtral-12b-smoke",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
    vocab=256, head_dim=16, frontend_dim=32, n_frontend_tokens=4,
)
