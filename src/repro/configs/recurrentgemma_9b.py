"""recurrentgemma-9b [hybrid] — RG-LRU + local attention, 1 attn per 3
blocks ((rec,rec,attn)x12 + 2 rec), MQA kv=1, window 2048, lru_width 4096
[arXiv:2402.19427]."""
import dataclasses

from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-9b", family="rglru",
    n_layers=38, d_model=4096, n_heads=16, n_kv_heads=1,
    d_ff=12288, vocab=256000, head_dim=256, rope_theta=10_000.0,
    window=2048, lru_width=4096, attn_every=3,
)

SMOKE = dataclasses.replace(
    CONFIG, name="recurrentgemma-9b-smoke",
    n_layers=5, d_model=64, n_heads=4, n_kv_heads=1, d_ff=128,
    vocab=256, head_dim=16, window=8, lru_width=64,
)
