"""deepseek-coder-33b [dense] — llama-arch, GQA kv=8 [arXiv:2401.14196]."""
import dataclasses

from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-coder-33b", family="dense",
    n_layers=62, d_model=7168, n_heads=56, n_kv_heads=8,
    d_ff=19200, vocab=32256, head_dim=128, rope_theta=100_000.0,
)

SMOKE = dataclasses.replace(
    CONFIG, name="deepseek-coder-33b-smoke",
    n_layers=3, d_model=64, n_heads=8, n_kv_heads=2, d_ff=128,
    vocab=256, head_dim=8,
)
