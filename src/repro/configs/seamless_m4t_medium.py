"""seamless-m4t-medium [audio] — enc-dec backbone, audio frontend STUB
(input_specs provides precomputed frame embeddings) [arXiv:2308.11596]."""
import dataclasses

from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium", family="encdec",
    n_layers=12, d_model=1024, n_heads=16, n_kv_heads=16,
    d_ff=4096, vocab=256206, head_dim=64, rope_theta=10_000.0,
    n_enc_layers=12, frontend="audio", frontend_dim=1024,
)

SMOKE = dataclasses.replace(
    CONFIG, name="seamless-m4t-medium-smoke",
    n_layers=2, n_enc_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=128, vocab=256, head_dim=16, frontend_dim=32,
)
