"""phi3-medium-14b [dense] — RoPE SwiGLU GQA [arXiv:2404.14219]."""
import dataclasses

from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="phi3-medium-14b", family="dense",
    n_layers=40, d_model=5120, n_heads=40, n_kv_heads=10,
    d_ff=17920, vocab=100352, head_dim=128, rope_theta=10_000.0,
)

SMOKE = dataclasses.replace(
    CONFIG, name="phi3-medium-14b-smoke",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
    vocab=256, head_dim=16,
)
