"""Synchronization scopes (paper §3.2, "Dispatch").

A scope groups vtasks that must progress together within a bounded
virtual-time skew.  A vtask may belong to multiple scopes; dispatch
eligibility requires the bound to hold in *every* scope.

scope.vtime (the cached minimum) is computed over RUNNABLE members only —
blocked vtasks are excluded (they cannot make progress and would pin the
minimum, deadlocking e.g. VM boot where halted vCPUs lag the bootstrap
vCPU).  On wake, a previously blocked vtask's vtime is forwarded to the
wake-up's *causal* timestamp — the message visibility / event fire time
(a sleeper observes that time moved up to the interrupt that woke it).
Forwarding must depend on nothing else: the scope's current member
minimum is a function of the orchestration engine's window schedule, so
forwarding to it would give every engine (single / barrier / async /
multi-process dist) different timings for the same simulation.
"""
from __future__ import annotations

from typing import Iterable, List, Optional

from repro.core.vtask import State, VTask


class Scope:
    def __init__(self, name: str, skew_bound_ns: int):
        self.name = name
        self.skew_bound_ns = int(skew_bound_ns)
        self.members: List[VTask] = []
        self._cached_vtime: Optional[int] = None

    def add(self, task: VTask) -> None:
        if task not in self.members:
            self.members.append(task)
            if self not in task.scopes:
                task.scopes.append(self)
        self.invalidate()

    def remove(self, task: VTask) -> None:
        if task in self.members:
            self.members.remove(task)
        if self in task.scopes:
            task.scopes.remove(self)
        self.invalidate()

    def invalidate(self) -> None:
        self._cached_vtime = None

    @property
    def vtime(self) -> int:
        """Cached min vtime over runnable members (+inf if none)."""
        if self._cached_vtime is None:
            vs = [t.vtime for t in self.members if t.state == State.RUNNABLE]
            self._cached_vtime = min(vs) if vs else -1
        return self._cached_vtime

    def eligible(self, task: VTask) -> bool:
        sv = self.vtime
        if sv < 0:      # no runnable members -> nothing to lag behind
            return True
        return task.vtime <= sv + self.skew_bound_ns

    def pin_bound(self, task: VTask) -> int:
        """The vtime up to which *other* members may advance while
        ``task`` stays put: beyond task.vtime + skew_bound they become
        ineligible.  Used by the orchestrator's lazy proxy sync — a stale
        proxy needs a refresh only when the host's window reaches past
        its pin bound."""
        return task.vtime + self.skew_bound_ns

def all_eligible(task: VTask) -> bool:
    return all(s.eligible(task) for s in task.scopes)


def wake(task: VTask, at_vtime: Optional[int] = None) -> None:
    """Unblock + forward vtime to the wake-up's causal timestamp
    ``at_vtime`` (message visibility / event fire time).

    Forwarding is *causal only*, never to the scope's current member
    minimum: that minimum reflects how far peers happened to run under
    one engine's window schedule, so using it would make wake timings —
    and therefore simulation results — engine-dependent (the
    single/barrier/async/dist equivalence bar in
    ``tests/engine_harness.py`` is what enforces this)."""
    if at_vtime is not None:
        task.vtime = max(task.vtime, at_vtime)
    task.state = State.RUNNABLE
    for s in task.scopes:
        s.invalidate()
