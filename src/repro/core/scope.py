"""Synchronization scopes (paper §3.2, "Dispatch").

A scope groups vtasks that must progress together within a bounded
virtual-time skew.  A vtask may belong to multiple scopes; dispatch
eligibility requires the bound to hold in *every* scope.

scope.vtime (the member minimum) is computed over RUNNABLE members only —
blocked vtasks are excluded (they cannot make progress and would pin the
minimum, deadlocking e.g. VM boot where halted vCPUs lag the bootstrap
vCPU).  On wake, a previously blocked vtask's vtime is forwarded to the
wake-up's *causal* timestamp — the message visibility / event fire time
(a sleeper observes that time moved up to the interrupt that woke it).
Forwarding must depend on nothing else: the scope's current member
minimum is a function of the orchestration engine's window schedule, so
forwarding to it would give every engine (single / barrier / async /
multi-process dist) different timings for the same simulation.

The minimum is tracked *incrementally*: each scope keeps a lazy
min-heap of ``(vtime, id)`` member entries.  ``notify(task)`` pushes a
fresh entry in O(log n) whenever a member's vtime changes or it becomes
runnable (vtime is monotone, so stale entries are always <= the true
value and surface at the head, where the query discards them); blocked/
finished/removed members need no bookkeeping at all — their entries
fail the validity check at query time.  This replaces the O(members)
recompute per invalidation that dominated large-scope scheduling.
"""
from __future__ import annotations

import heapq
from typing import List, Optional, Set, Tuple

from repro.core.vtask import State, VTask


class Scope:
    def __init__(self, name: str, skew_bound_ns: int):
        self.name = name
        self.skew_bound_ns = int(skew_bound_ns)
        self.members: List[VTask] = []
        self._member_set: Set[VTask] = set()
        self._heap: List[Tuple[int, int, VTask]] = []

    def add(self, task: VTask) -> None:
        if task not in self._member_set:
            self.members.append(task)
            self._member_set.add(task)
            if self not in task.scopes:
                task.scopes.append(self)
            self.notify(task)

    def remove(self, task: VTask) -> None:
        if task in self._member_set:
            self.members.remove(task)
            self._member_set.discard(task)
        if self in task.scopes:
            task.scopes.remove(self)

    def notify(self, task: VTask) -> None:
        """Index a member's current (vtime, state) in O(log n).  Must be
        called whenever a member's vtime changes while runnable or it
        transitions to RUNNABLE; all other transitions are handled
        lazily (stale entries fail validation at query time)."""
        if task.state is State.RUNNABLE:
            heapq.heappush(self._heap, (task.vtime, task.id, task))

    @property
    def vtime(self) -> int:
        """Min vtime over runnable members (-1 if none), amortized O(1):
        pop stale heads (blocked/done/removed members, superseded
        vtimes) until a live entry — the true minimum — surfaces."""
        h = self._heap
        while h:
            v, _, t = h[0]
            if (t.state is State.RUNNABLE and t.vtime == v
                    and t in self._member_set):
                return v
            heapq.heappop(h)
        return -1

    def eligible(self, task: VTask) -> bool:
        sv = self.vtime
        if sv < 0:      # no runnable members -> nothing to lag behind
            return True
        return task.vtime <= sv + self.skew_bound_ns

    def pin_bound(self, task: VTask) -> int:
        """The vtime up to which *other* members may advance while
        ``task`` stays put: beyond task.vtime + skew_bound they become
        ineligible.  Used by the orchestrator's lazy proxy sync — a stale
        proxy needs a refresh only when the host's window reaches past
        its pin bound."""
        return task.vtime + self.skew_bound_ns


def all_eligible(task: VTask) -> bool:
    return all(s.eligible(task) for s in task.scopes)


def wake(task: VTask, at_vtime: Optional[int] = None) -> None:
    """Unblock + forward vtime to the wake-up's causal timestamp
    ``at_vtime`` (message visibility / event fire time).

    Forwarding is *causal only*, never to the scope's current member
    minimum: that minimum reflects how far peers happened to run under
    one engine's window schedule, so using it would make wake timings —
    and therefore simulation results — engine-dependent (the
    single/barrier/async/dist equivalence bar in
    ``tests/engine_harness.py`` is what enforces this)."""
    if task.sched is not None and task.state is State.BLOCKED \
            and task.kind != "proxy":
        task.sched._n_blocked -= 1
    if at_vtime is not None:
        task.vtime = max(task.vtime, at_vtime)
    task.state = State.RUNNABLE
    for s in task.scopes:
        s.notify(task)
    if task.sched is not None:
        task.sched._runq_push(task)
