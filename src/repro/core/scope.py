"""Synchronization scopes (paper §3.2, "Dispatch").

A scope groups vtasks that must progress together within a bounded
virtual-time skew.  A vtask may belong to multiple scopes; dispatch
eligibility requires the bound to hold in *every* scope.

scope.vtime (the cached minimum) is computed over RUNNABLE members only —
blocked vtasks are excluded (they cannot make progress and would pin the
minimum, deadlocking e.g. VM boot where halted vCPUs lag the bootstrap
vCPU).  On wake, a previously blocked vtask's vtime is forwarded to the
current scope vtime (time causality: a sleeper observes that time moved).
"""
from __future__ import annotations

from typing import Iterable, List, Optional

from repro.core.vtask import State, VTask


class Scope:
    def __init__(self, name: str, skew_bound_ns: int):
        self.name = name
        self.skew_bound_ns = int(skew_bound_ns)
        self.members: List[VTask] = []
        self._cached_vtime: Optional[int] = None

    def add(self, task: VTask) -> None:
        if task not in self.members:
            self.members.append(task)
            if self not in task.scopes:
                task.scopes.append(self)
        self.invalidate()

    def remove(self, task: VTask) -> None:
        if task in self.members:
            self.members.remove(task)
        if self in task.scopes:
            task.scopes.remove(self)
        self.invalidate()

    def invalidate(self) -> None:
        self._cached_vtime = None

    @property
    def vtime(self) -> int:
        """Cached min vtime over runnable members (+inf if none)."""
        if self._cached_vtime is None:
            vs = [t.vtime for t in self.members if t.state == State.RUNNABLE]
            self._cached_vtime = min(vs) if vs else -1
        return self._cached_vtime

    def eligible(self, task: VTask) -> bool:
        sv = self.vtime
        if sv < 0:      # no runnable members -> nothing to lag behind
            return True
        return task.vtime <= sv + self.skew_bound_ns

    def forward_on_wake(self, task: VTask) -> None:
        """Paper: wake-up forwards vtime to the current scope vtime."""
        sv = self.vtime
        if sv >= 0 and task.vtime < sv:
            task.vtime = sv


def all_eligible(task: VTask) -> bool:
    return all(s.eligible(task) for s in task.scopes)


def wake(task: VTask) -> None:
    """Unblock + forward vtime across every scope (max of scope vtimes)."""
    for s in task.scopes:
        s.forward_on_wake(task)
    task.state = State.RUNNABLE
    for s in task.scopes:
        s.invalidate()
