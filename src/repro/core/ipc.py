"""Simulation-aware IPC (paper §3.4): messages, endpoints, hubs.

* **Message** separates timing control from data movement: metadata holds
  addressing + virtual-time info (send vtime, computed visibility time);
  the payload rides alongside (the shared-memory path of the paper is an
  in-process reference, which is exactly zero-copy here).
* **Endpoint** proxies a component's communication interface.  Each has a
  per-receiver incoming queue ordered by visibility time; the scheduler
  reads the queue head as a dispatch hint.
* **Hub** is the kernel-resident router: lightweight routing + latency
  control on the common path.  ``hook`` is the eBPF analogue — a pure
  function (msg, hub state) -> extra_latency_ns / rerouting that runs
  inline in the hub without a context switch.  Heavier behavior is a
  modeled component behind the same endpoint—hub interface
  (``ModeledHubComponent``).

Latency model on the common path (per link): serialization (size/bw) +
propagation (latency_ns) + FIFO queuing (link busy-until tracking).
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Any, Callable, Dict, List, Optional, Tuple

from repro.core.vtime import SEC


@dataclasses.dataclass
class Message:
    src: str
    dst: str
    size_bytes: int
    send_vtime: int
    visibility_time: int = 0
    payload: Any = None
    seq: int = 0
    hops: int = 0

    def sort_key(self):
        # (visibility, src, per-src seq): a process-independent total
        # order.  seq is assigned per *sender* (see Hub.send), so the
        # same simulation produces the same tie-break whether it runs in
        # one process or sharded across dist workers — a global counter
        # would encode which process happened to assign it.
        return (self.visibility_time, self.src, self.seq)


@dataclasses.dataclass
class LinkSpec:
    bandwidth_bps: float = 10e9 * 8      # 10 GB/s default
    latency_ns: int = 2_000              # 2 us
    mtu: int = 0                         # 0 = no segmentation


class Endpoint:
    """A component port.  ``owner`` is the vtask that receives here."""

    def __init__(self, name: str, owner=None):
        self.name = name
        self.owner = owner
        self.hub: Optional["Hub"] = None
        self._queue: List[Tuple[Tuple[int, int], Message]] = []
        self._waiters: List[Any] = []    # vtasks blocked on this endpoint

    # receiver side --------------------------------------------------------
    def deliver(self, msg: Message) -> None:
        heapq.heappush(self._queue, (msg.sort_key(), msg))
        head = self._queue[0][1].visibility_time
        if self.owner is not None:
            self.owner.inbox_hint = head
        if self._waiters:
            # index the (possibly new) head visibility for receivers that
            # blocked here, so the scheduler's wake pass finds them
            # without scanning; prune waiters that have moved on
            keep = []
            for t in self._waiters:
                r = t._wait_reason
                if r is not None and r[0] == "recv" and r[1] is self:
                    keep.append(t)
                    if t.sched is not None:
                        t.sched._wait_push(t, head)
            self._waiters = keep

    def head_visibility(self) -> Optional[int]:
        return self._queue[0][1].visibility_time if self._queue else None

    def pop_visible(self, vtime: int) -> Optional[Message]:
        """Messages become visible only in virtual-time order."""
        if self._queue and self._queue[0][1].visibility_time <= vtime:
            _, msg = heapq.heappop(self._queue)
            if self.owner is not None:
                self.owner.inbox_hint = self.head_visibility()
            return msg
        return None

    def pending(self) -> int:
        return len(self._queue)


HookFn = Callable[[Message, Dict[str, Any]], int]


class Hub:
    """Kernel-resident message router with per-link latency control."""

    def __init__(self, name: str, default_link: LinkSpec = LinkSpec()):
        self.name = name
        self._src_seq: Dict[str, int] = {}        # per-sender message seq
        self.endpoints: Dict[str, Endpoint] = {}
        self.links: Dict[Tuple[str, str], LinkSpec] = {}
        self.default_link = default_link
        self.hooks: List[HookFn] = []
        # ingress hooks run only on the hub that owns the destination
        # endpoint (the local-delivery branch of route()), so a
        # cross-host message is charged exactly once — at the receiver
        self.ingress_hooks: List[HookFn] = []
        self.state: Dict[str, Any] = {}           # hook scratch state
        self.busy_until: Dict[Tuple[str, str], int] = {}
        self.stats = {"messages": 0, "bytes": 0, "queued_ns": 0}
        self.peers: Dict[str, "Hub"] = {}         # distributed hub instances
        self.peer_link: LinkSpec = LinkSpec(bandwidth_bps=25e9 * 8,
                                            latency_ns=10_000)
        # per-peer link specs (heterogeneous topologies) + per-link
        # visibility-time accounting.  ``peer_link`` stays as the default
        # for peers without an explicit entry (back-compat).
        self.peer_links: Dict[str, LinkSpec] = {}
        self.peer_stats: Dict[str, Dict[str, int]] = {}

    # wiring -----------------------------------------------------------------
    def attach(self, ep: Endpoint) -> Endpoint:
        self.endpoints[ep.name] = ep
        ep.hub = self
        return ep

    def connect(self, a: str, b: str, link: LinkSpec) -> None:
        self.links[(a, b)] = link
        self.links[(b, a)] = link

    def add_hook(self, fn: HookFn) -> None:
        """eBPF-analogue: inline, pure extra-latency/steering program."""
        self.hooks.append(fn)

    def add_ingress_hook(self, fn: HookFn) -> None:
        """Receiver-side hook: runs only when *this* hub delivers the
        message to a local endpoint (after any cross-host forwarding),
        e.g. per-host receive-clock skew.  Add-only, like hooks."""
        self.ingress_hooks.append(fn)

    def peer_with(self, other: "Hub", link: Optional[LinkSpec] = None):
        """Distributed hub instance (paper §3.5): one logical hub spanning
        hosts; cross-instance messages carry addressing+visibility
        metadata over the host interconnect link.

        ``link`` is recorded per peer pair, so different pairs may use
        different interconnects (fast intra-rack vs slow cross-rack); the
        per-pair latency is the conservative lookahead of that channel."""
        self.peers[other.name] = other
        other.peers[self.name] = self
        if link is not None:
            self.peer_link = link
            other.peer_link = link
        # pin the pair's link at peering time (each direction from the
        # sender's current default when none is given) so a later
        # peer_with on some *other* pair cannot retroactively change
        # this channel via the shared scalar
        self.peer_links[other.name] = link or self.peer_link
        other.peer_links[self.name] = link or other.peer_link

    def lookahead_ns(self, peer_name: str) -> int:
        """Guaranteed minimum delay of any message sent to ``peer_name``:
        a message sent at t is never visible there before t + lookahead."""
        return self.peer_links.get(peer_name, self.peer_link).latency_ns

    # data path ----------------------------------------------------------------
    def _link(self, src: str, dst: str) -> LinkSpec:
        return self.links.get((src, dst), self.default_link)

    def send(self, src: str, dst: str, size_bytes: int, send_vtime: int,
             payload: Any = None) -> Message:
        seq = self._src_seq.get(src, 0)
        self._src_seq[src] = seq + 1
        msg = Message(src=src, dst=dst, size_bytes=size_bytes,
                      send_vtime=send_vtime, payload=payload, seq=seq)
        return self.route(msg)

    def route(self, msg: Message) -> Message:
        msg.hops += 1
        extra = 0
        for hook in self.hooks:
            extra += int(hook(msg, self.state))
        # hooks may only *add* latency: a negative total would let a
        # message undercut the link's guaranteed lookahead and break
        # conservative cross-host synchronization.
        extra = max(0, extra)
        if msg.dst not in self.endpoints:
            # cross-host: forward to the distributed hub instance owning dst
            for peer in self.peers.values():
                if msg.dst in peer.endpoints:
                    link = self.peer_links.get(peer.name, self.peer_link)
                    sent_at = msg.send_vtime
                    msg.send_vtime = self._serialize(msg, ("__peer__",
                                                           peer.name),
                                                     link, extra)
                    if getattr(peer, "is_remote", False):
                        # dist engine: the peer hub lives in another OS
                        # process (repro.dist.worker.RemotePeer).  The
                        # owning worker replays route() on its replica
                        # and performs the per-link accounting there.
                        return peer.forward(self.name, msg, sent_at)
                    routed = peer.route(msg)
                    self._account_peer(peer.name, routed, sent_at, link)
                    return routed
            raise KeyError(f"hub {self.name}: unknown endpoint {msg.dst}")
        if self.ingress_hooks:
            # same add-only contract as sender hooks: clamped as a
            # group so a (buggy) negative hook cannot undercut the
            # link's guaranteed lookahead
            extra += max(0, sum(int(fn(msg, self.state))
                                for fn in self.ingress_hooks))
        link = self._link(msg.src, msg.dst)
        msg.visibility_time = self._serialize(msg, (msg.src, msg.dst),
                                              link, extra)
        self.endpoints[msg.dst].deliver(msg)
        self.stats["messages"] += 1
        self.stats["bytes"] += msg.size_bytes
        return msg

    def _account_peer(self, peer_name: str, msg: Message, sent_at: int,
                      link: LinkSpec) -> None:
        """Per-link visibility-time accounting: every cross-host message
        must satisfy visibility >= send + link latency (slack >= 0), which
        is exactly the invariant the per-link lookahead relies on."""
        st = self.peer_stats.setdefault(
            peer_name, {"messages": 0, "bytes": 0,
                        "min_slack_ns": None, "max_visibility_ns": 0})
        st["messages"] += 1
        st["bytes"] += msg.size_bytes
        slack = msg.visibility_time - sent_at - link.latency_ns
        st["min_slack_ns"] = (slack if st["min_slack_ns"] is None
                              else min(st["min_slack_ns"], slack))
        st["max_visibility_ns"] = max(st["max_visibility_ns"],
                                      msg.visibility_time)

    def _serialize(self, msg: Message, link_key, link: LinkSpec,
                   extra_ns: int) -> int:
        ser_ns = int(msg.size_bytes * 8 / link.bandwidth_bps * SEC)
        start = max(msg.send_vtime, self.busy_until.get(link_key, 0))
        self.stats["queued_ns"] += start - msg.send_vtime
        end = start + ser_ns
        self.busy_until[link_key] = end
        return end + link.latency_ns + extra_ns


class ModeledHubComponent:
    """Detailed connection behavior as a modeled component behind the same
    endpoint—hub interface (paper: 'more detailed connection behavior can
    instead be modeled as a separate component ... at higher overhead').

    Wrap as a vtask body with ``switch_vtask_body``: it drains its ingress
    endpoint, applies a per-message service model, and re-routes."""

    def __init__(self, name: str, hub: Hub, service_fn):
        self.name = name
        self.hub = hub
        self.ingress = hub.attach(Endpoint(f"{name}.in"))
        self.service_fn = service_fn       # (msg) -> (service_ns, out_dst)
