"""Live memory-hierarchy management (paper §3.3): the cell abstraction.

A cell binds one live component to a controlled resource domain: CPU set,
NUMA node, LLC way allocation (Intel CAT / AMD QoS analogue), memory-
bandwidth share (MBA analogue), interrupt placement.  On the simulation
host we cannot program real RDT MSRs, so the subsystem does exactly what
the paper prescribes for *imperfect* isolation: estimate the residual
deviation and fold it into virtual-time advance — "imperfect isolation is
not hidden; it is explicitly incorporated into simulated time."

Two distortions are modeled:

* **Spatial interference**: a closed-form contention model.  Cache
  pressure = working-set overflow beyond the cell's way fraction; memory
  bandwidth = demand vs. MBA share under co-active demand, weighted by
  the workload's memory-bound fraction.  The resulting multiplier scales
  clock-derived vtime of live calls.
* **Temporal residue**: warm-cell tracking with `n_warm_slots` capacity.
  Dispatching a cold cell costs reconditioning time (flush outgoing +
  prefetch incoming) plus a deterministic "PMU-sampled" residue
  (hash-derived, reproducible) — charged to the incoming component's
  vtime at its next live call.

All constants are calibration knobs (see benchmarks/cell_bench.py).
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict
from typing import Dict, List, Optional

from repro.core.vtask import VTask

TOTAL_WAYS = 12


def _hash01(*xs: int) -> float:
    """Deterministic pseudo-random in [-1, 1) (PMU-sampling stand-in)."""
    h = 2166136261
    for x in xs:
        h = (h ^ (x & 0xFFFFFFFF)) * 16777619 & 0xFFFFFFFF
    return (h / 2**31) - 1.0


@dataclasses.dataclass
class Cell:
    name: str
    ways: int = 4                     # CAT way allocation
    bw_share: float = 0.5             # MBA throttle (fraction of machine BW)
    bw_demand: float = 0.3            # workload's bandwidth appetite
    working_set_frac: float = 0.5     # working set / LLC size
    mem_frac: float = 0.3             # memory-bound fraction of runtime
    cpus: tuple = ()
    numa: int = 0


class CellManager:
    def __init__(self, total_ways: int = TOTAL_WAYS,
                 miss_penalty: float = 0.6,
                 recondition_ns: int = 50_000,
                 residue_frac: float = 0.05,
                 n_warm_slots: int = 4):
        self.cells: Dict[str, Cell] = {}
        self.total_ways = total_ways
        self.miss_penalty = miss_penalty
        self.recondition_ns = recondition_ns
        self.residue_frac = residue_frac
        self.n_warm_slots = n_warm_slots
        self._warm: "OrderedDict[str, None]" = OrderedDict()
        self._switches = 0
        self.stats = {"switches": 0, "recondition_ns": 0,
                      "interference_events": 0}

    # -- allocation ------------------------------------------------------------
    def create(self, name: str, **kwargs) -> Cell:
        if name in self.cells:
            raise ValueError(f"cell {name} exists")
        cell = Cell(name=name, **kwargs)
        self.cells[name] = cell
        return cell

    def assign(self, task: VTask, name: str) -> VTask:
        if name not in self.cells:
            raise KeyError(name)
        task.cell = name
        return task

    def release(self, name: str) -> None:
        self.cells.pop(name, None)
        self._warm.pop(name, None)

    # -- spatial interference ----------------------------------------------------
    def slowdown(self, task: VTask, coactive_cells: List[Optional[str]]
                 ) -> float:
        if not task.cell or task.cell not in self.cells:
            return 1.0
        c = self.cells[task.cell]
        # cache: overflow beyond the cell's partition (CAT guarantees the
        # partition itself; overflow lines miss)
        ways_frac = c.ways / self.total_ways
        overflow = max(0.0, c.working_set_frac - ways_frac)
        s_cache = self.miss_penalty * overflow / max(c.working_set_frac,
                                                     1e-9)
        # bandwidth: MBA share under co-active demand
        others = [self.cells[x] for x in set(coactive_cells)
                  if x and x in self.cells and x != task.cell]
        total_demand = c.bw_demand + sum(o.bw_demand for o in others)
        if total_demand > 1.0:
            total_share = c.bw_share + sum(o.bw_share for o in others)
            avail = c.bw_share / max(total_share, 1e-9)
            got = min(c.bw_demand, avail)
        else:
            got = c.bw_demand
        s_bw = c.mem_frac * max(0.0, c.bw_demand / max(got, 1e-9) - 1.0)
        s = 1.0 + s_cache + s_bw
        if s > 1.0:
            self.stats["interference_events"] += 1
        return s

    # -- temporal residue ----------------------------------------------------------
    def switch_cost(self, task: VTask) -> int:
        """Reconditioning + residue when the task's cell is cold."""
        if not task.cell or task.cell not in self.cells:
            return 0
        if task.cell in self._warm:
            self._warm.move_to_end(task.cell)
            return 0
        if len(self._warm) >= self.n_warm_slots:
            self._warm.popitem(last=False)       # evict LRU (flush)
        self._warm[task.cell] = None
        self._switches += 1
        residue = _hash01(task.id, self._switches) * self.residue_frac
        cost = int(self.recondition_ns * (1.0 + residue))
        self.stats["switches"] += 1
        self.stats["recondition_ns"] += cost
        return cost
