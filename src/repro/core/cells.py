"""Live memory-hierarchy management (paper §3.3): the cell abstraction.

A cell binds one live component to a controlled resource domain: CPU set,
NUMA node, LLC way allocation (Intel CAT / AMD QoS analogue), memory-
bandwidth share (MBA analogue), interrupt placement.  On the simulation
host we cannot program real RDT MSRs, so the subsystem does exactly what
the paper prescribes for *imperfect* isolation: estimate the residual
deviation and fold it into virtual-time advance — "imperfect isolation is
not hidden; it is explicitly incorporated into simulated time."

Two distortions are modeled:

* **Spatial interference**: a closed-form contention model.  Cache
  pressure = working-set overflow beyond the cell's way fraction; memory
  bandwidth = demand vs. MBA share under co-active demand, weighted by
  the workload's memory-bound fraction.  The resulting multiplier scales
  clock-derived vtime of live calls.  Accounting distinguishes *spatial
  interference* (the multiplier grew because co-active cells contend)
  from *self-pressure* (the cell's own working set overflows its ways,
  or its demand exceeds the machine, with nobody else around).
* **Temporal residue**: warm-cell tracking with ``n_warm_slots``
  capacity.  Dispatching a cold cell costs reconditioning time (flush
  outgoing + prefetch incoming) plus a deterministic "PMU-sampled"
  residue (hash-derived, reproducible) — charged to the incoming
  component's vtime at its next live call.

State model (the engine-equivalence contract)
---------------------------------------------

One ``CellManager`` per simulated *host* — the facade constructs them
per host in every engine, and the multi-process dist workers rebuild
bit-identical replicas, so a cell name denotes independent state on
each host it is used on.  Everything that feeds virtual time is a
function of declarative, engine-independent inputs:

* **Co-activity is assignment-based**: a cell is live on its host from
  the first :meth:`assign` until :meth:`release` — a CAT/MBA allocation
  holds its ways and bandwidth share for the component's lifetime, not
  just while a task happens to be dispatched (and not merely until it
  finishes: a dead component's cell still occupies the hierarchy until
  released).  Assignments happen at build time, so the coactive set —
  and therefore every spatial multiplier — is identical across the
  single/barrier/async/dist engines regardless of how they window
  execution.  The per-host *live-cell multiset* is maintained
  incrementally (O(1) aggregate reads per live call; updates only at
  assign/release), replacing the old O(n)-tasks scan per LiveCall.
* **Residues are name-keyed**: the reconditioning residue hashes the
  task's *name* and its per-task cold-entry ordinal, never process
  state (vtask ids drift across builds in one process; a global switch
  counter drifts with dispatch interleaving).
* **Warm-slot LRU transitions happen at live-call dispatch
  boundaries**, which the scheduler orders by ``(vtime, id)``.  On a
  host that dispatches serially (``n_cpus=1`` — the same condition
  under which ``cpu_resource`` queuing is engine-exact) that order is
  provably engine-invariant, so switch charges agree bit-exactly across
  engines; wider hosts may batch racing live calls across window gates
  differently (spatial interference stays exact either way).

All constants are calibration knobs (see benchmarks/run.py::cells).
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict
from typing import Any, Dict, List, Optional

from repro.core.vtask import VTask

TOTAL_WAYS = 12

#: slowdown-histogram bucket upper edges (inclusive); the report keeps
#: integer counts per bucket so cross-engine comparison is exact
SLOWDOWN_BUCKETS = (1.0, 1.05, 1.1, 1.25, 1.5, 2.0)


def _hash01(*xs: int) -> float:
    """Deterministic pseudo-random in [-1, 1) (PMU-sampling stand-in)."""
    h = 2166136261
    for x in xs:
        h = (h ^ (x & 0xFFFFFFFF)) * 16777619 & 0xFFFFFFFF
    return (h / 2**31) - 1.0


def _stable_hash(s: str) -> int:
    """FNV-1a over UTF-8 bytes: a process- and build-order-independent
    int key for residue hashing (vtask ids are neither)."""
    h = 2166136261
    for b in s.encode("utf-8"):
        h = ((h ^ b) * 16777619) & 0xFFFFFFFF
    return h


#: precomputed histogram labels (the bucket lookup runs on every live
#: call — only float compares belong on that path)
_BUCKET_LABELS = tuple(f"<={e:.2f}" for e in SLOWDOWN_BUCKETS) \
    + (f">{SLOWDOWN_BUCKETS[-1]:.2f}",)


def _bucket(s: float) -> str:
    for i, edge in enumerate(SLOWDOWN_BUCKETS):
        if s <= edge:
            return _BUCKET_LABELS[i]
    return _BUCKET_LABELS[-1]


@dataclasses.dataclass
class Cell:
    name: str
    ways: int = 4                     # CAT way allocation
    bw_share: float = 0.5             # MBA throttle (fraction of machine BW)
    bw_demand: float = 0.3            # workload's bandwidth appetite
    working_set_frac: float = 0.5     # working set / LLC size
    mem_frac: float = 0.3             # memory-bound fraction of runtime
    cpus: tuple = ()
    numa: int = 0


class CellManager:
    """Per-host cell allocation, spatial-interference, and warm-slot
    state (see the module docstring for the engine-equivalence
    contract)."""

    def __init__(self, total_ways: int = TOTAL_WAYS,
                 miss_penalty: float = 0.6,
                 recondition_ns: int = 50_000,
                 residue_frac: float = 0.05,
                 n_warm_slots: int = 4,
                 host: int = 0):
        self.host = host
        self.cells: Dict[str, Cell] = {}
        self.total_ways = total_ways
        self.miss_penalty = miss_penalty
        self.recondition_ns = recondition_ns
        self.residue_frac = residue_frac
        self.n_warm_slots = n_warm_slots
        self._warm: "OrderedDict[str, None]" = OrderedDict()
        # live-cell multiset: cell -> number of assigned tasks, plus the
        # O(1) aggregates slowdown() reads per live call (sum of demand/
        # share over cells with >= 1 assignment, each counted once)
        self._assigned: Dict[str, int] = {}
        self._tasks: Dict[str, List[VTask]] = {}   # backrefs for release
        self._solo: Dict[str, float] = {}          # cached solo multipliers
        self._n_live = 0
        self._live_demand = 0.0
        self._live_share = 0.0
        self.stats = {"switches": 0, "recondition_ns": 0,
                      "interference_events": 0, "self_pressure_events": 0}
        self._cell_stats: Dict[str, Dict[str, Any]] = {}

    # -- allocation ------------------------------------------------------------
    def add(self, cell: Cell) -> Cell:
        """Register an existing :class:`Cell` spec (copied defensively)."""
        if cell.name in self.cells:
            raise ValueError(f"cell {cell.name} exists")
        cell = dataclasses.replace(cell)
        self.cells[cell.name] = cell
        # the solo multiplier is a pure function of the (immutable)
        # spec + manager knobs: cache it so contended live calls don't
        # run the float pipeline twice
        self._solo[cell.name] = self._slowdown_of(cell, 0.0, 0.0)
        self._cell_stats.setdefault(cell.name, {
            "live_calls": 0, "interference_events": 0,
            "self_pressure_events": 0, "switches": 0,
            "recondition_ns": 0, "max_slowdown_ppm": 0,
            "slowdown_hist": {}})
        return cell

    def create(self, name: str, **kwargs) -> Cell:
        return self.add(Cell(name=name, **kwargs))

    def assign(self, task: VTask, name: str) -> VTask:
        """Bind a task to a cell and register it in the live-cell
        multiset.  Membership is keyed on the manager's own records —
        not on ``task.cell``, which may already carry the name from the
        ``VTask(cell=...)`` constructor arg — so assign() is idempotent
        and constructor-labelled tasks register correctly."""
        if name not in self.cells:
            raise KeyError(name)
        if task.cell and task.cell != name:
            self._unassign(task)
        tasks = self._tasks.setdefault(name, [])
        if task not in tasks:
            task.cell = name
            tasks.append(task)
            self._assigned[name] = self._assigned.get(name, 0) + 1
            if self._assigned[name] == 1:
                self._recount_live()
        return task

    def _unassign(self, task: VTask) -> None:
        name, task.cell = task.cell, None
        tasks = self._tasks.get(name, [])
        if task in tasks:
            tasks.remove(task)
            self._assigned[name] -= 1
            if self._assigned[name] == 0:
                del self._assigned[name]
                self._recount_live()

    def release(self, name: str) -> None:
        """Destroy a cell: drop its allocation from the live multiset,
        evict its warm slot, and clear every assigned task's ``.cell``
        backref — a released name must stop charging interference and
        switch costs even if the same name is created again later."""
        self.cells.pop(name, None)
        self._warm.pop(name, None)
        self._solo.pop(name, None)
        for t in self._tasks.pop(name, ()):
            if t.cell == name:
                t.cell = None
        if self._assigned.pop(name, 0):
            self._recount_live()

    def _recount_live(self) -> None:
        """Rebuild the live-cell aggregates (assign/release only — never
        on the per-live-call hot path).  A full recount in cell creation
        order keeps the float sums bit-identical across engines: every
        replica performs the same op sequence."""
        live = [c for n, c in self.cells.items()
                if self._assigned.get(n, 0) > 0]
        self._n_live = len(live)
        self._live_demand = sum(c.bw_demand for c in live)
        self._live_share = sum(c.bw_share for c in live)

    @property
    def warm_cells(self) -> tuple:
        """Warm-slot contents, LRU-first (introspection/tests)."""
        return tuple(self._warm)

    # -- spatial interference ----------------------------------------------------
    def _slowdown_of(self, c: Cell, others_demand: float,
                     others_share: float) -> float:
        # cache: overflow beyond the cell's partition (CAT guarantees the
        # partition itself; overflow lines miss)
        ways_frac = c.ways / self.total_ways
        overflow = max(0.0, c.working_set_frac - ways_frac)
        s_cache = self.miss_penalty * overflow / max(c.working_set_frac,
                                                     1e-9)
        # bandwidth: MBA share under co-active demand
        total_demand = c.bw_demand + others_demand
        if total_demand > 1.0:
            avail = c.bw_share / max(c.bw_share + others_share, 1e-9)
            got = min(c.bw_demand, avail)
        else:
            got = c.bw_demand
        s_bw = c.mem_frac * max(0.0, c.bw_demand / max(got, 1e-9) - 1.0)
        return 1.0 + s_cache + s_bw

    def slowdown(self, task: VTask,
                 coactive_cells: Optional[List[Optional[str]]] = None
                 ) -> float:
        """Spatial-interference multiplier for one live call.

        With ``coactive_cells=None`` (the engine hot path) the co-active
        set is the host's live-cell multiset — O(1) aggregate reads, no
        task scan.  An explicit list overrides it (calibration and unit
        tests)."""
        if not task.cell or task.cell not in self.cells:
            return 1.0
        c = self.cells[task.cell]
        if coactive_cells is None:
            own_live = self._assigned.get(c.name, 0) > 0
            n_others = self._n_live - (1 if own_live else 0)
            others_demand = self._live_demand - (c.bw_demand if own_live
                                                 else 0.0)
            others_share = self._live_share - (c.bw_share if own_live
                                               else 0.0)
        else:
            others = [self.cells[x] for x in set(coactive_cells)
                      if x and x in self.cells and x != task.cell]
            n_others = len(others)
            others_demand = sum(o.bw_demand for o in others)
            others_share = sum(o.bw_share for o in others)
        s = self._slowdown_of(c, others_demand, others_share)
        # self-pressure (the cell alone) vs spatial interference (the
        # extra multiplier co-active cells add): report stats must mean
        # what they say — a solo working-set overflow is not
        # "interference among co-located live hosts"
        s_solo = self._solo[c.name] if n_others else s
        cs = self._cell_stats[c.name]
        cs["live_calls"] += 1
        if s_solo > 1.0:
            self.stats["self_pressure_events"] += 1
            cs["self_pressure_events"] += 1
        if s > s_solo:
            self.stats["interference_events"] += 1
            cs["interference_events"] += 1
        ppm = int(round(s * 1e6))
        if ppm > cs["max_slowdown_ppm"]:
            cs["max_slowdown_ppm"] = ppm
        b = _bucket(s)
        cs["slowdown_hist"][b] = cs["slowdown_hist"].get(b, 0) + 1
        return s

    # -- temporal residue ----------------------------------------------------------
    def switch_cost(self, task: VTask) -> int:
        """Reconditioning + residue when the task's cell is cold."""
        if not task.cell or task.cell not in self.cells:
            return 0
        if task.cell in self._warm:
            self._warm.move_to_end(task.cell)
            return 0
        if len(self._warm) >= self.n_warm_slots:
            self._warm.popitem(last=False)       # evict LRU (flush)
        self._warm[task.cell] = None
        self.stats["switches"] += 1
        task.stats["cell_switches"] = uses = \
            task.stats.get("cell_switches", 0) + 1
        residue = _hash01(_stable_hash(task.name), uses) \
            * self.residue_frac
        cost = int(self.recondition_ns * (1.0 + residue))
        self.stats["recondition_ns"] += cost
        cs = self._cell_stats[task.cell]
        cs["switches"] += 1
        cs["recondition_ns"] += cost
        return cost

    # -- reporting -------------------------------------------------------------
    def snapshot(self) -> Optional[Dict[str, Any]]:
        """JSON-able per-host cell report (``SimReport.cells`` section),
        or None when this host never had cells (keeps cell-less reports
        and goldens unchanged).  Integer-valued throughout, so
        cross-engine equality checks are exact."""
        if not self._cell_stats and not any(self.stats.values()):
            return None
        cells = {}
        for name in sorted(self._cell_stats):
            st = self._cell_stats[name]
            cells[name] = {
                "assigned": self._assigned.get(name, 0),
                "live_calls": st["live_calls"],
                "interference_events": st["interference_events"],
                "self_pressure_events": st["self_pressure_events"],
                "switches": st["switches"],
                "recondition_ns": st["recondition_ns"],
                "max_slowdown_ppm": st["max_slowdown_ppm"],
                "slowdown_hist": dict(st["slowdown_hist"]),
            }
        return {"switches": self.stats["switches"],
                "recondition_ns": self.stats["recondition_ns"],
                "interference_events": self.stats["interference_events"],
                "self_pressure_events":
                    self.stats["self_pressure_events"],
                "cells": cells}
