"""Pure discrete-event simulation baseline (the gem5/ns-3 stand-in).

LiveStack's Table 2 compares against a gem5-based modular setup that "did
not finish within a week".  To reproduce that comparison honestly on this
container, this module provides a classic event-queue engine that models
the SAME workloads at fine event granularity (one event per ``grain_ns``
of simulated compute, the way a cycle-ish functional+timing simulator
processes work), so the benchmark can measure events/second and report
measured or extrapolated wall time for the full workload.

The engine is deliberately a fair, optimized-Python DES (heapq, tuple
events, no allocation in the hot loop) — the slowdown vs. LiveStack comes
from the *method* (fine-grained event processing), not an artificially
slow implementation.
"""
from __future__ import annotations

import heapq
import itertools
import time
from typing import Callable, List, Optional, Tuple


class DESEngine:
    def __init__(self) -> None:
        self._heap: List[Tuple[int, int, Callable]] = []
        self._seq = itertools.count()
        self.now = 0
        self.events_processed = 0

    def schedule(self, t_ns: int, fn: Callable) -> None:
        heapq.heappush(self._heap, (t_ns, next(self._seq), fn))

    def run(self, until_ns: Optional[int] = None,
            max_events: Optional[int] = None,
            wall_budget_s: Optional[float] = None) -> dict:
        """Returns run stats; stops early on any budget."""
        t_start = time.perf_counter()
        n0 = self.events_processed
        while self._heap:
            if until_ns is not None and self._heap[0][0] > until_ns:
                break
            if max_events is not None and \
                    self.events_processed - n0 >= max_events:
                break
            if wall_budget_s is not None and \
                    (self.events_processed & 0xFFF) == 0 and \
                    time.perf_counter() - t_start > wall_budget_s:
                break
            t, _, fn = heapq.heappop(self._heap)
            self.now = t
            self.events_processed += 1
            fn()
        wall = time.perf_counter() - t_start
        done = self.events_processed - n0
        return {
            "events": done,
            "wall_s": wall,
            "events_per_s": done / wall if wall > 0 else float("inf"),
            "sim_ns": self.now,
            "exhausted": not self._heap,
        }


def fine_grained_compute(engine: DESEngine, start_ns: int, duration_ns: int,
                         grain_ns: int, on_done: Callable,
                         work_fn: Optional[Callable] = None) -> int:
    """Model a compute span as duration/grain events (the DES way).

    ``work_fn``, if given, is executed once at the final event (functional
    result); the *timing* is carried by the event cascade.  Returns the
    number of events scheduled (lazily, one at a time — constant memory).
    """
    n_events = max(1, duration_ns // grain_ns)

    def step(i: int):
        def fire():
            if i + 1 < n_events:
                engine.schedule(start_ns + (i + 1) * grain_ns, step(i + 1))
            else:
                if work_fn is not None:
                    work_fn()
                on_done()
        return fire

    engine.schedule(start_ns + grain_ns, step(0))
    return n_events


def extrapolate_wall_s(measured: dict, total_events: int) -> float:
    """Extrapolated wall time for a full workload from a measured slice."""
    eps = measured["events_per_s"]
    return total_events / max(eps, 1e-9)
