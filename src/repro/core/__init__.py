"""LiveStack core: OS-level live-simulation substrate in JAX-native form.

Subsystems (one module per paper subsystem):
  vtime        — virtual-time accounting (§3.2): LiveClock, RunPage, CostModel
  vtask        — the vtask abstraction + action vocabulary (§3.2)
  scope        — synchronization scopes, bounded-skew arithmetic (§3.2)
  scheduler    — reference dispatch engine (§3.2)
  cells        — live memory-hierarchy management (§3.3)
  ipc          — simulation-aware IPC: messages/endpoints/hubs (§3.4)
  orchestrator — distributed simulation orchestration (§3.5)
  engine_jax   — vectorized fast-path engine (kernel-hot-path analogue)
  des          — fine-grained DES baseline (the gem5/ns-3 comparison)
  cluster      — ClusterSpec: chips/ICI/DCN topology -> vtasks + hubs
  workloads    — live workload adapters (Table-2 benchmarks + LM steps)
"""
from repro.core.vtime import (NS, US, MS, SEC, CostModel, LiveClock,
                              RunPage, to_ns)
from repro.core.vtask import (Await, Compute, Event, LiveCall, Recv, Send,
                              State, VTask, Yield)
from repro.core.scope import Scope, all_eligible, wake
from repro.core.cells import Cell, CellManager
from repro.core.ipc import Endpoint, Hub, LinkSpec, Message
from repro.core.scheduler import DeadlockError, SchedStats, Scheduler
from repro.core.orchestrator import Orchestrator, ProxyVTask
from repro.core.des import DESEngine, extrapolate_wall_s, fine_grained_compute
