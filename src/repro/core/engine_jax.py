"""Vectorized fast-path engine: LiveStack's "keep vtime updates and IPC
delivery on the kernel hot path" principle, realized as compiled JAX.

The reference scheduler dispatches Python generators — perfect semantics,
O(n) Python per round.  Cluster-scale simulations (one vtask per chip at
512..100k chips) need the hot path compiled.  This engine vectorizes the
scheduler inner loop over ALL vtasks as array ops under ``jax.jit``:

  state arrays:  vtime (N,) int64, runnable (N,) bool,
                 scope membership M (N, S) bool
  per round:     scope minima  -> eligibility mask (bounded skew)
                 -> advance eligible vtasks by their per-dispatch duration
                 -> message visibility + delivery counts

The per-round math matches ``Scheduler`` exactly for compute-only vtasks
(property-tested against it), and is the substrate for the cluster
simulations in ``repro.core.cluster``.  The segmented-min/eligibility hot
spot has a Pallas TPU kernel (``repro.kernels.minskew``); the jnp path
here is its oracle and CPU fallback.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

INF = jnp.int32(2**30)
TICK_NS = 100  # cluster sims use 0.1us ticks: int32 range = ~214 simulated s


@dataclasses.dataclass
class VecState:
    """Array-of-structs state for N vtasks / S scopes."""
    vtime: jnp.ndarray          # (N,) int32 ticks
    runnable: jnp.ndarray       # (N,) bool
    membership: jnp.ndarray     # (N, S) bool
    skew: jnp.ndarray           # (S,) int32
    duration: jnp.ndarray       # (N,) int32 — per-dispatch vtime advance
    steps_left: jnp.ndarray     # (N,) int32 — dispatches until done

    @staticmethod
    def create(n: int, scopes: int, durations, steps, membership, skews):
        return VecState(
            vtime=jnp.zeros((n,), jnp.int32),
            runnable=jnp.asarray(np.asarray(steps) > 0),
            membership=jnp.asarray(membership, bool).reshape(n, scopes),
            skew=jnp.asarray(skews, jnp.int32).reshape(scopes),
            duration=jnp.asarray(durations, jnp.int32).reshape(n),
            steps_left=jnp.asarray(steps, jnp.int32).reshape(n),
        )


def scope_minima(vtime: jnp.ndarray, runnable: jnp.ndarray,
                 membership: jnp.ndarray) -> jnp.ndarray:
    """(S,) min vtime over runnable members (INF when none) — the cached
    scope vtime of the paper, recomputed batch-style."""
    v = jnp.where(runnable[:, None] & membership, vtime[:, None], INF)
    return jnp.min(v, axis=0)


def eligibility(vtime: jnp.ndarray, runnable: jnp.ndarray,
                membership: jnp.ndarray, skew: jnp.ndarray,
                minima: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Bounded-skew dispatch mask: eligible iff for EVERY scope the vtask
    belongs to, vtime <= scope_min + skew."""
    if minima is None:
        minima = scope_minima(vtime, runnable, membership)
    ok_scope = vtime[:, None] <= minima[None, :] + skew[None, :]
    ok = jnp.all(ok_scope | ~membership | (minima == INF)[None, :], axis=1)
    return ok & runnable


@partial(jax.jit, donate_argnums=(0,))
def _round(state: VecState) -> VecState:
    minima = scope_minima(state.vtime, state.runnable, state.membership)
    elig = eligibility(state.vtime, state.runnable, state.membership,
                       state.skew, minima)
    vtime = jnp.where(elig, state.vtime + state.duration, state.vtime)
    steps = jnp.where(elig, state.steps_left - 1, state.steps_left)
    runnable = state.runnable & (steps > 0)
    return dataclasses.replace(state, vtime=vtime, runnable=runnable,
                               steps_left=steps)


jax.tree_util.register_dataclass(
    VecState,
    data_fields=["vtime", "runnable", "membership", "skew", "duration",
                 "steps_left"],
    meta_fields=[])


def run_vectorized(state: VecState, max_rounds: int = 1_000_000
                   ) -> Tuple[VecState, int]:
    """Run rounds until no vtask is runnable.  Uses a compiled while loop
    (whole simulation stays on device — zero Python per round)."""

    def cond(carry):
        st, i = carry
        return jnp.any(st.runnable) & (i < max_rounds)

    def body(carry):
        st, i = carry
        return _round(st), i + 1

    st, rounds = jax.lax.while_loop(cond, body, (state, jnp.int32(0)))
    return st, int(rounds)


# ---------------------------------------------------------------------------
# Batched IPC visibility (hub fast path)
# ---------------------------------------------------------------------------


@jax.jit
def hub_visibility(send_vtime: jnp.ndarray, size_bytes: jnp.ndarray,
                   link_id: jnp.ndarray, link_bw_Bps: jnp.ndarray,
                   link_lat_ns: jnp.ndarray) -> jnp.ndarray:
    """Visibility times for a batch of messages with FIFO link queuing.

    Messages must be sorted by (link_id, send_vtime).  Per link:
      start_i = max(send_i, end_{i-1}),  end_i = start_i + size/bw,
      visibility_i = end_i + latency.
    The FIFO recurrence is a max-plus scan — computed with an associative
    scan over (shift, add) pairs, segmented by link_id.
    """
    ser = (size_bytes.astype(jnp.float32) * 1e9
           / link_bw_Bps[link_id]).astype(jnp.int32)
    first = jnp.concatenate([jnp.ones((1,), bool),
                             link_id[1:] != link_id[:-1]])

    # FIFO recurrence  end_i = max(send_i, end_{i-1}) + ser_i  as a
    # segmented max-plus associative scan.  Each message is the function
    # f_i(x) = max(x, send_i) + ser_i represented as (S=send_i, A=ser_i);
    # composition (f2 after f1) = (max(S1, S2 - A1), A1 + A2), and with
    # x0 = -inf the prefix composition gives end_i = S_i' + A_i'.
    # Segment starts (new link) reset the composition.
    def combine(e1, e2):
        s1, a1, seg1 = e1
        s2, a2, seg2 = e2
        s = jnp.where(seg2, s2, jnp.maximum(s1, s2 - a1))
        a = jnp.where(seg2, a2, a1 + a2)
        return s, a, seg1 | seg2

    s, a, _ = jax.lax.associative_scan(combine, (send_vtime, ser, first))
    return s + a + link_lat_ns[link_id]


def hub_visibility_ref(send_vtime, size_bytes, link_id, link_bw_Bps,
                       link_lat_ns):
    """Sequential oracle for hub_visibility (numpy)."""
    send_vtime = np.asarray(send_vtime)
    size_bytes = np.asarray(size_bytes)
    link_id = np.asarray(link_id)
    busy: dict = {}
    out = np.zeros_like(send_vtime)
    for i in range(len(send_vtime)):
        l = int(link_id[i])
        ser = int(size_bytes[i] * 1e9 / float(link_bw_Bps[l]))
        start = max(int(send_vtime[i]), busy.get(l, 0))
        end = start + ser
        busy[l] = end
        out[i] = end + int(link_lat_ns[l])
    return out
