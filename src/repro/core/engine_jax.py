"""Vectorized fast-path engine: LiveStack's "keep vtime updates and IPC
delivery on the kernel hot path" principle, realized as compiled JAX.

The reference scheduler dispatches Python generators — perfect semantics,
O(n) Python per round.  Cluster-scale simulations (one vtask per chip at
512..100k chips) need the hot path compiled.  This engine vectorizes the
scheduler inner loop over ALL vtasks as array ops under ``jax.jit``:

  state arrays:  vtime (N,) int64, runnable (N,) bool,
                 scope membership M (N, S) bool
  per round:     scope minima  -> eligibility mask (bounded skew)
                 -> advance eligible vtasks by their per-dispatch duration
                 -> message visibility + delivery counts

The per-round math matches ``Scheduler`` exactly for compute-only vtasks
(property-tested against it), and is the substrate for the cluster
simulations in ``repro.core.cluster``.  The segmented-min/eligibility hot
spot has a Pallas TPU kernel (``repro.kernels.minskew``); the jnp path
here is its oracle and CPU fallback.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

INF = jnp.int32(2**30)
INF_TICKS = 2**30               # python-int mirror of INF
TICK_NS = 100  # cluster sims use 0.1us ticks: int32 range = ~214 simulated s


class TickRangeError(ValueError):
    """Simulated times would overflow the engine's int32 tick range
    (``INF = 2**30`` ticks).  Raised at build time — before any round
    runs — so an over-long horizon is an explicit error instead of a
    silent int32 wraparound mid-simulation.  Fix: fewer steps / shorter
    durations, or a coarser tick (``TICK_NS`` for the synthetic engine,
    ``tick_ns=`` for the facade compiler)."""


@dataclasses.dataclass
class VecState:
    """Array-of-structs state for N vtasks / S scopes."""
    vtime: jnp.ndarray          # (N,) int32 ticks
    runnable: jnp.ndarray       # (N,) bool
    membership: jnp.ndarray     # (N, S) bool
    skew: jnp.ndarray           # (S,) int32
    duration: jnp.ndarray       # (N,) int32 — per-dispatch vtime advance
    steps_left: jnp.ndarray     # (N,) int32 — dispatches until done

    @staticmethod
    def create(n: int, scopes: int, durations, steps, membership, skews):
        durations = np.asarray(durations, np.int64).reshape(n)
        steps = np.asarray(steps, np.int64).reshape(n)
        if (durations < 0).any() or (steps < 0).any():
            raise ValueError("durations and steps must be >= 0")
        # per-task final vtime = duration * steps, exactly (vtime only
        # advances by own durations); validate it fits the tick range
        # instead of wrapping int32 mid-run
        total = durations * steps
        if total.size and int(total.max()) >= INF_TICKS:
            worst = int(np.argmax(total))
            raise TickRangeError(
                f"vtask {worst}: duration {int(durations[worst])} x "
                f"steps {int(steps[worst])} = {int(total[worst])} ticks "
                f">= 2**30 — exceeds the int32 tick range; use a "
                f"coarser tick (TICK_NS) or fewer steps")
        return VecState(
            vtime=jnp.zeros((n,), jnp.int32),
            runnable=jnp.asarray(steps > 0),
            membership=jnp.asarray(membership, bool).reshape(n, scopes),
            skew=jnp.asarray(skews, jnp.int32).reshape(scopes),
            duration=jnp.asarray(durations, jnp.int32).reshape(n),
            steps_left=jnp.asarray(steps, jnp.int32).reshape(n),
        )


def scope_minima(vtime: jnp.ndarray, runnable: jnp.ndarray,
                 membership: jnp.ndarray) -> jnp.ndarray:
    """(S,) min vtime over runnable members (INF when none) — the cached
    scope vtime of the paper, recomputed batch-style."""
    v = jnp.where(runnable[:, None] & membership, vtime[:, None], INF)
    return jnp.min(v, axis=0)


def eligibility(vtime: jnp.ndarray, runnable: jnp.ndarray,
                membership: jnp.ndarray, skew: jnp.ndarray,
                minima: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Bounded-skew dispatch mask: eligible iff for EVERY scope the vtask
    belongs to, vtime <= scope_min + skew."""
    if minima is None:
        minima = scope_minima(vtime, runnable, membership)
    ok_scope = vtime[:, None] <= minima[None, :] + skew[None, :]
    ok = jnp.all(ok_scope | ~membership | (minima == INF)[None, :], axis=1)
    return ok & runnable


@partial(jax.jit, donate_argnums=(0,))
def _round(state: VecState) -> VecState:
    minima = scope_minima(state.vtime, state.runnable, state.membership)
    elig = eligibility(state.vtime, state.runnable, state.membership,
                       state.skew, minima)
    vtime = jnp.where(elig, state.vtime + state.duration, state.vtime)
    steps = jnp.where(elig, state.steps_left - 1, state.steps_left)
    runnable = state.runnable & (steps > 0)
    return dataclasses.replace(state, vtime=vtime, runnable=runnable,
                               steps_left=steps)


jax.tree_util.register_dataclass(
    VecState,
    data_fields=["vtime", "runnable", "membership", "skew", "duration",
                 "steps_left"],
    meta_fields=[])


def run_vectorized(state: VecState, max_rounds: int = 1_000_000
                   ) -> Tuple[VecState, int]:
    """Run rounds until no vtask is runnable.  Uses a compiled while loop
    (whole simulation stays on device — zero Python per round)."""

    def cond(carry):
        st, i = carry
        return jnp.any(st.runnable) & (i < max_rounds)

    def body(carry):
        st, i = carry
        return _round(st), i + 1

    st, rounds = jax.lax.while_loop(cond, body, (state, jnp.int32(0)))
    return st, int(rounds)


@partial(jax.jit, static_argnums=(2,))
def _sweep_one(state: VecState, durations: jnp.ndarray,
               max_rounds: int):
    st = dataclasses.replace(state, duration=durations)

    def cond(carry):
        s, i = carry
        return jnp.any(s.runnable) & (i < max_rounds)

    def body(carry):
        s, i = carry
        minima = scope_minima(s.vtime, s.runnable, s.membership)
        elig = eligibility(s.vtime, s.runnable, s.membership, s.skew,
                           minima)
        vtime = jnp.where(elig, s.vtime + s.duration, s.vtime)
        steps = jnp.where(elig, s.steps_left - 1, s.steps_left)
        runnable = s.runnable & (steps > 0)
        return (dataclasses.replace(s, vtime=vtime, runnable=runnable,
                                    steps_left=steps), i + 1)

    st, rounds = jax.lax.while_loop(cond, body, (st, jnp.int32(0)))
    return st.vtime, rounds


def run_vectorized_sweep(state: VecState, duration_axis,
                         max_rounds: int = 1_000_000):
    """Batched configuration sweep: ``jax.vmap`` the whole while-loop
    simulation over a (V, N) axis of per-task durations (V config
    variants sharing everything else).  Returns (final vtimes (V, N),
    rounds (V,)) — V simulations for one compiled dispatch."""
    duration_axis = jnp.asarray(duration_axis, jnp.int32)
    vt, rounds = jax.vmap(_sweep_one, in_axes=(None, 0, None))(
        state, duration_axis, max_rounds)
    return vt, rounds


# ---------------------------------------------------------------------------
# Facade tape interpreter (`Simulation.run(engine="vectorized")`)
# ---------------------------------------------------------------------------
#
# The facade compiler (``repro.sim.vectorized``) lowers a scenario to a
# static per-task *op tape* plus per-message routing tables; this module
# owns the jitted round loop that interprets the tapes.  Per round, for
# every non-done task: fail gates fire, the current op's readiness and
# bounded-skew eligibility are evaluated (the minskew Pallas kernel or
# the jnp oracle above), and eligible tasks execute exactly one op.  On
# the scenario surface the compiler admits, results are provably
# schedule-independent, so this loop is bit-identical to the reference
# engines (see tests/engine_harness.py).

OP_END, OP_COMPUTE, OP_SEND, OP_RECV = 0, 1, 2, 3


@dataclasses.dataclass
class VecTape:
    """Static (per-compile) arrays: tapes, scopes, message routing."""
    op_kind: jnp.ndarray        # (N, P) int32: OP_*
    op_arg: jnp.ndarray         # (N, P) int32: ticks | message id
    n_ops: jnp.ndarray          # (N,) int32
    fail_pc: jnp.ndarray        # (N,) int32 (INF = never)
    fail_vtime: jnp.ndarray     # (N,) int32 ticks (INF = never)
    membership: jnp.ndarray     # (N, S) bool
    skew: jnp.ndarray           # (S,) int32 ticks
    send_overhead: jnp.ndarray  # () int32 ticks
    msg_ch1: jnp.ndarray        # (M,) int32 — stage-1 channel
    msg_ser1: jnp.ndarray       # (M,) int32 ticks
    msg_lat1: jnp.ndarray       # (M,) int32 ticks
    msg_two_stage: jnp.ndarray  # (M,) bool — cross-host second hop
    msg_ch2: jnp.ndarray        # (M,) int32
    msg_ser2: jnp.ndarray       # (M,) int32 ticks
    msg_lat2: jnp.ndarray       # (M,) int32 ticks
    msg_extra: jnp.ndarray      # (M, D) int32 — DegradeLink extras
    msg_extra_from: jnp.ndarray  # (M, D) int32 — send_vtime thresholds


@dataclasses.dataclass
class VecSimState:
    """Per-round mutable state.  ``sent``/``vis``/``sent_vt`` carry one
    extra trailing row — the unmatched-recv sentinel (never sent, so a
    receiver matched to it blocks forever, as in the reference)."""
    vtime: jnp.ndarray          # (N,) int32 ticks
    pc: jnp.ndarray             # (N,) int32
    done: jnp.ndarray           # (N,) bool
    sent: jnp.ndarray           # (M+1,) bool
    vis: jnp.ndarray            # (M+1,) int32 — final visibility
    sent_vt: jnp.ndarray        # (M+1,) int32 — send vtime (overhead incl.)
    busy: jnp.ndarray           # (C,) int32 — per-channel busy-until
    rounds: jnp.ndarray         # () int32
    progressed: jnp.ndarray     # () bool — any op executed / kill fired


for _cls, _fields in ((VecTape, ["op_kind", "op_arg", "n_ops", "fail_pc",
                                 "fail_vtime", "membership", "skew",
                                 "send_overhead", "msg_ch1", "msg_ser1",
                                 "msg_lat1", "msg_two_stage", "msg_ch2",
                                 "msg_ser2", "msg_lat2", "msg_extra",
                                 "msg_extra_from"]),
                      (VecSimState, ["vtime", "pc", "done", "sent",
                                     "vis", "sent_vt", "busy", "rounds",
                                     "progressed"])):
    jax.tree_util.register_dataclass(_cls, data_fields=_fields,
                                     meta_fields=[])


def init_vec_sim_state(tape: VecTape, n_channels: int) -> VecSimState:
    n = tape.op_kind.shape[0]
    m1 = tape.msg_ch1.shape[0] + 1
    return VecSimState(
        vtime=jnp.zeros((n,), jnp.int32),
        pc=jnp.zeros((n,), jnp.int32),
        done=(tape.n_ops == 0),
        sent=jnp.zeros((m1,), bool),
        vis=jnp.zeros((m1,), jnp.int32),
        sent_vt=jnp.zeros((m1,), jnp.int32),
        busy=jnp.zeros((max(n_channels, 1),), jnp.int32),
        rounds=jnp.int32(0),
        progressed=jnp.asarray(True),
    )


def vec_sim_round(tape: VecTape, st: VecSimState, *,
                  pallas: bool = False,
                  interpret: bool = False) -> VecSimState:
    """One dispatch round.  Kill gates fire *before* execution (matching
    ``fail_gated_body``: the wrapped generator returns when the op at
    the fail boundary is produced, before it runs); blocked receivers
    are excluded from scope minima (reference: blocked vtasks leave the
    runnable heap); the effective vtime of a ready receiver is
    max(vtime, visibility) in both minima and eligibility (reference:
    ``scope.wake`` forwards vtime before the retry dispatch)."""
    n, p = tape.op_kind.shape
    m = tape.msg_ch1.shape[0]
    idx = jnp.arange(n)
    pcc = jnp.clip(st.pc, 0, max(p - 1, 0))
    kind = tape.op_kind[idx, pcc]
    arg = tape.op_arg[idx, pcc]

    active = ~st.done
    kill = active & ((st.pc == tape.fail_pc)
                     | (st.vtime >= tape.fail_vtime))
    active = active & ~kill
    done = st.done | kill

    is_recv = active & (kind == OP_RECV)
    marg = jnp.where(is_recv, arg, 0)
    recv_ready = is_recv & st.sent[marg]
    ready = active & (~is_recv | recv_ready)
    eff = jnp.where(recv_ready, jnp.maximum(st.vtime, st.vis[marg]),
                    st.vtime)

    if tape.membership.shape[1] == 0:
        elig = ready
    elif pallas:
        from repro.kernels.minskew import minskew
        _, elig8 = minskew(eff, ready.astype(jnp.int8),
                           tape.membership.astype(jnp.int8), tape.skew,
                           interpret=interpret)
        elig = elig8 != 0
    else:
        minima = scope_minima(eff, ready, tape.membership)
        elig = eligibility(eff, ready, tape.membership, tape.skew,
                           minima)

    do_comp = elig & (kind == OP_COMPUTE)
    do_send = elig & (kind == OP_SEND)
    do_recv = elig & (kind == OP_RECV)
    sv = st.vtime + tape.send_overhead
    vtime = jnp.where(do_comp, st.vtime + arg, st.vtime)
    vtime = jnp.where(do_recv, jnp.maximum(st.vtime, st.vis[marg]),
                      vtime)
    vtime = jnp.where(do_send, sv, vtime)

    # sends: at most one message per channel per round (single-producer
    # channels, one op per task per round), so plain scatters suffice
    m_idx = jnp.where(do_send, arg, m + 1)     # m+1 = out of range: drop
    sent_vt = st.sent_vt.at[m_idx].set(sv, mode="drop")
    sent = st.sent.at[m_idx].set(True, mode="drop")
    now = sent[:m] & ~st.sent[:m]              # newly sent this round
    msv = sent_vt[:m]
    start1 = jnp.maximum(msv, st.busy[tape.msg_ch1])
    end1 = start1 + tape.msg_ser1
    extra = jnp.sum(jnp.where(msv[:, None] >= tape.msg_extra_from,
                              tape.msg_extra, 0),
                    axis=1).astype(jnp.int32)
    vis1 = end1 + tape.msg_lat1 + extra        # extra is post-busy (hook)
    start2 = jnp.maximum(vis1, st.busy[tape.msg_ch2])
    end2 = start2 + tape.msg_ser2
    vis2 = end2 + tape.msg_lat2
    vism = jnp.where(tape.msg_two_stage, vis2, vis1)
    c = st.busy.shape[0]
    busy = st.busy.at[jnp.where(now, tape.msg_ch1, c)].set(
        end1, mode="drop")
    busy = busy.at[jnp.where(now & tape.msg_two_stage,
                             tape.msg_ch2, c)].set(end2, mode="drop")
    vis = st.vis.at[:m].set(jnp.where(now, vism, st.vis[:m]))

    pc = jnp.where(elig, st.pc + 1, st.pc)
    done = done | (pc >= tape.n_ops)
    return VecSimState(
        vtime=vtime, pc=pc, done=done, sent=sent, vis=vis,
        sent_vt=sent_vt, busy=busy, rounds=st.rounds + 1,
        progressed=jnp.any(elig) | jnp.any(kill))


@partial(jax.jit, static_argnames=("pallas", "interpret"))
def run_vec_tape(tape: VecTape, st: VecSimState, max_rounds,
                 *, pallas: bool = False,
                 interpret: bool = False) -> VecSimState:
    """Run rounds to the fixpoint: every task done, or no op executed
    and no kill fired (the remaining tasks are blocked — a deadlock).
    Whole run stays on device; the minimal ready task is always
    eligible, so each round progresses and rounds <= total ops + N."""

    def cond(s):
        return (jnp.any(~s.done) & s.progressed
                & (s.rounds < max_rounds))

    def body(s):
        return vec_sim_round(tape, s, pallas=pallas, interpret=interpret)

    return jax.lax.while_loop(cond, body, st)


def run_vec_tape_batch(tapes: VecTape, states: VecSimState,
                       max_rounds) -> VecSimState:
    """vmap the whole tape interpreter over a leading variants axis
    (every leaf of ``tapes``/``states`` stacked to (V, ...)).  The
    batched while-loop masks finished variants, so per-variant results
    are identical to running each tape alone (asserted in tests).  Uses
    the jnp eligibility path — the Pallas kernel serves single runs."""
    return jax.vmap(
        lambda t, s: run_vec_tape(t, s, max_rounds))(tapes, states)


# ---------------------------------------------------------------------------
# Batched IPC visibility (hub fast path)
# ---------------------------------------------------------------------------


@jax.jit
def hub_visibility(send_vtime: jnp.ndarray, size_bytes: jnp.ndarray,
                   link_id: jnp.ndarray, link_bw_Bps: jnp.ndarray,
                   link_lat_ns: jnp.ndarray,
                   ser_ns: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Visibility times for a batch of messages with FIFO link queuing.

    Messages must be sorted by (link_id, send_vtime).  Per link:
      start_i = max(send_i, end_{i-1}),  end_i = start_i + size/bw,
      visibility_i = end_i + latency.
    The FIFO recurrence is a max-plus scan — computed with an associative
    scan over (shift, add) pairs, segmented by link_id.  ``ser_ns``
    bypasses the float32 serialization math with exact precomputed
    per-message durations (see kernels.hub_route).
    """
    if ser_ns is not None:
        ser = ser_ns.astype(jnp.int32)
    else:
        ser = (size_bytes.astype(jnp.float32) * 1e9
               / link_bw_Bps[link_id]).astype(jnp.int32)
    first = jnp.concatenate([jnp.ones((1,), bool),
                             link_id[1:] != link_id[:-1]])

    # FIFO recurrence  end_i = max(send_i, end_{i-1}) + ser_i  as a
    # segmented max-plus associative scan.  Each message is the function
    # f_i(x) = max(x, send_i) + ser_i represented as (S=send_i, A=ser_i);
    # composition (f2 after f1) = (max(S1, S2 - A1), A1 + A2), and with
    # x0 = -inf the prefix composition gives end_i = S_i' + A_i'.
    # Segment starts (new link) reset the composition.
    def combine(e1, e2):
        s1, a1, seg1 = e1
        s2, a2, seg2 = e2
        s = jnp.where(seg2, s2, jnp.maximum(s1, s2 - a1))
        a = jnp.where(seg2, a2, a1 + a2)
        return s, a, seg1 | seg2

    s, a, _ = jax.lax.associative_scan(combine, (send_vtime, ser, first))
    return s + a + link_lat_ns[link_id]


def hub_visibility_ref(send_vtime, size_bytes, link_id, link_bw_Bps,
                       link_lat_ns, ser_ns=None):
    """Sequential oracle for hub_visibility (numpy)."""
    send_vtime = np.asarray(send_vtime)
    size_bytes = np.asarray(size_bytes)
    link_id = np.asarray(link_id)
    busy: dict = {}
    out = np.zeros_like(send_vtime)
    for i in range(len(send_vtime)):
        l = int(link_id[i])
        ser = (int(ser_ns[i]) if ser_ns is not None
               else int(size_bytes[i] * 1e9 / float(link_bw_Bps[l])))
        start = max(int(send_vtime[i]), busy.get(l, 0))
        end = start + ser
        busy[l] = end
        out[i] = end + int(link_lat_ns[l])
    return out
