"""The vtask abstraction (paper §3.2).

A vtask is any unit of execution the simulation coordinates — live (real
code running at native speed under measured/cost-derived vtime) or modeled
(a performance model reporting simulated latency).

Execution model: a vtask body is a Python generator that yields *actions*
to the scheduler.  This is the in-process realization of "user-space
thread whose execution must be coordinated": the yield points are the
dispatch boundaries (KVM exits / preemption points in the paper).

Actions:
  Compute(ns)            — modeled advance of simulated time.
  LiveCall(fn, args)     — execute fn natively NOW; vtime advances by the
                           measured host span x clock calibration
                           (clock-derived vtime), or by an explicit
                           cost-model duration when provided.
  Send(endpoint, ...)    — enqueue a message through the endpoint's hub.
  Recv(endpoint)         — block until a message is *visible* (vtime
                           ordering enforced by the scheduler+hub).
  Await(event)           — block on an event object.
  Yield()                — cooperative reschedule point.
  Done(value)            — finish (also raised by StopIteration).
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Any, Callable, Iterator, Optional, Tuple

from repro.core.vtime import LiveClock, RunPage


class State(enum.Enum):
    RUNNABLE = "runnable"
    BLOCKED = "blocked"
    DONE = "done"
    FAULTY = "faulty"       # preempted for failing to report progress


# --------------------------- actions ---------------------------------------


@dataclasses.dataclass
class Compute:
    ns: int
    label: str = ""


@dataclasses.dataclass
class LiveCall:
    fn: Callable
    args: tuple = ()
    kwargs: dict = dataclasses.field(default_factory=dict)
    cost_ns: Optional[int] = None    # cost-derived override (else measured)
    label: str = ""


@dataclasses.dataclass
class Send:
    endpoint: Any                    # repro.core.ipc.Endpoint
    dst: str                         # destination endpoint name
    size_bytes: int
    payload: Any = None


@dataclasses.dataclass
class Recv:
    endpoint: Any
    timeout_ns: Optional[int] = None


@dataclasses.dataclass
class Await:
    event: "Event"


@dataclasses.dataclass
class Yield:
    pass


class Event:
    """Level-triggered event with a vtime stamp (for Await)."""

    def __init__(self) -> None:
        self.set_at_vtime: Optional[int] = None
        self.waiters: list = []

    def fire(self, vtime: int) -> None:
        self.set_at_vtime = vtime
        # index the fire time for blocked waiters so the scheduler's
        # wake pass finds them without scanning (visibility/event index)
        for t in self.waiters:
            r = t._wait_reason
            if (r is not None and r[0] == "event" and r[1] is self
                    and t.sched is not None):
                t.sched._wait_push(t, vtime)
        self.waiters.clear()


# --------------------------- vtask ------------------------------------------


class VTask:
    _next_id = 0

    def __init__(self, name: str, body: Optional[Iterator] = None, *,
                 kind: str = "live", clock: Optional[LiveClock] = None,
                 host: int = 0, cell: Optional[str] = None):
        assert kind in ("live", "modeled", "proxy")
        self.id = VTask._next_id
        VTask._next_id += 1
        self.name = name
        self.kind = kind
        self.body = body
        self.state = State.RUNNABLE if body is not None else State.BLOCKED
        self.vtime = 0
        self.scopes: list = []
        self.host = host
        self.cell = cell
        self.clock = clock or LiveClock()
        self.run_page = RunPage()
        self.result: Any = None
        self.inbox_hint: Optional[int] = None     # head-of-queue visibility
        self.zero_progress = 0                    # preemption counter
        self.stats = {"dispatches": 0, "live_ns": 0, "msgs_rx": 0,
                      "msgs_tx": 0, "blocked_rounds": 0,
                      "cell_switches": 0}
        self._wait_reason: Optional[Tuple[str, Any]] = None
        self._pending_action: Any = None   # blocked action awaiting retry
        # scheduler back-reference + index bookkeeping (set by spawn;
        # see repro.core.scheduler's runnable + visibility indexes)
        self.sched: Any = None
        self._runq_on = False              # a live runnable-heap entry exists
        self._runq_v = -1                  # vtime of that entry
        self._wait_on = False              # a live wake-index entry exists
        self._wait_v: Optional[int] = None  # its wake time

    # -- scope membership --
    def join(self, scope) -> "VTask":
        if scope not in self.scopes:
            self.scopes.append(scope)
            scope.add(self)
        return self

    def runnable(self) -> bool:
        return self.state == State.RUNNABLE

    def __repr__(self) -> str:
        return (f"VTask({self.name}#{self.id} {self.kind} {self.state.value}"
                f" v={self.vtime})")
