"""Table-2 workload suite: the same *unmodified* workload functions run
three ways —

  physical  : real threads + real wire delays (the "hardware switch"
              testbed; ground truth wall-clock),
  livestack : the identical functions as live vtasks under virtual time
              (accuracy = predicted vtime vs physical wall-clock),
  DES       : fine-grained event simulation of the same spans (the
              gem5-style baseline; wall-time comparison).

Workloads mirror the paper's categories:
  arith    — CoreMark analogue (1 instance, pure compute)
  oltp     — TPC-C analogue (2 instances: client+server, request/response)
  kvstore  — YCSB analogue (3 instances: 2 clients + 1 server)
  shuffle  — TPC-DS analogue (3 instances: map -> all-to-all -> reduce)

The compute bodies are numpy (releases the GIL, so the physical runs get
real parallelism) and are bit-identical between modes — the paper's
"compatibility" requirement, in-process.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
import time
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

import os

from repro.core.des import DESEngine, extrapolate_wall_s, \
    fine_grained_compute
from repro.core.ipc import Endpoint, Hub, LinkSpec
from repro.core.scheduler import Scheduler
from repro.core.scope import Scope
from repro.core.vtask import Compute, LiveCall, Recv, Send, VTask
from repro.core.vtime import SEC, US


# ---------------------------------------------------------------------------
# The "production" compute functions (identical in every mode)
# ---------------------------------------------------------------------------


def arith_kernel(n: int = 64) -> float:
    a = np.random.default_rng(0).random((n, n))
    x = a
    for _ in range(4):
        x = np.tanh(x @ a)
    return float(x.sum())


def txn_kernel(store: dict, key: int, payload: np.ndarray) -> float:
    """An OLTP transaction: read-modify-write + a little math."""
    cur = store.get(key, 0.0)
    val = float(np.dot(payload, payload) * 1e-6 + cur * 0.5)
    store[key] = val
    return val


def kv_read(store: dict, key: int) -> float:
    v = store.get(key, 0.0)
    return float(np.sqrt(abs(v) + 1.0))


def map_kernel(shard: np.ndarray, n_parts: int) -> List[np.ndarray]:
    """Map phase: transform + partition by hash."""
    y = np.sin(shard) * shard
    parts = [y[i::n_parts].copy() for i in range(n_parts)]
    return parts


def reduce_kernel(parts: List[np.ndarray]) -> float:
    return float(sum(p.sum() for p in parts))


# ---------------------------------------------------------------------------
# Physical testbed: threads + a wire with real (slept) latency
# ---------------------------------------------------------------------------


class Wire:
    """Point-to-point link with bandwidth/latency enforced in wall time,
    matching Hub/LinkSpec semantics (serialization + propagation).

    Delivery uses sleep for the bulk + a short spin for the tail, so the
    enforced latency is close to nominal; the residual OS overhead
    (queue wake-ups, GIL hops) is measured by ``calibrate_wire`` and
    folded into the hub's link parameters — the paper's methodology
    ("prototype hub parameters set to match" the physical switch)."""

    SPIN_S = 2e-4

    def __init__(self, bandwidth_bps: float, latency_s: float):
        self.q: "queue.Queue" = queue.Queue()
        self.bw = bandwidth_bps
        self.lat = latency_s
        self._busy_until = 0.0
        self._lock = threading.Lock()

    def send(self, payload, size_bytes: int) -> None:
        now = time.perf_counter()
        with self._lock:
            start = max(now, self._busy_until)
            end = start + size_bytes * 8 / self.bw
            self._busy_until = end
        self.q.put((end + self.lat, payload))

    def recv(self):
        deliver_at, payload = self.q.get()
        while True:
            now = time.perf_counter()
            if now >= deliver_at:
                return payload
            if deliver_at - now > self.SPIN_S:
                time.sleep(deliver_at - now - self.SPIN_S)


_CALIBRATED: dict = {}


def calibrate_wire(n_pings: int = 400) -> "LinkSpec":
    """Measure the physical testbed's *effective* link characteristics
    (nominal latency + OS residuals) and return the matched LinkSpec for
    the LiveStack hub — exactly how the paper matches its hub to the
    hardware switch."""
    if "link" in _CALIBRATED:
        return _CALIBRATED["link"]
    size = 64
    up = Wire(LINK_BW, LINK_LAT_S)
    down = Wire(LINK_BW, LINK_LAT_S)

    def echo():
        for _ in range(n_pings):
            down.send(up.recv(), size)

    th = threading.Thread(target=echo)
    th.start()
    t0 = time.perf_counter()
    for i in range(n_pings):
        up.send(i, size)
        down.recv()
    rtt = (time.perf_counter() - t0) / n_pings
    th.join()
    ser = 2 * size * 8 / LINK_BW
    eff_lat_ns = max(int((rtt - ser) / 2 * 1e9), 1000)
    link = LinkSpec(bandwidth_bps=LINK_BW, latency_ns=eff_lat_ns)
    _CALIBRATED["link"] = link
    _CALIBRATED["overhead_ns"] = max(eff_lat_ns - int(LINK_LAT_S * 1e9),
                                     0)
    return link


@dataclasses.dataclass
class WorkloadResult:
    name: str
    mode: str                       # physical | livestack | des
    sim_s: float                    # simulated/predicted duration
    wall_s: float                   # wall-clock of the run itself
    metrics: Dict[str, float]


LINK = LinkSpec(bandwidth_bps=10e9, latency_ns=50_000)     # 50 us switch
LINK_BW = 10e9
LINK_LAT_S = 50e-6


# ------------------------------- arith ---------------------------------------


def _host_cpus() -> int:
    return os.cpu_count() or 1


def arith_physical(iters: int = 300) -> WorkloadResult:
    arith_kernel()                                  # warm-up (cold numpy)
    t0 = time.perf_counter()
    for _ in range(iters):
        arith_kernel()
    wall = time.perf_counter() - t0
    return WorkloadResult("arith", "physical", wall, wall,
                          {"iters_per_s": iters / wall})


def arith_livestack(iters: int = 300) -> WorkloadResult:
    arith_kernel()                                  # warm-up (cold numpy)
    sched = Scheduler(n_cpus=1)

    def body():
        for _ in range(iters):
            yield LiveCall(arith_kernel)

    t = sched.spawn(VTask("arith", body(), kind="live"))
    t0 = time.perf_counter()
    sched.run()
    wall = time.perf_counter() - t0
    sim_s = t.vtime / SEC
    return WorkloadResult("arith", "livestack", sim_s, wall,
                          {"iters_per_s": iters / sim_s})


def arith_des(iters: int = 300, grain_ns: int = 1000) -> WorkloadResult:
    """DES baseline: each kernel invocation modeled at grain_ns events,
    executing the same functional work."""
    # calibrate per-iteration duration once (a DES would know it from its
    # microarchitectural model; we grant it the oracle duration)
    t0 = time.perf_counter()
    arith_kernel()
    per_iter_ns = int((time.perf_counter() - t0) * SEC)
    eng = DESEngine()
    state = {"left": iters, "t": 0}

    def launch():
        if state["left"] == 0:
            return
        state["left"] -= 1
        fine_grained_compute(eng, eng.now, per_iter_ns, grain_ns, launch,
                             work_fn=arith_kernel)

    launch()
    stats = eng.run(wall_budget_s=10.0)
    total_events = iters * max(1, per_iter_ns // grain_ns)
    wall = (stats["wall_s"] if stats["exhausted"]
            else extrapolate_wall_s(stats, total_events))
    return WorkloadResult("arith", "des", iters * per_iter_ns / SEC, wall,
                          {"events": total_events,
                           "extrapolated": 0.0 if stats["exhausted"]
                           else 1.0})


# ------------------------------- oltp ----------------------------------------


def _oltp_payloads(n: int, size: int = 2048):
    rng = np.random.default_rng(7)
    return rng.random((n, size))


def oltp_physical(n_req: int = 800) -> WorkloadResult:
    payloads = _oltp_payloads(n_req)
    txn_kernel({}, 0, payloads[0])                  # warm-up
    up = Wire(LINK_BW, LINK_LAT_S)
    down = Wire(LINK_BW, LINK_LAT_S)
    store: dict = {}
    lat: List[float] = []

    def server():
        for _ in range(n_req):
            i = up.recv()
            txn_kernel(store, int(i) % 97, payloads[i])
            down.send(i, 256)

    def client():
        for i in range(n_req):
            t0 = time.perf_counter()
            up.send(i, 16_384)
            _ = down.recv()
            lat.append(time.perf_counter() - t0)

    ts = threading.Thread(target=server)
    tc = threading.Thread(target=client)
    t0 = time.perf_counter()
    ts.start()
    tc.start()
    tc.join()
    ts.join()
    wall = time.perf_counter() - t0
    return WorkloadResult("oltp", "physical", wall, wall, {
        "avg_latency_us": float(np.mean(lat) * 1e6),
        "throughput_ops": n_req / wall,
    })


def oltp_livestack(n_req: int = 800) -> WorkloadResult:
    payloads = _oltp_payloads(n_req)
    hub = Hub("oltp", calibrate_wire())
    txn_kernel({}, 0, payloads[0])                  # warm-up
    sched = Scheduler(n_cpus=_host_cpus(), send_overhead_ns=2_000,
                      cpu_resource=True)
    cl = hub.attach(Endpoint("client"))
    sv = hub.attach(Endpoint("server"))
    store: dict = {}
    lat_v: List[int] = []

    def server():
        for _ in range(n_req):
            msg = yield Recv(sv)
            i = msg.payload
            yield LiveCall(txn_kernel, (store, int(i) % 97, payloads[i]))
            yield Send(sv, "client", 256, payload=i)

    def client():
        for i in range(n_req):
            t0 = yield Send(cl, "server", 16_384, payload=i)
            yield Recv(cl)

    c = sched.spawn(VTask("client", client(), kind="live"))
    s = sched.spawn(VTask("server", server(), kind="live"))
    scope = Scope("oltp", 200 * US)
    c.join(scope)
    s.join(scope)
    t0 = time.perf_counter()
    sched.run()
    wall = time.perf_counter() - t0
    sim_s = max(c.vtime, s.vtime) / SEC
    # per-request latency from the hub stats: sim duration / n
    return WorkloadResult("oltp", "livestack", sim_s, wall, {
        "avg_latency_us": sim_s / n_req * 1e6,
        "throughput_ops": n_req / sim_s,
    })


def oltp_des(n_req: int = 800, grain_ns: int = 1000) -> WorkloadResult:
    payloads = _oltp_payloads(4)
    store: dict = {}
    t0 = time.perf_counter()
    for i in range(4):
        txn_kernel(store, i, payloads[i])
    txn_ns = int((time.perf_counter() - t0) / 4 * SEC)
    wire_ns = int(LINK_LAT_S * SEC + 16_384 * 8 / LINK_BW * SEC)
    eng = DESEngine()
    state = {"left": n_req}

    def request():
        if state["left"] == 0:
            return
        state["left"] -= 1

        def arrive():
            fine_grained_compute(eng, eng.now, txn_ns, grain_ns, reply)

        def reply():
            eng.schedule(eng.now + wire_ns, request)

        eng.schedule(eng.now + wire_ns, arrive)

    request()
    stats = eng.run(wall_budget_s=10.0)
    per_req_events = max(1, txn_ns // grain_ns) + 2
    total_events = n_req * per_req_events
    wall = (stats["wall_s"] if stats["exhausted"]
            else extrapolate_wall_s(stats, total_events))
    sim_s = n_req * (txn_ns + 2 * wire_ns) / SEC
    return WorkloadResult("oltp", "des", sim_s, wall,
                          {"events": total_events})


# ------------------------------- kvstore -------------------------------------


def kv_physical(n_ops: int = 600, n_clients: int = 2) -> WorkloadResult:
    rng = np.random.default_rng(3)
    keys = rng.zipf(1.5, size=(n_clients, n_ops)) % 1024
    ups = [Wire(LINK_BW, LINK_LAT_S) for _ in range(n_clients)]
    downs = [Wire(LINK_BW, LINK_LAT_S) for _ in range(n_clients)]
    req = Wire(LINK_BW, LINK_LAT_S)   # client -> server mux
    store: dict = {i: float(i) for i in range(1024)}
    payload = np.random.default_rng(5).random(512)

    def server():
        for _ in range(n_clients * n_ops):
            ci, op, key = req.recv()
            if op == 0:
                kv_read(store, int(key))
            else:
                txn_kernel(store, int(key), payload)
            downs[ci].send(key, 128)

    def client(ci):
        for j in range(n_ops):
            req.send((ci, j % 10 == 0, keys[ci, j]), 1024)
            downs[ci].recv()

    th = [threading.Thread(target=server)] + [
        threading.Thread(target=client, args=(ci,))
        for ci in range(n_clients)]
    t0 = time.perf_counter()
    for t in th:
        t.start()
    for t in th:
        t.join()
    wall = time.perf_counter() - t0
    return WorkloadResult("kvstore", "physical", wall, wall,
                          {"runtime_s": wall})


def kv_livestack(n_ops: int = 600, n_clients: int = 2) -> WorkloadResult:
    rng = np.random.default_rng(3)
    keys = rng.zipf(1.5, size=(n_clients, n_ops)) % 1024
    hub = Hub("kv", calibrate_wire())
    sched = Scheduler(n_cpus=_host_cpus(), send_overhead_ns=2_000,
                      cpu_resource=True)
    sv = hub.attach(Endpoint("server"))
    ceps = [hub.attach(Endpoint(f"client{i}")) for i in range(n_clients)]
    store: dict = {i: float(i) for i in range(1024)}
    payload = np.random.default_rng(5).random(512)

    def server():
        for _ in range(n_clients * n_ops):
            msg = yield Recv(sv)
            ci, write, key = msg.payload
            if write:
                yield LiveCall(txn_kernel, (store, int(key), payload))
            else:
                yield LiveCall(kv_read, (store, int(key)))
            yield Send(sv, f"client{ci}", 128, payload=key)

    def client(ci):
        def body():
            for j in range(n_ops):
                yield Send(ceps[ci], "server", 1024,
                           payload=(ci, j % 10 == 0, keys[ci, j]))
                yield Recv(ceps[ci])
        return body

    s = sched.spawn(VTask("server", server(), kind="live"))
    cs = [sched.spawn(VTask(f"client{i}", client(i)(), kind="live"))
          for i in range(n_clients)]
    scope = Scope("kv", 200 * US)
    for t in [s] + cs:
        t.join(scope)
    t0 = time.perf_counter()
    sched.run()
    wall = time.perf_counter() - t0
    sim_s = max(t.vtime for t in [s] + cs) / SEC
    return WorkloadResult("kvstore", "livestack", sim_s, wall,
                          {"runtime_s": sim_s})


# ------------------------------- shuffle -------------------------------------


def _shards(n_workers: int, size: int = 400_000):
    rng = np.random.default_rng(11)
    return [rng.random(size) for _ in range(n_workers)]


def shuffle_physical(n_workers: int = 3, rounds: int = 6) -> WorkloadResult:
    shards = _shards(n_workers)
    for sh in shards:
        map_kernel(sh, n_workers)                   # warm-up
    wires = {(i, j): Wire(LINK_BW, LINK_LAT_S)
             for i in range(n_workers) for j in range(n_workers) if i != j}
    results = [0.0] * n_workers

    def worker(i):
        for _ in range(rounds):
            parts = map_kernel(shards[i], n_workers)
            for j in range(n_workers):
                if j != i:
                    wires[(i, j)].send(parts[j], parts[j].nbytes)
            mine = [parts[i]]
            for j in range(n_workers):
                if j != i:
                    mine.append(wires[(j, i)].recv())
            results[i] = reduce_kernel(mine)

    th = [threading.Thread(target=worker, args=(i,))
          for i in range(n_workers)]
    t0 = time.perf_counter()
    for t in th:
        t.start()
    for t in th:
        t.join()
    wall = time.perf_counter() - t0
    return WorkloadResult("shuffle", "physical", wall, wall,
                          {"runtime_s": wall})


def shuffle_livestack(n_workers: int = 3, rounds: int = 6
                      ) -> WorkloadResult:
    shards = _shards(n_workers)
    hub = Hub("shuffle", calibrate_wire())
    for sh in shards:
        map_kernel(sh, n_workers)                   # warm-up
    sched = Scheduler(n_cpus=_host_cpus(), send_overhead_ns=2_000,
                      cpu_resource=True)
    eps = [hub.attach(Endpoint(f"w{i}")) for i in range(n_workers)]

    def worker(i):
        def body():
            for _ in range(rounds):
                parts = yield LiveCall(map_kernel, (shards[i], n_workers))
                for j in range(n_workers):
                    if j != i:
                        yield Send(eps[i], f"w{j}", parts[j].nbytes,
                                   payload=parts[j])
                mine = [parts[i]]
                for j in range(n_workers):
                    if j != i:
                        msg = yield Recv(eps[i])
                        mine.append(msg.payload)
                yield LiveCall(reduce_kernel, (mine,))
        return body

    ts = [sched.spawn(VTask(f"w{i}", worker(i)(), kind="live"))
          for i in range(n_workers)]
    scope = Scope("shuffle", 500 * US)
    for t in ts:
        t.join(scope)
    t0 = time.perf_counter()
    sched.run()
    wall = time.perf_counter() - t0
    sim_s = max(t.vtime for t in ts) / SEC
    return WorkloadResult("shuffle", "livestack", sim_s, wall,
                          {"runtime_s": sim_s})


# ------------------------------- registry ------------------------------------


WORKLOADS = {
    "arith": {"physical": arith_physical, "livestack": arith_livestack,
              "des": arith_des, "instances": 1,
              "paper_row": "CoreMark", "metric": "iters_per_s"},
    "oltp": {"physical": oltp_physical, "livestack": oltp_livestack,
             "des": oltp_des, "instances": 2,
             "paper_row": "TPC-C (MySQL)", "metric": "throughput_ops"},
    "kvstore": {"physical": kv_physical, "livestack": kv_livestack,
                "instances": 3,
                "paper_row": "YCSB (HBase)", "metric": "runtime_s"},
    "shuffle": {"physical": shuffle_physical,
                "livestack": shuffle_livestack, "instances": 3,
                "paper_row": "TPC-DS 99 (Spark)", "metric": "runtime_s"},
}


def accuracy(pred: float, actual: float) -> float:
    """Paper-style accuracy: 1 - |pred - actual| / actual."""
    return max(0.0, 1.0 - abs(pred - actual) / max(actual, 1e-12))
