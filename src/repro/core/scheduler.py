"""Simulation-oriented scheduling (paper §3.2): the reference engine.

Deterministic in-process realization of LiveStack's scheduler:

* vtasks yield actions (see ``repro.core.vtask``); the yield points are
  the dispatch boundaries.
* Per round, up to ``n_cpus`` runnable vtasks satisfying the bounded-skew
  condition in **every** scope are dispatched (lowest-vtime first,
  deterministic id tie-break).  The globally minimal runnable vtask is
  always eligible (see ``tests/test_scheduler.py::test_no_livelock``), so
  the simulation cannot livelock while work remains.
* Live vtasks advance clock-derived vtime (measured host span x
  calibration, scaled by the cell-interference factor — imperfect
  isolation is folded into simulated time, §3.3); modeled vtasks advance
  by reported latency (sync return or async RunPage), and are preempted
  to FAULTY after ``preempt_after`` consecutive zero-progress dispatches.
* Blocked vtasks are excluded from scope minima; wake-up forwards their
  vtime to the wake-up's causal timestamp (message visibility time /
  event fire time) — deterministic regardless of how the orchestrator
  windows execution, so every engine produces identical timings.
* If nothing is runnable, the scheduler performs an idle jump to the
  earliest pending visibility/event time (a halted CPU observing elapsed
  time on resume).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

from repro.core import scope as scope_mod
from repro.core.cells import CellManager
from repro.core.ipc import Endpoint, Message
from repro.core.vtask import (Await, Compute, LiveCall, Recv, Send, State,
                              VTask, Yield)


@dataclasses.dataclass
class SchedStats:
    rounds: int = 0
    dispatches: int = 0
    live_calls: int = 0
    idle_jumps: int = 0
    preemptions: int = 0
    skew_stalls: int = 0          # eligible-check rejections
    max_skew_seen: int = 0
    window_runs: int = 0          # run_until invocations (orchestrator)
    gate_deferrals: int = 0       # wake-ups deferred past a strict bound
    wakes: int = 0                # successful blocked->runnable wake-ups


class DeadlockError(RuntimeError):
    pass


class Scheduler:
    def __init__(self, host: int = 0, n_cpus: int = 8,
                 cells: Optional[CellManager] = None,
                 preempt_after: int = 100,
                 send_overhead_ns: int = 500,
                 distributed: bool = False,
                 cpu_resource: bool = False):
        self.host = host
        self.n_cpus = n_cpus
        self.cells = cells or CellManager()
        self.tasks: List[VTask] = []
        self.preempt_after = preempt_after
        self.send_overhead_ns = send_overhead_ns
        self.distributed = distributed   # a remote host may still wake us
        # cpu_resource: model the host's CPUs as contended resources in
        # *virtual time* (per-CPU busy-until).  In the paper this happens
        # implicitly — vCPUs execute on real, time-shared cores and the
        # pvclock measures it; in-process live calls execute solo, so
        # co-located compute must queue for a simulated CPU instead.
        # Leave False for cluster sims where every vtask is its own
        # machine.
        self.cpu_resource = cpu_resource
        self._cpu_free_at: List[int] = [0] * n_cpus
        self.stats = SchedStats()
        self._inbound: Dict[int, Message] = {}    # task.id -> pending recv
        # strict window bound for the round being dispatched (async
        # engine); read by _exec_action so Recv/Await cannot idle-advance
        # a task past it.  Carried on the scheduler, not the _dispatch
        # signature, so tests may still wrap _dispatch(task).
        self._strict_gate: Optional[int] = None

    # -- registration --------------------------------------------------------
    def spawn(self, task: VTask) -> VTask:
        task.host = self.host
        self.tasks.append(task)
        for s in task.scopes:
            s.invalidate()
        return task

    # -- introspection -------------------------------------------------------
    def runnable(self) -> List[VTask]:
        return [t for t in self.tasks if t.state == State.RUNNABLE]

    def unfinished(self) -> List[VTask]:
        return [t for t in self.tasks
                if t.state in (State.RUNNABLE, State.BLOCKED)]

    def now(self) -> int:
        """Host-level simulated time = min over unfinished vtasks."""
        vs = [t.vtime for t in self.unfinished()]
        return min(vs) if vs else max(
            (t.vtime for t in self.tasks), default=0)

    def next_time(self) -> Optional[int]:
        """Conservative next-event time: min over runnable real vtasks'
        vtime and blocked vtasks' pending visibility.  Blocked vtasks with
        nothing pending cannot act (or send) until woken, so they do not
        hold the horizon back (classic PDES next-event semantics)."""
        times = []
        for t in self.tasks:
            if t.kind == "proxy":
                continue
            if t.state == State.RUNNABLE:
                times.append(t.vtime)
            elif t.state == State.BLOCKED and t._wait_reason:
                kind, obj = t._wait_reason
                v = (obj.head_visibility() if kind == "recv"
                     else obj.set_at_vtime)
                if v is not None:
                    times.append(max(t.vtime, v))
        return min(times) if times else None

    def horizon(self) -> int:
        """Completed simulated time = max vtime reached."""
        return max((t.vtime for t in self.tasks), default=0)

    # -- wake-ups -------------------------------------------------------------
    def _try_wake(self, task: VTask, bound: Optional[int] = None) -> bool:
        """Wake a blocked task to its pending visibility/event time.

        ``bound`` (async-engine strict window): a wake-up at or past the
        bound is deferred — a peer that has not run yet could still make
        an *earlier* message visible at the same endpoint, so waking past
        the bound would timestamp the task against the wrong message."""
        reason = task._wait_reason
        if reason is None:
            return False
        kind, obj = reason
        if kind == "recv":
            ep: Endpoint = obj
            vis = ep.head_visibility()
            if vis is None:
                return False
            if bound is not None and vis >= bound:
                self.stats.gate_deferrals += 1
                return False
            scope_mod.wake(task, at_vtime=vis)   # idle-until-interrupt
            task._wait_reason = None
            self.stats.wakes += 1
            return True
        if kind == "event":
            if obj.set_at_vtime is None:
                return False
            if bound is not None and obj.set_at_vtime >= bound:
                self.stats.gate_deferrals += 1
                return False
            scope_mod.wake(task, at_vtime=obj.set_at_vtime)
            task._wait_reason = None
            self.stats.wakes += 1
            return True
        return False

    def _wake_pass(self, bound: Optional[int] = None) -> None:
        for t in self.tasks:
            if t.state == State.BLOCKED:
                self._try_wake(t, bound=bound)

    # -- one action -----------------------------------------------------------
    def _advance(self, task: VTask, delta_ns: int) -> None:
        if delta_ns < 0:
            raise ValueError("vtime cannot go backwards")
        task.vtime += delta_ns
        for s in task.scopes:
            s.invalidate()

    def _advance_on_cpu(self, task: VTask, delta_ns: int) -> None:
        """Advance vtime by a compute span, queuing for a simulated CPU
        when cpu_resource accounting is on (virtual-time time-sharing)."""
        if not self.cpu_resource:
            self._advance(task, delta_ns)
            return
        cpu = min(range(self.n_cpus), key=self._cpu_free_at.__getitem__)
        start = max(task.vtime, self._cpu_free_at[cpu])
        end = start + delta_ns
        self._cpu_free_at[cpu] = end
        self._advance(task, end - task.vtime)

    def _exec_action(self, task: VTask, action):
        """Returns value to send into the generator on next dispatch.

        ``self._strict_gate`` (strict window bound): a Recv/Await may not
        idle-advance the task to a visibility/event time at or past the
        gate — a peer that has not run yet could still produce an earlier
        input, so the task blocks and is woken through the gated wake
        path instead."""
        gate = self._strict_gate
        if isinstance(action, Compute):
            progress = action.ns + task.run_page.drain()
            self._advance_on_cpu(task, progress)
            if task.kind == "modeled":
                if progress == 0:
                    task.zero_progress += 1
                    if task.zero_progress >= self.preempt_after:
                        task.state = State.FAULTY
                        self.stats.preemptions += 1
                        for s in task.scopes:
                            s.invalidate()
                else:
                    task.zero_progress = 0
            return None
        if isinstance(action, LiveCall):
            self.stats.live_calls += 1
            slow = self.cells.slowdown(task, self._coactive_cells(task))
            if action.cost_ns is not None:
                result = action.fn(*action.args, **action.kwargs)
                delta = int(action.cost_ns * slow)
            else:
                result, host_delta = task.clock.measure(
                    action.fn, *action.args, **action.kwargs)
                delta = int(host_delta * slow)
            delta += self.cells.switch_cost(task)
            task.stats["live_ns"] += delta
            self._advance_on_cpu(task, delta)
            return result
        if isinstance(action, Send):
            hub = action.endpoint.hub
            self._advance(task, self.send_overhead_ns)
            msg = hub.send(action.endpoint.name, action.dst,
                           action.size_bytes, task.vtime, action.payload)
            task.stats["msgs_tx"] += 1
            return msg
        if isinstance(action, Recv):
            msg = action.endpoint.pop_visible(task.vtime)
            if msg is not None:
                task.stats["msgs_rx"] += 1
                return msg
            vis = action.endpoint.head_visibility()
            if vis is not None and (gate is None or vis < gate):
                # message exists but not yet visible: idle until it is
                self._advance(task, vis - task.vtime)
                msg = action.endpoint.pop_visible(task.vtime)
                task.stats["msgs_rx"] += 1
                return msg
            if vis is not None:
                self.stats.gate_deferrals += 1
            task.state = State.BLOCKED
            task._wait_reason = ("recv", action.endpoint)
            for s in task.scopes:
                s.invalidate()
            return None
        if isinstance(action, Await):
            ev = action.event
            if ev.set_at_vtime is not None and (
                    gate is None or ev.set_at_vtime < gate):
                self._advance(task, max(0, ev.set_at_vtime - task.vtime))
                return None
            if ev.set_at_vtime is not None:
                self.stats.gate_deferrals += 1
            task.state = State.BLOCKED
            task._wait_reason = ("event", ev)
            for s in task.scopes:
                s.invalidate()
            return None
        if isinstance(action, Yield):
            return None
        raise TypeError(f"unknown action {action!r}")

    def _coactive_cells(self, task: VTask) -> List[str]:
        """Cells of other unfinished live tasks on this host (spatial
        interference candidates)."""
        return [t.cell for t in self.tasks
                if t is not task and t.cell is not None
                and t.state in (State.RUNNABLE, State.BLOCKED)]

    def _dispatch(self, task: VTask) -> None:
        task.stats["dispatches"] += 1
        self.stats.dispatches += 1
        if task._pending_action is not None:
            # retry the action that blocked (Recv/Await); the generator
            # must receive its real result, not None.
            action, task._pending_action = task._pending_action, None
        else:
            send_value = task.result
            task.result = None
            try:
                action = task.body.send(send_value)
            except StopIteration as stop:
                task.state = State.DONE
                task.result = getattr(stop, "value", None)
                for s in task.scopes:
                    s.invalidate()
                return
        value = self._exec_action(task, action)
        if task.state == State.BLOCKED:
            task._pending_action = action
            return
        task.result = value

    # -- main loop --------------------------------------------------------------
    def step_round(self, until_vtime: Optional[int] = None,
                   strict: bool = False) -> bool:
        """One dispatch round.  Returns False when nothing is left to do
        locally (all done, or stalled on remote proxy vtime / the epoch
        gate — the orchestrator then syncs proxies and resumes).

        ``until_vtime`` is the conservative epoch gate: only vtasks with
        vtime < until_vtime may dispatch this round.  With ``strict``
        (async engine), the gate also applies to idle-jump wake-ups: a
        blocked vtask whose pending visibility lies at or past the gate
        stays blocked, because a not-yet-sent remote message could still
        become visible *earlier* — waking past the gate would let the
        vtask miss it."""
        self.stats.rounds += 1
        self._wake_pass(until_vtime if strict else None)
        all_runnable = [t for t in self.runnable() if t.kind != "proxy"]
        runnable = all_runnable
        if until_vtime is not None:
            runnable = [t for t in runnable if t.vtime < until_vtime]
            if not runnable and all_runnable:
                return False            # everything is past the epoch gate
        if not runnable:
            blocked = [t for t in self.tasks
                       if t.state == State.BLOCKED and t.kind != "proxy"]
            if not blocked:
                return False
            # idle jump: earliest pending visibility/event
            horizon = None
            wakeable = []
            for t in blocked:
                kind, obj = t._wait_reason or (None, None)
                if kind == "recv":
                    v = obj.head_visibility()
                elif kind == "event":
                    v = obj.set_at_vtime
                else:
                    v = None
                if v is None:
                    continue
                if strict and until_vtime is not None and v >= until_vtime:
                    self.stats.gate_deferrals += 1
                    continue
                wakeable.append(t)
                horizon = v if horizon is None else min(horizon, v)
            if horizon is None:
                if self.distributed or (strict and until_vtime is not None):
                    # a remote host may still deliver; yield to orchestrator
                    return False
                raise DeadlockError(
                    f"host {self.host}: all tasks blocked with no pending "
                    f"messages/events: {blocked}")
            self.stats.idle_jumps += 1
            for t in wakeable:
                self._try_wake(t)
            return True
        # bounded-skew eligibility, lowest-vtime first; ineligible vtasks
        # are rescheduled (counted as skew stalls) until peers catch up
        runnable.sort(key=lambda t: (t.vtime, t.id))
        eligible = []
        for t in runnable:
            if scope_mod.all_eligible(t):
                eligible.append(t)
            else:
                self.stats.skew_stalls += 1
        picked = eligible[: self.n_cpus]
        if not picked:
            # every dispatchable vtask is skew-bound behind a proxy (remote)
            # vtime: yield to the orchestrator for a proxy sync.
            return False
        self._strict_gate = until_vtime if strict else None
        try:
            for t in picked:
                for s in t.scopes:
                    sv = s.vtime
                    if sv >= 0:
                        self.stats.max_skew_seen = max(
                            self.stats.max_skew_seen, t.vtime - sv)
                self._dispatch(t)
        finally:
            self._strict_gate = None
        return True

    def run(self, max_rounds: int = 10_000_000,
            until_vtime: Optional[int] = None) -> SchedStats:
        for _ in range(max_rounds):
            if not self.step_round(until_vtime):
                break
        return self.stats

    def run_until(self, bound: Optional[int],
                  max_rounds: int = 10_000_000) -> int:
        """Async-engine hook: drain every action strictly below ``bound``
        (None = no bound) without ever waking a vtask past it.  Returns
        the number of dispatches performed in this window."""
        self.stats.window_runs += 1
        before = self.stats.dispatches
        for _ in range(max_rounds):
            if not self.step_round(until_vtime=bound, strict=True):
                break
        return self.stats.dispatches - before
