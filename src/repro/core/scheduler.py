"""Simulation-oriented scheduling (paper §3.2): the reference engine.

Deterministic in-process realization of LiveStack's scheduler:

* vtasks yield actions (see ``repro.core.vtask``); the yield points are
  the dispatch boundaries.
* Per round, up to ``n_cpus`` runnable vtasks satisfying the bounded-skew
  condition in **every** scope are dispatched (lowest-vtime first,
  deterministic id tie-break).  The globally minimal runnable vtask is
  always eligible (see ``tests/test_scheduler.py::test_no_livelock``), so
  the simulation cannot livelock while work remains.
* Live vtasks advance clock-derived vtime (measured host span x
  calibration, scaled by the cell-interference factor — imperfect
  isolation is folded into simulated time, §3.3); modeled vtasks advance
  by reported latency (sync return or async RunPage), and are preempted
  to FAULTY after ``preempt_after`` consecutive zero-progress dispatches.
* Blocked vtasks are excluded from scope minima; wake-up forwards their
  vtime to the wake-up's causal timestamp (message visibility time /
  event fire time) — deterministic regardless of how the orchestrator
  windows execution, so every engine produces identical timings.

Hot-path structure (this is the per-round inner loop of every engine,
so none of it may scan the full task list):

* ``_runq`` — a lazy-invalidation min-heap of ``(vtime, id)`` over
  runnable non-proxy vtasks.  Entries go stale when a vtask blocks,
  finishes, or advances; stale entries are discarded at pop time
  (``_runq_v``/``_runq_on`` track the single live entry per vtask).
  Dispatch pops the heap in exactly the ``(vtime, id)`` order the old
  full sort produced, so dispatch order — and therefore every result —
  is bit-identical to the scan-based scheduler.
* ``_wake_q`` / ``_next_q`` — the visibility/event index: blocked
  vtasks with a known pending wake-up (message visibility or event fire
  time) are heap-indexed by that time (``_wake_q``) and by their
  conservative next-event time ``max(vtime, visibility)`` (``_next_q``).
  Wake passes drain only the entries below the window gate and
  ``next_time()`` peeks both heads, instead of scanning every task and
  every inbox per round.  Index entries are *hints*: ``_try_wake``
  revalidates everything, so stale entries are harmless.
* Scope minima are maintained incrementally by the scopes themselves
  (see ``repro.core.scope``): O(log n) heap pushes on vtime changes
  replace the O(members) recompute per invalidation.
* Cell co-activity (§3.3) is read from the :class:`CellManager`'s
  per-host live-cell multiset — O(1) aggregate reads per LiveCall,
  replacing the old O(tasks) coactive scan (see ``repro.core.cells``).
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import List, Optional

from repro.core import scope as scope_mod
from repro.core.cells import CellManager
from repro.core.vtask import (Await, Compute, LiveCall, Recv, Send, State,
                              VTask, Yield)


@dataclasses.dataclass
class SchedStats:
    rounds: int = 0
    dispatches: int = 0
    live_calls: int = 0
    preemptions: int = 0
    skew_stalls: int = 0          # eligible-check rejections
    max_skew_seen: int = 0
    window_runs: int = 0          # run_until invocations (orchestrator)
    gate_deferrals: int = 0       # wake-ups deferred past a strict bound
    wakes: int = 0                # successful blocked->runnable wake-ups


class DeadlockError(RuntimeError):
    """Conservative engines raise this when no task can make progress.

    ``info`` is an optional structured detail (surfaced as
    ``SimReport.detail_info``): engines populate it with the wedged
    hosts and, for membership scenarios, any still-pending joins, so a
    failure names the responsible host instead of only carrying prose.
    """

    def __init__(self, message: str, info: Optional[dict] = None):
        super().__init__(message)
        self.info: dict = dict(info or {})


class Scheduler:
    def __init__(self, host: int = 0, n_cpus: int = 8,
                 cells: Optional[CellManager] = None,
                 preempt_after: int = 100,
                 send_overhead_ns: int = 500,
                 distributed: bool = False,
                 cpu_resource: bool = False):
        self.host = host
        self.n_cpus = n_cpus
        # cell state is keyed by host (one manager per simulated host,
        # facade-constructed in every engine); the default manager
        # inherits this scheduler's host id
        self.cells = cells or CellManager(host=host)
        self.tasks: List[VTask] = []
        self.preempt_after = preempt_after
        self.send_overhead_ns = send_overhead_ns
        self.distributed = distributed   # a remote host may still wake us
        # cpu_resource: model the host's CPUs as contended resources in
        # *virtual time* (per-CPU busy-until).  In the paper this happens
        # implicitly — vCPUs execute on real, time-shared cores and the
        # pvclock measures it; in-process live calls execute solo, so
        # co-located compute must queue for a simulated CPU instead.
        # Leave False for cluster sims where every vtask is its own
        # machine.
        self.cpu_resource = cpu_resource
        self._cpu_free_at: List[int] = [0] * n_cpus
        self.stats = SchedStats()
        # strict window bound for the round being dispatched (async
        # engine); read by _exec_action so Recv/Await cannot idle-advance
        # a task past it.  Carried on the scheduler, not the _dispatch
        # signature, so tests may still wrap _dispatch(task).
        self._strict_gate: Optional[int] = None
        # hot-path indexes (see module docstring)
        self._runq: List[tuple] = []       # (vtime, id, task), lazy
        self._wake_q: List[tuple] = []     # (wake time, id, task), lazy
        self._next_q: List[tuple] = []     # (max(vtime, wake), id, task)
        self._n_blocked = 0                # blocked non-proxy tasks
        self._n_unfinished = 0             # runnable+blocked non-proxy

    # -- registration --------------------------------------------------------
    def spawn(self, task: VTask) -> VTask:
        task.host = self.host
        task.sched = self
        if task.cell is not None and task.cell in self.cells.cells:
            # constructor-labelled cell (VTask(cell=...)): register it
            # in this host's live-cell multiset so it spatially
            # interferes like an explicitly assign()ed task.  An
            # unknown name keeps the core's lenient no-op semantics
            # (the facade validates declarations at build time).
            self.cells.assign(task, task.cell)
        self.tasks.append(task)
        if task.kind != "proxy":
            if task.state in (State.RUNNABLE, State.BLOCKED):
                self._n_unfinished += 1
            if task.state == State.BLOCKED:
                self._n_blocked += 1
        self._runq_push(task)
        for s in task.scopes:
            s.notify(task)
        return task

    # -- runnable index ------------------------------------------------------
    def _runq_push(self, task: VTask) -> None:
        """Ensure a live heap entry exists for a runnable non-proxy task
        at its current vtime (no-op otherwise; duplicates are avoided by
        tracking the one live entry per task)."""
        if task.state is not State.RUNNABLE or task.kind == "proxy":
            return
        if task._runq_on and task._runq_v == task.vtime:
            return
        task._runq_on = True
        task._runq_v = task.vtime
        heapq.heappush(self._runq, (task.vtime, task.id, task))

    def _runq_head(self) -> bool:
        """Drop stale heap heads; True iff a valid head remains."""
        q = self._runq
        while q:
            v, _, t = q[0]
            if t._runq_on and t._runq_v == v:
                if t.state is State.RUNNABLE and t.vtime == v:
                    return True
                t._runq_on = False      # the live entry went stale
            heapq.heappop(q)
        return False

    def _runq_min(self) -> Optional[int]:
        return self._runq[0][0] if self._runq_head() else None

    # -- visibility/event index ----------------------------------------------
    def _wait_push(self, task: VTask, wake_time: Optional[int]) -> None:
        """Index a blocked task's pending wake-up (message visibility /
        event fire time).  Called at block time, by Endpoint.deliver for
        messages arriving while blocked, and by Event.fire."""
        if wake_time is None or task.kind == "proxy":
            return
        if task._wait_on and task._wait_v is not None \
                and task._wait_v <= wake_time:
            return                  # an earlier-or-equal entry is live
        task._wait_on = True
        task._wait_v = wake_time
        heapq.heappush(self._wake_q, (wake_time, task.id, task))
        heapq.heappush(self._next_q,
                       (max(task.vtime, wake_time), task.id, task))

    def _wake_min(self) -> Optional[int]:
        """Earliest indexed pending wake-up (conservative: may be lower
        than the true wake time for a re-blocked task, never higher)."""
        q = self._wake_q
        while q:
            v, _, t = q[0]
            if t.state is State.BLOCKED and t._wait_reason is not None:
                return v
            heapq.heappop(q)
        return None

    def _blocked_next_min(self) -> Optional[int]:
        """Min over blocked tasks of max(vtime, pending wake time) —
        the blocked contribution to next_time()."""
        q = self._next_q
        while q:
            k, _, t = q[0]
            if t.state is State.BLOCKED and t._wait_reason is not None:
                kind, obj = t._wait_reason
                v = (obj.head_visibility() if kind == "recv"
                     else obj.set_at_vtime)
                if v is not None and max(t.vtime, v) == k:
                    return k
            heapq.heappop(q)
        return None

    # -- introspection -------------------------------------------------------
    def runnable(self) -> List[VTask]:
        return [t for t in self.tasks if t.state == State.RUNNABLE]

    def unfinished(self) -> List[VTask]:
        return [t for t in self.tasks
                if t.state in (State.RUNNABLE, State.BLOCKED)]

    def has_unfinished(self) -> bool:
        """O(1) liveness check over non-proxy tasks."""
        return self._n_unfinished > 0

    def now(self) -> int:
        """Host-level simulated time = min over unfinished vtasks."""
        vs = [t.vtime for t in self.unfinished()]
        return min(vs) if vs else max(
            (t.vtime for t in self.tasks), default=0)

    def next_time(self) -> Optional[int]:
        """Conservative next-event time: min over runnable real vtasks'
        vtime and blocked vtasks' pending visibility.  Blocked vtasks with
        nothing pending cannot act (or send) until woken, so they do not
        hold the horizon back (classic PDES next-event semantics).
        O(1) amortized via the runnable + visibility indexes."""
        rv = self._runq_min()
        bv = self._blocked_next_min()
        if rv is None:
            return bv
        if bv is None:
            return rv
        return min(rv, bv)

    def quiescent_below(self, bound: Optional[int]) -> bool:
        """True iff a strict ``run_until(bound)`` is provably a no-op:
        nothing runnable and no pending wake-up lies below the bound
        (``bound=None`` checks for any work at all).  The orchestrator
        uses this to skip idle hosts without calling into them."""
        rv = self._runq_min()
        if rv is not None and (bound is None or rv < bound):
            return False
        wv = self._wake_min()
        return wv is None or (bound is not None and wv >= bound)

    def horizon(self) -> int:
        """Completed simulated time = max vtime reached."""
        return max((t.vtime for t in self.tasks), default=0)

    # -- wake-ups -------------------------------------------------------------
    def _try_wake(self, task: VTask, bound: Optional[int] = None) -> bool:
        """Wake a blocked task to its pending visibility/event time.

        ``bound`` (async-engine strict window): a wake-up at or past the
        bound is deferred — a peer that has not run yet could still make
        an *earlier* message visible at the same endpoint, so waking past
        the bound would timestamp the task against the wrong message."""
        reason = task._wait_reason
        if reason is None:
            return False
        kind, obj = reason
        vis = (obj.head_visibility() if kind == "recv"
               else obj.set_at_vtime)
        if vis is None:
            return False
        if bound is not None and vis >= bound:
            self.stats.gate_deferrals += 1
            return False
        scope_mod.wake(task, at_vtime=vis)   # idle-until-interrupt
        task._wait_reason = None
        task._wait_on = False
        task._wait_v = None
        self.stats.wakes += 1
        return True

    def _wake_pass(self, bound: Optional[int] = None) -> None:
        """Wake every blocked task whose indexed pending wake-up lies
        below ``bound`` (everything pending when ``bound`` is None).
        Drains only the index entries below the gate — entries at or
        past it stay for future, larger windows."""
        q = self._wake_q
        while q:
            v, _, t = q[0]
            if bound is not None and v >= bound:
                break
            heapq.heappop(q)
            if t._wait_v == v:
                t._wait_on = False      # live entry consumed
                t._wait_v = None
            if t.state is State.BLOCKED:
                self._try_wake(t, bound=bound)

    # -- one action -----------------------------------------------------------
    def _advance(self, task: VTask, delta_ns: int) -> None:
        if delta_ns < 0:
            raise ValueError("vtime cannot go backwards")
        task.vtime += delta_ns

    def _advance_on_cpu(self, task: VTask, delta_ns: int) -> None:
        """Advance vtime by a compute span, queuing for a simulated CPU
        when cpu_resource accounting is on (virtual-time time-sharing)."""
        if not self.cpu_resource:
            self._advance(task, delta_ns)
            return
        cpu = min(range(self.n_cpus), key=self._cpu_free_at.__getitem__)
        start = max(task.vtime, self._cpu_free_at[cpu])
        end = start + delta_ns
        self._cpu_free_at[cpu] = end
        self._advance(task, end - task.vtime)

    def _block(self, task: VTask, reason) -> None:
        task.state = State.BLOCKED
        task._wait_reason = reason
        self._n_blocked += 1

    def _exec_action(self, task: VTask, action):
        """Returns value to send into the generator on next dispatch.

        ``self._strict_gate`` (strict window bound): a Recv/Await may not
        idle-advance the task to a visibility/event time at or past the
        gate — a peer that has not run yet could still produce an earlier
        input, so the task blocks and is woken through the gated wake
        path instead."""
        gate = self._strict_gate
        if isinstance(action, Compute):
            progress = action.ns + task.run_page.drain()
            self._advance_on_cpu(task, progress)
            if task.kind == "modeled":
                if progress == 0:
                    task.zero_progress += 1
                    if task.zero_progress >= self.preempt_after:
                        task.state = State.FAULTY
                        self._n_unfinished -= 1
                        self.stats.preemptions += 1
                else:
                    task.zero_progress = 0
            return None
        if isinstance(action, LiveCall):
            self.stats.live_calls += 1
            # co-activity comes from the manager's per-host live-cell
            # multiset (O(1) aggregates), not a task scan
            slow = self.cells.slowdown(task)
            if action.cost_ns is not None:
                if action.cost_ns <= 0:
                    raise ValueError(
                        f"task {task.name!r}: LiveCall "
                        f"{action.label or action.fn!r} has "
                        f"cost_ns={action.cost_ns}; live costs must be "
                        f">= 1 ns (a 0-cost live call would let the "
                        f"task spin without advancing vtime)")
                result = action.fn(*action.args, **action.kwargs)
                delta = int(action.cost_ns * slow)
            else:
                result, host_delta = task.clock.measure(
                    action.fn, *action.args, **action.kwargs)
                # zero/negative measured spans (sub-ns callables, timer
                # warp) must still advance vtime — conservative
                # lookahead needs monotone progress
                delta = max(1, int(host_delta * slow))
            delta += self.cells.switch_cost(task)
            task.stats["live_ns"] += delta
            self._advance_on_cpu(task, delta)
            return result
        if isinstance(action, Send):
            hub = action.endpoint.hub
            self._advance(task, self.send_overhead_ns)
            msg = hub.send(action.endpoint.name, action.dst,
                           action.size_bytes, task.vtime, action.payload)
            task.stats["msgs_tx"] += 1
            return msg
        if isinstance(action, Recv):
            msg = action.endpoint.pop_visible(task.vtime)
            if msg is not None:
                task.stats["msgs_rx"] += 1
                return msg
            vis = action.endpoint.head_visibility()
            if vis is not None and (gate is None or vis < gate):
                # message exists but not yet visible: idle until it is
                self._advance(task, vis - task.vtime)
                msg = action.endpoint.pop_visible(task.vtime)
                task.stats["msgs_rx"] += 1
                return msg
            if vis is not None:
                self.stats.gate_deferrals += 1
            self._block(task, ("recv", action.endpoint))
            if task not in action.endpoint._waiters:
                action.endpoint._waiters.append(task)
            self._wait_push(task, vis)
            return None
        if isinstance(action, Await):
            ev = action.event
            if ev.set_at_vtime is not None and (
                    gate is None or ev.set_at_vtime < gate):
                self._advance(task, max(0, ev.set_at_vtime - task.vtime))
                return None
            if ev.set_at_vtime is not None:
                self.stats.gate_deferrals += 1
            self._block(task, ("event", ev))
            if task not in ev.waiters:
                ev.waiters.append(task)
            self._wait_push(task, ev.set_at_vtime)
            return None
        if isinstance(action, Yield):
            return None
        raise TypeError(f"unknown action {action!r}")

    def _dispatch(self, task: VTask) -> None:
        task.stats["dispatches"] += 1
        self.stats.dispatches += 1
        if task._pending_action is not None:
            # retry the action that blocked (Recv/Await); the generator
            # must receive its real result, not None.
            action, task._pending_action = task._pending_action, None
        else:
            send_value = task.result
            task.result = None
            try:
                action = task.body.send(send_value)
            except StopIteration as stop:
                task.state = State.DONE
                task.result = getattr(stop, "value", None)
                self._n_unfinished -= 1
                return
        value = self._exec_action(task, action)
        if task.state == State.BLOCKED:
            task._pending_action = action
            return
        task.result = value

    # -- main loop --------------------------------------------------------------
    def step_round(self, until_vtime: Optional[int] = None,
                   strict: bool = False) -> bool:
        """One dispatch round.  Returns False when nothing is left to do
        locally (all done, or stalled on remote proxy vtime / the epoch
        gate — the orchestrator then syncs proxies and resumes).

        ``until_vtime`` is the conservative epoch gate: only vtasks with
        vtime < until_vtime may dispatch this round.  With ``strict``
        (async engine), the gate also applies to wake-ups: a blocked
        vtask whose pending visibility lies at or past the gate stays
        blocked, because a not-yet-sent remote message could still
        become visible *earlier* — waking past the gate would let the
        vtask miss it."""
        self.stats.rounds += 1
        self._wake_pass(until_vtime if strict else None)
        q = self._runq
        if not self._runq_head():
            # nothing runnable; the wake pass above already drained
            # every pending wake-up below the gate
            if self._n_blocked == 0:
                return False            # all done/faulty
            if self.distributed or (strict and until_vtime is not None):
                # a remote host may still deliver; yield to orchestrator
                return False
            blocked = [t for t in self.tasks
                       if t.state == State.BLOCKED and t.kind != "proxy"]
            raise DeadlockError(
                f"host {self.host}: all tasks blocked with no pending "
                f"messages/events: {blocked}")
        if until_vtime is not None and q[0][0] >= until_vtime:
            return False                # everything is past the epoch gate
        # bounded-skew eligibility, lowest-(vtime, id) first — the heap
        # pops in exactly the order the old full sort produced.
        # Ineligible vtasks are re-queued (counted as skew stalls) until
        # peers catch up.
        picked: List[VTask] = []
        stalled: List[VTask] = []
        while len(picked) < self.n_cpus:
            if not self._runq_head():
                break
            v, _, t = q[0]
            if until_vtime is not None and v >= until_vtime:
                break
            heapq.heappop(q)
            t._runq_on = False
            if scope_mod.all_eligible(t):
                picked.append(t)
            else:
                self.stats.skew_stalls += 1
                stalled.append(t)
        for t in stalled:
            self._runq_push(t)
        if not picked:
            # every dispatchable vtask is skew-bound behind a proxy (remote)
            # vtime: yield to the orchestrator for a proxy sync.
            return False
        if len(picked) == self.n_cpus and self._runq_head():
            # visibility probe: the next-in-line vtask is examined even
            # though the CPUs are full, so a skew-held vtask still shows
            # up in the stall counter (the old full scan counted every
            # ineligible runnable per round).
            v, _, t = q[0]
            if (until_vtime is None or v < until_vtime) \
                    and not scope_mod.all_eligible(t):
                self.stats.skew_stalls += 1
        self._strict_gate = until_vtime if strict else None
        try:
            for t in picked:
                for s in t.scopes:
                    sv = s.vtime
                    if sv >= 0:
                        self.stats.max_skew_seen = max(
                            self.stats.max_skew_seen, t.vtime - sv)
                v_before = t.vtime
                self._dispatch(t)
                if t.state is State.RUNNABLE:
                    self._runq_push(t)
                    if t.vtime != v_before:
                        for s in t.scopes:
                            s.notify(t)
        finally:
            self._strict_gate = None
        return True

    def run(self, max_rounds: int = 10_000_000,
            until_vtime: Optional[int] = None) -> SchedStats:
        for _ in range(max_rounds):
            if not self.step_round(until_vtime):
                break
        return self.stats

    def run_until(self, bound: Optional[int],
                  max_rounds: int = 10_000_000) -> int:
        """Async-engine hook: drain every action strictly below ``bound``
        (None = no bound) without ever waking a vtask past it.  Returns
        the number of dispatches performed in this window."""
        self.stats.window_runs += 1
        before = self.stats.dispatches
        for _ in range(max_rounds):
            if not self.step_round(until_vtime=bound, strict=True):
                break
        return self.stats.dispatches - before
