"""Cluster model: TPU pods as LiveStack components.

Maps a production mesh (16x16 chips/pod, 2 pods) onto the simulation
substrate: every chip is a vtask; ICI links and the DCN are hubs; one
synchronization scope per collective group.  The per-chip compute/step
durations come from the dry-run roofline terms (``results/dryrun``) — the
cost-derived vtime model of DESIGN.md — optionally calibrated by really
executing a reduced-config step on the host (live calibration).

Since the `repro.sim` facade landed, this module holds the *specs*
(:class:`ClusterSpec`, :class:`StepCost`, :class:`StragglerSpec`) plus
two thin adapters kept for the legacy call sites:
``build_training_cluster`` and ``build_rack_cluster`` construct their
simulations through :class:`repro.sim.Simulation` and are verified
bit-identical to direct hand-wiring (``tests/test_sim_equivalence.py``).
New code should use `repro.sim` directly — declarative
topology/placement/workloads/fault injection, structured
:class:`~repro.sim.report.SimReport` results.
"""
from __future__ import annotations

import dataclasses
import json
import math
import pathlib
from typing import Callable, Optional, Tuple

from repro.core.ipc import LinkSpec
from repro.core.vtime import SEC, CostModel

RESULTS = pathlib.Path(__file__).resolve().parents[3] / "results" / "dryrun"


@dataclasses.dataclass(frozen=True)
class ClusterSpec:
    n_pods: int = 1
    chips_per_pod: int = 256
    ici_bw_Bps: float = 50e9            # per link
    ici_lat_ns: int = 1_000
    dcn_bw_Bps: float = 25e9
    dcn_lat_ns: int = 10_000
    cost: CostModel = CostModel()

    @property
    def n_chips(self) -> int:
        return self.n_pods * self.chips_per_pod


@dataclasses.dataclass
class StepCost:
    """Per-chip per-step cost (from the dry-run artifact or analytic)."""
    compute_ns: int
    ici_bytes: int                      # per-chip wire bytes per step
    dcn_bytes: int = 0

    @staticmethod
    def from_dryrun(arch: str, shape: str, mesh: str = "16x16",
                    cost: CostModel = CostModel(),
                    variant: str = "") -> "StepCost":
        """Prefer the trip-count-corrected costs (results/costs, see
        launch/costcount.py); fall back to the raw dry-run record.
        ``variant`` selects an optimized §Perf configuration."""
        suffix = f"__{variant}" if variant else ""
        corrected = (RESULTS.parent / "costs"
                     / f"{arch}__{shape}__{mesh}{suffix}.json")
        if corrected.exists():
            rec = json.loads(corrected.read_text())
            if rec.get("status") == "ok":
                c = rec["corrected"]
                compute_ns = int(max(c["flops"] / cost.peak_flops,
                                     c["bytes"] / cost.hbm_bw) * SEC)
                return StepCost(compute_ns=compute_ns,
                                ici_bytes=int(c["coll_bytes"]))
        f = RESULTS / f"{arch}__{shape}__{mesh}.json"
        rec = json.loads(f.read_text())
        if rec["status"] != "ok":
            raise ValueError(f"dry-run cell {f.name}: {rec['status']}")
        flops = rec["flops_per_chip"]
        bts = rec["bytes_per_chip"]
        coll = rec["collectives"]
        ici = sum(v for k, v in coll.items() if k != "count")
        compute_ns = int(max(flops / cost.peak_flops,
                             bts / cost.hbm_bw) * SEC)
        return StepCost(compute_ns=compute_ns, ici_bytes=int(ici))


@dataclasses.dataclass
class StragglerSpec:
    chip: int                           # straggling chip index
    slowdown: float = 2.0               # compute multiplier


def build_training_cluster(
    spec: ClusterSpec,
    step_cost: StepCost,
    n_steps: int,
    *,
    skew_bound_ns: int = 1_000_000,
    stragglers: Tuple[StragglerSpec, ...] = (),
    fail_at: Optional[Tuple[int, int]] = None,   # (chip, step) -> dies
    live_step_fn: Optional[Callable] = None,     # executed natively per step
    chips_per_host: int = 0,                     # 0 = all on one scheduler
    mode: str = "async",                         # engine when sharded
):
    """Build a data-parallel training simulation (adapter over
    `repro.sim`).

    ``chips_per_host == 0`` keeps every chip on one Scheduler (the
    legacy shape).  ``chips_per_host > 0`` shards chips across
    ``ceil(n_chips / chips_per_host)`` orchestrated hosts: placement
    routes through ``Orchestrator.co_locate`` on the ring-traffic
    matrix (so ring neighbors co-locate), host pairs that share a pod
    get an ICI-class interconnect and pod-disjoint pairs a DCN-class
    one, and ``mode`` picks the orchestration engine.

    Returns ``(engine, tasks, ctx)`` where ``engine`` is a Scheduler
    (single-host) or an Orchestrator (sharded) — both have ``.run()``.
    ``ctx`` additionally carries the built ``repro.sim.Simulation`` as
    ``ctx["sim"]``.
    """
    from repro.sim import (ChipRingTraining, FailTask, Scenario,
                           Simulation, Straggler, Topology)

    wl = ChipRingTraining(spec, step_cost, n_steps,
                          skew_bound_ns=skew_bound_ns,
                          live_step_fn=live_step_fn)
    # legacy semantics: duplicate straggler specs for one chip override
    # (dict last-wins), they do not compound like stacked injections
    slowdown = {s.chip: s.slowdown for s in stragglers}
    injections = tuple(Straggler(f"chip{c}", m)
                       for c, m in slowdown.items())
    if fail_at is not None:
        injections += (FailTask(f"chip{fail_at[0]}",
                                at_compute=fail_at[1]),)
    scenario = Scenario("training", injections)

    if chips_per_host <= 0:
        sim = Simulation(Topology.single_host(n_cpus=64), wl, scenario,
                         mode="single")
    else:
        from repro.core.orchestrator import Orchestrator

        n_hosts = math.ceil(spec.n_chips / chips_per_host)
        # placement first (routed through co_locate on the ring-traffic
        # matrix), then host links derived from where chips actually
        # landed: hosts sharing a pod get an ICI-class interconnect,
        # pod-disjoint hosts a DCN-class one.  Deriving from the real
        # placement (not an assumed contiguous sharding) keeps the link
        # classes consistent even when heavy cross-pod traffic makes
        # co_locate merge leaders across pods.
        placement = Orchestrator.co_locate(
            [f"chip{c}" for c in range(spec.n_chips)], wl.traffic(),
            n_hosts, chips_per_host)
        host_pods = {}
        for c in range(spec.n_chips):
            host_pods.setdefault(placement[f"chip{c}"], set()).add(
                c // spec.chips_per_pod)
        topo = Topology(n_hosts=n_hosts,
                        n_cpus=max(1, min(64, chips_per_host)))
        ici = LinkSpec(bandwidth_bps=spec.ici_bw_Bps * 8,
                       latency_ns=spec.ici_lat_ns)
        dcn = LinkSpec(bandwidth_bps=spec.dcn_bw_Bps * 8,
                       latency_ns=spec.dcn_lat_ns)
        for a in range(n_hosts):
            for b in range(a + 1, n_hosts):
                shared_pod = (host_pods.get(a, set())
                              & host_pods.get(b, set()))
                topo.link(a, b, ici if shared_pod else dcn)
        sim = Simulation(topo, wl, scenario, mode=mode,
                         placement=placement)
    sim.build()
    engine = sim.scheduler if sim.scheduler is not None \
        else sim.orchestrator
    ctx = {"scope": sim.scopes[0] if len(sim.scopes) == 1
           else sim.scopes,
           "hubs": list(sim.hubs.values()),
           "done_steps": wl.done_steps,
           "endpoints": [sim.endpoints[f"chip{c}"]
                         for c in range(spec.n_chips)],
           "sim": sim}
    return engine, sim.tasks, ctx


def build_rack_cluster(
    *,
    n_racks: int = 2,
    hosts_per_rack: int = 2,
    n_iters: int = 200,
    compute_ns: int = 5_000,
    msg_bytes: int = 4096,
    cross_every: int = 20,
    intra_link: LinkSpec = LinkSpec(bandwidth_bps=80e9 * 8,
                                    latency_ns=2_000),
    cross_link: LinkSpec = LinkSpec(bandwidth_bps=25e9 * 8,
                                    latency_ns=50_000),
    rack_slowdown: Tuple[float, ...] = (),
    skew_bound_ns: int = 0,
    mode: str = "async",
):
    """Heterogeneous-latency multi-host topology (paper §3.5), adapter
    over `repro.sim`: a :class:`~repro.sim.workloads.RackRing` workload
    on a :meth:`~repro.sim.topology.Topology.racks` topology, one worker
    pinned per host.  ``rack_slowdown`` becomes per-worker Straggler
    injections (imbalanced racks).

    Returns (orchestrator, tasks, ctx); ``ctx["sim"]`` carries the
    built Simulation.
    """
    from repro.sim import RackRing, Scenario, Simulation, Topology

    wl = RackRing(n_racks=n_racks, hosts_per_rack=hosts_per_rack,
                  n_iters=n_iters, compute_ns=compute_ns,
                  msg_bytes=msg_bytes, cross_every=cross_every,
                  skew_bound_ns=skew_bound_ns)
    topo = Topology.racks(n_racks, hosts_per_rack, intra_link,
                          cross_link, n_cpus=4)
    sim = Simulation(topo, wl,
                     Scenario("rack", wl.stragglers(rack_slowdown)),
                     mode=mode, placement=wl.default_placement())
    sim.build()
    ctx = {"hubs": list(sim.hubs.values()),
           "iters_done": wl.iters_done,
           "endpoints": [sim.endpoints[f"w{h}"]
                         for h in range(wl.n_workers)],
           "sim": sim}
    return sim.orchestrator, sim.tasks, ctx


def analytic_step_ns(spec: ClusterSpec, step_cost: StepCost) -> int:
    """Closed-form per-step time (the validation target for the sim)."""
    comm = step_cost.ici_bytes / spec.ici_bw_Bps * SEC + spec.ici_lat_ns
    dcn = (step_cost.dcn_bytes / spec.dcn_bw_Bps * SEC + spec.dcn_lat_ns
           if spec.n_pods > 1 else 0)
    return int(step_cost.compute_ns + comm + dcn)
