"""Cluster model: TPU pods as LiveStack components.

Maps a production mesh (16x16 chips/pod, 2 pods) onto the simulation
substrate: every chip is a vtask; ICI links and the DCN are hubs; one
synchronization scope per collective group.  The per-chip compute/step
durations come from the dry-run roofline terms (``results/dryrun``) — the
cost-derived vtime model of DESIGN.md — optionally calibrated by really
executing a reduced-config step on the host (live calibration).

This is the paper's use case pointed at our workloads: "what will this
unmodified training stack do on the 512-chip cluster I don't have yet?"
— including stragglers, failures, and interference, which closed-form
rooflines cannot express.
"""
from __future__ import annotations

import dataclasses
import json
import pathlib
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.core.ipc import Endpoint, Hub, LinkSpec
from repro.core.scheduler import Scheduler
from repro.core.scope import Scope
from repro.core.vtask import Compute, LiveCall, Recv, Send, VTask
from repro.core.vtime import SEC, US, CostModel

RESULTS = pathlib.Path(__file__).resolve().parents[3] / "results" / "dryrun"


@dataclasses.dataclass(frozen=True)
class ClusterSpec:
    n_pods: int = 1
    chips_per_pod: int = 256
    ici_bw_Bps: float = 50e9            # per link
    ici_lat_ns: int = 1_000
    dcn_bw_Bps: float = 25e9
    dcn_lat_ns: int = 10_000
    cost: CostModel = CostModel()

    @property
    def n_chips(self) -> int:
        return self.n_pods * self.chips_per_pod


@dataclasses.dataclass
class StepCost:
    """Per-chip per-step cost (from the dry-run artifact or analytic)."""
    compute_ns: int
    ici_bytes: int                      # per-chip wire bytes per step
    dcn_bytes: int = 0

    @staticmethod
    def from_dryrun(arch: str, shape: str, mesh: str = "16x16",
                    cost: CostModel = CostModel(),
                    variant: str = "") -> "StepCost":
        """Prefer the trip-count-corrected costs (results/costs, see
        launch/costcount.py); fall back to the raw dry-run record.
        ``variant`` selects an optimized §Perf configuration."""
        suffix = f"__{variant}" if variant else ""
        corrected = (RESULTS.parent / "costs"
                     / f"{arch}__{shape}__{mesh}{suffix}.json")
        if corrected.exists():
            rec = json.loads(corrected.read_text())
            if rec.get("status") == "ok":
                c = rec["corrected"]
                compute_ns = int(max(c["flops"] / cost.peak_flops,
                                     c["bytes"] / cost.hbm_bw) * SEC)
                return StepCost(compute_ns=compute_ns,
                                ici_bytes=int(c["coll_bytes"]))
        f = RESULTS / f"{arch}__{shape}__{mesh}.json"
        rec = json.loads(f.read_text())
        if rec["status"] != "ok":
            raise ValueError(f"dry-run cell {f.name}: {rec['status']}")
        flops = rec["flops_per_chip"]
        bts = rec["bytes_per_chip"]
        coll = rec["collectives"]
        ici = sum(v for k, v in coll.items() if k != "count")
        compute_ns = int(max(flops / cost.peak_flops,
                             bts / cost.hbm_bw) * SEC)
        return StepCost(compute_ns=compute_ns, ici_bytes=int(ici))


@dataclasses.dataclass
class StragglerSpec:
    chip: int                           # straggling chip index
    slowdown: float = 2.0               # compute multiplier


def build_training_cluster(
    spec: ClusterSpec,
    step_cost: StepCost,
    n_steps: int,
    *,
    skew_bound_ns: int = 1_000_000,
    stragglers: Tuple[StragglerSpec, ...] = (),
    fail_at: Optional[Tuple[int, int]] = None,   # (chip, step) -> dies
    live_step_fn: Optional[Callable] = None,     # executed natively per step
    chips_per_host: int = 0,                     # 0 = all on one scheduler
) -> Tuple[Scheduler, List[VTask], Dict]:
    """Build a data-parallel training simulation.

    Per step each chip: compute (roofline-derived or live-measured), then
    exchanges its per-step collective bytes with its ring neighbor through
    the pod hub (reduce-scatter + all-gather ring), with cross-pod
    gradient reduction over the DCN once per step.
    """
    sched = Scheduler(n_cpus=64)
    pod_hubs = [Hub(f"ici{p}", LinkSpec(bandwidth_bps=spec.ici_bw_Bps * 8,
                                        latency_ns=spec.ici_lat_ns))
                for p in range(spec.n_pods)]
    dcn = Hub("dcn", LinkSpec(bandwidth_bps=spec.dcn_bw_Bps * 8,
                              latency_ns=spec.dcn_lat_ns))
    scope = Scope("train", skew_bound_ns)
    slowdown = {s.chip: s.slowdown for s in stragglers}

    endpoints = []
    dcn_eps = []
    for c in range(spec.n_chips):
        p = c // spec.chips_per_pod
        ep = pod_hubs[p].attach(Endpoint(f"chip{c}"))
        endpoints.append(ep)
        if c % spec.chips_per_pod == 0:      # pod leader joins the DCN
            dcn_eps.append(dcn.attach(Endpoint(f"pod{p}")))

    tasks: List[VTask] = []
    done_steps = np.zeros(spec.n_chips, dtype=np.int64)

    def chip_body(c: int):
        p = c // spec.chips_per_pod
        right = p * spec.chips_per_pod + (c + 1) % spec.chips_per_pod
        ep = endpoints[c]
        mult = slowdown.get(c, 1.0)

        def body():
            for step in range(n_steps):
                if fail_at is not None and fail_at == (c, step):
                    return                    # chip dies silently
                # 1. compute (live or cost-derived)
                if live_step_fn is not None:
                    yield LiveCall(live_step_fn,
                                   cost_ns=int(step_cost.compute_ns * mult))
                else:
                    yield Compute(int(step_cost.compute_ns * mult))
                # 2. ring exchange: send grad shard to right neighbor,
                #    receive from left (models RS+AG wire bytes per chip)
                yield Send(ep, f"chip{right}", step_cost.ici_bytes)
                yield Recv(ep)
                # 3. pod leader: cross-pod all-reduce over DCN
                if spec.n_pods > 1 and c % spec.chips_per_pod == 0:
                    other = (p + 1) % spec.n_pods
                    yield Send(dcn_eps[p], f"pod{other}",
                               step_cost.dcn_bytes)
                    yield Recv(dcn_eps[p])
                done_steps[c] = step + 1

        t = VTask(f"chip{c}", body(),
                  kind="live" if live_step_fn else "modeled")
        t.join(scope)
        return t

    for c in range(spec.n_chips):
        tasks.append(sched.spawn(chip_body(c)))

    ctx = {"scope": scope, "hubs": pod_hubs + [dcn],
           "done_steps": done_steps, "endpoints": endpoints}
    return sched, tasks, ctx


def build_rack_cluster(
    *,
    n_racks: int = 2,
    hosts_per_rack: int = 2,
    n_iters: int = 200,
    compute_ns: int = 5_000,
    msg_bytes: int = 4096,
    cross_every: int = 20,
    intra_link: LinkSpec = LinkSpec(bandwidth_bps=80e9 * 8,
                                    latency_ns=2_000),
    cross_link: LinkSpec = LinkSpec(bandwidth_bps=25e9 * 8,
                                    latency_ns=50_000),
    rack_slowdown: Tuple[float, ...] = (),
    skew_bound_ns: int = 0,
    mode: str = "async",
):
    """Heterogeneous-latency multi-host topology (paper §3.5): one worker
    vtask per host, hosts grouped into racks.  Intra-rack pairs share a
    fast link, rack-to-rack pairs a slow one — the regime where per-link
    lookahead beats a global-min-latency barrier, because racks only need
    to synchronize at the slow-link granularity while the barrier engine
    paces *everyone* at the fast-link window.

    Per iteration each worker computes then exchanges ``msg_bytes`` with
    its intra-rack ring neighbor; rack leaders additionally run a
    cross-rack leader ring every ``cross_every`` iterations.
    ``rack_slowdown`` scales per-rack compute (imbalanced racks), and a
    ``skew_bound_ns`` > 0 adds one global scope over all workers
    (exercising cross-host proxies + lazy sync).

    Returns (orchestrator, tasks, ctx).
    """
    from repro.core.orchestrator import Orchestrator

    n_hosts = n_racks * hosts_per_rack
    orch = Orchestrator(n_hosts=n_hosts, n_cpus=4, mode=mode)
    for a in range(n_hosts):
        for b in range(a + 1, n_hosts):
            same_rack = a // hosts_per_rack == b // hosts_per_rack
            orch.connect_hosts(a, b,
                               intra_link if same_rack else cross_link)
    hubs = [orch.add_hub(h, Hub(f"hub{h}",
                                LinkSpec(bandwidth_bps=80e9 * 8,
                                         latency_ns=500)))
            for h in range(n_hosts)]
    eps = [hubs[h].attach(Endpoint(f"w{h}")) for h in range(n_hosts)]
    xeps = {r: hubs[r * hosts_per_rack].attach(Endpoint(f"lead{r}"))
            for r in range(n_racks)}
    iters_done = np.zeros(n_hosts, dtype=np.int64)

    def worker(h: int):
        r = h // hosts_per_rack
        slot = h % hosts_per_rack
        right = r * hosts_per_rack + (slot + 1) % hosts_per_rack
        mult = rack_slowdown[r] if r < len(rack_slowdown) else 1.0
        is_leader = slot == 0
        next_rack = (r + 1) % n_racks

        def body():
            for i in range(n_iters):
                yield Compute(int(compute_ns * mult))
                if hosts_per_rack > 1:
                    yield Send(eps[h], f"w{right}", msg_bytes)
                    yield Recv(eps[h])
                if (is_leader and n_racks > 1
                        and (i + 1) % cross_every == 0):
                    yield Send(xeps[r], f"lead{next_rack}", msg_bytes)
                    yield Recv(xeps[r])
                iters_done[h] = i + 1

        return orch.host(h).spawn(VTask(f"w{h}", body(), kind="modeled"))

    tasks = [worker(h) for h in range(n_hosts)]
    if skew_bound_ns > 0:
        orch.global_scope("cluster", tasks, skew_bound_ns=skew_bound_ns)
    ctx = {"hubs": hubs, "iters_done": iters_done, "endpoints": eps}
    return orch, tasks, ctx


def analytic_step_ns(spec: ClusterSpec, step_cost: StepCost) -> int:
    """Closed-form per-step time (the validation target for the sim)."""
    comm = step_cost.ici_bytes / spec.ici_bw_Bps * SEC + spec.ici_lat_ns
    dcn = (step_cost.dcn_bytes / spec.dcn_bw_Bps * SEC + spec.dcn_lat_ns
           if spec.n_pods > 1 else 0)
    return int(step_cost.compute_ns + comm + dcn)
