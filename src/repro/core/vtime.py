"""Virtual-time accounting (paper §3.2, "Virtual-Time Accounting").

Two vtime sources, exactly mirroring the paper:

* **Clock-derived** (live vtasks): the paper adapts KVM's pvclock so that
  guest-visible time advances only during actual vCPU execution, absorbing
  preemption gaps into the TSC offset.  ``LiveClock`` is our analogue: it
  measures host wall-time spans *only while the live call executes* (the
  scheduler is not running the vtask between dispatches, so "steal time"
  is structurally absorbed) and applies a calibration scale mapping host
  execution speed to the simulated target's speed.  The scheduler and the
  "guest" (workload code) read the same clock — single source of truth.

* **Model-driven** (modeled vtasks): components report accumulated
  simulated latency either synchronously (return value of a step — the
  ``ioctl`` analogue) or asynchronously through a shared ``RunPage`` the
  scheduler polls (the per-vtask run-page analogue).

All vtimes are integer nanoseconds for exact, platform-independent
determinism.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional

NS = 1
US = 1_000
MS = 1_000_000
SEC = 1_000_000_000


def to_ns(seconds: float) -> int:
    return int(round(seconds * SEC))


@dataclasses.dataclass
class RunPage:
    """Shared async progress page for a modeled vtask (paper: per-vtask
    run page).  The component accumulates simulated latency; the scheduler
    drains it at dispatch points."""
    accumulated_ns: int = 0
    epoch: int = 0                      # bumped on every report

    def report(self, delta_ns: int) -> None:
        if delta_ns < 0:
            raise ValueError("negative vtime advance")
        self.accumulated_ns += int(delta_ns)
        self.epoch += 1

    def drain(self) -> int:
        d, self.accumulated_ns = self.accumulated_ns, 0
        return d


class LiveClock:
    """pvclock analogue for live vtasks.

    ``calibration`` converts measured host-nanoseconds into simulated
    target-nanoseconds (e.g. host CPU step time -> TPU roofline step
    time).  ``measure`` brackets one live execution span; between spans
    the clock does not advance (preemption-gap absorption).
    """

    def __init__(self, calibration: float = 1.0,
                 timer: Callable[[], int] = time.perf_counter_ns):
        self.calibration = float(calibration)
        self._timer = timer
        self.total_host_ns = 0
        self.total_vtime_ns = 0

    def measure(self, fn: Callable, *args, **kwargs):
        """Execute ``fn`` live; returns (result, vtime_delta_ns)."""
        t0 = self._timer()
        result = fn(*args, **kwargs)
        host_ns = self._timer() - t0
        v_ns = int(round(host_ns * self.calibration))
        self.total_host_ns += host_ns
        self.total_vtime_ns += v_ns
        return result, v_ns


@dataclasses.dataclass(frozen=True)
class CostModel:
    """Cost-derived vtime for live components when the target hardware is
    not the host (the dry-run roofline terms *are* this model).

    vtime(op) = max(flops/peak_flops, bytes/hbm_bw) + collective_ns."""
    peak_flops: float = 197e12          # TPU v5e bf16
    hbm_bw: float = 819e9
    link_bw: float = 50e9

    def step_ns(self, flops: float, bytes_hbm: float,
                coll_bytes: float = 0.0, coll_ns: float = 0.0) -> int:
        compute = flops / self.peak_flops
        memory = bytes_hbm / self.hbm_bw
        coll = coll_ns / SEC + coll_bytes / self.link_bw
        return to_ns(max(compute, memory) + coll)
