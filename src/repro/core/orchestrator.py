"""Distributed simulation orchestration (paper §3.5).

Composes per-host subsystems (scheduler, hubs, cells) into one
cluster-scale simulation while preserving local semantics:

* **Proxy vtasks**: a synchronization scope may contain remote members;
  locally they appear as ``kind="proxy"`` vtasks participating in the
  bounded-skew arithmetic.  The orchestrator (the control-plane daemon of
  the paper) refreshes proxy vtimes at sync epochs; between syncs the
  proxy is conservatively stale, so local tasks can never run ahead of a
  remote peer by more than skew_bound + sync staleness.
* **Distributed hubs**: ``Hub.peer_with`` links hub instances; cross-host
  messages carry addressing + visibility-time metadata over a host-
  interconnect ``LinkSpec``.
* **Conservative epochs**: each epoch runs every host up to
  ``global_min + window`` where ``window`` = the minimum cross-host link
  latency (CMB-style lookahead) — a cross-host message sent at t is
  visible no earlier than t + latency, so no host can miss one.
* **Placement**: greedy co-location of frequently-interacting components
  (traffic-weighted) to cut cross-host coordination, plus utilization
  rebalancing hooks.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

from repro.core.ipc import Hub, LinkSpec
from repro.core.scheduler import DeadlockError, Scheduler
from repro.core.scope import Scope
from repro.core.vtask import State, VTask


class ProxyVTask(VTask):
    """Local stand-in for a remote scope member."""

    def __init__(self, remote: VTask, host: int):
        super().__init__(f"proxy:{remote.name}", body=None, kind="proxy",
                         host=host)
        self.remote = remote
        self.state = State.RUNNABLE
        self.vtime = remote.vtime

    def sync(self) -> None:
        self.vtime = self.remote.vtime
        # a finished/blocked remote must not pin the local scope minimum
        self.state = (State.RUNNABLE if self.remote.state == State.RUNNABLE
                      else State.BLOCKED)
        for s in self.scopes:
            s.invalidate()


@dataclasses.dataclass
class HostSpec:
    host_id: int
    n_cpus: int = 8


class Orchestrator:
    def __init__(self, n_hosts: int = 1, n_cpus: int = 8,
                 dcn_link: LinkSpec = LinkSpec(bandwidth_bps=25e9 * 8,
                                               latency_ns=10_000)):
        self.hosts: Dict[int, Scheduler] = {
            h: Scheduler(host=h, n_cpus=n_cpus, distributed=True)
            for h in range(n_hosts)}
        self.hubs: Dict[int, Hub] = {}
        self.dcn_link = dcn_link
        self.proxies: List[ProxyVTask] = []
        self.global_scopes: List[Scope] = []
        self.stats = {"epochs": 0, "proxy_syncs": 0, "cross_host_msgs": 0}

    # -- wiring -----------------------------------------------------------------
    def host(self, h: int) -> Scheduler:
        return self.hosts[h]

    def add_hub(self, host: int, hub: Hub) -> Hub:
        if host in self.hubs:
            # peer the new hub with existing instances (distributed hub)
            pass
        for other in self.hubs.values():
            hub.peer_with(other, self.dcn_link)
        self.hubs[host] = hub
        return hub

    def global_scope(self, name: str, members: List[VTask],
                     skew_bound_ns: int) -> List[Scope]:
        """One logical scope spanning hosts: a local Scope per host with
        real members + proxies for remote members."""
        per_host: Dict[int, List[VTask]] = {}
        for t in members:
            per_host.setdefault(t.host, []).append(t)
        scopes = []
        for h, local in per_host.items():
            s = Scope(f"{name}@host{h}", skew_bound_ns)
            for t in local:
                t.join(s)
            for t in members:
                if t.host != h:
                    p = ProxyVTask(t, host=h)
                    self.hosts[h].spawn(p)
                    p.join(s)
                    self.proxies.append(p)
            scopes.append(s)
        self.global_scopes.extend(scopes)
        return scopes

    # -- placement ---------------------------------------------------------------
    @staticmethod
    def co_locate(components: List[str],
                  traffic: Dict[Tuple[str, str], float],
                  n_hosts: int, capacity: int) -> Dict[str, int]:
        """Greedy traffic-weighted placement: heaviest edges first, merge
        into the same host while capacity permits."""
        placement: Dict[str, int] = {}
        groups: List[List[str]] = []
        edges = sorted(traffic.items(), key=lambda kv: -kv[1])

        def group_of(c):
            for g in groups:
                if c in g:
                    return g
            return None

        for (a, b), _w in edges:
            ga, gb = group_of(a), group_of(b)
            if ga is None and gb is None:
                groups.append([a, b])
            elif ga is not None and gb is None and len(ga) < capacity:
                ga.append(b)
            elif gb is not None and ga is None and len(gb) < capacity:
                gb.append(a)
            elif (ga is not None and gb is not None and ga is not gb
                  and len(ga) + len(gb) <= capacity):
                ga.extend(gb)
                groups.remove(gb)
        for c in components:
            if group_of(c) is None:
                groups.append([c])
        groups.sort(key=len, reverse=True)
        loads = [0] * n_hosts
        for g in groups:
            h = loads.index(min(loads))
            for c in g:
                placement[c] = h
            loads[h] += len(g)
        return placement

    # -- control plane --------------------------------------------------------------
    def sync_proxies(self) -> None:
        for p in self.proxies:
            p.sync()
            self.stats["proxy_syncs"] += 1

    def unfinished(self) -> bool:
        return any(
            t.state in (State.RUNNABLE, State.BLOCKED)
            for h in self.hosts.values() for t in h.tasks
            if t.kind != "proxy")

    def global_now(self) -> int:
        """Conservative next-event time across hosts (PDES semantics:
        blocked vtasks with nothing pending cannot generate events)."""
        nows = [t for t in (h.next_time() for h in self.hosts.values())
                if t is not None]
        return min(nows) if nows else self.horizon()

    def horizon(self) -> int:
        return max((t.vtime for h in self.hosts.values()
                    for t in h.tasks if t.kind != "proxy"), default=0)

    def run(self, max_epochs: int = 1_000_000) -> dict:
        window = max(1, min((hub.peer_link.latency_ns
                             for hub in self.hubs.values()), default=1000))
        for _ in range(max_epochs):
            if not self.unfinished():
                break
            self.stats["epochs"] += 1
            gmin = self.global_now()
            before = self.horizon()
            before_d = sum(h.stats.dispatches for h in self.hosts.values())
            for h in self.hosts.values():
                h.run(until_vtime=gmin + window)
            self.sync_proxies()
            if not self.unfinished():
                break
            after_d = sum(h.stats.dispatches for h in self.hosts.values())
            if self.horizon() == before and after_d == before_d:
                # no progress in a full epoch: either everything is blocked
                # on cross-host messages (hub routing is immediate, so the
                # wake pass resolves it next epoch) or true deadlock.
                moved = False
                for h in self.hosts.values():
                    h._wake_pass()
                    if h.runnable():
                        moved = True
                if not moved:
                    raise DeadlockError("distributed simulation wedged")
        total_msgs = sum(hub.stats["messages"]
                         for hub in self.hubs.values())
        return {"epochs": self.stats["epochs"],
                "vtime_ns": self.horizon(),
                "messages": total_msgs}
