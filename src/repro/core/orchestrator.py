"""Distributed simulation orchestration (paper §3.5).

Composes per-host subsystems (scheduler, hubs, cells) into one
cluster-scale simulation while preserving local semantics:

* **Proxy vtasks**: a synchronization scope may contain remote members;
  locally they appear as ``kind="proxy"`` vtasks participating in the
  bounded-skew arithmetic.  The orchestrator (the control-plane daemon of
  the paper) refreshes proxy vtimes at sync points; between syncs the
  proxy is conservatively stale, so local tasks can never run ahead of a
  remote peer by more than skew_bound + sync staleness.
* **Distributed hubs**: ``Hub.peer_with`` links hub instances; cross-host
  messages carry addressing + visibility-time metadata over a host-
  interconnect ``LinkSpec``.  Links may be heterogeneous (fast intra-rack
  + slow cross-rack) — see ``connect_hosts``.
* **Placement**: greedy co-location of frequently-interacting components
  (traffic-weighted) to cut cross-host coordination, plus utilization
  rebalancing hooks.

Orchestration engines
---------------------

Two conservative engines share all of the wiring above; pick one with
``Orchestrator(mode=...)``:

``mode="async"`` (default) — per-link-lookahead conservative PDES.
  Each host advances to its own *earliest-input time* (EIT): the
  earliest vtime at which any peer could still make a message visible
  here, computed per host pair from that pair's link ``latency_ns``
  (the channel lookahead) rather than the global minimum.  Peer clock
  lower bounds are propagated transitively through the host graph
  (null-message-style LBTS relaxation), so a host only blocks on peers
  that can actually affect it, and hosts on fast intra-rack links stop
  gating hosts that only share a slow cross-rack link.  Proxy vtasks
  are refreshed lazily: a proxy is synced only when the host's window
  reaches past its scope pin bound (``vtime + skew_bound_ns``), i.e.
  only when its staleness could pin the local scope minimum.  Progress
  is guaranteed without wake heuristics: every link has lookahead
  >= 1 ns, so the minimum-time host's EIT always lies strictly past the
  global minimum.  A full round with no dispatch and no proxy change
  means true deadlock (``DeadlockError``).

``mode="barrier"`` — the legacy global-barrier epoch loop.  Every epoch
  runs all hosts to ``global_min + window`` where ``window`` is the
  *minimum* cross-host link latency, then barriers and syncs every
  proxy.  Kept for head-to-head comparison (see
  ``benchmarks/cluster_bench.py``); on heterogeneous-latency topologies
  it pays one epoch per min-latency window and one proxy sync per proxy
  per epoch, which the async engine mostly avoids.

Both engines are conservative, so they produce identical simulation
results (final vtimes, message counts); they differ only in how many
synchronization rounds (``stats["epochs"]``) and proxy syncs they need.
A third engine, ``repro.dist``, runs the async protocol across real OS
worker processes: its coordinator reuses :func:`lbts_bounds` /
:func:`earliest_input_time` below, so all three engines compute the
same conservative clock bounds and stay bit-identical (enforced by
``tests/engine_harness.py``).

Most callers should not wire an Orchestrator by hand: the `repro.sim`
facade (:class:`repro.sim.Simulation`) builds hosts, hubs, links,
scopes, and placement from a declarative Topology/Workload/Scenario
description, picks the engine automatically, and returns a structured
:class:`repro.sim.SimReport`.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, List, Optional, Tuple, Union

import numpy as np

from repro.core.cells import Cell, CellManager
from repro.core.ipc import Hub, LinkSpec
from repro.core.scheduler import DeadlockError, Scheduler
from repro.core.scope import Scope
from repro.core.vtask import State, VTask

_INF = 2**62
#: internal unreachable sentinel for closure distances; half of _INF so
#: int64 min-plus sums (CAP + CAP, _INF + CAP) can never overflow
_CAP = _INF >> 1


def lbts_bounds(next_times: Dict[int, Optional[int]],
                lookahead: Dict[Tuple[int, int], int]) -> Dict[int, int]:
    """Null-message-style LBTS relaxation: lb[h] is a lower bound on the
    vtime of *any* future action of host h, accounting for transitive
    wake-up chains (h may be woken by a message from p, which may first
    be woken by q, ...).  Fixpoint of

        lb[h] = min(local_next(h), min_p lb[p] + lookahead(p, h))

    over the host graph; converges in <= n_hosts passes because all
    lookaheads are positive.

    This is the *reference* implementation; the hot paths (in-process
    async engine and the dist coordinator) use :class:`LBTSSolver`,
    which computes the identical fixpoint through a precomputed
    min-plus closure of the static lookahead graph plus an
    unchanged-input cache (``tests/test_orchestrator_async.py`` pins
    solver == reference)."""
    lb = {h: (_INF if t is None else t) for h, t in next_times.items()}
    for _ in range(len(lb)):
        changed = False
        for (src, dst), la in lookahead.items():
            if lb[src] >= _INF:
                continue
            v = lb[src] + la
            if v < lb[dst]:
                lb[dst] = v
                changed = True
        if not changed:
            break
    return lb


def earliest_input_time(host: int, lb: Dict[int, int],
                        lookahead: Dict[Tuple[int, int], int]
                        ) -> Optional[int]:
    """Earliest-input time of ``host``: no peer can make a message
    visible here before this vtime, so every local event strictly below
    it is safe to execute.  None = unbounded (no peer can reach this
    host at all)."""
    times = [lb[src] + la for (src, dst), la in lookahead.items()
             if dst == host and lb[src] < _INF]
    return min(times) if times else None


class LBTSSolver:
    """Incremental LBTS/EIT computation over a *static* lookahead graph.

    Channels are pinned at peering time, so the graph never changes
    during a run; the fixpoint ``lb[h] = min_s next[s] + dist(s, h)``
    (with ``dist`` the min-plus closure of the lookahead edges,
    ``dist(h, h) = 0``) can therefore be evaluated as one vectorized
    min-plus product per round instead of an O(E x n) relaxation — and
    skipped entirely when no host's next-event time changed since the
    last round (the common case once parts of the cluster go quiescent).
    Produces bit-identical values to :func:`lbts_bounds` /
    :func:`earliest_input_time`."""

    def __init__(self, lookahead: Dict[Tuple[int, int], int],
                 hosts: Iterable[int]):
        self.hosts: List[int] = sorted(hosts)
        self._idx = {h: i for i, h in enumerate(self.hosts)}
        n = len(self.hosts)
        dist = np.full((n, n), _CAP, dtype=np.int64)
        np.fill_diagonal(dist, 0)
        #: direct in-edges per host, for EIT against a mutating lb dict
        self.in_edges: Dict[int, List[Tuple[int, int]]] = {
            h: [] for h in self.hosts}
        for (src, dst), la in lookahead.items():
            i, j = self._idx[src], self._idx[dst]
            dist[i, j] = min(dist[i, j], la)
            self.in_edges[dst].append((src, la))
        # min-plus closure, Floyd-Warshall with one vectorized (n, n)
        # relaxation per pivot: O(n^2) memory (a cubed temporary would
        # cost n^3 * 8 bytes at the host counts this exists for).
        # Entries stay <= _CAP by the running minimum, so pivot sums
        # never exceed 2 * _CAP < 2**63 — no int64 overflow.
        for k in range(n):
            np.minimum(dist, dist[:, k, None] + dist[None, k, :],
                       out=dist)
        self._dist = dist
        self._next_cache: Optional[Dict[int, Optional[int]]] = None
        self._lb_vec: Optional[np.ndarray] = None

    def bounds(self, next_times: Dict[int, Optional[int]]
               ) -> Dict[int, int]:
        """LBTS clock bounds for all hosts; recomputed only when some
        host's conservative next-event time changed.  Returns a fresh
        dict (callers mutate it mid-round)."""
        if next_times != self._next_cache:
            n = len(self.hosts)
            vec = np.fromiter(
                (_INF if next_times[h] is None else next_times[h]
                 for h in self.hosts), dtype=np.int64, count=n)
            # mask unreachable pairs before the min — a finite source
            # plus the _CAP sentinel must stay "no bound", not become a
            # huge-but-finite one (sums stay < 2**63, so no overflow)
            contrib = np.where(self._dist >= _CAP, _INF,
                               vec[:, None] + self._dist)
            lb = np.minimum(contrib.min(axis=0), _INF)
            self._next_cache = dict(next_times)
            self._lb_vec = lb
        return {h: int(self._lb_vec[i])
                for i, h in enumerate(self.hosts)}

    def eit(self, host: int, lb: Dict[int, int]) -> Optional[int]:
        """Earliest-input time of ``host`` against the (possibly
        mid-round-refreshed) lb dict: O(in-degree), identical to
        :func:`earliest_input_time`."""
        best = None
        for src, la in self.in_edges[host]:
            v = lb[src]
            if v >= _INF:
                continue
            c = v + la
            if best is None or c < best:
                best = c
        return best


class ProxyVTask(VTask):
    """Local stand-in for a remote scope member."""

    def __init__(self, remote: VTask, host: int):
        super().__init__(f"proxy:{remote.name}", body=None, kind="proxy",
                         host=host)
        self.remote = remote
        self.state = State.RUNNABLE
        self.vtime = remote.vtime
        # staleness bookkeeping (lazy sync): vtime of the mirrored source
        # at the last sync, sync count, and the largest source-vs-mirror
        # gap ever observed at a sync point.
        self.sync_count = 0
        self.last_sync_vtime = remote.vtime
        self.max_staleness_ns = 0

    def _mirror_state(self) -> State:
        """A finished/blocked remote must not pin the local scope min."""
        return (State.RUNNABLE if self.remote.state == State.RUNNABLE
                else State.BLOCKED)

    def is_stale(self) -> bool:
        return (self.vtime != self.remote.vtime
                or self.state != self._mirror_state())

    def sync(self) -> bool:
        """Refresh from the remote; returns True iff anything changed.

        Staleness bookkeeping: ``max_staleness_ns`` records the largest
        remote-vs-proxy vtime gap ever observed at a sync point (the
        proxy can only *under*-report, so staleness tightens the skew
        bound — a liveness cost, never a correctness one)."""
        remote_v = self.remote.vtime
        changed = self.is_stale()
        self.max_staleness_ns = max(self.max_staleness_ns,
                                    remote_v - self.vtime)
        self.sync_count += 1
        self.last_sync_vtime = remote_v
        if changed:
            self.vtime = remote_v
            self.state = self._mirror_state()
            for s in self.scopes:
                s.notify(self)
        return changed


@dataclasses.dataclass
class HostSpec:
    """Declarative per-host configuration for hand-wired orchestration:
    CPU budget plus the host's §3.3 memory-hierarchy cell allocations
    (the facade derives the same thing from ``Topology.cell``
    declarations + placement)."""
    host_id: int
    n_cpus: int = 8
    cells: Tuple[Cell, ...] = ()

    def cell_manager(self, **knobs) -> CellManager:
        """Build this host's CellManager (``knobs`` are CellManager
        calibration parameters: total_ways, miss_penalty, ...)."""
        cm = CellManager(host=self.host_id, **knobs)
        for cell in self.cells:
            cm.add(cell)
        return cm


class Orchestrator:
    def __init__(self, n_hosts: int = 1,
                 n_cpus: Union[int, Dict[int, int]] = 8,
                 dcn_link: LinkSpec = LinkSpec(bandwidth_bps=25e9 * 8,
                                               latency_ns=10_000),
                 mode: str = "async",
                 cells: Optional[Dict[int, CellManager]] = None,
                 joins: Optional[Dict[int, int]] = None):
        assert mode in ("async", "barrier"), mode
        self.mode = mode
        if not isinstance(n_cpus, dict):
            n_cpus = {h: n_cpus for h in range(n_hosts)}
        self.hosts: Dict[int, Scheduler] = {}
        #: membership timeline: host -> vtime it joins the cluster
        #: (0 = founding member) and host -> vtime it leaves, plus the
        #: ordered event log surfaced in ``SimReport.control``
        self.join_vtime: Dict[int, int] = {}
        self.leave_vtime: Dict[int, int] = {}
        self.membership_events: List[dict] = []
        self.hubs: Dict[int, Hub] = {}
        self.dcn_link = dcn_link
        # optional heterogeneous topology: (host_a, host_b) -> LinkSpec,
        # consulted when hubs are peered; pairs without an entry use
        # dcn_link.
        self.host_links: Dict[Tuple[int, int], LinkSpec] = {}
        self.proxies: List[ProxyVTask] = []
        self._host_proxies: Dict[int, List[ProxyVTask]] = {}
        self.global_scopes: List[Scope] = []
        self.stats = {"epochs": 0, "proxy_syncs": 0, "cross_host_msgs": 0,
                      "max_proxy_staleness_ns": 0, "max_window_ns": 0,
                      "quiescent_skips": 0, "membership_epochs": 0}
        self._solver: Optional[LBTSSolver] = None   # built on first run
        # membership-epoch state (lazy; see _membership_state)
        self._active_hosts: Optional[List[int]] = None
        self._pending_joins: Optional[List[Tuple[int, int]]] = None
        joins = joins or {}
        # per-host cell state (§3.3): each host's scheduler gets its own
        # CellManager — passed in by the facade, defaulted otherwise
        for h in range(n_hosts):
            self.add_host(h, n_cpus=n_cpus.get(h, 8),
                          at_vtime=joins.get(h, 0),
                          cells=None if cells is None else cells.get(h))

    @classmethod
    def from_host_specs(cls, specs: List[HostSpec], *,
                        dcn_link: LinkSpec = LinkSpec(
                            bandwidth_bps=25e9 * 8, latency_ns=10_000),
                        mode: str = "async",
                        cell_knobs: Optional[dict] = None
                        ) -> "Orchestrator":
        """Hand-wiring entry point for heterogeneous hosts: one
        :class:`HostSpec` per host (ids must be exactly 0..n-1), each
        contributing its CPU budget and §3.3 cell allocations."""
        ids = sorted(s.host_id for s in specs)
        if ids != list(range(len(specs))):
            raise ValueError(f"host ids must be 0..{len(specs) - 1}, "
                             f"got {ids}")
        return cls(
            n_hosts=len(specs),
            n_cpus={s.host_id: s.n_cpus for s in specs},
            dcn_link=dcn_link, mode=mode,
            cells={s.host_id: s.cell_manager(**(cell_knobs or {}))
                   for s in specs})

    # -- wiring -----------------------------------------------------------------
    def host(self, h: int) -> Scheduler:
        return self.hosts[h]

    # -- membership (vtime-stamped join/leave events) ----------------------------
    def add_host(self, h: int, *, n_cpus: int = 8, at_vtime: int = 0,
                 cells: Optional[CellManager] = None) -> Scheduler:
        """Add host ``h`` to the cluster as a vtime-stamped membership
        event.  ``at_vtime=0`` is a founding member; ``at_vtime=T > 0``
        means the host *joins* at simulated time ``T``: its scheduler and
        hub are wired at build time (fresh state, no resurrection of any
        prior host's tasks or cells), but the conservative engines keep
        it out of the LBTS closure — and clamp every active host's
        window at ``T`` — until the membership epoch flips (see
        ``_run_async``).  The facade spawns the joiner's tasks with
        initial vtime ``T``, so the joiner's earliest possible send is
        ``>= T`` and join-time lookahead attach is add-only conservative:
        no pre-join host ever executes an event at ``>= T`` before the
        joiner's edges are in the graph."""
        if h in self.hosts:
            raise ValueError(f"host {h} is already a cluster member")
        if at_vtime < 0:
            raise ValueError(f"host {h}: join vtime must be >= 0, "
                             f"got {at_vtime}")
        self.join_vtime[h] = at_vtime
        if at_vtime > 0:
            self.membership_events.append(
                {"event": "join", "host": h, "vtime": at_vtime})
        self._active_hosts = None       # membership timeline changed
        self._pending_joins = None
        self._solver = None
        sched = Scheduler(host=h, n_cpus=n_cpus, distributed=True,
                          cells=cells)
        self.hosts[h] = sched
        return sched

    def retire_host(self, h: int, at_vtime: int) -> None:
        """Record host ``h`` leaving the cluster at ``at_vtime`` (the
        membership half of ``FailHost``: the facade kills the host's
        tasks through the ordinary fault wrappers; this logs the churn
        event).  Leaves need no solver rebuild — a retired host goes
        quiescent, and quiescent hosts already stop gating peers — so
        the conservative window schedule (and every pinned golden
        ``sync_rounds``) is unchanged."""
        if h not in self.hosts:
            raise ValueError(f"cannot retire unknown host {h}")
        prior = self.leave_vtime.get(h)
        if prior is None or at_vtime < prior:
            self.leave_vtime[h] = at_vtime
        self.membership_events.append(
            {"event": "leave", "host": h, "vtime": at_vtime})

    def membership_timeline(self) -> List[dict]:
        """Vtime-ordered membership events (joins + leaves)."""
        return sorted(self.membership_events,
                      key=lambda e: (e["vtime"], e["event"], e["host"]))

    def _membership_state(self) -> Tuple[List[int], List[Tuple[int, int]]]:
        """(active hosts, pending joins as sorted (vtime, host)) — the
        epoch state for the conservative engines.  Persisted on self so
        chunked re-entry (the dist sole-worker path) resumes the same
        epoch."""
        if self._active_hosts is None:
            self._active_hosts = sorted(
                h for h, t in self.join_vtime.items() if t <= 0)
            self._pending_joins = sorted(
                (t, h) for h, t in self.join_vtime.items() if t > 0)
            if not self._active_hosts and self.hosts:
                raise ValueError(
                    "cluster has no founding member: at least one host "
                    "must join at vtime 0")
        return self._active_hosts, self._pending_joins

    def _activate_epoch(self) -> None:
        """Flip the membership epoch: admit every pending joiner at the
        earliest pending join vtime into the active set and invalidate
        the solver so the min-plus closure re-solves over the grown
        graph."""
        t0 = self._pending_joins[0][0]
        while self._pending_joins and self._pending_joins[0][0] == t0:
            _, h = self._pending_joins.pop(0)
            self._active_hosts.append(h)
        self._active_hosts.sort()
        self._solver = None
        self.stats["membership_epochs"] += 1

    def connect_hosts(self, a: int, b: int, link: LinkSpec) -> None:
        """Declare the interconnect between hosts ``a`` and ``b`` (both
        directions); pairs not declared fall back to ``dcn_link``.
        Per-pair latency becomes that pair's synchronization lookahead
        in the async engine.  If both hosts already have hubs, the
        existing channel is re-pinned to the new link."""
        self.host_links[(a, b)] = link
        self.host_links[(b, a)] = link
        self._solver = None             # lookahead graph changed
        ha, hb = self.hubs.get(a), self.hubs.get(b)
        if ha is not None and hb is not None:
            ha.peer_with(hb, link)

    def _link_for(self, a: int, b: int) -> LinkSpec:
        return self.host_links.get((a, b), self.dcn_link)

    def add_hub(self, host: int, hub: Hub) -> Hub:
        for other_host, other in self.hubs.items():
            hub.peer_with(other, self._link_for(host, other_host))
        self.hubs[host] = hub
        self._solver = None             # lookahead graph changed
        return hub

    def global_scope(self, name: str, members: List[VTask],
                     skew_bound_ns: int) -> List[Scope]:
        """One logical scope spanning hosts: a local Scope per host with
        real members + proxies for remote members."""
        per_host: Dict[int, List[VTask]] = {}
        for t in members:
            per_host.setdefault(t.host, []).append(t)
        scopes = []
        for h, local in per_host.items():
            s = Scope(f"{name}@host{h}", skew_bound_ns)
            for t in local:
                t.join(s)
            for t in members:
                if t.host != h:
                    p = ProxyVTask(t, host=h)
                    self.hosts[h].spawn(p)
                    p.join(s)
                    self.proxies.append(p)
                    self._host_proxies.setdefault(h, []).append(p)
            scopes.append(s)
        self.global_scopes.extend(scopes)
        return scopes

    # -- placement ---------------------------------------------------------------
    @staticmethod
    def co_locate(components: List[str],
                  traffic: Dict[Tuple[str, str], float],
                  n_hosts: int, capacity: int) -> Dict[str, int]:
        """Greedy traffic-weighted placement: heaviest edges first, merge
        into the same host while capacity permits.

        Self-edges are ignored, ``capacity < 2`` degenerates to
        balanced singletons, components without traffic get their own
        group, and more groups than hosts simply stack on the
        least-loaded host."""
        placement: Dict[str, int] = {}
        groups: List[List[str]] = []
        edges = sorted(traffic.items(), key=lambda kv: -kv[1])

        def group_of(c):
            for g in groups:
                if c in g:
                    return g
            return None

        for (a, b), _w in edges:
            if a == b:
                continue
            ga, gb = group_of(a), group_of(b)
            if ga is None and gb is None:
                if capacity < 2:
                    continue        # singletons; placed by the tail loop
                groups.append([a, b])
            elif ga is not None and gb is None and len(ga) < capacity:
                ga.append(b)
            elif gb is not None and ga is None and len(gb) < capacity:
                gb.append(a)
            elif (ga is not None and gb is not None and ga is not gb
                  and len(ga) + len(gb) <= capacity):
                ga.extend(gb)
                groups.remove(gb)
        for c in components:
            if group_of(c) is None:
                groups.append([c])
        groups.sort(key=len, reverse=True)
        loads = [0] * n_hosts
        for g in groups:
            h = loads.index(min(loads))
            for c in g:
                placement[c] = h
            loads[h] += len(g)
        return placement

    # -- control plane --------------------------------------------------------------
    def sync_proxies(self) -> None:
        for p in self.proxies:
            p.sync()
            self.stats["proxy_syncs"] += 1
        self._note_staleness()

    def _note_staleness(self) -> None:
        for p in self.proxies:
            self.stats["max_proxy_staleness_ns"] = max(
                self.stats["max_proxy_staleness_ns"], p.max_staleness_ns)

    def unfinished(self) -> bool:
        return any(h.has_unfinished() for h in self.hosts.values())

    def global_now(self) -> int:
        """Conservative next-event time across hosts (PDES semantics:
        blocked vtasks with nothing pending cannot generate events)."""
        nows = [t for t in (h.next_time() for h in self.hosts.values())
                if t is not None]
        return min(nows) if nows else self.horizon()

    def horizon(self) -> int:
        return max((t.vtime for h in self.hosts.values()
                    for t in h.tasks if t.kind != "proxy"), default=0)

    # -- async engine: per-link lookahead ----------------------------------------
    def _lookahead(self, src: int, dst: int) -> Optional[int]:
        """Guaranteed minimum delay of a src->dst cross-host message, or
        None when no channel exists.  Read from the hubs' own routing
        config (single source of truth with the data path).  Clamped to
        >= 1 ns: a zero-latency link has no usable lookahead and would
        stall conservative progress."""
        shub, dhub = self.hubs.get(src), self.hubs.get(dst)
        if shub is None or dhub is None or dhub.name not in shub.peers:
            return None
        return max(1, shub.lookahead_ns(dhub.name))

    def lookahead_map(self, hosts: Optional[Iterable[int]] = None
                      ) -> Dict[Tuple[int, int], int]:
        """All directed cross-host channels and their lookahead, the
        input to :func:`lbts_bounds` / :func:`earliest_input_time`.
        ``hosts`` restricts the map to a membership epoch's active set
        (the solver re-solves over exactly these edges)."""
        la = {}
        members = self.hosts if hosts is None else list(hosts)
        for src in members:
            for dst in members:
                if src == dst:
                    continue
                v = self._lookahead(src, dst)
                if v is not None:
                    la[(src, dst)] = v
        return la

    def _clock_bounds(self) -> Dict[int, int]:
        return lbts_bounds(
            {h: sched.next_time() for h, sched in self.hosts.items()},
            self.lookahead_map())

    def _eit(self, host: int, lb: Dict[int, int]) -> Optional[int]:
        return earliest_input_time(host, lb, self.lookahead_map())

    def _next_times(self) -> Dict[int, Optional[int]]:
        return {h: sched.next_time() for h, sched in self.hosts.items()}

    def _lazy_sync(self, host: int, bound: Optional[int]) -> bool:
        """Sync a proxy only when its staleness could pin the local scope
        minimum within this window: once the window reaches past
        ``proxy.vtime + skew_bound`` (the scope pin bound), local members
        would skew-stall on the stale value."""
        changed = False
        for p in self._host_proxies.get(host, ()):
            if not p.is_stale():
                continue
            if bound is not None and p.scopes:
                pin = min(s.pin_bound(p) for s in p.scopes)
                if pin >= bound:
                    continue                  # cannot stall anyone yet
            if p.sync():
                changed = True
            self.stats["proxy_syncs"] += 1
        return changed

    def _membership_gmin(self, active: List[int]) -> Optional[int]:
        """Conservative next-event time over the active set only."""
        times = [t for t in (self.hosts[h].next_time() for h in active)
                 if t is not None]
        return min(times) if times else None

    def _wedge_info(self) -> dict:
        """Structured deadlock detail: which hosts hold unfinished work
        (and any joins still pending), so a membership-related wedge
        names the responsible host instead of only carrying prose."""
        active, pending = self._membership_state()
        return {
            "kind": "wedged",
            "wedged_hosts": [h for h in sorted(self.hosts)
                             if self.hosts[h].has_unfinished()],
            "pending_joins": [{"host": h, "vtime": t}
                              for t, h in pending],
        }

    def _run_async(self, max_rounds: int,
                   raise_on_exhaust: bool = True) -> bool:
        """Run the per-link-lookahead engine; returns True when the
        simulation finished, False when ``max_rounds`` elapsed first
        (only with ``raise_on_exhaust=False`` — the dist sole-worker
        path runs in bounded chunks to heartbeat its coordinator).

        Membership epochs: hosts with a pending join (``add_host`` with
        ``at_vtime=T > 0``) are kept out of the LBTS closure, and every
        active host's window is clamped at the earliest pending ``T``,
        until the active set provably cannot act below ``T`` — then the
        epoch flips, the joiner enters the graph, and the min-plus
        closure re-solves (cached between epochs).  Conservatism: the
        clamp means no pre-join host executes an event at ``>= T``
        before the joiner's edges exist, and the joiner's own tasks
        start at vtime ``T``, so its earliest send is ``>= T`` — wake
        forwarding is causal-timestamp-only, so the epoch-clamped
        schedule yields results bit-identical to every other engine."""
        # channels are pinned at peering time (Hub.peer_with), so within
        # a membership epoch the lookahead map is static — build the
        # solver's min-plus closure once per epoch (the dist coordinator
        # mirrors this logic round by round).  Cached across chunked
        # re-entry.
        active, pending = self._membership_state()
        solver = self._solver
        if solver is None:
            solver = self._solver = LBTSSolver(
                self.lookahead_map(active), active)
        for _ in range(max_rounds):
            if not self.unfinished():
                return True
            # membership epoch flips: admit pending joiners once no
            # active host can act strictly below the join vtime
            while pending:
                gmin = self._membership_gmin(active)
                if gmin is not None and gmin < pending[0][0]:
                    break
                self._activate_epoch()
                solver = self._solver = LBTSSolver(
                    self.lookahead_map(active), active)
            self.stats["epochs"] += 1
            progressed = False
            clamp = pending[0][0] if pending else None
            lb = solver.bounds(self._next_times())
            for h in active:
                sched = self.hosts[h]
                bound = solver.eit(h, lb)
                if clamp is not None:
                    bound = clamp if bound is None else min(bound, clamp)
                if self._lazy_sync(h, bound):
                    progressed = True
                elif sched.quiescent_below(bound):
                    # provably a no-op window: nothing runnable and no
                    # pending wake-up below this host's bound, and no
                    # proxy sync fell due — skip the host entirely.
                    self.stats["quiescent_skips"] += 1
                    continue
                if bound is not None:
                    start = sched.next_time()
                    if start is not None and bound > start:
                        self.stats["max_window_ns"] = max(
                            self.stats["max_window_ns"], bound - start)
                wakes_before = sched.stats.wakes
                if (sched.run_until(bound)
                        or sched.stats.wakes != wakes_before):
                    # dispatches are progress; so is a wake that consumed
                    # a pending visibility/event even when scope
                    # forwarding pushed the woken vtask past this round's
                    # window (no dispatch yet) — the next round's clock
                    # bounds see the new vtime.
                    progressed = True
                    # freshen this host's clock bound so later hosts in
                    # the same round see the larger lookahead window.
                    # The transitive component (h may still be woken by a
                    # peer that runs after it) must be re-applied: lb[h]
                    # is min(local next event, earliest peer wake-up).
                    t = sched.next_time()
                    local = _INF if t is None else t
                    # bound == _eit(h, lb) still: lb is untouched since
                    # the top of this iteration (and _eit ignores lb[h])
                    lb[h] = local if bound is None else min(local, bound)
            if not progressed:
                if pending:
                    # active set is wedged below the next join vtime:
                    # the epoch flip itself is the progress (the joiner
                    # may hold the messages everyone is blocked on)
                    self._activate_epoch()
                    solver = self._solver = LBTSSolver(
                        self.lookahead_map(active), active)
                    continue
                if self.unfinished():
                    self._note_staleness()
                    raise DeadlockError("distributed simulation wedged",
                                        info=self._wedge_info())
                return True
        if self.unfinished():
            if not raise_on_exhaust:
                return False
            self._note_staleness()
            raise DeadlockError(
                f"async engine exceeded {max_rounds} rounds "
                f"without finishing", info=self._wedge_info())
        return True

    # -- barrier engine (legacy, kept for head-to-head comparison) ---------------
    def _run_barrier(self, max_epochs: int) -> None:
        # CMB lookahead = the minimum latency over every cross-host
        # channel — any single faster link bounds how far all hosts may
        # conservatively run ahead.  ``peer_links`` is pinned per pair
        # at peering time, so it enumerates exactly the channels that
        # exist; no channels at all (e.g. a 1-host topology) means no
        # conservative constraint, and the window must be unbounded —
        # a finite window would defer wake-ups past the gate and let
        # scope-min forwarding observe a schedule that no unconstrained
        # engine produces (diverging from single/async results).
        lats = [link.latency_ns
                for hub in self.hubs.values()
                for link in hub.peer_links.values()]
        window = max(1, min(lats)) if lats else None
        stalled = 0
        for _ in range(max_epochs):
            if not self.unfinished():
                break
            self.stats["epochs"] += 1
            before = self.horizon()
            before_d = sum(h.stats.dispatches for h in self.hosts.values())
            gmin = self.global_now()
            for h in self.hosts.values():
                # strict window drain: a wake-up at or past the gate
                # could timestamp a receiver against a late slow-link
                # message that an unsent fast-link message will undercut
                h.run_until(None if window is None else gmin + window)
            self.sync_proxies()
            if not self.unfinished():
                break
            after_d = sum(h.stats.dispatches for h in self.hosts.values())
            if self.horizon() == before and after_d == before_d:
                # No progress in a full epoch: everything pending lies at
                # or past the gate.  Since nothing below gmin + window
                # could dispatch, any *future* send happens at
                # >= gmin + window and becomes visible at
                # >= gmin + 2*window — so waking blocked vtasks below
                # that horizon is conservative; anything further out is
                # reached by gmin itself advancing next epoch.
                moved = False
                for h in self.hosts.values():
                    h._wake_pass(bound=None if window is None
                                 else gmin + 2 * window)
                    if h.runnable():
                        moved = True
                if not moved:
                    if not any(h.next_time() is not None
                               for h in self.hosts.values()):
                        raise DeadlockError("distributed simulation wedged",
                                            info=self._wedge_info())
                    # pending events exist beyond the wake horizon; gmin
                    # itself advances next epoch.  Two stalled epochs in
                    # a row means even that cannot make progress.
                    stalled += 1
                    if stalled >= 2:
                        raise DeadlockError(
                            "distributed simulation stalled with pending "
                            "events beyond the wake horizon",
                            info=self._wedge_info())
            else:
                stalled = 0

    def run(self, max_epochs: int = 1_000_000) -> dict:
        if self.mode == "barrier":
            self._run_barrier(max_epochs)
        else:
            self._run_async(max_epochs)
        self._note_staleness()
        total_msgs = sum(hub.stats["messages"]
                         for hub in self.hubs.values())
        self.stats["cross_host_msgs"] = sum(
            st["messages"] for hub in self.hubs.values()
            for st in hub.peer_stats.values())
        return {"epochs": self.stats["epochs"],
                "vtime_ns": self.horizon(),
                "messages": total_msgs}
