from repro.parallel import ctx, sharding  # noqa: F401
