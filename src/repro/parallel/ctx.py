"""Mesh context threaded through model code.

Model forward functions are mesh-agnostic except for explicitly
communication-aware blocks (MoE expert parallelism, sequence-sharded
decode).  Those consult the active mesh set by the step builders /
launchers via ``use_mesh``.
"""
from __future__ import annotations

import threading
from contextlib import contextmanager
from typing import Optional, Tuple

import jax

_STATE = threading.local()


def set_mesh(mesh) -> None:
    _STATE.mesh = mesh


def get_mesh():
    return getattr(_STATE, "mesh", None)


@contextmanager
def use_mesh(mesh):
    prev = get_mesh()
    set_mesh(mesh)
    try:
        yield mesh
    finally:
        set_mesh(prev)


def set_unroll(flag: bool) -> None:
    """Counting mode: unroll inner (chunk) loops so XLA cost_analysis sees
    every iteration (while-loop bodies are counted once — verified in
    EXPERIMENTS.md §Dry-run methodology)."""
    _STATE.unroll = bool(flag)


def get_unroll() -> bool:
    return getattr(_STATE, "unroll", False)


@contextmanager
def use_unroll(flag: bool = True):
    prev = get_unroll()
    set_unroll(flag)
    try:
        yield
    finally:
        set_unroll(prev)


def batch_axes(mesh) -> Tuple[str, ...]:
    """Mesh axes over which the global batch is sharded."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def dp_size(mesh) -> int:
    out = 1
    for a in batch_axes(mesh):
        out *= mesh.shape[a]
    return out
