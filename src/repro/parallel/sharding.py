"""Logical-axis -> mesh-axis sharding rules (MaxText-style).

Every parameter/state leaf carries a tuple of logical axis names (see
``repro.models.common``).  A ``Rules`` mapping turns those into
``PartitionSpec``s for a concrete mesh.  Rules silently drop mesh axes
that the mesh does not have (so single-pod / multi-pod / test meshes
share one rule set).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# Default rule set.  Values are mesh-axis names or tuples thereof.
DEFAULT_RULES: Dict[str, object] = {
    "embed": "data",          # FSDP: shard the d_model dim of weights
    "heads": "model",         # TP over attention heads
    "kv": "model",            # TP over kv heads (GSPMD pads if uneven)
    "mlp": "model",           # TP over FFN hidden
    "vocab": "model",         # TP over vocabulary
    "expert": "model",        # EP over experts
    "expert_mlp": "data",     # FSDP dim inside expert weights
    "layer": None,            # never shard the stacked-layer dim
    "batch": ("pod", "data"),  # data parallel over batch
    "kv_seq": "model",        # decode KV cache: sequence-sharded (SP)
    "seq": None,              # training activations: seq replicated
    "lru": "model",           # recurrent state width
    "state_v": "model",       # mLSTM matrix-memory value dim
}


def spec_from_axes(axes: Tuple[Optional[str], ...], mesh: Mesh,
                   rules: Dict[str, object] | None = None,
                   shape: Optional[Tuple[int, ...]] = None) -> P:
    """Map logical axes to a PartitionSpec.

    A mesh axis is applied to a dim only if (a) it exists in the mesh,
    (b) it is not already used by another dim of this array, and (c) the
    dim size is divisible by it (pjit argument shardings must divide
    exactly — e.g. 8 GQA kv heads cannot shard over a 16-way model axis
    and fall back to replication; the roofline surfaces the cost)."""
    rules = rules or DEFAULT_RULES
    parts = []
    used = set()
    for i, ax in enumerate(axes):
        if ax is None:
            parts.append(None)
            continue
        mapped = rules.get(ax)
        if mapped is None:
            parts.append(None)
            continue
        if isinstance(mapped, str):
            mapped = (mapped,)
        eff = []
        dim = shape[i] if shape is not None else None
        div = 1
        for m in mapped:
            if m not in mesh.axis_names or m in used:
                continue
            sz = mesh.shape[m]
            if dim is not None and dim % (div * sz) != 0:
                continue
            eff.append(m)
            div *= sz
        used.update(eff)
        if not eff:
            parts.append(None)
        elif len(eff) == 1:
            parts.append(eff[0])
        else:
            parts.append(tuple(eff))
    return P(*parts)


def shardings_from_axes(axes_tree, mesh: Mesh,
                        rules: Dict[str, object] | None = None,
                        spec_tree=None):
    """Pytree of logical-axis tuples (+ optional ShapeDtypeStruct tree for
    divisibility checks) -> pytree of NamedShardings."""
    is_ax = lambda x: isinstance(x, tuple)

    def one(ax, sds=None):
        if ax == () or ax is None:
            return NamedSharding(mesh, P())
        shape = sds.shape if sds is not None else None
        return NamedSharding(mesh, spec_from_axes(ax, mesh, rules, shape))

    if spec_tree is None:
        return jax.tree.map(one, axes_tree, is_leaf=is_ax)
    return jax.tree.map(one, axes_tree, spec_tree, is_leaf=is_ax)


def batch_spec(mesh: Mesh, ndim: int = 2) -> P:
    """(B, ...) inputs: batch over ('pod','data'), rest replicated."""
    from repro.parallel.ctx import batch_axes

    ba = batch_axes(mesh)
    lead = ba[0] if len(ba) == 1 else ba
    return P(lead, *([None] * (ndim - 1)))


def batch_sharding(mesh: Mesh, ndim: int = 2) -> NamedSharding:
    return NamedSharding(mesh, batch_spec(mesh, ndim))


def size_of_spec(spec: P, shape, mesh: Mesh) -> int:
    """Per-device element count under a PartitionSpec (for napkin math)."""
    per = int(np.prod(shape))
    for dim, ax in enumerate(spec):
        if ax is None:
            continue
        axes = (ax,) if isinstance(ax, str) else ax
        div = int(np.prod([mesh.shape[a] for a in axes]))
        per //= max(1, div)
    return per
