"""Versioned, named scenario registry with pinned goldens.

gem5's reproducible-standard-experiments argument applied to this
repo: every showcase scenario — the example gallery, the live replay
stack, campaign bases, campaign-*derived* minimized reproducers — is a
named, versioned entry anyone can re-run bit-identically::

    from repro.sim import registry
    report = registry.load("live_recovery@v1").run()

Refs are ``name@vN``; a bare ``name`` resolves to the latest version.
Registering the same (name, version) twice is an error — a changed
scenario gets a *new version*, never a silent mutation; its golden is
pinned alongside.

Entries with a ``grid`` are **campaign bases**: their factory accepts a
Scenario override, so ``python -m repro.sim.campaign run --base <ref>``
can sweep a fault grid over them and replay reproducer specs against
them.

Goldens live in ``src/repro/sim/goldens/registry.json``: for each ref
the standalone *outcome* (``ok``/``deadlock``/``invariant-violation``/
``crash`` — no baseline, so no divergence class here) plus, for runs
that complete, the canonical timing-bearing report subset (the same
fields the gallery golden pins).  ``python -m repro.sim.registry
check`` re-runs every entry against its pin (CI); ``--regen`` rewrites
after a reviewed change.
"""
from __future__ import annotations

import dataclasses
import json
import pathlib
from typing import Callable, Dict, List, Optional, Tuple

from repro.core.cluster import ClusterSpec, StepCost
from repro.sim.campaign import (FaultGrid, default_invariants,
                                spec_scenario)
from repro.sim.control import AutoscaledServe, ThresholdAutoscaler
from repro.sim.scenario import BitFlip, ClockSkew, DegradeLink, \
    FailHost, Scenario, Straggler
from repro.sim.simulation import Simulation
from repro.sim.topology import Topology
from repro.sim.workloads import (ChipRingTraining, ModeledServe,
                                 RackRing, diurnal_arrivals)

_ROOT = pathlib.Path(__file__).resolve().parents[3]
GOLDEN_DIR = pathlib.Path(__file__).resolve().parent / "goldens"
GOLDEN = GOLDEN_DIR / "registry.json"
_TRACE_DIR = _ROOT / "tests" / "golden"

#: the canonical (deterministic, machine-independent) report subset —
#: kept field-for-field in sync with tests/test_golden_trace.py
CANONICAL_FIELDS = ("scenario", "status", "n_hosts", "vtime_ns",
                    "messages", "bytes", "tasks", "progress", "cells")

#: gallery sizes (shared with tests/test_golden_trace.py)
N_ITERS = 40
N_STEPS = 8


@dataclasses.dataclass(frozen=True)
class Entry:
    name: str
    version: int
    description: str
    #: fresh-Simulation factory; ``make(scenario)`` overrides the
    #: entry's scenario (campaign bases) — entries that cannot take an
    #: override (pinned live replays) raise ValueError on one
    make: Callable[..., Simulation]
    #: default fault grid — present on campaign bases only
    grid: Optional[Callable[[], FaultGrid]] = None
    tags: Tuple[str, ...] = ()

    @property
    def ref(self) -> str:
        return f"{self.name}@v{self.version}"


_REGISTRY: Dict[str, Dict[int, Entry]] = {}


def register(name: str, version: int, description: str,
             make: Callable[..., Simulation], *,
             grid: Optional[Callable[[], FaultGrid]] = None,
             tags: Tuple[str, ...] = ()) -> Entry:
    versions = _REGISTRY.setdefault(name, {})
    if version in versions:
        raise ValueError(
            f"{name}@v{version} is already registered — a changed "
            f"scenario needs a new version, not a re-register")
    ent = Entry(name, version, description, make, grid=grid, tags=tags)
    versions[version] = ent
    return ent


def entry(ref: str) -> Entry:
    """Resolve ``name`` (latest version) or ``name@vN`` (exact)."""
    name, _, ver = ref.partition("@")
    versions = _REGISTRY.get(name)
    if not versions:
        raise KeyError(f"unknown scenario {ref!r}; registered: "
                       f"{names()}")
    if not ver:
        return versions[max(versions)]
    if not ver.startswith("v") or not ver[1:].isdigit():
        raise KeyError(f"bad version in {ref!r} (want name@vN)")
    v = int(ver[1:])
    if v not in versions:
        raise KeyError(
            f"no version v{v} of {name!r}; have "
            f"{sorted(f'v{x}' for x in versions)}")
    return versions[v]


def load(ref: str, scenario: Optional[Scenario] = None) -> Simulation:
    """A fresh, unbuilt Simulation for ``ref`` (optionally with a
    scenario override, for campaign bases)."""
    ent = entry(ref)
    return ent.make(scenario) if scenario is not None else ent.make()


def names() -> List[str]:
    """Every registered ref, sorted (all versions)."""
    return sorted(e.ref for vs in _REGISTRY.values()
                  for e in vs.values())


def _no_override(ref: str, scenario) -> None:
    if scenario is not None:
        raise ValueError(
            f"{ref} pins its scenario (recorded live trace); it is "
            f"not a campaign base")


# ---------------------------------------------------------------------------
# gallery entries (the source of truth for tests/test_golden_trace.py)
# ---------------------------------------------------------------------------


def _straggler_host_death(scenario=None):
    wl = RackRing(n_iters=N_ITERS, skew_bound_ns=2_000_000)
    return Simulation(
        Topology.racks(2, 2), wl,
        scenario or Scenario(
            "straggler + host 3 dies",
            (Straggler("w1", 2.0),
             FailHost(host=3, at_vtime=N_ITERS * 4_000))),
        placement=wl.default_placement())


def _degraded_link(scenario=None):
    wl = RackRing(n_iters=N_ITERS, skew_bound_ns=2_000_000)
    return Simulation(
        Topology.racks(2, 2), wl,
        scenario or Scenario(
            "link 0<->2 8x latency",
            (DegradeLink(hosts=(0, 2), latency_factor=8.0,
                         from_vtime=N_ITERS * 1_000),)),
        placement=wl.default_placement())


def _colocated_serve_train(scenario=None):
    spec = ClusterSpec(n_pods=1, chips_per_pod=4)
    cost = StepCost(compute_ns=500_000, ici_bytes=1_000_000)
    return Simulation(
        Topology.single_host(n_cpus=1),
        [ChipRingTraining(spec, cost, N_STEPS,
                          skew_bound_ns=5_000_000),
         ModeledServe(n_clients=4, n_requests=N_STEPS,
                      service_ns=500_000)],
        scenario or Scenario("co-located serve + train"),
        cpu_resource=True)


def _colocated_cells(scenario=None):
    cells = {"w0": "hot", "w1": "cold", "w2": "hot", "w3": "cold"}
    wl = RackRing(n_racks=1, hosts_per_rack=4, n_iters=N_ITERS,
                  compute_ns=50_000, live=True, cells=cells,
                  skew_bound_ns=2_000_000)
    topo = Topology.single_host(n_cpus=1)
    topo.cell("hot", ways=2, working_set_frac=0.7, bw_share=0.3,
              bw_demand=0.7, mem_frac=0.6)
    topo.cell("cold", ways=8, working_set_frac=0.3, bw_share=0.5,
              bw_demand=0.4, mem_frac=0.2)
    topo.cell_config(n_warm_slots=2, recondition_ns=20_000)
    return Simulation(topo, wl, scenario or Scenario("co-located cells"))


def _live_recovery(scenario=None):
    from repro.live import CostLedger
    from repro.sim.live import live_recovery_sim
    _no_override("live_recovery@v1", scenario)
    return live_recovery_sim(
        CostLedger.replay(_TRACE_DIR / "live_recovery_trace.json"))


def _live_serve(scenario=None):
    from repro.live import CostLedger
    from repro.sim.live import live_serve_sim
    _no_override("live_serve@v1", scenario)
    return live_serve_sim(
        CostLedger.replay(_TRACE_DIR / "live_serve_trace.json"))


def _live_colocated(scenario=None):
    from repro.live import CostLedger
    from repro.sim.live import live_colocated_sim
    _no_override("live_colocated@v1", scenario)
    return live_colocated_sim(
        CostLedger.replay(_TRACE_DIR / "live_colocated_trace.json"))


def _diurnal_autoscale(scenario=None):
    # the membership marquee: a 4-host founding fleet rides one full
    # diurnal traffic period up to the 64-host pool and back down,
    # with the 60 late hosts joining the cluster as simulation events
    # (capacity_pool) just before the first scale-up decision needs
    # them.  Every decision is made by the control-plane workload from
    # observed simulated traffic — nothing here scripts the 4->64->4
    # ramp, the autoscaler discovers it.
    n_pool, founding = 64, 4
    join0, stagger = 100_000_000, 400_000
    topo = Topology(n_hosts=n_pool + 1, n_cpus=2)
    topo.capacity_pool(range(founding + 1, n_pool + 1), join0,
                       stagger_ns=stagger)
    ready = [0] * founding + [join0 + i * stagger
                              for i in range(n_pool - founding)]
    wl = AutoscaledServe(
        arrivals=diurnal_arrivals(3600, base_gap_ns=1_000_000,
                                  peak_gap_ns=12_500,
                                  period_ns=400_000_000, seed=7),
        n_pool=n_pool, ready_ns=ready, service_ns=800_000,
        min_active=founding, decide_every=8, probe_every=8,
        autoscaler=ThresholdAutoscaler(patience=3),
        placement="worst_fit")
    return Simulation(topo, wl,
                      scenario or Scenario("diurnal autoscale 4->64->4"),
                      placement=wl.default_placement())


# ---------------------------------------------------------------------------
# campaign bases + fault-injection showcases
# ---------------------------------------------------------------------------


def _rack_ring(scenario=None):
    wl = RackRing(n_racks=2, hosts_per_rack=2, n_iters=6,
                  compute_ns=5_000, cross_every=2,
                  skew_bound_ns=100_000)
    return Simulation(Topology.racks(2, 2), wl,
                      scenario or Scenario("rack ring base"),
                      placement=wl.default_placement())


def _rack_ring_grid() -> FaultGrid:
    return FaultGrid(types=("fail_task", "straggler", "clock_skew"),
                     targets=("w0", "w1", "w2", "w3"),
                     vtimes=(0, 20_000))


def _serve_smoke(scenario=None):
    return Simulation(Topology.single_host(n_cpus=4),
                      ModeledServe(n_clients=2, n_requests=4),
                      scenario or Scenario("serve base"))


def _serve_smoke_grid() -> FaultGrid:
    # type x target x vtime, planted to hit four outcome classes: a
    # bit-2 flip of a client's request payload routes the server's
    # response to a nonexistent endpoint (crash), fail_task starves
    # the server's fixed request count (deadlock), fail_host silently
    # zeroes progress (divergence), straggler only shifts time (ok)
    return FaultGrid(types=("bitflip", "fail_task", "fail_host",
                            "straggler"),
                     targets=("serve.client0", "serve.client1"),
                     vtimes=(0, 100_000),
                     knobs={"bit": 2})


def _bitflip_serve(scenario=None):
    return _serve_smoke(scenario or Scenario(
        "bit-2 flip of client0's first request payload",
        (BitFlip("serve.client0", at_step=0, bit=2),)))


def _clock_skew_rack(scenario=None):
    return _rack_ring(scenario or Scenario(
        "host 1 receive clock skewed +25us @ 100ppm",
        (ClockSkew(host=1, offset_ns=25_000, drift_ppm=100),)))


def _serve_flip_min(scenario=None):
    # campaign-derived: the minimized reproducer the serve_smoke
    # campaign emits for its planted bitflip crash, checked in as a
    # fault_repro/v1 spec and replayed as a first-class entry
    spec = json.loads((GOLDEN_DIR / "serve_flip_min.json").read_text())
    return _serve_smoke(scenario or spec_scenario(spec))


register("straggler_host_death", 1,
         "rack ring: straggler + mid-run host death (deadlock)",
         _straggler_host_death, tags=("gallery",))
register("degraded_link", 1,
         "rack ring: mid-run 8x cross-rack link degradation",
         _degraded_link, tags=("gallery",))
register("colocated_serve_train", 1,
         "serve + train sharing one host's simulated CPUs",
         _colocated_serve_train, tags=("gallery",))
register("colocated_cells", 1,
         "live rack ring on shared §3.3 memory-hierarchy cells",
         _colocated_cells, tags=("gallery",))
register("live_recovery", 1,
         "real sharded trainer: FailHost -> checkpoint restore "
         "(recorded trace replay)", _live_recovery,
         tags=("gallery", "live"))
register("live_serve", 1,
         "real BatchServer under open-loop Poisson arrivals "
         "(recorded trace replay)", _live_serve,
         tags=("gallery", "live"))
register("live_colocated", 1,
         "live train + live serve on one shared cell "
         "(recorded trace replay)", _live_colocated,
         tags=("gallery", "live"))
register("rack_ring", 1,
         "2x2 rack-ring campaign base (fail/straggle/skew grid)",
         _rack_ring, grid=_rack_ring_grid, tags=("campaign",))
register("serve_smoke", 1,
         "closed-loop serve campaign base with a planted bitflip "
         "crash", _serve_smoke, grid=_serve_smoke_grid,
         tags=("campaign",))
register("bitflip_serve", 1,
         "SDC showcase: bit-2 payload flip crashes hub routing",
         _bitflip_serve, tags=("fault",))
register("clock_skew_rack", 1,
         "per-host ingress clock skew on the rack ring",
         _clock_skew_rack, tags=("fault",))
register("serve_flip_min", 1,
         "campaign-derived minimized reproducer of the serve bitflip "
         "crash", _serve_flip_min, tags=("fault", "campaign-derived"))
register("diurnal_autoscale", 1,
         "65-host diurnal fleet: 60 hosts join mid-run, threshold "
         "autoscaler rides traffic 4->64->4", _diurnal_autoscale,
         tags=("gallery", "control"))


# ---------------------------------------------------------------------------
# pinned goldens
# ---------------------------------------------------------------------------


def canonical(report) -> dict:
    d = report.to_dict()
    out = {k: d[k] for k in CANONICAL_FIELDS}
    out["perf"] = {"sync_rounds": report.sync_rounds,
                   "proxy_syncs": report.proxy_syncs}
    if report.live:
        # live sections (recovery timelines) are golden-pinned too;
        # omitted when empty so pre-live rows stay byte-identical
        out["live"] = d["live"]
    if any(k != "membership" for k in report.control):
        # control-plane sections (autoscaler decisions, latency
        # percentiles, the membership timeline) are deterministic and
        # golden-pinned — but a bare membership timeline (FailHost
        # leave churn with no control workload) stays out so the
        # pre-membership fault rows remain byte-identical
        out["control"] = d["control"]
    return out


def golden_record(ref: str) -> dict:
    """Run ``ref`` standalone and reduce it to its pinned form: the
    outcome class (no baseline here, so no divergence) and, when the
    run completes, the canonical report subset."""
    try:
        report = load(ref).run()
    except Exception as e:                  # noqa: BLE001 - recorded
        return {"outcome": "crash",
                "detail": f"{type(e).__name__}: {e}",
                "canonical": None}
    violations = default_invariants(report)
    if violations:
        outcome = "invariant-violation"
    elif report.status == "deadlock":
        outcome = "deadlock"
    else:
        outcome = "ok"
    return {"outcome": outcome, "detail": "",
            "canonical": canonical(report)}


def check(refs: Optional[List[str]] = None, *,
          regen: bool = False) -> List[str]:
    """Replay every registered scenario against its pinned golden;
    returns a list of human-readable failures (empty = green).  With
    ``regen=True``, rewrite the golden file instead."""
    refs = refs or names()
    records = {ref: golden_record(ref) for ref in refs}
    if regen:
        GOLDEN_DIR.mkdir(exist_ok=True)
        existing = json.loads(GOLDEN.read_text()) \
            if GOLDEN.exists() else {}
        existing.update(records)
        GOLDEN.write_text(json.dumps(existing, indent=1,
                                     sort_keys=True) + "\n")
        return []
    if not GOLDEN.exists():
        return [f"no golden file {GOLDEN}; generate with "
                f"python -m repro.sim.registry check --regen"]
    golden = json.loads(GOLDEN.read_text())
    failures = []
    for ref, rec in records.items():
        want = golden.get(ref)
        if want is None:
            failures.append(f"{ref}: no pinned golden (--regen after "
                            f"review)")
        elif rec != want:
            diffs = [k for k in rec if rec.get(k) != want.get(k)]
            failures.append(f"{ref}: diverged from pin on {diffs}\n"
                            f"  got: {rec.get(diffs[0]) if diffs else rec}\n"
                            f" want: {want.get(diffs[0]) if diffs else want}")
    return failures


def main(argv: Optional[List[str]] = None) -> int:
    import argparse

    ap = argparse.ArgumentParser(
        prog="python -m repro.sim.registry",
        description="versioned scenario registry")
    sub = ap.add_subparsers(dest="cmd", required=True)
    lp = sub.add_parser("list")
    lp.add_argument("--json", action="store_true",
                    help="machine-readable listing (one object per "
                         "ref: name/version/tags/campaign-base flag)")
    p = sub.add_parser("check", help="replay every entry against its "
                                     "pinned golden")
    p.add_argument("refs", nargs="*", help="subset of refs (default "
                                           "all)")
    p.add_argument("--regen", action="store_true")
    args = ap.parse_args(argv)
    if args.cmd == "list":
        if args.json:
            rows = [{"ref": ref, "name": entry(ref).name,
                     "version": entry(ref).version,
                     "description": entry(ref).description,
                     "tags": list(entry(ref).tags),
                     "campaign_base": entry(ref).grid is not None}
                    for ref in names()]
            print(json.dumps(rows, indent=1))
            return 0
        for ref in names():
            e = entry(ref)
            kind = "campaign-base" if e.grid else ",".join(e.tags)
            print(f"{ref:26s} [{kind}] {e.description}")
        return 0
    failures = check(args.refs or None, regen=args.regen)
    if args.regen:
        print(f"wrote {GOLDEN}")
        return 0
    for f in failures:
        print(f"FAIL {f}")
    print(f"registry check: {len(names()) if not args.refs else len(args.refs)} "
          f"refs, {len(failures)} failures")
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
