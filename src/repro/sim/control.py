"""Traffic-driven control plane: autoscaling a serve fleet over a
mutable cluster membership.

The membership half of the story lives in the engines — hosts join and
leave as vtime-stamped simulation events (``Topology.join`` /
``Topology.capacity_pool``, ``JoinHost`` / ``FailHost`` injections,
``Orchestrator.add_host`` / ``retire_host``).  This module supplies the
*control plane* that reacts to traffic on top of that substrate:
:class:`AutoscaledServe` drives a pool of modeled servers through an
open-loop arrival schedule (:func:`~repro.sim.workloads.poisson_arrivals`
/ :func:`~repro.sim.workloads.diurnal_arrivals` /
:func:`~repro.sim.workloads.burst_arrivals`), scaling the active fleet
with a pluggable :class:`ThresholdAutoscaler` and routing each request
with a pluggable placement policy (:data:`PLACEMENT_POLICIES`:
``first_fit`` / ``best_fit`` / ``worst_fit``).

Topology integration: ``Topology.capacity_pool`` declares *when
capacity arrives* (hosts join on a provisioning timeline); the
controller's ``ready_ns`` schedule mirrors it and decides *when traffic
lands on it* — a scale-up can only boot servers whose host has joined.

Determinism: the controller, load balancer and response sink are
co-located on host 0 (``default_placement``), so in the dist engine one
worker owns all control state.  Every scale/placement decision is pure
integer arithmetic over the build-time arrival schedule (the controller
advances to each arrival with modeled compute, so its vtime *is* the
schedule); only measured request latencies come from the simulation —
recorded by the sink at response visibility, which every engine orders
identically.  The resulting ``SimReport.control`` section (decision
timeline, boot/drain counts, health probes, nearest-rank latency
percentiles) is integer-valued and bit-identical across
single/barrier/async/dist — the engine harness compares it exactly.

Protocol (all over one ``ctlnet`` fabric):

* ``("boot", gen)`` — controller -> server: enter the active set; each
  boot starts a fresh generation (a re-booted server counts serves
  against its new generation — fresh state, no resurrection).
* ``("req", j, arr_ns, k)`` — controller -> server ``k``: request
  ``j``, scheduled at ``arr_ns``.
* ``("resp", j, arr_ns, k)`` — server -> sink: request done; the sink
  records ``latency = sink.vtime - arr_ns``.
* ``("drain", )`` — controller -> server: leave the active set (the
  server keeps serving requests already routed to it — channel order
  guarantees those were delivered first).
* ``("probe", seq)`` / ``("ack", seq, k)`` — health check: controller
  probes every active server at a configurable decision cadence;
  servers ack to the sink.
* ``("stop", )`` / ``("fin", n_acks)`` — shutdown: every pool server
  (booted or not) stops; the sink drains exactly the announced probe
  acks after the last response.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.ipc import LinkSpec
from repro.core.vtask import Compute, Recv, Send
from repro.sim.scenario import TaskHandle
from repro.sim.topology import FabricSpec
from repro.sim.workload import EndpointSpec, Program, Workload

# -- placement policies ------------------------------------------------------
#
# A policy picks the server for one request:
#   policy(active, busy_until, now, service_ns, cap_ns) -> server id
# ``active`` is the sorted active set, ``busy_until[k]`` the vtime at
# which server k's modeled backlog drains (the controller charges
# ``service_ns`` per routed request), ``cap_ns`` the backlog a "fit"
# may not exceed.  Pure integer arithmetic; ties break to the lowest id.


def first_fit(active: List[int], busy_until: List[int], now: int,
              service_ns: int, cap_ns: int) -> int:
    """First idle server in id order; all busy -> least backlog."""
    for k in active:
        if busy_until[k] <= now:
            return k
    return min(active, key=lambda k: (busy_until[k], k))


def best_fit(active: List[int], busy_until: List[int], now: int,
             service_ns: int, cap_ns: int) -> int:
    """Deepest backlog that still fits under ``cap_ns`` after taking
    this request (pack tight, keep spare servers idle for scale-down);
    nothing fits -> least backlog."""
    fits = [k for k in active
            if max(busy_until[k] - now, 0) + service_ns <= cap_ns]
    if fits:
        return max(fits, key=lambda k: (max(busy_until[k] - now, 0), -k))
    return min(active, key=lambda k: (max(busy_until[k] - now, 0), k))


def worst_fit(active: List[int], busy_until: List[int], now: int,
              service_ns: int, cap_ns: int) -> int:
    """Least-backlog server (spread wide, minimize per-request queueing)."""
    return min(active, key=lambda k: (max(busy_until[k] - now, 0), k))


PLACEMENT_POLICIES: Dict[str, Callable] = {
    "first_fit": first_fit,
    "best_fit": best_fit,
    "worst_fit": worst_fit,
}


@dataclasses.dataclass(frozen=True)
class ThresholdAutoscaler:
    """Utilization-threshold scaling: utilization is measured per
    decision window as offered work over capacity —
    ``reqs * service_ns / (elapsed * n_active)`` — in integer permille.
    Above ``up_x1000`` the active set multiplies by ``factor``; below
    ``down_x1000`` it divides by ``factor`` (never past the caller's
    ``min_active`` / ``max_active``).  ``patience`` is hysteresis: the
    threshold must hold for that many *consecutive* decision windows
    before the fleet moves (jittered open-loop arrivals make single
    windows noisy; patience >= 2 stops flapping).  Pure integers, so
    decisions are bit-identical across engines."""
    up_x1000: int = 750
    down_x1000: int = 300
    factor: int = 2
    patience: int = 1

    def __post_init__(self):
        if not 0 <= self.down_x1000 < self.up_x1000:
            raise ValueError(
                f"need 0 <= down < up, got down={self.down_x1000} "
                f"up={self.up_x1000}")
        if self.factor < 2:
            raise ValueError(f"factor must be >= 2, got {self.factor}")
        if self.patience < 1:
            raise ValueError(f"patience must be >= 1, "
                             f"got {self.patience}")

    def target(self, util_x1000: int, n_active: int,
               min_active: int, max_active: int) -> int:
        if util_x1000 > self.up_x1000:
            return min(max_active, n_active * self.factor)
        if util_x1000 < self.down_x1000:
            return max(min_active, n_active // self.factor)
        return n_active


class AutoscaledServe(Workload):
    """Open-loop serve fleet under a traffic-driven control plane.

    Programs: ``ctl.lb`` (source + load balancer + autoscaler, one body
    so all control state is serial), ``ctl.sink`` (response collector /
    latency recorder), and ``pool{k}`` for ``k < n_pool`` (modeled
    servers, one per pool host).  ``default_placement`` puts both
    control programs on host 0 and ``pool{k}`` on host ``k + 1`` —
    pair it with ``Topology.capacity_pool`` joining those hosts on the
    ``ready_ns`` schedule.

    ``ready_ns[k]`` is the vtime from which server ``k`` may be booted
    (its host's join vtime; 0 = founding capacity).  At least
    ``min_active`` servers must be ready at vtime 0.
    """

    name = "autoserve"
    CTL = "ctl.lb"
    SINK = "ctl.sink"

    def __init__(self, *, arrivals: Sequence[int], n_pool: int,
                 ready_ns: Optional[Sequence[int]] = None,
                 service_ns: int = 200_000,
                 min_active: int = 1, max_active: Optional[int] = None,
                 decide_every: int = 8,
                 autoscaler: Optional[ThresholdAutoscaler] = None,
                 placement: str = "first_fit",
                 probe_every: int = 0,
                 queue_cap: int = 8,
                 req_bytes: int = 1024, resp_bytes: int = 256,
                 link: LinkSpec = LinkSpec(bandwidth_bps=25e9 * 8,
                                           latency_ns=10_000)):
        arr = np.asarray(arrivals, dtype=np.int64)
        if arr.ndim != 1 or len(arr) == 0:
            raise ValueError("arrivals must be a non-empty 1-D schedule")
        if np.any(arr < 1):
            raise ValueError("arrival vtimes must be >= 1 ns")
        if np.any(np.diff(arr) < 0):
            raise ValueError("arrivals must be non-decreasing")
        if n_pool < 1:
            raise ValueError(f"n_pool must be >= 1, got {n_pool}")
        if service_ns < 1:
            raise ValueError(f"service_ns must be >= 1, got {service_ns}")
        if decide_every < 1:
            raise ValueError(f"decide_every must be >= 1, "
                             f"got {decide_every}")
        if queue_cap < 1:
            raise ValueError(f"queue_cap must be >= 1, got {queue_cap}")
        if probe_every < 0:
            raise ValueError(f"probe_every must be >= 0, "
                             f"got {probe_every}")
        ready = ([0] * n_pool if ready_ns is None
                 else [int(v) for v in ready_ns])
        if len(ready) != n_pool:
            raise ValueError(f"ready_ns needs one entry per pool "
                             f"server: {len(ready)} != {n_pool}")
        max_active = n_pool if max_active is None else max_active
        if not 1 <= min_active <= max_active <= n_pool:
            raise ValueError(
                f"need 1 <= min_active <= max_active <= n_pool, got "
                f"{min_active} <= {max_active} <= {n_pool}")
        if sum(1 for v in ready if v <= 0) < min_active:
            raise ValueError(
                f"min_active={min_active} servers must be ready at "
                f"vtime 0; only {sum(1 for v in ready if v <= 0)} are")
        if placement not in PLACEMENT_POLICIES:
            raise ValueError(
                f"unknown placement policy {placement!r}; "
                f"available: {sorted(PLACEMENT_POLICIES)}")
        self.arrivals = arr
        self.n_pool = n_pool
        self.ready_ns = ready
        self.service_ns = service_ns
        self.min_active = min_active
        self.max_active = max_active
        self.decide_every = decide_every
        self.autoscaler = autoscaler or ThresholdAutoscaler()
        self.placement_name = placement
        self.probe_every = probe_every
        self.queue_cap = queue_cap
        self.req_bytes = req_bytes
        self.resp_bytes = resp_bytes
        self.link = link
        self._sink_handle = TaskHandle()
        # progress arrays (monotone counters: the dist merge max-folds
        # per-worker copies, so each must be written by one owner only)
        n = len(arr)
        self.sent = np.zeros(1, dtype=np.int64)        # ctl
        self.served = np.zeros(1, dtype=np.int64)      # sink
        self.routed = np.zeros(n_pool, dtype=np.int64)     # ctl
        self.served_by = np.zeros(n_pool, dtype=np.int64)  # server k
        self.boots = np.zeros(n_pool, dtype=np.int64)      # server k
        self.drains = np.zeros(n_pool, dtype=np.int64)     # server k
        self.probe_acks = np.zeros(1, dtype=np.int64)  # sink
        # control timeline (ctl + sink state; host 0's worker owns both)
        self.latencies = np.zeros(n, dtype=np.int64)
        self.decisions: List[Dict[str, int]] = []
        self.peak_active = 0
        self.probes_sent = 0
        self.boots_sent = 0
        self.drains_sent = 0

    # -- bodies --------------------------------------------------------------
    def _ctl_factory(self, eps):
        ep = eps["ctl.lb.ep"]

        def body():
            arr = self.arrivals
            scaler = self.autoscaler
            policy = PLACEMENT_POLICIES[self.placement_name]
            service = self.service_ns
            cap_ns = self.queue_cap * service
            busy_until = [0] * self.n_pool
            gen = [0] * self.n_pool
            active: List[int] = []

            def boot(k: int) -> Send:
                gen[k] += 1
                active.append(k)
                active.sort()
                self.boots_sent += 1
                return Send(ep, f"pool.srv{k}", 64,
                            payload=("boot", gen[k]))

            # initial fleet: lowest-id servers ready at vtime 0
            for k in range(self.n_pool):
                if len(active) >= self.min_active:
                    break
                if self.ready_ns[k] <= 0:
                    yield boot(k)
            self.peak_active = len(active)
            prev = 0
            last_decide = 0
            n_decisions = 0
            up_streak = down_streak = 0
            for i in range(len(arr)):
                t = int(arr[i])
                if t > prev:
                    yield Compute(t - prev)
                prev = t
                if i and i % self.decide_every == 0:
                    # offered work over capacity, integer permille
                    elapsed = max(1, t - last_decide)
                    util = (self.decide_every * service * 1000
                            // (elapsed * len(active)))
                    was = len(active)
                    # hysteresis: the threshold must hold `patience`
                    # consecutive windows before the fleet moves
                    if util > scaler.up_x1000:
                        up_streak, down_streak = up_streak + 1, 0
                    elif util < scaler.down_x1000:
                        up_streak, down_streak = 0, down_streak + 1
                    else:
                        up_streak = down_streak = 0
                    target = was
                    if max(up_streak, down_streak) >= scaler.patience:
                        target = scaler.target(util, was,
                                               self.min_active,
                                               self.max_active)
                        up_streak = down_streak = 0
                    if target > was:
                        for k in range(self.n_pool):
                            if len(active) >= target:
                                break
                            if k not in active and self.ready_ns[k] <= t:
                                yield boot(k)
                    elif target < was:
                        # drain highest ids first (boot order is lowest
                        # first, so the fleet shrinks LIFO)
                        for k in sorted(active, reverse=True):
                            if len(active) <= target:
                                break
                            active.remove(k)
                            self.drains_sent += 1
                            yield Send(ep, f"pool.srv{k}", 64,
                                       payload=("drain",))
                    self.decisions.append(
                        {"vtime": t, "util_x1000": int(util),
                         "from": was, "to": len(active)})
                    self.peak_active = max(self.peak_active,
                                           len(active))
                    n_decisions += 1
                    if self.probe_every \
                            and n_decisions % self.probe_every == 0:
                        for k in active:
                            self.probes_sent += 1
                            yield Send(ep, f"pool.srv{k}", 64,
                                       payload=("probe",
                                                self.probes_sent))
                    last_decide = t
                k = policy(active, busy_until, t, service, cap_ns)
                busy_until[k] = max(busy_until[k], t) + service
                yield Send(ep, f"pool.srv{k}", self.req_bytes,
                           payload=("req", i, t, k))
                self.routed[k] += 1
                self.sent[0] = i + 1
            for k in range(self.n_pool):
                yield Send(ep, f"pool.srv{k}", 64, payload=("stop",))
            yield Send(ep, "ctl.sink.ep", 64,
                       payload=("fin", self.probes_sent))
        return body()

    def _server_factory(self, k: int):
        def factory(eps):
            ep = eps[f"pool.srv{k}"]

            def body():
                while True:
                    msg = yield Recv(ep)
                    kind = msg.payload[0]
                    if kind == "req":
                        _, j, arr_ns, _who = msg.payload
                        yield Compute(self.service_ns)
                        yield Send(ep, "ctl.sink.ep", self.resp_bytes,
                                   payload=("resp", j, arr_ns, k))
                        self.served_by[k] += 1
                    elif kind == "boot":
                        # a fresh generation: re-booting a drained
                        # server starts clean, like a re-joined host
                        self.boots[k] += 1
                    elif kind == "probe":
                        yield Send(ep, "ctl.sink.ep", 64,
                                   payload=("ack", msg.payload[1], k))
                    elif kind == "drain":
                        # no early close: everything already routed
                        # here was delivered first (channel order) and
                        # still gets served
                        self.drains[k] += 1
                    elif kind == "stop":
                        return
            return body()
        return factory

    def _sink_factory(self, eps):
        ep = eps["ctl.sink.ep"]

        def body():
            task = self._sink_handle.task
            n = len(self.arrivals)
            got = acks = 0
            expect_acks: Optional[int] = None
            while (got < n or expect_acks is None
                   or acks < expect_acks):
                msg = yield Recv(ep)
                kind = msg.payload[0]
                if kind == "resp":
                    _, j, arr_ns, _k = msg.payload
                    self.latencies[j] = int(task.vtime) - int(arr_ns)
                    got += 1
                    self.served[0] = got
                elif kind == "ack":
                    acks += 1
                    self.probe_acks[0] = acks
                elif kind == "fin":
                    expect_acks = int(msg.payload[1])
        return body()

    # -- workload protocol ---------------------------------------------------
    def fabrics(self) -> List[FabricSpec]:
        return [FabricSpec("ctlnet", self.link)]

    def programs(self) -> List[Program]:
        out = [
            Program(name=self.CTL, make_body=self._ctl_factory,
                    endpoints=(EndpointSpec("ctl.lb.ep", "ctlnet"),)),
            Program(name=self.SINK, make_body=self._sink_factory,
                    endpoints=(EndpointSpec("ctl.sink.ep", "ctlnet"),),
                    handle=self._sink_handle)]
        for k in range(self.n_pool):
            out.append(Program(
                name=f"pool{k}", make_body=self._server_factory(k),
                endpoints=(EndpointSpec(f"pool.srv{k}", "ctlnet"),)))
        return out

    def default_placement(self) -> Dict[str, int]:
        pl = {self.CTL: 0, self.SINK: 0}
        for k in range(self.n_pool):
            pl[f"pool{k}"] = k + 1
        return pl

    def traffic(self) -> Dict[Tuple[str, str], float]:
        n = len(self.arrivals)
        per = float(n) / self.n_pool
        t: Dict[Tuple[str, str], float] = {}
        for k in range(self.n_pool):
            t[(self.CTL, f"pool{k}")] = per * self.req_bytes
            t[(f"pool{k}", self.SINK)] = per * self.resp_bytes
        return t

    def progress(self) -> Dict[str, np.ndarray]:
        return {"sent": self.sent, "served": self.served,
                "routed": self.routed, "served_by": self.served_by,
                "boots": self.boots, "drains": self.drains,
                "probe_acks": self.probe_acks}

    def reset(self) -> None:
        self.sent[:] = 0
        self.served[:] = 0
        self.routed[:] = 0
        self.served_by[:] = 0
        self.boots[:] = 0
        self.drains[:] = 0
        self.probe_acks[:] = 0
        self.latencies[:] = 0
        self.decisions.clear()
        self.peak_active = 0
        self.probes_sent = 0
        self.boots_sent = 0
        self.drains_sent = 0

    # -- control hook (SimReport.control) ------------------------------------
    def control_report(self, tasks: Optional[set] = None
                       ) -> Optional[Dict[str, Any]]:
        """Post-run control section.  ``tasks`` restricts to owned task
        names (dist workers): the controller, sink and their state all
        live on host 0, so exactly the worker owning ``ctl.lb`` reports
        — the coordinator's first-non-empty merge is authoritative."""
        if tasks is not None and self.CTL not in tasks:
            return None
        lat = sorted(int(v) for v in self.latencies if v > 0)

        def pct(q: int) -> int:     # nearest-rank, pure integers
            if not lat:
                return 0
            return lat[min(len(lat) - 1,
                           max(0, (q * len(lat) + 99) // 100 - 1))]

        return {
            "placement": self.placement_name,
            "autoscaler": {
                "up_x1000": self.autoscaler.up_x1000,
                "down_x1000": self.autoscaler.down_x1000,
                "factor": self.autoscaler.factor,
                "min_active": self.min_active,
                "max_active": self.max_active,
                "decide_every": self.decide_every},
            "decisions": list(self.decisions),
            "peak_active": int(self.peak_active),
            "final_active": int(self.decisions[-1]["to"]
                                if self.decisions else self.min_active),
            "served": int(self.served[0]),
            "boots": int(self.boots_sent),
            "drains": int(self.drains_sent),
            "probes": {"sent": int(self.probes_sent),
                       "acks": int(self.probe_acks[0])},
            "latency_ns": {
                "p50": pct(50), "p95": pct(95), "p99": pct(99),
                "max": lat[-1] if lat else 0,
                "mean": (sum(lat) // len(lat)) if lat else 0},
        }
