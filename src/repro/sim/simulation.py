"""Simulation: materialize (Topology, Workloads, Scenario) and run.

Single entry point of the facade.  ``build()`` turns the declarative
pieces into the concrete substrate — Scheduler or Orchestrator, hubs,
endpoints, scopes, injection wrappers — in a deterministic order, so a
facade-built simulation is bit-identical to careful hand-wiring (see
``tests/test_sim_equivalence.py``).  ``run()`` executes it and returns
a :class:`~repro.sim.report.SimReport`.

Engine selection: ``mode="auto"`` runs single-host topologies on a
plain :class:`~repro.core.scheduler.Scheduler` and multi-host ones on
the async :class:`~repro.core.orchestrator.Orchestrator`; ``"single"``,
``"async"``, and ``"barrier"`` force an engine (the orchestrator modes
work for ``n_hosts == 1`` too, which the legacy rack adapter relies
on).

Placement: ``placement="auto"`` routes component->host assignment
through ``Orchestrator.co_locate`` on the merged workload traffic
matrix; a dict pins components explicitly; ``"round_robin"`` spreads
them.

Cells (§3.3): ``Topology.cell`` declarations are validated against
every ``Program.cell`` / ``Interference.cell`` reference at build time
(an undeclared name is an error, not a silent no-op), instantiated as
one :class:`~repro.core.cells.CellManager` per host that ends up
hosting cell-bound components — identically in all four engines,
including the dist workers' forked replicas — and reported back as
``SimReport.cells``.  ``cells="auto"`` additionally derives a default
cell for every program co-located with another program or an
interference load (and for the loads themselves), so co-location
implies a controlled resource domain without per-program declarations.
"""
from __future__ import annotations

import math
import time
from typing import Any, Dict, List, Optional, Sequence, Tuple, Union

from repro.core.cells import CellManager
from repro.core.ipc import Endpoint, Hub, Message
from repro.core.orchestrator import Orchestrator
from repro.core.scheduler import DeadlockError, Scheduler
from repro.core.scope import Scope
from repro.core.vtask import Compute, State, VTask
from repro.sim.report import HostReport, SimReport, _jsonable
from repro.sim.scenario import (BitFlip, ClockSkew, DegradeLink,
                                FailHost, FailTask, Interference,
                                JoinHost, Scenario, Straggler,
                                TaskHandle, bitflip_body,
                                fail_gated_body, scaled_body)
from repro.sim.topology import CellSpec, FabricSpec, Topology
from repro.sim.workload import Program, Workload

PlacementSpec = Union[str, Dict[str, int]]


def _load_body(bursts: int, burst_ns: int):
    for _ in range(bursts):
        yield Compute(burst_ns)


class Simulation:
    def __init__(self, topology: Topology,
                 workloads: Union[Workload, Sequence[Workload]],
                 scenario: Optional[Scenario] = None, *,
                 placement: PlacementSpec = "auto",
                 mode: str = "auto",
                 capacity: Optional[int] = None,
                 cpu_resource: bool = False,
                 cells: str = "declared"):
        self.topology = topology
        self.workloads: List[Workload] = (
            [workloads] if isinstance(workloads, Workload)
            else list(workloads))
        self.scenario = scenario or Scenario()
        self.placement_spec = placement
        self.capacity = capacity
        self.cpu_resource = cpu_resource
        if cells not in ("declared", "auto"):
            raise ValueError(f"cells must be 'declared' or 'auto', "
                             f"got {cells!r}")
        self.cells_mode = cells
        if mode == "auto":
            mode = "single" if topology.n_hosts == 1 else "async"
        if mode not in ("single", "async", "barrier"):
            raise ValueError(f"unknown mode {mode!r}")
        if mode == "single" and topology.n_hosts > 1:
            raise ValueError("mode='single' needs a 1-host topology")
        self.mode = mode
        # populated by build()
        self.scheduler: Optional[Scheduler] = None
        self.orchestrator: Optional[Orchestrator] = None
        self.hubs: Dict[str, Hub] = {}          # fabric- or host-keyed
        self.endpoints: Dict[str, Endpoint] = {}
        self.tasks: List[VTask] = []            # workload programs, in order
        self.task_by_name: Dict[str, VTask] = {}
        self.scopes: List[Scope] = []
        self.placement: Dict[str, int] = {}
        self.cell_managers: Dict[int, CellManager] = {}
        #: merged membership declarations (Topology.join + JoinHost
        #: injections): host -> join vtime; resolved by build()
        self.joins: Dict[int, int] = {}
        #: single-engine membership log (leave events from FailHost);
        #: multi-host engines read the orchestrator's timeline instead
        self._membership_events: List[dict] = []
        self._built = False

    # -- introspection helpers ----------------------------------------------
    def _programs(self) -> List[Tuple[Workload, Program]]:
        out = []
        seen = set()
        for wl in self.workloads:
            for prog in wl.programs():
                if prog.name in seen:
                    raise ValueError(f"duplicate program {prog.name!r}")
                seen.add(prog.name)
                out.append((wl, prog))
        return out

    def _fabrics(self) -> List[FabricSpec]:
        out: List[FabricSpec] = []
        by_name: Dict[str, FabricSpec] = {}
        for wl in self.workloads:
            for fab in wl.fabrics():
                prev = by_name.get(fab.name)
                if prev is None:
                    by_name[fab.name] = fab
                    out.append(fab)
                elif prev.link != fab.link:
                    raise ValueError(
                        f"fabric {fab.name!r} declared with two links")
        return out

    def _merged_traffic(self) -> Dict[Tuple[str, str], float]:
        traffic: Dict[Tuple[str, str], float] = {}
        for wl in self.workloads:
            for pair, w in wl.traffic().items():
                traffic[pair] = traffic.get(pair, 0.0) + w
        return traffic

    def _resolve_placement(self, names: List[str]) -> Dict[str, int]:
        n_hosts = self.topology.n_hosts
        spec = self.placement_spec
        if n_hosts == 1 and not isinstance(spec, dict):
            return {n: 0 for n in names}
        if isinstance(spec, dict):
            missing = [n for n in names if n not in spec]
            if missing:
                raise ValueError(f"placement missing {missing}")
            bad = [n for n in names
                   if not 0 <= spec[n] < n_hosts]
            if bad:
                raise ValueError(f"placement out of range for {bad}")
            return {n: spec[n] for n in names}
        if spec == "round_robin":
            return {n: i % n_hosts for i, n in enumerate(names)}
        if spec == "auto":
            capacity = self.capacity or max(
                1, math.ceil(len(names) / n_hosts))
            return Orchestrator.co_locate(
                names, self._merged_traffic(), n_hosts, capacity)
        raise ValueError(f"unknown placement {spec!r}")

    # -- cells (§3.3) --------------------------------------------------------
    def _resolve_interference(self) -> List[Tuple[Interference, int]]:
        """Validate each Interference injection and pin it to a host
        (declaration order preserved: the i-th entry becomes vtask
        ``load{i}``)."""
        out: List[Tuple[Interference, int]] = []
        n_hosts = self.topology.n_hosts
        for inj in self.scenario.injections:
            if not isinstance(inj, Interference):
                continue
            host = inj.host
            if host is not None and not 0 <= host < n_hosts:
                raise ValueError(
                    f"Interference host {host} outside "
                    f"0..{n_hosts - 1}")
            if host is None:
                if inj.co_locate_with is None:
                    raise ValueError(
                        "Interference needs host or co_locate_with")
                if inj.co_locate_with not in self.placement:
                    raise ValueError(
                        f"Interference co_locate_with targets "
                        f"unknown program {inj.co_locate_with!r}")
                host = self.placement[inj.co_locate_with]
            out.append((inj, host))
        return out

    def _resolve_cells(self, programs,
                       inter_targets: List[Tuple[Interference, int]]
                       ) -> Tuple[Dict[str, str], List[Optional[str]]]:
        """Map programs and interference loads to cells, derive auto
        cells for co-located placements (``cells="auto"``), reject
        undeclared references, and construct the per-host CellManagers
        (``self.cell_managers``)."""
        topo = self.topology
        cell_specs: Dict[str, CellSpec] = dict(topo.cells)
        cell_of: Dict[str, str] = {p.name: p.cell for _, p in programs
                                   if p.cell}
        load_cells: List[Optional[str]] = [inj.cell
                                           for inj, _ in inter_targets]
        if self.cells_mode == "auto":
            # co-location implies a controlled resource domain: every
            # program sharing a host with another program or an
            # interference load gets a default cell, as does each load
            prog_hosts: Dict[int, List[str]] = {}
            for _, p in programs:
                prog_hosts.setdefault(
                    self.placement[p.name], []).append(p.name)
            load_hosts = {h for _, h in inter_targets}
            for h in sorted(prog_hosts):
                if len(prog_hosts[h]) < 2 and h not in load_hosts:
                    continue
                for n in prog_hosts[h]:
                    if n not in cell_of:
                        auto = f"cell:{n}"
                        cell_specs.setdefault(auto, CellSpec(name=auto))
                        cell_of[n] = auto
            for i in range(len(load_cells)):
                if load_cells[i] is None:
                    auto = f"cell:load{i}"
                    cell_specs.setdefault(auto, CellSpec(name=auto))
                    load_cells[i] = auto
        # a Program.cell naming an undeclared cell used to be a silent
        # no-op (slowdown 1.0, switch cost 0 — see repro.core.cells);
        # through the facade, that masks misconfiguration, so it is a
        # build-time error.
        bad = [(p.name, p.cell) for _, p in programs
               if p.cell and p.cell not in cell_specs]
        bad += [(f"Interference#{i}", c)
                for i, c in enumerate(load_cells)
                if c and c not in cell_specs]
        if bad:
            raise ValueError(
                f"undeclared cells referenced (declare them with "
                f"Topology.cell(name, ...)): {bad}")
        self.cell_managers = {}
        if cell_specs:
            need: Dict[int, set] = {}
            for n, c in cell_of.items():
                need.setdefault(self.placement[n], set()).add(c)
            for i, (_inj, h) in enumerate(inter_targets):
                if load_cells[i]:
                    need.setdefault(h, set()).add(load_cells[i])
            for h in sorted(need):
                cm = CellManager(host=h, **topo.cell_knobs)
                for name, spec in cell_specs.items():  # decl. order
                    if name in need[h]:
                        cm.add(spec.to_cell())
                self.cell_managers[h] = cm
        return cell_of, load_cells

    # -- membership ----------------------------------------------------------
    def _resolve_joins(self) -> Dict[int, int]:
        """Merge ``Topology.join`` declarations with :class:`JoinHost`
        injections into one host -> join-vtime map.  JoinHost gets the
        same validation as Topology.join (in range, not host 0, vtime
        >= 1); a host declared in both places — or twice — is a
        conflict, not a silent override."""
        joins: Dict[int, int] = dict(self.topology.joins)
        n_hosts = self.topology.n_hosts
        for inj in self.scenario.injections:
            if not isinstance(inj, JoinHost):
                continue
            if not 0 <= inj.host < n_hosts:
                raise ValueError(f"JoinHost host {inj.host} outside "
                                 f"0..{n_hosts - 1}")
            if inj.host == 0:
                raise ValueError("host 0 is the founding member and "
                                 "cannot join late")
            if inj.at_vtime < 1:
                raise ValueError(f"JoinHost vtime must be >= 1, got "
                                 f"{inj.at_vtime}")
            if inj.host in joins:
                raise ValueError(
                    f"host {inj.host} already has a join event at "
                    f"vtime {joins[inj.host]}")
            joins[inj.host] = inj.at_vtime
        return joins

    # -- scenario fault plan -------------------------------------------------
    def _resolve_fault_plan(self, names: List[str]
                            ) -> Tuple[Dict[str, float],
                                       Dict[str, FailTask]]:
        """Resolve Straggler/FailTask/FailHost injections to per-task
        compute scale factors and fail points.  Failure precedence (see
        tests/test_scenario_edges.py): an explicit FailTask always wins
        over a FailHost expansion regardless of declaration order; two
        explicit FailTasks on one program is an error; overlapping
        FailHosts on one host keep the earliest death.  Shared by
        ``build()`` (generator wrappers) and the vectorized compiler
        (fail_pc/fail_vtime arrays), so both engines kill identically.
        Requires ``self.placement`` (FailHost expansion)."""
        scale: Dict[str, float] = {}
        fails: Dict[str, FailTask] = {}
        explicit_fails: set = set()
        n_hosts = self.topology.n_hosts
        for inj in self.scenario.injections:
            if isinstance(inj, Straggler):
                scale[inj.task] = scale.get(inj.task, 1.0) * inj.slowdown
            elif isinstance(inj, FailTask):
                if inj.task in explicit_fails:
                    raise ValueError(f"two failures for {inj.task!r}")
                fails[inj.task] = inj
                explicit_fails.add(inj.task)
            elif isinstance(inj, FailHost):
                if not 0 <= inj.host < n_hosts:
                    raise ValueError(
                        f"FailHost host {inj.host} outside "
                        f"0..{n_hosts - 1}")
                for n, h in self.placement.items():
                    if h != inj.host or n in explicit_fails:
                        continue
                    prev = fails.get(n)
                    if prev is None or inj.at_vtime < prev.at_vtime:
                        fails[n] = FailTask(n, at_vtime=inj.at_vtime)
        unknown = [(t, "Straggler") for t in scale if t not in names] + \
                  [(t, "FailTask") for t in fails if t not in names]
        if unknown:
            raise ValueError(f"injections target unknown programs "
                             f"{unknown}; available: {sorted(names)}")
        return scale, fails

    def _resolve_bitflips(self, names: List[str]
                          ) -> Dict[str, List[BitFlip]]:
        """Validate BitFlip injections (known target, exactly one
        trigger, sane bit) and group them per task, declaration order
        preserved."""
        out: Dict[str, List[BitFlip]] = {}
        for inj in self.scenario.injections:
            if not isinstance(inj, BitFlip):
                continue
            if inj.task not in names:
                raise ValueError(
                    f"BitFlip targets unknown program {inj.task!r}; "
                    f"available: {sorted(names)}")
            if (inj.at_step is None) == (inj.at_vtime is None):
                raise ValueError(
                    f"BitFlip on {inj.task!r} needs exactly one of "
                    f"at_step= or at_vtime=")
            if inj.bit < 0:
                raise ValueError(f"BitFlip bit must be >= 0, "
                                 f"got {inj.bit}")
            out.setdefault(inj.task, []).append(inj)
        return out

    def _install_clock_skews(self, ep_host: Dict[str, int]) -> None:
        """Validate ClockSkew injections and install one ingress hook
        per injection on every hub: messages delivered to an endpoint
        on the skewed host arrive offset + drift later.  Non-negative
        offset/drift is a *build-time* requirement — a negative skew
        would let a message undercut the link lookahead and unsound
        the conservative cross-host windows."""
        n_hosts = self.topology.n_hosts
        for inj in self.scenario.injections:
            if not isinstance(inj, ClockSkew):
                continue
            if not 0 <= inj.host < n_hosts:
                raise ValueError(
                    f"ClockSkew host {inj.host} outside "
                    f"0..{n_hosts - 1}")
            if inj.offset_ns < 0 or inj.drift_ppm < 0:
                raise ValueError(
                    f"ClockSkew may only delay (conservative "
                    f"lookahead): offset_ns={inj.offset_ns}, "
                    f"drift_ppm={inj.drift_ppm}")

            def hook(msg, _state, inj=inj):
                if ep_host.get(msg.dst) != inj.host:
                    return 0
                return inj.offset_ns + \
                    (inj.drift_ppm * msg.send_vtime) // 1_000_000

            for hub in self.hubs.values():
                hub.add_ingress_hook(hook)

    # -- build ---------------------------------------------------------------
    def build(self) -> "Simulation":
        if self._built:
            return self
        # run-scoped workload state (progress arrays, timelines, replay
        # cursors) is cleared before anything is wired, so a Workload
        # instance reused across simulations starts every run fresh —
        # identically in all engines and every forked dist replica
        for wl in self.workloads:
            wl.reset()
        topo = self.topology
        programs = self._programs()
        fabrics = self._fabrics()
        names = [p.name for _, p in programs]
        self.placement = self._resolve_placement(names)

        # §3.3 cells: resolve Interference targets early (their hosts
        # feed auto-cell derivation and per-host manager construction),
        # validate every Program.cell / Interference.cell reference
        # against the Topology declarations, and build one CellManager
        # per host that hosts cell-bound components — before the engine
        # exists, so every engine (and every forked dist replica) gets
        # identical per-host cell state.
        inter_targets = self._resolve_interference()
        cell_of, load_cells = self._resolve_cells(programs,
                                                  inter_targets)

        # membership: merged Topology.join + JoinHost map (host 0 and
        # 1-host topologies can never join late, so `single` implies
        # an empty map — the validation above guarantees it)
        self.joins = self._resolve_joins()

        # engine + hubs
        single = self.mode == "single"
        fabric_eps: Dict[str, List[str]] = {f.name: [] for f in fabrics}
        if single:
            self.scheduler = Scheduler(n_cpus=topo.n_cpus,
                                       cells=self.cell_managers.get(0))
            for fab in fabrics:
                self.hubs[fab.name] = Hub(fab.name, fab.link)

            def hub_for(fabric: str, host: int) -> Hub:
                return self.hubs[fabric]
        else:
            self.orchestrator = Orchestrator(
                n_hosts=topo.n_hosts, n_cpus=topo.n_cpus,
                dcn_link=topo.default_host_link, mode=self.mode,
                cells=self.cell_managers or None,
                joins=self.joins or None)
            for (a, b), link in topo.host_links.items():
                self.orchestrator.connect_hosts(a, b, link)
            host_hubs: Dict[int, Hub] = {}
            if fabrics:
                host_fab = fabrics[0]
                for h in range(topo.n_hosts):
                    hub = Hub(f"{host_fab.name}{h}", host_fab.link)
                    host_hubs[h] = self.orchestrator.add_hub(h, hub)
                    self.hubs[hub.name] = hub

            def hub_for(fabric: str, host: int) -> Hub:
                if fabric not in fabric_eps:
                    raise KeyError(f"unknown fabric {fabric!r}")
                return host_hubs[host]

        # scenario: per-task fault plan (see _resolve_fault_plan)
        scale, fails = self._resolve_fault_plan(names)
        bitflips = self._resolve_bitflips(names)

        # membership churn half of FailHost: the kills themselves go
        # through the fault wrappers resolved above; here the leave is
        # logged on the membership timeline.  Deliberately no lookahead
        # rebuild — a dead host goes quiescent, and quiescent hosts
        # already stop gating peers — so window schedules (and pinned
        # golden sync_rounds) are unchanged.
        for inj in self.scenario.injections:
            if isinstance(inj, FailHost):
                if self.orchestrator is not None:
                    self.orchestrator.retire_host(inj.host, inj.at_vtime)
                else:
                    self._membership_events.append(
                        {"event": "leave", "host": inj.host,
                         "vtime": inj.at_vtime})

        # workload interception (Program.on_fail): a program may observe
        # its resolved failure at build time — "kill" keeps the normal
        # early-close wrapper, "survive" suppresses it (the workload
        # models the reaction itself, e.g. a live driver's recovery)
        for wl, prog in programs:
            if prog.on_fail is not None and prog.name in fails:
                verdict = prog.on_fail(fails[prog.name])
                if verdict == "survive":
                    del fails[prog.name]
                elif verdict != "kill":
                    raise ValueError(
                        f"program {prog.name!r}: on_fail returned "
                        f"{verdict!r} (expected 'kill' or 'survive')")

        # spawn, in declaration order (determinism: vtask ids, scope and
        # task-list order all follow this loop)
        ep_host: Dict[str, int] = {}
        for wl, prog in programs:
            host = self.placement[prog.name]
            eps: Dict[str, Endpoint] = {}
            for es in prog.endpoints:
                if es.name in self.endpoints:
                    raise ValueError(f"duplicate endpoint {es.name!r}")
                ep = hub_for(es.fabric, host).attach(Endpoint(es.name))
                eps[es.name] = ep
                self.endpoints[es.name] = ep
                ep_host[es.name] = host
                fabric_eps[es.fabric].append(es.name)
            body = prog.make_body(eps)
            handles: List[TaskHandle] = []
            # innermost: data corruption (the flip happens before a
            # straggler scale or a fail gate sees the action stream)
            for bf in bitflips.get(prog.name, ()):
                bf_handle = TaskHandle()
                handles.append(bf_handle)
                body = bitflip_body(body, bf_handle, bf.at_step,
                                    bf.at_vtime, bf.bit)
            if prog.name in scale:
                body = scaled_body(body, scale[prog.name])
            if prog.name in fails:
                f = fails[prog.name]
                handle = TaskHandle()
                handles.append(handle)
                body = fail_gated_body(body, handle, f.at_compute,
                                       f.at_vtime)
            task = VTask(prog.name, body, kind=prog.kind)
            for h in handles:
                h.task = task
            if prog.handle is not None:
                prog.handle.task = task
            sched = self._sched_for(host)
            join_at = self.joins.get(host)
            if join_at is not None:
                # a joiner's programs start at its join vtime: the
                # host's earliest possible action is >= join_at, which
                # is what makes the membership epoch's add-only
                # lookahead attach conservative (Orchestrator.add_host)
                task.vtime = join_at
            sched.spawn(task)
            if prog.name in cell_of:
                # assign (not just a VTask backref): registers the task
                # in the host manager's live-cell multiset
                sched.cells.assign(task, cell_of[prog.name])
            self.tasks.append(task)
            self.task_by_name[prog.name] = task

        # non-host fabrics on shared host hubs: per-endpoint-pair link
        # overrides (skipped when the link equals the host fabric's —
        # indistinguishable)
        if not single and fabrics:
            host_link = fabrics[0].link
            for fab in fabrics[1:]:
                if fab.link == host_link:
                    continue
                members = fabric_eps[fab.name]
                for i, a in enumerate(members):
                    for b in members[i + 1:]:
                        for h in {ep_host[a], ep_host[b]}:
                            host_hubs[h].connect(a, b, fab.link)

        # scopes
        names_by_wl: Dict[int, List[str]] = {}
        for wl, prog in programs:
            names_by_wl.setdefault(id(wl), []).append(prog.name)
        for wl in self.workloads:
            wl_names = names_by_wl.get(id(wl), [])
            for ss in wl.scopes():
                members = [self.task_by_name[m]
                           for m in (ss.members or tuple(wl_names))]
                if single:
                    s = Scope(ss.name, ss.skew_bound_ns)
                    for t in members:
                        t.join(s)
                    self.scopes.append(s)
                else:
                    self.scopes.extend(self.orchestrator.global_scope(
                        ss.name, members, skew_bound_ns=ss.skew_bound_ns))

        # link degradation hooks + interference loads (targets resolved
        # and validated before the engine was built; spawn order — and
        # therefore vtask ids — matches the old interleaved loop)
        for inj in self.scenario.injections:
            if isinstance(inj, DegradeLink):
                self._install_degrade(inj, fabrics, fabric_eps, ep_host)
        self._install_clock_skews(ep_host)
        for i, (inj, host) in enumerate(inter_targets):
            load = VTask(f"load{i}",
                         _load_body(inj.bursts, inj.burst_ns),
                         kind="modeled")
            sched = self._sched_for(host)
            join_at = self.joins.get(host)
            if join_at is not None:
                load.vtime = join_at     # loads wait for the join too
            sched.spawn(load)
            if load_cells[i]:
                sched.cells.assign(load, load_cells[i])

        if self.cpu_resource:
            for sched in self._scheds():
                sched.cpu_resource = True

        self._built = True
        return self

    def _scheds(self) -> List[Scheduler]:
        if self.scheduler is not None:
            return [self.scheduler]
        return [self.orchestrator.hosts[h]
                for h in sorted(self.orchestrator.hosts)]

    def _sched_for(self, host: int) -> Scheduler:
        if self.scheduler is not None:
            return self.scheduler
        return self.orchestrator.host(host)

    def _install_degrade(self, inj: DegradeLink,
                         fabrics: List[FabricSpec],
                         fabric_eps: Dict[str, List[str]],
                         ep_host: Dict[str, int]) -> None:
        if (inj.fabric is None) == (inj.hosts is None):
            raise ValueError("DegradeLink needs exactly one of "
                             "fabric= or hosts=")
        if inj.fabric is not None:
            fab = next((f for f in fabrics if f.name == inj.fabric), None)
            if fab is None:
                raise ValueError(f"unknown fabric {inj.fabric!r}")
            members = set(fabric_eps[inj.fabric])
            extra = inj.extra_ns + int(
                (inj.latency_factor - 1.0) * fab.link.latency_ns)

            def match(msg: Message) -> bool:
                return msg.src in members and msg.dst in members
        else:
            a, b = inj.hosts
            n_hosts = self.topology.n_hosts
            bad = [h for h in (a, b) if not 0 <= h < n_hosts]
            if bad:
                # a pair outside the topology used to silently no-op
                # (the match predicate never fired); through the facade
                # that masks misconfiguration, so it is a build error
                raise ValueError(
                    f"DegradeLink hosts {inj.hosts} outside "
                    f"0..{n_hosts - 1}")
            pair_link = self.topology.host_links.get(
                (min(a, b), max(a, b)), self.topology.default_host_link)
            extra = inj.extra_ns + int(
                (inj.latency_factor - 1.0) * pair_link.latency_ns)

            def match(msg: Message) -> bool:
                return {ep_host.get(msg.src), ep_host.get(msg.dst)} \
                    == {a, b}
        if extra < 0:
            raise ValueError("DegradeLink may only add latency "
                             "(conservative lookahead)")

        for hub in self.hubs.values():
            def hook(msg, _state, hub=hub):
                # sender-side only: a forwarded cross-host message runs
                # the destination hub's hooks too — charge it once
                if msg.src not in hub.endpoints:
                    return 0
                if msg.send_vtime < inj.from_vtime or not match(msg):
                    return 0
                return extra
            hub.add_hook(hook)

    # -- run -----------------------------------------------------------------
    def run(self, *, engine: Optional[str] = None, n_workers: int = 2,
            on_deadlock: str = "report",
            max_rounds: Optional[int] = None,
            worker_timeout: float = 120.0,
            tick_ns: Optional[int] = None,
            pallas: str = "auto",
            verify: bool = False) -> SimReport:
        """Execute and return a SimReport.

        ``engine`` overrides the construction-time ``mode``:
        ``"single"``/``"async"``/``"barrier"`` pick an in-process
        engine; ``engine="dist"`` shards the topology's hosts across
        ``n_workers`` real OS worker processes (`repro.dist`), merging
        per-worker reports — results are bit-identical to the
        in-process engines.  ``engine="vectorized"`` compiles the
        scenario to JAX arrays and runs the jitted round loop
        (`repro.sim.vectorized`): bit-identical on the exact tier
        (auto tick), within a declared tolerance under an explicit
        ``tick_ns``; inadmissible scenarios raise
        :class:`~repro.sim.vectorized.UnsupportedByEngine`.
        ``max_rounds`` bounds the engine's dispatch rounds / sync
        epochs; None keeps each engine's own (generous) default.
        ``worker_timeout`` (dist only) fails a hung worker fast instead
        of wedging the caller.  ``tick_ns``/``pallas``/``verify``
        (vectorized only): quantization tick override, kernel path
        ("auto"/"on"/"off"/"interpret"), and a cross-check of the
        batched hub fan-out against the round loop."""
        if on_deadlock not in ("report", "raise"):
            raise ValueError(f"on_deadlock must be 'report' or 'raise', "
                             f"got {on_deadlock!r}")
        if engine == "vectorized":
            from repro.sim.vectorized import run_vectorized_sim
            report = run_vectorized_sim(
                self, tick_ns=tick_ns, pallas=pallas,
                max_rounds=max_rounds, verify=verify)
            if report.status == "deadlock" and on_deadlock == "raise":
                raise DeadlockError(report.detail
                                    or "vectorized simulation wedged")
            return report
        if engine == "dist":
            from repro.dist import run_dist
            from repro.sim.live import check_dist_live
            check_dist_live(self.workloads)
            report = run_dist(
                self, n_workers=n_workers, timeout=worker_timeout,
                **({} if max_rounds is None
                   else {"max_rounds": max_rounds}))
            if report.status == "deadlock" and on_deadlock == "raise":
                raise DeadlockError(report.detail
                                    or "distributed simulation wedged")
            return report
        if engine is not None:
            if engine not in ("single", "async", "barrier"):
                raise ValueError(f"unknown engine {engine!r}")
            if engine == "single" and self.topology.n_hosts > 1:
                raise ValueError("engine='single' needs a 1-host "
                                 "topology")
            if self._built and engine != self.mode:
                raise ValueError(
                    f"already built with mode={self.mode!r}; "
                    f"cannot re-run as engine={engine!r}")
            self.mode = engine
        if not self._built:
            self.build()
        status, detail = "ok", ""
        detail_info: Dict[str, Any] = {}
        t0 = time.perf_counter()
        try:
            if self.scheduler is not None:
                if max_rounds is None:
                    self.scheduler.run()
                else:
                    self.scheduler.run(max_rounds=max_rounds)
            elif max_rounds is None:
                self.orchestrator.run()
            else:
                self.orchestrator.run(max_epochs=max_rounds)
        except DeadlockError as e:
            if on_deadlock == "raise":
                raise
            status, detail = "deadlock", str(e)
            detail_info = dict(getattr(e, "info", {}) or {})
        wall = time.perf_counter() - t0
        return self._report(status, detail, wall, detail_info)

    def _report(self, status: str, detail: str, wall: float,
                detail_info: Optional[Dict[str, Any]] = None
                ) -> SimReport:
        msgs = sum(h.stats["messages"] for h in self.hubs.values())
        byts = sum(h.stats["bytes"] for h in self.hubs.values())
        links = {f"{hub.name}->{peer}": dict(st)
                 for hub in self.hubs.values()
                 for peer, st in hub.peer_stats.items()}
        hosts = [HostReport.from_sched(s.host, s.stats)
                 for s in self._scheds()]
        if self.orchestrator is not None:
            ost = self.orchestrator.stats
            vtime = self.orchestrator.horizon()
            sync_rounds = ost["epochs"]
            proxy_syncs = ost["proxy_syncs"]
            cross = sum(st["messages"] for hub in self.hubs.values()
                        for st in hub.peer_stats.values())
            staleness = ost["max_proxy_staleness_ns"]
            window = ost["max_window_ns"]
        else:
            vtime = self.scheduler.horizon()
            sync_rounds = proxy_syncs = cross = staleness = window = 0
        cells = {}
        for s in self._scheds():
            snap = s.cells.snapshot()
            if snap is not None:
                cells[str(s.host)] = snap
        # control-plane timeline, mirroring the dist merge exactly
        # (DistCoordinator._merge): one section per control workload,
        # then the membership events — present whenever there was
        # churn, [] when a control workload ran without any
        control: Dict[str, Any] = {}
        for wl in self.workloads:
            fn = getattr(wl, "control_report", None)
            sec = fn() if fn is not None else None
            if sec is not None:
                control[wl.name] = sec
        if self.orchestrator is not None:
            membership = self.orchestrator.membership_timeline()
        else:
            membership = sorted(
                self._membership_events,
                key=lambda e: (e["vtime"], e["event"], e["host"]))
        if membership:
            control["membership"] = membership
        elif control:
            control["membership"] = []
        return SimReport(
            status=status, mode=self.mode, n_hosts=self.topology.n_hosts,
            vtime_ns=vtime, wall_s=wall, messages=msgs, bytes=byts,
            sync_rounds=sync_rounds, proxy_syncs=proxy_syncs,
            cross_host_msgs=cross, max_proxy_staleness_ns=staleness,
            max_window_ns=window, hosts=hosts, links=links,
            tasks={t.name: {"vtime": t.vtime, "state": t.state.value,
                            "host": t.host} for t in self.tasks},
            progress={wl.name: _jsonable(wl.progress())
                      for wl in self.workloads},
            scenario=self.scenario.name, detail=detail, cells=cells,
            live={wl.name: sec for wl in self.workloads
                  for sec in [wl.live_report()] if sec is not None},
            control=control, detail_info=dict(detail_info or {}))

    def sweep(self, axis: Sequence[Scenario], *,
              tick_ns: Optional[int] = None,
              max_rounds: Optional[int] = None):
        """Vectorized batched configuration sweep: run one simulation
        per :class:`Scenario` in ``axis`` as a single ``jax.vmap``
        dispatch over stacked compiled tapes (this Simulation's
        topology/workloads/placement are shared; only the scenario
        varies).  Variants must share scenario *structure* — the same
        tapes, messages and channels; injections may change compute
        scales, fail points and degrade extras.  Returns a
        :class:`~repro.sim.vectorized.SweepResult` whose per-variant
        reports are bit-identical to ``run(engine="vectorized")`` on
        each scenario alone (and, on the exact tier, to the reference
        engines)."""
        from repro.sim.vectorized import sweep_vectorized
        return sweep_vectorized(self, list(axis), tick_ns=tick_ns,
                                max_rounds=max_rounds)

    # -- conveniences --------------------------------------------------------
    def done(self) -> bool:
        return all(t.state == State.DONE for t in self.tasks)
