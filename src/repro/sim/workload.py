"""The Workload protocol: reusable vtask program factories.

A workload declares *what runs*, independent of where it runs and what
faults are injected:

* :meth:`Workload.fabrics` — the logical message fabrics it needs.
* :meth:`Workload.programs` — one :class:`Program` per vtask: a body
  factory plus the endpoints it owns (name + fabric).
* :meth:`Workload.traffic` — program-pair traffic weights, consumed by
  declarative placement (``Orchestrator.co_locate``).
* :meth:`Workload.scopes` — bounded-skew synchronization scopes.
* :meth:`Workload.progress` — named progress arrays surfaced in the
  :class:`~repro.sim.report.SimReport` (and the observable blast radius
  of fault injections).

Bodies never reference hosts, hubs, or schedulers — the
:class:`~repro.sim.simulation.Simulation` wires those, so the same
workload runs single-host, sharded across an orchestrated cluster, or
under any :class:`~repro.sim.scenario.Scenario` without modification.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Iterator, List, Optional, Tuple

from repro.core.ipc import Endpoint
from repro.sim.topology import FabricSpec


@dataclasses.dataclass(frozen=True)
class EndpointSpec:
    """An endpoint a program owns: attach ``name`` to fabric ``fabric``."""
    name: str
    fabric: str


#: A body factory: receives the program's own endpoints (name -> Endpoint)
#: and returns the vtask generator.
BodyFactory = Callable[[Dict[str, Endpoint]], Iterator]


@dataclasses.dataclass
class Program:
    """One vtask, declaratively: name, body factory, owned endpoints.

    ``on_fail`` lets a workload intercept the fault plan: when the
    scenario resolves a failure for this program (an explicit
    ``FailTask`` or a ``FailHost`` expansion), the facade calls
    ``on_fail(failspec)`` at build time instead of blindly wrapping the
    body.  Return ``"kill"`` to keep the normal early-close wrapper
    (the workload just observed the death — e.g. a live trainer noting
    which shard host dies and when), or ``"survive"`` to suppress it
    (the program reacts to the failure itself, like a live driver
    running detection + checkpoint recovery).

    ``handle``: a :class:`~repro.sim.scenario.TaskHandle` the facade
    fills with the spawned VTask, so bodies that need their own vtime
    (live drivers making vtime-gated decisions) can read it.
    """
    name: str
    make_body: BodyFactory
    endpoints: Tuple[EndpointSpec, ...] = ()
    kind: str = "modeled"            # "modeled" | "live"
    cell: Optional[str] = None
    on_fail: Optional[Callable[[Any], str]] = None
    handle: Optional[Any] = None


#: -- vectorized-engine op descriptors ------------------------------------
#: A workload that can be compiled by the vectorized engine lowers each
#: *modeled* program body to a flat op list (`Workload.vec_ops`).  The
#: descriptors mirror the generator actions one-for-one: the vectorized
#: compiler (``repro.sim.vectorized``) proves the lowering admissible
#: (single-producer channels, no live calls, ...) and raises
#: ``UnsupportedByEngine`` otherwise — a workload returning ``None``
#: simply opts out.


@dataclasses.dataclass(frozen=True)
class VecCompute:
    """Modeled compute: advance the task's vtime by ``ns``."""
    ns: int


@dataclasses.dataclass(frozen=True)
class VecSend:
    """Send ``size_bytes`` from owned endpoint ``endpoint`` to ``dst``."""
    endpoint: str
    dst: str
    size_bytes: int


@dataclasses.dataclass(frozen=True)
class VecRecv:
    """Blocking receive on owned endpoint ``endpoint`` (payload unused —
    payload-dependent control flow is not lowerable)."""
    endpoint: str


@dataclasses.dataclass(frozen=True)
class VecMark:
    """Progress side effect: ``progress()[array][index] = value``, placed
    exactly where the generator body performs the assignment (so fault
    injections truncate progress identically in every engine)."""
    array: str
    index: int
    value: int


@dataclasses.dataclass(frozen=True)
class ScopeSpec:
    """A bounded-skew scope over ``members`` (None = every program of the
    declaring workload).  Spanning hosts it becomes a global scope with
    proxy vtasks; on one host, a plain :class:`~repro.core.scope.Scope`."""
    name: str
    skew_bound_ns: int
    members: Optional[Tuple[str, ...]] = None


class Workload:
    """Base class; subclasses override :meth:`programs` at minimum."""

    name: str = "workload"

    def fabrics(self) -> List[FabricSpec]:
        return []

    def programs(self) -> List[Program]:
        raise NotImplementedError

    def traffic(self) -> Dict[Tuple[str, str], float]:
        return {}

    def scopes(self) -> List[ScopeSpec]:
        return []

    def progress(self) -> Dict[str, Any]:
        return {}

    def reset(self) -> None:
        """Clear run-scoped state (progress arrays, timelines, replay
        cursors).  Workloads allocate their progress buffers in
        ``__init__``, so without a reset a Workload instance reused
        across two ``Simulation.run()`` calls carries the first run's
        progress into the second's report (and a stale parent array
        double-counts in the dist engine's max-merge).
        ``Simulation.build()`` and the dist coordinator call this once
        per run, before anything executes; the default is a no-op for
        stateless workloads."""
        return None

    def vec_ops(self) -> Optional[Dict[str, List[Any]]]:
        """Program name -> flat op list (:class:`VecCompute` /
        :class:`VecSend` / :class:`VecRecv` / :class:`VecMark`),
        action-for-action identical to the generator bodies.  ``None``
        (the default) means the workload has no vectorized lowering and
        ``Simulation.run(engine="vectorized")`` raises
        ``UnsupportedByEngine``."""
        return None

    # -- live-execution hooks (repro.sim.live) -------------------------------
    def live_mode(self) -> Optional[str]:
        """``"record"``/``"replay"`` for live workloads (the ledger
        mode), ``None`` for modeled ones.  The facade uses it to reject
        record mode under the dist engine (forked workers measuring wall
        time cannot produce one coherent trace)."""
        return None

    def live_fns(self) -> Dict[str, Any]:
        """Program name -> the real callable it wraps.  The dist engine
        pickles nothing (workers are forked), but a live fn that cannot
        be pickled is a reliable proxy for fork-unsafe captured state
        (JAX handles, locks, open files), so ``engine="dist"`` checks
        these at the facade and raises a clear error naming the fn."""
        return {}

    def live_report(self, tasks: Optional[set] = None
                    ) -> Optional[Dict[str, Any]]:
        """Post-run live section for :attr:`SimReport.live` (``None``
        for modeled workloads).  ``tasks`` restricts per-task entries to
        a subset — dist workers pass the task names they own, so the
        coordinator can merge disjoint worker sections."""
        return None
