"""Fault-campaign harness: swept fault grids, outcome classification,
and delta-minimized reproducers (ROADMAP item 4).

A :class:`Campaign` takes a *base* simulation factory (``make_sim:
Scenario -> Simulation`` — a fresh simulation per call, since built
simulations are single-shot) and a :class:`FaultGrid` — axes over
injection **type** x **target** x **vtime** x **count** plus per-type
knobs.  Every grid point becomes one Scenario, every point runs
deterministically (vectorized ``sweep`` fast path where the compiled
surface allows, per-point async fallback otherwise; execution order is
a seeded permutation but results are keyed by grid index, so reports
are order-independent), and every outcome is classified against a
fault-free baseline:

* ``crash``               — the engine raised (hub routing on a
                            corrupted payload, a dead dist worker, …);
                            the traceback is captured in the report and
                            the sweep *continues*.
* ``invariant-violation`` — a task went FAULTY (progress preemption) or
                            a link's visibility slack went negative, or
                            a user invariant hook returned violations.
* ``deadlock``            — ``SimReport.status == "deadlock"``.
* ``divergence``          — the run completed but its *functional
                            fingerprint* (task states/hosts, progress
                            arrays, message/byte totals) differs from
                            the baseline: the fault changed what
                            happened, not just when.
* ``ok``                  — masked or timing-only fault.

Every failing point is **delta-minimized** to a smallest reproducer:
greedy injection dropping to a fixpoint, then binary-shrinking integer
fields (vtimes, steps, offsets, extras) toward 0 and targets toward the
front of the target axis — clkscrew's parameter-grid search harness
applied to vtime/placement/fault axes.  The result is a replayable
``fault_repro/v1`` JSON spec whose serialization is byte-identical
across runs *and across campaign engines* (minimization trials always
run on the in-process reference engine; classification uses only
engine-independent report fields).

CLI::

    python -m repro.sim.campaign list
    python -m repro.sim.campaign run --base rack_ring@v1 --json out.json
    python -m repro.sim.campaign minimize --base serve_smoke@v1 --point 3
    python -m repro.sim.campaign smoke          # the CI gate
"""
from __future__ import annotations

import dataclasses
import itertools
import json
import time
import traceback as _traceback
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

from repro.sim.report import SimReport, _jsonable
from repro.sim.scenario import (BitFlip, ClockSkew, DegradeLink,
                                FailHost, FailTask, Injection,
                                Interference, JoinHost, Scenario,
                                Straggler)
from repro.sim.simulation import Simulation

OUTCOMES = ("ok", "deadlock", "invariant-violation", "crash",
            "divergence")

REPRO_SCHEMA = "fault_repro/v1"
REPORT_SCHEMA = "campaign_report/v1"

#: engine used for baseline, fallback points, and every minimization
#: trial: in-process, works for any host count, and classification
#: reads only engine-independent fields — so reproducer specs come out
#: byte-identical no matter which engine the campaign itself ran on
REF_ENGINE = "async"


def _ref_run(sim: Simulation) -> SimReport:
    """Run on the in-process reference engine.  Single-host sims stay
    on their constructed mode (the plain scheduler — bit-identical to
    async on every field classification reads)."""
    if sim.topology.n_hosts == 1:
        return sim.run()
    return sim.run(engine=REF_ENGINE)


# ---------------------------------------------------------------------------
# injection <-> JSON (the reproducer spec's vocabulary)
# ---------------------------------------------------------------------------

_INJECTION_TYPES: Dict[str, type] = {
    "Straggler": Straggler, "FailTask": FailTask, "FailHost": FailHost,
    "DegradeLink": DegradeLink, "Interference": Interference,
    "BitFlip": BitFlip, "ClockSkew": ClockSkew, "JoinHost": JoinHost,
}


def injection_to_dict(inj: Injection) -> dict:
    """Type-tagged, None-stripped, JSON-able encoding of one
    injection (tuples become lists; ``injection_from_dict`` restores
    them)."""
    d = {k: _jsonable(v)
         for k, v in dataclasses.asdict(inj).items() if v is not None}
    d["type"] = type(inj).__name__
    return d


def injection_from_dict(d: dict) -> Injection:
    kind = d.get("type")
    cls = _INJECTION_TYPES.get(kind)
    if cls is None:
        raise ValueError(f"unknown injection type {kind!r}; expected "
                         f"one of {sorted(_INJECTION_TYPES)}")
    kw = {k: v for k, v in d.items() if k != "type"}
    if cls is DegradeLink and kw.get("hosts") is not None:
        kw["hosts"] = tuple(kw["hosts"])
    return cls(**kw)


# ---------------------------------------------------------------------------
# the grid
# ---------------------------------------------------------------------------

#: injection builders per grid type.  Signature:
#: (target, vtime, knobs, host_of) -> Injection.  ``host_of`` coerces a
#: task-name target to its placed host for host-typed injections.
#: Extend the campaign vocabulary by registering here.
BUILDERS: Dict[str, Callable[..., Injection]] = {}


def _builder(name):
    def deco(fn):
        BUILDERS[name] = fn
        return fn
    return deco


@_builder("straggler")
def _b_straggler(target, vtime, knobs, host_of):
    # timing-only: the vtime axis has no trigger here (a straggler is
    # active for the whole run)
    return Straggler(str(target), float(knobs.get("slowdown", 3.0)))


@_builder("fail_task")
def _b_fail_task(target, vtime, knobs, host_of):
    return FailTask(str(target), at_vtime=int(vtime))


@_builder("fail_host")
def _b_fail_host(target, vtime, knobs, host_of):
    return FailHost(host=host_of(target), at_vtime=int(vtime))


@_builder("degrade_link")
def _b_degrade_link(target, vtime, knobs, host_of):
    return DegradeLink(fabric=str(target),
                       extra_ns=int(knobs.get("extra_ns", 25_000)),
                       from_vtime=int(vtime))


@_builder("bitflip")
def _b_bitflip(target, vtime, knobs, host_of):
    return BitFlip(str(target), at_vtime=int(vtime),
                   bit=int(knobs.get("bit", 0)))


@_builder("join_host")
def _b_join_host(target, vtime, knobs, host_of):
    # membership churn: the vtime axis is the join time.  vtime 0 means
    # a founding member (not a late join), so clamp to >= 1 — the grid's
    # shared vtime axis routinely starts at 0.
    return JoinHost(host=host_of(target), at_vtime=max(1, int(vtime)))


@_builder("clock_skew")
def _b_clock_skew(target, vtime, knobs, host_of):
    # the vtime axis is the skew magnitude: a constant receive-side
    # offset on the target host
    return ClockSkew(host=host_of(target), offset_ns=int(vtime),
                     drift_ppm=int(knobs.get("drift_ppm", 0)))


@dataclasses.dataclass(frozen=True)
class GridPoint:
    """One materialized grid point: its stable index in axis-product
    order (reports and reproducers key on this, not on execution
    order), the axis values that produced it, and the Scenario."""
    index: int
    type: str
    target: Any
    vtime: int
    count: int
    scenario: Scenario


class FaultGrid:
    """The swept parameter space: ``types x targets x vtimes x counts``
    (+ per-type ``knobs``).  ``count=k`` expands a point into ``k``
    injections of the same type on ``k`` consecutive targets (wrapping
    around the target axis) — correlated faults, not independent
    singles."""

    def __init__(self, *, types: Sequence[str],
                 targets: Sequence[Any],
                 vtimes: Sequence[int],
                 counts: Sequence[int] = (1,),
                 knobs: Optional[Dict[str, Any]] = None):
        unknown = [t for t in types if t not in BUILDERS]
        if unknown:
            raise ValueError(f"unknown fault types {unknown}; "
                             f"registered: {sorted(BUILDERS)}")
        if not types or not targets or not vtimes or not counts:
            raise ValueError("every grid axis needs at least one value")
        bad = [c for c in counts if not 1 <= c <= len(targets)]
        if bad:
            raise ValueError(f"counts {bad} outside 1..{len(targets)} "
                             f"(the target axis length)")
        self.types = list(types)
        self.targets = list(targets)
        self.vtimes = [int(v) for v in vtimes]
        self.counts = [int(c) for c in counts]
        self.knobs = dict(knobs or {})

    @property
    def shape(self) -> Tuple[int, int, int, int]:
        return (len(self.types), len(self.targets), len(self.vtimes),
                len(self.counts))

    @property
    def n_points(self) -> int:
        n = 1
        for d in self.shape:
            n *= d
        return n

    def to_dict(self) -> dict:
        return {"types": list(self.types),
                "targets": [_jsonable(t) for t in self.targets],
                "vtimes": list(self.vtimes),
                "counts": list(self.counts),
                "knobs": _jsonable(self.knobs),
                "shape": list(self.shape),
                "n_points": self.n_points}

    def points(self, host_of: Callable[[Any], int]) -> List[GridPoint]:
        """Materialize every point in axis-product order (stable
        indices)."""
        out = []
        for idx, (ftype, t_i, vtime, count) in enumerate(
                itertools.product(self.types,
                                  range(len(self.targets)),
                                  self.vtimes, self.counts)):
            build = BUILDERS[ftype]
            injs = tuple(
                build(self.targets[(t_i + k) % len(self.targets)],
                      vtime, self.knobs, host_of)
                for k in range(count))
            target = self.targets[t_i]
            name = (f"campaign:{idx}:{ftype}:{target}"
                    f"@{vtime}x{count}")
            out.append(GridPoint(index=idx, type=ftype, target=target,
                                 vtime=vtime, count=count,
                                 scenario=Scenario(name, injs)))
        return out


# ---------------------------------------------------------------------------
# classification
# ---------------------------------------------------------------------------


def functional_fingerprint(report: SimReport) -> dict:
    """The schedule- and engine-independent subset used for the
    divergence check: what happened, not when or how fast.  Every field
    here is in the cross-engine harness's CORE_FIELDS bar, so the same
    point classifies identically under async, dist, or the vectorized
    exact tier."""
    return {"status": report.status,
            "tasks": {n: {"state": t["state"], "host": t["host"]}
                      for n, t in report.tasks.items()},
            "progress": _jsonable(report.progress),
            "messages": report.messages,
            "bytes": report.bytes}


def default_invariants(report: SimReport) -> List[str]:
    """Built-in invariant checks: FAULTY tasks (progress preemption)
    and negative per-link visibility slack (a conservative-lookahead
    breach — by construction impossible unless an engine bug)."""
    out = []
    for name, t in sorted(report.tasks.items()):
        if t["state"] == "faulty":
            out.append(f"task {name} went faulty")
    for link, st in sorted(report.links.items()):
        slack = st.get("min_slack_ns")
        if slack is not None and slack < 0:
            out.append(f"link {link} min_slack_ns={slack} < 0")
    return out


def classify(report: SimReport, baseline: dict,
             invariants: Optional[Callable[[SimReport], List[str]]]
             = None) -> Tuple[str, str]:
    """(outcome, detail) for a completed run.  Severity ladder:
    invariant-violation > deadlock > divergence > ok (crash never
    reaches here — the run raised instead of returning a report)."""
    violations = default_invariants(report)
    if invariants is not None:
        violations += [str(v) for v in invariants(report)]
    if violations:
        return "invariant-violation", "; ".join(violations)
    if report.status == "deadlock":
        return "deadlock", report.detail
    fp = functional_fingerprint(report)
    if fp != baseline:
        diffs = [k for k in fp if fp[k] != baseline[k]]
        return "divergence", f"fingerprint differs on {diffs}"
    return "ok", ""


# ---------------------------------------------------------------------------
# the campaign
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class CampaignReport:
    """JSON-able campaign result: grid shape, per-point outcomes (grid
    order), outcome histogram, minimized reproducer specs, and
    throughput.  Everything except ``wall_s``/``points_per_s`` is
    deterministic for a fixed (base, grid, seed)."""
    base: str
    seed: int
    engine: str
    grid: dict
    baseline: dict
    points: List[dict]
    histogram: Dict[str, int]
    reproducers: List[dict]
    wall_s: float
    points_per_s: float
    fast_path: str
    schema: str = REPORT_SCHEMA

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    def to_json(self, indent: int = 1) -> str:
        return json.dumps(self.to_dict(), indent=indent, sort_keys=True)


class Campaign:
    """Sweep ``grid`` over ``make_sim`` and classify every point.

    ``make_sim(scenario)`` must return a *fresh, unbuilt* Simulation
    wired with the given scenario.  ``engine``:

    * ``"auto"`` — vectorized ``sweep`` in one vmap batch for the
      admissible points, per-point async for the rest (BitFlip /
      ClockSkew grids, inadmissible surfaces).
    * ``"async"`` / ``"barrier"`` / ``"single"`` — per-point in-process.
    * ``"dist"`` — per-point over ``n_workers`` OS workers; a
      :class:`~repro.dist.coordinator.DistWorkerError` on one point
      classifies that point as ``crash`` (worker traceback captured)
      and the campaign continues.

    ``invariants`` is an optional hook ``SimReport -> [violation
    strings]`` merged with the default checks."""

    def __init__(self, make_sim: Callable[[Scenario], Simulation],
                 grid: FaultGrid, *, seed: int = 0,
                 engine: str = "auto", n_workers: int = 2,
                 invariants: Optional[Callable] = None,
                 base_name: str = "custom",
                 worker_timeout: float = 60.0,
                 max_trials: int = 400):
        if engine not in ("auto", "single", "async", "barrier", "dist"):
            raise ValueError(f"unknown campaign engine {engine!r}")
        self.make_sim = make_sim
        self.grid = grid
        self.seed = int(seed)
        self.engine = engine
        self.n_workers = n_workers
        self.invariants = invariants
        self.base_name = base_name
        self.worker_timeout = worker_timeout
        self.max_trials = max_trials
        # resolved lazily by _prepare()
        self._baseline_report: Optional[SimReport] = None
        self._baseline_fp: Optional[dict] = None
        self._placement: Dict[str, int] = {}
        self._n_hosts: int = 0
        self._points: Optional[List[GridPoint]] = None

    # -- setup ---------------------------------------------------------------
    def _prepare(self) -> None:
        if self._points is not None:
            return
        base = self.make_sim(Scenario("baseline"))
        report = self._run_ref(base)
        if report.status != "ok":
            raise ValueError(
                f"campaign baseline must run clean, got "
                f"{report.status!r}: {report.detail}")
        self._baseline_report = report
        self._baseline_fp = functional_fingerprint(report)
        self._n_hosts = base.topology.n_hosts
        self._placement = dict(base.placement)
        self._points = self.grid.points(self._host_of)

    def _host_of(self, target: Any) -> int:
        """Coerce a target-axis value to a host id: ints pass through
        (range-checked at build time by the injection itself), task
        names resolve via the baseline placement."""
        if isinstance(target, bool):
            raise ValueError(f"bad host target {target!r}")
        if isinstance(target, int):
            return target
        if target in self._placement:
            return self._placement[target]
        raise ValueError(
            f"target {target!r} is neither a host id nor a placed "
            f"program; placed: {sorted(self._placement)}")

    def _run_ref(self, sim: Simulation) -> SimReport:
        return _ref_run(sim)

    # -- point execution -----------------------------------------------------
    def _run_point(self, scenario: Scenario) -> Tuple[str, str, str]:
        """(outcome, detail, traceback) for one grid point on the
        campaign engine.  Every exception — a corrupted payload blowing
        up hub routing in-process, a dist worker dying mid-point — is a
        ``crash`` classification, never a campaign abort."""
        try:
            sim = self.make_sim(scenario)
            if self.engine == "dist":
                report = sim.run(engine="dist",
                                 n_workers=self.n_workers,
                                 worker_timeout=self.worker_timeout)
            elif self.engine == "auto":
                report = self._run_ref(sim)
            else:
                report = sim.run(engine=self.engine)
        except Exception as e:              # noqa: BLE001 - classified
            tb = getattr(e, "worker_traceback", "") \
                or _traceback.format_exc()
            return "crash", f"{type(e).__name__}: {e}", tb
        outcome, detail = classify(report, self._baseline_fp,
                                   self.invariants)
        return outcome, detail, ""

    def _sweepable(self, scenario: Scenario) -> bool:
        # JoinHost rides the same fallback path as the data/ingress
        # injections: membership epochs are conservative-engine
        # machinery, so the sweep compiler refuses them at build
        return not any(isinstance(inj, (BitFlip, ClockSkew, JoinHost))
                       for inj in scenario.injections)

    def _try_sweep(self, points: List[GridPoint]
                   ) -> Optional[Dict[int, Tuple[str, str, str]]]:
        """Vectorized fast path: one vmap batch over every admissible
        point.  Returns None when the surface refuses (fall back to
        per-point runs); per-lane results are exact-tier bit-identical
        to the reference engines, so classification matches."""
        from repro.sim.vectorized import UnsupportedByEngine
        try:
            base = self.make_sim(Scenario("sweep-base"))
            res = base.sweep([p.scenario for p in points])
            if res.tier != "exact":
                return None
        except (UnsupportedByEngine, ValueError, RuntimeError):
            return None
        out = {}
        for p, rep in zip(points, res.reports):
            outcome, detail = classify(rep, self._baseline_fp,
                                       self.invariants)
            out[p.index] = (outcome, detail, "")
        return out

    # -- run -----------------------------------------------------------------
    def run(self, *, minimize: bool = True,
            minimize_outcomes: Sequence[str] = (
                "crash", "invariant-violation", "deadlock",
                "divergence")) -> CampaignReport:
        import numpy as np

        self._prepare()
        points = self._points
        t0 = time.perf_counter()
        results: Dict[int, Tuple[str, str, str]] = {}
        fast_path = "per-point"

        order = np.random.default_rng(self.seed).permutation(
            len(points))
        if self.engine == "auto":
            sweepable = [p for p in points
                         if self._sweepable(p.scenario)]
            if sweepable:
                swept = self._try_sweep(sweepable)
                if swept is not None:
                    results.update(swept)
                    fast_path = ("sweep" if len(swept) == len(points)
                                 else "mixed")
        for i in order:
            p = points[int(i)]
            if p.index in results:
                continue
            results[p.index] = self._run_point(p.scenario)

        histogram = {o: 0 for o in OUTCOMES}
        point_rows = []
        for p in points:
            outcome, detail, tb = results[p.index]
            histogram[outcome] += 1
            row = {"index": p.index, "scenario": p.scenario.name,
                   "type": p.type, "target": _jsonable(p.target),
                   "vtime": p.vtime, "count": p.count,
                   "outcome": outcome, "detail": detail}
            if tb:
                row["traceback"] = tb
            point_rows.append(row)

        reproducers = []
        if minimize:
            for p in points:
                outcome = results[p.index][0]
                if outcome in minimize_outcomes and outcome != "ok":
                    reproducers.append(
                        self.minimize_point(p, outcome))
        wall = time.perf_counter() - t0
        return CampaignReport(
            base=self.base_name, seed=self.seed, engine=self.engine,
            grid=self.grid.to_dict(), baseline=self._baseline_fp,
            points=point_rows, histogram=histogram,
            reproducers=reproducers, wall_s=wall,
            points_per_s=(len(points) / wall if wall > 0
                          else float("inf")),
            fast_path=fast_path)

    # -- minimization --------------------------------------------------------
    def _outcome_of(self, injections: Sequence[Injection],
                    counter: List[int]) -> str:
        """One minimization trial, always on the reference engine (the
        spec must not depend on the campaign engine)."""
        if counter[0] >= self.max_trials:
            raise RuntimeError(
                f"minimization exceeded max_trials={self.max_trials}")
        counter[0] += 1
        try:
            sim = self.make_sim(Scenario("min-trial",
                                         tuple(injections)))
            report = self._run_ref(sim)
        except Exception:                   # noqa: BLE001 - classified
            return "crash"
        return classify(report, self._baseline_fp,
                        self.invariants)[0]

    def _shrink_int(self, injs: List[Injection], i: int, field: str,
                    target: str, counter: List[int],
                    floor: int = 0) -> None:
        """Binary-shrink one integer field toward ``floor`` while the
        outcome class is preserved (in place)."""
        cur = getattr(injs[i], field)
        if cur is None or not isinstance(cur, int) or cur <= floor:
            return
        lo, hi = floor, cur
        while lo < hi:
            mid = (lo + hi) // 2
            trial = list(injs)
            trial[i] = dataclasses.replace(injs[i], **{field: mid})
            if self._outcome_of(trial, counter) == target:
                hi = mid
            else:
                lo = mid + 1
        injs[i] = dataclasses.replace(injs[i], **{field: hi})

    def _with_target(self, inj: Injection, raw: Any) -> Injection:
        if isinstance(inj, (Straggler, FailTask, BitFlip)):
            return dataclasses.replace(inj, task=str(raw))
        if isinstance(inj, (FailHost, ClockSkew)):
            return dataclasses.replace(inj, host=self._host_of(raw))
        if isinstance(inj, DegradeLink) and inj.fabric is not None:
            return dataclasses.replace(inj, fabric=str(raw))
        return inj

    def _target_index(self, inj: Injection) -> Optional[int]:
        """Position of this injection's target on the grid's target
        axis (None when it is not on the axis — nothing to shrink)."""
        for j, t in enumerate(self.grid.targets):
            if self._with_target(inj, t) == inj:
                return j
        return None

    def minimize_point(self, point: GridPoint,
                       outcome: Optional[str] = None) -> dict:
        """Delta-minimize one failing grid point to a smallest
        reproducer preserving its outcome class: greedy injection drop
        to a fixpoint, then binary-shrink integer fields toward 0 and
        targets toward the front of the target axis.  Returns the
        ``fault_repro/v1`` spec (see :func:`spec_to_bytes` for the
        byte-stable serialization)."""
        self._prepare()
        if outcome is None:
            outcome = self._run_point(point.scenario)[0]
        if outcome == "ok":
            raise ValueError(
                f"point {point.index} ({point.scenario.name}) is not "
                f"failing; nothing to minimize")
        counter = [0]
        injs = list(point.scenario.injections)
        # confirm the target class reproduces on the reference engine
        # (engine-independent by construction; asserted for safety)
        ref = self._outcome_of(injs, counter)
        if ref != outcome:
            raise RuntimeError(
                f"point {point.index}: outcome {outcome!r} on the "
                f"campaign engine but {ref!r} on {REF_ENGINE} — "
                f"engine-dependent classification is a bug")
        # 1. greedy drop to a fixpoint
        changed = True
        while changed and len(injs) > 1:
            changed = False
            i = 0
            while i < len(injs) and len(injs) > 1:
                trial = injs[:i] + injs[i + 1:]
                if self._outcome_of(trial, counter) == outcome:
                    injs = trial
                    changed = True
                else:
                    i += 1
        # 2. binary-shrink integer fields
        shrink_fields = {
            Straggler: (), FailTask: ("at_vtime", "at_compute"),
            FailHost: ("at_vtime",),
            DegradeLink: ("extra_ns", "from_vtime"),
            BitFlip: ("at_vtime", "at_step", "bit"),
            ClockSkew: ("offset_ns", "drift_ppm"),
            Interference: ("bursts", "burst_ns"),
        }
        for i in range(len(injs)):
            for field in shrink_fields.get(type(injs[i]), ()):
                self._shrink_int(injs, i, field, outcome, counter)
        # 3. binary-shrink targets toward the front of the target axis
        for i in range(len(injs)):
            cur = self._target_index(injs[i])
            if cur is None or cur == 0:
                continue
            lo, hi = 0, cur
            while lo < hi:
                mid = (lo + hi) // 2
                trial = list(injs)
                trial[i] = self._with_target(
                    injs[i], self.grid.targets[mid])
                if self._outcome_of(trial, counter) == outcome:
                    hi = mid
                else:
                    lo = mid + 1
            injs[i] = self._with_target(injs[i],
                                        self.grid.targets[hi])
        return {
            "schema": REPRO_SCHEMA,
            "base": self.base_name,
            "outcome": outcome,
            "point": {"index": point.index, "type": point.type,
                      "target": _jsonable(point.target),
                      "vtime": point.vtime, "count": point.count},
            "injections": [injection_to_dict(inj) for inj in injs],
            "seed": self.seed,
            "trials": counter[0],
        }


def spec_to_bytes(spec: dict) -> bytes:
    """The byte-stable serialization the CI smoke compares: sorted
    keys, fixed indent, trailing newline."""
    return (json.dumps(spec, indent=1, sort_keys=True) + "\n").encode()


def spec_scenario(spec: dict) -> Scenario:
    if spec.get("schema") != REPRO_SCHEMA:
        raise ValueError(f"not a {REPRO_SCHEMA} spec: "
                         f"schema={spec.get('schema')!r}")
    injs = tuple(injection_from_dict(d) for d in spec["injections"])
    return Scenario(f"repro:{spec['base']}:{spec['point']['index']}",
                    injs)


def replay_spec(spec: dict,
                make_sim: Callable[[Scenario], Simulation], *,
                invariants: Optional[Callable] = None
                ) -> Tuple[str, str]:
    """Replay a reproducer spec standalone: run its injections against
    a fresh base, classify against a fresh fault-free baseline, and
    return (outcome, detail).  The outcome must equal
    ``spec["outcome"]`` — asserted by the CLI and tests."""
    fp = functional_fingerprint(_ref_run(make_sim(Scenario("baseline"))))
    try:
        report = _ref_run(make_sim(spec_scenario(spec)))
    except Exception as e:                  # noqa: BLE001 - classified
        return "crash", f"{type(e).__name__}: {e}"
    return classify(report, fp, invariants)


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------


def _campaign_for(base_ref: str, *, seed: int, engine: str,
                  n_workers: int) -> Campaign:
    from repro.sim import registry
    ent = registry.entry(base_ref)
    if ent.grid is None:
        raise SystemExit(
            f"{ent.ref} has no default fault grid; campaign bases: "
            f"{[e for e in registry.names() if registry.entry(e).grid]}")
    return Campaign(ent.make, ent.grid(), seed=seed, engine=engine,
                    n_workers=n_workers, base_name=ent.ref)


def _cmd_list() -> int:
    from repro.sim import registry
    rows = []
    for ref in registry.names():
        ent = registry.entry(ref)
        kind = "campaign-base" if ent.grid is not None else "scenario"
        rows.append(f"  {ref:24s} [{kind}] {ent.description}")
    print("registered scenarios (load with "
          "repro.sim.registry.load(ref)):")
    print("\n".join(rows))
    return 0


def _cmd_run(args) -> int:
    camp = _campaign_for(args.base, seed=args.seed, engine=args.engine,
                         n_workers=args.n_workers)
    report = camp.run(minimize=not args.no_minimize)
    if args.json:
        with open(args.json, "w") as f:
            f.write(report.to_json() + "\n")
    print(f"campaign {args.base}: {report.grid['n_points']} points "
          f"({report.fast_path}) in {report.wall_s:.2f}s "
          f"({report.points_per_s:.1f} pts/s)")
    print(f"  histogram: {report.histogram}")
    print(f"  reproducers: {len(report.reproducers)}")
    return 0


def _cmd_minimize(args) -> int:
    camp = _campaign_for(args.base, seed=args.seed, engine=args.engine,
                         n_workers=args.n_workers)
    camp._prepare()
    points = {p.index: p for p in camp._points}
    if args.point not in points:
        raise SystemExit(f"point {args.point} outside the grid "
                         f"(0..{len(points) - 1})")
    spec = camp.minimize_point(points[args.point])
    out = spec_to_bytes(spec).decode()
    if args.json:
        with open(args.json, "w") as f:
            f.write(out)
    print(out, end="")
    return 0


def _cmd_smoke() -> int:
    """The CI gate: a small seeded grid over the serve campaign base
    must (1) produce the pinned outcome histogram, (2) yield
    byte-identical reproducer specs across two independent runs, and
    (3) replay each reproducer standalone to its recorded outcome."""
    from repro.sim import registry
    ent = registry.entry("serve_smoke@v1")
    camp = Campaign(ent.make, ent.grid(), seed=0, base_name=ent.ref)
    report = camp.run()
    expect = {"ok": 4, "deadlock": 6, "invariant-violation": 0,
              "crash": 4, "divergence": 2}
    assert report.histogram == expect, (
        f"campaign smoke histogram drifted:\n got: {report.histogram}"
        f"\nwant: {expect}")
    assert report.reproducers, "no reproducers from a failing grid"
    rerun = Campaign(ent.make, ent.grid(), seed=0,
                     base_name=ent.ref).run()
    for a, b in zip(report.reproducers, rerun.reproducers):
        assert spec_to_bytes(a) == spec_to_bytes(b), (
            f"re-running minimization changed the reproducer spec:\n"
            f"{a}\nvs\n{b}")
    for spec in report.reproducers:
        outcome, detail = replay_spec(spec, ent.make)
        assert outcome == spec["outcome"], (
            f"reproducer replays to {outcome!r}, spec says "
            f"{spec['outcome']!r} ({detail})")
    print(f"campaign smoke ok: {report.grid['n_points']} points, "
          f"histogram {report.histogram}, "
          f"{len(report.reproducers)} reproducers byte-stable + "
          f"replayable ({report.points_per_s:.1f} pts/s)")
    return 0


def main(argv: Optional[List[str]] = None) -> int:
    import argparse

    ap = argparse.ArgumentParser(
        prog="python -m repro.sim.campaign",
        description="fault-campaign harness over registered scenario "
                    "bases")
    sub = ap.add_subparsers(dest="cmd", required=True)
    sub.add_parser("list", help="list registered scenarios and "
                               "campaign bases")
    for name in ("run", "minimize"):
        p = sub.add_parser(name)
        p.add_argument("--base", required=True,
                       help="registry ref, e.g. rack_ring@v1")
        p.add_argument("--seed", type=int, default=0)
        p.add_argument("--engine", default="auto",
                       choices=("auto", "single", "async", "barrier",
                                "dist"))
        p.add_argument("--n-workers", type=int, default=2)
        p.add_argument("--json", help="write the result to this path")
        if name == "run":
            p.add_argument("--no-minimize", action="store_true")
        else:
            p.add_argument("--point", type=int, required=True,
                           help="grid-point index to minimize")
    sub.add_parser("smoke", help="CI gate: pinned histogram + "
                                 "byte-identical minimization")
    args = ap.parse_args(argv)
    if args.cmd == "list":
        return _cmd_list()
    if args.cmd == "run":
        return _cmd_run(args)
    if args.cmd == "minimize":
        return _cmd_minimize(args)
    return _cmd_smoke()


if __name__ == "__main__":
    raise SystemExit(main())
