"""`repro.sim` — the declarative scenario API (single public facade).

One import gives everything needed to compose and run a simulation:

* :class:`Topology` — hosts, per-pair interconnect links, CPU budget,
  and §3.3 memory-hierarchy :class:`CellSpec` declarations
  (``Topology.cell`` / ``Topology.cell_config``) that programs bind to
  via ``Program.cell`` (validated at build, instantiated per host,
  reported as ``SimReport.cells``).
* :class:`Workload` — reusable vtask program factories (components +
  endpoints + fabrics + traffic + scopes).  Ports of the repo's
  workloads ship in :mod:`repro.sim.workloads`:
  :class:`ChipRingTraining`, :class:`RackRing`, :class:`ModeledServe`,
  and :class:`LiveServe` (the real serve stack under open-loop
  arrivals; see :func:`live_serve_sim` / :func:`record_live_serve` and
  the co-located :func:`live_colocated_sim`).
* :class:`Scenario` — declarative fault/interference injection:
  :class:`Straggler`, :class:`FailTask`, :class:`FailHost`,
  :class:`DegradeLink`, :class:`Interference`, :class:`BitFlip`
  (silent data corruption in a task's payload/result stream),
  :class:`ClockSkew` (per-host constant + drift receive-clock skew),
  and :class:`JoinHost` (membership churn — a host joins the cluster
  at a virtual time, like ``Topology.join``).
* :class:`AutoscaledServe` — the traffic-driven control plane
  (:mod:`repro.sim.control`): open-loop arrivals, health probes, a
  :class:`ThresholdAutoscaler` booting/draining a pool of late-joining
  hosts, pluggable placement (:data:`PLACEMENT_POLICIES`); reported in
  ``SimReport.control``.
* :class:`Campaign` — swept fault grids (:class:`FaultGrid`) over a
  scenario base: every point run deterministically, classified
  against the fault-free baseline, and failing points delta-minimized
  to replayable reproducer specs (:mod:`repro.sim.campaign`); named,
  versioned scenario entries with pinned goldens live in
  :mod:`repro.sim.registry` (``registry.load("live_recovery@v1")``).
* :class:`Simulation` — materializes the above into a single-host
  :class:`~repro.core.scheduler.Scheduler` or a multi-host
  :class:`~repro.core.orchestrator.Orchestrator` (picked automatically),
  places components via ``Orchestrator.co_locate`` when
  ``placement="auto"``, and returns a structured :class:`SimReport`.
  ``run(engine="dist", n_workers=K)`` shards the hosts across real OS
  worker processes (`repro.dist`) with bit-identical results.

Quickstart::

    from repro.core.cluster import ClusterSpec, StepCost
    from repro.sim import (ChipRingTraining, Scenario, Simulation,
                           Straggler, Topology)

    wl = ChipRingTraining(ClusterSpec(n_pods=1, chips_per_pod=8),
                          StepCost(compute_ns=5_000_000,
                                   ici_bytes=1_000_000), n_steps=4)
    report = Simulation(
        Topology.single_host(n_cpus=8), wl,
        Scenario("slow chip", (Straggler("chip3", 2.0),))).run()
    print(report.to_json())
"""
from repro.sim.topology import CellSpec, FabricSpec, Topology
from repro.sim.workload import (EndpointSpec, Program, ScopeSpec,
                                VecCompute, VecMark, VecRecv, VecSend,
                                Workload)
from repro.sim.scenario import (BitFlip, ClockSkew, DegradeLink,
                                FailHost, FailTask, Injection,
                                Interference, JoinHost, Scenario,
                                Straggler)
from repro.sim.report import HostReport, SimReport
from repro.sim.simulation import Simulation
from repro.sim.vectorized import SweepResult, UnsupportedByEngine
from repro.sim.workloads import (ChipRingTraining, LiveServe,
                                 ModeledServe, RackRing,
                                 burst_arrivals, diurnal_arrivals,
                                 poisson_arrivals)
from repro.sim.control import (PLACEMENT_POLICIES, AutoscaledServe,
                               ThresholdAutoscaler, best_fit,
                               first_fit, worst_fit)
from repro.sim.live import (LiveProgram, LiveTrainerRecovery,
                            ServeStack, TrainerStack,
                            live_colocated_sim, live_recovery_sim,
                            live_serve_sim, record_live_colocated,
                            record_live_recovery, record_live_serve,
                            recovery_timeline, serve_latency)
from repro.live import (CostLedger, LiveTraceError, LiveTraceMismatch,
                        TRACE_SCHEMA)
from repro.core.engine_jax import TickRangeError
from repro.sim.campaign import (Campaign, CampaignReport, FaultGrid,
                                GridPoint, replay_spec)
from repro.sim import registry

__all__ = [
    "AutoscaledServe", "BitFlip", "Campaign", "CampaignReport",
    "CellSpec", "ChipRingTraining", "ClockSkew", "CostLedger",
    "DegradeLink", "EndpointSpec", "FabricSpec", "FailHost",
    "FailTask", "FaultGrid", "GridPoint", "HostReport", "Injection",
    "Interference", "JoinHost", "LiveProgram", "LiveServe",
    "LiveTraceError", "LiveTraceMismatch", "LiveTrainerRecovery",
    "ModeledServe", "PLACEMENT_POLICIES", "Program", "RackRing",
    "Scenario", "ScopeSpec", "ServeStack", "SimReport", "Simulation",
    "Straggler", "SweepResult", "TRACE_SCHEMA", "ThresholdAutoscaler",
    "TickRangeError", "Topology", "TrainerStack",
    "UnsupportedByEngine", "VecCompute", "VecMark", "VecRecv",
    "VecSend", "Workload", "best_fit", "burst_arrivals",
    "diurnal_arrivals", "first_fit", "live_colocated_sim",
    "live_recovery_sim", "live_serve_sim", "poisson_arrivals",
    "record_live_colocated", "record_live_recovery",
    "record_live_serve", "recovery_timeline", "registry",
    "replay_spec", "serve_latency", "worst_fit",
]
