"""Workload ports: chip-ring training, rack-ring, and serving.

These are the repo's hand-wired simulations re-expressed against the
:class:`~repro.sim.workload.Workload` protocol.  Bodies are kept
action-for-action identical to the legacy builders so the thin adapters
in :mod:`repro.core.cluster` produce bit-identical results (verified by
``tests/test_sim_equivalence.py``); stragglers/failures moved out of the
bodies and into :class:`~repro.sim.scenario.Scenario` injections.

Serving comes in two forms: :class:`ModeledServe` (closed-loop clients
with a modeled service time) and :class:`LiveServe` — the real
:class:`~repro.serve.loop.BatchServer` prefill/decode steps under
simulated time, fed by an *open-loop* arrival schedule
(:func:`poisson_arrivals` / :func:`burst_arrivals`) standing in for
high-traffic clients that do not wait for responses.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.cluster import ClusterSpec, StepCost
from repro.core.ipc import LinkSpec
from repro.core.vtask import Compute, LiveCall, Recv, Send
from repro.sim.scenario import TaskHandle
from repro.sim.topology import FabricSpec
from repro.sim.workload import (EndpointSpec, Program, ScopeSpec,
                                VecCompute, VecMark, VecRecv, VecSend,
                                Workload)


def _live_step() -> None:
    """Trivial fork-safe body for cost-derived live iterations (the
    cost comes from ``cost_ns``; the call just has to be real)."""
    return None


class ChipRingTraining(Workload):
    """Data-parallel training: one vtask per chip.

    Per step each chip computes (cost-derived or live), exchanges its
    per-step collective bytes with its pod-ring neighbor over the pod
    ICI fabric, and pod leaders all-reduce over the DCN fabric.  Chips
    are oblivious to placement: single-host they share one scheduler;
    with ``chips_per_host`` sharding (see ``build_training_cluster``)
    the same bodies run across orchestrated hosts and ring edges that
    cross hosts ride the host interconnect.
    """

    name = "train"

    def __init__(self, spec: ClusterSpec, step_cost: StepCost,
                 n_steps: int, *, skew_bound_ns: int = 1_000_000,
                 live_step_fn: Optional[Callable] = None,
                 ledger=None,
                 cells: Optional[Dict[str, str]] = None):
        if ledger is not None and live_step_fn is None \
                and ledger.mode == "record":
            raise ValueError("a record-mode ledger needs live_step_fn "
                             "(the real callable to measure)")
        self.spec = spec
        self.step_cost = step_cost
        self.n_steps = n_steps
        self.skew_bound_ns = skew_bound_ns
        self.live_step_fn = live_step_fn
        # optional repro.live.CostLedger: per-(chip, step) recorded costs
        # replace the static cost model for live steps (record/replay)
        self.ledger = ledger
        # program name -> declared cell name (§3.3); chips with an
        # entry bind their live steps to that memory-hierarchy cell
        self.cells = cells or {}
        self.done_steps = np.zeros(spec.n_chips, dtype=np.int64)

    def fabrics(self) -> List[FabricSpec]:
        spec = self.spec
        ici = LinkSpec(bandwidth_bps=spec.ici_bw_Bps * 8,
                       latency_ns=spec.ici_lat_ns)
        dcn = LinkSpec(bandwidth_bps=spec.dcn_bw_Bps * 8,
                       latency_ns=spec.dcn_lat_ns)
        return [FabricSpec(f"ici{p}", ici) for p in range(spec.n_pods)] \
            + [FabricSpec("dcn", dcn)]

    def _chip_body(self, c: int):
        spec, cost = self.spec, self.step_cost
        p = c // spec.chips_per_pod
        right = p * spec.chips_per_pod + (c + 1) % spec.chips_per_pod
        leader = spec.n_pods > 1 and c % spec.chips_per_pod == 0
        other = (p + 1) % spec.n_pods
        live_fn = self.live_step_fn

        def make_body(eps):
            ep = eps[f"chip{c}"]
            dep = eps.get(f"pod{p}")

            def body():
                for step in range(self.n_steps):
                    if self.ledger is not None:
                        _, ns = self.ledger.charge(
                            f"chip{c}", f"step:{step}", live_fn)
                        yield LiveCall(_live_step, cost_ns=ns,
                                       label=f"step:{step}")
                    elif live_fn is not None:
                        yield LiveCall(live_fn, cost_ns=cost.compute_ns)
                    else:
                        yield Compute(cost.compute_ns)
                    yield Send(ep, f"chip{right}", cost.ici_bytes)
                    yield Recv(ep)
                    if leader:
                        yield Send(dep, f"pod{other}", cost.dcn_bytes)
                        yield Recv(dep)
                    self.done_steps[c] = step + 1
            return body()
        return make_body

    def programs(self) -> List[Program]:
        spec = self.spec
        out = []
        for c in range(spec.n_chips):
            p = c // spec.chips_per_pod
            eps: Tuple[EndpointSpec, ...] = (
                EndpointSpec(f"chip{c}", f"ici{p}"),)
            if c % spec.chips_per_pod == 0:
                eps += (EndpointSpec(f"pod{p}", "dcn"),)
            out.append(Program(
                name=f"chip{c}", make_body=self._chip_body(c),
                endpoints=eps,
                kind="live" if (self.live_step_fn or self.ledger)
                else "modeled",
                cell=self.cells.get(f"chip{c}")))
        return out

    def traffic(self) -> Dict[Tuple[str, str], float]:
        spec, cost = self.spec, self.step_cost
        t: Dict[Tuple[str, str], float] = {}
        for c in range(spec.n_chips):
            p = c // spec.chips_per_pod
            right = p * spec.chips_per_pod + (c + 1) % spec.chips_per_pod
            t[(f"chip{c}", f"chip{right}")] = float(max(cost.ici_bytes, 1))
        if spec.n_pods > 1:
            for p in range(spec.n_pods):
                a = p * spec.chips_per_pod
                b = ((p + 1) % spec.n_pods) * spec.chips_per_pod
                t[(f"chip{a}", f"chip{b}")] = float(
                    max(cost.dcn_bytes, 1))
        return t

    def scopes(self) -> List[ScopeSpec]:
        return [ScopeSpec("train", self.skew_bound_ns)]

    def progress(self) -> Dict[str, np.ndarray]:
        return {"done_steps": self.done_steps}

    def reset(self) -> None:
        self.done_steps[:] = 0
        if self.ledger is not None and self.ledger.mode == "replay":
            self.ledger.rewind()

    def live_mode(self):
        return self.ledger.mode if self.ledger is not None else None

    def live_fns(self):
        if self.live_step_fn is None:
            return {}
        return {f"chip{c}": self.live_step_fn
                for c in range(self.spec.n_chips)}

    def live_report(self, tasks=None):
        if self.ledger is None:
            return None
        return {"mode": self.ledger.mode,
                "calibration": self.ledger.calibration, "tasks": {}}

    def vec_ops(self):
        """Vectorized lowering — op-for-op the ``_chip_body`` stream
        (modeled computes only; live steps have no array form)."""
        if self.live_step_fn is not None or self.ledger is not None:
            return None
        spec, cost = self.spec, self.step_cost
        out = {}
        for c in range(spec.n_chips):
            p = c // spec.chips_per_pod
            right = p * spec.chips_per_pod + (c + 1) % spec.chips_per_pod
            leader = spec.n_pods > 1 and c % spec.chips_per_pod == 0
            other = (p + 1) % spec.n_pods
            ops = []
            for step in range(self.n_steps):
                ops.append(VecCompute(cost.compute_ns))
                ops.append(VecSend(f"chip{c}", f"chip{right}",
                                   cost.ici_bytes))
                ops.append(VecRecv(f"chip{c}"))
                if leader:
                    ops.append(VecSend(f"pod{p}", f"pod{other}",
                                       cost.dcn_bytes))
                    ops.append(VecRecv(f"pod{p}"))
                ops.append(VecMark("done_steps", c, step + 1))
            out[f"chip{c}"] = ops
        return out


class RackRing(Workload):
    """Heterogeneous-latency multi-host ring (paper §3.5): one worker
    per host, hosts grouped into racks; intra-rack ring every iteration,
    cross-rack leader ring every ``cross_every`` iterations.  Natural
    placement is one worker per host (``build_rack_cluster`` pins it);
    rack compute imbalance is a Scenario concern (Straggler injections).
    """

    name = "rack"

    def __init__(self, *, n_racks: int = 2, hosts_per_rack: int = 2,
                 n_iters: int = 200, compute_ns: int = 5_000,
                 msg_bytes: int = 4096, cross_every: int = 20,
                 skew_bound_ns: int = 0,
                 local_link: LinkSpec = LinkSpec(bandwidth_bps=80e9 * 8,
                                                 latency_ns=500),
                 live: bool = False,
                 cells: Optional[Dict[str, str]] = None):
        self.n_racks = n_racks
        self.hosts_per_rack = hosts_per_rack
        self.n_workers = n_racks * hosts_per_rack
        self.n_iters = n_iters
        self.compute_ns = compute_ns
        self.msg_bytes = msg_bytes
        self.cross_every = cross_every
        self.skew_bound_ns = skew_bound_ns
        self.local_link = local_link
        # live=True swaps each iteration's modeled Compute for a
        # cost-derived LiveCall, so workers can bind to §3.3 cells
        # (``cells``: worker name -> declared cell name) and pick up
        # spatial-interference / reconditioning charges
        self.live = live
        self.cells = cells or {}
        self.iters_done = np.zeros(self.n_workers, dtype=np.int64)

    def fabrics(self) -> List[FabricSpec]:
        return [FabricSpec("hub", self.local_link)]

    def _worker_body(self, h: int):
        r = h // self.hosts_per_rack
        slot = h % self.hosts_per_rack
        right = r * self.hosts_per_rack + (slot + 1) % self.hosts_per_rack
        is_leader = slot == 0
        next_rack = (r + 1) % self.n_racks

        def make_body(eps):
            ep = eps[f"w{h}"]
            xep = eps.get(f"lead{r}")

            def body():
                for i in range(self.n_iters):
                    if self.live:
                        yield LiveCall(_live_step,
                                       cost_ns=self.compute_ns)
                    else:
                        yield Compute(self.compute_ns)
                    if self.hosts_per_rack > 1:
                        yield Send(ep, f"w{right}", self.msg_bytes)
                        yield Recv(ep)
                    if (is_leader and self.n_racks > 1
                            and (i + 1) % self.cross_every == 0):
                        yield Send(xep, f"lead{next_rack}",
                                   self.msg_bytes)
                        yield Recv(xep)
                    self.iters_done[h] = i + 1
            return body()
        return make_body

    def programs(self) -> List[Program]:
        out = []
        for h in range(self.n_workers):
            r = h // self.hosts_per_rack
            eps: Tuple[EndpointSpec, ...] = (EndpointSpec(f"w{h}", "hub"),)
            if h % self.hosts_per_rack == 0:
                eps += (EndpointSpec(f"lead{r}", "hub"),)
            out.append(Program(name=f"w{h}",
                               make_body=self._worker_body(h),
                               endpoints=eps,
                               kind="live" if self.live else "modeled",
                               cell=self.cells.get(f"w{h}")))
        return out

    def default_placement(self) -> Dict[str, int]:
        return {f"w{h}": h for h in range(self.n_workers)}

    def live_fns(self):
        if not self.live:
            return {}
        return {f"w{h}": _live_step for h in range(self.n_workers)}

    def stragglers(self, rack_slowdown: Tuple[float, ...]):
        """Per-rack compute multipliers -> per-worker Straggler
        injections (racks beyond the tuple, and 1.0 entries, are
        untouched).  The single source of the mapping used by the
        legacy adapter, benchmarks, and examples."""
        from repro.sim.scenario import Straggler
        out = []
        for h in range(self.n_workers):
            r = h // self.hosts_per_rack
            if r < len(rack_slowdown) and rack_slowdown[r] != 1.0:
                out.append(Straggler(f"w{h}", rack_slowdown[r]))
        return tuple(out)

    def traffic(self) -> Dict[Tuple[str, str], float]:
        t: Dict[Tuple[str, str], float] = {}
        per_iter = float(self.msg_bytes) * self.n_iters
        for h in range(self.n_workers):
            r = h // self.hosts_per_rack
            slot = h % self.hosts_per_rack
            if self.hosts_per_rack > 1:
                right = r * self.hosts_per_rack \
                    + (slot + 1) % self.hosts_per_rack
                t[(f"w{h}", f"w{right}")] = per_iter
        if self.n_racks > 1:
            for r in range(self.n_racks):
                a = r * self.hosts_per_rack
                b = ((r + 1) % self.n_racks) * self.hosts_per_rack
                t[(f"w{a}", f"w{b}")] = per_iter / self.cross_every
        return t

    def scopes(self) -> List[ScopeSpec]:
        if self.skew_bound_ns > 0:
            return [ScopeSpec("cluster", self.skew_bound_ns)]
        return []

    def progress(self) -> Dict[str, np.ndarray]:
        return {"iters_done": self.iters_done}

    def reset(self) -> None:
        self.iters_done[:] = 0

    def vec_ops(self):
        """Vectorized lowering — op-for-op the ``_worker_body`` stream
        (modeled iterations only)."""
        if self.live:
            return None
        out = {}
        for h in range(self.n_workers):
            r = h // self.hosts_per_rack
            slot = h % self.hosts_per_rack
            right = (r * self.hosts_per_rack
                     + (slot + 1) % self.hosts_per_rack)
            is_leader = slot == 0
            next_rack = (r + 1) % self.n_racks
            ops = []
            for i in range(self.n_iters):
                ops.append(VecCompute(self.compute_ns))
                if self.hosts_per_rack > 1:
                    ops.append(VecSend(f"w{h}", f"w{right}",
                                       self.msg_bytes))
                    ops.append(VecRecv(f"w{h}"))
                if (is_leader and self.n_racks > 1
                        and (i + 1) % self.cross_every == 0):
                    ops.append(VecSend(f"lead{r}", f"lead{next_rack}",
                                       self.msg_bytes))
                    ops.append(VecRecv(f"lead{r}"))
                ops.append(VecMark("iters_done", h, i + 1))
            out[f"w{h}"] = ops
        return out


class ModeledServe(Workload):
    """Closed-loop request serving: ``n_clients`` clients think, send a
    request, and wait for the response; one server computes per-request
    service time.  Co-locate with a training workload (single host +
    ``cpu_resource=True``) to study interference coupling."""

    name = "serve"

    def __init__(self, *, n_clients: int = 2, n_requests: int = 50,
                 think_ns: int = 20_000, service_ns: int = 50_000,
                 req_bytes: int = 1024, resp_bytes: int = 256,
                 skew_bound_ns: int = 0,
                 link: LinkSpec = LinkSpec(bandwidth_bps=10e9 * 8,
                                           latency_ns=20_000)):
        self.n_clients = n_clients
        self.n_requests = n_requests
        self.think_ns = think_ns
        self.service_ns = service_ns
        self.req_bytes = req_bytes
        self.resp_bytes = resp_bytes
        self.skew_bound_ns = skew_bound_ns
        self.link = link
        self.served = np.zeros(n_clients, dtype=np.int64)

    def fabrics(self) -> List[FabricSpec]:
        return [FabricSpec("svc", self.link)]

    def programs(self) -> List[Program]:
        wl = self

        def server_factory(eps):
            srv = eps["serve.srv"]

            def body():
                for _ in range(wl.n_clients * wl.n_requests):
                    msg = yield Recv(srv)
                    yield Compute(wl.service_ns)
                    yield Send(srv, f"serve.cli{msg.payload}",
                               wl.resp_bytes, payload=msg.payload)
            return body()

        def client_factory(i):
            def factory(eps):
                cli = eps[f"serve.cli{i}"]

                def body():
                    for j in range(wl.n_requests):
                        yield Compute(wl.think_ns)
                        yield Send(cli, "serve.srv", wl.req_bytes,
                                   payload=i)
                        yield Recv(cli)
                        wl.served[i] = j + 1
                return body()
            return factory

        out = [Program(name="serve.server", make_body=server_factory,
                       endpoints=(EndpointSpec("serve.srv", "svc"),))]
        for i in range(self.n_clients):
            out.append(Program(
                name=f"serve.client{i}", make_body=client_factory(i),
                endpoints=(EndpointSpec(f"serve.cli{i}", "svc"),)))
        return out

    def traffic(self) -> Dict[Tuple[str, str], float]:
        w = float(self.n_requests * (self.req_bytes + self.resp_bytes))
        return {("serve.server", f"serve.client{i}"): w
                for i in range(self.n_clients)}

    def scopes(self) -> List[ScopeSpec]:
        if self.skew_bound_ns > 0:
            return [ScopeSpec("serve", self.skew_bound_ns)]
        return []

    def progress(self) -> Dict[str, np.ndarray]:
        return {"served": self.served}

    def reset(self) -> None:
        self.served[:] = 0


# ---------------------------------------------------------------------------
# open-loop arrival schedules + live serving
# ---------------------------------------------------------------------------


def poisson_arrivals(n: int, mean_gap_ns: int, *, seed: int = 0,
                     start_ns: int = 0) -> np.ndarray:
    """Open-loop Poisson arrival schedule: ``n`` absolute arrival
    vtimes (int64 ns) with exponential inter-arrival gaps of mean
    ``mean_gap_ns``, each clamped >= 1 ns, deterministic in ``seed``.

    The schedule is *generated once* — at record time for live serving
    — and pinned into the trace meta, so replays read the exact integer
    schedule back instead of re-deriving it from an RNG stream (numpy
    stream details must never be part of the determinism argument)."""
    if n < 1:
        raise ValueError(f"need at least one arrival, got n={n}")
    if mean_gap_ns < 1:
        raise ValueError(f"mean_gap_ns must be >= 1, got {mean_gap_ns}")
    rng = np.random.default_rng(seed)
    gaps = np.maximum(1, rng.exponential(float(mean_gap_ns),
                                         size=n)).astype(np.int64)
    return int(start_ns) + np.cumsum(gaps)


def burst_arrivals(n: int, burst_size: int, *, gap_ns: int,
                   spread_ns: int = 0, start_ns: int = 0) -> np.ndarray:
    """Deterministic bursty schedule: requests arrive in bursts of
    ``burst_size`` (``spread_ns`` apart inside a burst), one burst
    every ``gap_ns``, truncated to ``n`` requests — the high-traffic
    antagonist for queue-depth stats (a whole burst lands on the server
    at once)."""
    if n < 1 or burst_size < 1 or gap_ns < 1:
        raise ValueError("n, burst_size and gap_ns must be >= 1")
    out = []
    b = 0
    while len(out) < n:
        t0 = int(start_ns) + (b + 1) * int(gap_ns)
        for i in range(burst_size):
            out.append(t0 + i * int(spread_ns))
            if len(out) == n:
                break
        b += 1
    return np.asarray(out, dtype=np.int64)


def diurnal_arrivals(n: int, *, base_gap_ns: int, peak_gap_ns: int,
                     period_ns: int, seed: int = 0,
                     start_ns: int = 0) -> np.ndarray:
    """Open-loop diurnal schedule: ``n`` absolute arrival vtimes whose
    mean inter-arrival gap swings sinusoidally between ``base_gap_ns``
    (trough traffic, long gaps — the cycle starts here) and
    ``peak_gap_ns`` (peak traffic, short gaps, reached half a
    ``period_ns`` in), with exponential jitter around the phase mean,
    deterministic in ``seed``.  The traffic shape autoscalers exist
    for: load ramps up ~``base_gap_ns / peak_gap_ns``x into the peak
    and back down again.  Like :func:`poisson_arrivals`, the schedule
    is generated once at build time and pinned — int64 ns, clamped to
    >= 1 ns gaps."""
    if n < 1:
        raise ValueError(f"need at least one arrival, got n={n}")
    if not 1 <= peak_gap_ns <= base_gap_ns:
        raise ValueError(f"need 1 <= peak_gap_ns <= base_gap_ns, got "
                         f"peak={peak_gap_ns} base={base_gap_ns}")
    if period_ns < 2:
        raise ValueError(f"period_ns must be >= 2, got {period_ns}")
    rng = np.random.default_rng(seed)
    jitter = rng.exponential(1.0, size=n)
    out = np.empty(n, dtype=np.int64)
    t = int(start_ns)
    half_swing = (base_gap_ns - peak_gap_ns) / 2.0
    for i in range(n):
        phase = (t % period_ns) / period_ns
        mean = peak_gap_ns + half_swing * (
            1.0 + np.cos(2.0 * np.pi * phase))
        t += max(1, int(jitter[i] * mean))
        out[i] = t
    return out


class LiveServe(Workload):
    """Open-loop live serving: the real serve stack under simulated
    time (the serve half of the paper's full-stack claim).

    Two programs: ``serve.src`` — the open-loop source, emitting one
    request per entry of the ``arrivals`` schedule without waiting for
    responses (millions-of-users traffic has no closed loop); and
    ``serve.live`` — the live server, which forms *waves*: on receiving
    the head request it batches every request whose scheduled arrival
    is at or before its current vtime (up to ``max_batch``, the static
    batch of :class:`~repro.serve.loop.BatchServer`), then runs one
    prefill plus ``decode_steps`` decode steps as cost-derived
    :class:`~repro.core.vtask.LiveCall`\\ s charged through the
    :class:`~repro.live.CostLedger` — real jitted BatchServer steps in
    record mode (via :class:`~repro.sim.live.ServeStack`), pinned costs
    in replay.

    Determinism: wave membership depends only on the build-time
    ``arrivals`` array and the server's vtime, which replay re-derives
    exactly from the pinned costs — so the wave sequence, the ledger
    labels, per-request latencies, and queue depths are bit-identical
    across single/barrier/async/dist (`tests/test_live_serve.py`).

    The per-task live section reports simulated time-in-system
    percentiles (p50/p95/p99, nearest-rank on integers — no float
    interpolation) and queue-depth stats sampled at each wave start,
    surfaced through ``SimReport.live``.
    """

    name = "live_serve"
    SERVER = "serve.live"
    SOURCE = "serve.src"

    def __init__(self, *, ledger, arrivals: Sequence[int], stack=None,
                 max_batch: int = 4, decode_steps: int = 4,
                 req_bytes: int = 512, resp_bytes: int = 2048,
                 cell: Optional[str] = None,
                 link: LinkSpec = LinkSpec(bandwidth_bps=25e9 * 8,
                                           latency_ns=10_000)):
        if ledger.mode == "record" and stack is None:
            raise ValueError("record mode needs a real ServeStack "
                             "(the callables to measure)")
        if max_batch < 1 or decode_steps < 1:
            raise ValueError("max_batch and decode_steps must be >= 1")
        arr = np.asarray(arrivals, dtype=np.int64)
        if arr.ndim != 1 or len(arr) == 0:
            raise ValueError("arrivals must be a non-empty 1-D schedule")
        if np.any(arr < 1):
            raise ValueError("arrival vtimes must be >= 1 ns")
        if np.any(np.diff(arr) < 0):
            raise ValueError("arrivals must be non-decreasing")
        self.ledger = ledger
        self.stack = stack
        self.arrivals = arr
        self.max_batch = max_batch
        self.decode_steps = decode_steps
        self.req_bytes = req_bytes
        self.resp_bytes = resp_bytes
        self.cell = cell
        self.link = link
        self._handle = TaskHandle()
        self.sent = np.zeros(1, dtype=np.int64)
        self.served = np.zeros(1, dtype=np.int64)
        self.latencies = np.zeros(len(arr), dtype=np.int64)
        self.wave_sizes: List[int] = []
        self.wave_depths: List[int] = []

    # -- bodies --------------------------------------------------------------
    def _source_factory(self, eps):
        ep = eps["serve.lsrc"]

        def body():
            prev = 0
            for i, t in enumerate(self.arrivals):
                t = int(t)
                if t > prev:
                    yield Compute(t - prev)
                prev = t
                yield Send(ep, "serve.lsrv", self.req_bytes, payload=i)
                self.sent[0] = i + 1
            # open loop: responses are drained only after the last
            # request is out, so sending never waits on the server
            while True:
                msg = yield Recv(ep)
                if msg.payload[0] == "close":
                    return
        return body()

    def _server_factory(self, eps):
        ep = eps["serve.lsrv"]

        def body():
            led, stack = self.ledger, self.stack
            if stack is not None:
                stack.setup()    # model init + jit warm-up: outside
            task = self._handle.task            # simulated time
            arr = self.arrivals
            n = len(arr)
            done = wave = 0
            while done < n:
                yield Recv(ep)               # head request of the wave
                now = int(task.vtime)
                # wave membership: every request whose *scheduled*
                # arrival is at or before now, capped at the static
                # batch — build-time data + deterministic vtime only
                hi = done + 1
                while hi < n and hi - done < self.max_batch \
                        and int(arr[hi]) <= now:
                    hi += 1
                for _ in range(done + 1, hi):
                    yield Recv(ep)           # rest of the wave
                batch = hi - done
                depth = hi
                while depth < n and int(arr[depth]) <= now:
                    depth += 1
                self.wave_sizes.append(batch)
                self.wave_depths.append(depth - done)
                _, cost = led.charge(
                    self.SERVER, f"prefill:{wave}",
                    stack.prefill if stack else None, (wave, batch))
                yield LiveCall(_live_step, cost_ns=cost,
                               label=f"prefill:{wave}")
                for d in range(self.decode_steps):
                    _, cost = led.charge(
                        self.SERVER, f"decode:{wave}:{d}",
                        stack.decode if stack else None, (wave, d))
                    yield LiveCall(_live_step, cost_ns=cost,
                                   label=f"decode:{wave}:{d}")
                t_done = int(task.vtime)
                for j in range(done, hi):
                    self.latencies[j] = t_done - int(arr[j])
                yield Send(ep, "serve.lsrc", self.resp_bytes * batch,
                           payload=("wave", wave, batch))
                done = hi
                self.served[0] = done
                wave += 1
            yield Send(ep, "serve.lsrc", 64, payload=("close", wave, 0))
            if stack is not None:
                stack.close()
        return body()

    # -- workload protocol ---------------------------------------------------
    def fabrics(self) -> List[FabricSpec]:
        return [FabricSpec("lsvc", self.link)]

    def programs(self) -> List[Program]:
        return [
            Program(name=self.SOURCE, make_body=self._source_factory,
                    endpoints=(EndpointSpec("serve.lsrc", "lsvc"),)),
            Program(name=self.SERVER, make_body=self._server_factory,
                    endpoints=(EndpointSpec("serve.lsrv", "lsvc"),),
                    kind="live", cell=self.cell, handle=self._handle)]

    def default_placement(self) -> Dict[str, int]:
        return {self.SOURCE: 0, self.SERVER: 1}

    def traffic(self) -> Dict[Tuple[str, str], float]:
        n = len(self.arrivals)
        return {(self.SOURCE, self.SERVER):
                float(n * (self.req_bytes + self.resp_bytes))}

    def progress(self) -> Dict[str, np.ndarray]:
        return {"sent": self.sent, "served": self.served}

    def reset(self) -> None:
        self.sent[:] = 0
        self.served[:] = 0
        self.latencies[:] = 0
        self.wave_sizes.clear()
        self.wave_depths.clear()
        if self.ledger.mode == "replay":
            self.ledger.rewind()
        elif self.ledger.tasks.get(self.SERVER):
            raise ValueError(
                f"record ledger already holds {self.SERVER!r} costs: "
                f"one record run per ledger — save the trace and "
                f"replay it, or record with a fresh ledger")

    # -- live hooks ----------------------------------------------------------
    def live_mode(self):
        return self.ledger.mode

    def live_fns(self):
        return {self.SERVER: self.stack.prefill} if self.stack else {}

    def live_report(self, tasks: Optional[set] = None):
        sec = {"mode": self.ledger.mode,
               "calibration": self.ledger.calibration, "tasks": {}}
        if tasks is None or self.SERVER in tasks:
            done = int(self.served[0])
            lat = sorted(int(v) for v in self.latencies[:done])

            def pct(q):      # nearest-rank percentile, pure integers
                if not lat:
                    return 0
                return lat[min(len(lat) - 1,
                               max(0, (q * len(lat) + 99) // 100 - 1))]

            sec["tasks"][self.SERVER] = {
                "requests": done,
                "waves": len(self.wave_sizes),
                "max_wave_batch": max(self.wave_sizes, default=0),
                "latency_ns": {
                    "p50": pct(50), "p95": pct(95), "p99": pct(99),
                    "max": lat[-1] if lat else 0,
                    "mean": (sum(lat) // len(lat)) if lat else 0},
                "queue_depth": {
                    "max": max(self.wave_depths, default=0),
                    "sum": int(sum(self.wave_depths)),
                    "samples": len(self.wave_depths)}}
        return sec
