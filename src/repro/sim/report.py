"""Structured simulation results.

:class:`SimReport` replaces the ad-hoc ``(sched, tasks, ctx)`` tuples of
the hand-wired builders: one JSON-serializable record with per-host
dispatch/sync statistics, proxy staleness, per-link visibility slack,
per-task outcomes, and workload progress arrays.  ``status`` is
``"ok"`` or ``"deadlock"`` — fault injections that wedge the cluster
(e.g. a dead ring partner) are a *result*, not a crash.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Any, Dict, List

import numpy as np


@dataclasses.dataclass
class HostReport:
    """Per-host scheduler statistics (see SchedStats)."""
    host: int
    dispatches: int
    rounds: int
    skew_stalls: int
    max_skew_seen: int
    gate_deferrals: int
    window_runs: int
    preemptions: int
    live_calls: int

    @classmethod
    def from_sched(cls, host: int, stats) -> "HostReport":
        return cls(host=host, dispatches=stats.dispatches,
                   rounds=stats.rounds,
                   skew_stalls=stats.skew_stalls,
                   max_skew_seen=stats.max_skew_seen,
                   gate_deferrals=stats.gate_deferrals,
                   window_runs=stats.window_runs,
                   preemptions=stats.preemptions,
                   live_calls=stats.live_calls)


def _jsonable(value: Any) -> Any:
    if isinstance(value, np.ndarray):
        return value.tolist()
    if isinstance(value, (np.integer, np.floating)):
        return value.item()
    if isinstance(value, dict):
        return {k: _jsonable(v) for k, v in value.items()}
    if isinstance(value, (list, tuple)):
        return [_jsonable(v) for v in value]
    return value


@dataclasses.dataclass
class SimReport:
    status: str                      # "ok" | "deadlock"
    mode: str    # "single" | "async" | "barrier" | "dist" | "vectorized"
    n_hosts: int
    vtime_ns: int                    # simulated horizon
    wall_s: float
    messages: int
    bytes: int
    sync_rounds: int                 # orchestrator epochs (0 single-host)
    proxy_syncs: int
    cross_host_msgs: int
    max_proxy_staleness_ns: int
    max_window_ns: int
    hosts: List[HostReport]
    links: Dict[str, Dict[str, Any]]     # "hub->peer" -> peer_stats
    tasks: Dict[str, Dict[str, Any]]     # name -> {vtime, state, host}
    progress: Dict[str, Any]             # workload -> named arrays
    scenario: str = "baseline"
    detail: str = ""                     # deadlock detail, if any
    n_workers: int = 1                   # OS worker processes (dist engine)
    #: per-host §3.3 cell accounting, keyed by str(host): switches,
    #: recondition_ns, interference/self-pressure events, and per-cell
    #: slowdown histograms (CellManager.snapshot(); empty when the
    #: simulation declared no cells).  Integer-valued, so engines can be
    #: compared bit-exactly on it.
    cells: Dict[str, Any] = dataclasses.field(default_factory=dict)
    #: vectorized engine only: the compiled tick size and which bar of
    #: the two-tier conformance contract this run sits under ("exact" =
    #: every additive ns quantity was tick-divisible, results are
    #: bit-identical to the reference engines; "tolerance" = quantized,
    #: vtimes within the declared bound).  0/"" for the other engines.
    tick_ns: int = 0
    tier: str = ""
    #: live-execution sections, keyed by workload name (repro.sim.live):
    #: ledger mode + calibration and per-task records — for the marquee
    #: recovery scenario, the detection → restore → re-mesh → resumed
    #: timeline with vtimes.  Empty for fully modeled simulations, and
    #: integer-vtimed so the cross-engine harness compares it bit-exactly.
    live: Dict[str, Any] = dataclasses.field(default_factory=dict)
    #: control-plane timeline (repro.sim.control): a ``"membership"``
    #: list of vtime-ordered join/leave events plus one section per
    #: control workload (scale decisions, health events, placement, and
    #: p50/p95/p99 simulated request latency).  Empty when the
    #: simulation has neither membership churn nor a control workload;
    #: integer-vtimed so engines compare bit-exactly.
    control: Dict[str, Any] = dataclasses.field(default_factory=dict)
    #: structured companion to ``detail``: for deadlocks, the wedged
    #: hosts and any membership joins that never activated
    #: ({"kind": "wedged", "wedged_hosts": [...], "pending_joins":
    #: [...]}).  Empty on ok runs.  ``detail`` stays the human-readable
    #: string so existing goldens are byte-identical.
    detail_info: Dict[str, Any] = dataclasses.field(default_factory=dict)

    def to_dict(self) -> Dict[str, Any]:
        d = dataclasses.asdict(self)
        return _jsonable(d)

    def to_json(self, indent: int = 2) -> str:
        return json.dumps(self.to_dict(), indent=indent)
