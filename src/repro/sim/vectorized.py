"""The vectorized facade engine: compile a Simulation to arrays, run
the jitted round loop (``repro.core.engine_jax``), decompile back to a
normal :class:`~repro.sim.report.SimReport`.

``Simulation.run(engine="vectorized")`` is the fifth engine, held to
the same cross-engine equivalence bar as single/barrier/async/dist via
a *two-tier* contract (tests/engine_harness.py):

* **exact tier** — every additive ns quantity of the scenario (compute
  durations post-straggler, the scheduler's send overhead, per-message
  serialization and latency, DegradeLink extras) is divisible by the
  compiled tick (auto tick = their gcd, so auto-ticked scenarios are
  always exact when they fit the range): results are **bit-identical**
  to the reference engines, including per-link stats.
* **tolerance tier** — an explicit ``tick_ns=`` quantizes those
  quantities: per-task vtimes carry a declared bound
  (``tick * n_quantities`` — each additive term appears at most once on
  any event's max-plus dependency path), while the schedule-independent
  invariants (completion sets, per-task states, message/byte totals,
  progress arrays) stay exact.

Admissible scenario surface (everything else raises
:class:`UnsupportedByEngine` at build time, never silently diverges):
modeled programs lowered via ``Workload.vec_ops`` (RackRing,
ChipRingTraining), any topology/placement, Straggler / FailTask /
FailHost / DegradeLink / Interference injections, bounded-skew scopes.
Not admissible: live programs (real callables can't be arrays), §3.3
cells (stateful per-dispatch charges), ``cpu_resource`` (CPU-slot
schedules are engine timing, not results), multi-producer endpoints
(receive matching becomes schedule-dependent — e.g. ModeledServe), and
scenarios the reference would preempt (>= ``preempt_after`` consecutive
zero-progress computes).

Why the restricted surface is *provably* schedule-independent: each
channel has a single producer executing its sends in program order, so
per-channel FIFO busy chains and message visibilities depend only on
the producer's vtime trajectory; each receive is matched to one message
at compile time and resolves to ``vtime = max(vtime, visibility)``;
scope gating and CPU slots delay dispatch but never change any of those
values.  Hence dispatch-all-eligible-per-round produces the reference
fixpoint exactly.
"""
from __future__ import annotations

import dataclasses
import inspect
import math
import time
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from repro.core import engine_jax as ej
from repro.core.scheduler import Scheduler
from repro.core.vtime import SEC
from repro.sim.report import HostReport, SimReport, _jsonable
from repro.sim.scenario import (BitFlip, ClockSkew, DegradeLink,
                                FailTask, Interference, JoinHost,
                                Scenario)
from repro.sim.workload import VecCompute, VecMark, VecRecv, VecSend

__all__ = ["UnsupportedByEngine", "compile_simulation",
           "run_vectorized_sim", "sweep_vectorized", "SweepResult"]

#: reference-engine constants, read off Scheduler so a recalibration
#: there cannot silently diverge this engine
_SCHED_DEFAULTS = {
    p.name: p.default
    for p in inspect.signature(Scheduler.__init__).parameters.values()}
SEND_OVERHEAD_NS = int(_SCHED_DEFAULTS["send_overhead_ns"])
PREEMPT_AFTER = int(_SCHED_DEFAULTS["preempt_after"])

_INF = ej.INF_TICKS


class UnsupportedByEngine(ValueError):
    """The scenario uses a feature outside the vectorized engine's
    admissible surface (see module docstring).  Raised at build time so
    an unsupported run is an explicit error, not a silent divergence."""


def _ser_ns(size_bytes: int, link) -> int:
    # exactly Hub._serialize's expression
    return int(size_bytes * 8 / link.bandwidth_bps * SEC)


@dataclasses.dataclass
class _Msg:
    src_ep: str
    dst_ep: str
    size: int
    src_task: int
    src_host: int
    dst_host: int
    ch1: int
    ser1: int               # ns
    lat1: int               # ns
    two_stage: bool
    ch2: int
    ser2: int               # ns
    lat2: int               # ns
    extras: List[Tuple[int, int]]   # (from_vtime ns, extra ns)


@dataclasses.dataclass
class CompiledSim:
    """Tick-level arrays (``tape``) + everything decompile needs."""
    tape: "ej.VecTape"
    n_channels: int
    tick_ns: int
    tier: str                       # "exact" | "tolerance"
    tol_ns: int                     # declared vtime bound (0 = exact)
    max_rounds: int
    n_tasks: int
    n_programs: int                 # leading tasks that are programs
    task_names: List[str]
    task_hosts: List[int]
    #: per task: (op_index, workload_index, array, index, value); fires
    #: iff final pc >= op_index
    markers: List[List[Tuple[int, int, str, int, int]]]
    msgs: List[_Msg]
    hub_base: str                   # multi-host hub name prefix
    n_hosts: int
    scenario_name: str
    #: additive ns quantities (for sweep: shared-tick computation)
    quantities: List[int]


# ---------------------------------------------------------------------------
# lowering: facade -> ns-level tapes
# ---------------------------------------------------------------------------


def _detect_cells(sim, programs, inter_targets) -> bool:
    cell_of = {p.name: p.cell for _, p in programs if p.cell}
    load_cells = [inj.cell for inj, _ in inter_targets]
    if sim.cells_mode == "auto":
        prog_hosts: Dict[int, List[str]] = {}
        for _, p in programs:
            prog_hosts.setdefault(sim.placement[p.name],
                                  []).append(p.name)
        load_hosts = {h for _, h in inter_targets}
        for h, names in prog_hosts.items():
            if len(names) >= 2 or h in load_hosts:
                return True
        if load_cells:
            return True
    return bool(cell_of) or any(c is not None for c in load_cells)


def _lower(sim) -> Dict[str, Any]:
    """Validate the scenario against the admissible surface and lower
    it to ns-level python/numpy structures (tick-independent)."""
    topo = sim.topology
    programs = sim._programs()
    fabrics = sim._fabrics()
    names = [p.name for _, p in programs]
    placement = sim._resolve_placement(names)
    sim.placement = placement
    inter_targets = sim._resolve_interference()

    if sim.cpu_resource:
        raise UnsupportedByEngine(
            "cpu_resource=True: CPU-slot contention is an engine "
            "schedule, not an array op")
    if getattr(topo, "joins", None) or any(
            isinstance(inj, JoinHost) for inj in sim.scenario.injections):
        raise UnsupportedByEngine(
            "membership joins: late hosts need the conservative "
            "engines' membership-epoch re-solve; the vectorized "
            "compiler lowers a fixed host set")
    for inj in sim.scenario.injections:
        # explicit rejection, not silent omission: a campaign's sweep
        # fast path relies on this raise to fall back to the reference
        # engines for data-corruption / ingress-skew grids
        if isinstance(inj, BitFlip):
            raise UnsupportedByEngine(
                "BitFlip: payload values have no vectorized lowering "
                "(tapes carry sizes and timing, not data)")
        if isinstance(inj, ClockSkew):
            raise UnsupportedByEngine(
                "ClockSkew: ingress hooks are per-delivery hub state, "
                "not a tape-time transform")
    for _, p in programs:
        if p.kind != "modeled":
            raise UnsupportedByEngine(
                f"live program {p.name!r}: real callables have no "
                f"vectorized lowering")
    if _detect_cells(sim, programs, inter_targets):
        raise UnsupportedByEngine(
            "memory-hierarchy cells: per-dispatch cell charges are "
            "stateful scheduler semantics")

    # workload lowering
    ops_by_name: Dict[str, list] = {}
    wl_of_prog: Dict[str, int] = {}
    for wi, wl in enumerate(sim.workloads):
        wl_progs = [p.name for w, p in programs if w is wl]
        vec = wl.vec_ops()
        if vec is None:
            raise UnsupportedByEngine(
                f"workload {wl.name!r} has no vec_ops() lowering")
        missing = [n for n in wl_progs if n not in vec]
        if missing:
            raise ValueError(
                f"vec_ops() of {wl.name!r} missing programs {missing}")
        for n in wl_progs:
            ops_by_name[n] = list(vec[n])
            wl_of_prog[n] = wi

    # endpoints (mirrors the build() spawn loop's wiring checks)
    ep_owner: Dict[str, str] = {}
    ep_fabric: Dict[str, str] = {}
    fabric_by_name = {f.name: f for f in fabrics}
    for _, p in programs:
        for es in p.endpoints:
            if es.name in ep_owner:
                raise ValueError(f"duplicate endpoint {es.name!r}")
            if es.fabric not in fabric_by_name:
                raise KeyError(f"unknown fabric {es.fabric!r}")
            ep_owner[es.name] = p.name
            ep_fabric[es.name] = es.fabric

    scale, fails = sim._resolve_fault_plan(names)

    # task list: programs (report-visible) then interference loads
    tapes: List[list] = []        # per task: real ops (marks stripped)
    markers: List[List[Tuple[int, int, str, int, int]]] = []
    task_names: List[str] = []
    task_hosts: List[int] = []
    for _, p in programs:
        factor = scale.get(p.name)
        real: list = []
        marks: List[Tuple[int, int, str, int, int]] = []
        for op in ops_by_name[p.name]:
            if isinstance(op, VecMark):
                marks.append((len(real), wl_of_prog[p.name],
                              op.array, op.index, op.value))
                continue
            if isinstance(op, VecCompute):
                ns = int(op.ns * factor) if factor is not None else op.ns
                real.append(VecCompute(ns))
            elif isinstance(op, (VecSend, VecRecv)):
                if ep_owner.get(op.endpoint) != p.name:
                    raise ValueError(
                        f"program {p.name!r} uses endpoint "
                        f"{op.endpoint!r} it does not own")
                real.append(op)
            else:
                raise UnsupportedByEngine(
                    f"program {p.name!r}: op {op!r} has no vectorized "
                    f"form")
        tapes.append(real)
        markers.append(marks)
        task_names.append(p.name)
        task_hosts.append(placement[p.name])
    n_programs = len(programs)
    for i, (inj, host) in enumerate(inter_targets):
        tapes.append([VecCompute(inj.burst_ns)] * inj.bursts)
        markers.append([])
        task_names.append(f"load{i}")
        task_hosts.append(host)
    n_tasks = len(tapes)
    for name, real in zip(task_names, tapes):
        # the reference counter resets on *progress*, so interleaved
        # sends/recvs don't break a zero-compute run
        zero_run = 0
        for op in real:
            if isinstance(op, VecCompute):
                zero_run = zero_run + 1 if op.ns <= 0 else 0
                if zero_run >= PREEMPT_AFTER:
                    raise UnsupportedByEngine(
                        f"task {name!r}: >= {PREEMPT_AFTER} "
                        f"consecutive zero-progress computes — the "
                        f"reference scheduler would preempt it FAULTY")

    # messages + channels.  Pass 1: sends, in task/program order (=
    # per-channel FIFO order); pass 2: receive matching.
    channels: Dict[tuple, int] = {}

    def chan(key: tuple) -> int:
        return channels.setdefault(key, len(channels))

    msgs: List[_Msg] = []
    sends_to: Dict[str, List[int]] = {}
    dst_sources: Dict[str, set] = {}
    peer_producers: Dict[tuple, set] = {}
    send_arg: Dict[Tuple[int, int], int] = {}
    for t, ops in enumerate(tapes):
        for j, op in enumerate(ops):
            if not isinstance(op, VecSend):
                continue
            if op.dst not in ep_owner:
                raise KeyError(f"unknown endpoint {op.dst!r}")
            fs, fd = ep_fabric[op.endpoint], ep_fabric[op.dst]
            if fs != fd:
                raise UnsupportedByEngine(
                    f"cross-fabric send {op.endpoint!r}->{op.dst!r} "
                    f"({fs!r} vs {fd!r})")
            flink = fabric_by_name[fs].link
            sh = placement[ep_owner[op.endpoint]]
            dh = placement[ep_owner[op.dst]]
            if sh == dh:
                m = _Msg(op.endpoint, op.dst, op.size_bytes, t, sh, dh,
                         ch1=chan(("ep", op.endpoint, op.dst)),
                         ser1=_ser_ns(op.size_bytes, flink),
                         lat1=flink.latency_ns, two_stage=False,
                         ch2=0, ser2=0, lat2=0, extras=[])
            else:
                plink = topo.host_link(sh, dh)
                key = ("peer", sh, dh)
                peer_producers.setdefault(key, set()).add(t)
                m = _Msg(op.endpoint, op.dst, op.size_bytes, t, sh, dh,
                         ch1=chan(key),
                         ser1=_ser_ns(op.size_bytes, plink),
                         lat1=plink.latency_ns, two_stage=True,
                         ch2=chan(("ep", op.endpoint, op.dst)),
                         ser2=_ser_ns(op.size_bytes, flink),
                         lat2=flink.latency_ns, extras=[])
            mid = len(msgs)
            msgs.append(m)
            send_arg[(t, j)] = mid
            sends_to.setdefault(op.dst, []).append(mid)
            dst_sources.setdefault(op.dst, set()).add(op.endpoint)
    n_msgs = len(msgs)
    multi = sorted(ep for ep, srcs in dst_sources.items()
                   if len(srcs) > 1)
    if multi:
        raise UnsupportedByEngine(
            f"endpoints {multi} receive from multiple source "
            f"endpoints: receive matching would depend on the engine "
            f"schedule")
    multi_peer = sorted(k[1:] for k, ts in peer_producers.items()
                        if len(ts) > 1)
    if multi_peer:
        raise UnsupportedByEngine(
            f"host pairs {multi_peer} carry cross-host sends from "
            f"multiple producer tasks: peer-channel FIFO order would "
            f"depend on the engine schedule")
    recv_arg: Dict[Tuple[int, int], int] = {}
    recv_count: Dict[str, int] = {}
    for t, ops in enumerate(tapes):
        for j, op in enumerate(ops):
            if not isinstance(op, VecRecv):
                continue
            k = recv_count.get(op.endpoint, 0)
            recv_count[op.endpoint] = k + 1
            matched = sends_to.get(op.endpoint, [])
            # unmatched -> the never-sent sentinel row (blocks forever)
            recv_arg[(t, j)] = matched[k] if k < len(matched) else n_msgs

    # DegradeLink hooks -> per-message (from_vtime, extra) pairs
    # (sender-side stage-1 only, exactly like Hub.route's hook pass)
    fabric_eps: Dict[str, List[str]] = {f.name: [] for f in fabrics}
    for _, p in programs:
        for es in p.endpoints:
            fabric_eps[es.fabric].append(es.name)
    for inj in sim.scenario.injections:
        if not isinstance(inj, DegradeLink):
            continue
        if (inj.fabric is None) == (inj.hosts is None):
            raise ValueError("DegradeLink needs exactly one of "
                             "fabric= or hosts=")
        if inj.fabric is not None:
            fab = fabric_by_name.get(inj.fabric)
            if fab is None:
                raise ValueError(f"unknown fabric {inj.fabric!r}")
            members = set(fabric_eps[inj.fabric])
            extra = inj.extra_ns + int(
                (inj.latency_factor - 1.0) * fab.link.latency_ns)

            def match(m: _Msg) -> bool:
                return m.src_ep in members and m.dst_ep in members
        else:
            a, b = inj.hosts
            pair_link = topo.host_link(a, b)
            extra = inj.extra_ns + int(
                (inj.latency_factor - 1.0) * pair_link.latency_ns)

            def match(m: _Msg, a=a, b=b) -> bool:
                return {m.src_host, m.dst_host} == {a, b}
        if extra < 0:
            raise ValueError("DegradeLink may only add latency "
                             "(conservative lookahead)")
        for m in msgs:
            if match(m):
                m.extras.append((inj.from_vtime, extra))

    # fail points: at_compute -> tape index of the k-th (0-based)
    # compute op; at_vtime -> checked at every op boundary
    fail_pc = [None] * n_tasks
    fail_vt = [None] * n_tasks
    for i, name in enumerate(task_names[:n_programs]):
        f = fails.get(name)
        if f is None:
            continue
        if f.at_vtime is not None:
            fail_vt[i] = f.at_vtime
        if f.at_compute is not None:
            k = 0
            for j, op in enumerate(tapes[i]):
                if isinstance(op, VecCompute):
                    if k == f.at_compute:
                        fail_pc[i] = j
                        break
                    k += 1

    # scopes (loads never join)
    name_idx = {n: i for i, n in enumerate(task_names[:n_programs])}
    scope_members: List[List[int]] = []
    scope_skews: List[int] = []
    names_by_wl: Dict[int, List[str]] = {}
    for wl, prog in programs:
        names_by_wl.setdefault(id(wl), []).append(prog.name)
    for wl in sim.workloads:
        wl_names = names_by_wl.get(id(wl), [])
        for ss in wl.scopes():
            members = [name_idx[m]
                       for m in (ss.members or tuple(wl_names))]
            scope_members.append(members)
            scope_skews.append(ss.skew_bound_ns)

    return dict(tapes=tapes, markers=markers, task_names=task_names,
                task_hosts=task_hosts, n_programs=n_programs,
                msgs=msgs, n_channels=len(channels),
                send_arg=send_arg, recv_arg=recv_arg,
                scope_members=scope_members, scope_skews=scope_skews,
                fail_pc=fail_pc, fail_vt=fail_vt,
                hub_base=fabrics[0].name if fabrics else "hub",
                n_hosts=topo.n_hosts, scenario_name=sim.scenario.name)


def _quantities(low: Dict[str, Any]) -> List[int]:
    """Every additive ns quantity of the lowered scenario (each appears
    at most once on any event time's max-plus dependency path)."""
    qs: List[int] = []
    for ops in low["tapes"]:
        qs.extend(op.ns for op in ops if isinstance(op, VecCompute))
    for m in low["msgs"]:
        qs.append(SEND_OVERHEAD_NS)
        qs.extend((m.ser1, m.lat1))
        if m.two_stage:
            qs.extend((m.ser2, m.lat2))
        qs.extend(e for _, e in m.extras)
    return qs


# ---------------------------------------------------------------------------
# quantization: ns -> ticks
# ---------------------------------------------------------------------------


def _quantize(low: Dict[str, Any],
              tick_ns: Optional[int]) -> CompiledSim:
    qs = _quantities(low)
    pos = [q for q in qs if q > 0]
    if tick_ns is None:
        tick = math.gcd(*pos) if pos else 1
    else:
        if tick_ns < 1:
            raise ValueError(f"tick_ns must be >= 1, got {tick_ns}")
        tick = int(tick_ns)
    # conservative horizon bound: any event time is a max-plus path sum
    # over distinct additive quantities <= their total sum
    total_ns = sum(q for q in pos)
    bound_ticks = total_ns // tick + len(qs) + 1
    if bound_ticks >= _INF:
        raise ej.TickRangeError(
            f"scenario horizon bound {total_ns} ns = {bound_ticks} "
            f"ticks at tick_ns={tick} >= 2**30 — exceeds the int32 "
            f"tick range; pass a coarser tick_ns= (tolerance tier) or "
            f"shrink the scenario")
    exact = all(q % tick == 0 for q in pos)
    tier = "exact" if exact else "tolerance"
    tol = 0 if exact else tick * len(qs)

    def q_add(x: int) -> int:           # additive quantity: round-half
        return (int(x) + tick // 2) // tick

    def q_ceil(x: int) -> int:          # threshold: exact under >= cmp
        return min(-(-int(x) // tick), _INF)

    tapes, msgs = low["tapes"], low["msgs"]
    n = len(tapes)
    p = max(1, max((len(t) for t in tapes), default=0))
    op_kind = np.zeros((n, p), np.int32)
    op_arg = np.zeros((n, p), np.int32)
    n_ops = np.zeros(n, np.int32)
    for i, ops in enumerate(tapes):
        n_ops[i] = len(ops)
        for j, op in enumerate(ops):
            if isinstance(op, VecCompute):
                op_kind[i, j] = ej.OP_COMPUTE
                op_arg[i, j] = q_add(op.ns)
            elif isinstance(op, VecSend):
                op_kind[i, j] = ej.OP_SEND
                op_arg[i, j] = low["send_arg"][(i, j)]
            else:
                op_kind[i, j] = ej.OP_RECV
                op_arg[i, j] = low["recv_arg"][(i, j)]
    fail_pc = np.full(n, _INF, np.int32)
    fail_vt = np.full(n, _INF, np.int32)
    for i in range(n):
        if low["fail_pc"][i] is not None:
            fail_pc[i] = low["fail_pc"][i]
        if low["fail_vt"][i] is not None:
            fail_vt[i] = q_ceil(low["fail_vt"][i])
    s = len(low["scope_members"])
    membership = np.zeros((n, s), bool)
    skew = np.zeros(s, np.int32)
    for j, members in enumerate(low["scope_members"]):
        membership[members, j] = True
        skew[j] = min(low["scope_skews"][j] // tick, _INF - 1)
    m = len(msgs)
    d = max((len(msg.extras) for msg in msgs), default=0)
    ch1 = np.zeros(m, np.int32)
    ser1 = np.zeros(m, np.int32)
    lat1 = np.zeros(m, np.int32)
    two = np.zeros(m, bool)
    ch2 = np.zeros(m, np.int32)
    ser2 = np.zeros(m, np.int32)
    lat2 = np.zeros(m, np.int32)
    extra = np.zeros((m, d), np.int32)
    extra_from = np.zeros((m, d), np.int32)
    for i, msg in enumerate(msgs):
        ch1[i], ser1[i], lat1[i] = msg.ch1, q_add(msg.ser1), \
            q_add(msg.lat1)
        two[i] = msg.two_stage
        ch2[i], ser2[i], lat2[i] = msg.ch2, q_add(msg.ser2), \
            q_add(msg.lat2)
        for k, (frm, ext) in enumerate(msg.extras):
            extra_from[i, k] = q_ceil(frm)
            extra[i, k] = q_add(ext)
    import jax.numpy as jnp
    tape = ej.VecTape(
        op_kind=jnp.asarray(op_kind), op_arg=jnp.asarray(op_arg),
        n_ops=jnp.asarray(n_ops), fail_pc=jnp.asarray(fail_pc),
        fail_vtime=jnp.asarray(fail_vt),
        membership=jnp.asarray(membership), skew=jnp.asarray(skew),
        send_overhead=jnp.int32(q_add(SEND_OVERHEAD_NS)),
        msg_ch1=jnp.asarray(ch1), msg_ser1=jnp.asarray(ser1),
        msg_lat1=jnp.asarray(lat1), msg_two_stage=jnp.asarray(two),
        msg_ch2=jnp.asarray(ch2), msg_ser2=jnp.asarray(ser2),
        msg_lat2=jnp.asarray(lat2), msg_extra=jnp.asarray(extra),
        msg_extra_from=jnp.asarray(extra_from))
    total_ops = int(n_ops.sum())
    return CompiledSim(
        tape=tape, n_channels=low["n_channels"],
        tick_ns=tick, tier=tier, tol_ns=tol,
        max_rounds=total_ops + n + 3,
        n_tasks=n, n_programs=low["n_programs"],
        task_names=low["task_names"], task_hosts=low["task_hosts"],
        markers=low["markers"], msgs=msgs, hub_base=low["hub_base"],
        n_hosts=low["n_hosts"], scenario_name=low["scenario_name"],
        quantities=qs)


def compile_simulation(sim, tick_ns: Optional[int] = None) -> CompiledSim:
    """Lower + quantize ``sim`` for the vectorized engine.  Raises
    :class:`UnsupportedByEngine` for inadmissible scenarios and
    :class:`~repro.core.engine_jax.TickRangeError` when the horizon
    bound exceeds the int32 tick range at the chosen tick."""
    return _quantize(_lower(sim), tick_ns)


# ---------------------------------------------------------------------------
# batched hub fan-out (kernels/hub_route with the jnp scan as oracle)
# ---------------------------------------------------------------------------


def _batched_visibility(comp: CompiledSim, sent: np.ndarray,
                        sent_vt: np.ndarray,
                        pallas: str) -> Optional[np.ndarray]:
    """Recompute every message's final visibility (ticks) with the
    batched segmented-scan fan-out pass — ``kernels.hub_route`` on the
    Pallas paths, the jnp associative scan otherwise.  Serialization
    durations come from the tick-quantized tape via the kernels'
    ``ser_ns=`` integer bypass (the float32 size*1e9/bw path only
    carries 24 mantissa bits), so the result is bit-equal to the round
    loop's incremental visibilities for every *sent* message (unsent
    messages form a per-channel suffix; their rows are garbage and
    masked by the caller).  Returns None when there are no messages."""
    import jax.numpy as jnp

    msgs = comp.msgs
    m = len(msgs)
    if m == 0:
        return None
    tape = comp.tape
    c = max(comp.n_channels, 1)
    ser1 = np.asarray(tape.msg_ser1)
    lat1_t = np.zeros(c, np.int32)
    lat2_t = np.zeros(c, np.int32)
    lat1_m = np.asarray(tape.msg_lat1)
    lat2_m = np.asarray(tape.msg_lat2)
    ch1 = np.asarray(tape.msg_ch1)
    ch2 = np.asarray(tape.msg_ch2)
    lat1_t[ch1] = lat1_m
    two = np.asarray(tape.msg_two_stage)
    lat2_t[ch2[two]] = lat2_m[two]
    extra = np.sum(
        np.where(sent_vt[:m, None] >= np.asarray(tape.msg_extra_from),
                 np.asarray(tape.msg_extra), 0),
        axis=1).astype(np.int64) if np.asarray(tape.msg_extra).size \
        else np.zeros(m, np.int64)
    bw = np.ones(c, np.float32)        # unused: ser_ns bypass
    use_pallas = pallas in ("on", "interpret")

    def fanout(send, ser, link_id, lat_t):
        if use_pallas:
            from repro.kernels.hub_route import hub_route
            out = hub_route(jnp.asarray(send, jnp.int32),
                            jnp.asarray(ser, jnp.int32),
                            jnp.asarray(link_id, jnp.int32),
                            jnp.asarray(bw),
                            jnp.asarray(lat_t, jnp.int32),
                            ser_ns=jnp.asarray(ser, jnp.int32),
                            interpret=pallas == "interpret")
        else:
            out = ej.hub_visibility(jnp.asarray(send, jnp.int32),
                                    jnp.asarray(ser, jnp.int32),
                                    jnp.asarray(link_id, jnp.int32),
                                    jnp.asarray(bw),
                                    jnp.asarray(lat_t, jnp.int32),
                                    ser_ns=jnp.asarray(ser, jnp.int32))
        return np.asarray(out, np.int64)

    # stage 1: all messages, per-channel program order (= array order
    # per channel; lexsort keeps it within each channel)
    o1 = np.lexsort((np.arange(m), ch1))
    end1 = np.empty(m, np.int64)
    end1[o1] = fanout(sent_vt[:m][o1], ser1[o1], ch1[o1], lat1_t) \
        - lat1_t[ch1[o1]]
    vis = end1 + lat1_m + extra
    # stage 2: cross-host messages only, keyed by their dest channel
    xi = np.flatnonzero(two)
    if xi.size:
        o2 = xi[np.argsort(ch2[xi], kind="stable")]
        vis2 = fanout(vis[o2], np.asarray(tape.msg_ser2)[o2], ch2[o2],
                      lat2_t)
        out = vis.copy()
        out[o2] = vis2
        vis = out
    return vis


# ---------------------------------------------------------------------------
# run + decompile
# ---------------------------------------------------------------------------


def _resolve_pallas(pallas: str) -> Tuple[bool, bool]:
    import jax
    if pallas not in ("auto", "on", "off", "interpret"):
        raise ValueError(f"pallas must be auto/on/off/interpret, "
                         f"got {pallas!r}")
    if pallas == "auto":
        pallas = "on" if jax.default_backend() == "tpu" else "off"
    return pallas != "off", pallas == "interpret"


def _decompile(sim, comp: CompiledSim, st, wall: float, *,
               pallas: str, verify: bool) -> SimReport:
    tick = comp.tick_ns
    vtime = np.asarray(st.vtime, np.int64)
    pc = np.asarray(st.pc)
    done = np.asarray(st.done)
    sent = np.asarray(st.sent)[:len(comp.msgs)]
    sent_vt = np.asarray(st.sent_vt, np.int64)
    vis_loop = np.asarray(st.vis, np.int64)[:len(comp.msgs)]
    rounds = int(st.rounds)

    bvis = _batched_visibility(comp, sent, sent_vt, pallas)
    if bvis is not None:
        vis = np.where(sent, bvis, vis_loop)
        if verify and sent.any() and \
                not np.array_equal(vis[sent], vis_loop[sent]):
            raise RuntimeError(
                "vectorized engine: batched hub fan-out disagrees "
                "with the round loop's visibilities")
    else:
        vis = vis_loop

    status, detail = "ok", ""
    if not done.all():
        blocked = [comp.task_names[i] for i in np.flatnonzero(~done)]
        status = "deadlock"
        detail = (f"vectorized fixpoint: no task eligible; blocked: "
                  f"{blocked}")

    tasks = {}
    for i in range(comp.n_programs):
        tasks[comp.task_names[i]] = {
            "vtime": int(vtime[i]) * tick,
            "state": "done" if done[i] else "blocked",
            "host": comp.task_hosts[i]}

    progress: Dict[str, Any] = {}
    arrays = [{k: np.zeros_like(v) for k, v in wl.progress().items()}
              for wl in sim.workloads]
    for i in range(comp.n_programs):
        for op_idx, wi, arr, index, value in comp.markers[i]:
            if pc[i] >= op_idx:
                arrays[wi][arr][index] = value
    for wl, arrs in zip(sim.workloads, arrays):
        progress[wl.name] = _jsonable(arrs)

    msgs_total = int(sent.sum())
    bytes_total = sum(m.size for m, s in zip(comp.msgs, sent) if s)
    links: Dict[str, Dict[str, Any]] = {}
    cross = 0
    for i, m in enumerate(comp.msgs):
        if not sent[i] or not m.two_stage:
            continue
        cross += 1
        key = (f"{comp.hub_base}{m.src_host}->"
               f"{comp.hub_base}{m.dst_host}")
        st_ = links.setdefault(key, {"messages": 0, "bytes": 0,
                                     "min_slack_ns": None,
                                     "max_visibility_ns": 0})
        st_["messages"] += 1
        st_["bytes"] += m.size
        slack = int(vis[i]) * tick - int(sent_vt[i]) * tick - m.lat1
        st_["min_slack_ns"] = (slack if st_["min_slack_ns"] is None
                               else min(st_["min_slack_ns"], slack))
        st_["max_visibility_ns"] = max(st_["max_visibility_ns"],
                                       int(vis[i]) * tick)

    host_disp = [0] * comp.n_hosts
    for i in range(comp.n_tasks):
        host_disp[comp.task_hosts[i]] += int(pc[i])
    hosts = [HostReport(host=h, dispatches=host_disp[h], rounds=rounds,
                        skew_stalls=0, max_skew_seen=0,
                        gate_deferrals=0, window_runs=0, preemptions=0,
                        live_calls=0)
             for h in range(comp.n_hosts)]

    horizon = int(vtime.max(initial=0)) * tick
    return SimReport(
        status=status, mode="vectorized", n_hosts=comp.n_hosts,
        vtime_ns=horizon, wall_s=wall, messages=msgs_total,
        bytes=bytes_total, sync_rounds=rounds, proxy_syncs=0,
        cross_host_msgs=cross, max_proxy_staleness_ns=0,
        max_window_ns=0, hosts=hosts, links=links, tasks=tasks,
        progress=progress, scenario=comp.scenario_name, detail=detail,
        cells={}, tick_ns=tick, tier=comp.tier)


def run_vectorized_sim(sim, *, tick_ns: Optional[int] = None,
                       pallas: str = "auto",
                       max_rounds: Optional[int] = None,
                       verify: bool = False) -> SimReport:
    """Compile ``sim``, run the jitted round loop, decompile the
    resulting arrays to a :class:`SimReport` (``mode="vectorized"``)."""
    import jax
    use_pallas, interpret = _resolve_pallas(pallas)
    t0 = time.perf_counter()
    comp = compile_simulation(sim, tick_ns)
    cap = comp.max_rounds if max_rounds is None else max_rounds
    st0 = ej.init_vec_sim_state(comp.tape, comp.n_channels)
    st = ej.run_vec_tape(comp.tape, st0, cap, pallas=use_pallas,
                         interpret=interpret)
    jax.block_until_ready(st.vtime)
    wall = time.perf_counter() - t0
    if bool(st.progressed) and not bool(np.asarray(st.done).all()):
        raise RuntimeError(
            f"vectorized engine: max_rounds={cap} exhausted before "
            f"the fixpoint")
    return _decompile(sim, comp, st, wall,
                      pallas=("interpret" if interpret
                              else "on" if use_pallas else "off"),
                      verify=verify)


# ---------------------------------------------------------------------------
# batched configuration sweep (jax.vmap over scenario variants)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class SweepResult:
    """One compiled dispatch over V scenario variants."""
    reports: List[SimReport]
    wall_s: float
    configs_per_s: float
    tick_ns: int
    tier: str


def sweep_vectorized(sim, axis: List[Scenario], *,
                     tick_ns: Optional[int] = None,
                     max_rounds: Optional[int] = None) -> SweepResult:
    """Run one vectorized simulation per :class:`Scenario` in ``axis``
    as a single ``jax.vmap`` batch (shared compiled round loop, stacked
    tapes).  Variants must share scenario *structure* (same tapes,
    messages, channels — injections may change durations, fail points,
    degrade extras); a shared tick (gcd across variants) keeps every
    admissible variant on the exact tier.  Each returned report is
    bit-identical to running its variant alone (asserted in tests)."""
    import jax
    import jax.numpy as jnp

    if not axis:
        raise ValueError("sweep needs at least one Scenario")
    from repro.sim.simulation import Simulation
    variants = [
        Simulation(sim.topology, sim.workloads, sc,
                   placement=sim.placement_spec, mode=sim.mode,
                   capacity=sim.capacity, cpu_resource=sim.cpu_resource,
                   cells=sim.cells_mode)
        for sc in axis]
    lows = [_lower(v) for v in variants]
    if tick_ns is None:
        pos = [q for low in lows for q in _quantities(low) if q > 0]
        tick_ns = math.gcd(*pos) if pos else 1
    comps = [_quantize(low, tick_ns) for low in lows]
    base = comps[0]
    shapes = [jax.tree_util.tree_map(lambda x: jnp.shape(x), c.tape)
              for c in comps]
    if any(sh != shapes[0] for sh in shapes[1:]):
        raise UnsupportedByEngine(
            "sweep variants must share scenario structure (same "
            "tapes/messages/channels); only injection values may vary")
    tapes = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs),
                                   *[c.tape for c in comps])
    states = jax.tree_util.tree_map(
        lambda *xs: jnp.stack(xs),
        *[ej.init_vec_sim_state(c.tape, base.n_channels)
          for c in comps])
    cap = (max(c.max_rounds for c in comps)
           if max_rounds is None else max_rounds)
    t0 = time.perf_counter()
    out = ej.run_vec_tape_batch(tapes, states, cap)
    jax.block_until_ready(out.vtime)
    wall = time.perf_counter() - t0
    reports = []
    for v, comp in enumerate(comps):
        st_v = jax.tree_util.tree_map(lambda x: x[v], out)
        if bool(st_v.progressed) and \
                not bool(np.asarray(st_v.done).all()):
            raise RuntimeError(
                f"vectorized sweep variant {v}: max_rounds={cap} "
                f"exhausted before the fixpoint")
        reports.append(_decompile(variants[v], comp, st_v,
                                  wall / len(comps), pallas="off",
                                  verify=False))
    return SweepResult(reports=reports, wall_s=wall,
                       configs_per_s=len(comps) / wall if wall > 0
                       else float("inf"),
                       tick_ns=tick_ns, tier=base.tier)
