"""Live-execution workloads: the real stack under simulated time.

The paper's headline claim is full-stack fidelity — the *unmodified*
production stack executes live while virtual time stays shared and
deterministic.  This module is that subsystem for the facade:

* :class:`LiveProgram` — wrap any named real step callables in
  cost-derived :class:`~repro.core.vtask.LiveCall`\\ s.  Each simulated
  step, the :class:`~repro.live.CostLedger` either *records* the real
  call's wall span (scaled by the clock calibration, clamped to >= 1
  ns) or *replays* the pinned cost from a versioned JSON trace, so a
  recorded live scenario passes the cross-engine equivalence bar
  bit-identically (single/barrier/async/dist; the vectorized engine
  keeps raising ``UnsupportedByEngine`` — real callables have no array
  form).  Programs are cell-bindable, so live steps pick up §3.3
  memory-interference charges like any other live vtask.
* :class:`LiveTrainerRecovery` + :class:`TrainerStack` — the marquee
  scenario: a real sharded :class:`~repro.runtime.trainer.Trainer`
  driven step-by-step under simulated time; a scenario ``FailHost``
  kills one shard-anchor host, the driver detects it (routed through
  the real :class:`~repro.runtime.failures.FailureInjector` /
  ``SimulatedHostFailure`` machinery), restores the last committed
  checkpoint via the real :class:`~repro.checkpoint.CheckpointManager`,
  elastically re-meshes (rebuild + re-jit + re-shard), and resumes —
  emitting a recovery timeline (detect → restore → re-mesh → resumed
  vtimes) into ``SimReport.live``.
* :func:`live_recovery_sim` / :func:`record_live_recovery` — the
  canned marquee scenario builder (scenario parameters travel inside
  the trace's ``meta`` so a replay reconstructs exactly the recorded
  run) and its one-shot recorder.
* :class:`ServeStack` + :func:`live_serve_sim` /
  :func:`record_live_serve` — the serve half: the real
  :class:`~repro.serve.loop.BatchServer` prefill/decode steps driven as
  a :class:`~repro.sim.workloads.LiveServe` workload under open-loop
  arrivals, reporting simulated time-in-system percentiles.
* :func:`live_colocated_sim` / :func:`record_live_colocated` —
  live-on-live interference: a real trainer and a real server sharing
  one §3.3 memory-hierarchy cell (and one multi-driver ledger — the
  recorder's sequential-span guard keeps their wall spans honest).
* :func:`check_dist_live` — facade guard for ``engine="dist"``: record
  mode is rejected (forked workers cannot produce one coherent trace)
  and every live fn must pickle — an unpicklable callable is a
  reliable proxy for fork-unsafe captured state, and the facade error
  names the fn instead of surfacing a worker crash traceback.

Determinism: replayed costs are integers fed through the scheduler's
cost-derived LiveCall path; every control-flow decision in the bodies
below depends only on step indices and task vtimes, which replay
re-derives exactly from the pinned costs (see ``repro.live.recorder``).
"""
from __future__ import annotations

import contextlib
from typing import Any, Callable, Dict, List, Optional, Sequence

import numpy as np

from repro.core.ipc import LinkSpec
from repro.core.vtask import Compute, LiveCall, Recv, Send
from repro.live import CostLedger
from repro.runtime.failures import FailureInjector, SimulatedHostFailure
from repro.sim.scenario import FailHost, Scenario, TaskHandle
from repro.sim.simulation import Simulation
from repro.sim.topology import FabricSpec, Topology
from repro.sim.workload import EndpointSpec, Program, Workload
from repro.sim.workloads import LiveServe, poisson_arrivals


def _noop(*_args) -> None:
    """Fork-safe stand-in executed by replayed LiveCalls (the pinned
    cost carries the timing; the call just has to be real)."""
    return None


# ---------------------------------------------------------------------------
# generic live workload
# ---------------------------------------------------------------------------


class LiveProgram(Workload):
    """Named real step callables under simulated time.

    ``fns`` maps program name -> callable invoked as ``fn(step)`` each
    simulated step (record mode only; replay never calls it).  With
    ``ring_bytes > 0`` the programs additionally exchange a message
    ring per step, so multi-host placements exercise the transport.
    """

    def __init__(self, fns: Dict[str, Callable], n_steps: int, *,
                 ledger: CostLedger, name: str = "live",
                 ring_bytes: int = 0,
                 link: LinkSpec = LinkSpec(bandwidth_bps=25e9 * 8,
                                           latency_ns=10_000),
                 cells: Optional[Dict[str, str]] = None,
                 skew_bound_ns: int = 0):
        if not fns:
            raise ValueError("LiveProgram needs at least one fn")
        self.fns = dict(fns)
        self.n_steps = n_steps
        self.ledger = ledger
        self.name = name
        self.ring_bytes = ring_bytes
        self.link = link
        self.cells = cells or {}
        self.skew_bound_ns = skew_bound_ns
        self.order = list(self.fns)
        self.steps_done = np.zeros(len(self.order), dtype=np.int64)

    def _ring(self) -> bool:
        return self.ring_bytes > 0 and len(self.order) > 1

    def fabrics(self) -> List[FabricSpec]:
        if self._ring():
            return [FabricSpec(f"{self.name}.hub", self.link)]
        return []

    def _body_factory(self, i: int):
        task = self.order[i]
        fn = self.fns[task]
        right = self.order[(i + 1) % len(self.order)]

        def make_body(eps):
            ep = eps.get(task)

            def body():
                for step in range(self.n_steps):
                    _, cost = self.ledger.charge(task, f"step:{step}",
                                                 fn, (step,))
                    yield LiveCall(_noop, cost_ns=cost,
                                   label=f"step:{step}")
                    if ep is not None:
                        yield Send(ep, right, self.ring_bytes)
                        yield Recv(ep)
                    self.steps_done[i] = step + 1
            return body()
        return make_body

    def programs(self) -> List[Program]:
        ring = self._ring()
        return [Program(
            name=t, make_body=self._body_factory(i),
            endpoints=(EndpointSpec(t, f"{self.name}.hub"),) if ring
            else (),
            kind="live", cell=self.cells.get(t))
            for i, t in enumerate(self.order)]

    def traffic(self):
        if not self._ring():
            return {}
        per = float(self.ring_bytes) * self.n_steps
        return {(t, self.order[(i + 1) % len(self.order)]): per
                for i, t in enumerate(self.order)}

    def scopes(self):
        from repro.sim.workload import ScopeSpec
        if self.skew_bound_ns > 0:
            return [ScopeSpec(self.name, self.skew_bound_ns)]
        return []

    def progress(self):
        return {"steps_done": self.steps_done}

    def reset(self) -> None:
        self.steps_done[:] = 0
        if self.ledger.mode == "replay":
            self.ledger.rewind()
        elif any(self.ledger.tasks.get(t) for t in self.order):
            raise ValueError(
                f"record ledger already holds costs for "
                f"{sorted(t for t in self.order if self.ledger.tasks.get(t))} "
                f"— one record run per ledger; save the trace and "
                f"replay it, or record with a fresh ledger")

    # live hooks
    def live_mode(self):
        return self.ledger.mode

    def live_fns(self):
        return dict(self.fns)

    def live_report(self, tasks: Optional[set] = None):
        return {"mode": self.ledger.mode,
                "calibration": self.ledger.calibration, "tasks": {}}


# ---------------------------------------------------------------------------
# marquee scenario: real trainer + FailHost + checkpoint re-mesh
# ---------------------------------------------------------------------------


class TrainerStack:
    """Record-mode binding of the seed's real runtime/checkpoint layers
    to the live recovery driver's phases.  All JAX imports are lazy so
    the module stays importable from forked dist workers (which never
    touch this class — replay mode passes ``stack=None``)."""

    def __init__(self, *, arch: str = "qwen3_4b", n_steps: int = 8,
                 seq_len: int = 32, global_batch: int = 4,
                 mesh_shape: Sequence[int] = (2, 1),
                 remesh_shape: Sequence[int] = (1, 1),
                 checkpoint_dir: Optional[str] = None, seed: int = 0):
        self.arch = arch
        self.n_steps = n_steps
        self.seq_len = seq_len
        self.global_batch = global_batch
        self.mesh_shape = tuple(mesh_shape)
        self.remesh_shape = tuple(remesh_shape)
        self.checkpoint_dir = checkpoint_dir
        self.seed = seed
        self.trainer = None
        self.params = self.opt = None
        self._ctx = contextlib.ExitStack()

    def _mesh(self, shape):
        import jax
        from repro.launch.mesh import make_test_mesh
        data, model = shape
        ndev = len(jax.devices())
        data = max(1, min(int(data), ndev // max(1, int(model))))
        return make_test_mesh(data=data, model=int(model))

    def setup(self) -> None:
        if self.trainer is not None:
            return
        import dataclasses
        import tempfile

        import jax.numpy as jnp

        from repro import configs
        from repro.parallel import ctx as pctx
        from repro.runtime.trainer import Trainer, TrainerConfig
        cfg = dataclasses.replace(configs.get_smoke(self.arch),
                                  remat=False)
        ckpt_dir = self.checkpoint_dir or tempfile.mkdtemp(
            prefix="repro_live_ckpt_")
        tcfg = TrainerConfig(
            n_steps=self.n_steps, seq_len=self.seq_len,
            global_batch=self.global_batch,
            # the live driver controls checkpoint cadence itself
            checkpoint_every=10 ** 9, checkpoint_dir=ckpt_dir,
            checkpoint_async=False, log_every=10 ** 9, seed=self.seed)
        mesh = self._mesh(self.mesh_shape)
        self.trainer = Trainer(cfg, tcfg, mesh=mesh,
                               injector=FailureInjector(),
                               log_fn=lambda _s: None)
        self._ctx.enter_context(pctx.use_mesh(mesh))
        self.params, self.opt = self.trainer.init_state()
        # warm the jit so recorded step costs are steady-state, not
        # compile time (an unrecorded step 0 on synthetic data)
        self.params, self.opt, _ = self.trainer.step(
            self.params, self.opt, jnp.int32(0),
            self.trainer.data.batch(0))

    def step(self, step: int) -> None:
        import jax
        import jax.numpy as jnp
        self.params, self.opt, metrics = self.trainer.step(
            self.params, self.opt, jnp.int32(step),
            self.trainer.data.batch(step))
        jax.block_until_ready(metrics["loss"])

    def save(self, step: int) -> None:
        self.trainer.ckpt.save({"params": self.params, "opt": self.opt},
                               step, blocking=True)

    def restore(self) -> int:
        self.params, self.opt, step = self.trainer._recover()
        return step

    def remesh(self) -> None:
        """Elastic re-mesh after the simulated host loss: rebuild the
        device mesh at the (smaller) post-failure shape, re-jit the
        train step, and re-shard the restored state onto it."""
        import jax

        from repro.parallel import ctx as pctx
        mesh = self._mesh(self.remesh_shape)
        self.trainer.mesh = mesh
        self.trainer._build()
        if self.trainer.p_sh is not None:
            self.params = jax.device_put(self.params, self.trainer.p_sh)
            self.opt = jax.device_put(self.opt, self.trainer.o_sh)
        self._ctx.close()
        self._ctx = contextlib.ExitStack()
        self._ctx.enter_context(pctx.use_mesh(mesh))

    def close(self) -> None:
        if self.trainer is not None:
            self.trainer.ckpt.wait()
        self._ctx.close()


class LiveTrainerRecovery(Workload):
    """The marquee live scenario as a workload.

    Programs (in vtask order): ``live.trainer`` — the live driver on
    host 0, running the real (or replayed) train steps; ``live.shard1..
    N`` — modeled shard anchors, one per worker host, representing the
    trainer's presence there (a scenario ``FailHost`` kills the anchor
    and, via ``Program.on_fail``, arms the driver's detection at the
    failure vtime); ``live.store`` — a modeled checkpoint store the
    driver saves to / restores from over the interconnect.

    The driver's recovery path goes through the *real* runtime
    machinery in both modes: a :class:`FailureInjector` armed at the
    detected step raises :class:`SimulatedHostFailure`, and the handler
    restores + re-meshes (real calls in record mode, replayed costs
    otherwise), appending ``{event, step, vtime}`` records that surface
    as the ``SimReport.live`` recovery timeline.
    """

    name = "live_train"
    DRIVER = "live.trainer"
    STORE = "live.store"

    def __init__(self, *, ledger: CostLedger,
                 stack: Optional[TrainerStack] = None,
                 n_steps: int = 8, checkpoint_every: int = 3,
                 n_shards: int = 2, detection_ns: int = 2_000_000,
                 ckpt_bytes: int = 4_000_000, req_bytes: int = 256,
                 ack_bytes: int = 64, store_ns: int = 500_000,
                 beat_ns: int = 1_000_000, n_beats: Optional[int] = None,
                 cell: Optional[str] = None,
                 link: LinkSpec = LinkSpec(bandwidth_bps=25e9 * 8,
                                           latency_ns=10_000)):
        if ledger.mode == "record" and stack is None:
            raise ValueError("record mode needs a real TrainerStack")
        if checkpoint_every < 1 or n_steps < 1:
            raise ValueError("n_steps and checkpoint_every must be >= 1")
        self.ledger = ledger
        self.stack = stack
        self.n_steps = n_steps
        self.checkpoint_every = checkpoint_every
        self.n_shards = n_shards
        self.detection_ns = detection_ns
        self.ckpt_bytes = ckpt_bytes
        self.req_bytes = req_bytes
        self.ack_bytes = ack_bytes
        self.store_ns = store_ns
        self.beat_ns = beat_ns
        self.n_beats = n_beats if n_beats is not None else n_steps * 8
        self.cell = cell
        self.link = link
        self.shards = [f"live.shard{i}" for i in range(1, n_shards + 1)]
        self._handle = TaskHandle()
        self._fail_at: Optional[int] = None   # armed at build by on_fail
        self._timeline: List[dict] = []
        self.restarts = 0
        self.final_step = 0
        self.steps_done = np.zeros(1, dtype=np.int64)
        self.beats = np.zeros(max(1, n_shards), dtype=np.int64)

    # -- build-time failure notice (Program.on_fail) -------------------------
    def _shard_on_fail(self, failspec) -> str:
        """A scenario failure resolved onto a shard anchor: the anchor
        still dies (``"kill"``), and the driver's detection arms at the
        failure vtime — deterministic build-time data, identical in
        every engine and every forked dist replica."""
        at = failspec.at_vtime
        if at is not None:
            self._fail_at = at if self._fail_at is None \
                else min(self._fail_at, at)
        return "kill"

    def _event(self, event: str, step: int, task) -> None:
        self._timeline.append({"event": event, "step": int(step),
                               "vtime": int(task.vtime)})

    # -- bodies --------------------------------------------------------------
    def _driver_factory(self, eps):
        ep = eps["live.tr"]

        def body():
            led, stack = self.ledger, self.stack
            injector = FailureInjector()
            if stack is not None:
                stack.setup()        # cluster warm-up: outside sim time
            task = self._handle.task
            step = last_saved = 0
            fired = resumed_pending = False
            while step < self.n_steps:
                if (self._fail_at is not None and not fired
                        and task.vtime >= self._fail_at):
                    fired = True
                    # the dead shard host is noticed one detection
                    # latency after its failure vtime passed
                    yield Compute(self.detection_ns)
                    # route through the real runtime failure machinery
                    injector.fail_at_steps.add(step)
                    try:
                        injector.check(step)
                    except SimulatedHostFailure:
                        self.restarts += 1
                        self._event("detect", step, task)
                        # fetch the last committed checkpoint from the
                        # store (request out, checkpoint bytes back),
                        # then the real restore + state rebuild
                        yield Send(ep, "live.ckpt", self.req_bytes,
                                   payload=("restore", last_saved))
                        yield Recv(ep)
                        _, cost = led.charge(
                            self.DRIVER, f"restore:{self.restarts}",
                            stack.restore if stack else None)
                        yield LiveCall(_noop, cost_ns=cost,
                                       label="restore")
                        step = last_saved
                        self._event("restore", step, task)
                        # elastic re-mesh: rebuild without the dead host
                        _, cost = led.charge(
                            self.DRIVER, f"remesh:{self.restarts}",
                            stack.remesh if stack else None)
                        yield LiveCall(_noop, cost_ns=cost,
                                       label="remesh")
                        self._event("remesh", step, task)
                        resumed_pending = True
                _, cost = led.charge(self.DRIVER, f"step:{step}",
                                     stack.step if stack else None,
                                     (step,))
                yield LiveCall(_noop, cost_ns=cost, label=f"step:{step}")
                step += 1
                self.steps_done[0] = max(int(self.steps_done[0]), step)
                if resumed_pending:
                    self._event("resumed", step - 1, task)
                    resumed_pending = False
                if step % self.checkpoint_every == 0 \
                        and step < self.n_steps:
                    yield Send(ep, "live.ckpt", self.ckpt_bytes,
                               payload=("save", step))
                    yield Recv(ep)
                    _, cost = led.charge(self.DRIVER, f"save:{step}",
                                         stack.save if stack else None,
                                         (step,))
                    yield LiveCall(_noop, cost_ns=cost,
                                   label=f"save:{step}")
                    last_saved = step
            self.final_step = step
            yield Send(ep, "live.ckpt", 64, payload=("close", None))
            if stack is not None:
                stack.close()
        return body()

    def _store_factory(self, eps):
        sep = eps["live.ckpt"]

        def body():
            while True:
                msg = yield Recv(sep)
                kind = msg.payload[0]
                if kind == "close":
                    return
                yield Compute(self.store_ns)
                size = self.ckpt_bytes if kind == "restore" \
                    else self.ack_bytes
                yield Send(sep, "live.tr", size,
                           payload=("ack", msg.payload[1]))
        return body()

    def _shard_factory(self, i: int):
        def make_body(eps):
            def body():
                for b in range(self.n_beats):
                    yield Compute(self.beat_ns)
                    self.beats[i] = b + 1
            return body()
        return make_body

    # -- workload protocol ---------------------------------------------------
    def fabrics(self) -> List[FabricSpec]:
        return [FabricSpec("livec", self.link)]

    def programs(self) -> List[Program]:
        out = [Program(
            name=self.DRIVER, make_body=self._driver_factory,
            endpoints=(EndpointSpec("live.tr", "livec"),),
            kind="live", cell=self.cell, handle=self._handle)]
        for i, s in enumerate(self.shards):
            out.append(Program(name=s, make_body=self._shard_factory(i),
                               on_fail=self._shard_on_fail))
        out.append(Program(name=self.STORE,
                           make_body=self._store_factory,
                           endpoints=(EndpointSpec("live.ckpt",
                                                   "livec"),)))
        return out

    def default_placement(self) -> Dict[str, int]:
        pl = {self.DRIVER: 0}
        for i, s in enumerate(self.shards):
            pl[s] = i + 1
        pl[self.STORE] = self.n_shards + 1
        return pl

    def traffic(self):
        saves = max(0, self.n_steps // self.checkpoint_every - 1)
        return {(self.DRIVER, self.STORE):
                float(self.ckpt_bytes) * max(1, saves)}

    def progress(self):
        return {"steps_done": self.steps_done, "beats": self.beats}

    def reset(self) -> None:
        self.steps_done[:] = 0
        self.beats[:] = 0
        self._timeline.clear()
        self.restarts = 0
        self.final_step = 0
        self._fail_at = None     # re-armed by on_fail at build time
        if self.ledger.mode == "replay":
            self.ledger.rewind()
        elif self.ledger.tasks.get(self.DRIVER):
            raise ValueError(
                f"record ledger already holds {self.DRIVER!r} costs — "
                f"one record run per ledger; save the trace and replay "
                f"it, or record with a fresh ledger")

    # -- live hooks ----------------------------------------------------------
    def live_mode(self):
        return self.ledger.mode

    def live_fns(self):
        return {self.DRIVER: self.stack.step} if self.stack else {}

    def live_report(self, tasks: Optional[set] = None):
        sec = {"mode": self.ledger.mode,
               "calibration": self.ledger.calibration, "tasks": {}}
        if tasks is None or self.DRIVER in tasks:
            sec["tasks"][self.DRIVER] = {
                "recovery": list(self._timeline),
                "restarts": int(self.restarts),
                "final_step": int(self.final_step)}
        return sec


# ---------------------------------------------------------------------------
# canned marquee scenario + recorder
# ---------------------------------------------------------------------------

#: Scenario parameters of the canned recovery run.  A record run stores
#: the resolved values in the trace's ``meta["recovery"]``; a replay
#: rebuilds the simulation from them, so trace and scenario cannot
#: drift apart silently (and any residual divergence fails fast in the
#: ledger's label check).
RECOVERY_DEFAULTS: Dict[str, Any] = dict(
    n_steps=8, checkpoint_every=3, n_shards=2, fail_host=1,
    fail_at_vtime=600_000_000, detection_ns=2_000_000,
    ckpt_bytes=4_000_000, req_bytes=256, ack_bytes=64,
    store_ns=500_000, beat_ns=1_000_000)

_WL_KEYS = ("n_steps", "checkpoint_every", "n_shards", "detection_ns",
            "ckpt_bytes", "req_bytes", "ack_bytes", "store_ns",
            "beat_ns")

#: Safety margin (in train steps) the recovery recorder adds when it
#: derives ``fail_at_vtime`` from a probe step.  The failure should
#: land *after* the first checkpoint commits (``checkpoint_every``
#: steps) but before the next one — half a step past the commit puts it
#: mid-step on any machine speed, so the replayed restore always
#: resumes from a real committed checkpoint.  Named (rather than a bare
#: ``+ 0.5`` in the formula) and pinned into ``meta["fail_probe"]`` so
#: every derived fail-at vtime in a saved trace is auditable.
FAIL_PROBE_MARGIN_STEPS: float = 0.5


def live_recovery_sim(ledger: CostLedger, *,
                      stack: Optional[TrainerStack] = None,
                      **overrides) -> Simulation:
    """Build the marquee recovery Simulation for ``ledger``'s mode.
    Replay reads the scenario parameters pinned in the trace meta;
    record resolves defaults + overrides and pins them."""
    params = dict(RECOVERY_DEFAULTS)
    if ledger.mode == "replay":
        params.update(ledger.meta.get("recovery", {}))
    unknown = sorted(set(overrides) - set(params))
    if unknown:
        raise ValueError(f"unknown recovery parameters {unknown}; "
                         f"expected {sorted(params)}")
    params.update(overrides)
    if ledger.mode == "record":
        ledger.meta["recovery"] = dict(params)
    wl = LiveTrainerRecovery(ledger=ledger, stack=stack,
                             **{k: params[k] for k in _WL_KEYS})
    n_hosts = params["n_shards"] + 2
    if not 0 <= params["fail_host"] < n_hosts:
        raise ValueError(f"fail_host {params['fail_host']} outside "
                         f"0..{n_hosts - 1}")
    topo = Topology.full_mesh(n_hosts, wl.link, n_cpus=4)
    return Simulation(
        topo, wl,
        Scenario("live recovery",
                 (FailHost(host=params["fail_host"],
                           at_vtime=params["fail_at_vtime"]),)),
        placement=wl.default_placement())


def record_live_recovery(out_path, *, arch: str = "qwen3_4b",
                         seq_len: int = 32, global_batch: int = 4,
                         calibration: float = 1.0,
                         engine: str = "async", **overrides):
    """One-shot recorder for the canned recovery scenario: run the real
    sharded trainer under simulated time, measure every phase, and save
    the trace to ``out_path``.  Returns ``(report, ledger)``.

    The failure vtime (unless overridden) is placed from a probe step:
    a little past the first checkpoint commit, so the restore resumes
    from a real committed checkpoint mid-run on any machine speed."""
    import time as _time
    ledger = CostLedger.record(calibration=calibration)
    params = dict(RECOVERY_DEFAULTS)
    params.update(overrides)
    stack = TrainerStack(arch=arch, n_steps=params["n_steps"],
                         seq_len=seq_len, global_batch=global_batch)
    stack.setup()
    if "fail_at_vtime" not in overrides:
        t0 = _time.perf_counter_ns()
        stack.step(0)
        span = _time.perf_counter_ns() - t0
        steps_to_failure = params["checkpoint_every"] \
            + FAIL_PROBE_MARGIN_STEPS
        params["fail_at_vtime"] = max(1, int(
            span * calibration * steps_to_failure))
        ledger.meta["fail_probe"] = {
            "probe_span_ns": int(span), "calibration": calibration,
            "margin_steps": FAIL_PROBE_MARGIN_STEPS,
            "steps_to_failure": steps_to_failure,
            "fail_at_vtime": params["fail_at_vtime"]}
    sim = live_recovery_sim(ledger, stack=stack, **params)
    report = sim.run(engine=engine)
    ledger.save(out_path)
    return report, ledger


def recovery_timeline(report, *, workload: str = "live_train",
                      task: str = LiveTrainerRecovery.DRIVER
                      ) -> List[dict]:
    """The ``{event, step, vtime}`` recovery records of a run's live
    section (empty when the scenario had no failure)."""
    sec = report.live.get(workload, {})
    return list(sec.get("tasks", {}).get(task, {})
                .get("recovery", []))


# ---------------------------------------------------------------------------
# serve scenario: real BatchServer under open-loop arrivals
# ---------------------------------------------------------------------------


class ServeStack:
    """Record-mode binding of the real :class:`~repro.serve.loop.
    BatchServer` to :class:`~repro.sim.workloads.LiveServe`'s per-wave
    phases.  JAX imports are lazy (same fork-safety reasoning as
    :class:`TrainerStack`; replay passes ``stack=None``).

    The server runs a *static* batch per wave (the BatchServer
    contract): every wave prefill uses the same ``(max_batch,
    prompt_len)`` prompt shape regardless of how many requests the wave
    actually carries, so one compiled program serves every wave and
    recorded costs reflect the static batch the real server would
    execute.  Prompts are deterministic functions of the wave index —
    no RNG stream in the record path."""

    def __init__(self, *, arch: str = "qwen3_4b", max_batch: int = 4,
                 prompt_len: int = 8, decode_steps: int = 4,
                 seed: int = 0):
        if max_batch < 1 or prompt_len < 1 or decode_steps < 1:
            raise ValueError("max_batch, prompt_len and decode_steps "
                             "must be >= 1")
        self.arch = arch
        self.max_batch = max_batch
        self.prompt_len = prompt_len
        self.decode_steps = decode_steps
        self.seed = seed
        self.server = None
        self._tok = self._cache = None

    def _prompts(self, wave: int):
        import jax.numpy as jnp
        vocab = self.server.cfg.vocab
        ids = (np.arange(self.max_batch * self.prompt_len,
                         dtype=np.int64)
               .reshape(self.max_batch, self.prompt_len)
               * 31 + wave * 131 + 7) % max(2, vocab)
        return jnp.asarray(ids, dtype=jnp.int32)

    def setup(self) -> None:
        if self.server is not None:
            return
        import dataclasses

        import jax
        import jax.numpy as jnp

        from repro import configs
        from repro.models import registry
        from repro.serve.loop import BatchServer
        cfg = dataclasses.replace(configs.get_smoke(self.arch),
                                  remat=False)
        params = registry.init(cfg, jax.random.PRNGKey(self.seed))
        self.server = BatchServer(cfg, params,
                                  max_new_tokens=self.decode_steps + 1)
        # warm both jits so recorded per-wave costs are steady-state
        # execution, never compile time
        logits, cache = self.server._prefill(params, self._prompts(0),
                                             None)
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        logits, _ = self.server._decode(params, tok, cache)
        jax.block_until_ready(logits)

    def prefill(self, wave: int, batch: int) -> None:
        import jax
        import jax.numpy as jnp
        logits, self._cache = self.server._prefill(
            self.server.params, self._prompts(wave), None)
        self._tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        jax.block_until_ready(self._tok)

    def decode(self, wave: int, d: int) -> None:
        import jax
        import jax.numpy as jnp
        logits, self._cache = self.server._decode(
            self.server.params, self._tok, self._cache)
        self._tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        jax.block_until_ready(self._tok)

    def close(self) -> None:
        self._tok = self._cache = None


#: Scenario parameters of the canned serve run.  ``arrivals`` is the
#: resolved open-loop schedule: a record run pins the concrete integer
#: list (plus everything else) into ``meta["serve"]``, so a replay
#: reads the exact schedule back and never re-derives it from an RNG
#: stream.  ``mean_gap_ns=None`` means the recorder probes one wave and
#: aims the mean inter-arrival gap at half the wave's service span, so
#: waves genuinely batch up on any machine speed.
SERVE_DEFAULTS: Dict[str, Any] = dict(
    n_requests=12, mean_gap_ns=None, seed=0, arrivals=None,
    max_batch=4, decode_steps=4, req_bytes=512, resp_bytes=2048)


def live_serve_sim(ledger: CostLedger, *,
                   stack: Optional[ServeStack] = None,
                   **overrides) -> Simulation:
    """Build the canned serve Simulation for ``ledger``'s mode: the
    live server on one host, the open-loop source on another.  Replay
    reads the pinned parameters (including the concrete arrival
    schedule) from the trace meta; record resolves defaults + overrides
    and pins them."""
    params = dict(SERVE_DEFAULTS)
    if ledger.mode == "replay":
        params.update(ledger.meta.get("serve", {}))
    unknown = sorted(set(overrides) - set(params))
    if unknown:
        raise ValueError(f"unknown serve parameters {unknown}; "
                         f"expected {sorted(params)}")
    params.update(overrides)
    if params["arrivals"] is None:
        if params["mean_gap_ns"] is None:
            raise ValueError(
                "no arrival schedule: pass arrivals=... (explicit "
                "vtimes) or mean_gap_ns=... (Poisson schedule), or "
                "record via record_live_serve which probes a gap")
        params["arrivals"] = [int(v) for v in poisson_arrivals(
            params["n_requests"], params["mean_gap_ns"],
            seed=params["seed"])]
    params["arrivals"] = [int(v) for v in params["arrivals"]]
    params["n_requests"] = len(params["arrivals"])
    if ledger.mode == "record":
        ledger.meta["serve"] = dict(params)
    wl = LiveServe(ledger=ledger, stack=stack,
                   arrivals=params["arrivals"],
                   max_batch=params["max_batch"],
                   decode_steps=params["decode_steps"],
                   req_bytes=params["req_bytes"],
                   resp_bytes=params["resp_bytes"])
    topo = Topology.full_mesh(2, wl.link, n_cpus=4)
    return Simulation(topo, wl, placement=wl.default_placement())


def record_live_serve(out_path, *, arch: str = "qwen3_4b",
                      prompt_len: int = 8, calibration: float = 1.0,
                      engine: str = "async", **overrides):
    """One-shot recorder for the canned serve scenario: run the real
    BatchServer under simulated time against an open-loop Poisson
    schedule, measure every wave phase, and save the trace to
    ``out_path``.  Returns ``(report, ledger)``.

    Unless ``arrivals``/``mean_gap_ns`` is overridden, the schedule is
    derived from a probe wave (one prefill + ``decode_steps`` decodes):
    the mean gap targets half the wave span, so the open-loop source
    outruns the server and waves batch multiple requests.  The probe
    is pinned into ``meta["serve_probe"]`` for auditability; the
    resolved schedule itself lands in ``meta["serve"]["arrivals"]``."""
    import time as _time
    ledger = CostLedger.record(calibration=calibration)
    params = dict(SERVE_DEFAULTS)
    params.update(overrides)
    stack = ServeStack(arch=arch, max_batch=params["max_batch"],
                       prompt_len=prompt_len,
                       decode_steps=params["decode_steps"])
    stack.setup()
    if params["arrivals"] is None and params["mean_gap_ns"] is None:
        t0 = _time.perf_counter_ns()
        stack.prefill(0, params["max_batch"])
        for d in range(params["decode_steps"]):
            stack.decode(0, d)
        span = _time.perf_counter_ns() - t0
        params["mean_gap_ns"] = max(1, int(span * calibration) // 2)
        ledger.meta["serve_probe"] = {
            "probe_span_ns": int(span), "calibration": calibration,
            "mean_gap_ns": params["mean_gap_ns"]}
    sim = live_serve_sim(ledger, stack=stack, **params)
    report = sim.run(engine=engine)
    ledger.save(out_path)
    return report, ledger


def serve_latency(report, *, workload: str = "live_serve",
                  task: str = LiveServe.SERVER) -> Dict[str, int]:
    """The simulated time-in-system percentiles (p50/p95/p99/max/mean,
    ns) of a run's serve live section (empty if absent)."""
    sec = report.live.get(workload, {})
    return dict(sec.get("tasks", {}).get(task, {})
                .get("latency_ns", {}))


# ---------------------------------------------------------------------------
# co-located live train + live serve on shared §3.3 cells
# ---------------------------------------------------------------------------

#: Scenario parameters of the canned co-located run: a live trainer
#: (no failure injected) and a live server sharing host 0 and one
#: declared memory-hierarchy cell, recorded into ONE multi-driver
#: ledger.  Record pins the resolved dict (including the serve arrival
#: schedule) into ``meta["colocated"]``.
COLOCATED_DEFAULTS: Dict[str, Any] = dict(
    train=dict(n_steps=4, checkpoint_every=2, n_shards=1,
               detection_ns=2_000_000, ckpt_bytes=1_000_000,
               req_bytes=256, ack_bytes=64, store_ns=500_000,
               beat_ns=1_000_000),
    serve=dict(n_requests=8, mean_gap_ns=None, seed=1, arrivals=None,
               max_batch=2, decode_steps=2, req_bytes=512,
               resp_bytes=2048),
    cell=dict(ways=2, working_set_frac=0.7, bw_share=0.3,
              bw_demand=0.7, mem_frac=0.6),
    cell_cfg=dict(n_warm_slots=1, recondition_ns=20_000))

CELL_NAME = "colo"


def live_colocated_sim(ledger: CostLedger, *,
                       train_stack: Optional[TrainerStack] = None,
                       serve_stack: Optional[ServeStack] = None,
                       **overrides) -> Simulation:
    """Build the live-on-live interference Simulation: the recovery
    driver (failure-free here) and the live server both bound to cell
    ``"colo"`` on host 0, so their LiveCalls charge §3.3 co-activity
    slowdowns against each other.  Both workloads share ``ledger`` —
    one trace holds both drivers' costs (``live.trainer`` +
    ``serve.live`` task keys are disjoint)."""
    params = {k: dict(v) for k, v in COLOCATED_DEFAULTS.items()}
    if ledger.mode == "replay":
        for k, v in ledger.meta.get("colocated", {}).items():
            params.setdefault(k, {}).update(v)
    unknown = sorted(set(overrides) - set(params))
    if unknown:
        raise ValueError(f"unknown colocated sections {unknown}; "
                         f"expected {sorted(params)}")
    for k, v in overrides.items():
        bad = sorted(set(v) - set(COLOCATED_DEFAULTS[k]))
        if bad:
            raise ValueError(f"unknown colocated {k} parameters {bad}")
        params[k].update(v)
    sp = params["serve"]
    if sp["arrivals"] is None:
        if sp["mean_gap_ns"] is None:
            raise ValueError(
                "no serve arrival schedule: pass serve={'arrivals': "
                "...} or serve={'mean_gap_ns': ...}, or record via "
                "record_live_colocated which probes a gap")
        sp["arrivals"] = [int(v) for v in poisson_arrivals(
            sp["n_requests"], sp["mean_gap_ns"], seed=sp["seed"])]
    sp["arrivals"] = [int(v) for v in sp["arrivals"]]
    sp["n_requests"] = len(sp["arrivals"])
    if ledger.mode == "record":
        ledger.meta["colocated"] = {k: dict(v)
                                    for k, v in params.items()}
    train = LiveTrainerRecovery(
        ledger=ledger, stack=train_stack, cell=CELL_NAME,
        **{k: params["train"][k] for k in _WL_KEYS})
    serve = LiveServe(
        ledger=ledger, stack=serve_stack, cell=CELL_NAME,
        arrivals=sp["arrivals"], max_batch=sp["max_batch"],
        decode_steps=sp["decode_steps"], req_bytes=sp["req_bytes"],
        resp_bytes=sp["resp_bytes"])
    n_shards = params["train"]["n_shards"]
    n_hosts = n_shards + 3
    topo = Topology.full_mesh(n_hosts, train.link, n_cpus=4)
    topo.cell(CELL_NAME, **params["cell"])
    topo.cell_config(**params["cell_cfg"])
    placement = train.default_placement()      # driver 0, shards,
    placement[serve.SERVER] = 0                # store; server shares
    placement[serve.SOURCE] = n_shards + 2     # the driver's host/cell
    return Simulation(topo, [train, serve], placement=placement)


def record_live_colocated(out_path, *, arch: str = "qwen3_4b",
                          seq_len: int = 32, global_batch: int = 4,
                          prompt_len: int = 8,
                          calibration: float = 1.0,
                          engine: str = "async", **overrides):
    """One-shot recorder for the co-located scenario: real trainer
    steps (single-device mesh, in-process) interleaved with real
    BatchServer waves, both measured into one multi-driver ledger under
    the in-process engines' one-live-call-at-a-time dispatch.  Returns
    ``(report, ledger)``."""
    import time as _time
    ledger = CostLedger.record(calibration=calibration)
    params = {k: dict(v) for k, v in COLOCATED_DEFAULTS.items()}
    for k, v in overrides.items():
        if k not in params:
            raise ValueError(f"unknown colocated section {k!r}")
        params[k].update(v)
    tp, sp = params["train"], params["serve"]
    train_stack = TrainerStack(arch=arch, n_steps=tp["n_steps"],
                               seq_len=seq_len,
                               global_batch=global_batch,
                               mesh_shape=(1, 1))
    serve_stack = ServeStack(arch=arch, max_batch=sp["max_batch"],
                             prompt_len=prompt_len,
                             decode_steps=sp["decode_steps"])
    train_stack.setup()
    serve_stack.setup()
    if sp["arrivals"] is None and sp["mean_gap_ns"] is None:
        t0 = _time.perf_counter_ns()
        serve_stack.prefill(0, sp["max_batch"])
        for d in range(sp["decode_steps"]):
            serve_stack.decode(0, d)
        span = _time.perf_counter_ns() - t0
        sp["mean_gap_ns"] = max(1, int(span * calibration) // 2)
        ledger.meta["serve_probe"] = {
            "probe_span_ns": int(span), "calibration": calibration,
            "mean_gap_ns": sp["mean_gap_ns"]}
    sim = live_colocated_sim(ledger, train_stack=train_stack,
                             serve_stack=serve_stack, **params)
    report = sim.run(engine=engine)
    ledger.save(out_path)
    return report, ledger


# ---------------------------------------------------------------------------
# facade guards + dist merging
# ---------------------------------------------------------------------------


def check_dist_live(workloads: Sequence[Workload]) -> None:
    """``engine="dist"`` preflight for live workloads (see module
    docstring): reject record mode, and require every live fn to
    pickle — failing with a facade error that names the fn."""
    import pickle
    for wl in workloads:
        if wl.live_mode() == "record":
            raise ValueError(
                f"workload {wl.name!r}: live record mode is not "
                f"supported under engine='dist' — forked workers each "
                f"measure their own wall clock and cannot produce one "
                f"coherent trace; record on an in-process engine "
                f"('single'/'barrier'/'async') and replay the saved "
                f"trace under dist")
        for prog, fn in sorted(wl.live_fns().items()):
            try:
                pickle.dumps(fn)
            except Exception as e:
                raise ValueError(
                    f"engine='dist' cannot run live program {prog!r}: "
                    f"its live fn {fn!r} is not picklable ({e}).  Dist "
                    f"workers are forked OS processes and an "
                    f"unpicklable callable almost always captures "
                    f"fork-unsafe state (JAX buffers, locks, open "
                    f"files); define live fns at module top level with "
                    f"picklable state, or record a trace in-process "
                    f"and replay it (replay never calls the fn)"
                ) from e


def merge_live_sections(parts: Sequence[Dict[str, dict]]
                        ) -> Dict[str, dict]:
    """Merge per-worker ``SimReport.live`` sections (dist engine).
    ``tasks`` sub-dicts are owner-disjoint (each worker reports only
    the tasks it executed) and union; every other key is deterministic
    build-time data, identical across replicas — first non-empty
    wins."""
    out: Dict[str, dict] = {}
    for part in parts:
        for wl_name, sec in part.items():
            cur = out.setdefault(wl_name, {})
            for key, value in sec.items():
                if key == "tasks":
                    cur.setdefault("tasks", {}).update(value)
                elif key not in cur or cur[key] in ("", None):
                    cur[key] = value
    return out
