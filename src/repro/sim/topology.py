"""Declarative cluster topology for `repro.sim`.

A :class:`Topology` names the *machines*: how many hosts run the
simulation, how many simulated CPUs each host's scheduler gets, the
interconnect :class:`~repro.core.ipc.LinkSpec` of every host pair, and
the §3.3 memory-hierarchy :class:`CellSpec` declarations programs may
bind to (``Program.cell`` / ``Interference.cell``).  The logical
message *fabrics* (ICI rings, DCN, service networks) belong to the
workloads (see :class:`repro.sim.workload.Workload.fabrics`); the
topology only says what hardware they are mapped onto.

Host-pair links double as the conservative synchronization lookahead of
the async orchestration engine — see ``Orchestrator.connect_hosts``.
Cell declarations are *names + knobs*: cell state itself is per host —
the :class:`~repro.sim.simulation.Simulation` instantiates a declared
cell on every host where one of its programs lands, each with
independent warm/interference state (see ``repro.core.cells``).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

from repro.core.cells import Cell
from repro.core.ipc import LinkSpec

#: CellManager calibration knobs accepted by :meth:`Topology.cell_config`
CELL_KNOBS = ("total_ways", "miss_penalty", "recondition_ns",
              "residue_frac", "n_warm_slots")


@dataclasses.dataclass(frozen=True)
class CellSpec:
    """A declared §3.3 cell: a named controlled resource domain (CAT
    way allocation, MBA bandwidth share, working-set/memory profile)
    that programs bind to via ``Program.cell``.  Instantiated per host
    at build time."""
    name: str
    ways: int = 4                     # CAT way allocation
    bw_share: float = 0.5             # MBA throttle (fraction of machine BW)
    bw_demand: float = 0.3            # workload's bandwidth appetite
    working_set_frac: float = 0.5     # working set / LLC size
    mem_frac: float = 0.3             # memory-bound fraction of runtime
    cpus: Tuple[int, ...] = ()
    numa: int = 0

    def to_cell(self) -> Cell:
        return Cell(name=self.name, ways=self.ways,
                    bw_share=self.bw_share, bw_demand=self.bw_demand,
                    working_set_frac=self.working_set_frac,
                    mem_frac=self.mem_frac, cpus=tuple(self.cpus),
                    numa=self.numa)


@dataclasses.dataclass(frozen=True)
class FabricSpec:
    """A named message fabric a workload communicates over.

    Single-host simulations materialize each fabric as its own
    :class:`~repro.core.ipc.Hub`.  Multi-host simulations give every
    host one hub (default link = the first declared fabric) and express
    the remaining fabrics as per-endpoint-pair link overrides on it.
    """
    name: str
    link: LinkSpec


class Topology:
    """Hosts + host-interconnect links + per-host CPU budget."""

    def __init__(self, n_hosts: int = 1, n_cpus: int = 8,
                 default_host_link: LinkSpec = LinkSpec(
                     bandwidth_bps=25e9 * 8, latency_ns=10_000)):
        if n_hosts < 1:
            raise ValueError("n_hosts must be >= 1")
        self.n_hosts = n_hosts
        self.n_cpus = n_cpus
        self.default_host_link = default_host_link
        # insertion order is preserved and becomes the connect order
        self.host_links: Dict[Tuple[int, int], LinkSpec] = {}
        # §3.3 cell declarations (name -> CellSpec, declaration order —
        # which becomes the per-host creation order) + per-host
        # CellManager calibration knobs
        self.cells: Dict[str, CellSpec] = {}
        self.cell_knobs: Dict[str, Any] = {}
        # membership timeline: host -> join vtime (> 0).  Hosts without
        # an entry are founding members; a declared joiner exists in the
        # cluster from build time (scheduler, hub, links) but enters the
        # conservative clock protocol — and its tasks start — at its
        # join vtime.  See Topology.join / Orchestrator.add_host.
        self.joins: Dict[int, int] = {}

    def join(self, host: int, at_vtime: int) -> "Topology":
        """Declare that ``host`` joins the cluster at simulated time
        ``at_vtime`` (> 0) instead of being a founding member.  Programs
        placed on it spawn with initial vtime ``at_vtime``; the engines
        keep it out of the LBTS closure until the membership epoch
        flips.  Host 0 must stay a founding member (the cluster needs
        at least one host at vtime 0)."""
        if not (0 <= host < self.n_hosts):
            raise ValueError(f"join({host}) outside 0..{self.n_hosts-1}")
        if host == 0:
            raise ValueError("host 0 is the founding member and cannot "
                             "join late")
        if at_vtime < 1:
            raise ValueError(f"join vtime must be >= 1 (got {at_vtime}); "
                             f"a vtime-0 join is a founding member")
        if host in self.joins:
            raise ValueError(f"host {host} already has a join event at "
                             f"vtime {self.joins[host]}")
        self.joins[host] = at_vtime
        return self

    def capacity_pool(self, hosts, start_vtime: int,
                      stagger_ns: int = 0) -> "Topology":
        """Declare a provisioning schedule for a pool of late-joining
        hosts: the first joins at ``start_vtime``, each subsequent one
        ``stagger_ns`` later (0 = all at once).  This is the
        simulation-native shape of an autoscaling group: capacity
        *arrives* on this timeline; a control-plane workload decides
        when to put traffic on it (see ``repro.sim.control``)."""
        for i, h in enumerate(hosts):
            self.join(h, start_vtime + i * stagger_ns)
        return self

    def cell(self, name: str, **knobs) -> "Topology":
        """Declare a memory-hierarchy cell (``knobs`` are the
        :class:`CellSpec` fields: ways, bw_share, bw_demand,
        working_set_frac, mem_frac, cpus, numa)."""
        if name in self.cells:
            raise ValueError(f"cell {name!r} already declared")
        self.cells[name] = CellSpec(name=name, **knobs)
        return self

    def cell_config(self, **knobs) -> "Topology":
        """Set CellManager calibration knobs applied to every host's
        manager (total_ways, miss_penalty, recondition_ns,
        residue_frac, n_warm_slots)."""
        unknown = sorted(set(knobs) - set(CELL_KNOBS))
        if unknown:
            raise ValueError(f"unknown cell knobs {unknown}; "
                             f"expected {CELL_KNOBS}")
        self.cell_knobs.update(knobs)
        return self

    def link(self, a: int, b: int, spec: LinkSpec) -> "Topology":
        """Declare the interconnect between hosts ``a`` and ``b``."""
        if not (0 <= a < self.n_hosts and 0 <= b < self.n_hosts):
            raise ValueError(f"link({a}, {b}) outside 0..{self.n_hosts-1}")
        if a == b:
            raise ValueError("a host needs no link to itself")
        self.host_links[(min(a, b), max(a, b))] = spec
        return self

    def host_link(self, a: int, b: int) -> LinkSpec:
        """The effective interconnect of host pair (a, b): the declared
        per-pair link, else ``default_host_link`` — the same resolution
        the engines use (``Orchestrator.connect_hosts`` wiring, degrade
        hooks, the vectorized compiler)."""
        return self.host_links.get((min(a, b), max(a, b)),
                                   self.default_host_link)

    # -- canned shapes -------------------------------------------------------
    @classmethod
    def single_host(cls, n_cpus: int = 8) -> "Topology":
        return cls(n_hosts=1, n_cpus=n_cpus)

    @classmethod
    def full_mesh(cls, n_hosts: int, link: LinkSpec,
                  n_cpus: int = 8) -> "Topology":
        topo = cls(n_hosts=n_hosts, n_cpus=n_cpus)
        for a in range(n_hosts):
            for b in range(a + 1, n_hosts):
                topo.link(a, b, link)
        return topo

    @classmethod
    def racks(cls, n_racks: int, hosts_per_rack: int,
              intra_link: LinkSpec = LinkSpec(bandwidth_bps=80e9 * 8,
                                              latency_ns=2_000),
              cross_link: LinkSpec = LinkSpec(bandwidth_bps=25e9 * 8,
                                              latency_ns=50_000),
              n_cpus: int = 4) -> "Topology":
        """Hosts grouped into racks: fast intra-rack links, slow
        cross-rack links — the heterogeneous-latency regime where the
        per-link-lookahead async engine beats the global barrier."""
        n_hosts = n_racks * hosts_per_rack
        topo = cls(n_hosts=n_hosts, n_cpus=n_cpus)
        for a in range(n_hosts):
            for b in range(a + 1, n_hosts):
                same = a // hosts_per_rack == b // hosts_per_rack
                topo.link(a, b, intra_link if same else cross_link)
        return topo
