"""Declarative fault/interference injection.

A :class:`Scenario` is a named tuple of :class:`Injection`\\ s applied at
build time by :class:`~repro.sim.simulation.Simulation` — workload
bodies are never edited.  Mechanisms:

* :class:`Straggler` / :class:`FailTask` / :class:`FailHost` wrap the
  target program's generator: compute actions are scaled, or the body is
  closed at a given compute index / virtual time (the vtask finishes
  early, exactly like the legacy ``fail_at`` chip death — downstream
  effects, including a wedged cluster, propagate through the engines
  and surface as ``SimReport.status == "deadlock"``).
* :class:`DegradeLink` installs a hub hook (the eBPF analogue) on the
  sending side that adds latency to matching messages from a given
  virtual time on.  Hooks may only *add* latency, so conservative
  cross-host lookahead is preserved by construction.
* :class:`Interference` spawns a co-located load program; with
  ``Simulation(cpu_resource=True)`` its compute queues for the same
  simulated CPUs as the victim's, coupling their timing in virtual
  time.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Optional, Tuple

from repro.core.vtask import Compute, LiveCall


class Injection:
    """Marker base class for scenario injections."""


@dataclasses.dataclass(frozen=True)
class Straggler(Injection):
    """Scale the target program's modeled compute (and cost-derived live
    calls) by ``slowdown``.  Measured (cost-less) live calls are
    unaffected — their duration comes from the host clock.  Multiple
    stragglers on the same task compound multiplicatively."""
    task: str
    slowdown: float = 2.0


@dataclasses.dataclass(frozen=True)
class FailTask(Injection):
    """Kill one program: before its ``at_compute``-th compute action
    (0-based — the legacy ``fail_at=(chip, step)`` semantics for bodies
    with one compute per step), or at the first dispatch boundary once
    its vtime reaches ``at_vtime``."""
    task: str
    at_compute: Optional[int] = None
    at_vtime: Optional[int] = None


@dataclasses.dataclass(frozen=True)
class FailHost(Injection):
    """Kill every program placed on ``host`` once their vtime reaches
    ``at_vtime`` (a machine dying mid-run)."""
    host: int
    at_vtime: int


@dataclasses.dataclass(frozen=True)
class DegradeLink(Injection):
    """Add latency to messages on a fabric or between a host pair.

    ``latency_factor`` multiplies the base link latency (1.0 = none),
    ``extra_ns`` adds a flat term, and only messages sent at
    ``from_vtime`` or later are affected (mid-run degradation)."""
    fabric: Optional[str] = None
    hosts: Optional[Tuple[int, int]] = None
    latency_factor: float = 1.0
    extra_ns: int = 0
    from_vtime: int = 0


@dataclasses.dataclass(frozen=True)
class Interference(Injection):
    """Co-located load: ``bursts`` x ``burst_ns`` of modeled compute on
    ``host`` (or wherever ``co_locate_with`` was placed).  Two
    contention axes, composable: ``Simulation(cpu_resource=True)``
    queues the load's compute on the victim host's simulated CPUs, and
    ``cell`` binds the load to a declared memory-hierarchy cell
    (``Topology.cell``) so its bandwidth demand spatially interferes
    with co-located live cells — no cpu_resource needed for that axis
    (``Simulation(cells="auto")`` derives the cell instead)."""
    host: Optional[int] = None
    co_locate_with: Optional[str] = None
    bursts: int = 100
    burst_ns: int = 5_000
    cell: Optional[str] = None


@dataclasses.dataclass(frozen=True)
class Scenario:
    name: str = "baseline"
    injections: Tuple[Injection, ...] = ()


# -- body wrappers (build-time machinery, used by Simulation) ----------------


class TaskHandle:
    """Late-bound reference to the wrapped program's VTask (the VTask is
    created *around* the wrapped generator, so wrappers that need its
    vtime get it via this mutable cell)."""
    __slots__ = ("task",)

    def __init__(self):
        self.task = None


def scaled_body(body: Iterator, factor: float) -> Iterator:
    """Forward the action stream, scaling Compute ns and cost-derived
    LiveCall cost_ns by ``factor``."""
    result = None
    while True:
        try:
            action = body.send(result)
        except StopIteration:
            return
        if isinstance(action, Compute):
            action = dataclasses.replace(action, ns=int(action.ns * factor))
        elif isinstance(action, LiveCall) and action.cost_ns is not None:
            # clamp: a straggler factor must never scale a live cost to
            # 0 — the scheduler rejects non-positive live costs
            action = dataclasses.replace(
                action, cost_ns=max(1, int(action.cost_ns * factor)))
        result = yield action


def fail_gated_body(body: Iterator, handle: TaskHandle,
                    at_compute: Optional[int],
                    at_vtime: Optional[int]) -> Iterator:
    """Forward the action stream until the failure point, then return
    (the vtask completes early — it died)."""
    computes = 0
    result = None
    while True:
        try:
            action = body.send(result)
        except StopIteration:
            return
        if (at_vtime is not None and handle.task is not None
                and handle.task.vtime >= at_vtime):
            return
        if at_compute is not None and isinstance(action,
                                                 (Compute, LiveCall)):
            if computes >= at_compute:
                return
            computes += 1
        result = yield action
