"""Declarative fault/interference injection.

A :class:`Scenario` is a named tuple of :class:`Injection`\\ s applied at
build time by :class:`~repro.sim.simulation.Simulation` — workload
bodies are never edited.  Mechanisms:

* :class:`Straggler` / :class:`FailTask` / :class:`FailHost` wrap the
  target program's generator: compute actions are scaled, or the body is
  closed at a given compute index / virtual time (the vtask finishes
  early, exactly like the legacy ``fail_at`` chip death — downstream
  effects, including a wedged cluster, propagate through the engines
  and surface as ``SimReport.status == "deadlock"``).
* :class:`DegradeLink` installs a hub hook (the eBPF analogue) on the
  sending side that adds latency to matching messages from a given
  virtual time on.  Hooks may only *add* latency, so conservative
  cross-host lookahead is preserved by construction.
* :class:`Interference` spawns a co-located load program; with
  ``Simulation(cpu_resource=True)`` its compute queues for the same
  simulated CPUs as the victim's, coupling their timing in virtual
  time.
* :class:`BitFlip` wraps the target program's generator like the
  failure wrappers, but instead of killing the body it corrupts *data*:
  at the chosen data-bearing action (``Send`` / ``LiveCall``) one bit
  of the payload (or of the live-call result) is flipped — silent data
  corruption that downstream consumers and ``LiveCall`` replay observe,
  while timing machinery is untouched.
* :class:`ClockSkew` installs an *ingress* hub hook on the hub owning
  the destination endpoint: every message delivered to an endpoint on
  the skewed host arrives ``offset_ns + drift`` later (the receiver's
  skewed clock timestamps arrivals late).  Offsets and drift are
  validated non-negative at build time, so — like
  :class:`DegradeLink` — the hook only ever *adds* latency and
  conservative cross-host lookahead stays sound.
"""
from __future__ import annotations

import dataclasses
import struct
from typing import Iterator, Optional, Tuple

from repro.core.vtask import Compute, LiveCall, Send


class Injection:
    """Marker base class for scenario injections."""


@dataclasses.dataclass(frozen=True)
class Straggler(Injection):
    """Scale the target program's modeled compute (and cost-derived live
    calls) by ``slowdown``.  Measured (cost-less) live calls are
    unaffected — their duration comes from the host clock.  Multiple
    stragglers on the same task compound multiplicatively."""
    task: str
    slowdown: float = 2.0


@dataclasses.dataclass(frozen=True)
class FailTask(Injection):
    """Kill one program: before its ``at_compute``-th compute action
    (0-based — the legacy ``fail_at=(chip, step)`` semantics for bodies
    with one compute per step), or at the first dispatch boundary once
    its vtime reaches ``at_vtime``."""
    task: str
    at_compute: Optional[int] = None
    at_vtime: Optional[int] = None


@dataclasses.dataclass(frozen=True)
class FailHost(Injection):
    """Kill every program placed on ``host`` once their vtime reaches
    ``at_vtime`` (a machine dying mid-run).

    Membership semantics: this is ordinary churn — the facade records a
    ``leave`` event on the cluster's membership timeline
    (``SimReport.control["membership"]``) and kills the host's tasks
    through the standard fault wrappers.  A leave needs no lookahead
    rebuild (a dead host goes quiescent, and quiescent hosts already
    stop gating peers), so results and sync-round schedules are
    byte-identical to the pre-membership special case."""
    host: int
    at_vtime: int


@dataclasses.dataclass(frozen=True)
class JoinHost(Injection):
    """Scenario-driven membership churn: ``host`` joins the cluster at
    ``at_vtime`` (>= 1), exactly like a ``Topology.join`` declaration —
    programs placed on it spawn with initial vtime ``at_vtime`` and the
    conservative engines admit it at the membership-epoch flip.  The
    host id must be within the topology's ``n_hosts`` and must not
    already be a founding member with tasks that start at vtime 0 or
    carry a conflicting join declaration.  Not admissible on the
    vectorized engine (raises ``UnsupportedByEngine`` at build)."""
    host: int
    at_vtime: int


@dataclasses.dataclass(frozen=True)
class DegradeLink(Injection):
    """Add latency to messages on a fabric or between a host pair.

    ``latency_factor`` multiplies the base link latency (1.0 = none),
    ``extra_ns`` adds a flat term, and only messages sent at
    ``from_vtime`` or later are affected (mid-run degradation)."""
    fabric: Optional[str] = None
    hosts: Optional[Tuple[int, int]] = None
    latency_factor: float = 1.0
    extra_ns: int = 0
    from_vtime: int = 0


@dataclasses.dataclass(frozen=True)
class Interference(Injection):
    """Co-located load: ``bursts`` x ``burst_ns`` of modeled compute on
    ``host`` (or wherever ``co_locate_with`` was placed).  Two
    contention axes, composable: ``Simulation(cpu_resource=True)``
    queues the load's compute on the victim host's simulated CPUs, and
    ``cell`` binds the load to a declared memory-hierarchy cell
    (``Topology.cell``) so its bandwidth demand spatially interferes
    with co-located live cells — no cpu_resource needed for that axis
    (``Simulation(cells="auto")`` derives the cell instead)."""
    host: Optional[int] = None
    co_locate_with: Optional[str] = None
    bursts: int = 100
    burst_ns: int = 5_000
    cell: Optional[str] = None


@dataclasses.dataclass(frozen=True)
class BitFlip(Injection):
    """Silent data corruption in the target program's data path.

    Exactly one trigger: the ``at_step``-th data-bearing action
    (0-based over the body's ``Send``/``LiveCall`` stream), or the
    first data-bearing action once the task's vtime reaches
    ``at_vtime`` (mirroring :class:`FailTask`'s two triggers).  At the
    trigger, ``bit`` is flipped in the ``Send`` payload before it
    enters the hub (downstream consumers receive the corrupted value)
    or in the ``LiveCall`` result before the body observes it (replay
    of recorded live calls sees the corruption).  Payloads with no
    flippable scalar (``None``) pass through unchanged — the injection
    is then masked, which is itself a valid campaign outcome."""
    task: str
    at_step: Optional[int] = None
    at_vtime: Optional[int] = None
    bit: int = 0


@dataclasses.dataclass(frozen=True)
class ClockSkew(Injection):
    """Per-host receive-clock skew: every message delivered to an
    endpoint placed on ``host`` becomes visible
    ``offset_ns + drift_ppm * send_vtime / 1e6`` ns later (integer
    floor).  Both terms must be non-negative — validated at build time
    — so the ingress hook only adds latency and the per-link
    conservative lookahead bound survives.  Multiple skews on one host
    sum."""
    host: int
    offset_ns: int = 0
    drift_ppm: int = 0


@dataclasses.dataclass(frozen=True)
class Scenario:
    name: str = "baseline"
    injections: Tuple[Injection, ...] = ()


# -- body wrappers (build-time machinery, used by Simulation) ----------------


class TaskHandle:
    """Late-bound reference to the wrapped program's VTask (the VTask is
    created *around* the wrapped generator, so wrappers that need its
    vtime get it via this mutable cell)."""
    __slots__ = ("task",)

    def __init__(self):
        self.task = None


def scaled_body(body: Iterator, factor: float) -> Iterator:
    """Forward the action stream, scaling Compute ns and cost-derived
    LiveCall cost_ns by ``factor``."""
    result = None
    while True:
        try:
            action = body.send(result)
        except StopIteration:
            return
        if isinstance(action, Compute):
            action = dataclasses.replace(action, ns=int(action.ns * factor))
        elif isinstance(action, LiveCall) and action.cost_ns is not None:
            # clamp: a straggler factor must never scale a live cost to
            # 0 — the scheduler rejects non-positive live costs
            action = dataclasses.replace(
                action, cost_ns=max(1, int(action.cost_ns * factor)))
        result = yield action


def fail_gated_body(body: Iterator, handle: TaskHandle,
                    at_compute: Optional[int],
                    at_vtime: Optional[int]) -> Iterator:
    """Forward the action stream until the failure point, then return
    (the vtask completes early — it died)."""
    computes = 0
    result = None
    while True:
        try:
            action = body.send(result)
        except StopIteration:
            return
        if (at_vtime is not None and handle.task is not None
                and handle.task.vtime >= at_vtime):
            return
        if at_compute is not None and isinstance(action,
                                                 (Compute, LiveCall)):
            if computes >= at_compute:
                return
            computes += 1
        result = yield action


def flip_bit(value, bit: int):
    """Flip one bit of a scalar payload; containers flip their first
    flippable element; unflippable values pass through unchanged (a
    masked fault, not an error — determinism is what matters)."""
    if isinstance(value, bool):
        return (not value) if bit == 0 else value
    if isinstance(value, int):
        return value ^ (1 << bit)
    if isinstance(value, float):
        (bits,) = struct.unpack("<Q", struct.pack("<d", value))
        return struct.unpack("<d", struct.pack("<Q",
                                               bits ^ (1 << (bit % 64))))[0]
    if isinstance(value, str) and value:
        return chr(ord(value[0]) ^ (1 << (bit % 16))) + value[1:]
    if isinstance(value, (tuple, list)):
        for i, v in enumerate(value):
            flipped = flip_bit(v, bit)
            if flipped is not v and flipped != v:
                out = list(value)
                out[i] = flipped
                return type(value)(out) if isinstance(value, tuple) \
                    else out
        return value
    return value


def bitflip_body(body: Iterator, handle: TaskHandle,
                 at_step: Optional[int], at_vtime: Optional[int],
                 bit: int) -> Iterator:
    """Forward the action stream; at the trigger (the ``at_step``-th
    data-bearing action, or the first one at/after ``at_vtime``) flip
    one payload bit: Send payloads are corrupted *before* the hub sees
    them, LiveCall results are corrupted before the body observes them.
    Exactly one flip per injection."""
    steps = 0
    result = None
    flipped = False
    while True:
        try:
            action = body.send(result)
        except StopIteration:
            return
        fire = False
        if not flipped and isinstance(action, (Send, LiveCall)):
            if at_step is not None:
                fire = steps == at_step
            else:
                fire = (handle.task is not None
                        and handle.task.vtime >= at_vtime)
            steps += 1
        if fire and isinstance(action, Send):
            flipped = True
            action = dataclasses.replace(
                action, payload=flip_bit(action.payload, bit))
            result = yield action
        elif fire:
            flipped = True
            result = yield action
            result = flip_bit(result, bit)
        else:
            result = yield action
