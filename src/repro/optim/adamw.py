"""AdamW in pure JAX, sharding-aware.

Moments are fp32 and shard exactly like their parameters (FSDP over the
``data`` axis per the default rules), so optimizer state adds 8 bytes/param
spread over the full mesh.  Params stay in their storage dtype (bf16) with
fp32 update arithmetic (no separate master copy; the fp32 moments plus
stochastic-free rounding are sufficient at these scales and halve HBM).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


def adamw_init(params) -> dict:
    zeros32 = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "m": jax.tree.map(zeros32, params),
        "v": jax.tree.map(zeros32, params),
        "count": jnp.zeros((), jnp.int32),
    }


def opt_state_specs(param_specs) -> dict:
    f32 = lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32)
    return {
        "m": jax.tree.map(f32, param_specs),
        "v": jax.tree.map(f32, param_specs),
        "count": jax.ShapeDtypeStruct((), jnp.int32),
    }


def opt_state_axes(param_axes) -> dict:
    is_ax = lambda x: isinstance(x, tuple)
    return {
        "m": jax.tree.map(lambda a: a, param_axes, is_leaf=is_ax),
        "v": jax.tree.map(lambda a: a, param_axes, is_leaf=is_ax),
        "count": (),
    }


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(tree)))


def adamw_update(cfg: AdamWConfig, grads, params, state: dict,
                 lr: jnp.ndarray) -> Tuple[Any, dict, dict]:
    """Returns (new_params, new_state, metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-12))
    count = state["count"] + 1
    c1 = 1.0 - cfg.b1 ** count.astype(jnp.float32)
    c2 = 1.0 - cfg.b2 ** count.astype(jnp.float32)

    def upd(g, p, m, v):
        g32 = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g32
        v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g32)
        step = (m / c1) / (jnp.sqrt(v / c2) + cfg.eps)
        if p.ndim >= 2:  # decay matrices only (norms/bias excluded)
            step = step + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * step).astype(p.dtype), m, v

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state["m"])
    flat_v = treedef.flatten_up_to(state["v"])
    out = [upd(g, p, m, v) for g, p, m, v in
           zip(flat_g, flat_p, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_p, {"m": new_m, "v": new_v, "count": count}, metrics
