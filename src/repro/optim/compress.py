"""Gradient compression (int8, per-tensor scale) with error feedback.

Models the distributed-optimization trick of reducing gradients in int8
over the interconnect: quantize -> (all-reduce happens on the quantized
representation) -> dequantize, with the quantization residual carried to
the next step (error feedback keeps convergence; see 1-bit Adam /
PowerSGD literature).  In the single-program pjit world the collective
itself is emitted by XLA, so what we implement is the numerically
faithful transform (and the roofline credit: 4x fewer collective bytes
in fp32 terms, 2x vs bf16 — reflected in §Perf collective-term
estimates).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ef_init(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compress_grads(grads, ef_state):
    """Returns (dequantized grads, new error-feedback state)."""

    def one(g, e):
        g32 = g.astype(jnp.float32) + e
        scale = jnp.maximum(jnp.max(jnp.abs(g32)), 1e-12) / 127.0
        q = jnp.clip(jnp.round(g32 / scale), -127, 127).astype(jnp.int8)
        deq = q.astype(jnp.float32) * scale
        return deq, g32 - deq

    flat_g, tdef = jax.tree.flatten(grads)
    flat_e = tdef.flatten_up_to(ef_state)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return (tdef.unflatten([o[0] for o in out]),
            tdef.unflatten([o[1] for o in out]))
