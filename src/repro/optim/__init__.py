from repro.optim.adamw import (AdamWConfig, adamw_init, adamw_update,
                               opt_state_specs, opt_state_axes)
from repro.optim.schedule import lr_schedule
