"""Learning-rate schedule: linear warmup + cosine decay."""
from __future__ import annotations

import jax.numpy as jnp


def lr_schedule(step, *, peak_lr: float = 3e-4, warmup: int = 100,
                total: int = 10_000, floor_frac: float = 0.1):
    step = step.astype(jnp.float32)
    warm = peak_lr * step / max(warmup, 1)
    prog = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
    cos = peak_lr * (floor_frac + (1 - floor_frac)
                     * 0.5 * (1 + jnp.cos(jnp.pi * prog)))
    return jnp.where(step < warmup, warm, cos)
