"""Flagship example: simulate 512-chip training of an assigned
architecture BEFORE owning the pods (the paper's use case pointed at ML
systems) — now written against the declarative `repro.sim` facade.

The per-chip step cost comes from the multi-pod dry-run artifact (the
cost-derived vtime model); the ICI/DCN fabrics, placement, engines, and
fault injections are all declared, not hand-wired.  Then we do what
closed-form rooflines cannot: inject stragglers, chip/host deaths,
degraded links, and co-located interference, and read the end-to-end
effect off a structured SimReport.

Run:  PYTHONPATH=src python examples/cluster_sim.py [--arch qwen3_4b]
      (--smoke shrinks everything for CI)
"""
import argparse
import os

from repro.core.cluster import ClusterSpec, StepCost, analytic_step_ns
from repro.sim import (ChipRingTraining, DegradeLink, FailHost, FailTask,
                       ModeledServe, RackRing, Scenario, Simulation,
                       Straggler, Topology)


def resolve_cost(arch: str, variant: str = "") -> StepCost:
    try:
        cost = StepCost.from_dryrun(arch, "train_4k", "2x16x16",
                                    variant=variant)
        src = f"dry-run artifact{' (' + variant + ')' if variant else ''}"
    except Exception:
        try:
            cost = StepCost.from_dryrun(arch, "train_4k", "16x16",
                                        variant=variant)
            src = "single-pod dry-run artifact"
        except Exception:
            cost = StepCost(compute_ns=5_000_000, ici_bytes=50_000_000)
            src = "fallback constants (run launch/dryrun first)"
    cost.dcn_bytes = max(cost.ici_bytes // 8, 1)
    print(f"[{arch}] per-chip step cost from {src}: "
          f"compute={cost.compute_ns/1e6:.2f} ms, "
          f"ici={cost.ici_bytes/1e6:.1f} MB")
    return cost


def run(arch: str, n_steps: int = 4, variant: str = "",
        chips_per_pod: int = 256):
    spec = ClusterSpec(n_pods=2, chips_per_pod=chips_per_pod)
    cost = resolve_cost(arch, variant)
    analytic = analytic_step_ns(spec, cost)
    print(f"  closed-form step time: {analytic/1e6:.2f} ms")

    fail_chip = spec.n_chips * 3 // 5          # 300 of 512, scales down
    fail_step = n_steps // 2
    scenarios = [
        Scenario("baseline"),
        Scenario("straggler 2x on chip 7", (Straggler("chip7", 2.0),)),
        Scenario(f"chip {fail_chip} dies at step {fail_step}",
                 (FailTask(f"chip{fail_chip}", at_compute=fail_step),)),
    ]
    for scenario in scenarios:
        wl = ChipRingTraining(spec, cost, n_steps,
                              skew_bound_ns=2_000_000)
        report = Simulation(Topology.single_host(n_cpus=64), wl,
                            scenario).run()
        done = report.progress["train"]["done_steps"]
        print(f"  {scenario.name:28s}: "
              f"{report.vtime_ns/n_steps/1e6:9.2f} ms/step "
              f"(analytic x{report.vtime_ns/n_steps/analytic:.2f}) "
              f"steps done [{min(done)}..{max(done)}] "
              f"wall={report.wall_s:.1f}s msgs={report.messages} "
              f"[{report.status}]")


def run_multihost(n_racks: int = 2, hosts_per_rack: int = 2,
                  n_iters: int = 200, dist_workers: int = 2):
    """Orchestrate the simulation itself across hosts (paper §3.5):
    heterogeneous interconnect — 2us intra-rack, 50us cross-rack, with
    rack 1 computing 3x slower — under every orchestration engine.  The
    per-link-lookahead async engine lets each rack advance at its own
    link granularity instead of creeping at the global minimum latency;
    the dist engine shards the same hosts across real OS worker
    processes (`repro.dist`) behind the same LBTS protocol.  All
    engines produce bit-identical simulation results."""
    print(f"\nmulti-host orchestration: {n_racks} racks x "
          f"{hosts_per_rack} hosts, 2us intra-rack / 50us cross-rack, "
          f"rack 1 is 3x slower")
    engines = ["barrier", "async"]
    if hasattr(os, "fork"):        # the dist engine forks OS workers
        engines.append("dist")
    results = {}
    for engine in engines:
        wl = RackRing(n_racks=n_racks, hosts_per_rack=hosts_per_rack,
                      n_iters=n_iters, skew_bound_ns=2_000_000)
        sim = Simulation(
            Topology.racks(n_racks, hosts_per_rack), wl,
            Scenario("imbalanced racks", wl.stragglers((1.0, 3.0))),
            placement=wl.default_placement(),
        )
        if engine == "dist":
            report = sim.run(engine="dist", n_workers=dist_workers,
                             on_deadlock="raise")
            label = f"dist x{report.n_workers}"
        else:
            report = sim.run(engine=engine, on_deadlock="raise")
            label = engine
        results[engine] = report
        print(f"  {label:8s}: {report.sync_rounds:5d} sync rounds, "
              f"{report.proxy_syncs:5d} proxy syncs, "
              f"{report.messages} msgs, sim={report.vtime_ns/1e6:.2f} ms, "
              f"wall={report.wall_s*1e3:.0f} ms")
    b, a = results["barrier"], results["async"]
    assert a.tasks == b.tasks, "engines must agree on simulation results"
    assert a.messages == b.messages
    if "dist" in results:
        d = results["dist"]
        assert a.tasks == d.tasks and a.messages == d.messages
        print(f"  identical results — even across {d.n_workers} OS "
              f"worker processes; async needed "
              f"{b.sync_rounds/a.sync_rounds:.2f}x fewer rounds than "
              f"barrier; dist determinism holds per-message "
              f"({d.cross_host_msgs} cross-host msgs replayed "
              f"bit-exactly)")
    else:
        print(f"  identical results; async needed "
              f"{b.sync_rounds/a.sync_rounds:.2f}x fewer rounds "
              f"(dist engine skipped: no fork on this platform)")
    return results


def run_scenarios(n_iters: int = 120, n_steps: int = 20,
                  multihost: bool = True):
    """Four scenarios only the declarative API can express.  The first
    two are multi-host (skipped with --skip-multihost); the last two
    are single-host."""
    print("\nscenario gallery (repro.sim injections):")

    if multihost:
        # 1. straggler + mid-run host failure: blast radius, not a crash
        wl = RackRing(n_iters=n_iters, skew_bound_ns=2_000_000)
        report = Simulation(
            Topology.racks(2, 2), wl,
            Scenario("straggler + host 3 dies",
                     (Straggler("w1", 2.0),
                      FailHost(host=3, at_vtime=n_iters * 4_000))),
            placement=wl.default_placement(), mode="async").run()
        done = report.progress["rack"]["iters_done"]
        print(f"  straggler + host death      : [{report.status}] "
              f"iters/worker {done} — the dead host's ring partner "
              f"wedges; the report records how far everyone got")

        # 2. mid-run degraded cross-rack link
        outs = {}
        for name, inj in (("healthy", ()),
                          ("link 0<->2 8x latency",
                           (DegradeLink(hosts=(0, 2), latency_factor=8.0,
                                        from_vtime=n_iters * 1_000),))):
            wl = RackRing(n_iters=n_iters, skew_bound_ns=2_000_000)
            outs[name] = Simulation(
                Topology.racks(2, 2), wl, Scenario(name, inj),
                placement=wl.default_placement(), mode="async").run()
        h, d = outs["healthy"], outs["link 0<->2 8x latency"]
        print(f"  degraded cross-rack link    : [{d.status}] sim time "
              f"{h.vtime_ns/1e6:.2f} -> {d.vtime_ns/1e6:.2f} ms "
              f"(+{(d.vtime_ns/h.vtime_ns - 1) * 100:.0f}% from the "
              f"slow link, same {d.messages} msgs)")

    # 3. co-located serving + training, coupled through simulated CPUs.
    # The tightly-synced train ring keeps low vtimes and wins the
    # virtual-time-ordered CPU queue, so serving takes the brunt — the
    # kind of asymmetry closed-form models miss.
    spec = ClusterSpec(n_pods=1, chips_per_pod=4)
    cost = StepCost(compute_ns=500_000, ici_bytes=1_000_000)

    def train():
        return ChipRingTraining(spec, cost, n_steps,
                                skew_bound_ns=5_000_000)

    def serve():
        return ModeledServe(n_clients=4, n_requests=n_steps,
                            service_ns=500_000)

    alone_t = Simulation(Topology.single_host(n_cpus=1), train(),
                         cpu_resource=True).run()
    alone_s = Simulation(Topology.single_host(n_cpus=1), serve(),
                         cpu_resource=True).run()
    both = Simulation(Topology.single_host(n_cpus=1),
                      [train(), serve()], cpu_resource=True).run()
    t0 = alone_t.tasks["chip0"]["vtime"]
    t1 = both.tasks["chip0"]["vtime"]
    s0 = alone_s.tasks["serve.client0"]["vtime"]
    s1 = both.tasks["serve.client0"]["vtime"]
    print(f"  co-located serve + train    : [{both.status}] train "
          f"{t0/n_steps/1e6:.2f} -> {t1/n_steps/1e6:.2f} ms/step "
          f"(+{(t1/t0 - 1) * 100:.0f}%), serving "
          f"{s0/1e6:.1f} -> {s1/1e6:.1f} ms "
          f"(+{(s1/s0 - 1) * 100:.0f}%) for "
          f"{sum(both.progress['serve']['served'])} requests")

    # 4. live memory-hierarchy cells (§3.3): four live ring workers
    # bound to CAT/MBA-style cells on one host — imperfect isolation
    # (bandwidth contention, working-set overflow, warm-slot
    # reconditioning) is folded into virtual time, and the report says
    # exactly where it went.
    def ring(cells=None):
        return RackRing(n_racks=1, hosts_per_rack=4, n_iters=n_iters,
                        compute_ns=50_000, live=True, cells=cells,
                        skew_bound_ns=2_000_000)

    iso = Simulation(Topology.single_host(n_cpus=1), ring()).run()
    topo = Topology.single_host(n_cpus=1)
    topo.cell("hot", ways=2, working_set_frac=0.7, bw_share=0.3,
              bw_demand=0.7, mem_frac=0.6)
    topo.cell("cold", ways=8, working_set_frac=0.3, bw_share=0.5,
              bw_demand=0.4, mem_frac=0.2)
    topo.cell_config(n_warm_slots=2, recondition_ns=20_000)
    celled = Simulation(
        topo, ring({"w0": "hot", "w1": "cold",
                    "w2": "hot", "w3": "cold"}),
        Scenario("co-located cells")).run()
    cs = celled.cells["0"]
    hot = cs["cells"]["hot"]
    print(f"  co-located memory cells     : [{celled.status}] "
          f"sim time {iso.vtime_ns/1e6:.2f} -> "
          f"{celled.vtime_ns/1e6:.2f} ms "
          f"(+{(celled.vtime_ns/iso.vtime_ns - 1) * 100:.0f}% from "
          f"imperfect isolation: {cs['interference_events']} "
          f"interference events, {cs['switches']} cell switches, "
          f"hot-cell slowdown up to "
          f"{hot['max_slowdown_ppm']/1e6:.2f}x)")


def run_live_recovery(dist_workers: int = 2):
    """Live recovery demo (replay mode): the marquee scenario — a real
    sharded Trainer recorded once under simulated time (the checked-in
    trace at tests/golden/live_recovery_trace.json; re-record with
    ``python -m repro.live record``) takes a FailHost mid-run, restores
    the last committed checkpoint, elastically re-meshes, and resumes.
    Replaying the pinned costs reproduces the recorded vtimes
    bit-exactly on every engine — no JAX work happens here."""
    import pathlib

    from repro.live import CostLedger
    from repro.sim import live_recovery_sim, recovery_timeline

    trace = (pathlib.Path(__file__).parent.parent / "tests" / "golden"
             / "live_recovery_trace.json")
    print("\nlive trainer recovery (recorded-cost replay):")
    engines = ["barrier", "async"]
    if hasattr(os, "fork"):
        engines.append("dist")
    results = {}
    for engine in engines:
        sim = live_recovery_sim(CostLedger.replay(trace))
        if engine == "dist":
            report = sim.run(engine="dist", n_workers=dist_workers)
        else:
            report = sim.run(engine=engine)
        results[engine] = report
        assert report.status == "ok", report.detail
    base = results[engines[0]]
    for engine in engines[1:]:
        r = results[engine]
        assert (r.tasks, r.vtime_ns, r.live) == \
            (base.tasks, base.vtime_ns, base.live), \
            f"{engine} diverged from {engines[0]}"
    tl = recovery_timeline(base)
    names = {e["event"]: e["vtime"] for e in tl}
    print(f"  engines {'/'.join(engines)} bit-identical; recovery "
          f"timeline (vtime):")
    for e in tl:
        print(f"    {e['event']:8s} step {e['step']} at "
              f"{e['vtime']/1e6:10.2f} ms")
    assert names["detect"] < names["restore"] <= names["resumed"]
    print(f"  final step "
          f"{base.live['live_train']['tasks']['live.trainer']['final_step']}"
          f" reached after 1 restart, horizon "
          f"{base.vtime_ns/1e6:.0f} ms")
    return results


def run_live_serve(dist_workers: int = 2):
    """Live serving demo (replay mode): the real BatchServer recorded
    once under open-loop Poisson arrivals (the checked-in trace at
    tests/golden/live_serve_trace.json; re-record with ``python -m
    repro.live record --scenario serve``).  Replaying the pinned
    per-wave prefill/decode costs reproduces the recorded request
    latencies bit-exactly on every engine — and the co-located trace
    (live trainer + live server sharing one §3.3 cell) replays the
    same way from one multi-driver ledger."""
    import pathlib

    from repro.live import CostLedger
    from repro.sim import live_colocated_sim, live_serve_sim, serve_latency

    golden = pathlib.Path(__file__).parent.parent / "tests" / "golden"
    print("\nlive serving (recorded-cost replay):")
    engines = ["barrier", "async"]
    if hasattr(os, "fork"):
        engines.append("dist")
    results = {}
    for engine in engines:
        sim = live_serve_sim(CostLedger.replay(
            golden / "live_serve_trace.json"))
        if engine == "dist":
            report = sim.run(engine="dist", n_workers=dist_workers)
        else:
            report = sim.run(engine=engine)
        results[engine] = report
        assert report.status == "ok", report.detail
    base = results[engines[0]]
    for engine in engines[1:]:
        r = results[engine]
        assert (r.tasks, r.vtime_ns, serve_latency(r)) == \
            (base.tasks, base.vtime_ns, serve_latency(base)), \
            f"{engine} diverged from {engines[0]}"
    sec = base.live["live_serve"]["tasks"]["serve.live"]
    lt = sec["latency_ns"]
    print(f"  engines {'/'.join(engines)} bit-identical; "
          f"{sec['requests']} requests in {sec['waves']} waves "
          f"(max wave batch {sec['max_wave_batch']})")
    print(f"  latency p50 {lt['p50']/1e6:.2f} ms, "
          f"p99 {lt['p99']/1e6:.2f} ms, max {lt['max']/1e6:.2f} ms; "
          f"max queue depth {sec['queue_depth']['max']}")
    assert lt["p50"] <= lt["p95"] <= lt["p99"] <= lt["max"]

    # co-located live train + live serve: one trace, two drivers, one
    # shared cell — the replay carries both the recovery timeline and
    # the serving percentiles
    colo = live_colocated_sim(CostLedger.replay(
        golden / "live_colocated_trace.json")).run(engine="async")
    assert colo.status == "ok", colo.detail
    clat = serve_latency(colo)
    final = colo.live["live_train"]["tasks"]["live.trainer"]["final_step"]
    cell = colo.cells["0"]["cells"]["colo"]
    print(f"  co-located train + serve    : [{colo.status}] one cell, "
          f"{cell['assigned']} live drivers, "
          f"{colo.cells['0']['switches']} cell switches; trainer "
          f"reached step {final}, serve p99 "
          f"{clat['p99']/1e6:.2f} ms")
    return results


def run_autoscale(dist_workers: int = 2, smoke: bool = False):
    """Membership + control-plane demo: a founding fleet rides a
    diurnal traffic wave — late pool hosts *join the cluster as
    simulation events* (``Topology.capacity_pool``), a threshold
    autoscaler boots and drains them from observed traffic, and every
    scaling decision plus the request-latency percentiles come out
    bit-identical on the in-process and multi-process engines."""
    from repro.sim import (AutoscaledServe, ThresholdAutoscaler,
                           diurnal_arrivals)

    n_pool, founding = (8, 4) if smoke else (16, 4)
    join0, stagger = 20_000_000, 500_000
    print(f"\ntraffic-driven control plane: {founding} founding hosts, "
          f"{n_pool - founding} joining mid-run, threshold autoscaler")

    def make():
        topo = Topology(n_hosts=n_pool + 1, n_cpus=2)
        topo.capacity_pool(range(founding + 1, n_pool + 1), join0,
                           stagger_ns=stagger)
        ready = [0] * founding + [join0 + i * stagger
                                  for i in range(n_pool - founding)]
        wl = AutoscaledServe(
            arrivals=diurnal_arrivals(700 if smoke else 1400,
                                      base_gap_ns=1_000_000,
                                      peak_gap_ns=60_000,
                                      period_ns=100_000_000, seed=5),
            n_pool=n_pool, ready_ns=ready, service_ns=400_000,
            min_active=founding, decide_every=8, probe_every=4,
            autoscaler=ThresholdAutoscaler(patience=2),
            placement="worst_fit")
        return Simulation(topo, wl, Scenario("diurnal autoscale"),
                          placement=wl.default_placement())

    a = make().run(engine="async")
    assert a.status == "ok", a.detail
    if hasattr(os, "fork"):
        d = make().run(engine="dist", n_workers=dist_workers)
        assert (d.tasks, d.vtime_ns, d.control) == \
            (a.tasks, a.vtime_ns, a.control), \
            "dist diverged from async on the control plane"
        print(f"  async == dist x{dist_workers} bit-identical "
              f"(including every autoscaler decision)")
    sec = a.control["autoserve"]
    moves = [(d_["vtime"], d_["from"], d_["to"])
             for d_ in sec["decisions"] if d_["from"] != d_["to"]]
    joins = [e for e in a.control["membership"] if e["event"] == "join"]
    print(f"  {len(joins)} hosts joined mid-run; fleet path: "
          + " -> ".join([str(founding)] + [str(t) for _, _, t in moves]))
    print(f"  {sec['served']} requests, boots={sec['boots']} "
          f"drains={sec['drains']} probes={sec['probes']['sent']}; "
          f"latency p50 {sec['latency_ns']['p50']/1e6:.2f} ms, "
          f"p99 {sec['latency_ns']['p99']/1e6:.2f} ms")
    return a


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3_4b")
    ap.add_argument("--steps", type=int, default=4)
    ap.add_argument("--variant", default="",
                    help="optimized cost variant, e.g. gather_causal")
    ap.add_argument("--skip-multihost", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="small sizes for CI")
    args = ap.parse_args()
    if args.smoke:
        run(args.arch, n_steps=2, variant=args.variant, chips_per_pod=16)
        if not args.skip_multihost:
            run_multihost(n_iters=60)
        run_scenarios(n_iters=40, n_steps=8,
                      multihost=not args.skip_multihost)
        if not args.skip_multihost:
            run_live_recovery()
            run_live_serve()
            run_autoscale(smoke=True)
    else:
        run(args.arch, args.steps, args.variant)
        if not args.skip_multihost:
            run_multihost()
        run_scenarios(multihost=not args.skip_multihost)
        if not args.skip_multihost:
            run_live_recovery()
            run_live_serve()
            run_autoscale()
