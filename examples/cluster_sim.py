"""Flagship example: simulate 512-chip training of an assigned
architecture BEFORE owning the pods (the paper's use case pointed at ML
systems).

The per-chip step cost comes from the multi-pod dry-run artifact (the
cost-derived vtime model); the ICI/DCN fabrics are LiveStack hubs; every
chip is a vtask in one bounded-skew scope.  Then we do what closed-form
rooflines cannot: inject a straggler and a chip failure and watch the
end-to-end effect.

Run:  PYTHONPATH=src python examples/cluster_sim.py [--arch qwen3_4b]
"""
import argparse
import time

from repro.core.cluster import (ClusterSpec, StepCost, StragglerSpec,
                                analytic_step_ns, build_training_cluster)
from repro.core.vtime import SEC


def run(arch: str, n_steps: int = 4, variant: str = ""):
    spec = ClusterSpec(n_pods=2, chips_per_pod=256)
    try:
        cost = StepCost.from_dryrun(arch, "train_4k", "2x16x16",
                                    variant=variant)
        src = f"dry-run artifact{' (' + variant + ')' if variant else ''}"
    except Exception:
        try:
            cost = StepCost.from_dryrun(arch, "train_4k", "16x16",
                                        variant=variant)
            src = "single-pod dry-run artifact"
        except Exception:
            cost = StepCost(compute_ns=5_000_000, ici_bytes=50_000_000)
            src = "fallback constants (run launch/dryrun first)"
    cost.dcn_bytes = max(cost.ici_bytes // 8, 1)
    print(f"[{arch}] per-chip step cost from {src}: "
          f"compute={cost.compute_ns/1e6:.2f} ms, "
          f"ici={cost.ici_bytes/1e6:.1f} MB")

    scenarios = [
        ("baseline", dict()),
        ("straggler 2x on chip 7",
         dict(stragglers=(StragglerSpec(chip=7, slowdown=2.0),))),
        ("chip 300 dies at step 2", dict(fail_at=(300, 2))),
    ]
    analytic = analytic_step_ns(spec, cost)
    print(f"  closed-form step time: {analytic/1e6:.2f} ms")
    for name, kw in scenarios:
        sched, tasks, ctx = build_training_cluster(
            spec, cost, n_steps, skew_bound_ns=2_000_000, **kw)
        t0 = time.perf_counter()
        try:
            sched.run()
            status = "ok"
        except Exception as e:       # failure propagates as a stall
            status = type(e).__name__
        wall = time.perf_counter() - t0
        sim = max(t.vtime for t in tasks)
        done = ctx["done_steps"]
        print(f"  {name:28s}: {sim/n_steps/1e6:9.2f} ms/step "
              f"(analytic x{sim/n_steps/analytic:.2f}) "
              f"steps done [{done.min()}..{done.max()}] "
              f"wall={wall:.1f}s "
              f"msgs={sum(h.stats['messages'] for h in ctx['hubs'])} "
              f"[{status}]")


def run_multihost(n_racks: int = 2, hosts_per_rack: int = 2,
                  n_iters: int = 200):
    """Orchestrate the simulation itself across hosts (paper §3.5):
    heterogeneous interconnect — 2us intra-rack, 50us cross-rack, with
    rack 1 computing 3x slower — under both orchestration engines.  The
    per-link-lookahead async engine lets each rack advance at its own
    link granularity instead of creeping at the global minimum latency,
    while producing bit-identical simulation results."""
    from repro.core import State
    from repro.core.cluster import build_rack_cluster

    print(f"\nmulti-host orchestration: {n_racks} racks x "
          f"{hosts_per_rack} hosts, 2us intra-rack / 50us cross-rack, "
          f"rack 1 is 3x slower")
    results = {}
    for mode in ("barrier", "async"):
        orch, tasks, ctx = build_rack_cluster(
            mode=mode, n_racks=n_racks, hosts_per_rack=hosts_per_rack,
            n_iters=n_iters, rack_slowdown=(1.0, 3.0),
            skew_bound_ns=2_000_000)
        t0 = time.perf_counter()
        res = orch.run()
        wall = time.perf_counter() - t0
        assert all(t.state == State.DONE for t in tasks)
        results[mode] = (res, [t.vtime for t in tasks])
        print(f"  {mode:8s}: {res['epochs']:5d} sync rounds, "
              f"{orch.stats['proxy_syncs']:5d} proxy syncs, "
              f"{res['messages']} msgs, sim={res['vtime_ns']/1e6:.2f} ms, "
              f"wall={wall*1e3:.0f} ms")
    assert results["barrier"][1] == results["async"][1], \
        "engines must agree on simulation results"
    rb = results["barrier"][0]["epochs"]
    ra = results["async"][0]["epochs"]
    print(f"  identical results; async needed {rb/ra:.2f}x fewer rounds")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3_4b")
    ap.add_argument("--steps", type=int, default=4)
    ap.add_argument("--variant", default="",
                    help="optimized cost variant, e.g. gather_causal")
    ap.add_argument("--skip-multihost", action="store_true")
    args = ap.parse_args()
    run(args.arch, args.steps, args.variant)
    if not args.skip_multihost:
        run_multihost()
