"""Quickstart: the three layers of LiveStack-JAX in one minute.

1. run a reduced architecture from the zoo (forward + one train step),
2. serve it (prefill + decode),
3. simulate a 2-component live workload under virtual time.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro import configs
from repro.core import (Compute, Endpoint, Hub, LinkSpec, LiveCall, Recv,
                        Scheduler, Scope, Send, US, VTask)
from repro.models import registry
from repro.models.common import softmax_cross_entropy


def part1_model():
    print("=== 1. model zoo ===")
    cfg = configs.get_smoke("qwen3-4b")
    params = registry.init(cfg, jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0,
                                cfg.vocab)
    logits = registry.forward(cfg, params, tokens)
    loss = softmax_cross_entropy(logits[:, :-1], tokens[:, 1:])
    print(f"  {cfg.name}: logits {logits.shape}, loss {float(loss):.3f}")
    full = configs.get("qwen3-4b")
    print(f"  full config: {full.n_layers}L d={full.d_model} "
          f"params={full.n_params()/1e9:.2f}B")


def part2_serving():
    print("=== 2. serving ===")
    from repro.serve.loop import BatchServer

    cfg = configs.get_smoke("qwen3-4b")
    params = registry.init(cfg, jax.random.PRNGKey(0))
    srv = BatchServer(cfg, params, max_new_tokens=8)
    prompts = jax.random.randint(jax.random.PRNGKey(2), (2, 16), 0,
                                 cfg.vocab)
    out = srv.generate(prompts)
    s = out["stats"]
    print(f"  generated {out['tokens'].shape} tokens, "
          f"{s.per_token_ms:.1f} ms/tok, {s.throughput_tok_s:.0f} tok/s")


def part3_livestack():
    print("=== 3. live simulation (the paper) ===")
    hub = Hub("net", LinkSpec(bandwidth_bps=10e9 * 8, latency_ns=50_000))
    sched = Scheduler(n_cpus=2)
    cl = hub.attach(Endpoint("client"))
    sv = hub.attach(Endpoint("server"))

    def real_work():                     # LIVE code, natively executed
        return sum(i * i for i in range(20_000))

    def client():
        for i in range(20):
            yield Send(cl, "server", 16_384)
            yield Recv(cl)

    def server():
        for _ in range(20):
            yield Recv(sv)
            yield LiveCall(real_work)    # clock-derived vtime
            yield Send(sv, "client", 256)

    c = sched.spawn(VTask("client", client(), kind="live"))
    s = sched.spawn(VTask("server", server(), kind="live"))
    scope = Scope("rpc", skew_bound_ns=200 * US)
    c.join(scope)
    s.join(scope)
    t0 = time.perf_counter()
    sched.run()
    wall = time.perf_counter() - t0
    print(f"  simulated {c.vtime/1e6:.2f} ms of cluster time in "
          f"{wall*1e3:.1f} ms wall "
          f"({sched.stats.dispatches} dispatches, "
          f"{sched.stats.skew_stalls} skew stalls)")


if __name__ == "__main__":
    part1_model()
    part2_serving()
    part3_livestack()
