"""Batched-serving example: prefill + decode over a request batch, with
per-phase latency stats — the serving-side end-to-end driver.

Run:  PYTHONPATH=src python examples/serve_batch.py [--arch qwen3-4b]
"""
import argparse

import jax

from repro import configs
from repro.models import registry
from repro.serve.loop import BatchServer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-4b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--new-tokens", type=int, default=16)
    args = ap.parse_args()

    cfg = configs.get_smoke(args.arch)
    params = registry.init(cfg, jax.random.PRNGKey(0))
    srv = BatchServer(cfg, params, max_new_tokens=args.new_tokens,
                      eos_id=0)
    prompts = jax.random.randint(
        jax.random.PRNGKey(1), (args.batch, args.prompt_len), 1,
        cfg.vocab)
    out = srv.generate(prompts)
    s = out["stats"]
    print(f"arch={cfg.name} batch={args.batch} "
          f"prompt={args.prompt_len} new={out['tokens'].shape[1]}")
    print(f"prefill {s.prefill_s*1e3:.1f} ms | decode "
          f"{s.per_token_ms:.2f} ms/tok | {s.throughput_tok_s:.0f} tok/s")
    print("first row:", out["tokens"][0][:12])


if __name__ == "__main__":
    main()
