"""End-to-end driver: train a ~100M-param qwen3-family model with the
full runtime (synthetic data, AdamW, async checkpoints, failure
injection + elastic restart, straggler monitor).

Default is a quick preset that finishes in minutes on this CPU
container; ``--full`` trains the real ~100M config for a few hundred
steps.

Run:  PYTHONPATH=src python examples/train_100m.py [--full] [--steps N]
"""
import argparse
import dataclasses

import jax.numpy as jnp

from repro import configs
from repro.models.common import ModelConfig
from repro.runtime import FailureInjector, Trainer, TrainerConfig


def model_100m() -> ModelConfig:
    return dataclasses.replace(
        configs.get("qwen3-4b"), name="qwen3-100m",
        n_layers=16, d_model=512, n_heads=8, n_kv_heads=2, head_dim=64,
        d_ff=2048, vocab=32_000)


def model_quick() -> ModelConfig:
    return dataclasses.replace(
        configs.get("qwen3-4b"), name="qwen3-20m",
        n_layers=4, d_model=256, n_heads=4, n_kv_heads=2, head_dim=64,
        d_ff=1024, vocab=8_192, remat=False)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--steps", type=int, default=None)
    ap.add_argument("--fail-at", type=int, default=None,
                    help="inject a simulated host failure at this step")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train100m")
    args = ap.parse_args()

    cfg = model_100m() if args.full else model_quick()
    n_params = cfg.n_params()
    steps = args.steps or (300 if args.full else 60)
    print(f"model {cfg.name}: {n_params/1e6:.1f}M params, {steps} steps")

    tcfg = TrainerConfig(
        n_steps=steps,
        seq_len=256 if args.full else 128,
        global_batch=8 if args.full else 4,
        checkpoint_every=25,
        checkpoint_dir=args.ckpt_dir,
        log_every=10,
        peak_lr=6e-4, warmup=20)
    inj = FailureInjector(
        fail_at_steps={args.fail_at} if args.fail_at else set())
    tr = Trainer(cfg, tcfg, injector=inj)
    out = tr.run()
    losses = [h["loss"] for h in out["history"]]
    print(f"done: loss {losses[0]:.3f} -> {losses[-1]:.3f}, "
          f"restarts={out['restarts']}, "
          f"stragglers={len(out['stragglers'])}")
    assert losses[-1] < losses[0], "loss must decrease"


if __name__ == "__main__":
    main()
