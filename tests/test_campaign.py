"""Fault-campaign harness: swept grids, outcome classification,
delta-minimized reproducers, and fault containment.

The determinism bar mirrors the engine harness's: a seeded campaign
must produce the identical outcome per grid point and *byte-identical*
minimized reproducer specs across independent runs and across campaign
engines (async vs the multi-process dist engine) — minimization trials
always run on the in-process reference engine and classification reads
only engine-independent report fields, so the campaign engine must be
unobservable in the artifacts.

Containment: a grid point whose injection hard-kills an OS worker
process mid-campaign (silent corruption turning into ``os._exit``) must
classify as ``crash`` with the failure recorded, while the remaining
points still run — one poisoned point must not take down the sweep.
"""
import json
import os

import pytest

from engine_harness import HAS_FORK
from repro.core.ipc import LinkSpec
from repro.core.vtask import Compute, LiveCall
from repro.sim import (BitFlip, Campaign, FailTask, FaultGrid,
                       ModeledServe, Scenario, Simulation, Topology,
                       Workload, registry, replay_spec)
from repro.sim.campaign import (OUTCOMES, REPRO_SCHEMA, classify,
                                functional_fingerprint, spec_to_bytes)
from repro.sim.topology import FabricSpec
from repro.sim.workload import EndpointSpec, Program


def _serve(scenario=None):
    return Simulation(Topology.single_host(n_cpus=4),
                      ModeledServe(n_clients=2, n_requests=4),
                      scenario or Scenario("serve base"))


# -- grid --------------------------------------------------------------------


def test_grid_validates_axes():
    with pytest.raises(ValueError, match="unknown fault type"):
        FaultGrid(types=("warp",), targets=("a",), vtimes=(0,))
    with pytest.raises(ValueError, match="at least one"):
        FaultGrid(types=("straggler",), targets=(), vtimes=(0,))
    with pytest.raises(ValueError, match="count"):
        FaultGrid(types=("straggler",), targets=("a",), vtimes=(0,),
                  counts=(2,))


def test_grid_point_order_is_axis_product():
    grid = FaultGrid(types=("straggler", "fail_task"),
                     targets=("serve.client0", "serve.client1"),
                     vtimes=(0, 10))
    pts = grid.points(lambda t: 0)
    assert len(pts) == grid.n_points == 8
    assert [p.index for p in pts] == list(range(8))
    # fixed axis order: type (outermost), target, vtime (innermost)
    assert (pts[0].type, pts[0].target, pts[0].vtime) == \
        ("straggler", "serve.client0", 0)
    assert (pts[1].vtime, pts[2].target) == (10, "serve.client1")


# -- classification + campaign determinism -----------------------------------


def test_campaign_histogram_and_point_outcomes():
    ent = registry.entry("serve_smoke@v1")
    report = Campaign(ent.make, ent.grid(), seed=0,
                      base_name=ent.ref).run()
    assert report.histogram == {"ok": 4, "deadlock": 6,
                                "invariant-violation": 0, "crash": 4,
                                "divergence": 2}
    by_type = {}
    for p in report.points:
        by_type.setdefault(p["type"], set()).add(p["outcome"])
    assert by_type["bitflip"] == {"crash"}
    assert by_type["straggler"] == {"ok"}
    assert by_type["fail_task"] == {"deadlock"}
    assert by_type["fail_host"] == {"divergence", "deadlock"}
    # crashes carry the engine error and a traceback
    crash = next(p for p in report.points if p["outcome"] == "crash")
    assert "unknown endpoint" in crash["detail"]
    assert crash["traceback"]
    assert sum(report.histogram.values()) == report.grid["n_points"]


def test_reproducers_byte_identical_across_runs_and_replayable():
    ent = registry.entry("serve_smoke@v1")
    r1 = Campaign(ent.make, ent.grid(), seed=0, base_name=ent.ref).run()
    r2 = Campaign(ent.make, ent.grid(), seed=0, base_name=ent.ref).run()
    assert r1.reproducers and \
        [spec_to_bytes(s) for s in r1.reproducers] == \
        [spec_to_bytes(s) for s in r2.reproducers]
    for spec in r1.reproducers:
        assert spec["schema"] == REPRO_SCHEMA
        # the spec replays standalone — fresh sim, no campaign state —
        # to the exact outcome class it records
        outcome, _ = replay_spec(spec, ent.make)
        assert outcome == spec["outcome"]


def test_minimizer_reaches_minimal_spec():
    """The planted serve crash needs one injection: the greedy drop +
    binary shrink must land on the single-bit, vtime-0 form (bit 2
    shrinks to bit 1 — bit 0 turns the crash into a deadlock, so the
    minimizer must stop above it), and duplicate failing points must
    converge to the same canonical reproducer."""
    ent = registry.entry("serve_smoke@v1")
    report = Campaign(ent.make, ent.grid(), seed=0,
                      base_name=ent.ref).run()
    crashes = [s for s in report.reproducers
               if s["outcome"] == "crash"]
    assert len(crashes) == 4
    assert len({spec_to_bytes(s)
                for s in (dict(s, point=None, trials=None)
                          for s in crashes)}) == 1
    spec = crashes[0]
    assert spec["injections"] == [
        {"at_vtime": 0, "bit": 1, "task": "serve.client0",
         "type": "BitFlip"}]


@pytest.mark.skipif(not HAS_FORK, reason="dist engine needs os.fork")
def test_specs_identical_across_async_and_dist_campaigns():
    ent = registry.entry("rack_ring@v1")
    grid = FaultGrid(types=("fail_task", "straggler", "clock_skew"),
                     targets=("w0", "w1"), vtimes=(0,))
    r_async = Campaign(ent.make, grid, seed=1, engine="async",
                       base_name=ent.ref).run()
    r_dist = Campaign(ent.make, grid, seed=1, engine="dist",
                      n_workers=2, base_name=ent.ref).run()
    assert [p["outcome"] for p in r_async.points] == \
        [p["outcome"] for p in r_dist.points]
    assert r_async.reproducers, "grid should plant ring deadlocks"
    assert [spec_to_bytes(s) for s in r_async.reproducers] == \
        [spec_to_bytes(s) for s in r_dist.reproducers]


def test_baseline_must_be_fault_free():
    def broken(scenario=None):
        # ignores the campaign's scenario override: the fault is baked
        # into the base itself, so even the baseline run wedges
        return _serve(Scenario(
            "wedged base", (FailTask("serve.client0", at_vtime=0),)))
    grid = FaultGrid(types=("straggler",), targets=("serve.client0",),
                     vtimes=(0,))
    with pytest.raises(ValueError, match="baseline"):
        Campaign(broken, grid).run()


def test_custom_invariants_rank_above_divergence():
    ent = registry.entry("serve_smoke@v1")

    def all_served(report):
        served = report.progress["serve"]["served"]
        return [] if all(v == 4 for v in served) else \
            [f"incomplete serve counts {served}"]

    grid = FaultGrid(types=("fail_host",), targets=("serve.client0",),
                     vtimes=(0,))
    report = Campaign(ent.make, grid, invariants=all_served,
                      base_name=ent.ref).run(minimize=False)
    # without the hook this point is a divergence (see the smoke grid);
    # the user invariant reclassifies it up the severity ladder
    assert report.points[0]["outcome"] == "invariant-violation"
    assert "incomplete serve" in report.points[0]["detail"]


def test_report_json_round_trip():
    ent = registry.entry("serve_smoke@v1")
    report = Campaign(ent.make, ent.grid(), seed=0,
                      base_name=ent.ref).run(minimize=False)
    d = json.loads(report.to_json())
    assert d["schema"] == "campaign_report/v1"
    assert set(d["histogram"]) == set(OUTCOMES)
    assert d["grid"]["shape"] == [4, 2, 2, 1]
    assert len(d["points"]) == d["grid"]["n_points"] == 16
    assert d["wall_s"] >= 0 and d["points_per_s"] > 0


# -- fault containment: a point that kills its OS worker ---------------------


class _Fragile(Workload):
    """Two live workers whose step result, when bit-flipped, hard-kills
    the owning OS worker process (in-process runs raise instead, so
    minimization trials on the reference engine stay survivable)."""

    name = "fragile"

    def __init__(self):
        self.main_pid = os.getpid()

    def programs(self):
        def mk(i):
            def make_body(eps):
                def body():
                    v = yield LiveCall(lambda: 0, cost_ns=1_000)
                    if v:
                        if os.getpid() != self.main_pid:
                            os._exit(17)
                        raise RuntimeError("corrupted live result")
                    yield Compute(10_000)
                return body()
            return make_body
        return [Program(name=f"k{i}", make_body=mk(i), kind="live",
                        endpoints=(EndpointSpec(f"k{i}.ep", "fab"),))
                for i in range(2)]

    def fabrics(self):
        return [FabricSpec("fab", LinkSpec())]


@pytest.mark.skipif(not HAS_FORK, reason="dist engine needs os.fork")
def test_dist_worker_death_is_contained_and_campaign_continues():
    def make_sim(scenario=None):
        return Simulation(Topology.racks(1, 2), _Fragile(),
                          scenario or Scenario("fragile base"),
                          placement={"k0": 0, "k1": 1})

    grid = FaultGrid(types=("bitflip", "straggler"),
                     targets=("k0", "k1"), vtimes=(0,))
    report = Campaign(make_sim, grid, seed=0, engine="dist",
                      n_workers=2, worker_timeout=30.0).run()
    outcomes = {(p["type"], p["target"]): p["outcome"]
                for p in report.points}
    assert outcomes[("bitflip", "k0")] == "crash"
    assert outcomes[("bitflip", "k1")] == "crash"
    # the sweep survived both worker deaths and ran the rest
    assert outcomes[("straggler", "k0")] == "ok"
    assert outcomes[("straggler", "k1")] == "ok"
    killed = next(p for p in report.points
                  if p["outcome"] == "crash")
    assert "DistWorkerError" in killed["detail"]
    assert killed["traceback"]
    # minimization replayed the point in-process (RuntimeError branch)
    # and still pinned the crash class
    assert {s["outcome"] for s in report.reproducers} == {"crash"}


@pytest.mark.skipif(not HAS_FORK, reason="dist engine needs os.fork")
def test_worker_error_frame_carries_remote_traceback():
    """The other DistWorkerError path: the worker survives long enough
    to ship an error frame (hub routing KeyError from the corrupted
    payload), whose remote traceback must land in the point record."""
    ent = registry.entry("serve_smoke@v1")
    grid = FaultGrid(types=("bitflip",), targets=("serve.client0",),
                     vtimes=(0,), knobs={"bit": 2})
    report = Campaign(ent.make, grid, seed=0, engine="dist",
                      base_name=ent.ref,
                      worker_timeout=30.0).run(minimize=False)
    [point] = report.points
    assert point["outcome"] == "crash"
    assert "DistWorkerError" in point["detail"]
    assert "unknown endpoint serve.cli4" in point["traceback"]


def test_classify_exposes_fingerprint_fields():
    """Divergence detection reads only engine-independent functional
    fields — the fingerprint must not smuggle in vtimes (timing shifts
    are scenario-expected, not divergence)."""
    base = _serve().run()
    fp = functional_fingerprint(base)
    assert set(fp) == {"status", "tasks", "progress", "messages",
                       "bytes"}
    assert all("vtime" not in t for t in fp["tasks"].values())
    assert classify(_serve().run(), fp, lambda r: []) == ("ok", "")
