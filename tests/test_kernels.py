"""Per-kernel allclose vs. pure-jnp/numpy oracles, interpret mode on CPU.

Every kernel sweeps shapes (incl. non-divisible / padded cases) and
dtypes per the deliverable-(c) requirement."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.engine_jax import hub_visibility_ref
from repro.kernels import ref as kref
from repro.kernels.decode_attention import decode_attention
from repro.kernels.flash_attention import flash_attention_flat
from repro.kernels.hub_route import hub_route
from repro.kernels.minskew import minskew
from repro.kernels.mlstm_kernel import mlstm_chunkwise
from repro.kernels.rglru_scan import rglru_scan

RNG = np.random.default_rng(42)


def rand(shape, dtype=jnp.float32, scale=1.0):
    return jnp.asarray(RNG.standard_normal(shape) * scale, dtype)


TOL = {jnp.float32: dict(rtol=2e-5, atol=2e-5),
       jnp.bfloat16: dict(rtol=2e-2, atol=2e-2)}


# ---------------------------------------------------------------- flash attn


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "bh,hkv,sq,sk,hd,causal,window,bq,bk",
    [
        (4, 4, 128, 128, 64, True, 0, 64, 64),
        (4, 2, 128, 128, 64, True, 0, 64, 64),      # GQA 2:1
        (8, 2, 96, 96, 32, True, 0, 64, 64),        # padded seq
        (2, 1, 256, 256, 64, True, 64, 64, 64),     # sliding window
        (2, 2, 64, 192, 32, False, 0, 64, 64),      # cross attention
        (6, 3, 128, 128, 128, True, 0, 128, 128),   # MXU-aligned hd
    ])
def test_flash_attention_vs_ref(bh, hkv, sq, sk, hd, causal, window,
                                bq, bk, dtype):
    q = rand((bh, sq, hd), dtype)
    k = rand((hkv, sk, hd), dtype)
    v = rand((hkv, sk, hd), dtype)
    out = flash_attention_flat(q, k, v, causal=causal, window=window,
                               block_q=bq, block_k=bk, interpret=True)
    ref = kref.attention_flat_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32),
        **TOL[dtype])


# ---------------------------------------------------------------- decode attn


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "b,h,hkv,s,hd,bs",
    [
        (2, 4, 4, 256, 64, 128),
        (2, 8, 2, 256, 64, 128),        # GQA 4:1
        (3, 4, 1, 300, 32, 128),        # MQA + padded seq
        (1, 16, 8, 512, 128, 256),
    ])
def test_decode_attention_vs_ref(b, h, hkv, s, hd, bs, dtype):
    q = rand((b, h, hd), dtype)
    k = rand((b, s, hkv, hd), dtype)
    v = rand((b, s, hkv, hd), dtype)
    lengths = jnp.asarray(RNG.integers(1, s + 1, size=b), jnp.int32)
    out = decode_attention(q, k, v, lengths, block_s=bs, interpret=True)
    ref = kref.decode_attention_ref(q, k, v, lengths)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32),
        **TOL[dtype])


# ---------------------------------------------------------------- rglru


@pytest.mark.parametrize(
    "b,s,w,bt,with_h0",
    [
        (2, 128, 64, 64, False),
        (2, 128, 64, 64, True),
        (1, 300, 32, 128, True),        # padded seq
        (3, 64, 128, 64, False),
        (2, 16, 8, 16, True),           # tiny
    ])
def test_rglru_scan_vs_ref(b, s, w, bt, with_h0):
    log_a = -jnp.abs(rand((b, s, w)) * 0.3)     # decays in (0, 1]
    bv = rand((b, s, w))
    h0 = rand((b, w)) if with_h0 else None
    out = rglru_scan(log_a, bv, h0, block_t=bt, interpret=True)
    ref = kref.rglru_ref(log_a, bv, h0)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------- mlstm


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "bh,s,hd,chunk",
    [
        (2, 128, 32, 64),
        (4, 256, 64, 128),
        (1, 64, 128, 64),
        (2, 128, 32, 128),              # single chunk
    ])
def test_mlstm_chunkwise_vs_sequential(bh, s, hd, chunk, dtype):
    q = rand((bh, s, hd), dtype, 0.3)
    k = rand((bh, s, hd), dtype, 0.3)
    v = rand((bh, s, hd), dtype, 0.3)
    ig = rand((bh, s), jnp.float32)
    fg = rand((bh, s), jnp.float32) + 2.0
    out = mlstm_chunkwise(q, k, v, ig, fg, chunk=chunk, interpret=True)
    # oracle: sequential step form over (B=bh, H=1) heads
    c0 = jnp.zeros((bh, 1, hd, hd), jnp.float32)
    n0 = jnp.zeros((bh, 1, hd), jnp.float32)
    ref, _ = kref.mlstm_seq_ref(q[:, :, None, :], k[:, :, None, :],
                                v[:, :, None, :], ig[:, :, None],
                                fg[:, :, None], c0, n0)
    tol = dict(rtol=5e-2, atol=5e-2) if dtype == jnp.bfloat16 else \
        dict(rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(
        np.asarray(out, np.float32),
        np.asarray(ref[:, :, 0, :], np.float32), **tol)


def test_mlstm_matches_model_chunkwise():
    """Kernel == the model's jnp chunkwise form (exact same algorithm)."""
    from repro.models.xlstm import mlstm_chunkwise as model_chunkwise

    bh, s, hd = 3, 256, 32
    q, k, v = (rand((bh, s, hd), jnp.float32, 0.3) for _ in range(3))
    ig = rand((bh, s), jnp.float32)
    fg = rand((bh, s), jnp.float32) + 2.0
    out = mlstm_chunkwise(q, k, v, ig, fg, chunk=128, interpret=True)
    c0 = jnp.zeros((bh, 1, hd, hd), jnp.float32)
    n0 = jnp.zeros((bh, 1, hd), jnp.float32)
    ref, _ = model_chunkwise(q[:, :, None, :], k[:, :, None, :],
                             v[:, :, None, :], ig[:, :, None],
                             fg[:, :, None], c0, n0, chunk=128)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(ref[:, :, 0, :]),
                               rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------- minskew


@pytest.mark.parametrize(
    "n,s,bn,bs",
    [
        (64, 16, 32, 8),
        (200, 40, 64, 16),              # padded both dims
        (512, 128, 512, 128),
        (1000, 3, 256, 8),
    ])
def test_minskew_vs_ref(n, s, bn, bs):
    vtime = jnp.asarray(RNG.integers(0, 10_000, n), jnp.int32)
    runnable = jnp.asarray(RNG.random(n) < 0.7, jnp.int8)
    membership = jnp.asarray(RNG.random((n, s)) < 0.3, jnp.int8)
    skew = jnp.asarray(RNG.integers(1, 500, s), jnp.int32)
    minima, elig = minskew(vtime, runnable, membership, skew,
                           block_n=bn, block_s=bs, interpret=True)
    ref_min, ref_elig = kref.minskew_ref(vtime, runnable != 0,
                                         membership != 0, skew)
    np.testing.assert_array_equal(np.asarray(minima), ref_min)
    np.testing.assert_array_equal(np.asarray(elig) != 0, ref_elig)


def test_minskew_matches_engine_jax():
    from repro.core.engine_jax import eligibility, scope_minima

    n, s = 300, 25
    vtime = jnp.asarray(RNG.integers(0, 10_000, n), jnp.int32)
    runnable = jnp.asarray(RNG.random(n) < 0.6)
    membership = jnp.asarray(RNG.random((n, s)) < 0.25)
    skew = jnp.asarray(RNG.integers(1, 500, s), jnp.int32)
    minima_k, elig_k = minskew(vtime, runnable.astype(jnp.int8),
                               membership.astype(jnp.int8), skew,
                               interpret=True)
    minima_e = scope_minima(vtime, runnable, membership)
    elig_e = eligibility(vtime, runnable, membership, skew, minima_e)
    np.testing.assert_array_equal(np.asarray(minima_k),
                                  np.asarray(minima_e))
    np.testing.assert_array_equal(np.asarray(elig_k) != 0,
                                  np.asarray(elig_e))


# ---------------------------------------------------------------- hub_route


@pytest.mark.parametrize(
    "m,n_links,block",
    [
        (64, 4, 64),
        (500, 7, 128),                  # padded
        (2048, 1, 512),                 # one hot link
        (33, 33, 64),                   # one msg per link
    ])
def test_hub_route_vs_ref(m, n_links, block):
    link_id = np.sort(RNG.integers(0, n_links, m)).astype(np.int32)
    send = np.zeros(m, np.int64)
    # per-link sorted send times
    for l in range(n_links):
        idx = np.where(link_id == l)[0]
        send[idx] = np.sort(RNG.integers(0, 100_000, len(idx)))
    size = RNG.integers(64, 65_536, m).astype(np.int32)
    bw = RNG.uniform(1e9, 100e9, n_links)
    lat = RNG.integers(100, 10_000, n_links).astype(np.int32)
    out = hub_route(jnp.asarray(send, jnp.int32), jnp.asarray(size),
                    jnp.asarray(link_id), jnp.asarray(bw, jnp.float32),
                    jnp.asarray(lat), block=block, interpret=True)
    ref = hub_visibility_ref(send, size, link_id, bw, lat)
    # serialization rounding: float32 vs float64 division -> +-1ns slop
    np.testing.assert_allclose(np.asarray(out, np.int64), ref, atol=16)
