"""Per-kernel allclose vs. pure-jnp/numpy oracles, interpret mode on CPU.

Every kernel sweeps shapes (incl. non-divisible / padded cases) and
dtypes per the deliverable-(c) requirement."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.engine_jax import hub_visibility_ref
from repro.kernels import ref as kref
from repro.kernels.decode_attention import decode_attention
from repro.kernels.flash_attention import flash_attention_flat
from repro.kernels.hub_route import hub_route
from repro.kernels.minskew import minskew
from repro.kernels.mlstm_kernel import mlstm_chunkwise
from repro.kernels.rglru_scan import rglru_scan

RNG = np.random.default_rng(42)


def rand(shape, dtype=jnp.float32, scale=1.0):
    return jnp.asarray(RNG.standard_normal(shape) * scale, dtype)


TOL = {jnp.float32: dict(rtol=2e-5, atol=2e-5),
       jnp.bfloat16: dict(rtol=2e-2, atol=2e-2)}


# ---------------------------------------------------------------- flash attn


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "bh,hkv,sq,sk,hd,causal,window,bq,bk",
    [
        (4, 4, 128, 128, 64, True, 0, 64, 64),
        (4, 2, 128, 128, 64, True, 0, 64, 64),      # GQA 2:1
        (8, 2, 96, 96, 32, True, 0, 64, 64),        # padded seq
        (2, 1, 256, 256, 64, True, 64, 64, 64),     # sliding window
        (2, 2, 64, 192, 32, False, 0, 64, 64),      # cross attention
        (6, 3, 128, 128, 128, True, 0, 128, 128),   # MXU-aligned hd
    ])
def test_flash_attention_vs_ref(bh, hkv, sq, sk, hd, causal, window,
                                bq, bk, dtype):
    q = rand((bh, sq, hd), dtype)
    k = rand((hkv, sk, hd), dtype)
    v = rand((hkv, sk, hd), dtype)
    out = flash_attention_flat(q, k, v, causal=causal, window=window,
                               block_q=bq, block_k=bk, interpret=True)
    ref = kref.attention_flat_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32),
        **TOL[dtype])


# ---------------------------------------------------------------- decode attn


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "b,h,hkv,s,hd,bs",
    [
        (2, 4, 4, 256, 64, 128),
        (2, 8, 2, 256, 64, 128),        # GQA 4:1
        (3, 4, 1, 300, 32, 128),        # MQA + padded seq
        (1, 16, 8, 512, 128, 256),
    ])
def test_decode_attention_vs_ref(b, h, hkv, s, hd, bs, dtype):
    q = rand((b, h, hd), dtype)
    k = rand((b, s, hkv, hd), dtype)
    v = rand((b, s, hkv, hd), dtype)
    lengths = jnp.asarray(RNG.integers(1, s + 1, size=b), jnp.int32)
    out = decode_attention(q, k, v, lengths, block_s=bs, interpret=True)
    ref = kref.decode_attention_ref(q, k, v, lengths)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32),
        **TOL[dtype])


# ---------------------------------------------------------------- rglru


@pytest.mark.parametrize(
    "b,s,w,bt,with_h0",
    [
        (2, 128, 64, 64, False),
        (2, 128, 64, 64, True),
        (1, 300, 32, 128, True),        # padded seq
        (3, 64, 128, 64, False),
        (2, 16, 8, 16, True),           # tiny
    ])
def test_rglru_scan_vs_ref(b, s, w, bt, with_h0):
    log_a = -jnp.abs(rand((b, s, w)) * 0.3)     # decays in (0, 1]
    bv = rand((b, s, w))
    h0 = rand((b, w)) if with_h0 else None
    out = rglru_scan(log_a, bv, h0, block_t=bt, interpret=True)
    ref = kref.rglru_ref(log_a, bv, h0)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------- mlstm


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "bh,s,hd,chunk",
    [
        (2, 128, 32, 64),
        (4, 256, 64, 128),
        (1, 64, 128, 64),
        (2, 128, 32, 128),              # single chunk
    ])
def test_mlstm_chunkwise_vs_sequential(bh, s, hd, chunk, dtype):
    q = rand((bh, s, hd), dtype, 0.3)
    k = rand((bh, s, hd), dtype, 0.3)
    v = rand((bh, s, hd), dtype, 0.3)
    ig = rand((bh, s), jnp.float32)
    fg = rand((bh, s), jnp.float32) + 2.0
    out = mlstm_chunkwise(q, k, v, ig, fg, chunk=chunk, interpret=True)
    # oracle: sequential step form over (B=bh, H=1) heads
    c0 = jnp.zeros((bh, 1, hd, hd), jnp.float32)
    n0 = jnp.zeros((bh, 1, hd), jnp.float32)
    ref, _ = kref.mlstm_seq_ref(q[:, :, None, :], k[:, :, None, :],
                                v[:, :, None, :], ig[:, :, None],
                                fg[:, :, None], c0, n0)
    tol = dict(rtol=5e-2, atol=5e-2) if dtype == jnp.bfloat16 else \
        dict(rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(
        np.asarray(out, np.float32),
        np.asarray(ref[:, :, 0, :], np.float32), **tol)


def test_mlstm_matches_model_chunkwise():
    """Kernel == the model's jnp chunkwise form (exact same algorithm)."""
    from repro.models.xlstm import mlstm_chunkwise as model_chunkwise

    bh, s, hd = 3, 256, 32
    q, k, v = (rand((bh, s, hd), jnp.float32, 0.3) for _ in range(3))
    ig = rand((bh, s), jnp.float32)
    fg = rand((bh, s), jnp.float32) + 2.0
    out = mlstm_chunkwise(q, k, v, ig, fg, chunk=128, interpret=True)
    c0 = jnp.zeros((bh, 1, hd, hd), jnp.float32)
    n0 = jnp.zeros((bh, 1, hd), jnp.float32)
    ref, _ = model_chunkwise(q[:, :, None, :], k[:, :, None, :],
                             v[:, :, None, :], ig[:, :, None],
                             fg[:, :, None], c0, n0, chunk=128)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(ref[:, :, 0, :]),
                               rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------- minskew


@pytest.mark.parametrize(
    "n,s,bn,bs",
    [
        (64, 16, 32, 8),
        (200, 40, 64, 16),              # padded both dims
        (512, 128, 512, 128),
        (1000, 3, 256, 8),
    ])
def test_minskew_vs_ref(n, s, bn, bs):
    vtime = jnp.asarray(RNG.integers(0, 10_000, n), jnp.int32)
    runnable = jnp.asarray(RNG.random(n) < 0.7, jnp.int8)
    membership = jnp.asarray(RNG.random((n, s)) < 0.3, jnp.int8)
    skew = jnp.asarray(RNG.integers(1, 500, s), jnp.int32)
    minima, elig = minskew(vtime, runnable, membership, skew,
                           block_n=bn, block_s=bs, interpret=True)
    ref_min, ref_elig = kref.minskew_ref(vtime, runnable != 0,
                                         membership != 0, skew)
    np.testing.assert_array_equal(np.asarray(minima), ref_min)
    np.testing.assert_array_equal(np.asarray(elig) != 0, ref_elig)


def test_minskew_matches_engine_jax():
    from repro.core.engine_jax import eligibility, scope_minima

    n, s = 300, 25
    vtime = jnp.asarray(RNG.integers(0, 10_000, n), jnp.int32)
    runnable = jnp.asarray(RNG.random(n) < 0.6)
    membership = jnp.asarray(RNG.random((n, s)) < 0.25)
    skew = jnp.asarray(RNG.integers(1, 500, s), jnp.int32)
    minima_k, elig_k = minskew(vtime, runnable.astype(jnp.int8),
                               membership.astype(jnp.int8), skew,
                               interpret=True)
    minima_e = scope_minima(vtime, runnable, membership)
    elig_e = eligibility(vtime, runnable, membership, skew, minima_e)
    np.testing.assert_array_equal(np.asarray(minima_k),
                                  np.asarray(minima_e))
    np.testing.assert_array_equal(np.asarray(elig_k) != 0,
                                  np.asarray(elig_e))


# ---------------------------------------------------------------- hub_route


@pytest.mark.parametrize(
    "m,n_links,block",
    [
        (64, 4, 64),
        (500, 7, 128),                  # padded
        (2048, 1, 512),                 # one hot link
        (33, 33, 64),                   # one msg per link
    ])
def test_hub_route_vs_ref(m, n_links, block):
    link_id = np.sort(RNG.integers(0, n_links, m)).astype(np.int32)
    send = np.zeros(m, np.int64)
    # per-link sorted send times
    for l in range(n_links):
        idx = np.where(link_id == l)[0]
        send[idx] = np.sort(RNG.integers(0, 100_000, len(idx)))
    size = RNG.integers(64, 65_536, m).astype(np.int32)
    bw = RNG.uniform(1e9, 100e9, n_links)
    lat = RNG.integers(100, 10_000, n_links).astype(np.int32)
    out = hub_route(jnp.asarray(send, jnp.int32), jnp.asarray(size),
                    jnp.asarray(link_id), jnp.asarray(bw, jnp.float32),
                    jnp.asarray(lat), block=block, interpret=True)
    ref = hub_visibility_ref(send, size, link_id, bw, lat)
    # serialization rounding: float32 vs float64 division -> +-1ns slop
    np.testing.assert_allclose(np.asarray(out, np.int64), ref, atol=16)


# ------------------------------------------------- minskew edge cases (sim)


INF = 2**30


def _minskew_case(vtime, runnable, membership, skew, **kw):
    vtime = jnp.asarray(vtime, jnp.int32)
    runnable = np.asarray(runnable, bool)
    membership = np.asarray(membership, bool)
    skew = jnp.asarray(skew, jnp.int32)
    minima, elig = minskew(vtime, jnp.asarray(runnable, jnp.int8),
                           jnp.asarray(membership, jnp.int8), skew,
                           interpret=True, **kw)
    ref_min, ref_elig = kref.minskew_ref(np.asarray(vtime), runnable,
                                         membership, np.asarray(skew))
    np.testing.assert_array_equal(np.asarray(minima), ref_min)
    np.testing.assert_array_equal(np.asarray(elig) != 0, ref_elig)
    return np.asarray(minima), np.asarray(elig) != 0


def test_minskew_all_masked():
    """No runnable member anywhere: minima must be INF and nothing may
    dispatch (a fixpoint round of the vectorized engine)."""
    n, s = 40, 6
    minima, elig = _minskew_case(
        RNG.integers(0, 10_000, n), np.zeros(n, bool),
        RNG.random((n, s)) < 0.4, RNG.integers(1, 500, s))
    assert (minima == INF).all()
    assert not elig.any()


def test_minskew_empty_scope():
    """A scope with zero members is INF-min and must not gate anyone
    (the `minima == INF` escape in the eligibility rule)."""
    n, s = 24, 4
    membership = RNG.random((n, s)) < 0.5
    membership[:, 2] = False                      # nobody in scope 2
    minima, elig = _minskew_case(
        RNG.integers(0, 10_000, n), np.ones(n, bool), membership,
        np.zeros(s, np.int32))
    assert minima[2] == INF
    # zero skew + all runnable: exactly the global-min members of each
    # populated scope dispatch, so someone must be eligible
    assert elig.any()


def test_minskew_sentinel_vtimes():
    """Blocked tasks park at vtime INF in the vectorized engine; INF
    lanes must neither win minima nor become eligible."""
    n, s = 16, 3
    vtime = RNG.integers(0, 10_000, n)
    vtime[::2] = INF
    runnable = np.ones(n, bool)
    runnable[::2] = False
    minima, elig = _minskew_case(vtime, runnable,
                                 np.ones((n, s), bool),
                                 RNG.integers(1, 100, s))
    assert (minima < INF).all()
    assert not elig[::2].any()


def test_minskew_int32_boundary():
    """vtimes near the top of the tick range: minima + skew crosses
    2**30 but must not wrap int32."""
    n, s = 12, 2
    vtime = (INF - 1 - RNG.integers(0, 2_000, n)).astype(np.int64)
    minima, elig = _minskew_case(vtime, np.ones(n, bool),
                                 np.ones((n, s), bool),
                                 np.full(s, 5_000, np.int32))
    assert (minima >= INF - 2_001).all()
    assert elig.all()                   # all within skew of the min


def test_minskew_tiny_shapes():
    """N and S far below one block (padding-dominated grid)."""
    minima, elig = _minskew_case([7], [True], [[True]], [0])
    assert minima[0] == 7 and elig[0]
    _minskew_case(RNG.integers(0, 100, 3), [True, False, True],
                  RNG.random((3, 2)) < 0.5, [10, 20])


# ------------------------------------------------ hub_route ser_ns bypass


@pytest.mark.parametrize("m,block", [(1, 64), (7, 64), (129, 64),
                                     (500, 128)])
def test_hub_route_ser_ns_bitexact(m, block):
    """With integer ``ser_ns`` the kernel must match the sequential
    oracle *bit-exactly* — no float32 serialization slop.  This is the
    contract the vectorized sim engine's exact tier rides on (its tapes
    precompute tick-exact durations; f32 only carries 24 mantissa bits,
    so e.g. 163e9/1e9 would truncate to 162)."""
    n_links = 5
    link_id = np.sort(RNG.integers(0, n_links, m)).astype(np.int32)
    send = np.zeros(m, np.int64)
    for l in range(n_links):
        idx = np.where(link_id == l)[0]
        send[idx] = np.sort(RNG.integers(0, 50_000, len(idx)))
    ser = RNG.integers(0, 10_000, m).astype(np.int32)
    ser[RNG.random(m) < 0.2] = 163       # the f32-hostile value
    size = np.ones(m, np.int32)          # decoys: must be ignored
    bw = np.full(n_links, 1.0)
    lat = RNG.integers(0, 5_000, n_links).astype(np.int32)
    out = hub_route(jnp.asarray(send, jnp.int32), jnp.asarray(size),
                    jnp.asarray(link_id), jnp.asarray(bw, jnp.float32),
                    jnp.asarray(lat), ser_ns=jnp.asarray(ser),
                    block=block, interpret=True)
    ref = hub_visibility_ref(send, size, link_id, bw, lat, ser_ns=ser)
    np.testing.assert_array_equal(np.asarray(out, np.int64), ref)


def test_hub_visibility_ser_ns_bitexact():
    """The jnp scan path honors the same ser_ns bypass, bit-exactly."""
    from repro.core.engine_jax import hub_visibility

    m, n_links = 200, 4
    link_id = np.sort(RNG.integers(0, n_links, m)).astype(np.int32)
    send = np.zeros(m, np.int64)
    for l in range(n_links):
        idx = np.where(link_id == l)[0]
        send[idx] = np.sort(RNG.integers(0, 50_000, len(idx)))
    ser = RNG.integers(0, 10_000, m).astype(np.int32)
    lat = RNG.integers(0, 5_000, n_links).astype(np.int32)
    out = hub_visibility(jnp.asarray(send, jnp.int32),
                         jnp.ones(m, jnp.int32), jnp.asarray(link_id),
                         jnp.ones(n_links, jnp.float32),
                         jnp.asarray(lat), ser_ns=jnp.asarray(ser))
    ref = hub_visibility_ref(send, np.ones(m, np.int32), link_id,
                             np.ones(n_links), lat, ser_ns=ser)
    np.testing.assert_array_equal(np.asarray(out, np.int64), ref)


def test_hub_route_float32_mantissa_demo():
    """Regression pin for the bug the bypass fixes: a 163 ns
    serialization at 1 GB/ns-scale bandwidth truncates to 162 under
    the float32 path, and stays 163 under ser_ns."""
    send = jnp.zeros(1, jnp.int32)
    size = jnp.asarray([163], jnp.int32)
    link = jnp.zeros(1, jnp.int32)
    bw = jnp.asarray([1e9], jnp.float32)
    lat = jnp.zeros(1, jnp.int32)
    f32 = int(hub_route(send, size, link, bw, lat, interpret=True)[0])
    exact = int(hub_route(send, size, link, bw, lat,
                          ser_ns=jnp.asarray([163], jnp.int32),
                          interpret=True)[0])
    assert f32 == 162 and exact == 163
