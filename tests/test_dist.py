"""Unit tests for the multi-process dist engine itself: partitioning,
report merging, API guards, and fault containment (a crashed or hung
worker must fail the run fast — never wedge the caller or CI)."""
import os
import time

import pytest

from repro.dist import DistWorkerError, partition_hosts
from repro.sim import (RackRing, Scenario, Simulation, Topology,
                       Workload)
from repro.sim.workload import Program

pytestmark = pytest.mark.skipif(not hasattr(os, "fork"),
                                reason="dist engine needs fork")


def _rack_sim(n_iters=30):
    wl = RackRing(n_racks=2, hosts_per_rack=2, n_iters=n_iters,
                  skew_bound_ns=2_000_000)
    return Simulation(Topology.racks(2, 2), wl,
                      Scenario("imb", wl.stragglers((1.0, 3.0))),
                      placement=wl.default_placement())


# -- partitioning -------------------------------------------------------------


def test_partition_hosts_contiguous_and_balanced():
    assert partition_hosts(4, 2) == [[0, 1], [2, 3]]
    assert partition_hosts(5, 2) == [[0, 1, 2], [3, 4]]
    assert partition_hosts(3, 3) == [[0], [1], [2]]
    assert partition_hosts(1, 1) == [[0]]
    # every host owned exactly once
    parts = partition_hosts(7, 3)
    assert sorted(h for p in parts for h in p) == list(range(7))


def test_n_workers_clamped_to_hosts():
    rep = _rack_sim(n_iters=10).run(engine="dist", n_workers=16,
                                    worker_timeout=30.0)
    assert rep.n_workers == 4          # 4 hosts -> at most 4 workers
    assert rep.status == "ok"


# -- merged report ------------------------------------------------------------


def test_dist_report_shape():
    rep = _rack_sim().run(engine="dist", n_workers=2,
                          worker_timeout=30.0, on_deadlock="raise")
    assert rep.mode == "dist"
    assert rep.n_workers == 2
    assert rep.sync_rounds > 0                  # cross-partition rounds
    assert rep.cross_host_msgs > 0
    assert [h.host for h in rep.hosts] == [0, 1, 2, 3]
    assert all(t["state"] == "done" for t in rep.tasks.values())
    assert rep.progress["rack"]["iters_done"] == [30] * 4
    # per-link accounting survived the process boundary: every channel
    # respected its conservative lookahead (slack >= 0)
    assert rep.links and all(st["min_slack_ns"] >= 0
                             for st in rep.links.values())
    d = rep.to_dict()                           # JSON-able end to end
    assert d["n_workers"] == 2


def test_dist_progress_written_back_to_workloads():
    wl = RackRing(n_racks=2, hosts_per_rack=2, n_iters=10,
                  skew_bound_ns=2_000_000)
    sim = Simulation(Topology.racks(2, 2), wl,
                     placement=wl.default_placement())
    sim.run(engine="dist", n_workers=2, worker_timeout=30.0,
            on_deadlock="raise")
    # parent-side workload objects see the merged counters, like the
    # in-process engines
    assert wl.iters_done.tolist() == [10] * 4


# -- API guards ---------------------------------------------------------------


def test_dist_rejects_built_simulation():
    sim = _rack_sim()
    sim.build()
    with pytest.raises(ValueError, match="unbuilt"):
        sim.run(engine="dist", n_workers=2)


def test_dist_rejects_bad_worker_count():
    with pytest.raises(ValueError, match="n_workers"):
        _rack_sim().run(engine="dist", n_workers=0)


def test_unknown_engine_rejected():
    with pytest.raises(ValueError, match="unknown engine"):
        _rack_sim().run(engine="warp")


# -- fault containment --------------------------------------------------------


class _ExplodingWorkload(Workload):
    """Builds fine in the parent (declarative), detonates when a worker
    materializes the body."""

    name = "boom"

    def programs(self):
        def make_body(eps):
            raise RuntimeError("kaboom at build")
        return [Program(name="boom0", make_body=make_body)]


def test_crashed_worker_fails_fast_with_traceback():
    sim = Simulation(Topology.single_host(), _ExplodingWorkload())
    with pytest.raises(DistWorkerError, match="kaboom at build"):
        sim.run(engine="dist", n_workers=1, worker_timeout=30.0)


class _SleepyWorkload(Workload):
    """Stalls the worker's build long past the coordinator timeout —
    the moral equivalent of a hung worker process."""

    name = "sleepy"

    def programs(self):
        time.sleep(5.0)
        return []


def test_hung_worker_times_out_instead_of_wedging():
    sim = Simulation(Topology.single_host(), _SleepyWorkload())
    t0 = time.monotonic()
    with pytest.raises(DistWorkerError, match="hung"):
        sim.run(engine="dist", n_workers=1, worker_timeout=0.5)
    assert time.monotonic() - t0 < 4.0          # failed fast, no wedge
