"""Unit tests for the multi-process dist engine itself: partitioning,
report merging, API guards, and fault containment (a crashed or hung
worker must fail the run fast — never wedge the caller or CI)."""
import os
import time

import pytest

from repro.dist import DistWorkerError, partition_hosts
from repro.sim import (RackRing, Scenario, Simulation, Topology,
                       Workload)
from repro.sim.workload import Program

pytestmark = pytest.mark.skipif(not hasattr(os, "fork"),
                                reason="dist engine needs fork")


def _rack_sim(n_iters=30):
    wl = RackRing(n_racks=2, hosts_per_rack=2, n_iters=n_iters,
                  skew_bound_ns=2_000_000)
    return Simulation(Topology.racks(2, 2), wl,
                      Scenario("imb", wl.stragglers((1.0, 3.0))),
                      placement=wl.default_placement())


# -- partitioning -------------------------------------------------------------


def test_partition_hosts_contiguous_and_balanced():
    assert partition_hosts(4, 2) == [[0, 1], [2, 3]]
    assert partition_hosts(5, 2) == [[0, 1, 2], [3, 4]]
    assert partition_hosts(3, 3) == [[0], [1], [2]]
    assert partition_hosts(1, 1) == [[0]]
    # every host owned exactly once
    parts = partition_hosts(7, 3)
    assert sorted(h for p in parts for h in p) == list(range(7))


def test_n_workers_clamped_to_hosts():
    rep = _rack_sim(n_iters=10).run(engine="dist", n_workers=16,
                                    worker_timeout=30.0)
    assert rep.n_workers == 4          # 4 hosts -> at most 4 workers
    assert rep.status == "ok"


# -- merged report ------------------------------------------------------------


def test_dist_report_shape():
    rep = _rack_sim().run(engine="dist", n_workers=2,
                          worker_timeout=30.0, on_deadlock="raise")
    assert rep.mode == "dist"
    assert rep.n_workers == 2
    assert rep.sync_rounds > 0                  # cross-partition rounds
    assert rep.cross_host_msgs > 0
    assert [h.host for h in rep.hosts] == [0, 1, 2, 3]
    assert all(t["state"] == "done" for t in rep.tasks.values())
    assert rep.progress["rack"]["iters_done"] == [30] * 4
    # per-link accounting survived the process boundary: every channel
    # respected its conservative lookahead (slack >= 0)
    assert rep.links and all(st["min_slack_ns"] >= 0
                             for st in rep.links.values())
    d = rep.to_dict()                           # JSON-able end to end
    assert d["n_workers"] == 2


def test_dist_progress_written_back_to_workloads():
    wl = RackRing(n_racks=2, hosts_per_rack=2, n_iters=10,
                  skew_bound_ns=2_000_000)
    sim = Simulation(Topology.racks(2, 2), wl,
                     placement=wl.default_placement())
    sim.run(engine="dist", n_workers=2, worker_timeout=30.0,
            on_deadlock="raise")
    # parent-side workload objects see the merged counters, like the
    # in-process engines
    assert wl.iters_done.tolist() == [10] * 4


# -- API guards ---------------------------------------------------------------


def test_dist_rejects_built_simulation():
    sim = _rack_sim()
    sim.build()
    with pytest.raises(ValueError, match="unbuilt"):
        sim.run(engine="dist", n_workers=2)


def test_dist_rejects_bad_worker_count():
    with pytest.raises(ValueError, match="n_workers"):
        _rack_sim().run(engine="dist", n_workers=0)


def test_unknown_engine_rejected():
    with pytest.raises(ValueError, match="unknown engine"):
        _rack_sim().run(engine="warp")


# -- fault containment --------------------------------------------------------


class _ExplodingWorkload(Workload):
    """Builds fine in the parent (declarative), detonates when a worker
    materializes the body."""

    name = "boom"

    def programs(self):
        def make_body(eps):
            raise RuntimeError("kaboom at build")
        return [Program(name="boom0", make_body=make_body)]


def test_crashed_worker_fails_fast_with_traceback():
    sim = Simulation(Topology.single_host(), _ExplodingWorkload())
    with pytest.raises(DistWorkerError, match="kaboom at build"):
        sim.run(engine="dist", n_workers=1, worker_timeout=30.0)


class _SleepyWorkload(Workload):
    """Stalls the worker's build long past the coordinator timeout —
    the moral equivalent of a hung worker process."""

    name = "sleepy"

    def programs(self):
        time.sleep(5.0)
        return []


def test_hung_worker_times_out_instead_of_wedging():
    sim = Simulation(Topology.single_host(), _SleepyWorkload())
    t0 = time.monotonic()
    with pytest.raises(DistWorkerError, match="hung"):
        sim.run(engine="dist", n_workers=1, worker_timeout=0.5)
    assert time.monotonic() - t0 < 4.0          # failed fast, no wedge


# -- binary wire format -------------------------------------------------------


def test_envelope_frame_roundtrip():
    """Envelope records survive pack -> routing scan -> full unpack,
    for both the payload-free fast path and pickled payloads."""
    from repro.dist import frames

    cases = [
        dict(src_hub=3, dst_hub=65535, src_ep=7, dst_ep=123456,
             size_bytes=5038080, send_vtime=2**45, seq=991,
             sent_at=12345, hops=2, payload=None),
        dict(src_hub=0, dst_hub=1, src_ep=0, dst_ep=0, size_bytes=0,
             send_vtime=0, seq=0, sent_at=0, hops=0,
             payload={"client": 3, "xs": [1, 2, 3]}),
    ]
    buf = b"".join(frames.pack_envelope(**c) for c in cases)
    off = 0
    for c in cases:
        # the coordinator's routing scan reads dst hub + send vtime
        # without decoding the record
        dst_hub, send_vt, end = frames.scan_envelope(buf, off)
        assert dst_hub == c["dst_hub"] and send_vt == c["send_vtime"]
        fields, payload, end2 = frames.unpack_envelope(buf, off)
        assert end2 == end
        assert fields == (c["src_hub"], c["dst_hub"], c["src_ep"],
                          c["dst_ep"], c["size_bytes"], c["send_vtime"],
                          c["seq"], c["sent_at"], c["hops"])
        assert payload == c["payload"]
        off = end
    assert off == len(buf)


def test_step_and_reply_frame_roundtrip():
    from repro.dist import frames

    env = [frames.pack_envelope(src_hub=1, dst_hub=2, src_ep=3,
                                dst_ep=4, size_bytes=10,
                                send_vtime=1000, seq=5, sent_at=900,
                                hops=1, payload=None)]
    step = frames.pack_step({0: 5000, 1: None}, {7: (123, 1)}, env)
    bounds, updates, buf, off, n_env = frames.unpack_step(step)
    assert bounds == {0: 5000, 1: None}
    assert updates == {7: (123, 1)}
    assert n_env == 1
    fields, payload, _ = frames.unpack_envelope(buf, off)
    assert fields[1] == 2 and payload is None

    reply = frames.pack_reply(
        unfinished=True, applied=False, lazy_changed=True,
        dispatches=42, wakes=3, next_times={2: None, 3: 777},
        task_states={9: (55, 2)}, envelopes=env)
    r = frames.Reply(reply)
    assert (r.unfinished, r.applied, r.lazy_changed) == (True, False,
                                                         True)
    assert (r.dispatches, r.wakes) == (42, 3)
    assert r.next_times == {2: None, 3: 777}
    assert r.task_states == {9: (55, 2)}
    assert len(r.envelopes) == 1
    dst_hub, send_vt, record = r.envelopes[0]
    assert (dst_hub, send_vt) == (2, 1000)
    assert record == env[0]


def test_dist_payloads_cross_partitions():
    """Non-None message payloads (pickled per record) survive the
    binary transport: ModeledServe routes client ids in payloads."""
    from repro.core.ipc import LinkSpec
    from repro.sim import ModeledServe

    def make():
        wl = ModeledServe(n_clients=3, n_requests=5)
        return Simulation(
            Topology.full_mesh(2, LinkSpec(bandwidth_bps=25e9 * 8,
                                           latency_ns=10_000)), wl,
                          placement={"serve.server": 0,
                                     "serve.client0": 1,
                                     "serve.client1": 0,
                                     "serve.client2": 1})
    inproc = make().run(engine="async", on_deadlock="raise")
    dist = make().run(engine="dist", n_workers=2, worker_timeout=30.0,
                      on_deadlock="raise")
    assert dist.tasks == inproc.tasks
    assert dist.progress == inproc.progress


class _FireAndForget(Workload):
    """The sender's LAST action is a send; the receiver finishes
    without ever receiving.  The message is still in flight when every
    task is done — a cross-partition transport must deliver and replay
    it anyway, or message/byte totals and per-link stats diverge from
    the in-process engines."""

    name = "faf"

    def fabrics(self):
        from repro.core.ipc import LinkSpec
        from repro.sim.topology import FabricSpec
        return [FabricSpec("hub", LinkSpec(bandwidth_bps=80e9 * 8,
                                           latency_ns=500))]

    def programs(self):
        from repro.core.vtask import Compute, Send
        from repro.sim.workload import EndpointSpec

        def sender(eps):
            ep = eps["faf.w0"]

            def body():
                yield Compute(10_000)
                yield Send(ep, "faf.w1", 4096)
            return body()

        def receiver(eps):
            def body():
                yield Compute(100)      # never receives
            return body()

        return [Program(name="faf.w0", make_body=sender,
                        endpoints=(EndpointSpec("faf.w0", "hub"),)),
                Program(name="faf.w1", make_body=receiver,
                        endpoints=(EndpointSpec("faf.w1", "hub"),))]


def test_in_flight_message_delivered_after_all_tasks_finish():
    from engine_harness import assert_engines_agree

    def make():
        return Simulation(Topology.racks(1, 2), _FireAndForget(),
                          placement={"faf.w0": 0, "faf.w1": 1})

    reports = assert_engines_agree(make, label="fire-and-forget")
    # the orphaned message was routed everywhere (1 intra + ... the
    # cross-host leg counts once on the destination hub)
    assert reports["async"].messages == 1
    assert all(r.messages == 1 for r in reports.values())


def test_sole_worker_heartbeats_keep_long_runs_alive(monkeypatch):
    """n_workers=1 free-runs the async engine in chunks, ticking the
    coordinator between chunks — worker_timeout bounds reply liveness,
    not total run length.  Chunk size 1 forces a tick every engine
    round; the run must still complete (and stay correct) with a
    timeout far below the total wall time of a tickless run."""
    from repro.dist.worker import DistWorker

    monkeypatch.setattr(DistWorker, "RUN_ALL_CHUNK", 1)
    ref = _rack_sim().run(engine="async", on_deadlock="raise")
    rep = _rack_sim().run(engine="dist", n_workers=1,
                          worker_timeout=10.0, on_deadlock="raise")
    assert rep.status == "ok"
    assert rep.tasks == ref.tasks
    assert rep.sync_rounds == ref.sync_rounds   # it IS the async engine
