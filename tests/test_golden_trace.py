"""Golden-trace regression for the example scenario gallery.

``tests/golden/gallery.json`` is the canonical compact SimReport for
the seven scenarios ``examples/cluster_sim.py`` showcases (straggler +
mid-run host death, mid-run cross-rack link degradation, co-located
serve+train interference, co-located live cells with §3.3
memory-hierarchy charges, the live trainer recovery replayed from its
checked-in recorded trace, the live serve stack under open-loop
arrivals, and the co-located live train + live serve cells scenario —
the latter three all replayed from checked-in recorded traces), at CI
smoke sizes.  The test re-runs them
and diffs the *timing-bearing* fields — status, horizon, message and
byte totals, per-task final vtimes/states, progress arrays, per-host
cell accounting — so an engine refactor cannot silently shift
simulated timings: any shift must come with a reviewed golden update.

Each golden also pins a ``perf`` record — the default engine's
``sync_rounds`` and ``proxy_syncs`` aggregates — so a
coordination-overhead regression (an engine suddenly needing more
rounds or proxy refreshes for the same simulation) fails CI instead of
relying on wall-clock eyeballing.  These are deterministic for a fixed
engine; they are allowed to *change* with a reviewed ``--regen``, just
never silently.

Other engine-dependent counters (wall clock, window sizes) stay
excluded — engines are free to trade those off.

Regenerate after an *intentional* timing change:

    PYTHONPATH=src python tests/test_golden_trace.py --regen
"""
import json
import pathlib
import sys

import pytest

from repro.core.cluster import ClusterSpec, StepCost
from repro.sim import (ChipRingTraining, CostLedger, DegradeLink,
                       FailHost, ModeledServe, RackRing, Scenario,
                       Simulation, Straggler, Topology,
                       live_colocated_sim, live_recovery_sim,
                       live_serve_sim)

GOLDEN = pathlib.Path(__file__).parent / "golden" / "gallery.json"
LIVE_TRACE = (pathlib.Path(__file__).parent / "golden"
              / "live_recovery_trace.json")
SERVE_TRACE = (pathlib.Path(__file__).parent / "golden"
               / "live_serve_trace.json")
COLOCATED_TRACE = (pathlib.Path(__file__).parent / "golden"
                   / "live_colocated_trace.json")

#: the canonical (deterministic, machine-independent) report subset
CANONICAL_FIELDS = ("scenario", "status", "n_hosts", "vtime_ns",
                    "messages", "bytes", "tasks", "progress", "cells")

N_ITERS = 40
N_STEPS = 8


def _gallery():
    def straggler_host_death():
        wl = RackRing(n_iters=N_ITERS, skew_bound_ns=2_000_000)
        return Simulation(
            Topology.racks(2, 2), wl,
            Scenario("straggler + host 3 dies",
                     (Straggler("w1", 2.0),
                      FailHost(host=3, at_vtime=N_ITERS * 4_000))),
            placement=wl.default_placement())

    def degraded_link():
        wl = RackRing(n_iters=N_ITERS, skew_bound_ns=2_000_000)
        return Simulation(
            Topology.racks(2, 2), wl,
            Scenario("link 0<->2 8x latency",
                     (DegradeLink(hosts=(0, 2), latency_factor=8.0,
                                  from_vtime=N_ITERS * 1_000),)),
            placement=wl.default_placement())

    def colocated_serve_train():
        spec = ClusterSpec(n_pods=1, chips_per_pod=4)
        cost = StepCost(compute_ns=500_000, ici_bytes=1_000_000)
        return Simulation(
            Topology.single_host(n_cpus=1),
            [ChipRingTraining(spec, cost, N_STEPS,
                              skew_bound_ns=5_000_000),
             ModeledServe(n_clients=4, n_requests=N_STEPS,
                          service_ns=500_000)],
            Scenario("co-located serve + train"),
            cpu_resource=True)

    def colocated_cells():
        cells = {"w0": "hot", "w1": "cold", "w2": "hot", "w3": "cold"}
        wl = RackRing(n_racks=1, hosts_per_rack=4, n_iters=N_ITERS,
                      compute_ns=50_000, live=True, cells=cells,
                      skew_bound_ns=2_000_000)
        topo = Topology.single_host(n_cpus=1)
        topo.cell("hot", ways=2, working_set_frac=0.7, bw_share=0.3,
                  bw_demand=0.7, mem_frac=0.6)
        topo.cell("cold", ways=8, working_set_frac=0.3, bw_share=0.5,
                  bw_demand=0.4, mem_frac=0.2)
        topo.cell_config(n_warm_slots=2, recondition_ns=20_000)
        return Simulation(topo, wl, Scenario("co-located cells"))

    def live_recovery():
        # the marquee live scenario, replayed from the checked-in
        # recorded trace (one record run of the real sharded trainer;
        # re-record with `python -m repro.live record`) — golden-pinned
        # like any modeled scenario, recovery timeline included
        return live_recovery_sim(CostLedger.replay(LIVE_TRACE))

    def live_serve():
        # the serve half of the live stack: real BatchServer waves
        # under open-loop Poisson arrivals, replayed from the
        # checked-in trace (re-record with `python -m repro.live
        # record --scenario serve`) — latency percentiles and
        # queue-depth stats land in the golden live section
        return live_serve_sim(CostLedger.replay(SERVE_TRACE))

    def live_colocated():
        # live-on-live: real trainer + real server sharing host 0 and
        # one §3.3 cell, both replayed from ONE multi-driver trace
        # (re-record with `python -m repro.live record --scenario
        # colocated`) — cell co-activity charges are golden-pinned
        return live_colocated_sim(CostLedger.replay(COLOCATED_TRACE))

    return {"straggler_host_death": straggler_host_death,
            "degraded_link": degraded_link,
            "colocated_serve_train": colocated_serve_train,
            "colocated_cells": colocated_cells,
            "live_recovery": live_recovery,
            "live_serve": live_serve,
            "live_colocated": live_colocated}


def canonical(report) -> dict:
    d = report.to_dict()
    out = {k: d[k] for k in CANONICAL_FIELDS}
    out["perf"] = {"sync_rounds": report.sync_rounds,
                   "proxy_syncs": report.proxy_syncs}
    if report.live:
        # live sections (recovery timelines) are golden-pinned too;
        # omitted when empty so pre-live gallery rows stay byte-identical
        out["live"] = d["live"]
    return out


def vec_canonical(report) -> dict:
    """Canonical subset of a vectorized-engine run: the same
    timing-bearing fields plus the compiled tick/tier (no ``perf`` —
    round counts are engine-dependent)."""
    d = report.to_dict()
    out = {k: d[k] for k in CANONICAL_FIELDS}
    out["tier"] = report.tier
    out["tick_ns"] = report.tick_ns
    return out


def compute_traces() -> dict:
    from repro.sim import UnsupportedByEngine

    traces = {}
    for name, make in sorted(_gallery().items()):
        rec = canonical(make().run())
        try:
            # exact-tier scenarios additionally pin the vectorized
            # compiler's output; cpu_resource/cell scenarios raise and
            # simply carry no vectorized row
            rec["vectorized"] = vec_canonical(
                make().run(engine="vectorized", verify=True))
        except UnsupportedByEngine:
            pass
        traces[name] = rec
    return traces


@pytest.mark.parametrize("name", sorted(_gallery()))
def test_gallery_matches_golden_trace(name):
    golden = json.loads(GOLDEN.read_text())
    assert name in golden, (
        f"no golden trace for {name!r}; regenerate with "
        f"PYTHONPATH=src python {__file__} --regen")
    got = canonical(_gallery()[name]().run())
    want = golden[name]
    assert got.get("live") == want.get("live"), (
        f"{name}: live section shifted from the golden trace\n"
        f" got: {got.get('live')!r}\nwant: {want.get('live')!r}")
    for field in CANONICAL_FIELDS + ("perf",):
        assert got[field] == want[field], (
            f"{name}: {field} shifted from the golden trace "
            f"(intentional? regenerate with --regen and review the "
            f"diff)\n got: {got[field]!r}\nwant: {want[field]!r}")


#: gallery scenarios on the vectorized engine's admissible surface —
#: their golden records also pin the compiled (exact-tier) output
VEC_SCENARIOS = ("straggler_host_death", "degraded_link")


@pytest.mark.parametrize("name", VEC_SCENARIOS)
def test_gallery_vectorized_matches_golden_trace(name):
    golden = json.loads(GOLDEN.read_text())
    want = golden[name].get("vectorized")
    assert want is not None, (
        f"no vectorized golden for {name!r}; regenerate with "
        f"PYTHONPATH=src python {__file__} --regen")
    rep = _gallery()[name]().run(engine="vectorized", verify=True)
    got = vec_canonical(rep)
    assert rep.tier == "exact", f"{name}: compiled tier={rep.tier!r}"
    for field in CANONICAL_FIELDS + ("tier", "tick_ns"):
        assert got[field] == want[field], (
            f"{name}: vectorized {field} shifted from the golden "
            f"trace\n got: {got[field]!r}\nwant: {want[field]!r}")
    # and the compiled run must agree with the *reference engine's*
    # committed golden on every shared timing-bearing field: two
    # independently stored records, one simulation
    for field in CANONICAL_FIELDS:
        assert got[field] == golden[name][field], (
            f"{name}: vectorized diverges from the reference golden "
            f"on {field}: {got[field]!r} != {golden[name][field]!r}")


if __name__ == "__main__":
    if "--regen" not in sys.argv:
        sys.exit(f"usage: PYTHONPATH=src python {sys.argv[0]} --regen")
    GOLDEN.parent.mkdir(exist_ok=True)
    GOLDEN.write_text(json.dumps(compute_traces(), indent=1,
                                 sort_keys=True) + "\n")
    print(f"wrote {GOLDEN}")
