"""Golden-trace regression for the example scenario gallery.

``tests/golden/gallery.json`` is the canonical compact SimReport for
the seven scenarios ``examples/cluster_sim.py`` showcases (straggler +
mid-run host death, mid-run cross-rack link degradation, co-located
serve+train interference, co-located live cells with §3.3
memory-hierarchy charges, the live trainer recovery replayed from its
checked-in recorded trace, the live serve stack under open-loop
arrivals, and the co-located live train + live serve cells scenario —
the latter three all replayed from checked-in recorded traces), at CI
smoke sizes.  The test re-runs them
and diffs the *timing-bearing* fields — status, horizon, message and
byte totals, per-task final vtimes/states, progress arrays, per-host
cell accounting — so an engine refactor cannot silently shift
simulated timings: any shift must come with a reviewed golden update.

Each golden also pins a ``perf`` record — the default engine's
``sync_rounds`` and ``proxy_syncs`` aggregates — so a
coordination-overhead regression (an engine suddenly needing more
rounds or proxy refreshes for the same simulation) fails CI instead of
relying on wall-clock eyeballing.  These are deterministic for a fixed
engine; they are allowed to *change* with a reviewed ``--regen``, just
never silently.

Other engine-dependent counters (wall clock, window sizes) stay
excluded — engines are free to trade those off.

Regenerate after an *intentional* timing change:

    PYTHONPATH=src python tests/test_golden_trace.py --regen
"""
import json
import pathlib
import sys

import pytest

from repro.sim import registry

GOLDEN = pathlib.Path(__file__).parent / "golden" / "gallery.json"

#: the canonical (deterministic, machine-independent) report subset
CANONICAL_FIELDS = registry.CANONICAL_FIELDS

N_ITERS = registry.N_ITERS
N_STEPS = registry.N_STEPS


def _gallery():
    # the gallery is the registry's source of truth now: every entry
    # tagged "gallery" (v1 factories moved verbatim, trace replays
    # included), keyed by bare name so gallery.json stays byte-stable
    return {registry.entry(ref).name: registry.entry(ref).make
            for ref in registry.names()
            if "gallery" in registry.entry(ref).tags}


def canonical(report) -> dict:
    d = report.to_dict()
    out = {k: d[k] for k in CANONICAL_FIELDS}
    out["perf"] = {"sync_rounds": report.sync_rounds,
                   "proxy_syncs": report.proxy_syncs}
    if report.live:
        # live sections (recovery timelines) are golden-pinned too;
        # omitted when empty so pre-live gallery rows stay byte-identical
        out["live"] = d["live"]
    return out


def vec_canonical(report) -> dict:
    """Canonical subset of a vectorized-engine run: the same
    timing-bearing fields plus the compiled tick/tier (no ``perf`` —
    round counts are engine-dependent)."""
    d = report.to_dict()
    out = {k: d[k] for k in CANONICAL_FIELDS}
    out["tier"] = report.tier
    out["tick_ns"] = report.tick_ns
    return out


def compute_traces() -> dict:
    from repro.sim import UnsupportedByEngine

    traces = {}
    for name, make in sorted(_gallery().items()):
        rec = canonical(make().run())
        try:
            # exact-tier scenarios additionally pin the vectorized
            # compiler's output; cpu_resource/cell scenarios raise and
            # simply carry no vectorized row
            rec["vectorized"] = vec_canonical(
                make().run(engine="vectorized", verify=True))
        except UnsupportedByEngine:
            pass
        traces[name] = rec
    return traces


@pytest.mark.parametrize("name", sorted(_gallery()))
def test_gallery_matches_golden_trace(name):
    golden = json.loads(GOLDEN.read_text())
    assert name in golden, (
        f"no golden trace for {name!r}; regenerate with "
        f"PYTHONPATH=src python {__file__} --regen")
    got = canonical(_gallery()[name]().run())
    want = golden[name]
    assert got.get("live") == want.get("live"), (
        f"{name}: live section shifted from the golden trace\n"
        f" got: {got.get('live')!r}\nwant: {want.get('live')!r}")
    for field in CANONICAL_FIELDS + ("perf",):
        assert got[field] == want[field], (
            f"{name}: {field} shifted from the golden trace "
            f"(intentional? regenerate with --regen and review the "
            f"diff)\n got: {got[field]!r}\nwant: {want[field]!r}")


#: gallery scenarios on the vectorized engine's admissible surface —
#: their golden records also pin the compiled (exact-tier) output
VEC_SCENARIOS = ("straggler_host_death", "degraded_link")


@pytest.mark.parametrize("name", VEC_SCENARIOS)
def test_gallery_vectorized_matches_golden_trace(name):
    golden = json.loads(GOLDEN.read_text())
    want = golden[name].get("vectorized")
    assert want is not None, (
        f"no vectorized golden for {name!r}; regenerate with "
        f"PYTHONPATH=src python {__file__} --regen")
    rep = _gallery()[name]().run(engine="vectorized", verify=True)
    got = vec_canonical(rep)
    assert rep.tier == "exact", f"{name}: compiled tier={rep.tier!r}"
    for field in CANONICAL_FIELDS + ("tier", "tick_ns"):
        assert got[field] == want[field], (
            f"{name}: vectorized {field} shifted from the golden "
            f"trace\n got: {got[field]!r}\nwant: {want[field]!r}")
    # and the compiled run must agree with the *reference engine's*
    # committed golden on every shared timing-bearing field: two
    # independently stored records, one simulation
    for field in CANONICAL_FIELDS:
        assert got[field] == golden[name][field], (
            f"{name}: vectorized diverges from the reference golden "
            f"on {field}: {got[field]!r} != {golden[name][field]!r}")


if __name__ == "__main__":
    if "--regen" not in sys.argv:
        sys.exit(f"usage: PYTHONPATH=src python {sys.argv[0]} --regen")
    GOLDEN.parent.mkdir(exist_ok=True)
    GOLDEN.write_text(json.dumps(compute_traces(), indent=1,
                                 sort_keys=True) + "\n")
    print(f"wrote {GOLDEN}")
