"""Cross-engine equivalence at the >=64-host regime.

The large regime is what the hot-path overhaul exists for — an indexed
scheduler core, incremental LBTS bounds, quiescent-host skipping, and
the batched binary dist transport all only matter when there are many
hosts/vtasks — so the bit-identical bar must hold *there*, not just on
the 4-host smoke topologies.  CI-sized iteration counts keep this
cheap; ``benchmarks/cluster_bench.py::main_multihost_large`` runs the
same shape at full size.
"""
import pytest

from engine_harness import assert_engines_agree
from repro.sim import (DegradeLink, RackRing, Scenario, Simulation,
                       Straggler, Topology)

N_RACKS = 16
PER_RACK = 4  # 64 hosts


def _make_sim(scenario):
    def make():
        wl = RackRing(n_racks=N_RACKS, hosts_per_rack=PER_RACK,
                      n_iters=6, skew_bound_ns=2_000_000)
        return Simulation(Topology.racks(N_RACKS, PER_RACK), wl,
                          scenario, placement=wl.default_placement())
    return make


@pytest.mark.parametrize("name,scenario", [
    ("baseline", Scenario()),
    ("straggler", Scenario("slow rack", (Straggler("w4", 3.0),
                                         Straggler("w5", 2.0)))),
    ("degraded", Scenario("slow fabric", (DegradeLink(
        fabric="hub", extra_ns=30_000, from_vtime=20_000),))),
])
def test_64_hosts_bit_identical_across_engines(name, scenario):
    """barrier / async / dist(1 and 4 OS workers) agree bit-exactly on
    a 64-host heterogeneous-latency rack topology."""
    reports = assert_engines_agree(
        _make_sim(scenario), dist_workers=4, worker_timeout=120.0,
        label=f"64 hosts/{name}")
    assert reports["async"].status == "ok"
    assert reports["async"].n_hosts == N_RACKS * PER_RACK
